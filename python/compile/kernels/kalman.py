"""L1 Pallas kernel: masked batched scalar-Kalman update (Dithen eqs. 6-9).

Dithen runs one scalar Kalman filter per (workload, media-type) pair to
estimate the compute-unit-seconds (CUS) cost ``b_{w,k}`` of one media item.
At every monitoring instant the whole bank of ``B = W_max * K_max`` filters
is updated at once; that update is the compute hot-spot of the control
plane and is what this kernel implements.

Per slot ``j`` (time update + conditional measurement update):

    pi_minus[j] = pi[j] + sigma_z2                       (eq. 6)
    kappa[j]    = pi_minus[j] / (pi_minus[j] + sigma_v2) (eq. 7)
    if meas_mask[j]:
        b'[j]  = b[j] + kappa[j] * (b_tilde[j] - b[j])   (eq. 8)
        pi'[j] = (1 - kappa[j]) * pi_minus[j]            (eq. 9)
    else:            # no measurement between t-1 and t: time update only
        b'[j]  = b[j]
        pi'[j] = pi_minus[j]

The mask is soft (0.0 / 1.0) so the whole bank is branch-free and
vectorizes on the VPU.  The kernel is tiled over slots with ``BlockSpec``
so one block (default 256 lanes x 3 input vectors + 2 outputs, f32) stays
well under VMEM limits; sigma_z^2 / sigma_v^2 ride along as a (2,) vector
broadcast into every block.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the rust runtime
(xla crate / PJRT CPU) executes directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256


def _kalman_kernel(b_ref, pi_ref, bt_ref, mask_ref, sig_ref, b_out_ref, pi_out_ref):
    """One block of the masked Kalman bank update."""
    b = b_ref[...]
    pi = pi_ref[...]
    bt = bt_ref[...]
    mask = mask_ref[...]
    sigma_z2 = sig_ref[0]
    sigma_v2 = sig_ref[1]

    pi_minus = pi + sigma_z2                       # eq. (6)
    kappa = pi_minus / (pi_minus + sigma_v2)       # eq. (7)
    innov = bt - b
    b_meas = b + kappa * innov                     # eq. (8)
    pi_meas = (1.0 - kappa) * pi_minus             # eq. (9)

    # soft-select measurement vs. pure time update
    b_out_ref[...] = mask * b_meas + (1.0 - mask) * b
    pi_out_ref[...] = mask * pi_meas + (1.0 - mask) * pi_minus


@functools.partial(jax.jit, static_argnames=("block",))
def kalman_update(b_hat, pi, b_tilde, meas_mask, sigmas, *, block: int = DEFAULT_BLOCK):
    """Masked Kalman bank update over a flat slot vector.

    Args:
      b_hat:     f32[B]   current CUS estimates.
      pi:        f32[B]   current error covariances.
      b_tilde:   f32[B]   newest CUS measurements (ignored where mask==0).
      meas_mask: f32[B]   1.0 where a measurement arrived, else 0.0.
      sigmas:    f32[2]   (sigma_z^2, sigma_v^2) process/measurement noise.
      block:     slots per Pallas block; B must be divisible by it (the
                 caller pads; see model.monitor_step).

    Returns:
      (b_hat', pi') both f32[B].
    """
    (n,) = b_hat.shape
    if n % block != 0:
        # fall back to one whole-array block for small/odd test shapes
        block = n
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    sig_spec = pl.BlockSpec((2,), lambda i: (0,))
    out_shape = [
        jax.ShapeDtypeStruct(b_hat.shape, b_hat.dtype),
        jax.ShapeDtypeStruct(pi.shape, pi.dtype),
    ]
    return tuple(
        pl.pallas_call(
            _kalman_kernel,
            grid=grid,
            in_specs=[spec, spec, spec, spec, sig_spec],
            out_specs=[spec, spec],
            out_shape=out_shape,
            interpret=True,
        )(b_hat, pi, b_tilde, meas_mask, sigmas)
    )
