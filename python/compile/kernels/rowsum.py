"""L1 Pallas kernel: masked weighted row-reduction (Dithen eq. 1).

Computes, per workload ``w``, the required compute-unit-seconds

    r_w = sum_k  m_{w,k} * slot_mask_{w,k} * b_hat_{w,k}

over the ``[W, K]`` (workload x media-type) slot matrix.  This is the
reduction half of the monitoring-instant update; the elementwise Kalman
half lives in kernels/kalman.py.

Tiled with ``BlockSpec`` over the workload axis; K (media types per
workload, <= 16 in practice) always fits one block row, so each grid step
reduces a ``(block_w, K)`` tile to ``(block_w,)`` partial outputs with a
single in-VMEM row sum — no cross-block accumulation needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_W = 64


def _rowsum_kernel(m_ref, mask_ref, b_ref, r_out_ref):
    m = m_ref[...]
    mask = mask_ref[...]
    b = b_ref[...]
    r_out_ref[...] = jnp.sum(m * mask * b, axis=1)


@functools.partial(jax.jit, static_argnames=("block_w",))
def required_cus(m_rem, slot_mask, b_hat, *, block_w: int = DEFAULT_BLOCK_W):
    """Masked weighted row sum: r[w] = sum_k m[w,k]*mask[w,k]*b[w,k].

    Args:
      m_rem:     f32[W, K] remaining media items per slot.
      slot_mask: f32[W, K] 1.0 for active slots.
      b_hat:     f32[W, K] CUS estimates per slot.
      block_w:   workloads per Pallas block; W must divide (caller pads).

    Returns:
      f32[W] required CUSs per workload (eq. 1).
    """
    w, k = m_rem.shape
    if w % block_w != 0:
        block_w = w
    grid = (w // block_w,)
    in_spec = pl.BlockSpec((block_w, k), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_w,), lambda i: (i,))
    return pl.pallas_call(
        _rowsum_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((w,), b_hat.dtype),
        interpret=True,
    )(m_rem, slot_mask, b_hat)
