"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest/hypothesis suite checks the Pallas
kernels against (and, transitively, what the rust-side estimator bank is
validated against through the AOT artifact parity tests).
"""

from __future__ import annotations

import jax.numpy as jnp


def kalman_update_ref(b_hat, pi, b_tilde, meas_mask, sigmas):
    """Reference masked Kalman bank update (Dithen eqs. 6-9)."""
    sigma_z2, sigma_v2 = sigmas[0], sigmas[1]
    pi_minus = pi + sigma_z2
    kappa = pi_minus / (pi_minus + sigma_v2)
    b_meas = b_hat + kappa * (b_tilde - b_hat)
    pi_meas = (1.0 - kappa) * pi_minus
    b_new = meas_mask * b_meas + (1.0 - meas_mask) * b_hat
    pi_new = meas_mask * pi_meas + (1.0 - meas_mask) * pi_minus
    return b_new, pi_new


def required_cus_ref(m_rem, slot_mask, b_hat):
    """Reference masked weighted row sum (Dithen eq. 1)."""
    return jnp.sum(m_rem * slot_mask * b_hat, axis=1)


def service_rates_ref(r, d, wl_mask, n_tot, alpha, beta, n_w_max=jnp.inf):
    """Reference proportional-fair service rates (Dithen eqs. 11-14).

    s*_w = r_w / d_w; if N* > N_tot + alpha downscale by (N_tot+alpha)/N*,
    if N* < beta*N_tot upscale by beta*N_tot/N*, else keep.
    """
    safe_d = jnp.where(d > 0.0, d, 1.0)
    s_star = jnp.minimum(jnp.where(wl_mask > 0.0, r / safe_d, 0.0), n_w_max)
    n_star = jnp.sum(s_star)
    hi = n_tot + alpha
    lo = beta * n_tot
    scale = jnp.where(
        n_star > hi,
        hi / jnp.maximum(n_star, 1e-30),
        jnp.where(n_star < lo, lo / jnp.maximum(n_star, 1e-30), 1.0),
    )
    # no demand at all -> no scaling
    scale = jnp.where(n_star > 0.0, scale, 1.0)
    return s_star * scale, n_star


def aimd_ref(n_tot, n_star, alpha, beta, n_min, n_max):
    """Reference AIMD step (Dithen Fig. 4)."""
    incr = n_tot <= n_star
    up = jnp.minimum(n_tot + alpha, n_max)
    down = jnp.maximum(beta * n_tot, n_min)
    return jnp.where(incr, up, down)
