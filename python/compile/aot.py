"""AOT: lower the L2 monitor_step graph to HLO *text* artifacts.

The interchange format is HLO text, not ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The HLO text parser on the rust side reassigns ids, so text round-trips
cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Emits one artifact per (W, K) bank-shape variant plus a manifest the rust
runtime uses to pick a variant at startup.  Adding a variant is a one-line
change to ``VARIANTS``.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

#: (W, K) bank shapes to pre-compile.  W = max concurrent workloads,
#: K = max media types per workload.  The paper's experiments use 30
#: workloads x 1 media type; 64x4 is the default runtime variant, the
#: others serve tests (small) and headroom/perf study (large).
VARIANTS = ((8, 2), (64, 4), (256, 8))

MANIFEST = "manifest.json"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(w: int, k: int) -> str:
    lowered = jax.jit(model.monitor_step).lower(*model.example_args(w, k))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=",".join(f"{w}x{k}" for w, k in VARIANTS),
        help="comma-separated WxK list, e.g. 64x4,256x8",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    variants = []
    for spec in args.variants.split(","):
        w, k = (int(x) for x in spec.strip().split("x"))
        name = f"monitor_step_w{w}k{k}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_variant(w, k)
        with open(path, "w") as f:
            f.write(text)
        variants.append({"w": w, "k": k, "file": name})
        print(f"wrote {name}: {len(text)} chars")

    manifest = {
        "format": "hlo-text",
        "params_layout": list(model.PARAMS_LAYOUT),
        "outputs": ["b_hat", "pi", "r", "s", "n_star", "n_next"],
        "variants": variants,
    }
    with open(os.path.join(args.out_dir, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {MANIFEST} ({len(variants)} variants)")


if __name__ == "__main__":
    main()
