"""L2: the Dithen monitoring-instant compute graph.

``monitor_step`` is the whole per-tick numeric workload of the Global
Controller Instance (GCI), fused into one jitted graph:

  1. masked Kalman bank update of all W*K CUS estimators   (L1 kernel)
  2. required CUSs per workload, r_w = sum_k m*b            (L1 kernel)
  3. proportional-fair service rates s_w with AIMD-aware
     up/down scaling                                        (eqs. 11-14)
  4. the AIMD decision for N_tot[t+1]                       (Fig. 4)

Python only runs at *build* time: aot.py lowers this function once per
(W, K) variant to HLO text, and the rust coordinator executes the artifact
through PJRT on every monitoring tick.

Conventions: all arrays are f32; W and K are compile-time constants baked
into each artifact; inactive slots carry ``slot_mask == 0`` and are
numerically inert. Scalar knobs are packed into ``params`` so the artifact
has a small, fixed argument list:

  params = f32[8]:
    [sigma_z2, sigma_v2, n_tot, alpha, beta, n_min, n_max, n_w_max]
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.kalman import kalman_update
from .kernels.rowsum import required_cus

#: index layout of the packed scalar parameter vector
PARAMS_LAYOUT = (
    "sigma_z2", "sigma_v2", "n_tot", "alpha", "beta", "n_min", "n_max", "n_w_max",
)
N_PARAMS = len(PARAMS_LAYOUT)


def monitor_step(b_hat, pi, b_tilde, meas_mask, m_rem, slot_mask, d, params):
    """One Dithen monitoring instant over the full estimator bank.

    Args:
      b_hat:     f32[W, K] CUS estimates.
      pi:        f32[W, K] Kalman error covariances.
      b_tilde:   f32[W, K] new CUS measurements.
      meas_mask: f32[W, K] 1.0 where b_tilde is a real measurement.
      m_rem:     f32[W, K] remaining media items.
      slot_mask: f32[W, K] 1.0 for active (workload, media-type) slots.
      d:         f32[W]    remaining time-to-completion per workload (s).
      params:    f32[8]    packed scalars, see PARAMS_LAYOUT.

    Returns tuple:
      b_hat':  f32[W, K] updated estimates
      pi':     f32[W, K] updated covariances
      r:       f32[W]    required CUSs per workload (eq. 1)
      s:       f32[W]    adjusted service rates (eqs. 11-14)
      n_star:  f32[]     optimal total CUs (eq. 12)
      n_next:  f32[]     AIMD CU target for t+1 (Fig. 4)
    """
    w, k = b_hat.shape
    sigma_z2, sigma_v2, n_tot, alpha, beta, n_min, n_max, n_w_max = (
        params[i] for i in range(N_PARAMS)
    )
    sigmas = jnp.stack([sigma_z2, sigma_v2])

    # --- 1. Kalman bank update (Pallas, flat over B = W*K slots) --------
    flat = lambda a: a.reshape(w * k)
    b_new, pi_new = kalman_update(
        flat(b_hat), flat(pi), flat(b_tilde), flat(meas_mask), sigmas
    )
    b_new = b_new.reshape(w, k)
    pi_new = pi_new.reshape(w, k)
    # estimators only exist on active slots
    b_new = slot_mask * b_new + (1.0 - slot_mask) * b_hat
    pi_new = slot_mask * pi_new + (1.0 - slot_mask) * pi

    # --- 2. required CUSs per workload (Pallas row reduction) -----------
    r = required_cus(m_rem, slot_mask, b_new)

    # --- 3. proportional-fair service rates (eqs. 11-14) ----------------
    wl_mask = (jnp.sum(slot_mask, axis=1) > 0.0).astype(b_hat.dtype)
    safe_d = jnp.where(d > 0.0, d, 1.0)
    # eq. (11), with the per-workload cap N_{w,max} (§II-E-4): a workload
    # can never use more than n_w_max CUs, so demand beyond it is inert
    s_star = jnp.minimum(jnp.where(wl_mask > 0.0, r / safe_d, 0.0), n_w_max)
    n_star = jnp.sum(s_star)                                    # eq. (12)
    hi = n_tot + alpha
    lo = beta * n_tot
    denom = jnp.maximum(n_star, jnp.asarray(1e-30, b_hat.dtype))
    scale = jnp.where(n_star > hi, hi / denom,                  # eq. (13)
                      jnp.where(n_star < lo, lo / denom, 1.0))  # eq. (14)
    scale = jnp.where(n_star > 0.0, scale, 1.0)
    s = s_star * scale

    # --- 4. AIMD decision for the next instant (Fig. 4) -----------------
    n_next = jnp.where(
        n_tot <= n_star,
        jnp.minimum(n_tot + alpha, n_max),
        jnp.maximum(beta * n_tot, n_min),
    )

    return b_new, pi_new, r, s, n_star, n_next


def example_args(w: int, k: int):
    """ShapeDtypeStructs for lowering a (W, K) variant."""
    f32 = jnp.float32
    mat = jax.ShapeDtypeStruct((w, k), f32)
    return (
        mat, mat, mat, mat, mat, mat,
        jax.ShapeDtypeStruct((w,), f32),
        jax.ShapeDtypeStruct((N_PARAMS,), f32),
    )


@functools.lru_cache(maxsize=None)
def jitted():
    return jax.jit(monitor_step)
