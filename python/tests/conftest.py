import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

jax.config.update("jax_enable_x64", True)
jax.config.update("jax_platform_name", "cpu")
