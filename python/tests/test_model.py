"""L2 correctness: monitor_step graph semantics + shapes + AOT lowering."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")
F32 = np.float32


def make_params(sigma_z2=0.5, sigma_v2=0.5, n_tot=10.0, alpha=5.0, beta=0.9,
                n_min=10.0, n_max=100.0, n_w_max=10.0):
    return np.array(
        [sigma_z2, sigma_v2, n_tot, alpha, beta, n_min, n_max, n_w_max], F32
    )


def random_state(w, k, seed=0, active=0.8, measured=0.6):
    rng = np.random.default_rng(seed)
    b_hat = rng.uniform(0, 500, (w, k)).astype(F32)
    pi = rng.uniform(0, 5, (w, k)).astype(F32)
    b_tilde = rng.uniform(0, 500, (w, k)).astype(F32)
    slot_mask = (rng.uniform(size=(w, k)) < active).astype(F32)
    meas_mask = ((rng.uniform(size=(w, k)) < measured) * slot_mask).astype(F32)
    m_rem = (rng.integers(0, 1000, (w, k)) * slot_mask).astype(F32)
    d = rng.uniform(60, 7620, w).astype(F32)
    return b_hat, pi, b_tilde, meas_mask, m_rem, slot_mask, d


def run_step(w, k, seed=0, **pkw):
    state = random_state(w, k, seed)
    params = make_params(**pkw)
    out = model.jitted()(*state, params)
    return state, params, out


def test_shapes():
    w, k = 16, 4
    _, _, (b, pi, r, s, n_star, n_next) = run_step(w, k)
    assert b.shape == (w, k) and pi.shape == (w, k)
    assert r.shape == (w,) and s.shape == (w,)
    assert n_star.shape == () and n_next.shape == ()


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 48), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_matches_composed_reference(w, k, seed):
    """monitor_step == ref-Kalman + ref-rowsum + ref-rates + ref-AIMD."""
    state = random_state(w, k, seed)
    b_hat, pi, b_tilde, meas_mask, m_rem, slot_mask, d = state
    params = make_params()
    got = model.jitted()(*state, params)

    sig = np.array([0.5, 0.5], F32)
    want_b, want_pi = ref.kalman_update_ref(b_hat, pi, b_tilde, meas_mask, sig)
    want_b = slot_mask * want_b + (1 - slot_mask) * b_hat
    want_pi = slot_mask * want_pi + (1 - slot_mask) * pi
    want_r = ref.required_cus_ref(m_rem, slot_mask, np.asarray(want_b))
    wl_mask = (slot_mask.sum(axis=1) > 0).astype(F32)
    want_s, want_nstar = ref.service_rates_ref(
        np.asarray(want_r), d, wl_mask, 10.0, 5.0, 0.9, 10.0
    )
    want_next = ref.aimd_ref(10.0, want_nstar, 5.0, 0.9, 10.0, 100.0)

    np.testing.assert_allclose(got[0], want_b, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got[1], want_pi, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got[2], want_r, rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(got[3], want_s, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(got[4], want_nstar, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(got[5], want_next, rtol=1e-5)


def test_service_rates_respect_aimd_bounds():
    """After adjustment, sum(s) <= N_tot + alpha whenever demand had to be
    downscaled (eq. 13)."""
    w, k = 32, 4
    state = random_state(w, k, seed=3)
    params = make_params(n_tot=5.0, alpha=5.0)
    out = model.jitted()(*state, params)
    s, n_star = np.asarray(out[3]), float(out[4])
    if n_star > 5.0 + 5.0:
        assert s.sum() <= 5.0 + 5.0 + 1e-2


def test_aimd_additive_increase_and_cap():
    # huge demand -> increase by alpha, capped at n_max
    state = random_state(8, 2, seed=4)
    out = model.jitted()(
        *state, make_params(n_tot=98.0, alpha=5.0, n_max=100.0, n_w_max=1e9)
    )
    assert float(out[5]) == 100.0
    out = model.jitted()(
        *state, make_params(n_tot=20.0, alpha=5.0, n_max=100.0, n_w_max=1e9)
    )
    n_star = float(out[4])
    if n_star >= 20.0:
        assert float(out[5]) == 25.0


def test_aimd_multiplicative_decrease_and_floor():
    # zero demand -> decrease by beta, floored at n_min
    w, k = 8, 2
    zeros = np.zeros((w, k), F32)
    d = np.full(w, 3600.0, F32)
    args = (zeros, zeros, zeros, zeros, zeros, zeros, d)
    out = model.jitted()(*args, make_params(n_tot=50.0, beta=0.9, n_min=10.0))
    assert abs(float(out[5]) - 45.0) < 1e-4
    out = model.jitted()(*args, make_params(n_tot=10.5, beta=0.9, n_min=10.0))
    assert float(out[5]) == 10.0


def test_inactive_slots_are_inert():
    """A fully-masked slot's state must pass through unchanged."""
    w, k = 8, 2
    state = list(random_state(w, k, seed=5, active=1.0))
    state[5] = np.zeros((w, k), F32)  # slot_mask
    out = model.jitted()(*state, make_params())
    np.testing.assert_allclose(out[0], state[0], rtol=1e-6)
    np.testing.assert_allclose(out[1], state[1], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[2]), np.zeros(w, F32))


def test_aot_lowering_roundtrip(tmp_path):
    """lower -> HLO text -> non-empty, parseable header, deterministic."""
    from compile import aot

    text = aot.lower_variant(8, 2)
    assert "HloModule" in text and "ENTRY" in text
    text2 = aot.lower_variant(8, 2)
    assert text == text2


def test_aot_variant_shapes_in_hlo():
    from compile import aot

    text = aot.lower_variant(8, 2)
    assert "f32[8,2]" in text and "f32[8]" in text
