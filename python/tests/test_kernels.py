"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, dtypes, masks and magnitudes; every property
asserts allclose against ref.py.  This is the core numeric signal the rest
of the stack (AOT artifact -> rust runtime) inherits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.kalman import kalman_update
from compile.kernels.rowsum import required_cus

jax.config.update("jax_platform_name", "cpu")

F32 = np.float32
F64 = np.float64


def _tol(dtype):
    return dict(rtol=1e-5, atol=1e-5) if dtype == F32 else dict(rtol=1e-12, atol=1e-12)


@st.composite
def kalman_case(draw):
    n = draw(st.integers(min_value=1, max_value=1024))
    dtype = draw(st.sampled_from([F32, F64]))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    b_hat = rng.uniform(0.0, 1e4, n).astype(dtype)
    pi = rng.uniform(0.0, 10.0, n).astype(dtype)
    b_tilde = rng.uniform(0.0, 1e4, n).astype(dtype)
    mask = (rng.uniform(size=n) < draw(st.floats(0.0, 1.0))).astype(dtype)
    sigmas = np.array(
        [draw(st.floats(1e-3, 5.0)), draw(st.floats(1e-3, 5.0))], dtype=dtype
    )
    return b_hat, pi, b_tilde, mask, sigmas


@settings(max_examples=60, deadline=None)
@given(kalman_case())
def test_kalman_matches_ref(case):
    b_hat, pi, b_tilde, mask, sigmas = case
    got_b, got_pi = kalman_update(b_hat, pi, b_tilde, mask, sigmas)
    want_b, want_pi = ref.kalman_update_ref(b_hat, pi, b_tilde, mask, sigmas)
    tol = _tol(b_hat.dtype)
    np.testing.assert_allclose(got_b, want_b, **tol)
    np.testing.assert_allclose(got_pi, want_pi, **tol)


@settings(max_examples=40, deadline=None)
@given(
    st.integers(1, 300),
    st.integers(1, 16),
    st.sampled_from([F32, F64]),
    st.integers(0, 2**31 - 1),
)
def test_rowsum_matches_ref(w, k, dtype, seed):
    rng = np.random.default_rng(seed)
    m = rng.integers(0, 1000, (w, k)).astype(dtype)
    mask = (rng.uniform(size=(w, k)) < 0.7).astype(dtype)
    b = rng.uniform(0.0, 100.0, (w, k)).astype(dtype)
    got = required_cus(m, mask, b)
    want = ref.required_cus_ref(m, mask, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_kalman_paper_initialization_converges():
    """Paper init: b_hat[0]=pi[0]=0, sigma_z2=sigma_v2=0.5; constant
    measurements must converge to the measured value (underdamped from 0)."""
    n = 4
    b = np.zeros(n, F32)
    pi = np.zeros(n, F32)
    sig = np.array([0.5, 0.5], F32)
    target = np.full(n, 37.0, F32)
    ones = np.ones(n, F32)
    for _ in range(50):
        b, pi = kalman_update(b, pi, target, ones, sig)
    np.testing.assert_allclose(np.asarray(b), target, rtol=1e-3)


def test_kalman_gain_bounds():
    """kappa in (0,1): update never overshoots the innovation."""
    n = 64
    rng = np.random.default_rng(0)
    b = rng.uniform(0, 100, n).astype(F32)
    pi = rng.uniform(0, 5, n).astype(F32)
    bt = rng.uniform(0, 100, n).astype(F32)
    ones = np.ones(n, F32)
    sig = np.array([0.5, 0.5], F32)
    b2, _ = kalman_update(b, pi, bt, ones, sig)
    lo = np.minimum(b, bt) - 1e-4
    hi = np.maximum(b, bt) + 1e-4
    assert np.all(np.asarray(b2) >= lo) and np.all(np.asarray(b2) <= hi)


def test_kalman_mask_zero_is_time_update_only():
    n = 8
    rng = np.random.default_rng(1)
    b = rng.uniform(0, 100, n).astype(F32)
    pi = rng.uniform(0, 5, n).astype(F32)
    bt = rng.uniform(0, 100, n).astype(F32)
    zeros = np.zeros(n, F32)
    sig = np.array([0.5, 0.25], F32)
    b2, pi2 = kalman_update(b, pi, bt, zeros, sig)
    np.testing.assert_allclose(np.asarray(b2), b, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(pi2), pi + 0.5, rtol=1e-6)


def test_rowsum_empty_mask_is_zero():
    w, k = 16, 4
    m = np.full((w, k), 5.0, F32)
    b = np.full((w, k), 3.0, F32)
    got = required_cus(m, np.zeros((w, k), F32), b)
    np.testing.assert_allclose(np.asarray(got), np.zeros(w, F32))


@pytest.mark.parametrize("block", [32, 64, 256])
def test_kalman_block_size_invariance(block):
    """Result must not depend on the Pallas BlockSpec tiling."""
    n = 512
    rng = np.random.default_rng(2)
    b = rng.uniform(0, 100, n).astype(F32)
    pi = rng.uniform(0, 5, n).astype(F32)
    bt = rng.uniform(0, 100, n).astype(F32)
    mask = (rng.uniform(size=n) < 0.5).astype(F32)
    sig = np.array([0.5, 0.5], F32)
    got = kalman_update(b, pi, bt, mask, sig, block=block)
    want = kalman_update(b, pi, bt, mask, sig, block=n)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=1e-6)
