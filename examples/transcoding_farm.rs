//! Transcoding farm — a domain-specific deployment scenario.
//!
//! The §I motivation: a video service wants to transcode large nightly
//! batches with a hard delivery deadline, at minimum spot cost. This
//! example builds a custom workload mix (three transcode batches of very
//! different sizes arriving close together — the worst case for reactive
//! provisioning), runs it under AIMD and under Reactive, and compares
//! cost, instance peaks and deadline compliance.
//!
//! Run:  cargo run --release --example transcoding_farm

use dithen::config::Config;
use dithen::coordinator::PolicyKind;
use dithen::platform::{run_experiment, RunOpts};
use dithen::util::rng::Rng;
use dithen::util::table::{fmt_hm, Table};
use dithen::workload::{App, WorkloadSpec};

fn suite(seed: u64) -> Vec<WorkloadSpec> {
    let rng = Rng::new(seed);
    // 40 / 250 / 120 videos, arriving 5 minutes apart
    vec![
        WorkloadSpec::generate(0, App::Transcode, 40, None, &rng),
        WorkloadSpec::generate(1, App::Transcode, 250, None, &rng),
        WorkloadSpec::generate(2, App::Transcode, 120, None, &rng),
    ]
}

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::paper_defaults();
    cfg.control.monitor_interval_s = 300;
    let deadline = 2 * 3600; // 2 h delivery SLA

    let mut t = Table::new(vec![
        "policy",
        "cost ($)",
        "max instances",
        "finished",
        "deadlines met",
    ]);
    for policy in [PolicyKind::Aimd, PolicyKind::Reactive] {
        let m = run_experiment(cfg.clone(), suite(cfg.seed), RunOpts {
            policy,
            fixed_ttc_s: Some(deadline),
            horizon_s: 12 * 3600,
            ..Default::default()
        })?;
        t.row(vec![
            policy.name().to_string(),
            format!("{:.3}", m.total_cost),
            format!("{}", m.max_instances),
            fmt_hm(m.finished_at as f64),
            format!("{:.0}%", 100.0 * m.ttc_compliance()),
        ]);
    }
    t.print();
    println!("transcoding_farm OK");
    Ok(())
}
