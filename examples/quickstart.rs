//! Quickstart — the end-to-end driver.
//!
//! Runs the complete Dithen platform on the paper's 30-workload
//! multimedia suite (≈9 000 tasks, ≈29 GB of simulated media input):
//! workloads arrive every 5 minutes, are footprinted, Kalman-estimated
//! (AOT-compiled Pallas/JAX estimator bank via PJRT when `artifacts/`
//! exists), scheduled with proportional-fair service rates, and the AIMD
//! controller scales the simulated EC2 spot fleet. Prints the headline
//! metrics the paper reports: billing cost vs the lower bound, max
//! instances, and TTC compliance.
//!
//! Run:  cargo run --release --example quickstart

use dithen::config::Config;
use dithen::coordinator::PolicyKind;
use dithen::estimation::EstimatorKind;
use dithen::platform::{Platform, RunOpts};
use dithen::util::table::{fmt_hm, Table};
use dithen::workload::paper_suite;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::paper_defaults();
    cfg.control.monitor_interval_s = 300;
    let suite = paper_suite(cfg.seed);
    let n_tasks: usize = suite.iter().map(|w| w.n_tasks()).sum();
    let gb: f64 = suite.iter().map(|w| w.total_bytes()).sum::<u64>() as f64 / 1e9;
    println!("suite: {} workloads, {n_tasks} tasks, {gb:.1} GB input", suite.len());

    let opts = RunOpts {
        policy: PolicyKind::Aimd,
        estimator: EstimatorKind::Kalman,
        fixed_ttc_s: Some(2 * 3600 + 7 * 60), // the paper's 2 hr 07 min
        horizon_s: 16 * 3600,
        ..Default::default()
    };
    let platform = Platform::new(cfg.clone(), suite, opts);
    println!("estimator bank backend: {}", platform.backend_name());
    let m = platform.run()?;

    let lb = m.lower_bound_cost(cfg.market.base_spot_price);
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["completed at".to_string(), fmt_hm(m.finished_at as f64)])
        .row(vec!["total billing cost".to_string(), format!("${:.3}", m.total_cost)])
        .row(vec!["lower bound (100% occupancy)".to_string(), format!("${lb:.3}")])
        .row(vec!["cost vs LB".to_string(), format!("+{:.0}%", 100.0 * (m.total_cost - lb) / lb)])
        .row(vec!["max concurrent instances".to_string(), format!("{}", m.max_instances)])
        .row(vec!["TTC compliance".to_string(), format!("{:.0}%", 100.0 * m.ttc_compliance())])
        .row(vec!["monitoring ticks".to_string(), format!("{}", m.ticks)])
        .row(vec!["mean tick time".to_string(), format!("{:.1} µs", m.mean_tick_ns() / 1e3)]);
    t.print();

    assert!(m.ttc_compliance() >= 0.99, "quickstart must meet its TTCs");
    assert!(m.total_cost < 2.0 * lb + 0.2, "cost should be within ~2x of LB");
    println!("quickstart OK");
    Ok(())
}
