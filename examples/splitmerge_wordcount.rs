//! Split–Merge word histogram — the §II-B-2 advanced processing mode.
//!
//! Reproduces the §V-E MapReduce-style workload end to end: ~14 000
//! Gutenberg-like text files are word-counted in parallel (Split), the
//! partial histograms aggregated on a designated instance (Merge), under
//! a 1 h 05 min TTC with the split stage budgeted at 90 %.
//!
//! Run:  cargo run --release --example splitmerge_wordcount

use dithen::config::Config;
use dithen::platform::{run_experiment, RunOpts};
use dithen::util::table::{fmt_hm, Table};
use dithen::workload::wordcount_splitmerge;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::paper_defaults();
    cfg.control.monitor_interval_s = 300;
    let spec = wordcount_splitmerge(cfg.seed);
    println!(
        "workload: {} text files, {:.1} GB",
        spec.n_tasks(),
        spec.total_bytes() as f64 / 1e9
    );
    let ttc = 3600 + 5 * 60;
    let m = run_experiment(cfg.clone(), vec![spec], RunOpts {
        fixed_ttc_s: Some((ttc as f64 * 0.9) as u64),
        horizon_s: 12 * 3600,
        ..Default::default()
    })?;
    let lb = m.lower_bound_cost(cfg.market.base_spot_price);
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["cost".to_string(), format!("${:.3}", m.total_cost)])
        .row(vec!["lower bound".to_string(), format!("${lb:.3}")])
        .row(vec!["finished".to_string(), fmt_hm(m.finished_at as f64)])
        .row(vec!["max instances".to_string(), format!("{}", m.max_instances)]);
    t.print();
    assert!(m.outcomes[0].completed_at.is_some());
    println!("splitmerge_wordcount OK");
    Ok(())
}
