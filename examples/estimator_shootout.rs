//! Estimator shootout — drive the platform with each CUS estimator.
//!
//! Table II compares Kalman vs ad-hoc vs ARMA passively; this example
//! goes further and lets each estimator *drive* scheduling and scaling
//! (service rates + AIMD demand), showing how estimation quality
//! propagates into cost and deadline behaviour.
//!
//! Run:  cargo run --release --example estimator_shootout

use dithen::config::Config;
use dithen::estimation::EstimatorKind;
use dithen::platform::{run_experiment, RunOpts};
use dithen::util::table::{fmt_hm, Table};
use dithen::workload::paper_suite;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::paper_defaults();
    cfg.control.monitor_interval_s = 300;
    let mut t = Table::new(vec![
        "driving estimator",
        "cost ($)",
        "max instances",
        "finished",
        "TTC compliance",
    ]);
    for est in EstimatorKind::ALL {
        let m = run_experiment(cfg.clone(), paper_suite(cfg.seed), RunOpts {
            estimator: est,
            fixed_ttc_s: Some(7620),
            horizon_s: 16 * 3600,
            ..Default::default()
        })?;
        t.row(vec![
            est.name().to_string(),
            format!("{:.3}", m.total_cost),
            format!("{}", m.max_instances),
            fmt_hm(m.finished_at as f64),
            format!("{:.0}%", 100.0 * m.ttc_compliance()),
        ]);
    }
    t.print();
    println!("estimator_shootout OK");
    Ok(())
}
