//! Integration tests: whole-platform runs across the runtime + substrate
//! boundary. These assert the *relationships* the paper's evaluation
//! rests on, not exact dollar values.

use dithen::config::Config;
use dithen::coordinator::PolicyKind;
use dithen::estimation::EstimatorKind;
use dithen::platform::{run_experiment, Platform, RunOpts};
use dithen::util::rng::Rng;
use dithen::workload::{paper_suite, App, WorkloadSpec};

fn cfg(native: bool) -> Config {
    let mut c = Config::paper_defaults();
    c.use_xla = !native;
    c.control.monitor_interval_s = 300;
    c
}

fn opts(policy: PolicyKind, ttc: Option<u64>) -> RunOpts {
    RunOpts { policy, fixed_ttc_s: ttc, horizon_s: 16 * 3600, ..Default::default() }
}

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

#[test]
fn xla_and_native_full_runs_agree() {
    // The AOT Pallas/JAX artifact and the native bank must produce the
    // same *platform-level* outcome (f32 round-off cannot flip discrete
    // decisions in this deterministic suite).
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let suite = paper_suite(1234);
    let a = {
        let p = Platform::new(cfg(false), suite.clone(), opts(PolicyKind::Aimd, Some(7620)));
        assert_eq!(p.backend_name(), "xla", "artifacts exist; must pick xla");
        p.run().unwrap()
    };
    let b = {
        let p = Platform::new(cfg(true), suite, opts(PolicyKind::Aimd, Some(7620)));
        assert_eq!(p.backend_name(), "native");
        p.run().unwrap()
    };
    assert_eq!(a.max_instances, b.max_instances);
    assert_eq!(a.finished_at, b.finished_at);
    assert!((a.total_cost - b.total_cost).abs() < 1e-6);
}

#[test]
fn aimd_meets_all_ttcs_on_paper_suite() {
    let m = run_experiment(cfg(true), paper_suite(Config::paper_defaults().seed), opts(PolicyKind::Aimd, Some(7620)))
        .unwrap();
    assert_eq!(m.outcomes.len(), 30);
    assert!(m.outcomes.iter().all(|o| o.completed_at.is_some()));
    assert!(m.ttc_compliance() >= 0.999, "compliance {}", m.ttc_compliance());
}

#[test]
fn aimd_cheaper_than_reactive_and_as() {
    let c = cfg(true);
    let aimd = run_experiment(c.clone(), paper_suite(c.seed), opts(PolicyKind::Aimd, Some(7620))).unwrap();
    let reactive =
        run_experiment(c.clone(), paper_suite(c.seed), opts(PolicyKind::Reactive, Some(7620))).unwrap();
    let amazon =
        run_experiment(c.clone(), paper_suite(c.seed), opts(PolicyKind::AmazonAs1, None)).unwrap();
    assert!(
        aimd.total_cost < reactive.total_cost,
        "AIMD {} !< Reactive {}",
        aimd.total_cost,
        reactive.total_cost
    );
    assert!(
        aimd.total_cost < amazon.total_cost,
        "AIMD {} !< AS {}",
        aimd.total_cost,
        amazon.total_cost
    );
    // paper's Table III shape: AS roughly 1.5-4x the proposed method
    let ratio = amazon.total_cost / aimd.total_cost;
    assert!(ratio > 1.3, "AS/AIMD ratio {ratio} too small");
}

#[test]
fn every_run_cost_at_least_lower_bound() {
    let c = cfg(true);
    for policy in [PolicyKind::Aimd, PolicyKind::Mwa, PolicyKind::Lr] {
        let m = run_experiment(c.clone(), paper_suite(c.seed), opts(policy, Some(7620))).unwrap();
        let lb = m.lower_bound_cost(c.market.base_spot_price);
        assert!(m.total_cost >= lb, "{policy:?}: {} < LB {lb}", m.total_cost);
    }
}

#[test]
fn estimator_choice_preserves_completion() {
    let c = cfg(true);
    for est in EstimatorKind::ALL {
        let mut o = opts(PolicyKind::Aimd, Some(7620));
        o.estimator = est;
        let m = run_experiment(c.clone(), paper_suite(c.seed), o).unwrap();
        assert!(
            m.outcomes.iter().all(|x| x.completed_at.is_some()),
            "{est:?} left workloads unfinished"
        );
    }
}

#[test]
fn kalman_converges_on_all_long_workloads() {
    let c = cfg(true);
    let suite = paper_suite(c.seed);
    let m = run_experiment(c, suite.clone(), opts(PolicyKind::Aimd, Some(7620))).unwrap();
    for (w, spec) in suite.iter().enumerate() {
        // long workloads (many monitoring instants of wall time — small
        // task counts can finish inside one interval) must reach t_init
        if spec.total_true_cus() >= 5000.0 {
            let tr = &m.traces[&(w, 0)];
            assert!(
                tr.kalman_t_init.is_some(),
                "workload {w} ({}) never converged",
                spec.name
            );
        }
    }
}

#[test]
fn aimd_instance_count_bounded_by_fig4() {
    let c = cfg(true);
    let m = run_experiment(c.clone(), paper_suite(c.seed), opts(PolicyKind::Aimd, Some(7620))).unwrap();
    // N_max = 100 plus transient boot overlap; AIMD on this suite stays
    // in the paper's low-teens band
    assert!(m.max_instances <= 25, "AIMD used {} instances", m.max_instances);
}

#[test]
fn heterogeneous_mixed_suite_completes() {
    // all app classes + a split-merge in one run
    let rng = Rng::new(7);
    let mut suite: Vec<WorkloadSpec> = vec![
        WorkloadSpec::generate(0, App::FaceDetection, 150, None, &rng),
        WorkloadSpec::generate(1, App::SiftMatlab, 80, None, &rng),
        WorkloadSpec::generate(2, App::ImBlur, 300, None, &rng),
        WorkloadSpec::generate(3, App::WordHistogram, 500, None, &rng),
    ];
    suite.push(WorkloadSpec::generate_mode(
        4,
        App::CnnClassify,
        120,
        dithen::workload::Mode::SplitMerge { merge_frac: 0.05 },
        None,
        &rng,
    ));
    let m = run_experiment(cfg(true), suite, opts(PolicyKind::Aimd, Some(5400))).unwrap();
    assert!(m.outcomes.iter().all(|o| o.completed_at.is_some()));
}

#[test]
fn seeds_produce_different_but_valid_runs() {
    let mut c1 = cfg(true);
    c1.seed = 1;
    let mut c2 = cfg(true);
    c2.seed = 2;
    let a = run_experiment(c1, paper_suite(1), opts(PolicyKind::Aimd, Some(7620))).unwrap();
    let b = run_experiment(c2, paper_suite(2), opts(PolicyKind::Aimd, Some(7620))).unwrap();
    assert!(a.total_cost > 0.0 && b.total_cost > 0.0);
    assert_ne!(a.total_cost, b.total_cost);
}
