//! `dithen serve` end-to-end tests over real loopback HTTP (PR-7).
//!
//! The headline pin: a scripted client that submits the CI-sized
//! reclamation suite over `POST /submit` and drives the clock with
//! `POST /advance` produces `RunMetrics` **bit-identical** to the
//! equivalent batch [`Scenario`] run. Determinism survives HTTP
//! ingestion because the sim clock never reads the wall clock and the
//! daemon assembles submissions through the same scenario code path
//! ([`ArrivalProcess::Scripted`]) the batch twin uses.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use dithen::config::Config;
use dithen::platform::{ArrivalProcess, FaultSpec, Scenario, ScenarioBuilder};
use dithen::serve::{ClockMode, Daemon, DaemonHandle, ServeOpts};
use dithen::util::rng::Rng;
use dithen::workload::{App, WorkloadSpec};

/// The reclamation integration suite's config: native bank, small
/// chunk floor.
fn cfg() -> Config {
    let mut c = Config::paper_defaults();
    c.use_xla = false;
    c.control.n_min = 4.0;
    c
}

const WORKLOAD_SEED: u64 = 42;
const RECLAIM_AT: [u64; 8] = [300, 420, 540, 660, 780, 900, 1020, 1140];

/// The batch arm: exactly `tests/reclamation.rs`'s CI scenario.
fn batch_scenario() -> Scenario {
    let rng = Rng::new(WORKLOAD_SEED);
    let suite: Vec<WorkloadSpec> = (0..2)
        .map(|i| WorkloadSpec::generate(i, App::FaceDetection, 50, None, &rng))
        .collect();
    ScenarioBuilder::new(cfg())
        .workloads(suite)
        .fixed_ttc(Some(1500))
        .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
        .horizon(4 * 3600)
        .fault(FaultSpec::ReclamationAt { times: RECLAIM_AT.to_vec() })
        .build()
}

/// The daemon arm: the same scenario as a workload-less template; the
/// suite arrives over HTTP instead.
fn daemon_template() -> Scenario {
    ScenarioBuilder::new(cfg())
        .fixed_ttc(Some(1500))
        .arrivals(ArrivalProcess::Scripted { times: vec![] })
        .horizon(4 * 3600)
        .fault(FaultSpec::ReclamationAt { times: RECLAIM_AT.to_vec() })
        .build()
}

fn spawn_daemon(template: Scenario) -> DaemonHandle {
    let opts = ServeOpts { template, clock: ClockMode::Scripted, workload_seed: WORKLOAD_SEED };
    Daemon::spawn(opts, 0).expect("bind an ephemeral loopback port")
}

/// Issue one HTTP/1.1 request over a fresh connection and return
/// (status, body). The daemon closes after each response, so the body
/// is everything after the header/body separator.
fn req(addr: SocketAddr, method: &str, target: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect to the daemon");
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    write!(s, "{method} {target} HTTP/1.1\r\nHost: localhost\r\nContent-Length: 0\r\n\r\n")
        .expect("write request");
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("read response to EOF");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|code| code.parse().ok())
        .unwrap_or_else(|| panic!("malformed status line in: {raw:?}"));
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

#[test]
fn scripted_http_submission_is_bit_identical_to_the_batch_scenario() {
    let batch = batch_scenario().run().expect("batch arm runs");
    // sanity: this is the reclamation scenario, not a quiet one
    assert!(batch.reclamations > 0 && batch.requeued_tasks > 0);

    let handle = spawn_daemon(daemon_template());
    let addr = handle.addr;

    let (status, body) = req(addr, "GET", "/healthz");
    assert_eq!(status, 200, "healthz: {body}");

    // the scripted submission log: the batch twin's fixed-interval
    // arrivals, reproduced as explicit instants
    let (status, body) = req(addr, "POST", "/submit?app=face-detection&tasks=50&at=0");
    assert_eq!(status, 200, "submit w0: {body}");
    assert!(body.contains("\"workload\":0"), "ack: {body}");
    let (status, body) = req(addr, "POST", "/submit?app=face-detection&tasks=50&at=60");
    assert_eq!(status, 200, "submit w1: {body}");
    assert!(body.contains("\"workload\":1"), "ack: {body}");

    // before the first advance the platform is unassembled: queued
    let (status, body) = req(addr, "GET", "/status/1");
    assert_eq!(status, 200);
    assert!(body.contains("\"phase\":\"queued\""), "pre-start status: {body}");

    let (status, body) = req(addr, "POST", "/advance");
    assert_eq!(status, 200, "advance: {body}");
    assert!(body.contains("\"all_done\":true"), "suite must complete: {body}");

    let (status, body) = req(addr, "GET", "/status/0");
    assert_eq!(status, 200);
    assert!(body.contains("\"phase\":\"done\""), "post-run status: {body}");
    assert!(body.contains("\"completed\":50"), "post-run status: {body}");

    let (status, text) = req(addr, "GET", "/metrics");
    assert_eq!(status, 200);
    assert!(
        text.contains(&format!("dithen_tasks_completed {}", batch.tasks_completed)),
        "exposition must carry the completed-task counter: {text}"
    );
    assert!(text.contains("dithen_reclamations"), "exposition: {text}");

    // a second advance after quiescence must be a no-op, not extra ticks
    let (status, body) = req(addr, "POST", "/advance");
    assert_eq!(status, 200);
    assert!(body.contains("\"ticks_run\":0"), "post-quiescence advance: {body}");

    let live = handle.join().expect("graceful shutdown with final metrics");
    assert_eq!(live, batch, "HTTP-ingested run must be bit-identical to the batch scenario");
}

/// A tiny fault-free template for the endpoint round-trip tests.
fn tiny_template() -> Scenario {
    ScenarioBuilder::new(cfg())
        .fixed_ttc(Some(1500))
        .arrivals(ArrivalProcess::Scripted { times: vec![] })
        .horizon(2 * 3600)
        .build()
}

#[test]
fn every_endpoint_round_trips_over_loopback() {
    let handle = spawn_daemon(tiny_template());
    let addr = handle.addr;

    // liveness + empty exposition before any submission
    let (status, body) = req(addr, "GET", "/healthz");
    assert_eq!(status, 200);
    assert!(body.contains("true"));
    let (status, text) = req(addr, "GET", "/metrics");
    assert_eq!(status, 200);
    assert!(text.contains("dithen_up 1"));
    assert!(text.contains("dithen_workloads_submitted 0"));

    // submission validation
    let (status, _) = req(addr, "POST", "/submit?app=warp-drive&tasks=10");
    assert_eq!(status, 400, "unknown app");
    let (status, _) = req(addr, "POST", "/submit?app=face-detection&tasks=0");
    assert_eq!(status, 400, "zero tasks");
    let (status, _) = req(addr, "POST", "/advance");
    assert_eq!(status, 409, "advance with nothing submitted");

    // routing errors
    let (status, _) = req(addr, "GET", "/nope");
    assert_eq!(status, 404);
    let (status, _) = req(addr, "POST", "/healthz");
    assert_eq!(status, 405);
    let (status, _) = req(addr, "GET", "/status/abc");
    assert_eq!(status, 400);
    let (status, _) = req(addr, "GET", "/status/7");
    assert_eq!(status, 404, "workload never submitted");

    // a real submission, then the run
    let (status, body) = req(addr, "POST", "/submit?app=transcode&tasks=12");
    assert_eq!(status, 200, "{body}");
    let (status, body) = req(addr, "GET", "/status/0");
    assert_eq!(status, 200);
    assert!(body.contains("\"app\":\"transcode\""), "{body}");
    let (status, body) = req(addr, "POST", "/advance");
    assert_eq!(status, 200);
    assert!(body.contains("\"all_done\":true"), "{body}");
    let (status, text) = req(addr, "GET", "/metrics");
    assert_eq!(status, 200);
    assert!(text.contains("dithen_tasks_completed 12"), "{text}");
    assert!(text.contains("dithen_workloads_done 1"), "{text}");

    // POST /shutdown (instead of handle-initiated): daemon drains and
    // the control thread returns the finalized metrics
    let (status, body) = req(addr, "POST", "/shutdown");
    assert_eq!(status, 200);
    assert!(body.contains("\"draining\":true"), "{body}");
    let m = handle.wait().expect("finalize after POST /shutdown");
    assert_eq!(m.tasks_completed, 12);
}

#[test]
fn sse_stream_carries_tick_summaries() {
    let handle = spawn_daemon(tiny_template());
    let addr = handle.addr;

    // open the SSE stream; the daemon registers the subscriber through
    // the same FIFO command channel, so the following healthz
    // round-trip proves the subscription landed before we advance
    let mut sse = TcpStream::connect(addr).unwrap();
    sse.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
    write!(sse, "GET /events HTTP/1.1\r\nHost: localhost\r\n\r\n").unwrap();
    let (status, _) = req(addr, "GET", "/healthz");
    assert_eq!(status, 200);

    let (status, _) = req(addr, "POST", "/submit?app=face-detection&tasks=8");
    assert_eq!(status, 200);
    let (status, _) = req(addr, "POST", "/advance");
    assert_eq!(status, 200);

    // accumulate stream bytes until the tick frame shows up
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut seen = String::new();
    let mut buf = [0u8; 4096];
    while Instant::now() < deadline {
        match sse.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                seen.push_str(&String::from_utf8_lossy(&buf[..n]));
                if seen.contains("event: tick") && seen.contains("\"tasks_completed\":") {
                    break;
                }
            }
            Err(_) => {} // read timeout: poll again until the deadline
        }
    }
    assert!(seen.contains("200 OK"), "SSE preamble missing: {seen:?}");
    assert!(seen.contains("event: submitted"), "submission event missing: {seen:?}");
    assert!(seen.contains("event: tick"), "tick summaries missing: {seen:?}");
    assert!(seen.contains("\"tasks_completed\":"), "summary payload missing: {seen:?}");

    drop(sse);
    let m = handle.join().expect("graceful shutdown");
    assert_eq!(m.tasks_completed, 8);
}

#[test]
fn malformed_requests_over_the_wire_get_4xx_and_the_daemon_survives() {
    let handle = spawn_daemon(tiny_template());
    let addr = handle.addr;

    // raw garbage straight onto the socket
    for raw in [
        "not even http\r\n\r\n",
        "GET\r\n\r\n",
        "GET /healthz HTTP/9.9\r\n\r\n",
        "GET /healthz HTTP/1.1\r\nbroken header line\r\n\r\n",
        "POST /submit HTTP/1.1\r\nContent-Length: junk\r\n\r\n",
    ] {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        let code: u16 = resp.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap_or(0);
        assert!(
            (400..600).contains(&code),
            "expected an error status for {raw:?}, got: {resp:?}"
        );
    }

    // and the daemon still serves normal traffic afterwards
    let (status, _) = req(addr, "GET", "/healthz");
    assert_eq!(status, 200, "daemon must survive malformed connections");
    handle.join().expect("clean shutdown after abuse");
}
