//! Spot-reclamation integration tests: the end-to-end exercise of the
//! `TaskDb::requeue` FIFO re-entry path on the *platform* loop (closing
//! the ROADMAP "nothing exercises requeue" item).
//!
//! A scripted revocation schedule tears the whole fleet down repeatedly
//! in the middle of execution; the platform must requeue every in-flight
//! chunk's tasks at the Pending tail, re-boot capacity via the scaling
//! policy, and still complete every task exactly once — the DB state
//! machine panics on double completion, so a clean run *is* the
//! exactly-once proof, and the balanced `RunMetrics` counters are the
//! observable receipt.

use dithen::cloud::FleetSpec;
use dithen::config::Config;
use dithen::coordinator::PolicyKind;
use dithen::platform::{ArrivalProcess, FaultSpec, ScenarioBuilder};
use dithen::util::rng::Rng;
use dithen::workload::{App, WorkloadSpec};

fn cfg() -> Config {
    let mut c = Config::paper_defaults();
    c.use_xla = false;
    c.control.n_min = 4.0;
    c
}

fn suite(n_wl: usize, tasks_each: usize, app: App) -> Vec<WorkloadSpec> {
    let rng = Rng::new(42);
    (0..n_wl)
        .map(|i| WorkloadSpec::generate(i, app, tasks_each, None, &rng))
        .collect()
}

#[test]
fn reclamation_requeues_in_flight_tasks_and_completes_exactly_once() {
    // aggressive TTC keeps instances busy through the revocation window,
    // so at least one scripted instant catches chunks in flight
    let total_tasks = 2 * 50;
    let m = ScenarioBuilder::new(cfg())
        .workloads(suite(2, 50, App::FaceDetection))
        .fixed_ttc(Some(1500))
        .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
        .horizon(4 * 3600)
        .fault(FaultSpec::ReclamationAt {
            times: vec![300, 420, 540, 660, 780, 900, 1020, 1140],
        })
        .build()
        .run()
        .unwrap();

    assert!(m.reclamations > 0, "the scripted schedule revoked nothing");
    assert!(
        m.requeued_tasks > 0,
        "no in-flight chunk was caught by {} revocations — requeue path unexercised",
        m.reclamations
    );
    // every workload recovers and finishes after the fault window
    for (w, o) in m.outcomes.iter().enumerate() {
        assert!(o.completed_at.is_some(), "workload {w} never completed after reclamation");
    }
    // counts balance: each task completed exactly once despite requeues
    // (double completion would have panicked inside the task DB)
    assert_eq!(m.tasks_completed, total_tasks, "task completions do not balance");
    // requeued work re-executes, so busy time exceeds the no-fault cost
    // of the same suite
    let clean = ScenarioBuilder::new(cfg())
        .workloads(suite(2, 50, App::FaceDetection))
        .fixed_ttc(Some(1500))
        .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
        .horizon(4 * 3600)
        .build()
        .run()
        .unwrap();
    assert_eq!(clean.reclamations, 0);
    assert!(
        m.total_busy_cus > clean.total_busy_cus,
        "re-executed chunks must add busy time ({} vs {})",
        m.total_busy_cus,
        clean.total_busy_cus
    );
}

#[test]
fn reclamation_survives_every_policy() {
    // the recovery path is policy-agnostic: each scaling method must
    // re-grow the fleet after a mid-run wipeout and finish the suite
    for policy in [PolicyKind::Aimd, PolicyKind::Reactive, PolicyKind::Mwa] {
        let m = ScenarioBuilder::new(cfg())
            .workloads(suite(2, 25, App::FaceDetection))
            .policy(policy)
            .fixed_ttc(Some(1800))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(5 * 3600)
            .fault(FaultSpec::ReclamationAt { times: vec![600, 1200] })
            .build()
            .run()
            .unwrap();
        assert!(m.reclamations > 0, "{policy:?}: nothing revoked");
        assert!(
            m.outcomes.iter().all(|o| o.completed_at.is_some()),
            "{policy:?} did not recover from reclamation"
        );
        assert_eq!(m.tasks_completed, 50, "{policy:?}: unbalanced completions");
    }
}

#[test]
fn price_spike_on_large_type_revokes_only_that_pool() {
    // Partial revocation, market-driven: the small pool's bid sits above
    // the m3.medium hard price cap (on-demand x 1.2 = $0.0804, the
    // market simulator's structural ceiling — never crossed, always
    // fulfilable), while the 16-CU pool's bid sits barely above its
    // Table V base price —
    // the seeded m4.4xlarge trace is volatile enough (volatility grows
    // with CU count, Appendix A) to cross it within the horizon for
    // most seeds. Every seed must satisfy the partial-revocation
    // invariants; at least one must actually revoke the big pool and
    // requeue in-flight work.
    let mut saw_partial = false;
    let mut saw_requeue = false;
    for seed in [1u64, 7, 11, 42, 20161021] {
        let mut c = cfg();
        c.seed = seed;
        c.control.n_min = 20.0; // bootstrap fits one 16-CU instance
        let fleet = FleetSpec::parse("m3.medium:bid=0.1,m4.4xlarge:bid=0.115").unwrap();
        let m = ScenarioBuilder::new(c)
            .workloads(suite(2, 40, App::FaceDetection))
            .fixed_ttc(Some(1800))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(8 * 3600)
            .fleet(fleet)
            .fault(FaultSpec::PoolReclamation)
            .build()
            .run()
            .unwrap();
        assert_eq!(m.reclamations_by_pool.len(), 2, "seed {seed}: two pools expected");
        assert_eq!(
            m.reclamations_by_pool[0], 0,
            "seed {seed}: the never-crossed m3.medium pool was revoked"
        );
        assert_eq!(
            m.reclamations_by_pool.iter().sum::<u64>(),
            m.reclamations,
            "seed {seed}: per-pool tallies must decompose the total"
        );
        // partial revocation never blocks completion: the surviving
        // small pool absorbs the requeued work, and the task DB's state
        // machine guarantees each requeued task completes exactly once
        // (double completion panics)
        for (w, o) in m.outcomes.iter().enumerate() {
            assert!(o.completed_at.is_some(), "seed {seed}: workload {w} never completed");
        }
        assert_eq!(m.tasks_completed, 2 * 40, "seed {seed}: completions must balance");
        saw_partial |= m.reclamations > 0;
        saw_requeue |= m.requeued_tasks > 0;
    }
    assert!(saw_partial, "no seed crossed the large pool's bid");
    assert!(saw_requeue, "no revocation caught in-flight chunks on the large pool");
}

#[test]
fn splitmerge_merge_step_survives_reclamation() {
    // revocations spread far enough to plausibly catch the merge step
    // too (the merge epoch guard); regardless of what gets hit, the
    // workload must finish and counts must balance
    let rng = Rng::new(9);
    let spec = WorkloadSpec::generate_mode(
        0,
        App::CnnClassify,
        30,
        dithen::workload::Mode::SplitMerge { merge_frac: 0.2 },
        None,
        &rng,
    );
    let m = ScenarioBuilder::new(cfg())
        .workloads(vec![spec])
        .fixed_ttc(Some(1500))
        .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
        .horizon(5 * 3600)
        .fault(FaultSpec::ReclamationAt {
            times: vec![240, 360, 480, 600, 720, 840, 960, 1080, 1200],
        })
        .build()
        .run()
        .unwrap();
    assert!(m.reclamations > 0);
    assert!(m.outcomes[0].completed_at.is_some(), "split-merge did not recover");
    assert_eq!(m.tasks_completed, 30);
}

// ----- PR-10 partial failures -------------------------------------------

fn cfg_seeded(seed: u64) -> Config {
    let mut c = cfg();
    c.seed = seed;
    c
}

#[test]
fn chunk_crashes_retry_with_backoff_and_conserve_tasks() {
    // a 0.01/s hazard over ~minute-scale chunk walls crashes a large
    // share of attempts, so the retry/backoff path is exercised hard
    // and a few tasks plausibly exhaust the 3-retry budget. The
    // conservation law is exact either way: every task ends Completed
    // or abandoned, never both, never lost — double completion panics
    // inside the task DB, so a clean run is the exactly-once proof.
    let total = 2 * 50;
    let m = ScenarioBuilder::new(cfg())
        .workloads(suite(2, 50, App::FaceDetection))
        .fixed_ttc(Some(1800))
        .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
        .horizon(6 * 3600)
        .fault(FaultSpec::ChunkCrash { rate: 0.01 })
        .build()
        .run()
        .unwrap();
    assert!(m.chunk_retries > 0, "no chunk crash scheduled a retry");
    assert!(m.requeued_tasks > 0, "crash retries must re-enter the pending tail");
    for (w, o) in m.outcomes.iter().enumerate() {
        assert!(o.completed_at.is_some(), "workload {w} hung instead of finishing degraded");
    }
    // `tasks_completed` counts *terminal* tasks (the shard audit's
    // Completed + Failed), so an abandoned task is inside the total —
    // exactly once — and the receipt counter bounds the degraded share
    assert_eq!(m.tasks_completed, total, "every task must turn terminal exactly once");
    assert!(
        (m.tasks_abandoned as usize) < total,
        "the retry budget cannot abandon the entire suite at this hazard"
    );
    let outcome_abandoned: usize = m.outcomes.iter().map(|o| o.tasks_abandoned).sum();
    assert_eq!(
        outcome_abandoned, m.tasks_abandoned as usize,
        "per-workload abandonment receipts must decompose the total"
    );
    // budget exhaustion is a deadline violation, never a hang
    if m.tasks_abandoned > 0 {
        assert!(m.ttc_compliance() < 1.0, "abandoned tasks must count as TTC violations");
    }
}

#[test]
fn speculative_twins_complete_exactly_once_under_stragglers() {
    // first-completion-wins: the loser teardown is audited by the DB
    // state machine (a double count panics on the second complete) and
    // the balance check proves no task is lost to the teardown either
    let mut saw_spec = false;
    let mut saw_straggler = false;
    for seed in [1u64, 7, 11, 42, 20161021] {
        let m = ScenarioBuilder::new(cfg_seeded(seed))
            .workloads(suite(2, 40, App::FaceDetection))
            .fixed_ttc(Some(1800))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(8 * 3600)
            .fault(FaultSpec::Straggler { frac: 0.25, slowdown: 4.0 })
            .build()
            .run()
            .unwrap();
        for (w, o) in m.outcomes.iter().enumerate() {
            assert!(o.completed_at.is_some(), "seed {seed}: workload {w} never completed");
        }
        assert_eq!(m.tasks_completed, 2 * 40, "seed {seed}: completions must balance");
        assert_eq!(m.tasks_abandoned, 0, "seed {seed}: stragglers never abandon work");
        assert_eq!(m.chunk_retries, 0, "seed {seed}: stragglers never crash chunks");
        saw_spec |= m.speculative_launches > 0;
        saw_straggler |= m.straggler_instances > 0;
    }
    assert!(saw_straggler, "no seed marked any instance as a straggler");
    assert!(saw_spec, "no seed launched a speculative twin");
}

#[test]
fn aimd_regrows_capacity_under_stragglers() {
    // a 4x-degraded quarter of the fleet drains the remaining-task
    // count slower, so N* stays high longer and AIMD keeps additively
    // growing — on at least one seed the straggler run must provably
    // carry more concurrent capacity than the clean run of the same
    // suite (and every seed must still finish everything)
    let mut saw_growth = false;
    for seed in [1u64, 7, 11, 42, 20161021] {
        let clean = ScenarioBuilder::new(cfg_seeded(seed))
            .workloads(suite(2, 40, App::FaceDetection))
            .fixed_ttc(Some(1800))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(8 * 3600)
            .build()
            .run()
            .unwrap();
        let m = ScenarioBuilder::new(cfg_seeded(seed))
            .workloads(suite(2, 40, App::FaceDetection))
            .fixed_ttc(Some(1800))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(8 * 3600)
            .fault(FaultSpec::Straggler { frac: 0.25, slowdown: 4.0 })
            .build()
            .run()
            .unwrap();
        assert!(
            m.outcomes.iter().all(|o| o.completed_at.is_some()),
            "seed {seed}: AIMD did not recover from stragglers"
        );
        assert_eq!(m.tasks_completed, 2 * 40, "seed {seed}: unbalanced completions");
        saw_growth |= m.max_instances > clean.max_instances;
    }
    assert!(saw_growth, "no seed grew the fleet beyond its clean-run peak");
}
