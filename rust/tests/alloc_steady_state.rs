//! Zero-allocation guarantees for the GCI steady-state tick, pinned
//! with a counting global allocator: once warmed, the task-DB
//! lifecycle/query path and the estimator-bank step must not touch the
//! heap. (The whole test binary shares the counting allocator; each
//! test measures a delta around its own hot section, which stays
//! correct under `--test-threads=1`. CI runs this file single-threaded;
//! under parallel test threads the assertions could only fail
//! spuriously *upward*, never mask a regression, so we serialize via a
//! mutex to be exact.)

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use dithen::db::{TaskDb, TaskStatus};
use dithen::estimation::{Backend, Bank, BankParams, TickInputs};
use dithen::runtime::StepOutputs;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

/// Serializes the measured sections so tests can't count each other's
/// allocations.
static GATE: Mutex<()> = Mutex::new(());

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

// Ignored under a plain `cargo test`: the libtest harness may print
// (and allocate) from its own thread while a measured section runs,
// which could fail the ==0 assertion spuriously. CI runs this binary
// explicitly with `-- --ignored --test-threads=1`, where the harness
// is quiescent during measurement.
#[test]
#[ignore = "allocation counting needs --test-threads=1; CI runs with --ignored"]
fn task_db_lifecycle_and_tick_queries_are_allocation_free() {
    let _g = GATE.lock().unwrap();
    let n = 10_000usize;
    let mut db = TaskDb::new();
    for t in 0..n {
        db.insert(0, t % 2, t);
    }
    db.reserve_measurements(0);
    // warm: complete the first half (exercises every branch once)
    for t in 0..n / 2 {
        db.claim((0, t), 1);
        db.complete((0, t), 1.0, t as u64, 0);
    }

    let before = allocs();
    let mut acc = 0.0f64;
    // steady state: lifecycle ops + the per-tick query mix (the last 64
    // tasks are left pending for the requeue churn below)
    for t in n / 2..n - 64 {
        db.claim((0, t), 1);
        db.complete((0, t), 2.0, t as u64, 0);
        acc += db.remaining_slice(0).iter().sum::<u64>() as f64;
        acc += db.count_status(0, TaskStatus::Pending) as f64;
        acc += db.status_iter(0, TaskStatus::Pending).take(16).sum::<usize>() as f64;
        let win = db.measurements_window(0, t % 2, (t as u64).saturating_sub(32), t as u64);
        acc += win.iter().map(|&(_, c)| c).sum::<f64>();
    }
    // claim/requeue churn on the still-pending tail (spot reclamation path)
    for t in n - 64..n {
        db.claim((0, t), 9);
        db.requeue((0, t));
    }
    let delta = allocs() - before;
    std::hint::black_box(acc);
    assert_eq!(
        delta, 0,
        "task-DB steady state allocated {delta} times (must be zero)"
    );
}

#[test]
#[ignore = "allocation counting needs --test-threads=1; CI runs with --ignored"]
fn native_bank_step_into_is_allocation_free_after_warmup() {
    let _g = GATE.lock().unwrap();
    let (w, k) = (32usize, 4usize);
    let wk = w * k;
    let params = BankParams {
        sigma_z2: 0.5,
        sigma_v2: 0.5,
        alpha: 5.0,
        beta: 0.9,
        n_min: 10.0,
        n_max: 100.0,
        n_w_max: 10.0,
    };
    let mut bank = Bank::new(w, k, params, Backend::Native);
    let slot = vec![1.0f32; wk];
    let meas = vec![1.0f32; wk];
    let b_tilde = vec![42.0f32; wk];
    let m_rem = vec![10.0f32; wk];
    let d = vec![1000.0f32; w];
    let mut out = StepOutputs::default();
    let tick = TickInputs {
        b_tilde: &b_tilde,
        meas_mask: &meas,
        m_rem: &m_rem,
        slot_mask: &slot,
        d: &d,
        n_tot: 10.0,
    };
    // warm: sizes the output buffers
    bank.step_into(&tick, &mut out).unwrap();

    let before = allocs();
    for _ in 0..100 {
        bank.step_into(&tick, &mut out).unwrap();
    }
    let delta = allocs() - before;
    std::hint::black_box(&out);
    assert_eq!(
        delta, 0,
        "bank step_into steady state allocated {delta} times (must be zero)"
    );
}
