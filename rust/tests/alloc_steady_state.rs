//! Zero-allocation guarantees for the GCI steady-state tick, pinned
//! with a counting global allocator: once warmed, the task-DB
//! lifecycle/query path and the estimator-bank step must not touch the
//! heap. (The whole test binary shares the counting allocator; each
//! test measures a delta around its own hot section, which stays
//! correct under `--test-threads=1`. CI runs this file single-threaded;
//! under parallel test threads the assertions could only fail
//! spuriously *upward*, never mask a regression, so we serialize via a
//! mutex to be exact.)

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use dithen::cloud::{CloudBackend, Provider};
use dithen::config::MarketCfg;
use dithen::db::{TaskDb, TaskStatus};
use dithen::estimation::{
    kalman_update_scalar, kalman_update_simd, AdHoc, Arma, Backend, Bank, BankParams,
    BatchScratch, DeviationDetector, SlopeDetector, TickInputs,
};
use dithen::platform::{FaultModel, NoFaults, ReclamationAt, SpotReclamation};
use dithen::runtime::StepOutputs;
use dithen::sim::{Engine, Event};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

/// Serializes the measured sections so tests can't count each other's
/// allocations.
static GATE: Mutex<()> = Mutex::new(());

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

// Ignored under a plain `cargo test`: the libtest harness may print
// (and allocate) from its own thread while a measured section runs,
// which could fail the ==0 assertion spuriously. CI runs this binary
// explicitly with `-- --ignored --test-threads=1`, where the harness
// is quiescent during measurement.
#[test]
#[ignore = "allocation counting needs --test-threads=1; CI runs with --ignored"]
fn task_db_lifecycle_and_tick_queries_are_allocation_free() {
    let _g = GATE.lock().unwrap();
    let n = 10_000usize;
    let mut db = TaskDb::new();
    for t in 0..n {
        db.insert(0, t % 2, t);
    }
    db.reserve_measurements(0);
    // warm: complete the first half (exercises every branch once)
    for t in 0..n / 2 {
        db.claim((0, t), 1);
        db.complete((0, t), 1.0, t as u64, 0);
    }

    let before = allocs();
    let mut acc = 0.0f64;
    // steady state: lifecycle ops + the per-tick query mix (the last 64
    // tasks are left pending for the requeue churn below)
    for t in n / 2..n - 64 {
        db.claim((0, t), 1);
        db.complete((0, t), 2.0, t as u64, 0);
        acc += db.remaining_slice(0).iter().sum::<u64>() as f64;
        acc += db.count_status(0, TaskStatus::Pending) as f64;
        acc += db.status_iter(0, TaskStatus::Pending).take(16).sum::<usize>() as f64;
        let win = db.measurements_window(0, t % 2, (t as u64).saturating_sub(32), t as u64);
        acc += win.iter().map(|&(_, c)| c).sum::<f64>();
    }
    // claim/requeue churn on the still-pending tail (spot reclamation path)
    for t in n - 64..n {
        db.claim((0, t), 9);
        db.requeue((0, t));
    }
    let delta = allocs() - before;
    std::hint::black_box(acc);
    assert_eq!(
        delta, 0,
        "task-DB steady state allocated {delta} times (must be zero)"
    );
}

#[test]
#[ignore = "allocation counting needs --test-threads=1; CI runs with --ignored"]
fn native_bank_step_into_is_allocation_free_after_warmup() {
    let _g = GATE.lock().unwrap();
    let (w, k) = (32usize, 4usize);
    let wk = w * k;
    let params = BankParams {
        sigma_z2: 0.5,
        sigma_v2: 0.5,
        alpha: 5.0,
        beta: 0.9,
        n_min: 10.0,
        n_max: 100.0,
        n_w_max: 10.0,
    };
    let mut bank = Bank::new(w, k, params, Backend::Native);
    let slot = vec![1.0f32; wk];
    let meas = vec![1.0f32; wk];
    let b_tilde = vec![42.0f32; wk];
    let m_rem = vec![10.0f32; wk];
    let d = vec![1000.0f32; w];
    let mut out = StepOutputs::default();
    let tick = TickInputs {
        b_tilde: &b_tilde,
        meas_mask: &meas,
        m_rem: &m_rem,
        slot_mask: &slot,
        d: &d,
        n_tot: 10.0,
    };
    // warm: sizes the output buffers
    bank.step_into(&tick, &mut out).unwrap();

    let before = allocs();
    for _ in 0..100 {
        bank.step_into(&tick, &mut out).unwrap();
    }
    let delta = allocs() - before;
    std::hint::black_box(&out);
    assert_eq!(
        delta, 0,
        "bank step_into steady state allocated {delta} times (must be zero)"
    );
}

/// The lockstep batch tick (PR-5): once the padded scratch and every
/// cell's `StepOutputs` have been through one warm-up round, a full
/// gather → `step_batch_into` → scatter round over N cells must not
/// touch the heap — the batched executor's hot loop keeps the
/// zero-allocation contract the per-cell tick established.
#[test]
#[ignore = "allocation counting needs --test-threads=1; CI runs with --ignored"]
fn lockstep_batch_tick_is_allocation_free_after_warmup() {
    let _g = GATE.lock().unwrap();
    let (w, k, n) = (16usize, 2usize, 8usize);
    let wk = w * k;
    let params = BankParams {
        sigma_z2: 0.5,
        sigma_v2: 0.5,
        alpha: 5.0,
        beta: 0.9,
        n_min: 10.0,
        n_max: 100.0,
        n_w_max: 10.0,
    };
    let template = Bank::new(w, k, params, Backend::Native);
    let mut banks: Vec<Bank> = (0..n).map(|_| Bank::new(w, k, params, Backend::Native)).collect();
    let mut outs: Vec<StepOutputs> = (0..n).map(|_| StepOutputs::default()).collect();
    let slot = vec![1.0f32; wk];
    let meas = vec![1.0f32; wk];
    let b_tilde = vec![42.0f32; wk];
    let m_rem = vec![10.0f32; wk];
    let d = vec![1000.0f32; w];
    let tick = TickInputs {
        b_tilde: &b_tilde,
        meas_mask: &meas,
        m_rem: &m_rem,
        slot_mask: &slot,
        d: &d,
        n_tot: 10.0,
    };
    let mut batch = BatchScratch::default();
    let round = |banks: &mut Vec<Bank>, outs: &mut Vec<StepOutputs>, batch: &mut BatchScratch| {
        batch.begin(n, w, k);
        for bank in banks.iter() {
            batch.gather(bank, &tick).unwrap();
        }
        template.step_batch_into(batch).unwrap();
        for (i, bank) in banks.iter_mut().enumerate() {
            batch.scatter(i, bank, &mut outs[i]);
        }
    };
    // warm: sizes the padded scratch and every cell's output buffers
    round(&mut banks, &mut outs, &mut batch);

    let before = allocs();
    for _ in 0..100 {
        round(&mut banks, &mut outs, &mut batch);
    }
    let delta = allocs() - before;
    std::hint::black_box(&outs);
    assert_eq!(
        delta, 0,
        "lockstep batch round allocated {delta} times in steady state (must be zero)"
    );
}

/// The PR-6 skip primitives, engine half: computing the skip horizon
/// (`next_non_tick_time` — a scan of the heap's backing storage) and
/// fast-forwarding the clock (`advance_to`) must not touch the heap.
/// These run once per *skipped* monitoring instant, so an allocation
/// here would silently tax exactly the regime the skipper exists to
/// accelerate.
#[test]
#[ignore = "allocation counting needs --test-threads=1; CI runs with --ignored"]
fn engine_skip_primitives_are_allocation_free() {
    let _g = GATE.lock().unwrap();
    let mut e = Engine::new();
    // a realistically mixed queue: far-future arrivals behind a run of
    // monitor ticks (the shape the skipper actually scans); everything
    // sits past the advance range below, as `advance_to` requires
    for i in 0..64u64 {
        e.schedule_at(10_000 + i * 60, Event::MonitorTick);
    }
    for w in 0..8usize {
        e.schedule_at(10_000 + w as u64 * 7200, Event::WorkloadArrival { workload: w });
    }

    let before = allocs();
    let mut acc = 0u64;
    for t in 0..1000u64 {
        acc += e.next_non_tick_time().unwrap_or(0);
        acc += e.pending() as u64;
        e.advance_to(t); // strictly below every queued event
    }
    let delta = allocs() - before;
    std::hint::black_box(acc);
    assert_eq!(delta, 0, "engine skip primitives allocated {delta} times (must be zero)");
}

/// The PR-6 skip primitives, backend + fault half: the billing-due,
/// price-change and fault-schedule legs of the skip horizon are read
/// once per skip-eligibility check. All must be allocation-free scans
/// of existing state.
#[test]
#[ignore = "allocation counting needs --test-threads=1; CI runs with --ignored"]
fn skip_horizon_legs_are_allocation_free() {
    let _g = GATE.lock().unwrap();
    let mut p = Provider::new(MarketCfg::default(), 11, 8);
    for i in 0..16usize {
        let (id, ready_at) = p.request_spot_instance(0, i as u64 * 100);
        p.instance_ready(id, ready_at);
    }
    let market = SpotReclamation { bid: 0.0082 };
    let scripted = ReclamationAt::new(vec![600, 1200, 9000]);

    let before = allocs();
    let mut acc = 0u64;
    for t in 0..1000u64 {
        acc += CloudBackend::next_billing_due(&p, t).unwrap_or(0);
        acc += CloudBackend::next_price_change(&p, t).unwrap_or(0);
        acc += market.next_scheduled(&p, t).unwrap_or(0);
        acc += scripted.next_scheduled(&p, t).unwrap_or(0);
        acc += NoFaults.next_scheduled(&p, t).unwrap_or(0);
    }
    let delta = allocs() - before;
    std::hint::black_box(acc);
    assert_eq!(delta, 0, "skip horizon legs allocated {delta} times (must be zero)");
}

/// The PR-6 SIMD stage-1 kernel: like the scalar path it replaces, the
/// 8-lane unrolled Kalman update works entirely in caller-provided
/// slices — no spill buffers, no temporaries on the heap.
#[test]
#[ignore = "allocation counting needs --test-threads=1; CI runs with --ignored"]
fn simd_kernel_is_allocation_free() {
    let _g = GATE.lock().unwrap();
    let wk = 16 * 32 + 3; // odd tail exercises the scalar remainder
    let p = BankParams {
        sigma_z2: 0.5,
        sigma_v2: 0.5,
        alpha: 5.0,
        beta: 0.9,
        n_min: 10.0,
        n_max: 100.0,
        n_w_max: 10.0,
    };
    let b_hat = vec![40.0f32; wk];
    let pi = vec![1.0f32; wk];
    let b_tilde = vec![42.0f32; wk];
    let meas = vec![1.0f32; wk];
    let slot = vec![1.0f32; wk];
    let mut ob = vec![0.0f32; wk];
    let mut op = vec![0.0f32; wk];

    let before = allocs();
    for _ in 0..100 {
        kalman_update_simd(&b_hat, &pi, &b_tilde, &meas, &slot, &p, &mut ob, &mut op);
        kalman_update_scalar(&b_hat, &pi, &b_tilde, &meas, &slot, &p, &mut ob, &mut op);
    }
    let delta = allocs() - before;
    std::hint::black_box((&ob, &op));
    assert_eq!(delta, 0, "estimator kernel allocated {delta} times (must be zero)");
}

/// The traces-off tick path: with `record_traces = false` the per-slot
/// work each monitoring instant is exactly one ad-hoc update, one ARMA
/// update and three convergence-detector pushes. That mix must be
/// allocation-free — it is what remains of the per-tick estimator work
/// after trace recording (the last per-tick allocator, see
/// rust/BENCHMARKS.md) is gated off.
#[test]
#[ignore = "allocation counting needs --test-threads=1; CI runs with --ignored"]
fn passive_estimator_tick_path_is_allocation_free() {
    let _g = GATE.lock().unwrap();
    let mut adhoc = AdHoc::paper();
    let mut arma = Arma::paper();
    let mut kalman_det = SlopeDetector::new();
    let mut adhoc_det = SlopeDetector::new();
    let mut arma_det = DeviationDetector::paper(60); // 10-sample ring
    adhoc.seed(10.0);

    // warm: fill the detector ring / internal state once
    for i in 0..32 {
        let m = 10.0 + (i % 5) as f64 * 0.3;
        let a = adhoc.update(Some(m));
        let b = arma.update(m);
        let _ = kalman_det.push(m);
        let _ = adhoc_det.push(a);
        let _ = arma_det.push(b);
    }

    let before = allocs();
    let mut acc = 0.0f64;
    for i in 0..10_000u64 {
        let m = 10.0 + (i % 7) as f64 * 0.1;
        let with_meas = i % 3 != 0; // intervals without completions reuse b̃[t-1]
        let a = adhoc.update(if with_meas { Some(m) } else { None });
        let b = arma.update(m);
        acc += a + b;
        if kalman_det.push(m).is_some() {
            acc += 1.0;
        }
        if adhoc_det.push(a).is_some() {
            acc += 1.0;
        }
        if arma_det.push(b).is_some() {
            acc += 1.0;
        }
    }
    let delta = allocs() - before;
    std::hint::black_box(acc);
    assert_eq!(
        delta, 0,
        "passive estimator tick path allocated {delta} times (must be zero)"
    );
}
