//! Determinism property tests: the same `Config::seed` must produce
//! **byte-identical** `RunMetrics` — across repeated sequential runs,
//! and across the parallel experiment runner at any thread count.
//! (`RunMetrics` implements `PartialEq` over every curve, trace,
//! outcome and reclamation counter, so equality here is exhaustive, not
//! a spot check.) Reclamation scenarios are included: revocation events
//! come from the seeded market (or a scripted schedule), never from
//! wall clock, so fault-injected runs must be just as reproducible.

use dithen::cloud::FleetSpec;
use dithen::config::Config;
use dithen::estimation::BankCache;
use dithen::experiments::batched::{run_specs_batched, run_specs_batched_opts};
use dithen::experiments::parallel::{run_sharded, run_specs, run_specs_with_cache, RunSpec};
use dithen::platform::{
    run_experiment, ArrivalProcess, FaultSpec, RunOpts, Scenario, ScenarioBuilder, StreamSpec,
};
use dithen::util::rng::Rng;
use dithen::workload::{App, WorkloadSpec};

fn cfg(seed: u64) -> Config {
    let mut c = Config::paper_defaults();
    c.use_xla = false;
    c.control.n_min = 4.0;
    c.seed = seed;
    c
}

fn opts() -> RunOpts {
    RunOpts {
        fixed_ttc_s: Some(3600),
        arrival_interval_s: 60,
        horizon_s: 6 * 3600,
        ..Default::default()
    }
}

fn suite(seed: u64, n_wl: usize, tasks_each: usize) -> Vec<WorkloadSpec> {
    let rng = Rng::new(seed);
    (0..n_wl)
        .map(|i| WorkloadSpec::generate(i, App::FaceDetection, tasks_each, None, &rng))
        .collect()
}

/// A spot scenario with market-driven reclamation: the bid sits just
/// above the m3.medium base price, so whether (and when) the seeded
/// price trace crosses it is itself part of the seed's determinism.
fn reclamation_scenario(seed: u64) -> Scenario {
    ScenarioBuilder::new(cfg(seed))
        .workloads(suite(seed, 2, 30))
        .fixed_ttc(Some(3600))
        .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
        .horizon(6 * 3600)
        .fault(FaultSpec::SpotReclamation { bid: 0.0082 })
        .build()
}

/// A heterogeneous two-pool fleet under per-pool market reclamation:
/// whether (and when) the volatile 16-CU pool crosses its bid — and is
/// *partially* revoked while the m3.medium pool keeps working — is
/// itself part of the seed's determinism.
fn mixed_fleet_scenario(seed: u64) -> Scenario {
    let mut c = cfg(seed);
    c.control.n_min = 20.0; // bootstrap fits one 16-CU instance
    ScenarioBuilder::new(c)
        .workloads(suite(seed, 2, 30))
        .fixed_ttc(Some(1800))
        .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
        .horizon(6 * 3600)
        .fleet(FleetSpec::parse("m3.medium:bid=0.1,m4.4xlarge:bid=0.115").unwrap())
        .fault(FaultSpec::PoolReclamation)
        .build()
}

#[test]
fn same_seed_same_metrics_sequentially() {
    for seed in [1u64, 42, 20161021] {
        let a = run_experiment(cfg(seed), suite(seed, 2, 30), opts()).unwrap();
        let b = run_experiment(cfg(seed), suite(seed, 2, 30), opts()).unwrap();
        assert_eq!(a, b, "seed {seed}: two sequential runs diverged");
    }
}

#[test]
fn different_seeds_differ() {
    let a = run_experiment(cfg(1), suite(1, 2, 30), opts()).unwrap();
    let b = run_experiment(cfg(2), suite(2, 2, 30), opts()).unwrap();
    assert_ne!(a.total_cost, b.total_cost);
}

#[test]
fn reclamation_scenario_is_bit_identical_across_runs() {
    for seed in [3u64, 77, 20161021] {
        let scn = reclamation_scenario(seed);
        let a = scn.run().unwrap();
        let b = scn.run().unwrap();
        assert_eq!(a, b, "seed {seed}: reclamation scenario diverged between runs");
        // the fault stream itself must be seed-deterministic too
        assert_eq!(a.reclamations, b.reclamations);
        assert_eq!(a.requeued_tasks, b.requeued_tasks);
    }
}

#[test]
fn scripted_reclamation_is_bit_identical_across_runs() {
    let scn = ScenarioBuilder::new(cfg(5))
        .workloads(suite(5, 2, 40))
        .fixed_ttc(Some(1800))
        .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
        .horizon(6 * 3600)
        .fault(FaultSpec::ReclamationAt { times: vec![600, 900, 1200] })
        .build();
    let a = scn.run().unwrap();
    let b = scn.run().unwrap();
    assert_eq!(a, b);
    assert!(a.reclamations > 0, "scripted schedule must revoke something");
}

#[test]
fn mixed_fleet_partial_revocation_is_bit_identical_across_runs() {
    for seed in [3u64, 42] {
        let scn = mixed_fleet_scenario(seed);
        let a = scn.run().unwrap();
        let b = scn.run().unwrap();
        assert_eq!(a, b, "seed {seed}: mixed-fleet scenario diverged between runs");
        assert_eq!(a.reclamations_by_pool, b.reclamations_by_pool);
        assert_eq!(a.unfulfilled_requests, b.unfulfilled_requests);
    }
}

/// PR-4 bank-cache determinism pin at the whole-run level: the same
/// grid through a cold private cache (every cell cold-builds its
/// variant — the pre-cache behaviour), through the *same* cache again
/// (every cell hits), and through the process-global cache must be
/// bit-identical. The grid mixes 1- and 2-workload suites so several
/// (W, K) variants coexist in one cache.
#[test]
fn bank_cache_reuse_does_not_change_results() {
    let mut specs: Vec<RunSpec> = vec![];
    for (i, n_wl) in [1usize, 2, 1, 2].into_iter().enumerate() {
        let seed = 31 + i as u64;
        specs.push(RunSpec::from_opts(
            format!("cache/{i}"),
            cfg(seed),
            suite(seed, n_wl, 25),
            opts(),
        ));
    }
    let cache = BankCache::new();
    let cold = run_specs_with_cache(&specs, 2, &cache).unwrap();
    let cold_stats = cache.stats();
    assert_eq!(cold_stats.cold_builds, 2, "two distinct (W, K) shapes in the grid");
    let warm = run_specs_with_cache(&specs, 2, &cache).unwrap();
    assert_eq!(cold, warm, "a cache hit changed simulation results");
    assert_eq!(cache.stats().cold_builds, cold_stats.cold_builds, "warm pass must not rebuild");
    assert!(cache.stats().hits > cold_stats.hits);
    let global = run_specs(&specs, 2).unwrap();
    assert_eq!(cold, global, "global-cache run diverged from private-cache run");
}

/// PR-5 lockstep pin: the batched sweep executor must be
/// **bit-identical** to the per-cell sequential path on a mixed grid —
/// several (W, K) variants, a market-driven reclamation cell and a
/// mixed-fleet partial-revocation cell included — and invariant across
/// batch widths {1, 4, unbounded} and thread counts. Every comparison
/// is exhaustive `RunMetrics` equality.
#[test]
fn batched_sweep_is_bit_identical_to_per_cell() {
    let mut specs: Vec<RunSpec> = vec![];
    for (i, est) in dithen::estimation::EstimatorKind::ALL.iter().enumerate() {
        let seed = 400 + i as u64;
        specs.push(RunSpec::from_opts(
            format!("batch/{i}"),
            cfg(seed),
            suite(seed, 2, 25),
            RunOpts { estimator: *est, ..opts() },
        ));
    }
    specs.push(RunSpec::from_opts("batch/one-wl", cfg(410), suite(410, 1, 30), opts()));
    specs.push(RunSpec::new("batch/reclaim", reclamation_scenario(415)));
    specs.push(RunSpec::new("batch/fleet", mixed_fleet_scenario(420)));

    let reference = run_specs(&specs, 1).unwrap();
    for (threads, max_batch) in
        [(1usize, Some(1usize)), (1, Some(4)), (1, None), (4, None), (8, Some(2))]
    {
        let cache = BankCache::new();
        let batched = run_specs_batched_opts(&specs, threads, max_batch, &cache).unwrap();
        assert_eq!(
            reference, batched,
            "batched executor (threads={threads}, max_batch={max_batch:?}) diverged from the \
             per-cell sequential path"
        );
    }
    // the default chunking too (the `dithen sweep --batched` path)
    let batched = run_specs_batched(&specs, 2, &BankCache::new()).unwrap();
    assert_eq!(reference, batched);
}

/// PR-5 shard-split pin, degenerate case: a 1-part "split" driven
/// through the whole multi-platform machinery (split → platform per
/// part → shard audit → merge) must be bit-identical to the unsplit
/// `Scenario::run`.
#[test]
fn sharded_single_part_is_bit_identical_to_unsplit() {
    let scn = ScenarioBuilder::new(cfg(33))
        .workloads(suite(33, 3, 25))
        .fixed_ttc(Some(3600))
        .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
        .horizon(6 * 3600)
        .build();
    let cache = BankCache::new();
    let unsplit = scn.run_with_cache(&cache).unwrap();
    let merged = run_sharded(&scn, 1, 1, &cache).unwrap();
    assert_eq!(unsplit, merged, "1-part sharded run diverged from the unsplit platform");
}

/// PR-5 shard-split pin, multi-part: platform instances over disjoint
/// workload shard sets merge to the same `RunMetrics` no matter how
/// many worker threads drive them, and the merged totals conserve the
/// scenario's work exactly (every task terminal exactly once across
/// the disjoint shard sets — the in-driver audit would fail the run
/// otherwise).
#[test]
fn sharded_runs_merge_thread_count_invariantly() {
    let scn = ScenarioBuilder::new(cfg(34))
        .workloads(suite(34, 4, 20))
        .fixed_ttc(Some(3600))
        .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
        .horizon(6 * 3600)
        .record_traces(false)
        .build();
    let cache = BankCache::new();
    let reference = run_sharded(&scn, 3, 1, &cache).unwrap();
    for threads in [2usize, 4, 8] {
        let m = run_sharded(&scn, 3, threads, &cache).unwrap();
        assert_eq!(reference, m, "{threads}-thread sharded run diverged");
    }
    assert_eq!(reference.outcomes.len(), 4);
    assert_eq!(reference.tasks_completed, scn.n_tasks());
}

/// PR-6 sparse regime: three small workloads arrive two hours apart
/// and finish well inside their gap, so most monitoring instants fall
/// in provably idle stretches — exactly where the event-driven tick
/// skipper engages. `dense` pins the skipper off (the pre-PR-6 dense
/// tick loop) for the bit-identity comparisons below. Traces stay on:
/// the equality checks then cover every per-tick curve and sample the
/// fast-forward path must reproduce, not just end-of-run totals.
fn sparse_scenario(seed: u64, dense: bool) -> Scenario {
    ScenarioBuilder::new(cfg(seed))
        .workloads(suite(seed, 3, 12))
        .fixed_ttc(Some(1800))
        .arrivals(ArrivalProcess::FixedInterval { interval_s: 7200 })
        .horizon(8 * 3600)
        .dense_ticks(dense)
        .build()
}

/// PR-6 headline pin: a tick-skipped run must be **bit-identical** to
/// its dense twin — exhaustive `RunMetrics` equality over every curve,
/// trace, cost and outcome (only the `ticks_skipped` diagnostic is
/// excluded from `PartialEq`) — while actually executing fewer ticks.
#[test]
fn tick_skip_is_bit_identical_to_dense() {
    for seed in [1u64, 42, 20161021] {
        let skip = sparse_scenario(seed, false).run().unwrap();
        let dense = sparse_scenario(seed, true).run().unwrap();
        assert_eq!(skip, dense, "seed {seed}: tick-skipped run diverged from dense twin");
        assert_eq!(dense.ticks_skipped, 0, "dense_ticks must pin the skipper off");
        assert!(skip.ticks_skipped > 0, "seed {seed}: sparse regime never engaged the skipper");
        assert_eq!(skip.ticks, dense.ticks, "charged tick count must match the dense run");
        assert!(
            skip.ticks_executed() < dense.ticks,
            "seed {seed}: skipping must reduce executed ticks ({} vs {})",
            skip.ticks_executed(),
            dense.ticks
        );
    }
}

/// The `RunOpts` shim reaches the same skipper: `dense_ticks` through
/// `run_experiment` pins it off the same way the builder does.
#[test]
fn tick_skip_via_run_opts_shim() {
    let sparse_opts = |dense| RunOpts {
        fixed_ttc_s: Some(1800),
        arrival_interval_s: 7200,
        horizon_s: 8 * 3600,
        dense_ticks: dense,
        ..Default::default()
    };
    let skip = run_experiment(cfg(9), suite(9, 3, 12), sparse_opts(false)).unwrap();
    let dense = run_experiment(cfg(9), suite(9, 3, 12), sparse_opts(true)).unwrap();
    assert_eq!(skip, dense);
    assert!(skip.ticks_skipped > 0);
    assert_eq!(dense.ticks_skipped, 0);
}

/// Fault-injected sparse runs: every fault leg of the skip horizon
/// (market bid-crossing, per-pool bids on a mixed fleet, scripted
/// schedule — including an instant deep inside an idle stretch) must
/// stop the fast-forward exactly where the dense run observes the
/// event.
#[test]
fn tick_skip_under_faults_is_bit_identical_to_dense() {
    let scn = |seed, fault: FaultSpec, dense| {
        ScenarioBuilder::new(cfg(seed))
            .workloads(suite(seed, 3, 12))
            .fixed_ttc(Some(1800))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 7200 })
            .horizon(8 * 3600)
            .fault(fault)
            .dense_ticks(dense)
            .build()
    };
    let faults = [
        ("reclaim", FaultSpec::SpotReclamation { bid: 0.0082 }),
        // 20000 s sits in the post-completion idle tail — the scripted
        // leg must cut the skip there so the cursor state stays dense
        ("reclaim-at", FaultSpec::ReclamationAt { times: vec![600, 5000, 20000] }),
        // PR-10 partial failures act at dispatch/completion/request
        // instants, so they add no skip-horizon leg of their own:
        // retries, delayed boots and twin completions all surface as
        // ordinary events that already bound the fast-forward
        ("straggler", FaultSpec::Straggler { frac: 0.25, slowdown: 4.0 }),
        ("crash", FaultSpec::ChunkCrash { rate: 0.01 }),
        ("flake", FaultSpec::LaunchFlake { prob: 0.3, delay_s: 120 }),
    ];
    for (name, fault) in faults {
        let skip = scn(13, fault.clone(), false).run().unwrap();
        let dense = scn(13, fault, true).run().unwrap();
        assert_eq!(skip, dense, "{name}: tick-skipped run diverged from dense twin");
        assert_eq!(skip.reclamations, dense.reclamations);
        assert!(skip.ticks_skipped > 0, "{name}: skipper never engaged");
    }
    // mixed two-pool fleet under per-pool reclamation: the skip horizon
    // must respect per-instance hourly billing anchors and the price
    // boundaries of both pools at once
    let mixed = |dense| {
        let mut c = cfg(17);
        c.control.n_min = 20.0;
        ScenarioBuilder::new(c)
            .workloads(suite(17, 3, 12))
            .fixed_ttc(Some(1800))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 7200 })
            .horizon(8 * 3600)
            .fleet(FleetSpec::parse("m3.medium:bid=0.1,m4.4xlarge:bid=0.115").unwrap())
            .fault(FaultSpec::PoolReclamation)
            .dense_ticks(dense)
            .build()
    };
    let skip = mixed(false).run().unwrap();
    let dense = mixed(true).run().unwrap();
    assert_eq!(skip, dense, "mixed fleet: tick-skipped run diverged from dense twin");
    assert_eq!(skip.reclamations_by_pool, dense.reclamations_by_pool);
    assert!(skip.ticks_skipped > 0, "mixed fleet: skipper never engaged");
}

/// The skipper composes with every executor: the parallel runner, the
/// PR-5 lockstep batched executor, and the multi-platform shard driver
/// all produce results bit-identical to the dense sequential reference.
#[test]
fn tick_skip_composes_with_batched_and_sharded_executors() {
    let skip_specs = vec![
        RunSpec::new("skip/plain", sparse_scenario(70, false)),
        RunSpec::new("skip/reclaim", {
            let mut s = sparse_scenario(71, false);
            s.fault = FaultSpec::SpotReclamation { bid: 0.0082 };
            s
        }),
    ];
    let dense_specs: Vec<RunSpec> = skip_specs
        .iter()
        .map(|s| {
            let mut d = s.clone();
            d.scenario.dense_ticks = true;
            d
        })
        .collect();
    let reference = run_specs(&dense_specs, 1).unwrap();
    let parallel = run_specs(&skip_specs, 2).unwrap();
    assert_eq!(reference, parallel, "parallel tick-skipped sweep diverged from dense reference");
    assert!(parallel.iter().all(|m| m.ticks_skipped > 0));
    let batched = run_specs_batched(&skip_specs, 2, &BankCache::new()).unwrap();
    assert_eq!(reference, batched, "batched tick-skipped sweep diverged from dense reference");
    assert!(batched.iter().all(|m| m.ticks_skipped > 0));

    // shard driver: each part's platform sees its own sparse subset
    let shard_scn = |dense| {
        ScenarioBuilder::new(cfg(72))
            .workloads(suite(72, 4, 12))
            .fixed_ttc(Some(1800))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 7200 })
            .horizon(12 * 3600)
            .dense_ticks(dense)
            .build()
    };
    let cache = BankCache::new();
    let dense = run_sharded(&shard_scn(true), 2, 1, &cache).unwrap();
    let skipped = run_sharded(&shard_scn(false), 2, 2, &cache).unwrap();
    assert_eq!(dense, skipped, "sharded tick-skipped run diverged from dense sharded run");
    assert!(skipped.ticks_skipped > 0, "no shard engaged the skipper");
    assert_eq!(dense.ticks_skipped, 0);
}

/// PR-8 headline pin: a streamed run — workloads materialized lazily at
/// their arrival instants, shards audited and retired as workloads turn
/// terminal — must be **bit-identical** to its materialize-everything
/// twin. Traces stay on, so the equality covers every per-tick curve
/// and estimator sample, not just end-of-run totals; the comparison is
/// repeated across dense and tick-skipped execution (the skip horizon
/// gained an arrival leg from the stream cursor) and across sweep
/// thread counts.
#[test]
fn streaming_is_bit_identical_to_materialized() {
    let streamed_scn = |seed: u64, dense: bool, retire: bool| {
        ScenarioBuilder::new(cfg(seed))
            .stream(StreamSpec {
                n_workloads: 4,
                tasks_per_workload: 12,
                app: App::FaceDetection,
            })
            .retire_shards(retire)
            .fixed_ttc(Some(1800))
            // the PR-6 sparse shape: each workload finishes well inside
            // its two-hour arrival gap, so the skipper has idle
            // stretches to fast-forward — now bounded by the stream
            // cursor's next-arrival leg as well
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 7200 })
            .horizon(12 * 3600)
            .dense_ticks(dense)
            .record_traces(true)
            .build()
    };
    for seed in [1u64, 42] {
        for dense in [true, false] {
            let scn = streamed_scn(seed, dense, true);
            let mut twin = scn.materialize();
            assert!(twin.stream.is_none() && twin.specs.len() == 4, "twin must be eager");
            twin.retire_shards = false;
            let batch = twin.run().unwrap();
            let streamed = scn.run().unwrap();
            assert_eq!(
                streamed, batch,
                "seed {seed} dense={dense}: streamed+retired run diverged from the batch twin"
            );
            // retirement alone must be bitwise-unobservable too
            let kept = streamed_scn(seed, dense, false).run().unwrap();
            assert_eq!(
                kept, batch,
                "seed {seed} dense={dense}: streamed run without retirement diverged"
            );
            assert_eq!(streamed.tasks_completed, 4 * 12);
            if !dense {
                assert!(streamed.ticks_skipped > 0, "seed {seed}: skipper never engaged");
            }
        }
    }
    // thread-count invariance through the parallel sweep runner
    let specs: Vec<RunSpec> = [5u64, 6]
        .iter()
        .map(|&s| RunSpec::new(format!("stream/{s}"), streamed_scn(s, false, true)))
        .collect();
    let reference = run_specs(&specs, 1).unwrap();
    for threads in [2usize, 8] {
        let parallel = run_specs(&specs, threads).unwrap();
        assert_eq!(
            reference, parallel,
            "{threads}-thread streamed sweep diverged from the sequential reference"
        );
    }
}

/// PR-9 trait-seam pin: routing AIMD + Kalman through the
/// `ControlPolicy` trait object (and the per-instance exec-multiplier
/// hook, exactly 1.0 on the default m3.medium fleet) must leave the
/// platform bit-identical to itself wherever it runs — repeated direct
/// runs with traces ON (every per-tick estimator sample and curve
/// compared, exhaustive `RunMetrics` equality) and the parallel runner
/// at 1/2/8 threads all produce one value. The scenario is the
/// reclamation cell the PR-9 Pareto sweep leans on.
#[test]
fn trait_dispatched_aimd_kalman_is_bit_identical_across_executors() {
    let traced = |seed: u64| {
        let mut s = reclamation_scenario(seed);
        s.record_traces = true;
        s
    };
    for seed in [11u64, 20161021] {
        let direct_a = traced(seed).run().unwrap();
        let direct_b = traced(seed).run().unwrap();
        assert_eq!(direct_a, direct_b, "seed {seed}: trait-dispatched AIMD+Kalman diverged");
        assert!(!direct_a.traces.is_empty(), "traces must be on for this pin to bite");
        let specs = vec![RunSpec::new("pin/aimd-kalman", traced(seed))];
        for threads in [1usize, 2, 8] {
            let swept = run_specs(&specs, threads).unwrap();
            assert_eq!(
                direct_a, swept[0],
                "seed {seed}: {threads}-thread sweep diverged from the direct run"
            );
        }
    }
}

/// PR-10 partial-failure determinism pin: straggler marking, per-chunk
/// crash draws and launch flakes are all pure functions of (seed,
/// entity id) through salted substreams, so a fault-injected run must
/// be bit-identical run-to-run and thread-count-invariant through the
/// parallel sweep runner — including the recovery machinery it drags
/// in (retry backoff, speculative twins, abandonment receipts).
#[test]
fn partial_failure_faults_are_deterministic_across_runs_and_threads() {
    let scn = |seed: u64, fault: FaultSpec| {
        ScenarioBuilder::new(cfg(seed))
            .workloads(suite(seed, 2, 30))
            .fixed_ttc(Some(1800))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(8 * 3600)
            .fault(fault)
            .build()
    };
    let faults = [
        ("straggler", FaultSpec::Straggler { frac: 0.25, slowdown: 4.0 }),
        ("crash", FaultSpec::ChunkCrash { rate: 0.01 }),
        ("flake", FaultSpec::LaunchFlake { prob: 0.3, delay_s: 120 }),
    ];
    let mut specs: Vec<RunSpec> = vec![];
    for (name, fault) in faults {
        let a = scn(21, fault.clone()).run().unwrap();
        let b = scn(21, fault.clone()).run().unwrap();
        assert_eq!(a, b, "{name}: two sequential runs diverged");
        // the receipts are part of the exhaustive equality above, but
        // make the fault-stream determinism explicit too
        assert_eq!(a.chunk_retries, b.chunk_retries, "{name}");
        assert_eq!(a.speculative_launches, b.speculative_launches, "{name}");
        assert_eq!(a.straggler_instances, b.straggler_instances, "{name}");
        assert_eq!(a.tasks_abandoned, b.tasks_abandoned, "{name}");
        specs.push(RunSpec::new(format!("pf/{name}"), scn(21, fault)));
    }
    let sequential = run_specs(&specs, 1).unwrap();
    for threads in [2usize, 8] {
        let parallel = run_specs(&specs, threads).unwrap();
        assert_eq!(
            sequential, parallel,
            "{threads}-thread partial-failure sweep diverged from the sequential reference"
        );
    }
}

#[test]
fn parallel_runner_is_thread_count_invariant() {
    // a mixed grid: different seeds, estimators, policies, and a
    // reclamation scenario (the fault path must also be thread-invariant)
    let mut specs: Vec<RunSpec> = vec![];
    for (i, est) in dithen::estimation::EstimatorKind::ALL.iter().enumerate() {
        let seed = 7 + i as u64;
        specs.push(RunSpec::from_opts(
            format!("det/{i}"),
            cfg(seed),
            suite(seed, 2, 25),
            RunOpts { estimator: *est, ..opts() },
        ));
    }
    for (i, policy) in [
        dithen::coordinator::PolicyKind::Aimd,
        dithen::coordinator::PolicyKind::Reactive,
        dithen::coordinator::PolicyKind::Mwa,
        dithen::coordinator::PolicyKind::Pid,
        dithen::coordinator::PolicyKind::Mpc,
    ]
    .iter()
    .enumerate()
    {
        let seed = 100 + i as u64;
        specs.push(RunSpec::from_opts(
            format!("det/p{i}"),
            cfg(seed),
            suite(seed, 1, 30),
            RunOpts { policy: *policy, ..opts() },
        ));
    }
    specs.push(RunSpec::new("det/reclaim", reclamation_scenario(55)));
    specs.push(RunSpec::new("det/fleet", mixed_fleet_scenario(60)));

    let sequential = run_specs(&specs, 1).unwrap();
    for threads in [2usize, 4, 8] {
        let parallel = run_specs(&specs, threads).unwrap();
        assert_eq!(
            sequential, parallel,
            "{threads}-thread sweep diverged from the sequential reference"
        );
    }
}
