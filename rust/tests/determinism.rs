//! Determinism property tests: the same `Config::seed` must produce
//! **byte-identical** `RunMetrics` — across repeated sequential runs,
//! and across the parallel experiment runner at any thread count.
//! (`RunMetrics` derives `PartialEq` over every curve, trace and
//! outcome, so equality here is exhaustive, not a spot check.)

use dithen::config::Config;
use dithen::experiments::parallel::{run_specs, RunSpec};
use dithen::platform::{run_experiment, RunOpts};
use dithen::util::rng::Rng;
use dithen::workload::{App, WorkloadSpec};

fn cfg(seed: u64) -> Config {
    let mut c = Config::paper_defaults();
    c.use_xla = false;
    c.control.n_min = 4.0;
    c.seed = seed;
    c
}

fn opts() -> RunOpts {
    RunOpts {
        fixed_ttc_s: Some(3600),
        arrival_interval_s: 60,
        horizon_s: 6 * 3600,
        ..Default::default()
    }
}

fn suite(seed: u64, n_wl: usize, tasks_each: usize) -> Vec<WorkloadSpec> {
    let rng = Rng::new(seed);
    (0..n_wl)
        .map(|i| WorkloadSpec::generate(i, App::FaceDetection, tasks_each, None, &rng))
        .collect()
}

#[test]
fn same_seed_same_metrics_sequentially() {
    for seed in [1u64, 42, 20161021] {
        let a = run_experiment(cfg(seed), suite(seed, 2, 30), opts()).unwrap();
        let b = run_experiment(cfg(seed), suite(seed, 2, 30), opts()).unwrap();
        assert_eq!(a, b, "seed {seed}: two sequential runs diverged");
    }
}

#[test]
fn different_seeds_differ() {
    let a = run_experiment(cfg(1), suite(1, 2, 30), opts()).unwrap();
    let b = run_experiment(cfg(2), suite(2, 2, 30), opts()).unwrap();
    assert_ne!(a.total_cost, b.total_cost);
}

#[test]
fn parallel_runner_is_thread_count_invariant() {
    // a mixed grid: different seeds, estimators and policies
    let mut specs: Vec<RunSpec> = vec![];
    for (i, est) in dithen::estimation::EstimatorKind::ALL.iter().enumerate() {
        let seed = 7 + i as u64;
        specs.push(RunSpec {
            label: format!("det/{i}"),
            cfg: cfg(seed),
            suite: suite(seed, 2, 25),
            opts: RunOpts { estimator: *est, ..opts() },
        });
    }
    for (i, policy) in [
        dithen::coordinator::PolicyKind::Aimd,
        dithen::coordinator::PolicyKind::Reactive,
        dithen::coordinator::PolicyKind::Mwa,
    ]
    .iter()
    .enumerate()
    {
        let seed = 100 + i as u64;
        specs.push(RunSpec {
            label: format!("det/p{i}"),
            cfg: cfg(seed),
            suite: suite(seed, 1, 30),
            opts: RunOpts { policy: *policy, ..opts() },
        });
    }

    let sequential = run_specs(&specs, 1).unwrap();
    for threads in [2usize, 4, 8] {
        let parallel = run_specs(&specs, threads).unwrap();
        assert_eq!(
            sequential, parallel,
            "{threads}-thread sweep diverged from the sequential reference"
        );
    }
}
