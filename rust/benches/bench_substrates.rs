//! Substrate micro-benchmarks: the building blocks under the platform
//! loop — spot-market trace generation (Fig. 12), task-DB operations,
//! tracker assignment, chunk execution model, and the event queue.

mod common;

use dithen::cloud::Market;
use dithen::config::{MarketCfg, StorageCfg};
use dithen::coordinator::Tracker;
use dithen::db::{legacy::LegacyTaskDb, TaskDb};
use dithen::lci::execute_chunk;
use dithen::sim::{Engine, Event};
use dithen::storage::ObjectStore;
use dithen::util::rng::Rng;
use dithen::workload::{App, WorkloadSpec};

fn main() {
    // Fig. 12 substrate: 3-month price simulation for 6 types
    common::bench("market/3mo_6type_trace", 2, 50, || {
        Market::new(MarketCfg::default(), 7, 24 * 91)
    });

    // task DB: insert + claim + complete cycle for 10k tasks —
    // flat arena (current) vs the seed's BTreeMap store (baseline)
    common::bench("db/10k_task_lifecycle/arena", 1, 30, || {
        let mut db = TaskDb::new();
        for t in 0..10_000 {
            db.insert(0, 0, t);
        }
        db.reserve_measurements(0);
        for t in 0..10_000 {
            db.claim((0, t), 1);
            db.complete((0, t), 1.0, t as u64, 0);
        }
        db.workload_complete(0)
    });
    common::bench("db/10k_task_lifecycle/legacy", 1, 30, || {
        let mut db = LegacyTaskDb::new();
        for t in 0..10_000 {
            db.insert(0, 0, t);
        }
        for t in 0..10_000 {
            db.claim((0, t), 1);
            db.complete((0, t), 1.0, t as u64, 0);
        }
        db.workload_complete(0)
    });

    // the GCI-tick measurement query on a 50k-row workload: windowed
    // log slice (arena) vs full-table scan (legacy)
    let mut adb = TaskDb::new();
    let mut ldb = LegacyTaskDb::new();
    for t in 0..50_000 {
        adb.insert(0, t % 2, t);
        ldb.insert(0, t % 2, t);
    }
    adb.reserve_measurements(0);
    for t in 0..50_000 {
        adb.claim((0, t), 1);
        adb.complete((0, t), 1.0, t as u64, 0);
        ldb.claim((0, t), 1);
        ldb.complete((0, t), 1.0, t as u64, 0);
    }
    common::bench("db/50k_meas_window/arena", 10, 2000, || {
        adb.measurements_window(0, 0, 40_000, 40_060).len()
    });
    common::bench("db/50k_meas_window/legacy", 2, 50, || {
        ldb.measurements_between(0, 0, 40_000, 40_060).len()
    });

    // tracker: 64 workloads, 1000 tick+assign cycles
    common::bench("tracker/64wl_1k_cycles", 2, 50, || {
        let mut tr = Tracker::new(10.0);
        let rates: Vec<f64> = vec![0.7; 64];
        for w in 0..64usize {
            tr.register(w);
            tr.set_pending(w, true);
        }
        for _ in 0..1000 {
            tr.tick(&rates);
            while let Some(w) = tr.next_assignment() {
                tr.on_assign(w);
                tr.on_release(w);
            }
        }
        tr.allocated(0)
    });

    // chunk execution model (the per-chunk simulation cost)
    let rng = Rng::new(1);
    let spec = WorkloadSpec::generate(0, App::FaceDetection, 1000, None, &rng);
    let storage = ObjectStore::new(StorageCfg::default());
    let tasks: Vec<usize> = (0..100).collect();
    common::bench("lci/execute_chunk_100_tasks", 10, 2000, || {
        execute_chunk(&spec, &tasks, false, &storage)
    });

    // event queue throughput
    common::bench("sim/100k_event_churn", 1, 20, || {
        let mut e = Engine::new();
        for i in 0..100_000u64 {
            e.schedule(i % 1000, Event::MonitorTick);
        }
        let mut n = 0;
        while e.next().is_some() {
            n += 1;
        }
        n
    });
}
