//! End-to-end experiment benchmarks — one per paper table/figure family.
//!
//! Times a complete simulated experiment (the same code paths `dithen
//! repro` runs): Table II's estimation run, Fig. 8/9 + Table III's cost
//! runs per policy, Table IV's Lambda pricing sweep, and Fig. 10/11's
//! Split–Merge runs. Wall time here is the cost of regenerating each
//! paper artifact.

mod common;

use dithen::cloud::lambda::price_batch;
use dithen::config::Config;
use dithen::coordinator::PolicyKind;
use dithen::platform::{run_experiment, RunOpts};
use dithen::workload::{cnn_splitmerge, lambda_suite, paper_suite, wordcount_splitmerge};

fn cfg() -> Config {
    let mut c = Config::paper_defaults();
    c.use_xla = false; // keep benches backend-independent; see bench_bank
    c.control.monitor_interval_s = 300;
    c
}

fn main() {
    let cfg = cfg();

    // Table II family: the full suite under AIMD/Kalman (1-min ticks)
    common::bench("table2/suite_run_1min", 1, 5, || {
        let mut c = cfg.clone();
        c.control.monitor_interval_s = 60;
        run_experiment(c, paper_suite(cfg.seed), RunOpts {
            fixed_ttc_s: Some(7620),
            horizon_s: 12 * 3600,
            ..Default::default()
        })
        .unwrap()
    });

    // Fig. 8 / Table III family: one cost run per policy
    for policy in [
        PolicyKind::Aimd,
        PolicyKind::Reactive,
        PolicyKind::Mwa,
        PolicyKind::Lr,
        PolicyKind::AmazonAs1,
    ] {
        let ttc = if policy == PolicyKind::AmazonAs1 { None } else { Some(7620) };
        common::bench(&format!("fig8/{}", policy.name()), 1, 5, || {
            run_experiment(cfg.clone(), paper_suite(cfg.seed), RunOpts {
                policy,
                fixed_ttc_s: ttc,
                horizon_s: 16 * 3600,
                ..Default::default()
            })
            .unwrap()
        });
    }

    // Table IV family: Lambda pricing of 75k tasks
    let suite = lambda_suite(cfg.seed, 25_000);
    common::bench("table4/lambda_pricing_75k_tasks", 2, 20, || {
        suite
            .iter()
            .map(|s| {
                let d: Vec<f64> = s.tasks.iter().map(|t| t.true_cus).collect();
                price_batch(&cfg.lambda, &d)
            })
            .collect::<Vec<_>>()
    });

    // Fig. 10/11 family: Split–Merge runs
    common::bench("fig10/cnn_splitmerge", 1, 5, || {
        run_experiment(cfg.clone(), vec![cnn_splitmerge(cfg.seed)], RunOpts {
            fixed_ttc_s: Some(5130),
            horizon_s: 12 * 3600,
            ..Default::default()
        })
        .unwrap()
    });
    common::bench("fig11/wordcount_splitmerge", 1, 5, || {
        run_experiment(cfg.clone(), vec![wordcount_splitmerge(cfg.seed)], RunOpts {
            fixed_ttc_s: Some(3510),
            horizon_s: 12 * 3600,
            ..Default::default()
        })
        .unwrap()
    });
}
