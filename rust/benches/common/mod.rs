//! Minimal benchmark harness (the offline vendor set has no criterion).
//!
//! Each bench binary is `harness = false` and uses `bench()` to report
//! mean / p50 / p95 wall time per iteration after a warm-up, in a stable
//! one-line format that EXPERIMENTS.md §Perf records.

use std::time::Instant;

/// Run `f` for `iters` timed iterations (after `warmup` untimed ones) and
/// print statistics. Returns the mean nanoseconds per iteration.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> f64 {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    println!(
        "bench {name:<44} mean {:>12} p50 {:>12} p95 {:>12} (n={iters})",
        fmt_ns(mean),
        fmt_ns(p50),
        fmt_ns(p95),
    );
    mean
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}
