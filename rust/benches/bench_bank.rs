//! L1/L2 hot-path benchmark: one estimator-bank monitoring step, XLA
//! (AOT Pallas/JAX via PJRT) vs native rust, across bank shapes.
//!
//! This is the compute kernel executed at every GCI monitoring instant;
//! its latency budget is the monitoring interval (60 s), so anything in
//! the µs–ms range leaves 4–6 orders of magnitude of headroom — the
//! numbers here feed EXPERIMENTS.md §Perf.

mod common;

use dithen::estimation::{Backend, Bank, BankParams, TickInputs};
use dithen::runtime::Engine;
use dithen::util::rng::Rng;

fn params() -> BankParams {
    BankParams {
        sigma_z2: 0.5,
        sigma_v2: 0.5,
        alpha: 5.0,
        beta: 0.9,
        n_min: 10.0,
        n_max: 100.0,
        n_w_max: 10.0,
    }
}

fn inputs(w: usize, k: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let wk = w * k;
    let slot: Vec<f32> = (0..wk).map(|_| 1.0).collect();
    let meas: Vec<f32> = (0..wk).map(|_| if rng.f64() < 0.7 { 1.0 } else { 0.0 }).collect();
    let bt: Vec<f32> = (0..wk).map(|_| rng.uniform(1.0, 200.0) as f32).collect();
    let m: Vec<f32> = (0..wk).map(|_| rng.int(0, 500) as f32).collect();
    let d: Vec<f32> = (0..w).map(|_| rng.uniform(60.0, 7620.0) as f32).collect();
    (slot, meas, bt, m, d)
}

fn main() {
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut rng = Rng::new(0xBE);
    for &(w, k) in &[(8usize, 2usize), (64, 4), (256, 8)] {
        let (slot, meas, bt, m, d) = inputs(w, k, &mut rng);
        let tick = TickInputs {
            b_tilde: &bt,
            meas_mask: &meas,
            m_rem: &m,
            slot_mask: &slot,
            d: &d,
            n_tot: 10.0,
        };
        let mut native = Bank::new(w, k, params(), Backend::Native);
        common::bench(&format!("bank_step/native/{w}x{k}"), 50, 2000, || {
            native.step(&tick).unwrap()
        });
        if artifacts.join("manifest.json").exists() {
            let engine = Engine::load(&artifacts).unwrap();
            let mut xla = Bank::new(w, k, params(), Backend::xla(engine));
            common::bench(&format!("bank_step/xla/{w}x{k}"), 20, 500, || {
                xla.step(&tick).unwrap()
            });
        } else {
            eprintln!("artifacts missing; skipping XLA bench for {w}x{k}");
        }
    }
}
