//! L1/L2 hot-path benchmark: one estimator-bank monitoring step, XLA
//! (AOT Pallas/JAX via PJRT) vs native rust, across bank shapes.
//!
//! This is the compute kernel executed at every GCI monitoring instant;
//! its latency budget is the monitoring interval (60 s), so anything in
//! the µs–ms range leaves 4–6 orders of magnitude of headroom — the
//! numbers here feed EXPERIMENTS.md §Perf.

mod common;

use dithen::estimation::{
    kalman_update_scalar, kalman_update_simd, Backend, Bank, BankParams, BatchScratch, TickInputs,
};
use dithen::runtime::{Engine, StepOutputs};
use dithen::util::rng::Rng;

fn params() -> BankParams {
    BankParams {
        sigma_z2: 0.5,
        sigma_v2: 0.5,
        alpha: 5.0,
        beta: 0.9,
        n_min: 10.0,
        n_max: 100.0,
        n_w_max: 10.0,
    }
}

fn inputs(w: usize, k: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let wk = w * k;
    let slot: Vec<f32> = (0..wk).map(|_| 1.0).collect();
    let meas: Vec<f32> = (0..wk).map(|_| if rng.f64() < 0.7 { 1.0 } else { 0.0 }).collect();
    let bt: Vec<f32> = (0..wk).map(|_| rng.uniform(1.0, 200.0) as f32).collect();
    let m: Vec<f32> = (0..wk).map(|_| rng.int(0, 500) as f32).collect();
    let d: Vec<f32> = (0..w).map(|_| rng.uniform(60.0, 7620.0) as f32).collect();
    (slot, meas, bt, m, d)
}

fn main() {
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut rng = Rng::new(0xBE);
    for &(w, k) in &[(8usize, 2usize), (64, 4), (256, 8)] {
        let (slot, meas, bt, m, d) = inputs(w, k, &mut rng);
        let tick = TickInputs {
            b_tilde: &bt,
            meas_mask: &meas,
            m_rem: &m,
            slot_mask: &slot,
            d: &d,
            n_tot: 10.0,
        };
        let mut native = Bank::new(w, k, params(), Backend::Native);
        common::bench(&format!("bank_step/native/{w}x{k}"), 50, 2000, || {
            native.step(&tick).unwrap()
        });
        if artifacts.join("manifest.json").exists() {
            let engine = Engine::load(&artifacts).unwrap();
            let mut xla = Bank::new(w, k, params(), Backend::xla(engine));
            common::bench(&format!("bank_step/xla/{w}x{k}"), 20, 500, || {
                xla.step(&tick).unwrap()
            });
        } else {
            eprintln!("artifacts missing; skipping XLA bench for {w}x{k}");
        }
    }

    // PR-6: the stage-1 Kalman measurement update in isolation, scalar
    // index loop vs the 8-lane unrolled kernel `native_step_slices` now
    // calls, across the ISSUE grid of bank shapes. Both variants are
    // bit-identical by construction (no reassociation, no cross-lane
    // ops) — this bench records what the unrolling is worth, and the
    // outputs are compared once per shape as a cheap sanity cross-check.
    for &(w, k) in &[(4usize, 8usize), (8, 16), (16, 32)] {
        let wk = w * k;
        let (slot, meas, bt, _m, _d) = inputs(w, k, &mut rng);
        let b_hat: Vec<f32> = (0..wk).map(|_| rng.uniform(1.0, 200.0) as f32).collect();
        let pi: Vec<f32> = (0..wk).map(|_| rng.uniform(0.1, 5.0) as f32).collect();
        let p = params();
        let mut sb = vec![0.0f32; wk];
        let mut sp = vec![0.0f32; wk];
        let mut vb = vec![0.0f32; wk];
        let mut vp = vec![0.0f32; wk];
        common::bench(&format!("kalman_stage1/scalar/{w}x{k}"), 100, 20000, || {
            kalman_update_scalar(&b_hat, &pi, &bt, &meas, &slot, &p, &mut sb, &mut sp);
            sb[0]
        });
        common::bench(&format!("kalman_stage1/simd/{w}x{k}"), 100, 20000, || {
            kalman_update_simd(&b_hat, &pi, &bt, &meas, &slot, &p, &mut vb, &mut vp);
            vb[0]
        });
        assert!(
            sb.iter().zip(&vb).all(|(a, b)| a.to_bits() == b.to_bits())
                && sp.iter().zip(&vp).all(|(a, b)| a.to_bits() == b.to_bits()),
            "scalar and SIMD stage-1 kernels diverged at {w}x{k}"
        );
    }

    // PR-5: the lockstep batch path vs N per-cell steps, per batch
    // width — one sweep tick over N same-shape cells either as N
    // `step_into` calls or as gather → one `step_batch_into` → scatter
    // on the padded [N, W*K] scratch. Native backend (the grid-default
    // configuration); rust/BENCHMARKS.md "PR-5 update" records when
    // batching wins.
    let (w, k) = (32usize, 4usize);
    for &n in &[4usize, 16, 64] {
        let mut cells = Vec::with_capacity(n);
        for _ in 0..n {
            cells.push(inputs(w, k, &mut rng));
        }
        let mut looped: Vec<Bank> =
            (0..n).map(|_| Bank::new(w, k, params(), Backend::Native)).collect();
        let mut batched: Vec<Bank> =
            (0..n).map(|_| Bank::new(w, k, params(), Backend::Native)).collect();
        let mut outs: Vec<StepOutputs> = (0..n).map(|_| StepOutputs::default()).collect();
        let template = Bank::new(w, k, params(), Backend::Native);
        let mut batch = BatchScratch::default();
        common::bench(&format!("bank_batch/looped/{n}x{w}x{k}"), 20, 500, || {
            for (i, (slot, meas, bt, m, d)) in cells.iter().enumerate() {
                looped[i]
                    .step_into(
                        &TickInputs {
                            b_tilde: bt,
                            meas_mask: meas,
                            m_rem: m,
                            slot_mask: slot,
                            d,
                            n_tot: 10.0,
                        },
                        &mut outs[i],
                    )
                    .unwrap();
            }
        });
        common::bench(&format!("bank_batch/lockstep/{n}x{w}x{k}"), 20, 500, || {
            batch.begin(n, w, k);
            for (i, (slot, meas, bt, m, d)) in cells.iter().enumerate() {
                batch
                    .gather(
                        &batched[i],
                        &TickInputs {
                            b_tilde: bt,
                            meas_mask: meas,
                            m_rem: m,
                            slot_mask: slot,
                            d,
                            n_tot: 10.0,
                        },
                    )
                    .unwrap();
            }
            template.step_batch_into(&mut batch).unwrap();
            for (i, bank) in batched.iter_mut().enumerate() {
                batch.scatter(i, bank, &mut outs[i]);
            }
        });
    }
}
