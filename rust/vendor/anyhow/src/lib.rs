//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements exactly the surface the `dithen` crate uses — see
//! `vendor/README.md`. The error value is a flattened message chain
//! (outermost context first); `{e}` prints the outermost message,
//! `{e:#}` prints the whole chain separated by `: ` like upstream
//! anyhow's alternate formatting.

use std::fmt;

/// A dynamic error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Prepend a context message (becomes the new outermost entry).
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The outermost → innermost message chain.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }

    fn from_std<E: std::error::Error + ?Sized>(e: &E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}`: full cause chain, anyhow-style
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            Some((top, rest)) if !rest.is_empty() => {
                writeln!(f, "{top}")?;
                writeln!(f, "\nCaused by:")?;
                for (i, c) in rest.iter().enumerate() {
                    writeln!(f, "    {i}: {c}")?;
                }
                Ok(())
            }
            _ => write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or("")),
        }
    }
}

// The blanket `?` conversion. Error itself deliberately does NOT
// implement std::error::Error, which is what keeps this impl coherent
// (same trick as upstream anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($rest:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($rest)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn macros_build_messages() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(format!("{e}"), "bad value 3");

        fn f(v: usize) -> Result<usize> {
            ensure!(v < 10, "v too big: {v}");
            if v == 7 {
                bail!("unlucky");
            }
            Ok(v)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "v too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }
}
