//! API-compatible stub of the `xla` PJRT bindings.
//!
//! The offline vendor set has no `xla_extension` shared library, so
//! this crate mirrors the handful of types `dithen::runtime` touches
//! and reports the backend as unavailable from every entry point
//! (`PjRtClient::cpu()` errors, so callers fall back to the native
//! estimator bank before any other stubbed method can be reached).
//! Replacing the path dependency in `rust/Cargo.toml` with the real
//! bindings re-enables the AOT/PJRT hot path without source changes.

use std::fmt;

/// Error type matching the call sites' `?`-into-anyhow conversions.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>() -> Result<T> {
    Err(XlaError(
        "XLA/PJRT backend unavailable: built against the vendored stub (see rust/vendor/README.md)"
            .into(),
    ))
}

/// Element dtypes; only F32 is named by callers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// A host-side tensor literal.
#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// A device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// The PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Always errors in the stub; `Bank::with_best_backend` treats the
    /// failure as "no XLA" and picks the native backend.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// A computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_ops_report_unavailable() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 8])
            .is_err());
        assert!(Literal.to_tuple().is_err());
        assert!(Literal.to_vec::<f32>().is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
