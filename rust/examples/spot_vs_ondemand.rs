//! Scenario API tour — spot + reclamation vs on-demand.
//!
//! Builds the same bursty workload suite twice through the
//! `ScenarioBuilder` and runs it on two cloud backends:
//!
//! 1. the spot market with market-driven reclamation (instances revoked
//!    whenever the seeded spot price crosses the bid; in-flight chunks
//!    re-enter the task DB FIFO through `TaskDb::requeue`), and
//! 2. a flat-rate on-demand fleet that can never be reclaimed.
//!
//! The comparison prints the paper's core §IV trade: spot is several
//! times cheaper per billed hour, but the controller has to absorb
//! revocation churn (requeues, re-boots, lost busy time) to keep its
//! deadlines.
//!
//! Run:  cargo run --release --example spot_vs_ondemand

use dithen::cloud::BackendKind;
use dithen::config::Config;
use dithen::platform::{ArrivalProcess, FaultSpec, ScenarioBuilder};
use dithen::util::rng::Rng;
use dithen::util::table::{fmt_hm, Table};
use dithen::workload::{App, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::paper_defaults();
    cfg.control.n_min = 4.0;
    let rng = Rng::new(cfg.seed);
    let suite: Vec<WorkloadSpec> = (0..6)
        .map(|i| WorkloadSpec::generate(i, App::FaceDetection, 120, None, &rng))
        .collect();

    // flash-crowd arrivals: two bursts of three workloads
    let arrivals = ArrivalProcess::Bursty { burst: 3, gap_s: 1800 };

    let spot = ScenarioBuilder::new(cfg.clone())
        .workloads(suite.clone())
        .arrivals(arrivals.clone())
        .fixed_ttc(Some(3600))
        .horizon(12 * 3600)
        .backend(BackendKind::Spot)
        // bid barely above the m3.medium base price: the seeded market
        // occasionally crosses it and wipes the fleet
        .fault(FaultSpec::SpotReclamation { bid: 0.0083 })
        .build();
    let on_demand = ScenarioBuilder::new(cfg.clone())
        .workloads(suite)
        .arrivals(arrivals)
        .fixed_ttc(Some(3600))
        .horizon(12 * 3600)
        .backend(BackendKind::OnDemand)
        .build();

    println!("spot scenario:      {}", spot.describe());
    println!("on-demand scenario: {}", on_demand.describe());
    let ms = spot.run()?;
    let mo = on_demand.run()?;

    let mut t = Table::new(vec!["metric", "spot + reclamation", "on-demand"]);
    t.row(vec![
        "total cost".into(),
        format!("${:.3}", ms.total_cost),
        format!("${:.3}", mo.total_cost),
    ])
    .row(vec![
        "finished at".into(),
        fmt_hm(ms.finished_at as f64),
        fmt_hm(mo.finished_at as f64),
    ])
    .row(vec![
        "TTC compliance".into(),
        format!("{:.0}%", 100.0 * ms.ttc_compliance()),
        format!("{:.0}%", 100.0 * mo.ttc_compliance()),
    ])
    .row(vec![
        "reclamations".into(),
        format!("{}", ms.reclamations),
        format!("{}", mo.reclamations),
    ])
    .row(vec![
        "requeued tasks".into(),
        format!("{}", ms.requeued_tasks),
        format!("{}", mo.requeued_tasks),
    ])
    .row(vec![
        "max instances".into(),
        format!("{}", ms.max_instances),
        format!("{}", mo.max_instances),
    ]);
    t.print();

    println!(
        "spot is {:.1}x cheaper despite {} revocations",
        mo.total_cost / ms.total_cost.max(1e-12),
        ms.reclamations
    );
    Ok(())
}
