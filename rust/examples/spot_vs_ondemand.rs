//! Scenario API tour — spot + reclamation vs on-demand vs mixed fleet.
//!
//! Builds the same bursty workload suite three times through the
//! `ScenarioBuilder` and runs it on three cloud configurations:
//!
//! 1. the spot market with market-driven reclamation (instances revoked
//!    whenever the seeded spot price crosses the bid; replacement
//!    requests placed while the market is still above the bid stay
//!    *pending* — real-EC2 unfulfilled semantics — and in-flight chunks
//!    re-enter the task DB FIFO through `TaskDb::requeue`),
//! 2. a flat-rate on-demand fleet that can never be reclaimed, and
//! 3. a heterogeneous two-pool fleet (m3.medium + 16-CU m4.4xlarge,
//!    each with its own bid) under per-pool reclamation: a price spike
//!    on the volatile big type revokes only that pool while the small
//!    pool keeps working — a *partial* revocation.
//!
//! The comparison prints the paper's core §IV trade: spot is several
//! times cheaper per billed hour, but the controller has to absorb
//! revocation churn (requeues, re-boots, lost busy time) to keep its
//! deadlines.
//!
//! Run:  cargo run --release --example spot_vs_ondemand

use anyhow::Error;

use dithen::cloud::{BackendKind, FleetSpec};
use dithen::config::Config;
use dithen::platform::{ArrivalProcess, FaultSpec, ScenarioBuilder};
use dithen::util::rng::Rng;
use dithen::util::table::{fmt_hm, Table};
use dithen::workload::{App, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::paper_defaults();
    cfg.control.n_min = 4.0;
    let rng = Rng::new(cfg.seed);
    let suite: Vec<WorkloadSpec> = (0..6)
        .map(|i| WorkloadSpec::generate(i, App::FaceDetection, 120, None, &rng))
        .collect();

    // flash-crowd arrivals: two bursts of three workloads
    let arrivals = ArrivalProcess::Bursty { burst: 3, gap_s: 1800 };

    let base = |cfg: &Config| {
        ScenarioBuilder::new(cfg.clone())
            .workloads(suite.clone())
            .arrivals(arrivals.clone())
            .fixed_ttc(Some(3600))
            .horizon(12 * 3600)
    };

    let spot = base(&cfg)
        .backend(BackendKind::Spot)
        // bid barely above the m3.medium base price: the seeded market
        // occasionally crosses it and wipes the fleet
        .fault(FaultSpec::SpotReclamation { bid: 0.0083 })
        .build();
    let on_demand = base(&cfg).backend(BackendKind::OnDemand).build();
    let mut mixed_cfg = cfg.clone();
    mixed_cfg.control.n_min = 20.0; // bootstrap fits one 16-CU instance
    let fleet = FleetSpec::parse("m3.medium:bid=0.1,m4.4xlarge:bid=0.115").map_err(Error::msg)?;
    let mixed = base(&mixed_cfg)
        .backend(BackendKind::Spot)
        .fleet(fleet)
        .fault(FaultSpec::PoolReclamation)
        .build();

    println!("spot scenario:      {}", spot.describe());
    println!("on-demand scenario: {}", on_demand.describe());
    println!("mixed scenario:     {}", mixed.describe());
    let ms = spot.run()?;
    let mo = on_demand.run()?;
    let mx = mixed.run()?;

    let mut t = Table::new(vec!["metric", "spot + reclamation", "on-demand", "mixed fleet"]);
    t.row(vec![
        "total cost".into(),
        format!("${:.3}", ms.total_cost),
        format!("${:.3}", mo.total_cost),
        format!("${:.3}", mx.total_cost),
    ])
    .row(vec![
        "finished at".into(),
        fmt_hm(ms.finished_at as f64),
        fmt_hm(mo.finished_at as f64),
        fmt_hm(mx.finished_at as f64),
    ])
    .row(vec![
        "TTC compliance".into(),
        format!("{:.0}%", 100.0 * ms.ttc_compliance()),
        format!("{:.0}%", 100.0 * mo.ttc_compliance()),
        format!("{:.0}%", 100.0 * mx.ttc_compliance()),
    ])
    .row(vec![
        "reclamations".into(),
        format!("{}", ms.reclamations),
        format!("{}", mo.reclamations),
        format!("{:?}", mx.reclamations_by_pool),
    ])
    .row(vec![
        "requeued tasks".into(),
        format!("{}", ms.requeued_tasks),
        format!("{}", mo.requeued_tasks),
        format!("{}", mx.requeued_tasks),
    ])
    .row(vec![
        "unfulfilled requests".into(),
        format!("{}", ms.unfulfilled_requests),
        format!("{}", mo.unfulfilled_requests),
        format!("{}", mx.unfulfilled_requests),
    ])
    .row(vec![
        "max instances".into(),
        format!("{}", ms.max_instances),
        format!("{}", mo.max_instances),
        format!("{}", mx.max_instances),
    ]);
    t.print();

    println!(
        "spot is {:.1}x cheaper despite {} revocations; the mixed fleet's \
         per-pool revocations were {:?} (small pool keeps working)",
        mo.total_cost / ms.total_cost.max(1e-12),
        ms.reclamations,
        mx.reclamations_by_pool
    );
    Ok(())
}
