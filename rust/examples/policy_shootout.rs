//! Controller bake-off — AIMD vs PID vs MPC vs the reactive baseline.
//!
//! Runs one smoke-sized reclamation scenario (three face-detection
//! workloads arriving a minute apart on the spot market, bid barely
//! above the m3.medium base price) under four controllers, everything
//! else held fixed:
//!
//! 1. **AIMD** — the paper's billing-aware controller: additive
//!    increase toward N*, multiplicative decrease only at whole-hour
//!    billing boundaries (§III-B).
//! 2. **PID** — the PR-9 trait-dispatched three-term controller with
//!    conditional-integration anti-windup, tracking the same N* signal.
//! 3. **MPC** — the PR-9 receding-horizon controller: minimizes
//!    cost + deadline-shortfall penalty over an LR forecast of N*,
//!    tightening when the nearest deadline's slack shrinks.
//! 4. **Reactive** — snap to the instantaneous N* every tick, no
//!    smoothing and no billing awareness (the Pareto baseline the
//!    `sweep policies` dominance column is computed against).
//!
//! A fifth row swaps the Kalman bank for the last-observation
//! "reactive" *estimator* under the AIMD controller, separating what
//! the controller contributes from what the estimator contributes.
//!
//! Run:  cargo run --release --example policy_shootout

use dithen::config::Config;
use dithen::coordinator::PolicyKind;
use dithen::estimation::EstimatorKind;
use dithen::metrics::RunMetrics;
use dithen::platform::{ArrivalProcess, FaultSpec, Scenario, ScenarioBuilder};
use dithen::util::rng::Rng;
use dithen::util::table::{fmt_hm, Table};
use dithen::workload::{App, WorkloadSpec};

fn cell(policy: PolicyKind, estimator: EstimatorKind) -> Scenario {
    let mut cfg = Config::paper_defaults();
    cfg.control.n_min = 4.0;
    let rng = Rng::new(cfg.seed);
    let suite: Vec<WorkloadSpec> = (0..3)
        .map(|i| WorkloadSpec::generate(i, App::FaceDetection, 40, None, &rng))
        .collect();
    ScenarioBuilder::new(cfg)
        .workloads(suite)
        .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
        .fixed_ttc(Some(3600))
        .horizon(6 * 3600)
        .fault(FaultSpec::SpotReclamation { bid: 0.0082 })
        .policy(policy)
        .estimator(estimator)
        .build()
}

fn main() -> anyhow::Result<()> {
    let cells: Vec<(&str, PolicyKind, EstimatorKind)> = vec![
        ("aimd+kalman", PolicyKind::Aimd, EstimatorKind::Kalman),
        ("pid+kalman", PolicyKind::Pid, EstimatorKind::Kalman),
        ("mpc+kalman", PolicyKind::Mpc, EstimatorKind::Kalman),
        ("reactive+kalman", PolicyKind::Reactive, EstimatorKind::Kalman),
        ("aimd+reactive", PolicyKind::Aimd, EstimatorKind::Reactive),
    ];
    let mut results: Vec<(&str, RunMetrics)> = Vec::new();
    for &(label, policy, estimator) in &cells {
        let scn = cell(policy, estimator);
        println!("{label:>16}: {}", scn.describe());
        results.push((label, scn.run()?));
    }

    let mut t =
        Table::new(vec!["cell", "cost", "TTC compliance", "finished at", "max inst", "reclaims"]);
    for (label, m) in &results {
        t.row(vec![
            (*label).to_string(),
            format!("${:.3}", m.total_cost),
            format!("{:.0}%", 100.0 * m.ttc_compliance()),
            fmt_hm(m.finished_at as f64),
            format!("{}", m.max_instances),
            format!("{}", m.reclamations),
        ]);
    }
    t.print();

    // How to read the table: the reactive controller is the floor on
    // deadline performance (it buys exactly what N* asks for, instantly)
    // and usually the ceiling on cost — every fleet-size wiggle becomes
    // a boot plus a billed hour. AIMD sits on the cheap edge because it
    // only sheds instances at billing boundaries (an already-paid hour
    // is free capacity). PID lands between them: the integral term
    // closes steady-state error that AIMD's fixed additive step leaves,
    // while anti-windup keeps reclamation transients from slamming the
    // fleet. MPC spends slightly more than AIMD when forecasted demand
    // rises (it pre-provisions ahead of the ramp) and is the first to
    // tighten when deadline slack shrinks. The fifth row shows the
    // estimator's share of the margin: last-observation estimates make
    // chunk sizing twitchy, so even the cheap AIMD controller overbuys.
    let by = |l: &str| &results.iter().find(|(n, _)| *n == l).unwrap().1;
    let (aimd, reactive) = (by("aimd+kalman"), by("reactive+kalman"));
    println!(
        "aimd is {:.2}x the reactive baseline's cost at {:.0}% vs {:.0}% TTC compliance",
        aimd.total_cost / reactive.total_cost.max(1e-12),
        100.0 * aimd.ttc_compliance(),
        100.0 * reactive.ttc_compliance(),
    );
    Ok(())
}
