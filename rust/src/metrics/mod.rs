//! Run metrics: everything the experiment harness needs to regenerate
//! the paper's tables and figures from a platform run.

use crate::sim::SimTime;

/// Per-(workload, media-type) estimator trace (Fig. 6/7, Table II).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EstimatorTrace {
    /// (time, estimate) at each monitoring instant, per estimator.
    pub kalman: Vec<(SimTime, f64)>,
    pub adhoc: Vec<(SimTime, f64)>,
    pub arma: Vec<(SimTime, f64)>,
    pub ewma: Vec<(SimTime, f64)>,
    pub reactive: Vec<(SimTime, f64)>,
    /// Convergence instants (absolute sim time), if reached.
    pub kalman_t_init: Option<SimTime>,
    pub adhoc_t_init: Option<SimTime>,
    pub arma_t_init: Option<SimTime>,
    pub ewma_t_init: Option<SimTime>,
    pub reactive_t_init: Option<SimTime>,
    /// Estimate value at each estimator's own t_init.
    pub kalman_at_init: Option<f64>,
    pub adhoc_at_init: Option<f64>,
    pub arma_at_init: Option<f64>,
    pub ewma_at_init: Option<f64>,
    pub reactive_at_init: Option<f64>,
    /// Ground truth: empirical mean measured CUS over the whole workload
    /// (the paper's "final measured value" for MAE).
    pub final_measured: Option<f64>,
}

impl EstimatorTrace {
    /// Percentile MAE of one estimator at its t_init vs the final value.
    pub fn mae_pct(&self, which: crate::estimation::EstimatorKind) -> Option<f64> {
        use crate::estimation::EstimatorKind::*;
        let at_init = match which {
            Kalman => self.kalman_at_init,
            AdHoc => self.adhoc_at_init,
            Arma => self.arma_at_init,
            Ewma => self.ewma_at_init,
            Reactive => self.reactive_at_init,
        }?;
        let fin = self.final_measured?;
        if fin <= 0.0 {
            return None;
        }
        Some(100.0 * (at_init - fin).abs() / fin)
    }

    /// Time from workload arrival to the estimator's t_init.
    pub fn time_to_estimate(
        &self,
        which: crate::estimation::EstimatorKind,
        arrived_at: SimTime,
    ) -> Option<f64> {
        use crate::estimation::EstimatorKind::*;
        let t = match which {
            Kalman => self.kalman_t_init,
            AdHoc => self.adhoc_t_init,
            Arma => self.arma_t_init,
            Ewma => self.ewma_t_init,
            Reactive => self.reactive_t_init,
        }?;
        Some(t.saturating_sub(arrived_at) as f64)
    }
}

/// Per-workload outcome.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkloadOutcome {
    pub arrived_at: SimTime,
    pub completed_at: Option<SimTime>,
    pub deadline: Option<SimTime>,
    pub ttc_extended: bool,
    pub n_tasks: usize,
    pub total_bytes: u64,
    /// Tasks whose retry budget was exhausted (PR-10): terminal
    /// failures, counted as completed for conservation (the run never
    /// hangs) but the workload can no longer meet its TTC.
    pub tasks_abandoned: usize,
}

impl WorkloadOutcome {
    pub fn met_ttc(&self) -> Option<bool> {
        if self.tasks_abandoned > 0 {
            // an abandoned task is a deadline violation by definition,
            // even on best-effort workloads that finished "early"
            return Some(false);
        }
        Some(self.completed_at? <= self.deadline?)
    }
}

/// Everything recorded during one platform run.
/// `PartialEq` (manual, below) supports the determinism property
/// tests: two runs with the same seed must be *bit-identical* in
/// every simulation output — curves, traces, outcomes, costs. The
/// one exclusion is `tick_wall_ns`: it sums host wall-clock time
/// (`Instant::elapsed` in the GCI tick) and so differs between
/// equally-deterministic runs; comparing it would make every
/// determinism assertion fail on real hardware.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// (time, cumulative $) — the Fig. 8/9/10/11 curves.
    pub cost_curve: Vec<(SimTime, f64)>,
    /// (time, active instances) samples at each monitoring instant.
    pub instances_curve: Vec<(SimTime, usize)>,
    /// (time, N*_tot) demand curve.
    pub n_star_curve: Vec<(SimTime, f64)>,
    /// Max concurrent active instances (Table III row 4).
    pub max_instances: usize,
    /// Final total cost ($).
    pub total_cost: f64,
    /// Estimator traces keyed by (workload, media type).
    pub traces: std::collections::BTreeMap<(usize, usize), EstimatorTrace>,
    pub outcomes: Vec<WorkloadOutcome>,
    /// Total true CUSs processed (compute + overheads), for LB.
    pub total_busy_cus: f64,
    /// Completion time of the whole run.
    pub finished_at: SimTime,
    /// Monitoring ticks executed and total tick wall-time (perf metric).
    pub ticks: u64,
    pub tick_wall_ns: u128,
    /// How many of `ticks` were *fast-forwarded* by the sparse-tick
    /// skipper (PR-6) instead of running the full gather/step/finish
    /// round. Like `tick_wall_ns` this is a perf observable, not a
    /// simulation output — a skipped tick is bit-identical to a dense
    /// one in every compared field — so it is excluded from `PartialEq`
    /// (the `tick_skip_is_bit_identical_to_dense` pin compares a
    /// skipping run against a dense-tick run directly).
    pub ticks_skipped: u64,
    /// Instances revoked by the fault model (spot reclamation).
    pub reclamations: u64,
    /// Revocations per fleet pool (indexed like the scenario's
    /// `FleetSpec::pools`; empty before a platform run sizes it). A
    /// partial revocation shows up as a single hot entry while the
    /// other pools stay at zero.
    pub reclamations_by_pool: Vec<u64>,
    /// Spot requests left pending because the pool's market price was
    /// above its bid at request time (real-EC2 unfulfilled semantics);
    /// the scaling loop retries at later instants.
    pub unfulfilled_requests: u64,
    /// In-flight tasks re-queued through `TaskDb::requeue` after their
    /// instance was reclaimed or their chunk crashed and served its
    /// retry backoff (each later completes exactly once; the DB state
    /// machine panics on double completion).
    pub requeued_tasks: u64,
    /// Tasks that reached Completed/Failed across all workloads — must
    /// balance the suite's task count even under reclamation churn.
    pub tasks_completed: usize,
    /// Chunk re-dispatches after a transient crash (PR-10
    /// `ChunkCrash`): each crash costs the chunk's work and re-enters
    /// its tasks at the pending tail after an exponential backoff.
    pub chunk_retries: u64,
    /// Speculative twin chunks launched for timed-out stragglers
    /// (PR-10): first completion wins, the loser is torn down without
    /// double-counting.
    pub speculative_launches: u64,
    /// Instances the fault model marked as stragglers, counted at
    /// readiness (PR-10 `Straggler`).
    pub straggler_instances: u64,
    /// Tasks whose retry budget was exhausted (PR-10): terminally
    /// Failed, counted into `tasks_completed` for conservation (the
    /// run never hangs) and into their workload's deadline violation.
    pub tasks_abandoned: u64,
    /// High-water mark of simultaneously live (arrived, not yet
    /// retired) shards (PR-8). Only a shard-retiring run moves it off
    /// zero; like `ticks_skipped` it describes the *executor's* memory
    /// footprint, not the simulation, so it is excluded from
    /// `PartialEq` (the streaming==materialized twin pin compares runs
    /// whose peaks legitimately differ).
    pub peak_live_shards: usize,
    /// High-water mark of arena bytes held by live shards (PR-8).
    /// Memory observable, excluded from `PartialEq` like
    /// `peak_live_shards`.
    pub peak_arena_bytes: usize,
}

impl PartialEq for RunMetrics {
    fn eq(&self, other: &Self) -> bool {
        // every simulation output, but NOT tick_wall_ns (host wall
        // clock), ticks_skipped (executor strategy) or the peak_*
        // memory observables (executor footprint) — see the struct docs
        self.cost_curve == other.cost_curve
            && self.instances_curve == other.instances_curve
            && self.n_star_curve == other.n_star_curve
            && self.max_instances == other.max_instances
            && self.total_cost == other.total_cost
            && self.traces == other.traces
            && self.outcomes == other.outcomes
            && self.total_busy_cus == other.total_busy_cus
            && self.finished_at == other.finished_at
            && self.ticks == other.ticks
            && self.reclamations == other.reclamations
            && self.reclamations_by_pool == other.reclamations_by_pool
            && self.unfulfilled_requests == other.unfulfilled_requests
            && self.requeued_tasks == other.requeued_tasks
            && self.tasks_completed == other.tasks_completed
            && self.chunk_retries == other.chunk_retries
            && self.speculative_launches == other.speculative_launches
            && self.straggler_instances == other.straggler_instances
            && self.tasks_abandoned == other.tasks_abandoned
    }
}

impl RunMetrics {
    /// Lower-bound cost (§V-C): all busy CUSs packed at 100 % occupancy,
    /// billed in whole increments at the base spot price.
    pub fn lower_bound_cost(&self, price_per_hour: f64) -> f64 {
        (self.total_busy_cus / 3600.0) * price_per_hour
    }

    /// Fraction of workloads that met their confirmed TTC.
    pub fn ttc_compliance(&self) -> f64 {
        let evald: Vec<bool> = self.outcomes.iter().filter_map(|o| o.met_ttc()).collect();
        if evald.is_empty() {
            return 1.0;
        }
        evald.iter().filter(|&&b| b).count() as f64 / evald.len() as f64
    }

    /// Cost curve as (hours, $) f64 pairs for charting.
    pub fn cost_curve_hours(&self) -> Vec<(f64, f64)> {
        self.cost_curve
            .iter()
            .map(|&(t, c)| (t as f64 / 3600.0, c))
            .collect()
    }

    /// Mean wall time per monitoring tick, nanoseconds.
    pub fn mean_tick_ns(&self) -> f64 {
        if self.ticks == 0 {
            0.0
        } else {
            self.tick_wall_ns as f64 / self.ticks as f64
        }
    }

    /// Monitoring ticks that ran the full gather/step/finish round
    /// (as opposed to being fast-forwarded by the sparse-tick skipper).
    pub fn ticks_executed(&self) -> u64 {
        self.ticks - self.ticks_skipped
    }
}

/// One monitoring instant's observable state, snapshotted after the
/// tick phases complete — the payload of the `dithen serve` SSE `tick`
/// event (PR-7) and the per-tick view a resident client can follow
/// without polling `/metrics`. Counters are cumulative (they mirror the
/// matching [`RunMetrics`] fields mid-run); the fleet figures are the
/// instant's [`crate::cloud::FleetView`] description.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TickSummary {
    /// Sim time of the monitoring instant (seconds).
    pub t: SimTime,
    /// Ticks accounted so far (dense + skipped), = `RunMetrics::ticks`.
    pub ticks: u64,
    /// Workloads that have reached the front end.
    pub arrived: usize,
    /// Workloads that have completed (all tasks + merge done).
    pub done: usize,
    pub tasks_completed: u64,
    pub requeued_tasks: u64,
    pub reclamations: u64,
    /// Active CUs (running + draining) at the instant.
    pub active_cus: f64,
    /// Committed CUs (active + booting) — what scaling decisions see.
    pub committed_cus: f64,
    /// Cumulative billed cost in USD.
    pub total_cost: f64,
}

impl TickSummary {
    /// Compact single-line JSON rendering (the SSE `data:` payload).
    /// All fields are numeric, so no string escaping is involved.
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"t\":{},\"ticks\":{},\"arrived\":{},\"done\":{},",
                "\"tasks_completed\":{},\"requeued_tasks\":{},\"reclamations\":{},",
                "\"active_cus\":{},\"committed_cus\":{},\"total_cost\":{}}}"
            ),
            self.t,
            self.ticks,
            self.arrived,
            self.done,
            self.tasks_completed,
            self.requeued_tasks,
            self.reclamations,
            self.active_cus,
            self.committed_cus,
            self.total_cost,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimation::EstimatorKind;

    #[test]
    fn mae_pct_computation() {
        let tr = EstimatorTrace {
            kalman_at_init: Some(11.0),
            final_measured: Some(10.0),
            ..Default::default()
        };
        assert!((tr.mae_pct(EstimatorKind::Kalman).unwrap() - 10.0).abs() < 1e-9);
        assert_eq!(tr.mae_pct(EstimatorKind::Arma), None);
    }

    #[test]
    fn time_to_estimate_relative_to_arrival() {
        let tr = EstimatorTrace { adhoc_t_init: Some(900), ..Default::default() };
        assert_eq!(tr.time_to_estimate(EstimatorKind::AdHoc, 300), Some(600.0));
        assert_eq!(tr.time_to_estimate(EstimatorKind::Kalman, 300), None);
    }

    #[test]
    fn ttc_compliance_counts() {
        let mut m = RunMetrics::default();
        m.outcomes = vec![
            WorkloadOutcome { completed_at: Some(50), deadline: Some(100), ..Default::default() },
            WorkloadOutcome { completed_at: Some(150), deadline: Some(100), ..Default::default() },
            WorkloadOutcome { completed_at: None, deadline: Some(100), ..Default::default() },
        ];
        assert!((m.ttc_compliance() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lower_bound_scales_with_cus() {
        let m = RunMetrics { total_busy_cus: 7200.0, ..Default::default() };
        assert!((m.lower_bound_cost(0.0081) - 2.0 * 0.0081).abs() < 1e-12);
    }

    #[test]
    fn empty_run_defaults() {
        let m = RunMetrics::default();
        assert_eq!(m.ttc_compliance(), 1.0);
        assert_eq!(m.mean_tick_ns(), 0.0);
    }

    #[test]
    fn equality_ignores_wall_clock_but_not_outputs() {
        let a = RunMetrics { total_cost: 1.5, ticks: 9, tick_wall_ns: 111, ..Default::default() };
        let mut b = a.clone();
        b.tick_wall_ns = 99_999; // host timing noise must not break determinism checks
        assert_eq!(a, b);
        b.ticks_skipped = 5; // executor strategy, not a simulation output
        assert_eq!(a, b);
        assert_eq!(b.ticks_executed(), 4);
        b.peak_live_shards = 3; // executor memory footprint (PR-8)
        b.peak_arena_bytes = 4096;
        assert_eq!(a, b);
        b.total_cost = 2.0;
        assert_ne!(a, b);
        let mut c = a.clone();
        c.ticks = 10; // tick *count* is a simulation output and must compare
        assert_ne!(a, c);
        // the PR-10 degradation receipts are simulation outputs too
        for field in 0..4 {
            let mut d = a.clone();
            match field {
                0 => d.chunk_retries = 1,
                1 => d.speculative_launches = 1,
                2 => d.straggler_instances = 1,
                _ => d.tasks_abandoned = 1,
            }
            assert_ne!(a, d, "receipt field {field} must participate in equality");
        }
    }

    #[test]
    fn abandoned_tasks_count_as_ttc_violations() {
        // an on-time workload with an abandoned task still violates
        let on_time = WorkloadOutcome {
            completed_at: Some(50),
            deadline: Some(100),
            tasks_abandoned: 1,
            ..Default::default()
        };
        assert_eq!(on_time.met_ttc(), Some(false));
        // even a best-effort (deadline-less) workload reports violation
        let best_effort =
            WorkloadOutcome { completed_at: Some(50), tasks_abandoned: 2, ..Default::default() };
        assert_eq!(best_effort.met_ttc(), Some(false));
        let clean = WorkloadOutcome {
            completed_at: Some(50),
            deadline: Some(100),
            ..Default::default()
        };
        assert_eq!(clean.met_ttc(), Some(true));
        let m = RunMetrics { outcomes: vec![on_time, clean], ..Default::default() };
        assert!((m.ttc_compliance() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn tick_summary_json_is_flat_and_numeric() {
        let s = TickSummary {
            t: 120,
            ticks: 2,
            arrived: 1,
            done: 0,
            tasks_completed: 7,
            active_cus: 4.0,
            committed_cus: 6.5,
            total_cost: 0.0486,
            ..Default::default()
        };
        let j = s.to_json();
        assert!(j.starts_with("{\"t\":120,"), "{j}");
        assert!(j.contains("\"tasks_completed\":7"), "{j}");
        assert!(j.contains("\"committed_cus\":6.5"), "{j}");
        assert!(j.ends_with('}'), "{j}");
    }
}
