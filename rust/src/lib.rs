//! # Dithen — Computation-as-a-Service control plane (IEEE TCC 2016)
//!
//! Full reproduction of *"Dithen: A Computation-as-a-Service Cloud
//! Platform For Large-Scale Multimedia Processing"* (Doyle, Giotsas,
//! Anam, Andreopoulos) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: GCI monitoring loop, Kalman /
//!   ad-hoc / ARMA CUS estimation, proportional-fair service rates, AIMD
//!   instance scaling and its baselines (Reactive, MWA, LR, Amazon AS,
//!   lower bound), plus simulated substrates for everything the paper ran
//!   on live AWS (spot market, instances + hourly billing, S3, task DB,
//!   multimedia applications, Lambda pricing).
//! * **L2/L1 (python/, build-time only)** — the per-monitoring-instant
//!   estimator-bank graph (Pallas Kalman + row-reduction kernels) lowered
//!   once to HLO text; executed here via the PJRT CPU client
//!   ([`runtime`]). Python is never on the request path.
//!
//! See DESIGN.md for the architecture and the per-experiment index, and
//! EXPERIMENTS.md for reproduced paper tables/figures.

pub mod cli;
pub mod cloud;
pub mod config;
pub mod coordinator;
pub mod db;
pub mod estimation;
pub mod experiments;
pub mod lci;
pub mod metrics;
pub mod platform;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod storage;
pub mod util;
pub mod workload;
