//! Leader entrypoint: `dithen <command>`. See `dithen --help`.
fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dithen::cli::main_with(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
