//! Discrete-event simulation engine.
//!
//! The platform's substrates (spot market, instances, task execution,
//! transfers) advance on a shared simulated clock with second resolution.
//! The engine is a plain binary-heap event queue; determinism comes from
//! (time, sequence-number) ordering, so two events at the same instant
//! fire in scheduling order.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in seconds since experiment start.
pub type SimTime = u64;

/// An event tag dispatched by the platform loop. Carrying plain data (not
/// closures) keeps the queue `Send`, cloneable and debuggable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// Periodic GCI monitoring instant.
    MonitorTick,
    /// A workload arrives at the front end.
    WorkloadArrival { workload: usize },
    /// A chunk of tasks finishes on an instance.
    ChunkDone { instance: u64, chunk: u64 },
    /// A spot instance finished booting and is ready for work.
    InstanceReady { instance: u64 },
    /// Footprinting stage of a workload completed.
    FootprintDone { workload: usize },
    /// A Split–Merge workload's merge step completed. `epoch` guards
    /// against stale completions: a spot reclamation can revoke the
    /// instance running the merge, and the engine has no event
    /// cancellation, so the re-dispatched merge bumps the workload's
    /// merge epoch and the platform ignores events from older epochs.
    MergeDone { workload: usize, epoch: u32 },
    /// A crashed chunk's tasks re-enter the pending tail after their
    /// exponential backoff elapses (PR-10 recovery policy). Being a
    /// non-tick event it bounds the sparse-tick skip horizon, so a
    /// skipped stretch can never jump over a scheduled retry.
    RetryTasks { workload: usize, tasks: Vec<usize> },
}

#[derive(Debug, Clone, Eq, PartialEq)]
struct Scheduled {
    at: SimTime,
    seq: u64,
    event: Event,
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The event queue + clock.
#[derive(Debug, Default)]
pub struct Engine {
    now: SimTime,
    seq: u64,
    queue: BinaryHeap<Scheduled>,
}

impl Engine {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` `delay` seconds from now.
    pub fn schedule(&mut self, delay: SimTime, event: Event) {
        self.schedule_at(self.now + delay, event);
    }

    /// Schedule `event` at an absolute time. A time in the past
    /// saturates to `now` — the event fires at the current instant, in
    /// scheduling order. This is deliberate and identical in debug and
    /// release builds (the seed panicked in debug via a `debug_assert`
    /// but silently clamped in release, so debug and release runs could
    /// diverge on the same input; clamping is the documented contract
    /// because substrate callers legitimately compute ready-times that
    /// land "now", e.g. a zero boot delay).
    pub fn schedule_at(&mut self, at: SimTime, event: Event) {
        self.seq += 1;
        self.queue.push(Scheduled { at: at.max(self.now), seq: self.seq, event });
    }

    /// Pop the next event, advancing the clock. None when drained.
    pub fn next(&mut self) -> Option<(SimTime, Event)> {
        self.queue.pop().map(|s| {
            debug_assert!(s.at >= self.now, "time went backwards");
            self.now = s.at;
            (s.at, s.event)
        })
    }

    /// Peek at the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.queue.peek().map(|s| s.at)
    }

    /// Peek at the next event's `(time, sequence)` without popping —
    /// the streaming-arrival pump (PR-8) uses the sequence half to
    /// decide whether an un-queued streamed arrival at the same instant
    /// precedes the queued event (arrivals scheduled before the run
    /// started would have carried a smaller sequence number).
    pub fn peek(&self) -> Option<(SimTime, u64)> {
        self.queue.peek().map(|s| (s.at, s.seq))
    }

    /// Sequence number of the most recently scheduled event. Monotone;
    /// captures "everything scheduled so far" as a watermark.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Earliest scheduled instant of any **non-`MonitorTick`** event —
    /// the engine half of the sparse-tick skip horizon (PR-6): a
    /// monitoring instant strictly before this time can only observe
    /// state the previous tick already saw, because every externally
    /// driven change (arrival, chunk completion, instance readiness,
    /// footprint/merge completion) enters the platform through one of
    /// these queued events. Scans the heap's backing storage without
    /// allocating; `None` when no such event is pending.
    pub fn next_non_tick_time(&self) -> Option<SimTime> {
        self.queue
            .iter()
            .filter(|s| !matches!(s.event, Event::MonitorTick))
            .map(|s| s.at)
            .min()
    }

    /// Advance the clock to `t` without dispatching anything — the
    /// fast-forward primitive for skipped monitoring instants. The
    /// caller must have proven no queued event fires before `t`
    /// (checked in debug builds).
    pub fn advance_to(&mut self, t: SimTime) {
        debug_assert!(t >= self.now, "advance_to would move time backwards");
        debug_assert!(
            self.queue.peek().map_or(true, |s| s.at >= t),
            "advance_to would skip over a pending event"
        );
        self.now = t;
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_order() {
        let mut e = Engine::new();
        e.schedule(30, Event::MonitorTick);
        e.schedule(10, Event::WorkloadArrival { workload: 0 });
        e.schedule(20, Event::InstanceReady { instance: 1 });
        let order: Vec<SimTime> = std::iter::from_fn(|| e.next().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![10, 20, 30]);
        assert_eq!(e.now(), 30);
    }

    #[test]
    fn ties_fire_in_scheduling_order() {
        let mut e = Engine::new();
        e.schedule(5, Event::WorkloadArrival { workload: 1 });
        e.schedule(5, Event::WorkloadArrival { workload: 2 });
        e.schedule(5, Event::WorkloadArrival { workload: 3 });
        let ids: Vec<usize> = std::iter::from_fn(|| {
            e.next().map(|(_, ev)| match ev {
                Event::WorkloadArrival { workload } => workload,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn clock_is_monotone_under_interleaved_scheduling() {
        let mut e = Engine::new();
        e.schedule(10, Event::MonitorTick);
        let mut last = 0;
        while let Some((t, _)) = e.next() {
            assert!(t >= last);
            last = t;
            if t < 100 {
                e.schedule(10, Event::MonitorTick);
            }
        }
        assert_eq!(last, 100);
    }

    #[test]
    fn past_schedule_saturates_to_now() {
        // regression: identical debug/release behaviour — a past time
        // clamps to `now` instead of panicking (debug) or silently
        // diverging (release)
        let mut e = Engine::new();
        e.schedule(10, Event::MonitorTick);
        assert_eq!(e.next().map(|(t, _)| t), Some(10));
        e.schedule_at(3, Event::WorkloadArrival { workload: 7 });
        let (t, ev) = e.next().unwrap();
        assert_eq!(t, 10, "past event must fire at the current instant");
        assert_eq!(ev, Event::WorkloadArrival { workload: 7 });
        assert_eq!(e.now(), 10);
    }

    #[test]
    fn next_non_tick_time_ignores_monitor_ticks() {
        let mut e = Engine::new();
        assert_eq!(e.next_non_tick_time(), None);
        e.schedule(10, Event::MonitorTick);
        assert_eq!(e.next_non_tick_time(), None, "a tick is not an external event");
        e.schedule(50, Event::WorkloadArrival { workload: 0 });
        e.schedule(30, Event::ChunkDone { instance: 1, chunk: 2 });
        e.schedule(70, Event::InstanceReady { instance: 3 });
        assert_eq!(e.next_non_tick_time(), Some(30));
        // popping the earliest non-tick event moves the horizon out
        e.next(); // tick @10
        e.next(); // chunk @30
        assert_eq!(e.next_non_tick_time(), Some(50));
        // a scheduled retry (PR-10 backoff) bounds the horizon too
        e.schedule_at(45, Event::RetryTasks { workload: 0, tasks: vec![1, 2] });
        assert_eq!(e.next_non_tick_time(), Some(45));
    }

    #[test]
    fn advance_to_moves_clock_without_dispatch() {
        let mut e = Engine::new();
        e.schedule(100, Event::WorkloadArrival { workload: 1 });
        e.advance_to(40);
        assert_eq!(e.now(), 40);
        assert_eq!(e.pending(), 1, "advance_to must not dispatch");
        // events scheduled after an advance are relative to the new now
        e.schedule(10, Event::MonitorTick);
        assert_eq!(e.next().map(|(t, _)| t), Some(50));
        assert_eq!(e.next().map(|(t, _)| t), Some(100));
    }

    #[test]
    fn peek_exposes_time_and_sequence_watermark() {
        let mut e = Engine::new();
        assert_eq!(e.peek(), None);
        assert_eq!(e.seq(), 0);
        e.schedule(20, Event::MonitorTick);
        e.schedule(10, Event::WorkloadArrival { workload: 0 });
        assert_eq!(e.seq(), 2, "seq counts every schedule call");
        let (t, seq) = e.peek().expect("two events pending");
        assert_eq!(t, 10);
        assert_eq!(seq, 2, "the earliest event was scheduled second");
        // peek is non-destructive
        assert_eq!(e.pending(), 2);
        assert_eq!(e.next().map(|(t, _)| t), Some(10));
        assert_eq!(e.peek(), Some((20, 1)));
    }

    #[test]
    fn pending_counts() {
        let mut e = Engine::new();
        assert_eq!(e.pending(), 0);
        e.schedule(1, Event::MonitorTick);
        e.schedule(2, Event::MonitorTick);
        assert_eq!(e.pending(), 2);
        e.next();
        assert_eq!(e.pending(), 1);
        assert_eq!(e.peek_time(), Some(2));
    }
}
