//! Chunk allocation — the "BitTorrent-tracker" role of the GCI
//! (§II-E-1): map idle instances to workloads according to the
//! proportional-fair service rates.
//!
//! Service rates are fractional CUs; instances are integral. We use a
//! credit (deficit round-robin) scheme: every monitoring interval each
//! workload earns `s_w` credits; claiming an instance for one interval
//! costs one credit. Workloads with the largest credit balance (and
//! pending tasks) get instances first, which realizes fractional rates
//! over time — e.g. s_w = 0.5 holds an instance every other interval —
//! and keeps long-run allocation proportional to s_w.
//!
//! State is a flat `Vec` indexed by workload id (ids are dense arrival
//! slots), so the per-tick credit pass and the per-assignment argmax
//! scan are linear array walks with zero allocation (perf pass, §Perf).

/// Per-workload scheduling state.
#[derive(Debug, Clone, Default)]
pub struct WlSched {
    /// Accumulated service credits.
    pub credit: f64,
    /// Instances currently executing this workload's chunks.
    pub allocated: usize,
    /// Whether the workload has pending tasks to hand out.
    pub has_pending: bool,
    /// Whether the slot is registered (arrival seen, not yet removed).
    pub active: bool,
}

/// The tracker: deficit-round-robin allocator over workloads.
#[derive(Debug, Default)]
pub struct Tracker {
    state: Vec<WlSched>,
    /// Per-workload cap on concurrent instances (N_{w,max}).
    cap: f64,
    /// Watermark: every slot below `lo` is inactive (retired or never
    /// registered), so the per-tick scans start here instead of at 0 —
    /// under streaming arrivals with shard retirement (PR-8) the scan
    /// cost tracks the *live window*, not the total workloads ever
    /// seen. Lazily advanced; `register` pulls it back down on reuse.
    lo: usize,
}

impl Tracker {
    pub fn new(n_w_max: f64) -> Self {
        Tracker { state: Vec::new(), cap: n_w_max, lo: 0 }
    }

    pub fn register(&mut self, workload: usize) {
        if self.state.len() <= workload {
            self.state.resize_with(workload + 1, WlSched::default);
        }
        self.lo = self.lo.min(workload);
        let st = &mut self.state[workload];
        if !st.active {
            *st = WlSched { active: true, ..WlSched::default() };
        }
    }

    pub fn remove(&mut self, workload: usize) {
        if let Some(st) = self.state.get_mut(workload) {
            *st = WlSched::default();
        }
        while self.lo < self.state.len() && !self.state[self.lo].active {
            self.lo += 1;
        }
    }

    /// Credit each registered workload with its service rate for one
    /// interval (`rates[w]` is workload w's rate; missing entries are
    /// 0). Credits are capped so a starved workload cannot build an
    /// unbounded backlog and then monopolize the fleet (cap = N_{w,max}).
    pub fn tick(&mut self, rates: &[f64]) {
        let cap = self.cap.max(1.0);
        for (w, st) in self.state.iter_mut().enumerate().skip(self.lo) {
            if !st.active {
                continue;
            }
            let s = rates.get(w).copied().unwrap_or(0.0);
            st.credit = (st.credit + s).min(cap);
        }
    }

    pub fn set_pending(&mut self, workload: usize, pending: bool) {
        if let Some(st) = self.state.get_mut(workload) {
            if st.active {
                st.has_pending = pending;
            }
        }
    }

    pub fn on_assign(&mut self, workload: usize) {
        if let Some(st) = self.state.get_mut(workload) {
            if st.active {
                st.allocated += 1;
                st.credit -= 1.0;
            }
        }
    }

    pub fn on_release(&mut self, workload: usize) {
        if let Some(st) = self.state.get_mut(workload) {
            if st.active {
                st.allocated = st.allocated.saturating_sub(1);
            }
        }
    }

    pub fn allocated(&self, workload: usize) -> usize {
        self.state.get(workload).map(|s| s.allocated).unwrap_or(0)
    }

    pub fn credit(&self, workload: usize) -> f64 {
        self.state.get(workload).map(|s| s.credit).unwrap_or(0.0)
    }

    /// Pick the workload the next idle instance should serve: the one
    /// with pending tasks, below its cap, and the highest credit; ties
    /// break toward the lowest workload id (arrival order). Returns None
    /// when no workload can use an instance (credit must be positive —
    /// a workload only runs at its earned rate). Zero allocation.
    pub fn next_assignment(&self) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (w, st) in self.state.iter().enumerate().skip(self.lo) {
            if !(st.active && st.has_pending && (st.allocated as f64) < self.cap && st.credit >= 1.0)
            {
                continue;
            }
            // strict '>' keeps the lowest id on credit ties
            if best.map_or(true, |(_, c)| st.credit > c) {
                best = Some((w, st.credit));
            }
        }
        best.map(|(w, _)| w)
    }

    /// Greedy FIFO assignment, ignoring rates (Amazon-AS mode): earliest
    /// workload with pending tasks.
    pub fn next_fifo(&self) -> Option<usize> {
        self.state[self.lo..]
            .iter()
            .position(|st| st.active && st.has_pending)
            .map(|p| self.lo + p)
    }

    pub fn workloads(&self) -> impl Iterator<Item = usize> + '_ {
        self.state
            .iter()
            .enumerate()
            .skip(self.lo)
            .filter(|(_, st)| st.active)
            .map(|(w, _)| w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn rates(pairs: &[(usize, f64)]) -> Vec<f64> {
        let n = pairs.iter().map(|&(w, _)| w + 1).max().unwrap_or(0);
        let mut v = vec![0.0; n];
        for &(w, s) in pairs {
            v[w] = s;
        }
        v
    }

    #[test]
    fn highest_credit_wins() {
        let mut t = Tracker::new(10.0);
        t.register(0);
        t.register(1);
        t.set_pending(0, true);
        t.set_pending(1, true);
        t.tick(&rates(&[(0, 2.0), (1, 5.0)]));
        assert_eq!(t.next_assignment(), Some(1));
        t.on_assign(1);
        // 1 has 4 credits left, still beats 0's 2
        assert_eq!(t.next_assignment(), Some(1));
    }

    #[test]
    fn fractional_rate_alternates() {
        // s=0.5 should get an instance every other interval
        let mut t = Tracker::new(10.0);
        t.register(0);
        t.set_pending(0, true);
        let mut grants = 0;
        for _ in 0..10 {
            t.tick(&rates(&[(0, 0.5)]));
            if t.next_assignment() == Some(0) {
                t.on_assign(0);
                t.on_release(0); // chunk finishes within the interval
                grants += 1;
            }
        }
        assert_eq!(grants, 5);
    }

    #[test]
    fn respects_per_workload_cap() {
        let mut t = Tracker::new(2.0);
        t.register(0);
        t.set_pending(0, true);
        t.tick(&rates(&[(0, 10.0)]));
        t.on_assign(0);
        t.on_assign(0);
        assert_eq!(t.allocated(0), 2);
        assert_eq!(t.next_assignment(), None);
    }

    #[test]
    fn skips_workloads_without_pending() {
        let mut t = Tracker::new(10.0);
        t.register(0);
        t.register(1);
        t.set_pending(0, false);
        t.set_pending(1, true);
        t.tick(&rates(&[(0, 9.0), (1, 1.0)]));
        assert_eq!(t.next_assignment(), Some(1));
    }

    #[test]
    fn credit_capped_at_n_w_max() {
        let mut t = Tracker::new(3.0);
        t.register(0);
        for _ in 0..100 {
            t.tick(&rates(&[(0, 5.0)]));
        }
        assert!(t.credit(0) <= 3.0 + 1e-9);
    }

    #[test]
    fn ties_break_by_arrival_order() {
        let mut t = Tracker::new(10.0);
        for w in [3, 1, 2] {
            t.register(w);
            t.set_pending(w, true);
        }
        t.tick(&rates(&[(1, 2.0), (2, 2.0), (3, 2.0)]));
        assert_eq!(t.next_assignment(), Some(1));
    }

    #[test]
    fn fifo_ignores_credit() {
        let mut t = Tracker::new(10.0);
        t.register(0);
        t.register(1);
        t.set_pending(0, true);
        t.set_pending(1, true);
        t.tick(&rates(&[(1, 99.0)]));
        assert_eq!(t.next_fifo(), Some(0));
    }

    #[test]
    fn release_decrements_and_saturates() {
        let mut t = Tracker::new(10.0);
        t.register(0);
        t.on_release(0); // no-op at zero
        assert_eq!(t.allocated(0), 0);
    }

    #[test]
    fn removed_workload_is_inert_and_reregisterable() {
        let mut t = Tracker::new(10.0);
        t.register(0);
        t.set_pending(0, true);
        t.tick(&rates(&[(0, 5.0)]));
        t.remove(0);
        assert_eq!(t.next_assignment(), None);
        assert_eq!(t.workloads().count(), 0);
        t.register(0); // slot reuse starts from a clean state
        assert_eq!(t.credit(0), 0.0);
    }

    #[test]
    fn retired_prefix_is_skipped_without_changing_results() {
        // PR-8: removing a contiguous prefix advances the scan
        // watermark; behaviour toward the surviving suffix (and toward
        // re-registration below the watermark) is unchanged
        let mut t = Tracker::new(10.0);
        for w in 0..4 {
            t.register(w);
            t.set_pending(w, true);
        }
        t.tick(&rates(&[(0, 1.0), (1, 2.0), (2, 3.0), (3, 4.0)]));
        t.remove(0);
        t.remove(1);
        assert_eq!(t.workloads().collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(t.next_assignment(), Some(3));
        assert_eq!(t.next_fifo(), Some(2));
        // a slot below the watermark can come back (mid-run reuse)
        t.register(1);
        t.set_pending(1, true);
        t.tick(&rates(&[(1, 9.0)]));
        assert_eq!(t.next_assignment(), Some(1));
        assert_eq!(t.workloads().collect::<Vec<_>>(), vec![1, 2, 3]);
        // removing everything drains the tracker
        for w in [1, 2, 3] {
            t.remove(w);
        }
        assert_eq!(t.workloads().count(), 0);
        assert_eq!(t.next_fifo(), None);
    }

    #[test]
    fn long_run_allocation_proportional_to_rates() {
        forall(
            "tracker-proportional-fairness",
            0x7C,
            30,
            |r| {
                let s0 = r.uniform(0.2, 5.0);
                let s1 = r.uniform(0.2, 5.0);
                (s0, s1)
            },
            |&(s0, s1)| {
                let mut t = Tracker::new(100.0);
                t.register(0);
                t.register(1);
                t.set_pending(0, true);
                t.set_pending(1, true);
                let (mut g0, mut g1) = (0.0f64, 0.0f64);
                let rr = rates(&[(0, s0), (1, s1)]);
                for _ in 0..400 {
                    t.tick(&rr);
                    // drain all grantable capacity this interval
                    while let Some(w) = t.next_assignment() {
                        t.on_assign(w);
                        t.on_release(w);
                        if w == 0 {
                            g0 += 1.0;
                        } else {
                            g1 += 1.0;
                        }
                    }
                }
                let want = s0 / s1;
                let got = g0 / g1.max(1.0);
                if (got / want - 1.0).abs() < 0.15 {
                    Ok(())
                } else {
                    Err(format!("grant ratio {got} vs rate ratio {want}"))
                }
            },
        );
    }
}
