//! Instance-scaling control policies: the proposed AIMD controller
//! (Fig. 4), the §V-C baselines — Reactive, MWA, LR (Gandhi / Krioukov
//! et al.) and Amazon Autoscale's CPU-utilization rule — plus the PR-9
//! bake-off additions: a PID controller and a receding-horizon MPC over
//! the demand forecast.
//!
//! A policy maps the monitoring-instant context to the desired total CU
//! count N_tot[t+1]; the platform then requests/terminates spot
//! instances to meet it.
//!
//! # Adding a policy
//!
//! Implement [`ControlPolicy`] (one required method: [`target`]), add a
//! [`PolicyKind`] variant wired through [`PolicyKind::build`], and the
//! policy runs unmodified across every (estimator × backend × fault ×
//! arrivals) scenario cell — the platform evaluates it at each
//! monitoring instant through the same seam AIMD uses (see
//! `rust/BENCHMARKS.md` "how to add a policy/estimator").
//!
//! [`target`]: ControlPolicy::target

use crate::util::stats;

/// Monitoring instants of demand forecast a [`PolicyCtx`] carries
/// (index 0 is the current N*_tot; later entries are extrapolated).
pub const FORECAST_H: usize = 8;

/// What a policy sees at a monitoring instant.
#[derive(Debug, Clone)]
pub struct PolicyCtx<'a> {
    /// Simulated time (s).
    pub now: u64,
    /// Committed CUs (running + draining + booting) — what scaling has
    /// already paid for or requested.
    pub n_tot: f64,
    /// Optimal CU demand N*_tot[t] from eq. (12) (estimation-based
    /// policies only).
    pub n_star: f64,
    /// History of N*_tot at previous monitoring instants (oldest first,
    /// including the current value as the last element).
    pub n_star_history: &'a [f64],
    /// Demand forecast over the next monitoring instants: entry 0 is
    /// the current N*_tot (bitwise — [`Reactive`] on `forecast[0]`
    /// equals `Reactive` on `n_star`), entries `1..` extrapolate the
    /// Kalman-driven N* history forward (floored at 0). Empty only in
    /// hand-built test contexts.
    pub forecast: &'a [f64],
    /// Seconds until the tightest confirmed workload deadline, minimum
    /// over live workloads; `f64::INFINITY` when none is live. Lets a
    /// policy provision more aggressively when slack is short.
    pub deadline_slack_s: f64,
    /// Mean CPU utilization across active instances, in [0, 1].
    pub mean_utilization: f64,
    /// True when any workload still has pending/processing tasks.
    pub work_pending: bool,
}

/// A CU-scaling control policy (PR-9 trait seam; the pre-trait code
/// called this `ScalingPolicy`, which remains re-exported as an alias).
pub trait ControlPolicy: std::fmt::Debug {
    fn name(&self) -> &'static str;
    /// Desired N_tot for the next interval (the platform clamps/rounds).
    fn target(&mut self, ctx: &PolicyCtx) -> f64;
    /// Whether the policy consumes CUS estimates (Amazon AS does not).
    fn uses_estimation(&self) -> bool {
        true
    }
    /// Policy evaluation period in seconds (Amazon AS: fixed 5 min).
    fn eval_interval_s(&self) -> Option<u64> {
        None
    }
    /// Whether down-scaling should *drain lazily* — keep an idle
    /// instance until its pre-billed window nears exhaustion instead of
    /// terminating eagerly (the Fig. 4 AIMD termination rule). The
    /// AIMD-family controllers (AIMD, PID, MPC) drain lazily; the §V-C
    /// baselines terminate eagerly, exactly as the paper configures
    /// them.
    fn lazy_drain(&self) -> bool {
        false
    }
}

/// The proposed AIMD controller (Fig. 4).
#[derive(Debug, Clone)]
pub struct Aimd {
    pub alpha: f64,
    pub beta: f64,
    pub n_min: f64,
    pub n_max: f64,
}

impl Aimd {
    pub fn from_config(c: &crate::config::ControlCfg) -> Self {
        Aimd { alpha: c.alpha, beta: c.beta, n_min: c.n_min, n_max: c.n_max }
    }
}

impl ControlPolicy for Aimd {
    fn name(&self) -> &'static str {
        "AIMD"
    }
    fn target(&mut self, ctx: &PolicyCtx) -> f64 {
        if ctx.n_tot <= ctx.n_star {
            (ctx.n_tot + self.alpha).min(self.n_max)
        } else {
            (self.beta * ctx.n_tot).max(self.n_min)
        }
    }
    fn lazy_drain(&self) -> bool {
        true
    }
}

/// Reactive: directly match demand, N_tot[t+1] = N*_tot[t] (§II-E-2's
/// "direct way", called Reactive in §V-C).
#[derive(Debug, Clone)]
pub struct Reactive {
    pub n_min: f64,
    pub n_max: f64,
}

impl ControlPolicy for Reactive {
    fn name(&self) -> &'static str {
        "Reactive"
    }
    fn target(&mut self, ctx: &PolicyCtx) -> f64 {
        ctx.n_star.clamp(self.n_min, self.n_max)
    }
}

/// Mean-weighted-average over the last six optimal settings (eq. 16).
#[derive(Debug, Clone)]
pub struct Mwa {
    pub window: usize,
    pub n_min: f64,
    pub n_max: f64,
}

impl ControlPolicy for Mwa {
    fn name(&self) -> &'static str {
        "MWA"
    }
    fn target(&mut self, ctx: &PolicyCtx) -> f64 {
        let h = ctx.n_star_history;
        let tail = if h.len() > self.window { &h[h.len() - self.window..] } else { h };
        stats::mean(tail).clamp(self.n_min, self.n_max)
    }
}

/// Linear-regression extrapolation from the last six optimal settings.
#[derive(Debug, Clone)]
pub struct Lr {
    pub window: usize,
    pub n_min: f64,
    pub n_max: f64,
}

impl ControlPolicy for Lr {
    fn name(&self) -> &'static str {
        "LR"
    }
    fn target(&mut self, ctx: &PolicyCtx) -> f64 {
        let h = ctx.n_star_history;
        if h.is_empty() {
            return self.n_min;
        }
        stats::lr_extrapolate(h, self.window, 1.0).clamp(self.n_min, self.n_max)
    }
}

/// Amazon Autoscale baseline: ±`step` instances on a 20 % mean-CPU rule,
/// evaluated every five minutes (§V-C's configuration).
#[derive(Debug, Clone)]
pub struct AmazonAs {
    /// Instances added/removed per evaluation (paper: 1 or 10).
    pub step: f64,
    /// Utilization threshold (paper: 0.20).
    pub threshold: f64,
    pub n_max: f64,
}

impl ControlPolicy for AmazonAs {
    fn name(&self) -> &'static str {
        "Amazon AS"
    }
    fn target(&mut self, ctx: &PolicyCtx) -> f64 {
        if ctx.mean_utilization > self.threshold {
            (ctx.n_tot + self.step).min(self.n_max)
        } else {
            (ctx.n_tot - self.step).max(1.0)
        }
    }
    fn uses_estimation(&self) -> bool {
        false
    }
    fn eval_interval_s(&self) -> Option<u64> {
        Some(300)
    }
}

/// PID controller on the demand error e = N* − N_tot, with
/// conditional-integration anti-windup: the integral accumulates only
/// while the actuator is unsaturated, or while the error would pull the
/// output back inside the [n_min, n_max] range. Without this guard a
/// long saturated stretch (demand far above `n_max`) winds the integral
/// up and the controller overshoots for many instants after demand
/// drops; with it, recovery is immediate (pinned by the
/// `pid_anti_windup_recovers_immediately` test).
#[derive(Debug, Clone)]
pub struct Pid {
    pub kp: f64,
    pub ki: f64,
    pub kd: f64,
    pub n_min: f64,
    pub n_max: f64,
    integral: f64,
    prev_err: Option<f64>,
}

impl Pid {
    pub fn new(kp: f64, ki: f64, kd: f64, n_min: f64, n_max: f64) -> Self {
        Pid { kp, ki, kd, n_min, n_max, integral: 0.0, prev_err: None }
    }

    /// Paper-scale default gains: proportional-dominant (a unit demand
    /// error moves the target by ~0.6 CU), a slow integral to remove
    /// steady-state offset, and a small derivative to damp demand
    /// spikes.
    pub fn from_config(c: &crate::config::ControlCfg) -> Self {
        Pid::new(0.6, 0.1, 0.2, c.n_min, c.n_max)
    }
}

impl ControlPolicy for Pid {
    fn name(&self) -> &'static str {
        "PID"
    }
    fn target(&mut self, ctx: &PolicyCtx) -> f64 {
        let e = ctx.n_star - ctx.n_tot;
        // derivative over monitoring instants; zero on the first
        // evaluation (no phantom kick against an assumed prior error)
        let d = match self.prev_err {
            Some(prev) => e - prev,
            None => 0.0,
        };
        self.prev_err = Some(e);
        let trial = self.integral + e;
        let raw = ctx.n_tot + self.kp * e + self.ki * trial + self.kd * d;
        let clamped = raw.clamp(self.n_min, self.n_max);
        // conditional integration: commit the accumulated error only if
        // the output is unsaturated or the error de-saturates it
        if raw == clamped || (raw > clamped && e < 0.0) || (raw < clamped && e > 0.0) {
            self.integral = trial;
        }
        clamped
    }
    fn lazy_drain(&self) -> bool {
        true
    }
}

/// Receding-horizon model-predictive control over the demand forecast:
/// pick the N_tot minimizing expected cost over the next `horizon`
/// monitoring instants,
///
/// ```text
/// J(n) = Σ_h [ cu_cost·n + penalty·max(0, forecast[h] − n) ]
/// ```
///
/// — a piecewise-linear convex objective (holding capacity costs
/// `cu_cost` per CU-instant; under-provisioning against forecast demand
/// costs `penalty` per missing CU-instant, `penalty > cu_cost`). The
/// minimum over [n_min, n_max] is attained at one of the clamped
/// forecast values or an interval endpoint, so those are the only
/// candidates evaluated. When the tightest live deadline is closer than
/// `tight_slack_s`, the under-provision penalty doubles — the
/// deadline-slack input makes MPC provision ahead of a ramp instead of
/// chasing it.
///
/// With `horizon = 1` the argmin is exactly `forecast[0]` clamped —
/// bitwise the [`Reactive`] baseline, since `forecast[0]` *is* `n_star`
/// (pinned by `mpc_horizon_one_degenerates_to_reactive`).
#[derive(Debug, Clone)]
pub struct Mpc {
    /// Forecast instants optimized over (capped at the forecast length).
    pub horizon: usize,
    /// Cost of holding one CU for one monitoring instant (relative).
    pub cu_cost: f64,
    /// Cost of one CU of unmet forecast demand for one instant; must
    /// exceed `cu_cost` or the objective degenerates to "hold nothing".
    pub penalty: f64,
    /// Deadline slack (s) below which `penalty` doubles.
    pub tight_slack_s: f64,
    pub n_min: f64,
    pub n_max: f64,
}

impl Mpc {
    pub fn from_config(c: &crate::config::ControlCfg) -> Self {
        Mpc {
            horizon: 4,
            cu_cost: 1.0,
            penalty: 3.0,
            tight_slack_s: 1800.0,
            n_min: c.n_min,
            n_max: c.n_max,
        }
    }

    fn objective(&self, window: &[f64], penalty: f64, n: f64) -> f64 {
        window.iter().map(|&d| self.cu_cost * n + penalty * (d - n).max(0.0)).sum()
    }
}

impl ControlPolicy for Mpc {
    fn name(&self) -> &'static str {
        "MPC"
    }
    fn target(&mut self, ctx: &PolicyCtx) -> f64 {
        let h = self.horizon.min(ctx.forecast.len());
        if h == 0 {
            // hand-built context without a forecast: track demand
            return ctx.n_star.clamp(self.n_min, self.n_max);
        }
        let window = &ctx.forecast[..h];
        let penalty = if ctx.deadline_slack_s < self.tight_slack_s {
            2.0 * self.penalty
        } else {
            self.penalty
        };
        let mut best = self.n_max;
        let mut best_j = f64::INFINITY;
        let candidates = [self.n_min, self.n_max]
            .into_iter()
            .chain(window.iter().map(|d| d.clamp(self.n_min, self.n_max)));
        for n in candidates {
            let j = self.objective(window, penalty, n);
            // ties break toward the smaller (cheaper) fleet
            if j < best_j || (j == best_j && n < best) {
                best_j = j;
                best = n;
            }
        }
        best
    }
    fn lazy_drain(&self) -> bool {
        true
    }
}

/// Which policy a run uses (the §V-C comparison set plus the PR-9
/// bake-off controllers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Aimd,
    Reactive,
    Mwa,
    Lr,
    AmazonAs1,
    AmazonAs10,
    Pid,
    Mpc,
}

impl PolicyKind {
    pub const COMPARISON: [PolicyKind; 4] =
        [PolicyKind::Aimd, PolicyKind::Reactive, PolicyKind::Mwa, PolicyKind::Lr];

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Aimd => "AIMD",
            PolicyKind::Reactive => "Reactive",
            PolicyKind::Mwa => "MWA",
            PolicyKind::Lr => "LR",
            PolicyKind::AmazonAs1 => "Amazon AS (+1)",
            PolicyKind::AmazonAs10 => "Amazon AS (+10)",
            PolicyKind::Pid => "PID",
            PolicyKind::Mpc => "MPC",
        }
    }

    /// Instantiate with the given control config.
    ///
    /// N_min/N_max are parameters *of the AIMD-family algorithms*
    /// (Fig. 4) — PID and MPC share them; the predictive baselines track
    /// the demand estimate directly (floored at one instance so progress
    /// is always possible, capped at N_max), exactly the §V-C
    /// configuration where Reactive peaked at 28 instances while AIMD
    /// never left [10, 13].
    pub fn build(&self, c: &crate::config::ControlCfg) -> Box<dyn ControlPolicy> {
        match self {
            PolicyKind::Aimd => Box::new(Aimd::from_config(c)),
            PolicyKind::Reactive => Box::new(Reactive { n_min: 1.0, n_max: c.n_max }),
            PolicyKind::Mwa => Box::new(Mwa { window: 6, n_min: 1.0, n_max: c.n_max }),
            PolicyKind::Lr => Box::new(Lr { window: 6, n_min: 1.0, n_max: c.n_max }),
            PolicyKind::AmazonAs1 => {
                Box::new(AmazonAs { step: 1.0, threshold: 0.20, n_max: c.n_max })
            }
            PolicyKind::AmazonAs10 => {
                Box::new(AmazonAs { step: 10.0, threshold: 0.20, n_max: c.n_max })
            }
            PolicyKind::Pid => Box::new(Pid::from_config(c)),
            PolicyKind::Mpc => Box::new(Mpc::from_config(c)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ControlCfg;

    fn ctx<'a>(n_tot: f64, n_star: f64, hist: &'a [f64], util: f64) -> PolicyCtx<'a> {
        PolicyCtx {
            now: 0,
            n_tot,
            n_star,
            n_star_history: hist,
            forecast: &[],
            deadline_slack_s: f64::INFINITY,
            mean_utilization: util,
            work_pending: true,
        }
    }

    /// Context with a demand forecast (`forecast[0]` = `n_star`, like
    /// the platform constructs) and an explicit deadline slack.
    fn fctx<'a>(n_tot: f64, forecast: &'a [f64], slack_s: f64) -> PolicyCtx<'a> {
        PolicyCtx {
            now: 0,
            n_tot,
            n_star: forecast.first().copied().unwrap_or(0.0),
            n_star_history: &[],
            forecast,
            deadline_slack_s: slack_s,
            mean_utilization: 0.9,
            work_pending: true,
        }
    }

    #[test]
    fn aimd_additive_increase() {
        let mut p = Aimd { alpha: 5.0, beta: 0.9, n_min: 10.0, n_max: 100.0 };
        assert_eq!(p.target(&ctx(20.0, 30.0, &[], 0.9)), 25.0);
        // cap at n_max
        assert_eq!(p.target(&ctx(98.0, 200.0, &[], 0.9)), 100.0);
    }

    #[test]
    fn aimd_multiplicative_decrease() {
        let mut p = Aimd { alpha: 5.0, beta: 0.9, n_min: 10.0, n_max: 100.0 };
        assert_eq!(p.target(&ctx(50.0, 30.0, &[], 0.9)), 45.0);
        // floor at n_min
        assert_eq!(p.target(&ctx(10.5, 0.0, &[], 0.9)), 10.0);
    }

    #[test]
    fn aimd_equality_counts_as_increase() {
        // Fig. 4: incr = TRUE when N_tot <= N*
        let mut p = Aimd { alpha: 5.0, beta: 0.9, n_min: 10.0, n_max: 100.0 };
        assert_eq!(p.target(&ctx(30.0, 30.0, &[], 0.9)), 35.0);
    }

    /// The PR-9 trait-seam pin at the unit level: `PolicyKind::Aimd`
    /// built and driven through `dyn ControlPolicy` must be *bitwise*
    /// the closed-form Fig. 4 expression on every input — the whole-run
    /// twin lives in `tests/determinism.rs`.
    #[test]
    fn aimd_trait_dispatch_is_bitwise_the_closed_form() {
        let c = ControlCfg::default();
        let mut boxed = PolicyKind::Aimd.build(&c);
        assert!(boxed.lazy_drain(), "AIMD drains lazily through the trait");
        let mut n_tot = c.n_min;
        for (i, n_star) in
            [0.0, 3.7, 12.2, 40.0, 1e6, 0.1, 25.0, 24.999, 7.3].into_iter().enumerate()
        {
            let hist = [n_star];
            let got = boxed.target(&ctx(n_tot, n_star, &hist, 0.5 + 0.01 * i as f64));
            let want = if n_tot <= n_star {
                (n_tot + c.alpha).min(c.n_max)
            } else {
                (c.beta * n_tot).max(c.n_min)
            };
            assert_eq!(got.to_bits(), want.to_bits(), "step {i}");
            n_tot = got.round().max(0.0);
        }
    }

    #[test]
    fn reactive_matches_demand_with_clamps() {
        let mut p = Reactive { n_min: 10.0, n_max: 100.0 };
        assert_eq!(p.target(&ctx(5.0, 42.3, &[], 0.9)), 42.3);
        assert_eq!(p.target(&ctx(5.0, 3.0, &[], 0.9)), 10.0);
        assert_eq!(p.target(&ctx(5.0, 500.0, &[], 0.9)), 100.0);
    }

    #[test]
    fn mwa_averages_window() {
        let mut p = Mwa { window: 6, n_min: 0.0, n_max: 100.0 };
        let h = [10.0, 20.0, 30.0, 40.0, 50.0, 60.0, 70.0];
        // last six: 20..70 -> mean 45
        assert_eq!(p.target(&ctx(0.0, 70.0, &h, 0.9)), 45.0);
        // short history uses what exists
        assert_eq!(p.target(&ctx(0.0, 0.0, &[12.0], 0.9)), 12.0);
    }

    #[test]
    fn lr_extrapolates_trend() {
        let mut p = Lr { window: 6, n_min: 0.0, n_max: 100.0 };
        let h = [10.0, 20.0, 30.0, 40.0, 50.0, 60.0];
        let t = p.target(&ctx(0.0, 60.0, &h, 0.9));
        assert!((t - 70.0).abs() < 1e-9);
        // empty history falls back to n_min
        assert_eq!(p.target(&ctx(0.0, 0.0, &[], 0.9)), 0.0);
    }

    #[test]
    fn amazon_as_follows_utilization() {
        let mut p = AmazonAs { step: 10.0, threshold: 0.20, n_max: 100.0 };
        assert_eq!(p.target(&ctx(20.0, 0.0, &[], 0.5)), 30.0);
        assert_eq!(p.target(&ctx(20.0, 0.0, &[], 0.1)), 10.0);
        // never below 1
        assert_eq!(p.target(&ctx(3.0, 0.0, &[], 0.0)), 1.0);
        assert!(!p.uses_estimation());
        assert_eq!(p.eval_interval_s(), Some(300));
    }

    #[test]
    fn pid_closes_steady_state_error() {
        let mut p = Pid::new(0.6, 0.1, 0.2, 1.0, 100.0);
        // persistent demand above the fleet: the target must climb past
        // what proportional action alone gives (integral at work)
        let mut n_tot = 10.0;
        let mut last = 0.0;
        for _ in 0..20 {
            last = p.target(&ctx(n_tot, 30.0, &[], 0.9));
            n_tot = last;
        }
        assert!((last - 30.0).abs() < 1.0, "converged near demand, got {last}");
    }

    /// Anti-windup: 100 saturated instants (demand ≫ n_max) must not
    /// wind the integral up. The very next evaluation after demand
    /// collapses has error −40 and an unwound integral, so the raw
    /// output dives below n_min and the target recovers *immediately* —
    /// a wound-up integral (~100 × 450 × ki = 4500) would pin the
    /// output at n_max for dozens of instants instead.
    #[test]
    fn pid_anti_windup_recovers_immediately() {
        let mut p = Pid::new(0.6, 0.1, 0.2, 1.0, 50.0);
        for _ in 0..100 {
            assert_eq!(p.target(&ctx(50.0, 500.0, &[], 0.9)), 50.0);
        }
        // demand collapses: the first post-saturation target is already
        // at the floor, not creeping down from a saturated integral
        assert_eq!(p.target(&ctx(50.0, 10.0, &[], 0.9)), 1.0);
    }

    #[test]
    fn pid_first_evaluation_has_no_derivative_kick() {
        let mut a = Pid::new(0.6, 0.0, 5.0, 1.0, 100.0);
        let mut b = Pid::new(0.6, 0.0, 0.0, 1.0, 100.0);
        // huge kd, zero prior error: first outputs must match (d = 0)
        assert_eq!(
            a.target(&ctx(10.0, 20.0, &[], 0.9)),
            b.target(&ctx(10.0, 20.0, &[], 0.9))
        );
    }

    /// The MPC degeneracy pin: with a one-instant horizon the convex
    /// objective's argmin is exactly `forecast[0].clamp(n_min, n_max)` —
    /// bitwise the Reactive baseline (same f64 clamp on the same value).
    #[test]
    fn mpc_horizon_one_degenerates_to_reactive() {
        let mut mpc = Mpc {
            horizon: 1,
            cu_cost: 1.0,
            penalty: 3.0,
            tight_slack_s: 1800.0,
            n_min: 10.0,
            n_max: 100.0,
        };
        let mut reactive = Reactive { n_min: 10.0, n_max: 100.0 };
        for n_star in [0.0, 3.0, 10.0, 42.3, 99.999, 100.0, 500.0, 17.000000000000004] {
            let f = [n_star];
            let got = mpc.target(&fctx(5.0, &f, f64::INFINITY));
            let want = reactive.target(&ctx(5.0, n_star, &[], 0.9));
            assert_eq!(got.to_bits(), want.to_bits(), "n_star {n_star}");
        }
    }

    #[test]
    fn mpc_provisions_ahead_of_a_ramp() {
        let mut p = Mpc {
            horizon: 4,
            cu_cost: 1.0,
            penalty: 3.0,
            tight_slack_s: 1800.0,
            n_min: 1.0,
            n_max: 100.0,
        };
        // rising forecast: J(10)=220, J(20)=170, J(30)=150, J(40)=160
        let f = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(p.target(&fctx(10.0, &f, f64::INFINITY)), 30.0);
        // reactive would sit at forecast[0] = 10 and chase the ramp
    }

    #[test]
    fn mpc_tight_deadline_doubles_the_penalty() {
        let mut p = Mpc {
            horizon: 4,
            cu_cost: 1.0,
            penalty: 3.0,
            tight_slack_s: 1800.0,
            n_min: 1.0,
            n_max: 100.0,
        };
        let f = [10.0, 20.0, 30.0, 40.0];
        // ample slack: argmin 30 (see above). Tight slack doubles the
        // under-provision penalty: J6(30)=180 > J6(40)=160 -> 40.
        assert_eq!(p.target(&fctx(10.0, &f, 600.0)), 40.0);
    }

    #[test]
    fn lazy_drain_is_an_aimd_family_property() {
        let c = ControlCfg::default();
        for (k, lazy) in [
            (PolicyKind::Aimd, true),
            (PolicyKind::Pid, true),
            (PolicyKind::Mpc, true),
            (PolicyKind::Reactive, false),
            (PolicyKind::Mwa, false),
            (PolicyKind::Lr, false),
            (PolicyKind::AmazonAs1, false),
            (PolicyKind::AmazonAs10, false),
        ] {
            assert_eq!(k.build(&c).lazy_drain(), lazy, "{k:?}");
        }
    }

    #[test]
    fn kind_builds_all() {
        let c = ControlCfg::default();
        for k in [
            PolicyKind::Aimd,
            PolicyKind::Reactive,
            PolicyKind::Mwa,
            PolicyKind::Lr,
            PolicyKind::AmazonAs1,
            PolicyKind::AmazonAs10,
            PolicyKind::Pid,
            PolicyKind::Mpc,
        ] {
            let p = k.build(&c);
            assert!(!p.name().is_empty());
        }
    }
}
