//! Instance-scaling policies: the proposed AIMD controller (Fig. 4) and
//! the §V-C baselines — Reactive, MWA, LR (Gandhi / Krioukov et al.) and
//! Amazon Autoscale's CPU-utilization rule.
//!
//! A policy maps the monitoring-instant context to the desired total CU
//! count N_tot[t+1]; the platform then requests/terminates single-CU spot
//! instances to meet it.

use crate::util::stats;

/// What a policy sees at a monitoring instant.
#[derive(Debug, Clone)]
pub struct PolicyCtx<'a> {
    /// Simulated time (s).
    pub now: u64,
    /// Committed CUs (running + draining + booting) — what scaling has
    /// already paid for or requested.
    pub n_tot: f64,
    /// Optimal CU demand N*_tot[t] from eq. (12) (estimation-based
    /// policies only).
    pub n_star: f64,
    /// History of N*_tot at previous monitoring instants (oldest first,
    /// including the current value as the last element).
    pub n_star_history: &'a [f64],
    /// Mean CPU utilization across active instances, in [0, 1].
    pub mean_utilization: f64,
    /// True when any workload still has pending/processing tasks.
    pub work_pending: bool,
}

/// A CU-scaling policy.
pub trait ScalingPolicy: std::fmt::Debug {
    fn name(&self) -> &'static str;
    /// Desired N_tot for the next interval (the platform clamps/rounds).
    fn target(&mut self, ctx: &PolicyCtx) -> f64;
    /// Whether the policy consumes CUS estimates (Amazon AS does not).
    fn uses_estimation(&self) -> bool {
        true
    }
    /// Policy evaluation period in seconds (Amazon AS: fixed 5 min).
    fn eval_interval_s(&self) -> Option<u64> {
        None
    }
}

/// The proposed AIMD controller (Fig. 4).
#[derive(Debug, Clone)]
pub struct Aimd {
    pub alpha: f64,
    pub beta: f64,
    pub n_min: f64,
    pub n_max: f64,
}

impl Aimd {
    pub fn from_config(c: &crate::config::ControlCfg) -> Self {
        Aimd { alpha: c.alpha, beta: c.beta, n_min: c.n_min, n_max: c.n_max }
    }
}

impl ScalingPolicy for Aimd {
    fn name(&self) -> &'static str {
        "AIMD"
    }
    fn target(&mut self, ctx: &PolicyCtx) -> f64 {
        if ctx.n_tot <= ctx.n_star {
            (ctx.n_tot + self.alpha).min(self.n_max)
        } else {
            (self.beta * ctx.n_tot).max(self.n_min)
        }
    }
}

/// Reactive: directly match demand, N_tot[t+1] = N*_tot[t] (§II-E-2's
/// "direct way", called Reactive in §V-C).
#[derive(Debug, Clone)]
pub struct Reactive {
    pub n_min: f64,
    pub n_max: f64,
}

impl ScalingPolicy for Reactive {
    fn name(&self) -> &'static str {
        "Reactive"
    }
    fn target(&mut self, ctx: &PolicyCtx) -> f64 {
        ctx.n_star.clamp(self.n_min, self.n_max)
    }
}

/// Mean-weighted-average over the last six optimal settings (eq. 16).
#[derive(Debug, Clone)]
pub struct Mwa {
    pub window: usize,
    pub n_min: f64,
    pub n_max: f64,
}

impl ScalingPolicy for Mwa {
    fn name(&self) -> &'static str {
        "MWA"
    }
    fn target(&mut self, ctx: &PolicyCtx) -> f64 {
        let h = ctx.n_star_history;
        let tail = if h.len() > self.window { &h[h.len() - self.window..] } else { h };
        stats::mean(tail).clamp(self.n_min, self.n_max)
    }
}

/// Linear-regression extrapolation from the last six optimal settings.
#[derive(Debug, Clone)]
pub struct Lr {
    pub window: usize,
    pub n_min: f64,
    pub n_max: f64,
}

impl ScalingPolicy for Lr {
    fn name(&self) -> &'static str {
        "LR"
    }
    fn target(&mut self, ctx: &PolicyCtx) -> f64 {
        let h = ctx.n_star_history;
        if h.is_empty() {
            return self.n_min;
        }
        stats::lr_extrapolate(h, self.window, 1.0).clamp(self.n_min, self.n_max)
    }
}

/// Amazon Autoscale baseline: ±`step` instances on a 20 % mean-CPU rule,
/// evaluated every five minutes (§V-C's configuration).
#[derive(Debug, Clone)]
pub struct AmazonAs {
    /// Instances added/removed per evaluation (paper: 1 or 10).
    pub step: f64,
    /// Utilization threshold (paper: 0.20).
    pub threshold: f64,
    pub n_max: f64,
}

impl ScalingPolicy for AmazonAs {
    fn name(&self) -> &'static str {
        "Amazon AS"
    }
    fn target(&mut self, ctx: &PolicyCtx) -> f64 {
        if ctx.mean_utilization > self.threshold {
            (ctx.n_tot + self.step).min(self.n_max)
        } else {
            (ctx.n_tot - self.step).max(1.0)
        }
    }
    fn uses_estimation(&self) -> bool {
        false
    }
    fn eval_interval_s(&self) -> Option<u64> {
        Some(300)
    }
}

/// Which policy a run uses (the §V-C comparison set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Aimd,
    Reactive,
    Mwa,
    Lr,
    AmazonAs1,
    AmazonAs10,
}

impl PolicyKind {
    pub const COMPARISON: [PolicyKind; 4] =
        [PolicyKind::Aimd, PolicyKind::Reactive, PolicyKind::Mwa, PolicyKind::Lr];

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Aimd => "AIMD",
            PolicyKind::Reactive => "Reactive",
            PolicyKind::Mwa => "MWA",
            PolicyKind::Lr => "LR",
            PolicyKind::AmazonAs1 => "Amazon AS (+1)",
            PolicyKind::AmazonAs10 => "Amazon AS (+10)",
        }
    }

    /// Instantiate with the given control config.
    ///
    /// N_min/N_max are parameters *of the AIMD algorithm* (Fig. 4); the
    /// predictive baselines track the demand estimate directly (floored
    /// at one instance so progress is always possible, capped at N_max),
    /// exactly the §V-C configuration where Reactive peaked at 28
    /// instances while AIMD never left [10, 13].
    pub fn build(&self, c: &crate::config::ControlCfg) -> Box<dyn ScalingPolicy> {
        match self {
            PolicyKind::Aimd => Box::new(Aimd::from_config(c)),
            PolicyKind::Reactive => Box::new(Reactive { n_min: 1.0, n_max: c.n_max }),
            PolicyKind::Mwa => Box::new(Mwa { window: 6, n_min: 1.0, n_max: c.n_max }),
            PolicyKind::Lr => Box::new(Lr { window: 6, n_min: 1.0, n_max: c.n_max }),
            PolicyKind::AmazonAs1 => {
                Box::new(AmazonAs { step: 1.0, threshold: 0.20, n_max: c.n_max })
            }
            PolicyKind::AmazonAs10 => {
                Box::new(AmazonAs { step: 10.0, threshold: 0.20, n_max: c.n_max })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ControlCfg;

    fn ctx<'a>(n_tot: f64, n_star: f64, hist: &'a [f64], util: f64) -> PolicyCtx<'a> {
        PolicyCtx {
            now: 0,
            n_tot,
            n_star,
            n_star_history: hist,
            mean_utilization: util,
            work_pending: true,
        }
    }

    #[test]
    fn aimd_additive_increase() {
        let mut p = Aimd { alpha: 5.0, beta: 0.9, n_min: 10.0, n_max: 100.0 };
        assert_eq!(p.target(&ctx(20.0, 30.0, &[], 0.9)), 25.0);
        // cap at n_max
        assert_eq!(p.target(&ctx(98.0, 200.0, &[], 0.9)), 100.0);
    }

    #[test]
    fn aimd_multiplicative_decrease() {
        let mut p = Aimd { alpha: 5.0, beta: 0.9, n_min: 10.0, n_max: 100.0 };
        assert_eq!(p.target(&ctx(50.0, 30.0, &[], 0.9)), 45.0);
        // floor at n_min
        assert_eq!(p.target(&ctx(10.5, 0.0, &[], 0.9)), 10.0);
    }

    #[test]
    fn aimd_equality_counts_as_increase() {
        // Fig. 4: incr = TRUE when N_tot <= N*
        let mut p = Aimd { alpha: 5.0, beta: 0.9, n_min: 10.0, n_max: 100.0 };
        assert_eq!(p.target(&ctx(30.0, 30.0, &[], 0.9)), 35.0);
    }

    #[test]
    fn reactive_matches_demand_with_clamps() {
        let mut p = Reactive { n_min: 10.0, n_max: 100.0 };
        assert_eq!(p.target(&ctx(5.0, 42.3, &[], 0.9)), 42.3);
        assert_eq!(p.target(&ctx(5.0, 3.0, &[], 0.9)), 10.0);
        assert_eq!(p.target(&ctx(5.0, 500.0, &[], 0.9)), 100.0);
    }

    #[test]
    fn mwa_averages_window() {
        let mut p = Mwa { window: 6, n_min: 0.0, n_max: 100.0 };
        let h = [10.0, 20.0, 30.0, 40.0, 50.0, 60.0, 70.0];
        // last six: 20..70 -> mean 45
        assert_eq!(p.target(&ctx(0.0, 70.0, &h, 0.9)), 45.0);
        // short history uses what exists
        assert_eq!(p.target(&ctx(0.0, 0.0, &[12.0], 0.9)), 12.0);
    }

    #[test]
    fn lr_extrapolates_trend() {
        let mut p = Lr { window: 6, n_min: 0.0, n_max: 100.0 };
        let h = [10.0, 20.0, 30.0, 40.0, 50.0, 60.0];
        let t = p.target(&ctx(0.0, 60.0, &h, 0.9));
        assert!((t - 70.0).abs() < 1e-9);
        // empty history falls back to n_min
        assert_eq!(p.target(&ctx(0.0, 0.0, &[], 0.9)), 0.0);
    }

    #[test]
    fn amazon_as_follows_utilization() {
        let mut p = AmazonAs { step: 10.0, threshold: 0.20, n_max: 100.0 };
        assert_eq!(p.target(&ctx(20.0, 0.0, &[], 0.5)), 30.0);
        assert_eq!(p.target(&ctx(20.0, 0.0, &[], 0.1)), 10.0);
        // never below 1
        assert_eq!(p.target(&ctx(3.0, 0.0, &[], 0.0)), 1.0);
        assert!(!p.uses_estimation());
        assert_eq!(p.eval_interval_s(), Some(300));
    }

    #[test]
    fn kind_builds_all() {
        let c = ControlCfg::default();
        for k in [
            PolicyKind::Aimd,
            PolicyKind::Reactive,
            PolicyKind::Mwa,
            PolicyKind::Lr,
            PolicyKind::AmazonAs1,
            PolicyKind::AmazonAs10,
        ] {
            let p = k.build(&c);
            assert!(!p.name().is_empty());
        }
    }
}
