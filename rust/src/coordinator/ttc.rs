//! TTC confirmation (§II-E-4).
//!
//! Once a reliable CUS estimate exists (t_init), the GCI confirms the
//! requested time-to-completion: if meeting it would need a service rate
//! above the per-workload cap N_{w,max}, the TTC is extended so that the
//! rate equals the cap.

use crate::sim::SimTime;

/// Result of confirming a workload's TTC.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Confirmation {
    /// Confirmed absolute deadline.
    pub deadline: SimTime,
    /// Initial service rate s_w[t_init] implied by the confirmation.
    pub rate: f64,
    /// True if the requested TTC had to be extended.
    pub extended: bool,
}

/// Confirm a TTC given the required CUSs `r` (eq. 1), the requested
/// absolute `deadline`, the current time, and the rate cap.
pub fn confirm(r: f64, deadline: SimTime, now: SimTime, n_w_max: f64) -> Confirmation {
    let remaining = deadline.saturating_sub(now).max(1) as f64;
    let rate = r / remaining; // eq. (11)
    if rate <= n_w_max {
        Confirmation { deadline, rate, extended: false }
    } else {
        // extend d so that r / d = n_w_max
        let d = (r / n_w_max).ceil() as SimTime;
        Confirmation { deadline: now + d, rate: n_w_max, extended: true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn achievable_ttc_confirmed_unchanged() {
        // 3600 CUS over 3600 s -> rate 1.0, under the cap of 10
        let c = confirm(3600.0, 4600, 1000, 10.0);
        assert_eq!(c.deadline, 4600);
        assert!((c.rate - 1.0).abs() < 1e-12);
        assert!(!c.extended);
    }

    #[test]
    fn infeasible_ttc_extended_to_cap() {
        // 72000 CUS over 3600 s would need rate 20 > cap 10
        let c = confirm(72_000.0, 4600, 1000, 10.0);
        assert!(c.extended);
        assert!((c.rate - 10.0).abs() < 1e-12);
        assert_eq!(c.deadline, 1000 + 7200);
    }

    #[test]
    fn exactly_at_cap_not_extended() {
        let c = confirm(36_000.0, 4600, 1000, 10.0);
        assert!(!c.extended);
        assert!((c.rate - 10.0).abs() < 1e-12);
    }

    #[test]
    fn past_deadline_degenerates_gracefully() {
        // deadline already passed: remaining clamps to 1 s
        let c = confirm(100.0, 500, 1000, 10.0);
        assert!(c.extended);
        assert_eq!(c.deadline, 1000 + 10);
    }
}
