//! The Dithen coordinator (§II-E, §III, §IV): scaling policies, the
//! tracker-style chunk allocator, footprinting/chunk sizing, TTC
//! confirmation and the proportional-fair service-rate math.
//!
//! The integrated GCI monitoring loop that wires these to the substrates
//! lives in [`crate::platform`].

pub mod chunking;
pub mod policy;
pub mod service_rate;
pub mod tracker;
pub mod ttc;

pub use chunking::{chunk_size, footprint_count};
pub use policy::{
    Aimd, AmazonAs, ControlPolicy, Lr, Mpc, Mwa, Pid, PolicyCtx, PolicyKind, Reactive, FORECAST_H,
};
/// Pre-PR-9 name for [`ControlPolicy`], kept as an alias so existing
/// imports keep compiling.
pub use policy::ControlPolicy as ScalingPolicy;
pub use service_rate::{service_rates, service_rates_into};
pub use tracker::Tracker;
pub use ttc::{confirm, Confirmation};
