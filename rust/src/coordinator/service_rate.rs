//! Proportional-fair service rates (eqs. 10–14) — f64 native version.
//!
//! The XLA estimator bank computes the same quantities fused into the
//! monitor_step artifact (Kalman-driven runs); this standalone version
//! serves the ad-hoc/ARMA-driven comparison runs and the unit/property
//! tests. Maximizing f(s_w) = r_w ln(s_w) − d_w s_w gives s*_w = r_w/d_w
//! (eq. 11); the total is then reconciled with the available CUs through
//! the AIMD-aware adjustments of eqs. (13)/(14).

/// Compute adjusted service rates. `r[w]` required CUSs, `d[w]` remaining
/// TTC seconds, `active[w]` whether the workload exists. Returns
/// (rates, n_star).
pub fn service_rates(
    r: &[f64],
    d: &[f64],
    active: &[bool],
    n_tot: f64,
    alpha: f64,
    beta: f64,
    n_w_max: f64,
) -> (Vec<f64>, f64) {
    let mut out = vec![0.0; r.len()];
    let n_star = service_rates_into(r, d, active, n_tot, alpha, beta, n_w_max, &mut out);
    (out, n_star)
}

/// Allocation-free variant of [`service_rates`]: writes the adjusted
/// rates into `out` (same length as `r`) and returns n_star. Used by
/// the GCI tick, which reuses its scratch buffers across ticks.
#[allow(clippy::too_many_arguments)]
pub fn service_rates_into(
    r: &[f64],
    d: &[f64],
    active: &[bool],
    n_tot: f64,
    alpha: f64,
    beta: f64,
    n_w_max: f64,
    out: &mut [f64],
) -> f64 {
    assert_eq!(r.len(), d.len());
    assert_eq!(r.len(), active.len());
    assert_eq!(r.len(), out.len());
    let mut n_star = 0.0;
    for w in 0..r.len() {
        out[w] = if active[w] {
            let safe_d = if d[w] > 0.0 { d[w] } else { 1.0 };
            let s = (r[w] / safe_d).min(n_w_max); // eq. (11) + N_{w,max} cap
            n_star += s;
            s
        } else {
            0.0
        };
    }
    let hi = n_tot + alpha;
    let lo = beta * n_tot;
    let scale = if n_star > hi {
        hi / n_star // eq. (13)
    } else if n_star > 0.0 && n_star < lo {
        lo / n_star // eq. (14)
    } else {
        1.0
    };
    for s in out.iter_mut() {
        *s *= scale;
    }
    n_star
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    #[test]
    fn optimal_rate_is_r_over_d() {
        let (s, n) = service_rates(&[100.0, 200.0], &[50.0, 50.0], &[true, true], 6.0, 5.0, 0.9, 1e9);
        // n* = 2 + 4 = 6, within [beta*6, 6+5] -> no adjustment
        assert_eq!(s, vec![2.0, 4.0]);
        assert_eq!(n, 6.0);
    }

    #[test]
    fn downscale_when_over_capacity() {
        // n* = 20, n_tot = 5, hi = 10 -> scale 0.5 (eq. 13)
        let (s, n) = service_rates(&[1000.0], &[50.0], &[true], 5.0, 5.0, 0.9, 1e9);
        assert_eq!(n, 20.0);
        assert_eq!(s, vec![10.0]);
    }

    #[test]
    fn upscale_when_under_capacity() {
        // n* = 1, n_tot = 10, lo = 9 -> scale 9 (eq. 14)
        let (s, n) = service_rates(&[50.0], &[50.0], &[true], 10.0, 5.0, 0.9, 1e9);
        assert_eq!(n, 1.0);
        assert!((s[0] - 9.0).abs() < 1e-12);
    }

    #[test]
    fn inactive_workloads_get_zero() {
        let (s, n) = service_rates(&[100.0, 100.0], &[10.0, 10.0], &[true, false], 20.0, 5.0, 0.9, 1e9);
        assert_eq!(s[1], 0.0);
        assert_eq!(n, 10.0);
    }

    #[test]
    fn zero_demand_no_scaling() {
        let (s, n) = service_rates(&[0.0], &[10.0], &[true], 10.0, 5.0, 0.9, 1e9);
        assert_eq!(s, vec![0.0]);
        assert_eq!(n, 0.0);
    }

    #[test]
    fn expired_deadline_clamps_to_one_second() {
        let (s, _) = service_rates(&[100.0], &[0.0], &[true], 1000.0, 5.0, 0.9, 1e9);
        // d=0 -> treated as 1 s -> s* = 100, within [900, 1005] -> upscaled
        assert!(s[0] >= 100.0);
    }

    #[test]
    fn adjusted_total_respects_aimd_bounds() {
        forall(
            "service-rates-bounded",
            0x5E,
            300,
            |rng: &mut Rng| {
                let n = rng.int(1, 40) as usize;
                let r: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 50_000.0)).collect();
                let d: Vec<f64> = (0..n).map(|_| rng.uniform(1.0, 10_000.0)).collect();
                let active: Vec<bool> = (0..n).map(|_| rng.f64() < 0.8).collect();
                let n_tot = rng.uniform(1.0, 100.0);
                (r, d, active, n_tot)
            },
            |(r, d, active, n_tot)| {
                let (s, n_star) = service_rates(r, d, active, *n_tot, 5.0, 0.9, 1e9);
                let total: f64 = s.iter().sum();
                if s.iter().any(|x| *x < 0.0) {
                    return Err("negative rate".into());
                }
                // after adjustment the total must never exceed n_tot+alpha
                // (when there was demand) and must reach beta*n_tot when
                // demand existed below it
                if n_star > 0.0 && total > n_tot + 5.0 + 1e-6 {
                    return Err(format!("total {total} > hi {}", n_tot + 5.0));
                }
                if n_star > 0.0 && n_star < 0.9 * n_tot && (total - 0.9 * n_tot).abs() > 1e-6 {
                    return Err(format!("upscale total {total} != lo {}", 0.9 * n_tot));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn rates_preserve_proportionality() {
        // adjustment is a common scale: ratios s_i/s_j stay r_i d_j / (r_j d_i)
        let (s, _) = service_rates(&[100.0, 300.0], &[10.0, 10.0], &[true, true], 2.0, 5.0, 0.9, 1e9);
        assert!((s[1] / s[0] - 3.0).abs() < 1e-9);
    }
}
