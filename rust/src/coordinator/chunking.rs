//! Footprinting and chunk sizing (§II-E-1).
//!
//! The footprinting stage executes a small fraction of a new workload's
//! tasks to (i) verify the user code runs, (ii) seed the CUS estimator,
//! and (iii) pick a chunk size such that one chunk's processing time is
//! comparable to the monitoring interval — long "deadband" (environment
//! setup) times mandate grouping many tasks per chunk so the setup cost
//! amortizes.

/// Number of footprinting tasks for a workload of `n_tasks` items:
/// `frac` of the tasks, clamped to [min, max] and to the workload size.
pub fn footprint_count(n_tasks: usize, frac: f64, min: usize, max: usize) -> usize {
    let f = ((n_tasks as f64 * frac).round() as usize).clamp(min, max);
    f.min(n_tasks)
}

/// Deadband-amortization factor: a chunk must be long enough that the
/// per-chunk setup cost is a small fraction of it (§II-E-1: "long
/// deadband times in tasks mandate the grouping of several tasks into
/// large chunks"). The effective chunk-duration target is
/// `max(monitor_interval, AMORTIZE × deadband)`.
pub const AMORTIZE: f64 = 8.0;

/// Chunk size from the current per-item time estimate.
///
/// Solves `deadband + n * per_item_s ≈ target` for n, where the target
/// duration is the monitoring interval stretched (if needed) to amortize
/// the deadband; clamped to [1, remaining]. `per_item_s` must include
/// transfer time.
pub fn chunk_size(per_item_s: f64, deadband_s: f64, target_s: f64, remaining: usize) -> usize {
    if remaining == 0 {
        return 0;
    }
    let target = target_s.max(AMORTIZE * deadband_s);
    let budget = (target - deadband_s).max(per_item_s.max(1e-6));
    let n = (budget / per_item_s.max(1e-6)).floor() as usize;
    n.clamp(1, remaining)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn footprint_five_percent_clamped() {
        // paper's example: ~5% of submitted inputs
        assert_eq!(footprint_count(1000, 0.05, 1, 10), 10); // 50 -> cap 10
        assert_eq!(footprint_count(100, 0.05, 1, 10), 5);
        assert_eq!(footprint_count(10, 0.05, 1, 10), 1); // 0.5 -> min 1
        assert_eq!(footprint_count(1, 0.05, 1, 10), 1);
        assert_eq!(footprint_count(0, 0.05, 1, 10), 0);
    }

    #[test]
    fn chunk_fills_monitoring_interval() {
        // 2 s items, 0.5 s deadband, 60 s interval -> ~29 items
        assert_eq!(chunk_size(2.0, 0.5, 60.0, 1000), 29);
    }

    #[test]
    fn long_deadband_forces_large_chunks() {
        // SIFT: 30 s setup stretches the target to 8x30 = 240 s even
        // under 60 s monitoring -> (240-30)/6 = 35 items; a 300 s
        // interval gives (300-30)/6 = 45
        assert_eq!(chunk_size(6.0, 30.0, 60.0, 1000), 35);
        assert_eq!(chunk_size(6.0, 30.0, 300.0, 1000), 45);
    }

    #[test]
    fn heavy_items_chunk_singly() {
        // 60 s transcodes under a 60 s interval -> one per chunk
        assert_eq!(chunk_size(60.0, 1.0, 60.0, 500), 1);
    }

    #[test]
    fn chunk_clamped_to_remaining() {
        assert_eq!(chunk_size(0.1, 0.0, 60.0, 3), 3);
        assert_eq!(chunk_size(1.0, 0.0, 60.0, 0), 0);
    }

    #[test]
    fn chunk_always_at_least_one_when_work_remains() {
        forall(
            "chunk-size-bounds",
            0xC4,
            300,
            |r| {
                (
                    r.uniform(1e-3, 300.0),       // per_item
                    r.uniform(0.0, 120.0),        // deadband
                    r.uniform(1.0, 600.0),        // target
                    r.int(1, 10_000) as usize,    // remaining
                )
            },
            |&(per, dead, target, rem)| {
                let n = chunk_size(per, dead, target, rem);
                if (1..=rem).contains(&n) {
                    Ok(())
                } else {
                    Err(format!("chunk {n} outside [1, {rem}]"))
                }
            },
        );
    }
}
