//! Minimal threaded HTTP/1.1 transport for `dithen serve` (PR-7).
//!
//! The build is offline-hermetic — vendored crates only, no
//! tokio/axum/hyper — so the daemon's wire layer is hand-rolled on
//! `std::net`. This module is transport only: a bounded request parser
//! and a plain responder. It knows nothing about routes or the
//! platform; `serve::api` maps parsed requests to daemon commands.
//!
//! Contract (the robustness satellite): parsing NEVER panics on
//! malformed input. Every deviation — bad method token, oversized
//! request line / header, truncated body, junk where a header should
//! be — surfaces as an [`HttpError`] with a 4xx/5xx status, and the
//! connection is closed after the response (`Connection: close` on
//! every reply; one request per connection, so pipelined garbage after
//! a valid request is simply never read).
//!
//! Bounds: request line ≤ [`MAX_REQUEST_LINE`], each header line ≤
//! [`MAX_HEADER_LINE`], at most [`MAX_HEADERS`] headers, body ≤
//! [`MAX_BODY`] with a declared `Content-Length` (chunked bodies are
//! rejected as 501 — no endpoint needs them).

use std::io::{BufRead, Read, Write};

/// Longest accepted request line (method + target + version), bytes.
pub const MAX_REQUEST_LINE: usize = 8 * 1024;
/// Longest accepted single header line, bytes.
pub const MAX_HEADER_LINE: usize = 8 * 1024;
/// Most headers accepted on one request.
pub const MAX_HEADERS: usize = 64;
/// Largest accepted request body, bytes.
pub const MAX_BODY: usize = 1 << 20;

/// One parsed request. Header names are lowercased at parse time;
/// values keep their case with surrounding whitespace trimmed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    /// Path component of the target, without the query string.
    pub path: String,
    /// Raw query string ("" when absent).
    pub query: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup (names are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// A protocol violation: the status to answer with and a short reason
/// for the response body / log line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub reason: &'static str,
}

impl HttpError {
    pub fn new(status: u16, reason: &'static str) -> Self {
        HttpError { status, reason }
    }
}

/// Read one bounded line (LF-terminated, optional CR stripped).
/// `Ok(None)` = clean EOF before any byte; an unterminated line at the
/// cap reports `over_status` (414 for the request line, 431 for
/// headers), an EOF mid-line reports 400.
fn read_line<R: BufRead>(
    r: &mut R,
    max: usize,
    over_status: u16,
) -> Result<Option<String>, HttpError> {
    let mut buf = Vec::with_capacity(128);
    let n = r
        .by_ref()
        .take(max as u64 + 1)
        .read_until(b'\n', &mut buf)
        .map_err(|_| HttpError::new(400, "read error"))?;
    if n == 0 {
        return Ok(None);
    }
    if buf.last() != Some(&b'\n') {
        return Err(if n > max {
            HttpError::new(over_status, "line too long")
        } else {
            HttpError::new(400, "truncated request")
        });
    }
    buf.pop();
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf).map(Some).map_err(|_| HttpError::new(400, "non-utf8 request"))
}

/// Parse one request off the wire. `Ok(None)` means the peer closed
/// the connection cleanly before sending anything — not an error.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Option<Request>, HttpError> {
    // request line; tolerate a stray leading CRLF (RFC 7230 §3.5)
    let mut line = match read_line(r, MAX_REQUEST_LINE, 414)? {
        None => return Ok(None),
        Some(l) => l,
    };
    if line.is_empty() {
        line = match read_line(r, MAX_REQUEST_LINE, 414)? {
            None => return Ok(None),
            Some(l) => l,
        };
    }
    let mut it = line.split(' ');
    let method = it.next().unwrap_or("");
    let target = it.next().ok_or_else(|| HttpError::new(400, "malformed request line"))?;
    let version = it.next().ok_or_else(|| HttpError::new(400, "malformed request line"))?;
    if it.next().is_some() {
        return Err(HttpError::new(400, "malformed request line"));
    }
    if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::new(400, "bad method"));
    }
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new(505, "http version not supported"));
    }
    if !target.starts_with('/') {
        return Err(HttpError::new(400, "bad request target"));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    // headers
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let hline = match read_line(r, MAX_HEADER_LINE, 431)? {
            None => return Err(HttpError::new(400, "truncated request")),
            Some(l) => l,
        };
        if hline.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(HttpError::new(431, "too many headers"));
        }
        let (name, value) =
            hline.split_once(':').ok_or_else(|| HttpError::new(400, "malformed header"))?;
        if name.is_empty() || name.contains(' ') || name.contains('\t') {
            return Err(HttpError::new(400, "malformed header name"));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    // body: Content-Length only; no endpoint takes a chunked body
    let mut req = Request { method: method.to_string(), path, query, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        return Err(HttpError::new(501, "chunked bodies not supported"));
    }
    if let Some(cl) = req.header("content-length") {
        let len: usize = cl.parse().map_err(|_| HttpError::new(400, "bad content-length"))?;
        if len > MAX_BODY {
            return Err(HttpError::new(413, "body too large"));
        }
        let mut body = vec![0u8; len];
        r.read_exact(&mut body).map_err(|_| HttpError::new(400, "truncated body"))?;
        req.body = body;
    }
    Ok(Some(req))
}

/// Canonical reason phrase for the statuses the daemon emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        414 => "URI Too Long",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Status",
    }
}

/// Write one complete response and flush. Every response closes the
/// connection (one request per connection keeps the daemon's threading
/// model trivial and makes pipelined garbage unreachable).
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status,
        status_text(status),
        content_type,
        body.len()
    )?;
    w.write_all(body)?;
    w.flush()
}

/// Answer a protocol violation with its status and a one-line body.
pub fn write_error(w: &mut impl Write, e: HttpError) -> std::io::Result<()> {
    let body = format!("{}\n", e.reason);
    write_response(w, e.status, "text/plain; charset=utf-8", body.as_bytes())
}

/// Open an SSE response: headers only, no `Content-Length` — the body
/// is an unbounded event stream; the connection ends when either side
/// closes (daemon shutdown drops the subscription sender).
pub fn write_sse_preamble(w: &mut impl Write) -> std::io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufReader, Cursor};

    fn parse(bytes: &[u8]) -> Result<Option<Request>, HttpError> {
        read_request(&mut BufReader::new(Cursor::new(bytes.to_vec())))
    }

    #[test]
    fn parses_a_plain_get() {
        let req = parse(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.query, "");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn splits_query_and_reads_declared_body() {
        let raw = b"POST /submit?app=brisk&tasks=40 HTTP/1.1\r\n\
                    Content-Length: 4\r\n\r\nbody";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.path, "/submit");
        assert_eq!(req.query, "app=brisk&tasks=40");
        assert_eq!(req.body, b"body");
    }

    #[test]
    fn clean_close_before_a_request_is_not_an_error() {
        assert_eq!(parse(b""), Ok(None));
        // stray leading CRLF before the request line is tolerated
        let req = parse(b"\r\nGET / HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.path, "/");
    }

    #[test]
    fn malformed_requests_map_to_4xx_5xx_without_panicking() {
        // the robustness satellite's table: raw bytes -> expected status
        let cases: &[(&[u8], u16)] = &[
            (b"GARBAGE\r\n\r\n", 400),                                    // no target/version
            (b"GET /\r\n\r\n", 400),                                      // missing version
            (b"G@T / HTTP/1.1\r\n\r\n", 400),                             // bad method token
            (b"get / HTTP/1.1\r\n\r\n", 400),                             // lowercase method
            (b"GET / HTTP/1.1 extra\r\n\r\n", 400),                       // trailing junk
            (b"GET nohost HTTP/1.1\r\n\r\n", 400),                        // target w/o slash
            (b"GET / HTTP/2.0\r\n\r\n", 505),                             // wrong major version
            (b"GET / HTTP/1.1\r\nbroken header\r\n\r\n", 400),            // no colon
            (b"GET / HTTP/1.1\r\nBad Name: x\r\n\r\n", 400),              // space in name
            (b"GET / HTTP/1.1\r\n: empty\r\n\r\n", 400),                  // empty name
            (b"GET / HTTP/1.1\r\nX: y", 400),                             // EOF mid-headers
            (b"POST /s HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc", 400),  // truncated body
            (b"POST /s HTTP/1.1\r\nContent-Length: nope\r\n\r\n", 400),   // junk length
            (b"POST /s HTTP/1.1\r\nContent-Length: 9999999\r\n\r\n", 413), // body over cap
            (b"POST /s HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", 501),
        ];
        for (raw, want) in cases {
            match parse(raw) {
                Err(e) => assert_eq!(
                    e.status,
                    *want,
                    "input {:?}: got {} ({}), want {}",
                    String::from_utf8_lossy(raw),
                    e.status,
                    e.reason,
                    want
                ),
                Ok(r) => panic!("input {:?} parsed as {r:?}", String::from_utf8_lossy(raw)),
            }
        }
    }

    #[test]
    fn oversized_lines_and_header_floods_are_bounded() {
        // request line over the cap -> 414
        let mut raw = b"GET /".to_vec();
        raw.resize(raw.len() + MAX_REQUEST_LINE, b'a');
        raw.extend_from_slice(b" HTTP/1.1\r\n\r\n");
        assert_eq!(parse(&raw).unwrap_err().status, 414);
        // one header line over the cap -> 431
        let mut raw = b"GET / HTTP/1.1\r\nX: ".to_vec();
        raw.resize(raw.len() + MAX_HEADER_LINE, b'b');
        raw.extend_from_slice(b"\r\n\r\n");
        assert_eq!(parse(&raw).unwrap_err().status, 431);
        // too many headers -> 431
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..=MAX_HEADERS {
            raw.extend_from_slice(format!("X-{i}: v\r\n").as_bytes());
        }
        raw.extend_from_slice(b"\r\n");
        assert_eq!(parse(&raw).unwrap_err().status, 431);
    }

    #[test]
    fn pipelined_garbage_after_a_valid_request_is_never_read() {
        // one request per connection: the parser consumes exactly the
        // first request; trailing junk on the wire is ignored because
        // the daemon responds `Connection: close` and drops the socket
        let mut r = BufReader::new(Cursor::new(
            b"GET /metrics HTTP/1.1\r\n\r\n\x00\x01GARBAGE NOT HTTP".to_vec(),
        ));
        let req = read_request(&mut r).unwrap().unwrap();
        assert_eq!(req.path, "/metrics");
    }

    #[test]
    fn response_writer_emits_close_and_length() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{\"ok\":true}").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 11\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.ends_with("{\"ok\":true}"), "{text}");

        let mut out = Vec::new();
        write_error(&mut out, HttpError::new(404, "no such route")).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.ends_with("no such route\n"), "{text}");

        let mut out = Vec::new();
        write_sse_preamble(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Content-Type: text/event-stream"), "{text}");
    }
}
