//! Prometheus text exposition format (version 0.0.4) for the daemon's
//! `GET /metrics` endpoint (PR-7).
//!
//! Hand-rolled for the same reason as [`super::http`]: the build is
//! offline-hermetic, so no `prometheus` crate. The format is small —
//! `# HELP` / `# TYPE` comment lines plus `name{label="value"} 1.5`
//! samples — but has real escaping rules, which is exactly what the
//! satellite task pins down:
//!
//! * label **values** escape backslash (`\\`), double quote (`\"`) and
//!   newline (`\n`); everything else passes through verbatim,
//! * `# HELP` text escapes backslash and newline (quotes are legal
//!   there),
//! * metric and label **names** must match `[a-zA-Z_:][a-zA-Z0-9_:]*`
//!   (label names additionally forbid `:`); out-of-alphabet bytes are
//!   folded to `_` rather than emitted broken.

use std::fmt::Write as _;

/// Fold a metric name into the exposition alphabet
/// `[a-zA-Z_:][a-zA-Z0-9_:]*`. Invalid characters become `_`; an empty
/// name becomes `_` outright.
pub fn sanitize_metric_name(name: &str) -> String {
    if name.is_empty() {
        return "_".to_string();
    }
    name.chars()
        .enumerate()
        .map(|(i, c)| {
            let ok = c.is_ascii_alphabetic()
                || c == '_'
                || c == ':'
                || (i > 0 && c.is_ascii_digit());
            if ok {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Like [`sanitize_metric_name`] but for label names, where `:` is
/// reserved for recording rules and therefore also folded.
pub fn sanitize_label_name(name: &str) -> String {
    sanitize_metric_name(name).replace(':', "_")
}

/// Escape a label value per the exposition format: backslash, double
/// quote, and newline.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Escape `# HELP` text: backslash and newline only (quotes are legal).
pub fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Incremental builder for one exposition page.
#[derive(Debug, Default)]
pub struct PromText {
    buf: String,
}

impl PromText {
    pub fn new() -> Self {
        PromText::default()
    }

    /// Emit the `# HELP` / `# TYPE` preamble for a metric family.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        let name = sanitize_metric_name(name);
        let _ = writeln!(self.buf, "# HELP {name} {}", escape_help(help));
        let _ = writeln!(self.buf, "# TYPE {name} {kind}");
    }

    /// Emit one sample line, with optional labels.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.buf.push_str(&sanitize_metric_name(name));
        if !labels.is_empty() {
            self.buf.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.buf.push(',');
                }
                let name = sanitize_label_name(k);
                let _ = write!(self.buf, "{name}=\"{}\"", escape_label_value(v));
            }
            self.buf.push('}');
        }
        let _ = writeln!(self.buf, " {value}");
    }

    /// `family` + single unlabelled `sample` in one call — the common
    /// shape for the daemon's counters and gauges.
    pub fn scalar(&mut self, name: &str, kind: &str, help: &str, value: f64) {
        self.family(name, kind, help);
        self.sample(name, &[], value);
    }

    pub fn into_string(self) -> String {
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_families_render_help_type_sample() {
        let mut p = PromText::new();
        p.scalar("dithen_tasks_completed", "counter", "Tasks completed so far.", 80.0);
        assert_eq!(
            p.into_string(),
            "# HELP dithen_tasks_completed Tasks completed so far.\n\
             # TYPE dithen_tasks_completed counter\n\
             dithen_tasks_completed 80\n"
        );
    }

    #[test]
    fn label_values_escape_backslash_newline_quote() {
        // the exposition-format edge cases from the satellite task
        let mut p = PromText::new();
        p.sample(
            "dithen_fleet_cus",
            &[("pool", "m3\\medium"), ("note", "line1\nline2"), ("q", "say \"hi\"")],
            4.0,
        );
        assert_eq!(
            p.into_string(),
            "dithen_fleet_cus{pool=\"m3\\\\medium\",note=\"line1\\nline2\",q=\"say \\\"hi\\\"\"} 4\n"
        );
    }

    #[test]
    fn names_are_folded_into_the_exposition_alphabet() {
        assert_eq!(sanitize_metric_name("dithen.tasks-completed"), "dithen_tasks_completed");
        assert_eq!(sanitize_metric_name("9lives"), "_lives");
        assert_eq!(sanitize_metric_name("ok_name:sub"), "ok_name:sub");
        assert_eq!(sanitize_metric_name(""), "_");
        // label names additionally fold the colon
        assert_eq!(sanitize_label_name("a:b"), "a_b");
        assert_eq!(sanitize_label_name("röle"), "r_le");
    }

    #[test]
    fn help_text_escapes_backslash_and_newline_only() {
        assert_eq!(escape_help("a\\b\nc \"quoted\""), "a\\\\b\\nc \"quoted\"");
    }

    #[test]
    fn float_values_render_plainly() {
        let mut p = PromText::new();
        p.sample("m", &[], 0.5);
        p.sample("m", &[], 12.0);
        assert_eq!(p.into_string(), "m 0.5\nm 12\n");
    }
}
