//! Server-sent events for `dithen serve` (PR-7): the `GET /events`
//! stream carrying cloud events (spot reclamations as they are applied
//! at a monitoring instant) and per-tick summaries
//! ([`crate::metrics::TickSummary`]).
//!
//! The hub lives on the daemon's control thread — the single owner of
//! the platform — so publishing needs no locking: each `/events`
//! connection registers an `mpsc` sender via the command channel and
//! its handler thread forwards frames to the socket until either side
//! drops. A dead subscriber (closed socket → the handler drops its
//! receiver → `send` fails) is pruned on the next publish, so slow or
//! vanished clients can never stall the control loop.

use std::fmt::Write as _;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Render one SSE frame: an `event:` line, the data split across
/// `data:` lines (SSE reassembles multi-line payloads with `\n`), and
/// the blank-line terminator. Event names must be single-line; stray
/// CR/LF are folded to spaces rather than letting them forge frames.
pub fn sse_frame(event: &str, data: &str) -> String {
    let mut out = String::with_capacity(event.len() + data.len() + 16);
    let event = event.replace(['\n', '\r'], " ");
    let _ = writeln!(out, "event: {event}");
    for line in data.split('\n') {
        let line = line.strip_suffix('\r').unwrap_or(line);
        let _ = writeln!(out, "data: {line}");
    }
    out.push('\n');
    out
}

/// Fan-out point for SSE frames: one sender per live `/events`
/// connection.
#[derive(Debug, Default)]
pub struct SseHub {
    subs: Vec<Sender<String>>,
}

impl SseHub {
    pub fn new() -> Self {
        SseHub::default()
    }

    /// Register a new subscriber; the returned receiver yields
    /// ready-to-write frames.
    pub fn subscribe(&mut self) -> Receiver<String> {
        let (tx, rx) = channel();
        self.subs.push(tx);
        rx
    }

    /// Attach an externally created sender (the `/events` handler
    /// thread passes its own through the command channel).
    pub fn attach(&mut self, tx: Sender<String>) {
        self.subs.push(tx);
    }

    /// Broadcast one event, pruning subscribers whose receiver is gone.
    pub fn publish(&mut self, event: &str, data: &str) {
        if self.subs.is_empty() {
            return;
        }
        let frame = sse_frame(event, data);
        self.subs.retain(|tx| tx.send(frame.clone()).is_ok());
    }

    /// Live subscriber count (as of the last publish's pruning).
    pub fn len(&self) -> usize {
        self.subs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.subs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_follow_the_sse_wire_format() {
        assert_eq!(sse_frame("tick", "{\"t\":60}"), "event: tick\ndata: {\"t\":60}\n\n");
        // multi-line payloads become one data: line each
        assert_eq!(sse_frame("log", "a\nb\r\nc"), "event: log\ndata: a\ndata: b\ndata: c\n\n");
        // newline in an event name cannot forge an extra frame
        assert_eq!(sse_frame("x\ny", "d"), "event: x y\ndata: d\n\n");
    }

    #[test]
    fn hub_broadcasts_and_prunes_dead_subscribers() {
        let mut hub = SseHub::new();
        let alive = hub.subscribe();
        let dead = hub.subscribe();
        assert_eq!(hub.len(), 2);
        drop(dead);
        hub.publish("tick", "{}");
        assert_eq!(hub.len(), 1, "dead subscriber must be pruned on publish");
        assert_eq!(alive.try_recv().unwrap(), "event: tick\ndata: {}\n\n");
        // publishing with no subscribers is a no-op, not an allocation
        let mut empty = SseHub::new();
        empty.publish("tick", "{}");
        assert!(empty.is_empty());
    }
}
