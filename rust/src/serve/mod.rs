//! `dithen serve` — the resident Computation-as-a-Service daemon
//! (PR-7).
//!
//! The paper's platform is a *service*: workloads arrive from users
//! over the network, not from a pre-baked suite. Everything before
//! this module ran Dithen as a batch simulator — assemble a
//! [`crate::platform::Scenario`], run it to completion, read the
//! metrics. This module makes the platform resident: a daemon that
//! holds a live [`crate::platform::Platform`] and accepts workload
//! submissions over HTTP while the discrete-event loop runs.
//!
//! ```text
//!   POST /submit ──┐                       ┌── GET /status/{w}
//!   POST /advance ─┤   mpsc Command        ├── GET /metrics   (Prometheus)
//!   POST /shutdown ┼──► control thread ────┼── GET /events    (SSE)
//!                  │    owns Platform      └── GET /healthz
//!   (conn threads) ┘    + SseHub
//! ```
//!
//! Layout:
//!
//! * [`http`] — hand-rolled threaded HTTP/1.1 on `std::net` (the build
//!   is offline-hermetic: no tokio/axum/hyper). Bounded request line,
//!   headers, and body; malformed input maps to 4xx/5xx, never panics.
//! * [`api`] — routing, query decoding, JSON escaping.
//! * [`prometheus`] — text exposition (version 0.0.4) with the real
//!   escaping rules.
//! * [`events`] — SSE framing and the subscriber hub.
//! * [`daemon`] — the control thread that owns the platform, the
//!   accept loop, clock modes, and graceful shutdown.
//!
//! The headline property, pinned by `tests/serve_parity.rs`: under the
//! scripted clock, submitting a suite over HTTP and advancing to
//! quiescence yields `RunMetrics` **bit-identical** to the equivalent
//! batch [`crate::platform::Scenario`] run. Determinism survives HTTP
//! ingestion because the sim clock never reads the wall clock and
//! ingestion lands only at tick boundaries (the PR-5 phase seams).

pub mod api;
pub mod daemon;
pub mod events;
pub mod http;
pub mod prometheus;

pub use daemon::{
    install_signal_handlers, AdvanceAck, ClockMode, Daemon, DaemonHandle, ServeOpts, SubmitAck,
    SubmitReq,
};
