//! The resident CaaS daemon behind `dithen serve` (PR-7).
//!
//! ## Threading model
//!
//! [`crate::platform::Platform`] is deliberately not `Send`-shared: a
//! single **control thread** owns it outright (actor style), and HTTP
//! connection threads talk to it over an `mpsc` [`Command`] channel
//! with per-request reply channels. The accept loop spawns one short-
//! lived thread per connection (one request per connection, see
//! [`super::http`]); `/events` handlers stay alive forwarding SSE
//! frames until either side drops.
//!
//! ## Clock modes and determinism
//!
//! The sim clock never reads the wall clock. Under
//! [`ClockMode::Scripted`] the simulation only moves when a client
//! `POST /advance`s it, so a scripted client's submit/advance sequence
//! is a *program*, and replaying it reproduces the run bit-for-bit:
//! submissions received while the daemon is idle accumulate and the
//! first advance assembles the accumulated suite into a plain
//! [`Scenario`] with [`ArrivalProcess::Scripted`] arrivals — literally
//! the batch code path (`tests/serve_parity.rs` pins `RunMetrics`
//! equality). Submissions landing on a *running* platform go through
//! [`crate::platform::Platform::admit_workload`], whose bitwise
//! batch-twin argument lives with that method. Under
//! [`ClockMode::Paced`] the control thread maps wall time onto sim
//! time at a configured rate for interactive use — same code path per
//! tick, but no bit-reproducibility claim, since tick timing then
//! depends on when submissions race the wall clock.
//!
//! The PR-5 tick phases are the suspension points: between
//! `tick_finish` and the next `pump_to_tick` the control thread drains
//! queued commands, so ingestion lands exactly on monitoring-instant
//! boundaries.

use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::estimation::BankCache;
use crate::metrics::{RunMetrics, TickSummary};
use crate::platform::{ArrivalProcess, CloudEvent, Platform, Scenario, WlPhase};
use crate::sim::SimTime;
use crate::util::rng::Rng;
use crate::workload::{app_model, App, WorkloadSpec};

use super::api::{self, Route};
use super::events::SseHub;
use super::http::{self, Request};
use super::prometheus::PromText;

/// Process-wide graceful-shutdown latch, set by the SIGTERM/SIGINT
/// handler installed by the `serve` CLI command. The control loop
/// polls it between commands (≤100 ms latency). Tests never install
/// the handler, so in-process daemons are unaffected.
pub static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod sig {
    use super::SHUTDOWN;
    use std::sync::atomic::Ordering;

    /// `sighandler_t` — a plain C function pointer, so the declaration
    /// below needs no pointer casts.
    type Handler = extern "C" fn(i32);

    extern "C" {
        fn signal(signum: i32, handler: Handler) -> usize;
    }

    extern "C" fn on_signal(_sig: i32) {
        // a store to an atomic is async-signal-safe
        SHUTDOWN.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_signal);
            signal(SIGINT, on_signal);
        }
    }
}

/// Bind SIGTERM/SIGINT to the graceful-shutdown latch (no-op off
/// unix). Called by the CLI only — a test daemon shuts down over HTTP
/// or [`DaemonHandle::join`].
pub fn install_signal_handlers() {
    #[cfg(unix)]
    sig::install();
}

/// How the daemon maps wall time onto sim time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClockMode {
    /// Sim time moves only on `POST /advance` — fully deterministic;
    /// the mode every test and the parity pin run under.
    Scripted,
    /// Sim time tracks wall time at `speed` sim-seconds per
    /// wall-second (interactive use; no bit-reproducibility claim).
    Paced { speed: f64 },
}

impl ClockMode {
    fn label(&self) -> String {
        match *self {
            ClockMode::Scripted => "scripted".to_string(),
            ClockMode::Paced { speed } => format!("paced:{speed}"),
        }
    }
}

/// Daemon configuration: a workload-less [`Scenario`] acting as the
/// template (backend, fleet, fault model, policy, estimator, horizon,
/// TTC, config), plus serve-specific knobs.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Scenario template; `specs` and `arrivals` are ignored — they
    /// are replaced by the accumulated submissions and their scripted
    /// arrival instants at assembly time.
    pub template: Scenario,
    pub clock: ClockMode,
    /// Root seed for workload generation (`WorkloadSpec::generate`
    /// substreams per id). Defaults to the template's `cfg.seed` in
    /// the CLI; separate so a scripted client can reproduce a batch
    /// suite built from a different generator root.
    pub workload_seed: u64,
}

/// One `POST /submit`, decoded.
#[derive(Debug, Clone)]
pub struct SubmitReq {
    pub app: App,
    pub tasks: usize,
    /// Requested sim arrival instant; clamped to now and to the latest
    /// already-scheduled arrival (ids must arrive in order).
    pub at: Option<SimTime>,
    /// Per-workload requested TTC (the spec's `requested_ttc`).
    pub ttc: Option<u64>,
}

#[derive(Debug, Clone, Copy)]
pub struct SubmitAck {
    pub workload: usize,
    pub arrival_at: SimTime,
}

#[derive(Debug, Clone, Copy)]
pub struct AdvanceAck {
    pub now: SimTime,
    pub ticks_run: u64,
    /// No more progress is possible without new submissions.
    pub quiescent: bool,
    pub all_done: bool,
}

enum Command {
    Submit(SubmitReq, Sender<Result<SubmitAck, String>>),
    Advance { to: Option<SimTime>, reply: Sender<Result<AdvanceAck, String>> },
    Status { workload: usize, reply: Sender<Option<String>> },
    Metrics { reply: Sender<String> },
    Subscribe { tx: Sender<String> },
    Shutdown { reply: Sender<()> },
}

/// Handle to a spawned daemon: the bound address plus the control
/// channel. Dropping the handle does NOT stop the daemon — call
/// [`DaemonHandle::join`] (tests) or [`DaemonHandle::wait`] (CLI,
/// which relies on the signal latch or `POST /shutdown`).
pub struct DaemonHandle {
    pub addr: SocketAddr,
    tx: Sender<Command>,
    control: JoinHandle<Result<RunMetrics>>,
}

impl DaemonHandle {
    pub fn base_url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Ask the control loop to stop (idempotent; tolerates an already
    /// stopped daemon).
    pub fn shutdown(&self) {
        let (rtx, rrx) = channel();
        if self.tx.send(Command::Shutdown { reply: rtx }).is_ok() {
            let _ = rrx.recv_timeout(Duration::from_secs(60));
        }
    }

    /// Graceful stop + final metrics: what a scripted client calls
    /// once its submission program is complete.
    pub fn join(self) -> Result<RunMetrics> {
        self.shutdown();
        self.wait()
    }

    /// Wait for the control loop to exit on its own (SIGTERM latch or
    /// `POST /shutdown`) and return the final metrics.
    pub fn wait(self) -> Result<RunMetrics> {
        match self.control.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("daemon control thread panicked"),
        }
    }
}

pub struct Daemon;

impl Daemon {
    /// Bind `127.0.0.1:port` (0 = ephemeral, for tests), spawn the
    /// accept loop and the control thread, and return immediately.
    pub fn spawn(opts: ServeOpts, port: u16) -> Result<DaemonHandle> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let (tx, rx) = channel::<Command>();
        let done = Arc::new(AtomicBool::new(false));

        let conn_tx = tx.clone();
        let accept_done = done.clone();
        thread::Builder::new().name("dithen-http".into()).spawn(move || {
            for stream in listener.incoming() {
                if accept_done.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(s) = stream {
                    let tx = conn_tx.clone();
                    let _ = thread::Builder::new()
                        .name("dithen-conn".into())
                        .spawn(move || handle_connection(s, tx));
                }
            }
        })?;

        let control = thread::Builder::new().name("dithen-ctl".into()).spawn(move || {
            let result = Control::new(opts).run(&rx);
            // unblock the accept loop so its thread exits too
            done.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(addr);
            result
        })?;

        Ok(DaemonHandle { addr, tx, control })
    }
}

// ---------------------------------------------------------------------------
// connection handling (per-connection threads)
// ---------------------------------------------------------------------------

fn handle_connection(stream: TcpStream, tx: Sender<Command>) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let reader_half = match stream.try_clone() {
        Ok(c) => c,
        Err(_) => return,
    };
    let mut reader = std::io::BufReader::new(reader_half);
    let mut writer = stream;
    let req = match http::read_request(&mut reader) {
        Ok(Some(r)) => r,
        Ok(None) => return,
        Err(e) => {
            let _ = http::write_error(&mut writer, e);
            return;
        }
    };
    match api::route(&req.method, &req.path) {
        Err(e) => {
            let _ = http::write_error(&mut writer, e);
        }
        Ok(route) => dispatch(route, &req, &mut writer, &tx),
    }
}

/// Send a command and wait for the control loop's reply; `None` when
/// the daemon is gone (reply with 503).
fn ask<T>(tx: &Sender<Command>, build: impl FnOnce(Sender<T>) -> Command) -> Option<T> {
    let (rtx, rrx) = channel();
    tx.send(build(rtx)).ok()?;
    rrx.recv().ok()
}

fn respond_json(w: &mut TcpStream, status: u16, body: String) {
    let _ = http::write_response(w, status, "application/json", body.as_bytes());
}

fn respond_unavailable(w: &mut TcpStream) {
    let _ = http::write_error(w, http::HttpError::new(503, "daemon is shutting down"));
}

fn dispatch(route: Route, req: &Request, w: &mut TcpStream, tx: &Sender<Command>) {
    match route {
        Route::Healthz => match ask(tx, |r| Command::Metrics { reply: r }) {
            // a healthz that round-trips the control thread proves the
            // loop is alive, not merely that the socket accepts
            Some(_) => respond_json(w, 200, "{\"ok\":true}".to_string()),
            None => respond_unavailable(w),
        },
        Route::Metrics => match ask(tx, |r| Command::Metrics { reply: r }) {
            Some(text) => {
                let _ = http::write_response(
                    w,
                    200,
                    "text/plain; version=0.0.4; charset=utf-8",
                    text.as_bytes(),
                );
            }
            None => respond_unavailable(w),
        },
        Route::Status(workload) => {
            match ask(tx, |r| Command::Status { workload, reply: r }) {
                Some(Some(json)) => respond_json(w, 200, json),
                Some(None) => {
                    let _ = http::write_error(w, http::HttpError::new(404, "unknown workload"));
                }
                None => respond_unavailable(w),
            }
        }
        Route::Submit => {
            let params = api::parse_query(&req.query);
            let app = match api::query_get(&params, "app").and_then(api::parse_app) {
                Some(a) => a,
                None => {
                    respond_json(
                        w,
                        400,
                        "{\"error\":\"unknown or missing app (use a model name like face-detection)\"}"
                            .to_string(),
                    );
                    return;
                }
            };
            let tasks = match api::query_get(&params, "tasks").and_then(|t| t.parse().ok()) {
                Some(n) if n > 0 => n,
                _ => {
                    respond_json(
                        w,
                        400,
                        "{\"error\":\"tasks must be a positive integer\"}".to_string(),
                    );
                    return;
                }
            };
            let at = api::query_get(&params, "at").and_then(|t| t.parse().ok());
            let ttc = api::query_get(&params, "ttc").and_then(|t| t.parse().ok());
            match ask(tx, |r| Command::Submit(SubmitReq { app, tasks, at, ttc }, r)) {
                Some(Ok(ack)) => respond_json(
                    w,
                    200,
                    format!("{{\"workload\":{},\"arrival_at\":{}}}", ack.workload, ack.arrival_at),
                ),
                Some(Err(e)) => {
                    respond_json(w, 409, format!("{{\"error\":\"{}\"}}", api::json_escape(&e)))
                }
                None => respond_unavailable(w),
            }
        }
        Route::Advance => {
            let params = api::parse_query(&req.query);
            let to = api::query_get(&params, "to").and_then(|t| t.parse().ok());
            match ask(tx, |r| Command::Advance { to, reply: r }) {
                Some(Ok(a)) => respond_json(
                    w,
                    200,
                    format!(
                        "{{\"now\":{},\"ticks_run\":{},\"quiescent\":{},\"all_done\":{}}}",
                        a.now, a.ticks_run, a.quiescent, a.all_done
                    ),
                ),
                Some(Err(e)) => {
                    respond_json(w, 409, format!("{{\"error\":\"{}\"}}", api::json_escape(&e)))
                }
                None => respond_unavailable(w),
            }
        }
        Route::Shutdown => match ask(tx, |r| Command::Shutdown { reply: r }) {
            Some(()) => respond_json(w, 200, "{\"ok\":true,\"draining\":true}".to_string()),
            None => respond_unavailable(w),
        },
        Route::Events => {
            let (etx, erx) = channel::<String>();
            if tx.send(Command::Subscribe { tx: etx }).is_err() {
                respond_unavailable(w);
                return;
            }
            if http::write_sse_preamble(w).is_err() {
                return;
            }
            let _ = w.set_write_timeout(Some(Duration::from_secs(10)));
            loop {
                match erx.recv_timeout(Duration::from_secs(15)) {
                    Ok(frame) => {
                        if w.write_all(frame.as_bytes()).and_then(|_| w.flush()).is_err() {
                            break;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        // comment line keep-alive; also detects dead peers
                        if w.write_all(b": keep-alive\n\n").and_then(|_| w.flush()).is_err() {
                            break;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// control thread: the single owner of the platform
// ---------------------------------------------------------------------------

struct Control {
    template: Scenario,
    clock: ClockMode,
    rng: Rng,
    cache: BankCache,
    hub: SseHub,
    /// Submissions accumulated before the platform is assembled.
    pending_specs: Vec<WorkloadSpec>,
    pending_times: Vec<SimTime>,
    platform: Option<Platform>,
    next_id: usize,
    /// Latest scheduled arrival instant — later submissions clamp to
    /// it so arrival order always matches id order.
    last_arrival: SimTime,
    stop: bool,
    /// Horizon crossed: the run is over; submissions are rejected.
    finished: bool,
    /// Wall-clock anchor for paced mode (set at assembly).
    paced_origin: Option<Instant>,
}

impl Control {
    fn new(opts: ServeOpts) -> Self {
        Control {
            template: opts.template,
            clock: opts.clock,
            rng: Rng::new(opts.workload_seed),
            cache: BankCache::new(),
            hub: SseHub::new(),
            pending_specs: vec![],
            pending_times: vec![],
            platform: None,
            next_id: 0,
            last_arrival: 0,
            stop: false,
            finished: false,
            paced_origin: None,
        }
    }

    fn run(mut self, rx: &Receiver<Command>) -> Result<RunMetrics> {
        loop {
            if self.stop || SHUTDOWN.load(Ordering::SeqCst) {
                break;
            }
            match rx.recv_timeout(Duration::from_millis(100)) {
                Ok(Command::Advance { to, reply }) => {
                    let _ = reply.send(self.advance(to, rx));
                }
                Ok(cmd) => self.handle_non_advance(cmd),
                Err(RecvTimeoutError::Timeout) => self.drive_paced(),
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        self.hub.publish("shutdown", "{\"draining\":true}");
        // graceful drain: finish everything in flight (and any
        // submitted-but-unreached arrivals) before finalizing, so a
        // SIGTERM'd daemon still accounts every accepted task exactly
        // once — the same invariant the batch loop ends with
        if let Some(p) = self.platform.as_mut() {
            if !self.finished && p.all_done_at.is_none() {
                while let Ok(true) = p.pump_to_tick() {
                    p.tick_gather();
                    if p.step_bank().is_err() {
                        break;
                    }
                    p.tick_finish();
                    if p.all_done_at.is_some() {
                        break;
                    }
                }
            }
        }
        match self.platform.take() {
            Some(p) => p.finalize_with_db().map(|(m, _db)| m),
            None => Ok(RunMetrics::default()),
        }
    }

    fn handle_non_advance(&mut self, cmd: Command) {
        match cmd {
            Command::Submit(req, reply) => {
                let _ = reply.send(self.submit(req));
            }
            Command::Status { workload, reply } => {
                let _ = reply.send(self.status_json(workload));
            }
            Command::Metrics { reply } => {
                let _ = reply.send(self.metrics_text());
            }
            Command::Subscribe { tx } => self.hub.attach(tx),
            Command::Shutdown { reply } => {
                self.stop = true;
                let _ = reply.send(());
            }
            Command::Advance { reply, .. } => {
                let _ = reply.send(Err("an advance is already in progress".to_string()));
            }
        }
    }

    /// Build the platform from the accumulated submissions: the exact
    /// batch assembly path, with the submission log as the scripted
    /// arrival schedule. This is why idle-daemon ingestion is
    /// bit-identical to the batch scenario *by construction*.
    fn assemble(&mut self) -> Result<(), String> {
        let mut scn = self.template.clone();
        scn.specs = std::mem::take(&mut self.pending_specs);
        scn.arrivals = ArrivalProcess::Scripted { times: std::mem::take(&mut self.pending_times) };
        // a resident daemon has an unbounded lifetime: audit-and-retire
        // terminal shards so memory tracks the live window, not the
        // submission history. Retirement is bitwise-unobservable in
        // `RunMetrics` (the serve-parity pin still compares against a
        // keep-everything batch twin); `/status` serves retired
        // workloads from the audited terminal counts.
        scn.retire_shards = true;
        scn.validate().map_err(|e| e.to_string())?;
        let mut p = Platform::from_scenario_with_cache(scn, &self.cache);
        p.start();
        self.platform = Some(p);
        self.paced_origin = Some(Instant::now());
        Ok(())
    }

    fn submit(&mut self, req: SubmitReq) -> Result<SubmitAck, String> {
        if self.finished {
            return Err("scenario horizon reached; daemon is drained".to_string());
        }
        let id = self.next_id;
        let spec = WorkloadSpec::generate(id, req.app, req.tasks, req.ttc, &self.rng);
        let floor = match &self.platform {
            Some(p) => p.sim.now(),
            None => 0,
        };
        let at = req.at.unwrap_or(floor).max(floor).max(self.last_arrival);
        match self.platform.as_mut() {
            None => {
                self.pending_specs.push(spec);
                self.pending_times.push(at);
            }
            Some(p) => {
                p.admit_workload(spec, at).map_err(|e| e.to_string())?;
            }
        }
        self.next_id = id + 1;
        self.last_arrival = at;
        self.hub.publish("submitted", &format!("{{\"workload\":{id},\"arrival_at\":{at}}}"));
        if matches!(self.clock, ClockMode::Paced { .. }) && self.platform.is_none() {
            // paced mode starts the wall clock at first submission
            self.assemble()?;
        }
        Ok(SubmitAck { workload: id, arrival_at: at })
    }

    /// One tick round (the PR-5 phases), publishing the SSE summary
    /// and any cloud events applied at this instant. Returns false if
    /// the bank step failed.
    fn tick_round(p: &mut Platform, hub: &mut SseHub) -> Result<(), String> {
        p.tick_gather();
        p.step_bank().map_err(|e| e.to_string())?;
        p.tick_finish();
        if hub.is_empty() {
            return Ok(());
        }
        let now = p.sim.now();
        for ev in &p.fault_events {
            match ev {
                CloudEvent::Reclamation { instances } => hub.publish(
                    "cloud",
                    &format!(
                        "{{\"type\":\"reclamation\",\"t\":{now},\"instances\":{}}}",
                        instances.len()
                    ),
                ),
                CloudEvent::BootFailure { instances } => hub.publish(
                    "cloud",
                    &format!(
                        "{{\"type\":\"boot_failure\",\"t\":{now},\"instances\":{}}}",
                        instances.len()
                    ),
                ),
            }
        }
        let fleet = p.backend.describe(now);
        let done = p.wl.iter().filter(|w| matches!(w.phase, WlPhase::Done)).count();
        let summary = TickSummary {
            t: now,
            ticks: p.metrics.ticks,
            arrived: p.arrived,
            done,
            tasks_completed: p.metrics.tasks_completed as u64,
            requeued_tasks: p.metrics.requeued_tasks,
            reclamations: p.metrics.reclamations,
            active_cus: fleet.active_cus,
            committed_cus: fleet.committed_cus,
            total_cost: p.backend.total_cost(),
        };
        hub.publish("tick", &summary.to_json());
        Ok(())
    }

    /// Scripted-mode advance: run the batch loop until quiescent (or
    /// until sim time reaches `to`), draining queued commands between
    /// ticks — the ingestion suspension point.
    fn advance(
        &mut self,
        to: Option<SimTime>,
        rx: &Receiver<Command>,
    ) -> Result<AdvanceAck, String> {
        if let ClockMode::Paced { .. } = self.clock {
            return Err(
                "paced clock advances with wall time; /advance is scripted-mode only".to_string(),
            );
        }
        if self.finished {
            return Err("scenario horizon reached; daemon is drained".to_string());
        }
        if self.platform.is_none() {
            if self.pending_specs.is_empty() {
                return Err("no workloads submitted".to_string());
            }
            self.assemble()?;
        }
        let mut ticks_run = 0u64;
        let mut quiescent = false;
        loop {
            if self.stop || SHUTDOWN.load(Ordering::SeqCst) {
                break;
            }
            {
                let p = self.platform.as_mut().expect("assembled above");
                // quiescent already (e.g. a second advance after the
                // suite completed): running more ticks here would
                // execute monitoring instants the batch loop never ran
                if p.all_done_at.is_some() {
                    quiescent = true;
                    break;
                }
                if let Some(t) = to {
                    if p.sim.now() >= t {
                        break;
                    }
                }
                match p.pump_to_tick().map_err(|e| e.to_string())? {
                    true => {
                        Self::tick_round(p, &mut self.hub)?;
                        ticks_run += 1;
                        if p.all_done_at.is_some() {
                            quiescent = true;
                            break;
                        }
                    }
                    false => {
                        quiescent = true;
                        break;
                    }
                }
            }
            // between-tick suspension point: drain queued submissions
            // (and any status/metrics probes) before pumping on
            while let Ok(cmd) = rx.try_recv() {
                self.handle_non_advance(cmd);
            }
        }
        let p = self.platform.as_ref().expect("assembled above");
        let now = p.sim.now();
        let all_done = p.all_done_at.is_some();
        let crossed = now > p.horizon_s;
        let ack = AdvanceAck { now, ticks_run, quiescent, all_done };
        if crossed {
            self.finished = true;
        }
        Ok(ack)
    }

    /// Paced-mode driver: called on every idle wakeup; runs tick
    /// rounds while the next scheduled event is inside the wall-mapped
    /// sim-time budget.
    fn drive_paced(&mut self) {
        let ClockMode::Paced { speed } = self.clock else { return };
        if self.finished {
            return;
        }
        let Some(origin) = self.paced_origin else { return };
        let Some(p) = self.platform.as_mut() else { return };
        let target = (origin.elapsed().as_secs_f64() * speed) as SimTime;
        loop {
            if self.stop || SHUTDOWN.load(Ordering::SeqCst) {
                break;
            }
            match p.sim.peek_time() {
                Some(next) if next <= target => {}
                _ => break, // ahead of the wall clock, or drained
            }
            match p.pump_to_tick() {
                Ok(true) => {
                    if Self::tick_round(p, &mut self.hub).is_err() {
                        break;
                    }
                    if p.all_done_at.is_some() {
                        break; // resident: stay up for the next submission
                    }
                }
                _ => break,
            }
        }
        if p.sim.now() > p.horizon_s {
            self.finished = true;
        }
    }

    fn status_json(&self, w: usize) -> Option<String> {
        if w >= self.next_id {
            return None;
        }
        match &self.platform {
            None => {
                let spec = &self.pending_specs[w];
                Some(format!(
                    "{{\"workload\":{w},\"app\":\"{}\",\"phase\":\"queued\",\"arrival_at\":{},\"tasks\":{{\"total\":{},\"pending\":{2},\"processing\":0,\"completed\":0,\"failed\":0}}}}",
                    app_model(spec.app).name,
                    self.pending_times[w],
                    spec.n_tasks(),
                ))
            }
            Some(p) => {
                use crate::db::TaskStatus::*;
                let spec = &p.specs[w];
                let phase = if w >= p.arrived {
                    "queued"
                } else {
                    match p.wl[w].phase {
                        WlPhase::Footprinting => "footprinting",
                        WlPhase::Running => "running",
                        WlPhase::Merging => "merging",
                        WlPhase::Done => "done",
                    }
                };
                // a retired workload's shard (and its spec's task slab)
                // is gone — serve the exactly-once audited counts the
                // retirement recorded instead of querying the tombstone
                if let Some((completed, failed)) = p.wl[w].terminal {
                    return Some(format!(
                        "{{\"workload\":{w},\"app\":\"{}\",\"phase\":\"{phase}\",\"tasks\":{{\"total\":{},\"pending\":0,\"processing\":0,\"completed\":{completed},\"failed\":{failed}}}}}",
                        app_model(spec.app).name,
                        p.wl[w].n_tasks,
                    ));
                }
                Some(format!(
                    "{{\"workload\":{w},\"app\":\"{}\",\"phase\":\"{phase}\",\"tasks\":{{\"total\":{},\"pending\":{},\"processing\":{},\"completed\":{},\"failed\":{}}}}}",
                    app_model(spec.app).name,
                    spec.n_tasks(),
                    p.db.count_status(w, Pending),
                    p.db.count_status(w, Processing),
                    p.db.count_status(w, Completed),
                    p.db.count_status(w, Failed),
                ))
            }
        }
    }

    fn metrics_text(&self) -> String {
        let mut pt = PromText::new();
        pt.scalar("dithen_up", "gauge", "1 while the daemon's control loop is alive.", 1.0);
        pt.family("dithen_info", "gauge", "Daemon scenario description (constant 1).");
        pt.sample(
            "dithen_info",
            &[
                ("backend", self.template.backend.name()),
                ("fault", &self.template.fault.describe()),
                ("clock", &self.clock.label()),
            ],
            1.0,
        );
        pt.scalar(
            "dithen_workloads_submitted",
            "counter",
            "Workloads accepted over HTTP.",
            self.next_id as f64,
        );
        let Some(p) = self.platform.as_ref() else {
            return pt.into_string();
        };
        let now = p.sim.now();
        let m = &p.metrics;
        pt.scalar("dithen_sim_time_seconds", "gauge", "Current simulation instant.", now as f64);
        pt.scalar(
            "dithen_workloads_arrived",
            "counter",
            "Workloads that have reached the front end.",
            p.arrived as f64,
        );
        let done = p.wl.iter().filter(|w| matches!(w.phase, WlPhase::Done)).count();
        pt.scalar("dithen_workloads_done", "counter", "Workloads fully completed.", done as f64);
        // PR-8 residency observability: what the retirement path keeps
        // resident vs. what it has audited away
        pt.scalar(
            "dithen_live_shards",
            "gauge",
            "Workload shards currently resident (arrived - retired).",
            p.live_shards() as f64,
        );
        pt.scalar(
            "dithen_retired_shards",
            "gauge",
            "Terminal workload shards audited and retired.",
            p.retired_shards() as f64,
        );
        pt.scalar(
            "dithen_tasks_completed",
            "counter",
            "Tasks completed exactly once across all workloads.",
            m.tasks_completed as f64,
        );
        pt.scalar(
            "dithen_tasks_requeued",
            "counter",
            "Tasks re-entered at the pending tail after a reclamation.",
            m.requeued_tasks as f64,
        );
        // PR-10 partial-failure receipts
        pt.scalar(
            "dithen_chunk_retries",
            "counter",
            "Chunks lost to transient crashes that scheduled a retry.",
            m.chunk_retries as f64,
        );
        pt.scalar(
            "dithen_speculative_launches",
            "counter",
            "Speculative twin chunks launched against suspected stragglers.",
            m.speculative_launches as f64,
        );
        pt.scalar(
            "dithen_straggler_instances",
            "counter",
            "Instances that came up degraded under the straggler fault model.",
            m.straggler_instances as f64,
        );
        pt.scalar(
            "dithen_tasks_abandoned",
            "counter",
            "Tasks dropped after exhausting the per-task retry budget.",
            m.tasks_abandoned as f64,
        );
        pt.scalar(
            "dithen_reclamations",
            "counter",
            "Instances revoked by the fault model.",
            m.reclamations as f64,
        );
        pt.family(
            "dithen_reclamations_by_pool",
            "counter",
            "Instances revoked, by fleet pool index.",
        );
        for (pool, n) in m.reclamations_by_pool.iter().enumerate() {
            pt.sample("dithen_reclamations_by_pool", &[("pool", &pool.to_string())], *n as f64);
        }
        pt.scalar(
            "dithen_unfulfilled_requests",
            "counter",
            "Instance requests the provider could not fill.",
            m.unfulfilled_requests as f64,
        );
        pt.scalar(
            "dithen_ticks",
            "counter",
            "Monitoring instants accounted (executed + skipped).",
            m.ticks as f64,
        );
        pt.scalar(
            "dithen_ticks_skipped",
            "counter",
            "Monitoring instants fast-forwarded by the sparse-tick skipper.",
            m.ticks_skipped as f64,
        );
        pt.scalar(
            "dithen_total_cost_usd",
            "counter",
            "Cumulative billed cost.",
            p.backend.total_cost(),
        );
        let fleet = p.backend.describe(now);
        pt.family("dithen_fleet_instances", "gauge", "Instances by lifecycle state.");
        pt.sample("dithen_fleet_instances", &[("state", "booting")], fleet.booting as f64);
        pt.sample("dithen_fleet_instances", &[("state", "running")], fleet.running as f64);
        pt.sample("dithen_fleet_instances", &[("state", "draining")], fleet.draining as f64);
        pt.scalar(
            "dithen_fleet_active_cus",
            "gauge",
            "Active compute units (running + draining).",
            fleet.active_cus,
        );
        pt.scalar(
            "dithen_fleet_committed_cus",
            "gauge",
            "Committed compute units (active + booting).",
            fleet.committed_cus,
        );
        pt.into_string()
    }
}
