//! Request routing and wire-format helpers for `dithen serve` (PR-7):
//! the thin layer between the transport ([`super::http`]) and the
//! daemon's command loop ([`super::daemon`]).
//!
//! Submission parameters travel in the query string (`POST
//! /submit?app=face-detection&tasks=50&at=60`) rather than a JSON body
//! — every parameter is a scalar, so the query string is the simplest
//! thing that a shell one-liner, the CI smoke step, and the parity
//! test can all produce identically. Responses are JSON, hand-rendered
//! with [`json_escape`] for the few string fields.

use super::http::HttpError;
use crate::workload::{App, APP_MODELS};

/// The daemon's endpoint surface.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// `GET /healthz` — liveness, always 200 while the daemon runs.
    Healthz,
    /// `GET /metrics` — Prometheus text exposition.
    Metrics,
    /// `GET /events` — SSE stream of cloud events and tick summaries.
    Events,
    /// `GET /status/{workload}` — task DB shard counters.
    Status(usize),
    /// `POST /submit` — inject a workload into the live scenario.
    Submit,
    /// `POST /advance` — drive the scripted clock (scripted mode only).
    Advance,
    /// `POST /shutdown` — graceful drain and finalize.
    Shutdown,
}

/// Map (method, path) to a route; wrong method on a known path is 405,
/// unknown paths are 404, a non-numeric workload id is 400.
pub fn route(method: &str, path: &str) -> Result<Route, HttpError> {
    let known_get = ["/healthz", "/metrics", "/events"];
    let known_post = ["/submit", "/advance", "/shutdown"];
    match (method, path) {
        ("GET", "/healthz") => Ok(Route::Healthz),
        ("GET", "/metrics") => Ok(Route::Metrics),
        ("GET", "/events") => Ok(Route::Events),
        ("POST", "/submit") => Ok(Route::Submit),
        ("POST", "/advance") => Ok(Route::Advance),
        ("POST", "/shutdown") => Ok(Route::Shutdown),
        _ => {
            if let Some(rest) = path.strip_prefix("/status/") {
                if method != "GET" {
                    return Err(HttpError::new(405, "method not allowed"));
                }
                return rest
                    .parse::<usize>()
                    .map(Route::Status)
                    .map_err(|_| HttpError::new(400, "bad workload id"));
            }
            if known_get.contains(&path) || known_post.contains(&path) {
                Err(HttpError::new(405, "method not allowed"))
            } else {
                Err(HttpError::new(404, "no such route"))
            }
        }
    }
}

fn hex_val(b: u8) -> Option<u8> {
    match b {
        b'0'..=b'9' => Some(b - b'0'),
        b'a'..=b'f' => Some(b - b'a' + 10),
        b'A'..=b'F' => Some(b - b'A' + 10),
        _ => None,
    }
}

/// Decode `%XX` escapes and `+`-as-space. A malformed escape passes
/// through literally rather than erroring — query parsing never fails.
fn pct_decode(s: &str) -> String {
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' if i + 2 < b.len() => match (hex_val(b[i + 1]), hex_val(b[i + 2])) {
                (Some(hi), Some(lo)) => {
                    out.push(hi << 4 | lo);
                    i += 3;
                }
                _ => {
                    out.push(b'%');
                    i += 1;
                }
            },
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Split a raw query string into decoded key/value pairs. Keys without
/// `=` get an empty value; empty segments are dropped.
pub fn parse_query(q: &str) -> Vec<(String, String)> {
    q.split('&')
        .filter(|s| !s.is_empty())
        .map(|pair| {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            (pct_decode(k), pct_decode(v))
        })
        .collect()
}

/// First value for `key` among parsed query params.
pub fn query_get<'a>(params: &'a [(String, String)], key: &str) -> Option<&'a str> {
    params.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

/// Escape a string for embedding in a JSON double-quoted literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Resolve an application by its canonical model name
/// (`face-detection`, `transcode`, …) — the same labels the CLI and
/// the paper's §V use.
pub fn parse_app(name: &str) -> Option<App> {
    APP_MODELS.iter().find(|m| m.name == name).map(|m| m.app)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_dispatch_by_method_and_path() {
        assert_eq!(route("GET", "/healthz"), Ok(Route::Healthz));
        assert_eq!(route("GET", "/metrics"), Ok(Route::Metrics));
        assert_eq!(route("GET", "/events"), Ok(Route::Events));
        assert_eq!(route("POST", "/submit"), Ok(Route::Submit));
        assert_eq!(route("POST", "/advance"), Ok(Route::Advance));
        assert_eq!(route("POST", "/shutdown"), Ok(Route::Shutdown));
        assert_eq!(route("GET", "/status/7"), Ok(Route::Status(7)));
        // wrong method on a known path -> 405
        assert_eq!(route("POST", "/healthz").unwrap_err().status, 405);
        assert_eq!(route("GET", "/submit").unwrap_err().status, 405);
        assert_eq!(route("POST", "/status/7").unwrap_err().status, 405);
        // unknown path -> 404, junk id -> 400
        assert_eq!(route("GET", "/nope").unwrap_err().status, 404);
        assert_eq!(route("GET", "/status/abc").unwrap_err().status, 400);
        assert_eq!(route("GET", "/status/").unwrap_err().status, 400);
    }

    #[test]
    fn query_strings_decode_percent_and_plus() {
        let p = parse_query("app=face-detection&tasks=50&note=a+b%20c&flag");
        assert_eq!(query_get(&p, "app"), Some("face-detection"));
        assert_eq!(query_get(&p, "tasks"), Some("50"));
        assert_eq!(query_get(&p, "note"), Some("a b c"));
        assert_eq!(query_get(&p, "flag"), Some(""));
        assert_eq!(query_get(&p, "absent"), None);
        // malformed escapes pass through instead of erroring
        let p = parse_query("x=%zz&y=%2");
        assert_eq!(query_get(&p, "x"), Some("%zz"));
        assert_eq!(query_get(&p, "y"), Some("%2"));
        assert!(parse_query("").is_empty());
    }

    #[test]
    fn json_escape_covers_quotes_and_controls() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("l1\nl2\tt"), "l1\\nl2\\tt");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn apps_parse_by_model_name() {
        assert_eq!(parse_app("face-detection"), Some(App::FaceDetection));
        assert_eq!(parse_app("transcode"), Some(App::Transcode));
        assert_eq!(parse_app("word-histogram"), Some(App::WordHistogram));
        assert_eq!(parse_app("not-an-app"), None);
    }
}
