//! Scalar Kalman CUS estimator (Dithen eqs. 4–9) — pure-rust reference.
//!
//! This is the bit-exact CPU twin of the Pallas kernel in
//! `python/compile/kernels/kalman.py`: the estimator bank's XLA backend is
//! validated against this implementation in `estimation::bank` tests, and
//! it serves as the fallback backend when artifacts are absent.
//!
//! Paper initialization (§II-E-3): `b̂[0] = π[0] = 0`, σ_z² = σ_v² = 0.5,
//! and the filter is seeded with the footprinting measurement b̃[0].

/// One scalar Kalman filter state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Kalman {
    /// Current CUS estimate b̂.
    pub b_hat: f64,
    /// Error covariance π.
    pub pi: f64,
    /// Process noise σ_z².
    pub sigma_z2: f64,
    /// Measurement noise σ_v².
    pub sigma_v2: f64,
    /// Last measurement b̃ (the paper's update uses b̃[t-1]).
    pub last_meas: Option<f64>,
}

impl Kalman {
    /// Paper initialization.
    pub fn new(sigma_z2: f64, sigma_v2: f64) -> Self {
        Kalman { b_hat: 0.0, pi: 0.0, sigma_z2, sigma_v2, last_meas: None }
    }

    /// Seed with the footprinting measurement b̃[0] (§II-E-3 init).
    pub fn seed(&mut self, b_tilde0: f64) {
        self.last_meas = Some(b_tilde0);
    }

    /// One monitoring-instant update. `meas` is the new measurement (None
    /// = no tasks of this type completed in the interval: time update
    /// only). Returns the new estimate.
    pub fn update(&mut self, meas: Option<f64>) -> f64 {
        let pi_minus = self.pi + self.sigma_z2; // eq. (6)
        match meas.or(self.last_meas) {
            Some(b_tilde) => {
                let kappa = pi_minus / (pi_minus + self.sigma_v2); // eq. (7)
                self.b_hat += kappa * (b_tilde - self.b_hat); // eq. (8)
                self.pi = (1.0 - kappa) * pi_minus; // eq. (9)
            }
            None => {
                self.pi = pi_minus;
            }
        }
        if meas.is_some() {
            self.last_meas = meas;
        }
        self.b_hat
    }

    /// Kalman gain that the *next* measurement update would use.
    pub fn next_gain(&self) -> f64 {
        let pi_minus = self.pi + self.sigma_z2;
        pi_minus / (pi_minus + self.sigma_v2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn converges_to_constant_signal() {
        let mut k = Kalman::new(0.5, 0.5);
        k.seed(10.0);
        for _ in 0..60 {
            k.update(Some(10.0));
        }
        assert!((k.b_hat - 10.0).abs() < 1e-6);
    }

    #[test]
    fn paper_init_starts_at_zero() {
        let k = Kalman::new(0.5, 0.5);
        assert_eq!(k.b_hat, 0.0);
        assert_eq!(k.pi, 0.0);
    }

    #[test]
    fn first_update_moves_halfway_with_paper_sigmas() {
        // pi_minus = 0.5, kappa = 0.5/(0.5+0.5) = 0.5
        let mut k = Kalman::new(0.5, 0.5);
        k.seed(8.0);
        let b = k.update(Some(8.0));
        assert!((b - 4.0).abs() < 1e-12);
    }

    #[test]
    fn no_measurement_keeps_estimate_grows_uncertainty() {
        let mut k = Kalman::new(0.5, 0.5);
        k.seed(5.0);
        k.update(Some(5.0));
        let (b0, pi0) = (k.b_hat, k.pi);
        // paper semantics: with no fresh measurement the last one is
        // reused; to test the pure time update, clear it.
        k.last_meas = None;
        k.update(None);
        assert_eq!(k.b_hat, b0);
        assert!((k.pi - (pi0 + 0.5)).abs() < 1e-12);
    }

    #[test]
    fn gain_always_in_unit_interval() {
        forall(
            "kalman-gain-bounds",
            0xA1,
            300,
            |r| {
                let mut k = Kalman::new(r.uniform(1e-3, 5.0), r.uniform(1e-3, 5.0));
                k.seed(r.uniform(0.0, 100.0));
                for _ in 0..r.int(0, 20) {
                    k.update(Some(r.uniform(0.0, 100.0)));
                }
                k
            },
            |k| {
                let g = k.next_gain();
                if (0.0..=1.0).contains(&g) { Ok(()) } else { Err(format!("gain {g}")) }
            },
        );
    }

    #[test]
    fn estimate_stays_between_running_min_max_of_inputs() {
        forall(
            "kalman-bounded-by-observations",
            0xA2,
            200,
            |r| {
                let n = r.int(1, 30) as usize;
                let xs: Vec<f64> = (0..n).map(|_| r.uniform(1.0, 100.0)).collect();
                xs
            },
            |xs| {
                let mut k = Kalman::new(0.5, 0.5);
                k.seed(xs[0]);
                for &x in xs {
                    k.update(Some(x));
                }
                let lo = 0.0; // estimate starts at 0 and approaches data
                let hi = xs.iter().cloned().fold(0.0, f64::max) + 1e-9;
                if k.b_hat >= lo && k.b_hat <= hi {
                    Ok(())
                } else {
                    Err(format!("b_hat {} outside [0, {hi}]", k.b_hat))
                }
            },
        );
    }

    #[test]
    fn covariance_converges_to_fixed_point() {
        // steady-state pi* solves pi = (1-k)(pi+q), k=(pi+q)/(pi+q+r)
        let mut k = Kalman::new(0.5, 0.5);
        k.seed(1.0);
        for _ in 0..200 {
            k.update(Some(1.0));
        }
        let pi_star = k.pi;
        k.update(Some(1.0));
        assert!((k.pi - pi_star).abs() < 1e-10);
    }
}
