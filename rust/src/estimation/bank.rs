//! The estimator bank: all W×K Kalman CUS estimators updated in one shot
//! per monitoring instant, together with eqs. (1), (11)–(14) and the AIMD
//! decision — i.e. the full numeric tick of the GCI.
//!
//! Two interchangeable backends:
//!  * [`Backend::Xla`] — executes the AOT-compiled Pallas/JAX artifact
//!    through PJRT ([`crate::runtime::Engine`]); the production hot path.
//!  * [`Backend::Native`] — a bit-faithful f32 rust implementation; the
//!    fallback when artifacts are absent, and the cross-check oracle.
//!
//! The parity test at the bottom asserts both backends agree to f32
//! round-off on random states.

use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::runtime::{Engine, StepInputs, StepOutputs, N_PARAMS};

/// A compiled PJRT engine shared between banks: sweep cells with the
/// same (W, K) artifact shape reuse one executable instead of loading
/// and compiling it per cell (see [`super::cache::BankCache`]). The
/// `RwLock` exists for lazy per-shape *compilation* only — the one
/// write lock per shape inserts the executable, after which every
/// concurrent `monitor_step` execution runs under a **read** lock
/// ([`Engine::compiled`] + `Executable::run(&self)`), so same-shape
/// cells on different sweep workers never serialize the hot path.
pub type SharedEngine = Arc<RwLock<Engine>>;

/// Scalar knobs of the bank (mirrors PARAMS_LAYOUT in model.py minus
/// n_tot, which varies per tick).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BankParams {
    pub sigma_z2: f32,
    pub sigma_v2: f32,
    pub alpha: f32,
    pub beta: f32,
    pub n_min: f32,
    pub n_max: f32,
    pub n_w_max: f32,
}

impl BankParams {
    pub fn from_config(c: &crate::config::ControlCfg) -> Self {
        BankParams {
            sigma_z2: c.sigma_z2 as f32,
            sigma_v2: c.sigma_v2 as f32,
            alpha: c.alpha as f32,
            beta: c.beta as f32,
            n_min: c.n_min as f32,
            n_max: c.n_max as f32,
            n_w_max: c.n_w_max as f32,
        }
    }

    /// The artifact's parameter vector for one execution — the single
    /// encoding of PARAMS_LAYOUT (model.py order) shared by the
    /// per-cell and the batched XLA paths, so a layout change can never
    /// drift between them.
    fn to_array(self, n_tot: f32) -> [f32; N_PARAMS] {
        [
            self.sigma_z2,
            self.sigma_v2,
            n_tot,
            self.alpha,
            self.beta,
            self.n_min,
            self.n_max,
            self.n_w_max,
        ]
    }
}

/// Which compute backend the bank uses. `Clone` hands out another
/// reference to the same shared engine (never a recompilation) — the
/// bank *cache* relies on this to mint per-run banks from one cached
/// backend selection.
#[derive(Clone)]
pub enum Backend {
    Native,
    Xla(SharedEngine),
}

impl Backend {
    /// Wrap an owned engine for (potential) sharing.
    pub fn xla(engine: Engine) -> Backend {
        Backend::Xla(Arc::new(RwLock::new(engine)))
    }
}

/// Acquire a read guard on `engine` with the (w, k) executable
/// compiled — the one copy of the compile-resolution protocol shared
/// by the per-cell and the batched step: fast path is a read lock on
/// an already-compiled shape; otherwise a write lock compiles it once
/// and the loop re-checks (a racing compiler's work is observed, never
/// repeated).
fn compiled_read_guard(
    engine: &SharedEngine,
    w: usize,
    k: usize,
) -> Result<std::sync::RwLockReadGuard<'_, Engine>> {
    Ok(loop {
        let g = engine.read().expect("bank engine lock poisoned");
        if g.compiled(w, k).is_some() {
            break g;
        }
        drop(g);
        let mut g = engine.write().expect("bank engine lock poisoned");
        g.executable(w, k)?;
    })
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Native => write!(f, "Native"),
            Backend::Xla(_) => write!(f, "Xla"),
        }
    }
}

/// Per-tick inputs that vary (everything except the persistent state).
#[derive(Debug, Clone)]
pub struct TickInputs<'a> {
    pub b_tilde: &'a [f32],
    pub meas_mask: &'a [f32],
    pub m_rem: &'a [f32],
    pub slot_mask: &'a [f32],
    pub d: &'a [f32],
    pub n_tot: f32,
}

/// The estimator bank.
#[derive(Debug)]
pub struct Bank {
    pub w: usize,
    pub k: usize,
    pub params: BankParams,
    backend: Backend,
    b_hat: Vec<f32>,
    pi: Vec<f32>,
}

impl Bank {
    pub fn new(w: usize, k: usize, params: BankParams, backend: Backend) -> Self {
        Bank { w, k, params, backend, b_hat: vec![0.0; w * k], pi: vec![0.0; w * k] }
    }

    /// Try to build an XLA-backed bank; fall back to native (and report
    /// which) if artifacts are missing. One-off, uncached construction
    /// over the same selection logic the [`super::cache::BankCache`]
    /// uses (`cache::resolve` — shared so the two can never drift).
    pub fn with_best_backend(
        w: usize,
        k: usize,
        params: BankParams,
        artifacts_dir: &std::path::Path,
        prefer_xla: bool,
    ) -> (Self, &'static str) {
        let v = super::cache::resolve(w, k, params, artifacts_dir, prefer_xla);
        (v.instantiate(), v.backend_name())
    }

    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Native => "native",
            Backend::Xla(_) => "xla",
        }
    }

    /// Direct (mutable) access to the persistent estimates — used when a
    /// workload slot is freed/reused.
    pub fn reset_slot(&mut self, w: usize, k: usize) {
        let idx = w * self.k + k;
        self.b_hat[idx] = 0.0;
        self.pi[idx] = 0.0;
    }

    /// Widen the bank to `new_w` workload rows, appending zeroed
    /// estimator state (PR-7: `dithen serve` admits workloads into a
    /// *live* platform, so the bank must grow mid-run). Appended rows
    /// are bitwise-neutral until their workload arrives: every stage of
    /// [`native_step_slices`] reduces per row except the n* sum, which
    /// accumulates in row order — a trailing masked row contributes an
    /// exact `+0.0` tail term, so rows `0..w` step to the same bits a
    /// narrower bank would produce. The XLA backend compiles a fixed
    /// (W, K) executable and offers no such guarantee; growth there is
    /// rejected rather than silently re-shaped.
    pub fn grow_w(&mut self, new_w: usize) -> Result<()> {
        anyhow::ensure!(new_w >= self.w, "bank cannot shrink ({} -> {new_w})", self.w);
        anyhow::ensure!(
            matches!(self.backend, Backend::Native),
            "mid-run bank growth requires the native backend (xla executables are shape-compiled)"
        );
        self.b_hat.resize(new_w * self.k, 0.0);
        self.pi.resize(new_w * self.k, 0.0);
        self.w = new_w;
        Ok(())
    }

    /// Drop the estimator row at `lane`, shifting every higher row down
    /// one slot and zeroing the vacated trailing row (PR-8: shard
    /// retirement recycles bank lanes instead of growing without
    /// bound, so `w` tracks the *peak live window*, not the run). The
    /// compaction is bitwise-safe: every per-row stage reduces within
    /// its own row, and the one cross-row fold — the n* sum — runs in
    /// ascending row order over active rows with masked rows
    /// contributing an exact `+0.0`, so the compacted bank steps live
    /// rows to the same bits the sparser layout would. Native-only for
    /// the same reason as [`Self::grow_w`].
    pub fn retire_lane(&mut self, lane: usize) -> Result<()> {
        anyhow::ensure!(lane < self.w, "retire_lane {lane} out of range (w = {})", self.w);
        anyhow::ensure!(
            matches!(self.backend, Backend::Native),
            "lane retirement requires the native backend (xla executables are shape-compiled)"
        );
        let k = self.k;
        let end = self.w * k;
        self.b_hat.copy_within((lane + 1) * k..end, lane * k);
        self.pi.copy_within((lane + 1) * k..end, lane * k);
        self.b_hat[end - k..end].fill(0.0);
        self.pi[end - k..end].fill(0.0);
        Ok(())
    }

    pub fn b_hat(&self) -> &[f32] {
        &self.b_hat
    }

    pub fn pi(&self) -> &[f32] {
        &self.pi
    }

    pub fn estimate(&self, w: usize, k: usize) -> f32 {
        self.b_hat[w * self.k + k]
    }

    /// One monitoring-instant update; persists b_hat/pi internally and
    /// returns the derived quantities. Allocating convenience over
    /// [`Self::step_into`].
    pub fn step(&mut self, inp: &TickInputs) -> Result<StepOutputs> {
        let mut out = StepOutputs::default();
        self.step_into(inp, &mut out)?;
        Ok(out)
    }

    /// One monitoring-instant update writing into caller-owned output
    /// buffers. On the native backend this performs **zero heap
    /// allocation** once `out` has been through one step (buffers are
    /// resized on first use, then refilled in place) — the GCI reuses
    /// one `StepOutputs` across all ticks.
    pub fn step_into(&mut self, inp: &TickInputs, out: &mut StepOutputs) -> Result<()> {
        let wk = self.w * self.k;
        anyhow::ensure!(inp.b_tilde.len() == wk, "b_tilde size");
        anyhow::ensure!(inp.meas_mask.len() == wk, "meas_mask size");
        anyhow::ensure!(inp.m_rem.len() == wk, "m_rem size");
        anyhow::ensure!(inp.slot_mask.len() == wk, "slot_mask size");
        anyhow::ensure!(inp.d.len() == self.w, "d size");
        match &mut self.backend {
            Backend::Native => {
                native_step_into(self.w, self.k, &self.b_hat, &self.pi, inp, &self.params, out);
            }
            Backend::Xla(engine) => {
                // fast path: the shape is compiled — execute under a
                // read lock so concurrent same-engine banks don't
                // serialize (see `compiled_read_guard`).
                let guard = compiled_read_guard(engine, self.w, self.k)?;
                let exe = guard
                    .compiled(self.w, self.k)
                    .expect("executable compiled by compiled_read_guard");
                *out = exe.run(&StepInputs {
                    b_hat: &self.b_hat,
                    pi: &self.pi,
                    b_tilde: inp.b_tilde,
                    meas_mask: inp.meas_mask,
                    m_rem: inp.m_rem,
                    slot_mask: inp.slot_mask,
                    d: inp.d,
                    params: self.params.to_array(inp.n_tot),
                })?;
            }
        }
        self.b_hat.copy_from_slice(&out.b_hat);
        self.pi.copy_from_slice(&out.pi);
        Ok(())
    }

    /// One lockstep batch step: advance every lane gathered into
    /// `batch` — all cells of one (W, K) bank shape — through a single
    /// call, instead of one `step_into` per cell (PR-5; see
    /// [`BatchScratch`] for the layout). `self` is the *template* bank
    /// of the batch: it contributes the shape, the params and the
    /// backend (for XLA, the shared engine); per-lane estimator state
    /// travels in the batch scratch, gathered from and scattered back
    /// to each cell's own bank.
    ///
    /// Backends:
    /// * **Native** — the padded lanes are processed back-to-back
    ///   through the one [`native_step_slices`] kernel, so the batched
    ///   path is bit-identical to N per-cell `step_into` calls by
    ///   construction (and the contiguous `[N, W*K]` layout keeps the
    ///   whole batch's working set cache-resident across lanes).
    /// * **XLA** — the engine read lock is taken **once** for the whole
    ///   batch (amortizing the per-step lock acquisition and executable
    ///   lookup of the per-cell path) and each lane runs the compiled
    ///   (W, K) executable. The lanes are *not* row-concatenated into
    ///   one [N·W, K] execution: the (11)–(14) reductions (n*, the
    ///   rate rescale) sum over **all** rows of an execution, so
    ///   concatenation would couple independent cells through n* — a
    ///   genuine single-dispatch batch needs a batch-dimension
    ///   artifact variant ([N, W, K] inputs, per-cell reductions,
    ///   n_tot[N] params) from python/compile, which slots in behind
    ///   this same call once the manifest carries one.
    pub fn step_batch_into(&self, batch: &mut BatchScratch) -> Result<()> {
        anyhow::ensure!(
            batch.w == self.w && batch.k == self.k,
            "batch shape ({}, {}) does not match template bank ({}, {})",
            batch.w,
            batch.k,
            self.w,
            self.k
        );
        let wk = self.w * self.k;
        let (w, k, n) = (batch.w, batch.k, batch.n);
        match &self.backend {
            Backend::Native => {
                for lane in 0..n {
                    let inp = TickInputs {
                        b_tilde: &batch.b_tilde[lane * wk..][..wk],
                        meas_mask: &batch.meas_mask[lane * wk..][..wk],
                        m_rem: &batch.m_rem[lane * wk..][..wk],
                        slot_mask: &batch.slot_mask[lane * wk..][..wk],
                        d: &batch.d[lane * w..][..w],
                        n_tot: batch.n_tot[lane],
                    };
                    let (n_star, n_next) = native_step_slices(
                        w,
                        k,
                        &batch.b_hat[lane * wk..][..wk],
                        &batch.pi[lane * wk..][..wk],
                        &inp,
                        &self.params,
                        SliceOutputs {
                            b_hat: &mut batch.out_b_hat[lane * wk..][..wk],
                            pi: &mut batch.out_pi[lane * wk..][..wk],
                            r: &mut batch.out_r[lane * w..][..w],
                            s: &mut batch.out_s[lane * w..][..w],
                        },
                    );
                    batch.out_n_star[lane] = n_star;
                    batch.out_n_next[lane] = n_next;
                }
            }
            Backend::Xla(engine) => {
                // one read-lock acquisition for the whole batch (the
                // same compile-resolution protocol as step_into)
                let guard = compiled_read_guard(engine, w, k)?;
                let exe = guard
                    .compiled(w, k)
                    .expect("executable compiled by compiled_read_guard");
                for lane in 0..n {
                    let params = self.params.to_array(batch.n_tot[lane]);
                    let o = exe.run(&StepInputs {
                        b_hat: &batch.b_hat[lane * wk..][..wk],
                        pi: &batch.pi[lane * wk..][..wk],
                        b_tilde: &batch.b_tilde[lane * wk..][..wk],
                        meas_mask: &batch.meas_mask[lane * wk..][..wk],
                        m_rem: &batch.m_rem[lane * wk..][..wk],
                        slot_mask: &batch.slot_mask[lane * wk..][..wk],
                        d: &batch.d[lane * w..][..w],
                        params,
                    })?;
                    batch.out_b_hat[lane * wk..][..wk].copy_from_slice(&o.b_hat);
                    batch.out_pi[lane * wk..][..wk].copy_from_slice(&o.pi);
                    batch.out_r[lane * w..][..w].copy_from_slice(&o.r);
                    batch.out_s[lane * w..][..w].copy_from_slice(&o.s);
                    batch.out_n_star[lane] = o.n_star;
                    batch.out_n_next[lane] = o.n_next;
                }
            }
        }
        Ok(())
    }
}

/// Padded gather/scatter scratch for one lockstep batch of same-shape
/// cells (PR-5): per-lane bank state and tick inputs land in dense
/// row-major `[N, W*K]` / `[N, W]` / `[N]` arrays, one
/// [`Bank::step_batch_into`] advances every lane, and per-lane outputs
/// scatter back into each cell's own [`Bank`] / `StepOutputs`.
///
/// Sized once per (capacity, W, K) by [`BatchScratch::begin`] and then
/// only refilled — the steady-state gather → step → scatter round
/// performs **zero heap allocations** (pinned alongside the per-cell
/// contract in `tests/alloc_steady_state.rs`).
#[derive(Debug, Default)]
pub struct BatchScratch {
    /// Lanes gathered since the last `begin`.
    n: usize,
    /// Lane capacity the buffers are sized for.
    cap: usize,
    w: usize,
    k: usize,
    // per-lane persistent state gathered from each cell's bank
    b_hat: Vec<f32>,
    pi: Vec<f32>,
    // per-lane tick inputs
    b_tilde: Vec<f32>,
    meas_mask: Vec<f32>,
    m_rem: Vec<f32>,
    slot_mask: Vec<f32>,
    d: Vec<f32>,
    n_tot: Vec<f32>,
    // per-lane step outputs (filled by `Bank::step_batch_into`)
    out_b_hat: Vec<f32>,
    out_pi: Vec<f32>,
    out_r: Vec<f32>,
    out_s: Vec<f32>,
    out_n_star: Vec<f32>,
    out_n_next: Vec<f32>,
}

impl BatchScratch {
    /// Start a new lockstep round: size every buffer for up to `cap`
    /// lanes of shape (w, k) and reset the lane count. Re-sizing to the
    /// shape already held is a no-op (no allocation).
    pub fn begin(&mut self, cap: usize, w: usize, k: usize) {
        let wk = w * k;
        self.b_hat.resize(cap * wk, 0.0);
        self.pi.resize(cap * wk, 0.0);
        self.b_tilde.resize(cap * wk, 0.0);
        self.meas_mask.resize(cap * wk, 0.0);
        self.m_rem.resize(cap * wk, 0.0);
        self.slot_mask.resize(cap * wk, 0.0);
        self.d.resize(cap * w, 0.0);
        self.n_tot.resize(cap, 0.0);
        self.out_b_hat.resize(cap * wk, 0.0);
        self.out_pi.resize(cap * wk, 0.0);
        self.out_r.resize(cap * w, 0.0);
        self.out_s.resize(cap * w, 0.0);
        self.out_n_star.resize(cap, 0.0);
        self.out_n_next.resize(cap, 0.0);
        self.cap = cap;
        self.w = w;
        self.k = k;
        self.n = 0;
    }

    /// Gather one cell into the next free lane: its bank's persistent
    /// `b_hat`/`pi` plus this tick's inputs. Returns the lane index.
    /// Input sizes are validated exactly like [`Bank::step_into`].
    pub fn gather(&mut self, bank: &Bank, inp: &TickInputs) -> Result<usize> {
        anyhow::ensure!(
            bank.w == self.w && bank.k == self.k,
            "cell bank ({}, {}) does not match batch shape ({}, {})",
            bank.w,
            bank.k,
            self.w,
            self.k
        );
        anyhow::ensure!(self.n < self.cap, "batch is full ({} lanes)", self.cap);
        let wk = self.w * self.k;
        anyhow::ensure!(inp.b_tilde.len() == wk, "b_tilde size");
        anyhow::ensure!(inp.meas_mask.len() == wk, "meas_mask size");
        anyhow::ensure!(inp.m_rem.len() == wk, "m_rem size");
        anyhow::ensure!(inp.slot_mask.len() == wk, "slot_mask size");
        anyhow::ensure!(inp.d.len() == self.w, "d size");
        let lane = self.n;
        self.b_hat[lane * wk..][..wk].copy_from_slice(&bank.b_hat);
        self.pi[lane * wk..][..wk].copy_from_slice(&bank.pi);
        self.b_tilde[lane * wk..][..wk].copy_from_slice(inp.b_tilde);
        self.meas_mask[lane * wk..][..wk].copy_from_slice(inp.meas_mask);
        self.m_rem[lane * wk..][..wk].copy_from_slice(inp.m_rem);
        self.slot_mask[lane * wk..][..wk].copy_from_slice(inp.slot_mask);
        self.d[lane * self.w..][..self.w].copy_from_slice(inp.d);
        self.n_tot[lane] = inp.n_tot;
        self.n = lane + 1;
        Ok(lane)
    }

    /// Lanes gathered since the last [`Self::begin`].
    pub fn lanes(&self) -> usize {
        self.n
    }

    /// Scatter one lane's step results back into its cell: refill the
    /// cell's `StepOutputs` (resized on first use, then in place —
    /// the same contract as [`Bank::step_into`]) and persist the new
    /// `b_hat`/`pi` into the cell's bank.
    pub fn scatter(&self, lane: usize, bank: &mut Bank, out: &mut StepOutputs) {
        assert!(lane < self.n, "lane {lane} was never gathered (n = {})", self.n);
        assert!(
            bank.w == self.w && bank.k == self.k,
            "cell bank ({}, {}) does not match batch shape ({}, {})",
            bank.w,
            bank.k,
            self.w,
            self.k
        );
        let wk = self.w * self.k;
        out.b_hat.resize(wk, 0.0);
        out.pi.resize(wk, 0.0);
        out.r.resize(self.w, 0.0);
        out.s.resize(self.w, 0.0);
        out.b_hat.copy_from_slice(&self.out_b_hat[lane * wk..][..wk]);
        out.pi.copy_from_slice(&self.out_pi[lane * wk..][..wk]);
        out.r.copy_from_slice(&self.out_r[lane * self.w..][..self.w]);
        out.s.copy_from_slice(&self.out_s[lane * self.w..][..self.w]);
        out.n_star = self.out_n_star[lane];
        out.n_next = self.out_n_next[lane];
        bank.b_hat.copy_from_slice(&out.b_hat);
        bank.pi.copy_from_slice(&out.pi);
    }
}

/// The native (rust, f32) implementation of the monitor_step graph —
/// mirrors python/compile/model.py operation for operation. Allocating
/// convenience over [`native_step_into`].
pub fn native_step(
    w: usize,
    k: usize,
    b_hat: &[f32],
    pi: &[f32],
    inp: &TickInputs,
    p: &BankParams,
) -> StepOutputs {
    let mut out = StepOutputs::default();
    native_step_into(w, k, b_hat, pi, inp, p, &mut out);
    out
}

/// Caller-owned output slices of one monitor-step kernel invocation.
/// Borrowed views so the same kernel serves both the `Vec`-backed
/// per-cell path ([`native_step_into`]) and one lane of the padded
/// lockstep batch ([`Bank::step_batch_into`]).
struct SliceOutputs<'a> {
    b_hat: &'a mut [f32],
    pi: &'a mut [f32],
    r: &'a mut [f32],
    s: &'a mut [f32],
}

/// The monitor-step math on borrowed slices; returns `(n_star,
/// n_next)`. This is the **one** copy of the native kernel — the
/// per-cell and the batched paths both call it, so the two can never
/// diverge numerically (the lockstep determinism pin in
/// `tests/determinism.rs` rests on this).
fn native_step_slices(
    w: usize,
    k: usize,
    b_hat: &[f32],
    pi: &[f32],
    inp: &TickInputs,
    p: &BankParams,
    out: SliceOutputs<'_>,
) -> (f32, f32) {
    let wk = w * k;
    // 1. masked Kalman update (eqs. 6-9), inert outside slot_mask —
    // the element-wise stage, vectorized (PR-6)
    kalman_update_simd(
        &b_hat[..wk],
        &pi[..wk],
        &inp.b_tilde[..wk],
        &inp.meas_mask[..wk],
        &inp.slot_mask[..wk],
        p,
        &mut out.b_hat[..wk],
        &mut out.pi[..wk],
    );
    // 2. r_w = sum_k m*mask*b (eq. 1)
    for wi in 0..w {
        let mut acc = 0.0f32;
        for ki in 0..k {
            let i = wi * k + ki;
            acc += inp.m_rem[i] * inp.slot_mask[i] * out.b_hat[i];
        }
        out.r[wi] = acc;
    }
    // 3. proportional-fair service rates (eqs. 11-14)
    let mut n_star = 0.0f32;
    for wi in 0..w {
        let active = (0..k).any(|ki| inp.slot_mask[wi * k + ki] > 0.0);
        let safe_d = if inp.d[wi] > 0.0 { inp.d[wi] } else { 1.0 };
        // eq. (11) with the per-workload cap N_{w,max}
        out.s[wi] = if active { (out.r[wi] / safe_d).min(p.n_w_max) } else { 0.0 };
        n_star += out.s[wi];
    }
    let hi = inp.n_tot + p.alpha;
    let lo = p.beta * inp.n_tot;
    let denom = n_star.max(1e-30);
    let mut scale = if n_star > hi {
        hi / denom
    } else if n_star < lo {
        lo / denom
    } else {
        1.0
    };
    if n_star <= 0.0 {
        scale = 1.0;
    }
    for s in out.s.iter_mut() {
        *s *= scale;
    }
    // 4. AIMD (Fig. 4)
    let n_next = if inp.n_tot <= n_star {
        (inp.n_tot + p.alpha).min(p.n_max)
    } else {
        (p.beta * inp.n_tot).max(p.n_min)
    };
    (n_star, n_next)
}

/// [`native_step`] writing into reused output buffers: allocation-free
/// once `out` holds (w*k)/(w)-sized vectors.
pub fn native_step_into(
    w: usize,
    k: usize,
    b_hat: &[f32],
    pi: &[f32],
    inp: &TickInputs,
    p: &BankParams,
    out: &mut StepOutputs,
) {
    let wk = w * k;
    out.b_hat.resize(wk, 0.0);
    out.pi.resize(wk, 0.0);
    out.r.resize(w, 0.0);
    out.s.resize(w, 0.0);
    let (n_star, n_next) = native_step_slices(
        w,
        k,
        b_hat,
        pi,
        inp,
        p,
        SliceOutputs {
            b_hat: &mut out.b_hat,
            pi: &mut out.pi,
            r: &mut out.r,
            s: &mut out.s,
        },
    );
    out.n_star = n_star;
    out.n_next = n_next;
}

/// One element of the stage-1 masked Kalman update (eqs. 6-9). The
/// single source of the per-element arithmetic: the scalar reference
/// and the vectorized kernel both inline exactly this expression, so
/// they cannot drift (and `simd_kernel_matches_scalar` pins the
/// equality bit-for-bit anyway).
#[inline(always)]
fn kalman_cell(p: &BankParams, b_hat: f32, pi: f32, b_tilde: f32, m: f32, s: f32) -> (f32, f32) {
    let pi_minus = pi + p.sigma_z2;
    let kappa = pi_minus / (pi_minus + p.sigma_v2);
    let b_meas = b_hat + kappa * (b_tilde - b_hat);
    let pi_meas = (1.0 - kappa) * pi_minus;
    let mut b = m * b_meas + (1.0 - m) * b_hat;
    let mut pv = m * pi_meas + (1.0 - m) * pi_minus;
    b = s * b + (1.0 - s) * b_hat;
    pv = s * pv + (1.0 - s) * pi;
    (b, pv)
}

/// Scalar reference for the stage-1 masked Kalman update: one
/// [`kalman_cell`] per element, in index order. Exists so the
/// `simd_kernel_matches_scalar` pin and `bench_bank` have a
/// known-scalar baseline to hold the vectorized kernel against.
#[allow(clippy::too_many_arguments)] // mirrors the 8-plane kernel ABI; a struct would obscure it
pub fn kalman_update_scalar(
    b_hat: &[f32],
    pi: &[f32],
    b_tilde: &[f32],
    meas_mask: &[f32],
    slot_mask: &[f32],
    p: &BankParams,
    out_b: &mut [f32],
    out_pi: &mut [f32],
) {
    for i in 0..b_hat.len() {
        let (b, pv) = kalman_cell(p, b_hat[i], pi[i], b_tilde[i], meas_mask[i], slot_mask[i]);
        out_b[i] = b;
        out_pi[i] = pv;
    }
}

/// Number of f32 lanes the vectorized stage-1 kernel processes per
/// unrolled block. Eight f32s fill one AVX/AVX2 ymm register (and one
/// sublane row of a TPU VPU's 8×128 tile — the shape the XLA backend's
/// compiled kernel vectorizes to), so the unrolled block lowers to a
/// handful of whole-register ops on the targets we care about while
/// SSE/NEON simply split it into two 4-lane halves.
pub const KERNEL_LANES: usize = 8;

/// Vectorized stage-1 masked Kalman update (PR-6): the element loop of
/// [`kalman_update_scalar`] restructured into [`KERNEL_LANES`]-wide
/// unrolled blocks over `chunks_exact`, plus a scalar tail. Each lane
/// of a block evaluates the *same* [`kalman_cell`] expression on its
/// own element — no cross-lane operation, no reassociation, no FMA
/// contraction the scalar path wouldn't also do — so the result is
/// **bit-identical** to the scalar reference by construction; the
/// block structure only hands the compiler exact trip counts and
/// bounds-check-free slices so the eight independent element flows
/// lower to packed f32 arithmetic.
#[allow(clippy::too_many_arguments)] // same 8-plane signature as the scalar reference
pub fn kalman_update_simd(
    b_hat: &[f32],
    pi: &[f32],
    b_tilde: &[f32],
    meas_mask: &[f32],
    slot_mask: &[f32],
    p: &BankParams,
    out_b: &mut [f32],
    out_pi: &mut [f32],
) {
    const L: usize = KERNEL_LANES;
    let n = b_hat.len();
    let blocks = n / L;
    let split = blocks * L;
    let bh_t = &b_hat[split..];
    let pi_t = &pi[split..];
    let (ob, ob_t) = out_b.split_at_mut(split);
    let (op, op_t) = out_pi.split_at_mut(split);
    for ((((ob, op), bh), pv), ((bt, mm), sm)) in ob
        .chunks_exact_mut(L)
        .zip(op.chunks_exact_mut(L))
        .zip(b_hat[..split].chunks_exact(L))
        .zip(pi[..split].chunks_exact(L))
        .zip(
            b_tilde[..split]
                .chunks_exact(L)
                .zip(meas_mask[..split].chunks_exact(L))
                .zip(slot_mask[..split].chunks_exact(L)),
        )
    {
        for j in 0..L {
            let (b, pvx) = kalman_cell(p, bh[j], pv[j], bt[j], mm[j], sm[j]);
            ob[j] = b;
            op[j] = pvx;
        }
    }
    for j in 0..n - split {
        let (b, pvx) = kalman_cell(
            p,
            bh_t[j],
            pi_t[j],
            b_tilde[split + j],
            meas_mask[split + j],
            slot_mask[split + j],
        );
        ob_t[j] = b;
        op_t[j] = pvx;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn params() -> BankParams {
        BankParams {
            sigma_z2: 0.5,
            sigma_v2: 0.5,
            alpha: 5.0,
            beta: 0.9,
            n_min: 10.0,
            n_max: 100.0,
            n_w_max: 10.0,
        }
    }

    fn random_tick(
        w: usize,
        k: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, f32) {
        let wk = w * k;
        let slot: Vec<f32> = (0..wk).map(|_| if rng.f64() < 0.8 { 1.0 } else { 0.0 }).collect();
        let meas: Vec<f32> = (0..wk)
            .map(|i| if slot[i] > 0.0 && rng.f64() < 0.6 { 1.0 } else { 0.0 })
            .collect();
        let b_tilde: Vec<f32> = (0..wk).map(|_| rng.uniform(0.0, 300.0) as f32).collect();
        let m_rem: Vec<f32> = (0..wk).map(|_| rng.int(0, 500) as f32).collect();
        let d: Vec<f32> = (0..w).map(|_| rng.uniform(60.0, 7620.0) as f32).collect();
        let n_tot = rng.uniform(1.0, 60.0) as f32;
        (slot, meas, b_tilde, m_rem, d, n_tot)
    }

    #[test]
    fn native_bank_converges_on_constant_measurements() {
        let mut bank = Bank::new(4, 2, params(), Backend::Native);
        let wk = 8;
        let slot = vec![1.0f32; wk];
        let meas = vec![1.0f32; wk];
        let b_tilde = vec![42.0f32; wk];
        let m_rem = vec![10.0f32; wk];
        let d = vec![1000.0f32; 4];
        for _ in 0..60 {
            bank.step(&TickInputs {
                b_tilde: &b_tilde,
                meas_mask: &meas,
                m_rem: &m_rem,
                slot_mask: &slot,
                d: &d,
                n_tot: 10.0,
            })
            .unwrap();
        }
        for wi in 0..4 {
            for ki in 0..2 {
                assert!((bank.estimate(wi, ki) - 42.0).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn grown_bank_is_bitwise_equal_to_wide_bank() {
        // PR-7 pin: a bank grown mid-run must continue exactly like a
        // bank that was wide from the start (masked trailing rows are
        // bitwise-neutral) — this is what makes `dithen serve`'s
        // mid-run workload admission a bit-exact twin of the batch run.
        let k = 2;
        let mut wide = Bank::new(2, k, params(), Backend::Native);
        let mut narrow = Bank::new(1, k, params(), Backend::Native);
        let mut rng = Rng::new(0x5E7E);
        // phase 1: only row 0 live; the wide bank carries a masked row 1
        for _ in 0..5 {
            let (slot, meas, b_tilde, m_rem, d, n_tot) = random_tick(1, k, &mut rng);
            let pad = |v: &[f32]| {
                let mut p = v.to_vec();
                p.resize(2 * k, 0.0);
                p
            };
            let wide_d = vec![d[0], 0.0];
            let a = wide
                .step(&TickInputs {
                    b_tilde: &pad(&b_tilde),
                    meas_mask: &pad(&meas),
                    m_rem: &pad(&m_rem),
                    slot_mask: &pad(&slot),
                    d: &wide_d,
                    n_tot,
                })
                .unwrap();
            let b = narrow
                .step(&TickInputs {
                    b_tilde: &b_tilde,
                    meas_mask: &meas,
                    m_rem: &m_rem,
                    slot_mask: &slot,
                    d: &d,
                    n_tot,
                })
                .unwrap();
            assert_eq!(a.n_star.to_bits(), b.n_star.to_bits());
            assert_eq!(a.b_hat[..k], b.b_hat[..k]);
        }
        // grow and run both rows live with identical inputs
        narrow.grow_w(2).unwrap();
        assert_eq!(narrow.b_hat(), wide.b_hat());
        assert_eq!(narrow.pi(), wide.pi());
        for _ in 0..5 {
            let (slot, meas, b_tilde, m_rem, d, n_tot) = random_tick(2, k, &mut rng);
            let inp = TickInputs {
                b_tilde: &b_tilde,
                meas_mask: &meas,
                m_rem: &m_rem,
                slot_mask: &slot,
                d: &d,
                n_tot,
            };
            let a = wide.step(&inp).unwrap();
            let b = narrow.step(&inp).unwrap();
            assert_eq!(a.b_hat, b.b_hat);
            assert_eq!(a.pi, b.pi);
            assert_eq!(a.r, b.r);
            assert_eq!(a.s, b.s);
            assert_eq!(a.n_star.to_bits(), b.n_star.to_bits());
            assert_eq!(a.n_next.to_bits(), b.n_next.to_bits());
        }
        // shrinking is a contract violation, not a resize
        assert!(narrow.grow_w(1).is_err());
    }

    /// PR-8 pin: compacting a retired row out of the bank is bitwise
    /// neutral — the compacted bank (live rows packed low, trailing
    /// row zeroed and masked) steps to exactly the bits the wide bank
    /// produces for the same live rows with the retired row masked in
    /// place. This is what makes shard retirement invisible to the
    /// streaming==materialized twin.
    #[test]
    fn retired_lane_compaction_is_bitwise_neutral() {
        let k = 2;
        let mut rng = Rng::new(0x8E71);
        let mut masked = Bank::new(3, k, params(), Backend::Native);
        let mut compact = Bank::new(3, k, params(), Backend::Native);
        // warm both banks on identical 3-row traffic
        for _ in 0..6 {
            let (slot, meas, b_tilde, m_rem, d, n_tot) = random_tick(3, k, &mut rng);
            let inp = TickInputs {
                b_tilde: &b_tilde,
                meas_mask: &meas,
                m_rem: &m_rem,
                slot_mask: &slot,
                d: &d,
                n_tot,
            };
            masked.step(&inp).unwrap();
            compact.step(&inp).unwrap();
        }
        // retire the middle row: the masked twin zeroes it in place,
        // the compact twin shifts row 2 down into row 1
        masked.reset_slot(1, 0);
        masked.reset_slot(1, 1);
        compact.retire_lane(1).unwrap();
        let survivors =
            [masked.b_hat()[..k].to_vec(), masked.b_hat()[2 * k..].to_vec()].concat();
        assert_eq!(compact.b_hat()[..2 * k], survivors[..]);
        assert_eq!(&compact.b_hat()[2 * k..], &[0.0; 2][..], "vacated row must be zeroed");
        for _ in 0..6 {
            let (slot, meas, b_tilde, m_rem, d, n_tot) = random_tick(2, k, &mut rng);
            // masked layout: live rows 0 and 2, row 1 dead (zero masks)
            let spread = |v: &[f32]| {
                let mut s = vec![0.0f32; 3 * k];
                s[..k].copy_from_slice(&v[..k]);
                s[2 * k..].copy_from_slice(&v[k..]);
                s
            };
            let d3 = vec![d[0], 0.0, d[1]];
            let a = masked
                .step(&TickInputs {
                    b_tilde: &spread(&b_tilde),
                    meas_mask: &spread(&meas),
                    m_rem: &spread(&m_rem),
                    slot_mask: &spread(&slot),
                    d: &d3,
                    n_tot,
                })
                .unwrap();
            // compact layout: live rows 0 and 1, trailing row masked
            let pad = |v: &[f32]| {
                let mut p = v.to_vec();
                p.resize(3 * k, 0.0);
                p
            };
            let d_pad = vec![d[0], d[1], 0.0];
            let b = compact
                .step(&TickInputs {
                    b_tilde: &pad(&b_tilde),
                    meas_mask: &pad(&meas),
                    m_rem: &pad(&m_rem),
                    slot_mask: &pad(&slot),
                    d: &d_pad,
                    n_tot,
                })
                .unwrap();
            assert_eq!(a.n_star.to_bits(), b.n_star.to_bits(), "n* must survive compaction");
            assert_eq!(a.n_next.to_bits(), b.n_next.to_bits());
            assert_eq!(a.b_hat[..k], b.b_hat[..k], "row 0");
            assert_eq!(a.b_hat[2 * k..], b.b_hat[k..2 * k], "row 2 -> row 1");
            assert_eq!(a.s[0].to_bits(), b.s[0].to_bits());
            assert_eq!(a.s[2].to_bits(), b.s[1].to_bits());
        }
        // out-of-range lane is an error, not UB
        assert!(compact.retire_lane(3).is_err());
    }

    #[test]
    fn native_matches_scalar_kalman() {
        // the bank's slot (0,0) must evolve exactly like estimation::kalman
        // under the same measurement sequence (f32 vs f64 tolerance).
        let mut bank = Bank::new(2, 2, params(), Backend::Native);
        let mut scalar = crate::estimation::kalman::Kalman::new(0.5, 0.5);
        let mut rng = Rng::new(0xBEEF);
        let wk = 4;
        let mut slot = vec![0.0f32; wk];
        slot[0] = 1.0;
        let m_rem = vec![1.0f32; wk];
        let d = vec![100.0f32; 2];
        for _ in 0..30 {
            let x = rng.uniform(1.0, 50.0);
            scalar.seed(x);
            scalar.update(Some(x));
            let mut b_tilde = vec![0.0f32; wk];
            let mut meas = vec![0.0f32; wk];
            b_tilde[0] = x as f32;
            meas[0] = 1.0;
            bank.step(&TickInputs {
                b_tilde: &b_tilde,
                meas_mask: &meas,
                m_rem: &m_rem,
                slot_mask: &slot,
                d: &d,
                n_tot: 10.0,
            })
            .unwrap();
            assert!(
                (bank.estimate(0, 0) as f64 - scalar.b_hat).abs() < 1e-3,
                "bank={} scalar={}",
                bank.estimate(0, 0),
                scalar.b_hat
            );
        }
    }

    #[test]
    fn xla_and_native_backends_agree() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let (w, k) = (8, 2);
        let mut xla_bank = Bank::new(w, k, params(), Backend::xla(Engine::load(&dir).unwrap()));
        let mut nat_bank = Bank::new(w, k, params(), Backend::Native);
        assert_eq!(xla_bank.backend_name(), "xla");
        let mut rng = Rng::new(0xD17E);
        for step in 0..25 {
            let (slot, meas, b_tilde, m_rem, d, n_tot) = random_tick(w, k, &mut rng);
            let inp = TickInputs {
                b_tilde: &b_tilde,
                meas_mask: &meas,
                m_rem: &m_rem,
                slot_mask: &slot,
                d: &d,
                n_tot,
            };
            let a = xla_bank.step(&inp).unwrap();
            let b = nat_bank.step(&inp).unwrap();
            for i in 0..w * k {
                assert!(
                    (a.b_hat[i] - b.b_hat[i]).abs() <= 1e-3 * (1.0 + b.b_hat[i].abs()),
                    "step {step} b_hat[{i}]: xla={} native={}",
                    a.b_hat[i],
                    b.b_hat[i]
                );
                assert!((a.pi[i] - b.pi[i]).abs() <= 1e-4 * (1.0 + b.pi[i].abs()));
            }
            for wi in 0..w {
                assert!(
                    (a.r[wi] - b.r[wi]).abs() <= 1e-2 * (1.0 + b.r[wi].abs()),
                    "step {step} r[{wi}]: xla={} native={}",
                    a.r[wi],
                    b.r[wi]
                );
                assert!((a.s[wi] - b.s[wi]).abs() <= 1e-2 * (1.0 + b.s[wi].abs()));
            }
            assert!((a.n_star - b.n_star).abs() <= 1e-2 * (1.0 + b.n_star.abs()));
            assert!((a.n_next - b.n_next).abs() <= 1e-3 * (1.0 + b.n_next.abs()));
        }
    }

    #[test]
    fn bank_rejects_bad_sizes() {
        let mut bank = Bank::new(2, 2, params(), Backend::Native);
        let r = bank.step(&TickInputs {
            b_tilde: &[0.0; 3],
            meas_mask: &[0.0; 4],
            m_rem: &[0.0; 4],
            slot_mask: &[0.0; 4],
            d: &[0.0; 2],
            n_tot: 1.0,
        });
        assert!(r.is_err());
    }

    /// The batch-path determinism pin at the bank level: N cells
    /// driven through gather → `step_batch_into` → scatter must be
    /// bit-identical — outputs *and* persistent state — to the same
    /// cells stepped one `step_into` at a time, across many ticks and
    /// diverging per-cell input streams.
    #[test]
    fn batched_step_is_bit_identical_to_per_cell_steps() {
        let (w, k, n) = (5usize, 3usize, 6usize);
        let mut looped: Vec<Bank> =
            (0..n).map(|_| Bank::new(w, k, params(), Backend::Native)).collect();
        let mut batched: Vec<Bank> =
            (0..n).map(|_| Bank::new(w, k, params(), Backend::Native)).collect();
        let template = Bank::new(w, k, params(), Backend::Native);
        let mut batch = BatchScratch::default();
        let mut outs: Vec<StepOutputs> = (0..n).map(|_| StepOutputs::default()).collect();
        let mut rng = Rng::new(0xBA7C);
        for step in 0..30 {
            // per-cell input streams diverge (own RNG draws per cell)
            let ticks: Vec<_> = (0..n).map(|_| random_tick(w, k, &mut rng)).collect();
            batch.begin(n, w, k);
            for (i, (slot, meas, b_tilde, m_rem, d, n_tot)) in ticks.iter().enumerate() {
                let inp = TickInputs {
                    b_tilde,
                    meas_mask: meas,
                    m_rem,
                    slot_mask: slot,
                    d,
                    n_tot: *n_tot,
                };
                let lane = batch.gather(&batched[i], &inp).unwrap();
                assert_eq!(lane, i);
            }
            assert_eq!(batch.lanes(), n);
            template.step_batch_into(&mut batch).unwrap();
            for (i, (slot, meas, b_tilde, m_rem, d, n_tot)) in ticks.iter().enumerate() {
                batch.scatter(i, &mut batched[i], &mut outs[i]);
                let reference = looped[i]
                    .step(&TickInputs {
                        b_tilde,
                        meas_mask: meas,
                        m_rem,
                        slot_mask: slot,
                        d,
                        n_tot: *n_tot,
                    })
                    .unwrap();
                assert_eq!(outs[i], reference, "step {step} cell {i}: batched output diverged");
                assert_eq!(batched[i].b_hat(), looped[i].b_hat(), "step {step} cell {i}: b_hat");
                assert_eq!(batched[i].pi(), looped[i].pi(), "step {step} cell {i}: pi");
            }
        }
    }

    /// Lockstep width must not matter: one 8-lane batch and two 4-lane
    /// batches over the same cells give identical results (each lane is
    /// an independent column of the padded execution).
    #[test]
    fn batch_width_does_not_change_results() {
        let (w, k, n) = (3usize, 2usize, 8usize);
        let mut rng = Rng::new(0x51DE);
        let ticks: Vec<_> = (0..n).map(|_| random_tick(w, k, &mut rng)).collect();
        let template = Bank::new(w, k, params(), Backend::Native);
        let run_with_width = |width: usize| -> Vec<(Vec<f32>, Vec<f32>, StepOutputs)> {
            let mut banks: Vec<Bank> =
                (0..n).map(|_| Bank::new(w, k, params(), Backend::Native)).collect();
            let mut outs: Vec<StepOutputs> = (0..n).map(|_| StepOutputs::default()).collect();
            let mut batch = BatchScratch::default();
            for chunk in 0..n.div_ceil(width) {
                let lo = chunk * width;
                let hi = (lo + width).min(n);
                batch.begin(hi - lo, w, k);
                for i in lo..hi {
                    let (slot, meas, b_tilde, m_rem, d, n_tot) = &ticks[i];
                    batch
                        .gather(
                            &banks[i],
                            &TickInputs {
                                b_tilde,
                                meas_mask: meas,
                                m_rem,
                                slot_mask: slot,
                                d,
                                n_tot: *n_tot,
                            },
                        )
                        .unwrap();
                }
                template.step_batch_into(&mut batch).unwrap();
                for i in lo..hi {
                    batch.scatter(i - lo, &mut banks[i], &mut outs[i]);
                }
            }
            banks
                .iter()
                .zip(&outs)
                .map(|(b, o)| (b.b_hat().to_vec(), b.pi().to_vec(), o.clone()))
                .collect()
        };
        let full = run_with_width(n);
        for width in [1usize, 2, 4] {
            assert_eq!(run_with_width(width), full, "batch width {width} changed results");
        }
    }

    #[test]
    fn batch_rejects_shape_mismatches() {
        let template = Bank::new(2, 2, params(), Backend::Native);
        let other = Bank::new(3, 2, params(), Backend::Native);
        let mut batch = BatchScratch::default();
        batch.begin(2, 2, 2);
        // wrong-shape cell bank
        assert!(batch
            .gather(
                &other,
                &TickInputs {
                    b_tilde: &[0.0; 6],
                    meas_mask: &[0.0; 6],
                    m_rem: &[0.0; 6],
                    slot_mask: &[0.0; 6],
                    d: &[0.0; 3],
                    n_tot: 1.0,
                },
            )
            .is_err());
        // wrong-size inputs
        assert!(batch
            .gather(
                &template,
                &TickInputs {
                    b_tilde: &[0.0; 3],
                    meas_mask: &[0.0; 4],
                    m_rem: &[0.0; 4],
                    slot_mask: &[0.0; 4],
                    d: &[0.0; 2],
                    n_tot: 1.0,
                },
            )
            .is_err());
        // wrong-shape template
        assert!(other.step_batch_into(&mut batch).is_err());
        // capacity overflow
        let ok = TickInputs {
            b_tilde: &[0.0; 4],
            meas_mask: &[0.0; 4],
            m_rem: &[0.0; 4],
            slot_mask: &[0.0; 4],
            d: &[0.0; 2],
            n_tot: 1.0,
        };
        batch.gather(&template, &ok).unwrap();
        batch.gather(&template, &ok).unwrap();
        assert!(batch.gather(&template, &ok).is_err(), "third lane must overflow cap 2");
    }

    #[test]
    fn reset_slot_clears_state() {
        let mut bank = Bank::new(2, 2, params(), Backend::Native);
        let slot = vec![1.0f32; 4];
        bank.step(&TickInputs {
            b_tilde: &[5.0; 4],
            meas_mask: &[1.0; 4],
            m_rem: &[1.0; 4],
            slot_mask: &slot,
            d: &[100.0; 2],
            n_tot: 10.0,
        })
        .unwrap();
        assert!(bank.estimate(1, 1) > 0.0);
        bank.reset_slot(1, 1);
        assert_eq!(bank.estimate(1, 1), 0.0);
        assert!(bank.estimate(0, 0) > 0.0);
    }

    /// PR-6 pin: the vectorized stage-1 kernel is bit-identical to the
    /// scalar reference, and both production paths (per-cell
    /// `step_into`, batched `step_batch_into`) route through it. Exact
    /// f32 equality — shapes cover whole-block (wk % 8 == 0),
    /// tail-only (wk < 8) and mixed cases, evolving real state
    /// trajectories so the comparison isn't anchored at zero.
    #[test]
    fn simd_kernel_matches_scalar() {
        let mut rng = Rng::new(0x51AD);
        for (w, k) in [(4usize, 8usize), (8, 16), (16, 32), (3, 5), (1, 1), (2, 7)] {
            let wk = w * k;
            let mut b_hat: Vec<f32> = (0..wk).map(|_| rng.uniform(0.0, 200.0) as f32).collect();
            let mut pi: Vec<f32> = (0..wk).map(|_| rng.uniform(0.0, 5.0) as f32).collect();
            for step in 0..10 {
                let (slot, meas, b_tilde, m_rem, d, n_tot) = random_tick(w, k, &mut rng);
                let (mut sb, mut sp) = (vec![0.0f32; wk], vec![0.0f32; wk]);
                let (mut vb, mut vp) = (vec![0.0f32; wk], vec![0.0f32; wk]);
                let p = params();
                kalman_update_scalar(&b_hat, &pi, &b_tilde, &meas, &slot, &p, &mut sb, &mut sp);
                kalman_update_simd(&b_hat, &pi, &b_tilde, &meas, &slot, &p, &mut vb, &mut vp);
                for i in 0..wk {
                    assert_eq!(
                        sb[i].to_bits(),
                        vb[i].to_bits(),
                        "({w},{k}) step {step} b_hat[{i}]: scalar={} simd={}",
                        sb[i],
                        vb[i]
                    );
                    assert_eq!(sp[i].to_bits(), vp[i].to_bits(), "({w},{k}) step {step} pi[{i}]");
                }
                let inp = TickInputs {
                    b_tilde: &b_tilde,
                    meas_mask: &meas,
                    m_rem: &m_rem,
                    slot_mask: &slot,
                    d: &d,
                    n_tot,
                };
                // per-cell path: stage 1 of step_into is the kernel
                let mut cell = Bank::new(w, k, params(), Backend::Native);
                cell.b_hat.copy_from_slice(&b_hat);
                cell.pi.copy_from_slice(&pi);
                let out = cell.step(&inp).unwrap();
                assert_eq!(out.b_hat, sb, "({w},{k}) step {step}: per-cell path diverged");
                assert_eq!(out.pi, sp, "({w},{k}) step {step}: per-cell pi diverged");
                // batched path: one gathered lane, same kernel
                let mut lane_bank = Bank::new(w, k, params(), Backend::Native);
                lane_bank.b_hat.copy_from_slice(&b_hat);
                lane_bank.pi.copy_from_slice(&pi);
                let template = Bank::new(w, k, params(), Backend::Native);
                let mut batch = BatchScratch::default();
                batch.begin(1, w, k);
                batch.gather(&lane_bank, &inp).unwrap();
                template.step_batch_into(&mut batch).unwrap();
                let mut bout = StepOutputs::default();
                batch.scatter(0, &mut lane_bank, &mut bout);
                assert_eq!(bout.b_hat, sb, "({w},{k}) step {step}: batched path diverged");
                assert_eq!(bout.pi, sp, "({w},{k}) step {step}: batched pi diverged");
                // evolve the trajectory for the next step
                b_hat = sb;
                pi = sp;
            }
        }
    }

    /// The sparse-tick skipper's bank leg (PR-6,
    /// `Platform::fast_forward_tick`): on an all-zero slot mask the
    /// step is a fixed point — persistent `b_hat`/`pi` come back
    /// bit-unchanged and the consumed outputs (`r`, `s`, `n_star`) are
    /// zero *independent of `n_tot`* — so a skipped tick may reuse the
    /// previous step's outputs verbatim while the fleet keeps decaying.
    #[test]
    fn zero_slot_mask_step_is_a_fixed_point() {
        let (w, k) = (4usize, 3usize);
        let wk = w * k;
        let mut bank = Bank::new(w, k, params(), Backend::Native);
        let mut rng = Rng::new(0x1D1E);
        for _ in 0..5 {
            let (slot, meas, b_tilde, m_rem, d, n_tot) = random_tick(w, k, &mut rng);
            bank.step(&TickInputs {
                b_tilde: &b_tilde,
                meas_mask: &meas,
                m_rem: &m_rem,
                slot_mask: &slot,
                d: &d,
                n_tot,
            })
            .unwrap();
        }
        let b0 = bank.b_hat().to_vec();
        let p0 = bank.pi().to_vec();
        let zeros = vec![0.0f32; wk];
        let d = vec![0.0f32; w];
        for n_tot in [0.0f32, 7.0, 50.0] {
            let out = bank
                .step(&TickInputs {
                    b_tilde: &zeros,
                    meas_mask: &zeros,
                    m_rem: &zeros,
                    slot_mask: &zeros,
                    d: &d,
                    n_tot,
                })
                .unwrap();
            assert_eq!(bank.b_hat(), &b0[..], "state must be preserved (n_tot={n_tot})");
            assert_eq!(bank.pi(), &p0[..], "covariance must be preserved (n_tot={n_tot})");
            assert!(out.r.iter().all(|&x| x == 0.0), "r must be zero");
            assert!(out.s.iter().all(|&x| x == 0.0), "s must be zero");
            assert_eq!(out.n_star, 0.0, "n_star must be zero independent of n_tot");
        }
    }

    /// ROADMAP 5a, stub side: pin the padded row-major `[N, W, K]`
    /// batch layout a batch-dimension XLA artifact will consume —
    /// `[N, W*K]` planes at flat offset `lane*W*K + wi*K + ki`,
    /// `[N, W]` planes at `lane*W + wi`, `[N]` scalars at `lane` — so
    /// the artifact swap behind `step_batch_into` cannot silently
    /// reinterpret the buffers.
    #[test]
    fn batch_layout_is_padded_row_major() {
        let (w, k, cap) = (3usize, 4usize, 5usize);
        let wk = w * k;
        let mut batch = BatchScratch::default();
        batch.begin(cap, w, k);
        let sentinel = |lane: usize, wi: usize, ki: usize| (lane * 1000 + wi * 100 + ki) as f32;
        let mut banks: Vec<Bank> =
            (0..cap).map(|_| Bank::new(w, k, params(), Backend::Native)).collect();
        let no_meas = vec![0.0f32; wk];
        let all_slots = vec![1.0f32; wk];
        for lane in 0..cap {
            let mut b_tilde = vec![0.0f32; wk];
            let mut m_rem = vec![0.0f32; wk];
            for wi in 0..w {
                for ki in 0..k {
                    b_tilde[wi * k + ki] = sentinel(lane, wi, ki);
                    m_rem[wi * k + ki] = sentinel(lane, wi, ki) + 0.5;
                    banks[lane].b_hat[wi * k + ki] = sentinel(lane, wi, ki) + 0.25;
                }
            }
            let d: Vec<f32> = (0..w).map(|wi| sentinel(lane, wi, 99)).collect();
            let got = batch
                .gather(
                    &banks[lane],
                    &TickInputs {
                        b_tilde: &b_tilde,
                        meas_mask: &no_meas,
                        m_rem: &m_rem,
                        slot_mask: &all_slots,
                        d: &d,
                        n_tot: lane as f32 + 0.125,
                    },
                )
                .unwrap();
            assert_eq!(got, lane, "gather must hand out lanes in order");
        }
        for lane in 0..cap {
            for wi in 0..w {
                for ki in 0..k {
                    let flat = lane * wk + wi * k + ki;
                    let s = sentinel(lane, wi, ki);
                    assert_eq!(batch.b_tilde[flat], s, "b_tilde [{lane},{wi},{ki}]");
                    assert_eq!(batch.m_rem[flat], s + 0.5, "m_rem [{lane},{wi},{ki}]");
                    assert_eq!(batch.b_hat[flat], s + 0.25, "b_hat [{lane},{wi},{ki}]");
                }
                assert_eq!(batch.d[lane * w + wi], sentinel(lane, wi, 99), "d [{lane},{wi}]");
            }
            assert_eq!(batch.n_tot[lane], lane as f32 + 0.125, "n_tot [{lane}]");
        }
    }

    /// ROADMAP 5a, stub side: `begin` re-sizing to the shape already
    /// held must not zero the buffers — a partially-filled round leaves
    /// trailing lanes as stale padding that the kernel must ignore via
    /// the lane count (exactly the contract a padded batch-dimension
    /// artifact has: it executes `cap` lanes but only the first
    /// `lanes()` scatter back).
    #[test]
    fn batch_padding_lanes_are_stale_not_zeroed() {
        let (w, k, cap) = (2usize, 3usize, 4usize);
        let wk = w * k;
        let mut batch = BatchScratch::default();
        batch.begin(cap, w, k);
        let bank = Bank::new(w, k, params(), Backend::Native);
        let b_tilde = vec![7.0f32; wk];
        let ones = vec![1.0f32; wk];
        let m_rem = vec![3.0f32; wk];
        let d = vec![60.0f32; w];
        let fill = TickInputs {
            b_tilde: &b_tilde,
            meas_mask: &ones,
            m_rem: &m_rem,
            slot_mask: &ones,
            d: &d,
            n_tot: 9.0,
        };
        for _ in 0..cap {
            batch.gather(&bank, &fill).unwrap();
        }
        // new round, same shape: no realloc, no zeroing — only the lane
        // count resets
        batch.begin(cap, w, k);
        assert_eq!(batch.lanes(), 0);
        batch.gather(&bank, &fill).unwrap();
        assert_eq!(batch.lanes(), 1);
        for lane in 1..cap {
            assert!(
                batch.b_tilde[lane * wk..][..wk].iter().all(|&x| x == 7.0),
                "padding lane {lane} must keep its stale contents"
            );
        }
        // and the partial round still executes correctly over lane 0
        bank.step_batch_into(&mut batch).unwrap();
        let mut out = StepOutputs::default();
        let mut cell = Bank::new(w, k, params(), Backend::Native);
        batch.scatter(0, &mut cell, &mut out);
        let mut reference = Bank::new(w, k, params(), Backend::Native);
        let expect = reference.step(&fill).unwrap();
        assert_eq!(out, expect, "partial round diverged from per-cell step");
    }
}
