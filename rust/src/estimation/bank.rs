//! The estimator bank: all W×K Kalman CUS estimators updated in one shot
//! per monitoring instant, together with eqs. (1), (11)–(14) and the AIMD
//! decision — i.e. the full numeric tick of the GCI.
//!
//! Two interchangeable backends:
//!  * [`Backend::Xla`] — executes the AOT-compiled Pallas/JAX artifact
//!    through PJRT ([`crate::runtime::Engine`]); the production hot path.
//!  * [`Backend::Native`] — a bit-faithful f32 rust implementation; the
//!    fallback when artifacts are absent, and the cross-check oracle.
//!
//! The parity test at the bottom asserts both backends agree to f32
//! round-off on random states.

use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::runtime::{Engine, StepInputs, StepOutputs, N_PARAMS};

/// A compiled PJRT engine shared between banks: sweep cells with the
/// same (W, K) artifact shape reuse one executable instead of loading
/// and compiling it per cell (see [`super::cache::BankCache`]). The
/// `RwLock` exists for lazy per-shape *compilation* only — the one
/// write lock per shape inserts the executable, after which every
/// concurrent `monitor_step` execution runs under a **read** lock
/// ([`Engine::compiled`] + `Executable::run(&self)`), so same-shape
/// cells on different sweep workers never serialize the hot path.
pub type SharedEngine = Arc<RwLock<Engine>>;

/// Scalar knobs of the bank (mirrors PARAMS_LAYOUT in model.py minus
/// n_tot, which varies per tick).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BankParams {
    pub sigma_z2: f32,
    pub sigma_v2: f32,
    pub alpha: f32,
    pub beta: f32,
    pub n_min: f32,
    pub n_max: f32,
    pub n_w_max: f32,
}

impl BankParams {
    pub fn from_config(c: &crate::config::ControlCfg) -> Self {
        BankParams {
            sigma_z2: c.sigma_z2 as f32,
            sigma_v2: c.sigma_v2 as f32,
            alpha: c.alpha as f32,
            beta: c.beta as f32,
            n_min: c.n_min as f32,
            n_max: c.n_max as f32,
            n_w_max: c.n_w_max as f32,
        }
    }
}

/// Which compute backend the bank uses. `Clone` hands out another
/// reference to the same shared engine (never a recompilation) — the
/// bank *cache* relies on this to mint per-run banks from one cached
/// backend selection.
#[derive(Clone)]
pub enum Backend {
    Native,
    Xla(SharedEngine),
}

impl Backend {
    /// Wrap an owned engine for (potential) sharing.
    pub fn xla(engine: Engine) -> Backend {
        Backend::Xla(Arc::new(RwLock::new(engine)))
    }
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Native => write!(f, "Native"),
            Backend::Xla(_) => write!(f, "Xla"),
        }
    }
}

/// Per-tick inputs that vary (everything except the persistent state).
#[derive(Debug, Clone)]
pub struct TickInputs<'a> {
    pub b_tilde: &'a [f32],
    pub meas_mask: &'a [f32],
    pub m_rem: &'a [f32],
    pub slot_mask: &'a [f32],
    pub d: &'a [f32],
    pub n_tot: f32,
}

/// The estimator bank.
#[derive(Debug)]
pub struct Bank {
    pub w: usize,
    pub k: usize,
    pub params: BankParams,
    backend: Backend,
    b_hat: Vec<f32>,
    pi: Vec<f32>,
}

impl Bank {
    pub fn new(w: usize, k: usize, params: BankParams, backend: Backend) -> Self {
        Bank { w, k, params, backend, b_hat: vec![0.0; w * k], pi: vec![0.0; w * k] }
    }

    /// Try to build an XLA-backed bank; fall back to native (and report
    /// which) if artifacts are missing. One-off, uncached construction
    /// over the same selection logic the [`super::cache::BankCache`]
    /// uses (`cache::resolve` — shared so the two can never drift).
    pub fn with_best_backend(
        w: usize,
        k: usize,
        params: BankParams,
        artifacts_dir: &std::path::Path,
        prefer_xla: bool,
    ) -> (Self, &'static str) {
        let v = super::cache::resolve(w, k, params, artifacts_dir, prefer_xla);
        (v.instantiate(), v.backend_name())
    }

    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Native => "native",
            Backend::Xla(_) => "xla",
        }
    }

    /// Direct (mutable) access to the persistent estimates — used when a
    /// workload slot is freed/reused.
    pub fn reset_slot(&mut self, w: usize, k: usize) {
        let idx = w * self.k + k;
        self.b_hat[idx] = 0.0;
        self.pi[idx] = 0.0;
    }

    pub fn b_hat(&self) -> &[f32] {
        &self.b_hat
    }

    pub fn pi(&self) -> &[f32] {
        &self.pi
    }

    pub fn estimate(&self, w: usize, k: usize) -> f32 {
        self.b_hat[w * self.k + k]
    }

    /// One monitoring-instant update; persists b_hat/pi internally and
    /// returns the derived quantities. Allocating convenience over
    /// [`Self::step_into`].
    pub fn step(&mut self, inp: &TickInputs) -> Result<StepOutputs> {
        let mut out = StepOutputs::default();
        self.step_into(inp, &mut out)?;
        Ok(out)
    }

    /// One monitoring-instant update writing into caller-owned output
    /// buffers. On the native backend this performs **zero heap
    /// allocation** once `out` has been through one step (buffers are
    /// resized on first use, then refilled in place) — the GCI reuses
    /// one `StepOutputs` across all ticks.
    pub fn step_into(&mut self, inp: &TickInputs, out: &mut StepOutputs) -> Result<()> {
        let wk = self.w * self.k;
        anyhow::ensure!(inp.b_tilde.len() == wk, "b_tilde size");
        anyhow::ensure!(inp.meas_mask.len() == wk, "meas_mask size");
        anyhow::ensure!(inp.m_rem.len() == wk, "m_rem size");
        anyhow::ensure!(inp.slot_mask.len() == wk, "slot_mask size");
        anyhow::ensure!(inp.d.len() == self.w, "d size");
        match &mut self.backend {
            Backend::Native => {
                native_step_into(self.w, self.k, &self.b_hat, &self.pi, inp, &self.params, out);
            }
            Backend::Xla(engine) => {
                // fast path: the shape is compiled — execute under a
                // read lock so concurrent same-engine banks don't
                // serialize. The write lock is taken once per shape to
                // compile, then re-checked through the loop.
                let guard = loop {
                    let g = engine.read().expect("bank engine lock poisoned");
                    if g.compiled(self.w, self.k).is_some() {
                        break g;
                    }
                    drop(g);
                    let mut g = engine.write().expect("bank engine lock poisoned");
                    g.executable(self.w, self.k)?;
                };
                let exe = guard
                    .compiled(self.w, self.k)
                    .expect("executable compiled under the write lock above");
                let params = [
                    // must match PARAMS_LAYOUT in model.py
                    self.params.sigma_z2,
                    self.params.sigma_v2,
                    inp.n_tot,
                    self.params.alpha,
                    self.params.beta,
                    self.params.n_min,
                    self.params.n_max,
                    self.params.n_w_max,
                ];
                debug_assert_eq!(params.len(), N_PARAMS);
                *out = exe.run(&StepInputs {
                    b_hat: &self.b_hat,
                    pi: &self.pi,
                    b_tilde: inp.b_tilde,
                    meas_mask: inp.meas_mask,
                    m_rem: inp.m_rem,
                    slot_mask: inp.slot_mask,
                    d: inp.d,
                    params,
                })?;
            }
        }
        self.b_hat.copy_from_slice(&out.b_hat);
        self.pi.copy_from_slice(&out.pi);
        Ok(())
    }
}

/// The native (rust, f32) implementation of the monitor_step graph —
/// mirrors python/compile/model.py operation for operation. Allocating
/// convenience over [`native_step_into`].
pub fn native_step(
    w: usize,
    k: usize,
    b_hat: &[f32],
    pi: &[f32],
    inp: &TickInputs,
    p: &BankParams,
) -> StepOutputs {
    let mut out = StepOutputs::default();
    native_step_into(w, k, b_hat, pi, inp, p, &mut out);
    out
}

/// [`native_step`] writing into reused output buffers: allocation-free
/// once `out` holds (w*k)/(w)-sized vectors.
pub fn native_step_into(
    w: usize,
    k: usize,
    b_hat: &[f32],
    pi: &[f32],
    inp: &TickInputs,
    p: &BankParams,
    out: &mut StepOutputs,
) {
    let wk = w * k;
    out.b_hat.resize(wk, 0.0);
    out.pi.resize(wk, 0.0);
    out.r.resize(w, 0.0);
    out.s.resize(w, 0.0);
    // 1. masked Kalman update (eqs. 6-9), inert outside slot_mask
    for i in 0..wk {
        let pi_minus = pi[i] + p.sigma_z2;
        let kappa = pi_minus / (pi_minus + p.sigma_v2);
        let b_meas = b_hat[i] + kappa * (inp.b_tilde[i] - b_hat[i]);
        let pi_meas = (1.0 - kappa) * pi_minus;
        let m = inp.meas_mask[i];
        let mut b = m * b_meas + (1.0 - m) * b_hat[i];
        let mut pv = m * pi_meas + (1.0 - m) * pi_minus;
        let s = inp.slot_mask[i];
        b = s * b + (1.0 - s) * b_hat[i];
        pv = s * pv + (1.0 - s) * pi[i];
        out.b_hat[i] = b;
        out.pi[i] = pv;
    }
    // 2. r_w = sum_k m*mask*b (eq. 1)
    for wi in 0..w {
        let mut acc = 0.0f32;
        for ki in 0..k {
            let i = wi * k + ki;
            acc += inp.m_rem[i] * inp.slot_mask[i] * out.b_hat[i];
        }
        out.r[wi] = acc;
    }
    // 3. proportional-fair service rates (eqs. 11-14)
    let mut n_star = 0.0f32;
    for wi in 0..w {
        let active = (0..k).any(|ki| inp.slot_mask[wi * k + ki] > 0.0);
        let safe_d = if inp.d[wi] > 0.0 { inp.d[wi] } else { 1.0 };
        // eq. (11) with the per-workload cap N_{w,max}
        out.s[wi] = if active { (out.r[wi] / safe_d).min(p.n_w_max) } else { 0.0 };
        n_star += out.s[wi];
    }
    let hi = inp.n_tot + p.alpha;
    let lo = p.beta * inp.n_tot;
    let denom = n_star.max(1e-30);
    let mut scale = if n_star > hi {
        hi / denom
    } else if n_star < lo {
        lo / denom
    } else {
        1.0
    };
    if n_star <= 0.0 {
        scale = 1.0;
    }
    for s in out.s.iter_mut() {
        *s *= scale;
    }
    // 4. AIMD (Fig. 4)
    out.n_star = n_star;
    out.n_next = if inp.n_tot <= n_star {
        (inp.n_tot + p.alpha).min(p.n_max)
    } else {
        (p.beta * inp.n_tot).max(p.n_min)
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn params() -> BankParams {
        BankParams {
            sigma_z2: 0.5,
            sigma_v2: 0.5,
            alpha: 5.0,
            beta: 0.9,
            n_min: 10.0,
            n_max: 100.0,
            n_w_max: 10.0,
        }
    }

    fn random_tick(w: usize, k: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, f32) {
        let wk = w * k;
        let slot: Vec<f32> = (0..wk).map(|_| if rng.f64() < 0.8 { 1.0 } else { 0.0 }).collect();
        let meas: Vec<f32> = (0..wk)
            .map(|i| if slot[i] > 0.0 && rng.f64() < 0.6 { 1.0 } else { 0.0 })
            .collect();
        let b_tilde: Vec<f32> = (0..wk).map(|_| rng.uniform(0.0, 300.0) as f32).collect();
        let m_rem: Vec<f32> = (0..wk).map(|_| rng.int(0, 500) as f32).collect();
        let d: Vec<f32> = (0..w).map(|_| rng.uniform(60.0, 7620.0) as f32).collect();
        let n_tot = rng.uniform(1.0, 60.0) as f32;
        (slot, meas, b_tilde, m_rem, d, n_tot)
    }

    #[test]
    fn native_bank_converges_on_constant_measurements() {
        let mut bank = Bank::new(4, 2, params(), Backend::Native);
        let wk = 8;
        let slot = vec![1.0f32; wk];
        let meas = vec![1.0f32; wk];
        let b_tilde = vec![42.0f32; wk];
        let m_rem = vec![10.0f32; wk];
        let d = vec![1000.0f32; 4];
        for _ in 0..60 {
            bank.step(&TickInputs {
                b_tilde: &b_tilde,
                meas_mask: &meas,
                m_rem: &m_rem,
                slot_mask: &slot,
                d: &d,
                n_tot: 10.0,
            })
            .unwrap();
        }
        for wi in 0..4 {
            for ki in 0..2 {
                assert!((bank.estimate(wi, ki) - 42.0).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn native_matches_scalar_kalman() {
        // the bank's slot (0,0) must evolve exactly like estimation::kalman
        // under the same measurement sequence (f32 vs f64 tolerance).
        let mut bank = Bank::new(2, 2, params(), Backend::Native);
        let mut scalar = crate::estimation::kalman::Kalman::new(0.5, 0.5);
        let mut rng = Rng::new(0xBEEF);
        let wk = 4;
        let mut slot = vec![0.0f32; wk];
        slot[0] = 1.0;
        let m_rem = vec![1.0f32; wk];
        let d = vec![100.0f32; 2];
        for _ in 0..30 {
            let x = rng.uniform(1.0, 50.0);
            scalar.seed(x);
            scalar.update(Some(x));
            let mut b_tilde = vec![0.0f32; wk];
            let mut meas = vec![0.0f32; wk];
            b_tilde[0] = x as f32;
            meas[0] = 1.0;
            bank.step(&TickInputs {
                b_tilde: &b_tilde,
                meas_mask: &meas,
                m_rem: &m_rem,
                slot_mask: &slot,
                d: &d,
                n_tot: 10.0,
            })
            .unwrap();
            assert!(
                (bank.estimate(0, 0) as f64 - scalar.b_hat).abs() < 1e-3,
                "bank={} scalar={}",
                bank.estimate(0, 0),
                scalar.b_hat
            );
        }
    }

    #[test]
    fn xla_and_native_backends_agree() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let (w, k) = (8, 2);
        let mut xla_bank = Bank::new(w, k, params(), Backend::xla(Engine::load(&dir).unwrap()));
        let mut nat_bank = Bank::new(w, k, params(), Backend::Native);
        assert_eq!(xla_bank.backend_name(), "xla");
        let mut rng = Rng::new(0xD17E);
        for step in 0..25 {
            let (slot, meas, b_tilde, m_rem, d, n_tot) = random_tick(w, k, &mut rng);
            let inp = TickInputs {
                b_tilde: &b_tilde,
                meas_mask: &meas,
                m_rem: &m_rem,
                slot_mask: &slot,
                d: &d,
                n_tot,
            };
            let a = xla_bank.step(&inp).unwrap();
            let b = nat_bank.step(&inp).unwrap();
            for i in 0..w * k {
                assert!(
                    (a.b_hat[i] - b.b_hat[i]).abs() <= 1e-3 * (1.0 + b.b_hat[i].abs()),
                    "step {step} b_hat[{i}]: xla={} native={}",
                    a.b_hat[i],
                    b.b_hat[i]
                );
                assert!((a.pi[i] - b.pi[i]).abs() <= 1e-4 * (1.0 + b.pi[i].abs()));
            }
            for wi in 0..w {
                assert!(
                    (a.r[wi] - b.r[wi]).abs() <= 1e-2 * (1.0 + b.r[wi].abs()),
                    "step {step} r[{wi}]: xla={} native={}",
                    a.r[wi],
                    b.r[wi]
                );
                assert!((a.s[wi] - b.s[wi]).abs() <= 1e-2 * (1.0 + b.s[wi].abs()));
            }
            assert!((a.n_star - b.n_star).abs() <= 1e-2 * (1.0 + b.n_star.abs()));
            assert!((a.n_next - b.n_next).abs() <= 1e-3 * (1.0 + b.n_next.abs()));
        }
    }

    #[test]
    fn bank_rejects_bad_sizes() {
        let mut bank = Bank::new(2, 2, params(), Backend::Native);
        let r = bank.step(&TickInputs {
            b_tilde: &[0.0; 3],
            meas_mask: &[0.0; 4],
            m_rem: &[0.0; 4],
            slot_mask: &[0.0; 4],
            d: &[0.0; 2],
            n_tot: 1.0,
        });
        assert!(r.is_err());
    }

    #[test]
    fn reset_slot_clears_state() {
        let mut bank = Bank::new(2, 2, params(), Backend::Native);
        let slot = vec![1.0f32; 4];
        bank.step(&TickInputs {
            b_tilde: &[5.0; 4],
            meas_mask: &[1.0; 4],
            m_rem: &[1.0; 4],
            slot_mask: &slot,
            d: &[100.0; 2],
            n_tot: 10.0,
        })
        .unwrap();
        assert!(bank.estimate(1, 1) > 0.0);
        bank.reset_slot(1, 1);
        assert_eq!(bank.estimate(1, 1), 0.0);
        assert!(bank.estimate(0, 0) > 0.0);
    }
}
