//! "Ad-hoc" fixed-gain estimator (§V-B baseline).
//!
//! Same recursion as eq. (8) but with the scaling coefficient fixed at
//! κ = 0.1, "which was shown to perform best amongst other settings".

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdHoc {
    pub b_hat: f64,
    pub kappa: f64,
    pub last_meas: Option<f64>,
}

impl AdHoc {
    pub fn new(kappa: f64) -> Self {
        AdHoc { b_hat: 0.0, kappa, last_meas: None }
    }

    /// Paper setting κ = 0.1.
    pub fn paper() -> Self {
        Self::new(0.1)
    }

    pub fn seed(&mut self, b_tilde0: f64) {
        self.last_meas = Some(b_tilde0);
    }

    pub fn update(&mut self, meas: Option<f64>) -> f64 {
        if let Some(b_tilde) = meas.or(self.last_meas) {
            self.b_hat += self.kappa * (b_tilde - self.b_hat);
        }
        if meas.is_some() {
            self.last_meas = meas;
        }
        self.b_hat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_but_slower_than_kalman() {
        use crate::estimation::kalman::Kalman;
        let mut a = AdHoc::paper();
        let mut k = Kalman::new(0.5, 0.5);
        a.seed(10.0);
        k.seed(10.0);
        for _ in 0..10 {
            a.update(Some(10.0));
            k.update(Some(10.0));
        }
        // Kalman's early gains are ~0.5+, ad-hoc's fixed 0.1 trails badly
        assert!((k.b_hat - 10.0).abs() < (a.b_hat - 10.0).abs());
    }

    #[test]
    fn fixed_gain_recursion() {
        let mut a = AdHoc::new(0.1);
        a.seed(100.0);
        let b1 = a.update(Some(100.0));
        assert!((b1 - 10.0).abs() < 1e-12);
        let b2 = a.update(Some(100.0));
        assert!((b2 - 19.0).abs() < 1e-12);
    }

    #[test]
    fn no_measurement_reuses_last() {
        let mut a = AdHoc::new(0.5);
        a.seed(10.0);
        a.update(Some(10.0)); // 5.0
        a.update(None); // reuse 10.0 -> 7.5
        assert!((a.b_hat - 7.5).abs() < 1e-12);
    }

    #[test]
    fn never_seeded_stays_zero() {
        let mut a = AdHoc::paper();
        assert_eq!(a.update(None), 0.0);
    }
}
