//! CUS estimation (§II-E-3, §V-B): Kalman (proposed), ad-hoc fixed-gain
//! and ARMA baselines, the PR-9 bake-off additions (EWMA and the
//! arxiv-1604.04804-style last-observation "reactive" predictor),
//! convergence detection, and the batched estimator bank with its XLA
//! (Pallas/JAX AOT) and native backends.
//!
//! # Adding an estimator
//!
//! Implement [`Estimator`] (the `seed`/`update(Option<f64>)` shape every
//! passive estimator here shares), add an [`EstimatorKind`] variant, and
//! wire the three platform dispatch points that read the driving
//! estimate (`driving_r`, `driving_rates_into`, `build_chunk`) plus a
//! slot in the platform's per-(workload, type) `SlotEst` — see
//! `rust/BENCHMARKS.md` "how to add a policy/estimator" for the
//! file-by-file walk.

pub mod adhoc;
pub mod arma;
pub mod bank;
pub mod cache;
pub mod convergence;
pub mod kalman;
pub mod simple;

pub use adhoc::AdHoc;
pub use arma::Arma;
pub use bank::{
    kalman_update_scalar, kalman_update_simd, Backend, Bank, BankParams, BatchScratch, TickInputs,
    KERNEL_LANES,
};
pub use cache::{BankCache, BankVariant, CacheStats};
pub use convergence::{DeviationDetector, SlopeDetector};
pub use kalman::Kalman;
pub use simple::{Ewma, LastObservation};

/// The common surface of the passive per-(workload, media-type) CUS
/// predictors (PR-9 trait seam). `seed` stashes the pre-run footprint
/// measurement b̃[0]; `update` consumes one monitoring instant's
/// measurement — `None` when the instant completed no item of the type,
/// in which case estimators re-use their last measurement (or hold).
///
/// The platform's tick loop drives the concrete structs directly (the
/// passive loop is on the zero-allocation hot path and is pinned
/// bit-identical across PRs); this trait is the *extension seam* — new
/// estimators implement it, and the conformance tests below hold every
/// family to the same contract.
pub trait Estimator: std::fmt::Debug {
    fn name(&self) -> &'static str;
    /// Record the pre-run footprint measurement b̃[0].
    fn seed(&mut self, b_tilde0: f64);
    /// Consume a monitoring instant's measurement; returns the estimate.
    fn update(&mut self, meas: Option<f64>) -> f64;
    /// Current per-item CUS estimate b̂.
    fn estimate(&self) -> f64;
}

impl Estimator for AdHoc {
    fn name(&self) -> &'static str {
        "Ad-hoc"
    }
    fn seed(&mut self, b_tilde0: f64) {
        AdHoc::seed(self, b_tilde0)
    }
    fn update(&mut self, meas: Option<f64>) -> f64 {
        AdHoc::update(self, meas)
    }
    fn estimate(&self) -> f64 {
        self.b_hat
    }
}

impl Estimator for Ewma {
    fn name(&self) -> &'static str {
        "EWMA"
    }
    fn seed(&mut self, b_tilde0: f64) {
        Ewma::seed(self, b_tilde0)
    }
    fn update(&mut self, meas: Option<f64>) -> f64 {
        Ewma::update(self, meas)
    }
    fn estimate(&self) -> f64 {
        self.b_hat
    }
}

impl Estimator for LastObservation {
    fn name(&self) -> &'static str {
        "Reactive"
    }
    fn seed(&mut self, b_tilde0: f64) {
        LastObservation::seed(self, b_tilde0)
    }
    fn update(&mut self, meas: Option<f64>) -> f64 {
        LastObservation::update(self, meas)
    }
    fn estimate(&self) -> f64 {
        self.b_hat
    }
}

/// ARMA adapts to the trait by holding its estimate over measurement
/// gaps (its inherent `update` consumes *normalized* observations and
/// has no gap semantics of its own) and ignoring the seed (eq. 15 has
/// no seed term).
impl Estimator for Arma {
    fn name(&self) -> &'static str {
        "ARMA"
    }
    fn seed(&mut self, _b_tilde0: f64) {}
    fn update(&mut self, meas: Option<f64>) -> f64 {
        match meas {
            Some(b_norm) => Arma::update(self, b_norm),
            None => self.b_hat,
        }
    }
    fn estimate(&self) -> f64 {
        self.b_hat
    }
}

/// Which estimator a simulation run uses (Table II comparisons plus the
/// PR-9 bake-off additions).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EstimatorKind {
    Kalman,
    AdHoc,
    Arma,
    /// EWMA smoother (λ = 0.5), between ad-hoc and last-observation.
    Ewma,
    /// Last-observation predictor (arxiv 1604.04804's reactive
    /// estimation — the baseline the paper's >27 % claim is against).
    Reactive,
}

impl EstimatorKind {
    pub fn name(&self) -> &'static str {
        match self {
            EstimatorKind::Kalman => "Kalman-based",
            EstimatorKind::AdHoc => "Ad-hoc",
            EstimatorKind::Arma => "ARMA",
            EstimatorKind::Ewma => "EWMA",
            EstimatorKind::Reactive => "Reactive",
        }
    }

    pub const ALL: [EstimatorKind; 5] = [
        EstimatorKind::Kalman,
        EstimatorKind::AdHoc,
        EstimatorKind::Arma,
        EstimatorKind::Ewma,
        EstimatorKind::Reactive,
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every passive family through the one trait contract: seed, a
    /// measurement, a gap — estimates stay finite and non-negative, and
    /// `estimate()` always agrees with the last `update` return.
    #[test]
    fn estimator_trait_conformance() {
        let mut all: Vec<Box<dyn Estimator>> = vec![
            Box::new(AdHoc::paper()),
            Box::new(Arma::paper()),
            Box::new(Ewma::paper()),
            Box::new(LastObservation::new()),
        ];
        for est in &mut all {
            est.seed(10.0);
            for meas in [Some(12.0), None, Some(8.0), None] {
                let b = est.update(meas);
                assert!(b.is_finite() && b >= 0.0, "{}: {b}", est.name());
                assert_eq!(b.to_bits(), est.estimate().to_bits(), "{}", est.name());
            }
            assert!(!est.name().is_empty());
        }
    }

    /// The trait adapters are transparent: driving `AdHoc` through
    /// `dyn Estimator` is bitwise the inherent calls (the same guarantee
    /// the platform's concrete-field dispatch relies on).
    #[test]
    fn trait_dispatch_is_bitwise_the_inherent_calls() {
        let mut direct = AdHoc::paper();
        let mut boxed: Box<dyn Estimator> = Box::new(AdHoc::paper());
        direct.seed(7.0);
        boxed.seed(7.0);
        for meas in [Some(9.0), None, Some(2.5), Some(2.5), None] {
            let a = direct.update(meas);
            let b = boxed.update(meas);
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn kind_names_are_distinct() {
        for (i, a) in EstimatorKind::ALL.iter().enumerate() {
            for b in &EstimatorKind::ALL[i + 1..] {
                assert_ne!(a.name(), b.name());
            }
        }
    }
}
