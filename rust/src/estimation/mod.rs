//! CUS estimation (§II-E-3, §V-B): Kalman (proposed), ad-hoc fixed-gain
//! and ARMA baselines, convergence detection, and the batched estimator
//! bank with its XLA (Pallas/JAX AOT) and native backends.

pub mod adhoc;
pub mod arma;
pub mod bank;
pub mod cache;
pub mod convergence;
pub mod kalman;

pub use adhoc::AdHoc;
pub use arma::Arma;
pub use bank::{
    kalman_update_scalar, kalman_update_simd, Backend, Bank, BankParams, BatchScratch, TickInputs,
    KERNEL_LANES,
};
pub use cache::{BankCache, BankVariant, CacheStats};
pub use convergence::{DeviationDetector, SlopeDetector};
pub use kalman::Kalman;

/// Which estimator a simulation run uses (Table II comparisons).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EstimatorKind {
    Kalman,
    AdHoc,
    Arma,
}

impl EstimatorKind {
    pub fn name(&self) -> &'static str {
        match self {
            EstimatorKind::Kalman => "Kalman-based",
            EstimatorKind::AdHoc => "Ad-hoc",
            EstimatorKind::Arma => "ARMA",
        }
    }

    pub const ALL: [EstimatorKind; 3] =
        [EstimatorKind::Kalman, EstimatorKind::AdHoc, EstimatorKind::Arma];
}
