//! Second-order ARMA workload estimator of Roy et al. (§V-B, eq. 15).
//!
//! `b̂[t+1] = δ·b_norm[t] + γ·b_norm[t-1] + (1-δ-γ)·b_norm[t-2]`, where
//! b_norm[t] is the total execution time of the type so far divided by the
//! fraction of the workload completed (the paper's normalization), and
//! (δ, γ) take Roy et al.'s recommended weights.

/// Roy et al. recommended coefficients (most recent sample dominates).
pub const DELTA: f64 = 0.8;
pub const GAMMA: f64 = 0.15;

#[derive(Debug, Clone, Default)]
pub struct Arma {
    pub delta: f64,
    pub gamma: f64,
    /// Ring of the last three normalized observations (newest first).
    window: Vec<f64>,
    pub b_hat: f64,
}

impl Arma {
    pub fn new(delta: f64, gamma: f64) -> Self {
        Arma { delta, gamma, window: Vec::new(), b_hat: 0.0 }
    }

    pub fn paper() -> Self {
        Self::new(DELTA, GAMMA)
    }

    /// Push a normalized per-item CUS observation b_norm[t]; returns the
    /// new estimate. Until three observations exist, the estimate is the
    /// weighted mean of what is available (weights renormalized).
    pub fn update(&mut self, b_norm: f64) -> f64 {
        self.window.insert(0, b_norm);
        self.window.truncate(3);
        let w = [self.delta, self.gamma, 1.0 - self.delta - self.gamma];
        let mut num = 0.0;
        let mut den = 0.0;
        for (i, &x) in self.window.iter().enumerate() {
            num += w[i] * x;
            den += w[i];
        }
        self.b_hat = if den > 0.0 { num / den } else { 0.0 };
        self.b_hat
    }

    /// Number of observations so far.
    pub fn n_obs(&self) -> usize {
        self.window.len()
    }
}

/// Normalization helper: total execution time of a media type divided by
/// the fraction completed, re-expressed per item. Given cumulative CUS
/// spent `total_cus` on `done` of `total` items, the normalized per-item
/// cost is (total_cus / done) — the paper's "divided by the percentage of
/// the workload completed" scaled back to one item.
pub fn normalize_per_item(total_cus: f64, done: usize) -> Option<f64> {
    if done == 0 {
        None
    } else {
        Some(total_cus / done as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_window_uses_paper_weights() {
        let mut a = Arma::paper();
        a.update(1.0); // t-2 eventually
        a.update(2.0); // t-1
        let b = a.update(3.0); // t
        let want = 0.8 * 3.0 + 0.15 * 2.0 + 0.05 * 1.0;
        assert!((b - want).abs() < 1e-12);
    }

    #[test]
    fn partial_window_renormalizes() {
        let mut a = Arma::paper();
        let b1 = a.update(10.0);
        assert!((b1 - 10.0).abs() < 1e-12);
        let b2 = a.update(20.0);
        let want = (0.8 * 20.0 + 0.15 * 10.0) / 0.95;
        assert!((b2 - want).abs() < 1e-12);
    }

    #[test]
    fn tracks_moving_average_no_underdamping() {
        // ARMA is an MA estimator: on a constant signal it equals the
        // signal immediately (no overshoot-then-settle like Kalman-from-0)
        let mut a = Arma::paper();
        for _ in 0..5 {
            assert!((a.update(7.0) - 7.0).abs() < 1e-12);
        }
    }

    #[test]
    fn normalize_handles_zero_done() {
        assert_eq!(normalize_per_item(100.0, 0), None);
        assert_eq!(normalize_per_item(100.0, 4), Some(25.0));
    }

    #[test]
    fn window_never_exceeds_three() {
        let mut a = Arma::paper();
        for i in 0..10 {
            a.update(i as f64);
        }
        assert_eq!(a.n_obs(), 3);
    }
}
