//! Convergence detection — when is a CUS estimate "reliable"? (§V-B)
//!
//! The paper's criteria, used to set the monitoring instant t_init at
//! which the TTC can be confirmed:
//!
//! * **Kalman / ad-hoc**: both start from b̂ = 0 and overshoot
//!   (underdamped); the estimate is declared reliable at the first
//!   monitoring instant where the slope of b̂ across time turns negative.
//! * **ARMA**: a moving-average estimator with no underdamped shape, so a
//!   windowed-deviation rule is used instead: reliable when the deviation
//!   of the last `window` estimates stays within `threshold` (20 %) of
//!   their mean. The paper uses 3 samples for 5-min monitoring and 10 for
//!   1-min monitoring.

/// Slope-sign detector for underdamped estimators (Kalman, ad-hoc).
#[derive(Debug, Clone, Default)]
pub struct SlopeDetector {
    prev: Option<f64>,
    rose: bool,
    converged_at: Option<usize>,
    t: usize,
}

impl SlopeDetector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed the estimate at the next monitoring instant. Returns
    /// Some(t_init) the first time convergence is detected.
    pub fn push(&mut self, b_hat: f64) -> Option<usize> {
        let t = self.t;
        self.t += 1;
        if let Some(prev) = self.prev {
            let slope = b_hat - prev;
            if slope > 0.0 {
                self.rose = true;
            }
            // first negative slope after the initial rise
            if self.rose && slope < 0.0 && self.converged_at.is_none() {
                self.converged_at = Some(t);
                self.prev = Some(b_hat);
                return Some(t);
            }
        }
        self.prev = Some(b_hat);
        None
    }

    pub fn converged_at(&self) -> Option<usize> {
        self.converged_at
    }
}

/// Windowed-deviation detector for ARMA.
///
/// Storage is a fixed-size ring over the trailing `window` estimates,
/// allocated once at construction: `push` is allocation-free, so the
/// passive-estimator tick path stays heap-quiet when trace recording is
/// disabled (pinned by `tests/alloc_steady_state.rs`).
#[derive(Debug, Clone)]
pub struct DeviationDetector {
    window: usize,
    threshold: f64,
    /// Ring buffer of the last `window` estimates.
    ring: Vec<f64>,
    /// Total estimates seen (monitoring instants).
    count: usize,
    converged_at: Option<usize>,
}

impl DeviationDetector {
    /// `window`: number of trailing estimates compared; `threshold`:
    /// maximum allowed |x - mean| / mean (paper: 0.20).
    pub fn new(window: usize, threshold: f64) -> Self {
        DeviationDetector {
            window,
            threshold,
            ring: vec![0.0; window.max(1)],
            count: 0,
            converged_at: None,
        }
    }

    /// Paper settings per monitoring interval: 3 samples for 5-min
    /// monitoring, 10 for 1-min.
    pub fn paper(monitor_interval_s: u64) -> Self {
        let window = if monitor_interval_s <= 60 { 10 } else { 3 };
        Self::new(window, 0.20)
    }

    pub fn push(&mut self, b_hat: f64) -> Option<usize> {
        let t = self.count;
        let slot = self.count % self.ring.len();
        self.ring[slot] = b_hat;
        self.count += 1;
        if self.converged_at.is_some() || self.count < self.window {
            return None;
        }
        // ring order does not matter: the criterion is over the
        // unordered trailing window (mean + max deviation)
        let mean = crate::util::stats::mean(&self.ring);
        if mean <= 0.0 {
            return None;
        }
        let ok = self.ring.iter().all(|x| (x - mean).abs() / mean <= self.threshold);
        if ok {
            self.converged_at = Some(t);
            return Some(t);
        }
        None
    }

    pub fn converged_at(&self) -> Option<usize> {
        self.converged_at
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slope_detects_peak_of_underdamped_rise() {
        let mut d = SlopeDetector::new();
        // 0 -> rises -> peaks at t=3 -> decays
        let series = [0.0, 4.0, 7.0, 8.5, 8.0, 7.8, 7.9];
        let mut hit = None;
        for (t, &x) in series.iter().enumerate() {
            if let Some(ti) = d.push(x) {
                hit = Some((t, ti));
                break;
            }
        }
        assert_eq!(hit, Some((4, 4)));
    }

    #[test]
    fn slope_ignores_monotone_rise() {
        let mut d = SlopeDetector::new();
        for x in [0.0, 1.0, 2.0, 3.0, 4.0] {
            assert_eq!(d.push(x), None);
        }
        assert_eq!(d.converged_at(), None);
    }

    #[test]
    fn slope_requires_prior_rise() {
        // pure decay from the first sample: "rose" never set by a later
        // climb, but the seed measurement itself counts as the rise only
        // if a positive slope was seen. A strictly-decreasing series
        // therefore never converges by this rule.
        let mut d = SlopeDetector::new();
        for x in [9.0, 8.0, 7.0] {
            assert_eq!(d.push(x), None);
        }
    }

    #[test]
    fn slope_fires_once() {
        let mut d = SlopeDetector::new();
        let mut hits = 0;
        for x in [0.0, 5.0, 4.0, 6.0, 3.0] {
            if d.push(x).is_some() {
                hits += 1;
            }
        }
        assert_eq!(hits, 1);
        assert_eq!(d.converged_at(), Some(2));
    }

    #[test]
    fn deviation_waits_for_stability() {
        let mut d = DeviationDetector::new(3, 0.20);
        assert_eq!(d.push(10.0), None); // window not full
        assert_eq!(d.push(30.0), None);
        assert_eq!(d.push(50.0), None); // wild: 50 vs mean 30 = 66%
        assert_eq!(d.push(48.0), None); // 30,50,48: 30 deviates 29.7%
        assert_eq!(d.push(52.0), Some(4)); // 50,48,52 all within 4%
        assert_eq!(d.converged_at(), Some(4));
    }

    #[test]
    fn deviation_paper_windows() {
        assert_eq!(DeviationDetector::paper(60).window, 10);
        assert_eq!(DeviationDetector::paper(300).window, 3);
    }

    #[test]
    fn deviation_handles_zero_mean() {
        let mut d = DeviationDetector::new(2, 0.2);
        assert_eq!(d.push(0.0), None);
        assert_eq!(d.push(0.0), None); // mean 0: cannot normalize, no fire
    }
}
