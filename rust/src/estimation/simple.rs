//! Simple per-item CUS predictors for the PR-9 estimator bake-off:
//! an EWMA smoother and the last-observation "reactive" predictor the
//! predecessor paper (arxiv 1604.04804, CVSS) used for resource
//! estimation — the baseline the Dithen paper's >27 % cost-saving claim
//! is measured against.
//!
//! Both follow the [`super::AdHoc`] idiom exactly — `seed` stashes the
//! pre-run footprint measurement, `update(Option<f64>)` consumes a
//! per-instant measurement (or re-uses the last one when the instant
//! produced none) — so the platform's passive-estimator loop drives all
//! four families through one code shape.

/// Exponentially-weighted moving average of the per-item CUS
/// measurements: `b̂ ← b̂ + λ(b̃ − b̂)`. Structurally the ad-hoc
/// recursion, but with the heavier paper-EWMA weight λ = 0.5 — it
/// tracks fast and smooths little, sitting between ad-hoc (λ = 0.1)
/// and the raw last observation (λ = 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ewma {
    pub b_hat: f64,
    pub lambda: f64,
    pub last_meas: Option<f64>,
}

impl Ewma {
    pub fn new(lambda: f64) -> Self {
        Ewma { b_hat: 0.0, lambda, last_meas: None }
    }

    /// Default weight λ = 0.5.
    pub fn paper() -> Self {
        Self::new(0.5)
    }

    pub fn seed(&mut self, b_tilde0: f64) {
        self.last_meas = Some(b_tilde0);
    }

    pub fn update(&mut self, meas: Option<f64>) -> f64 {
        if let Some(b_tilde) = meas.or(self.last_meas) {
            self.b_hat += self.lambda * (b_tilde - self.b_hat);
        }
        if meas.is_some() {
            self.last_meas = meas;
        }
        self.b_hat
    }
}

/// Last-observation ("reactive") predictor: the estimate *is* the most
/// recent measurement, no smoothing at all — the arxiv-1604.04804-style
/// baseline. Fast to "converge" (one sample) and maximally noisy, which
/// is exactly the trade the Pareto sweep (`dithen sweep policies`)
/// quantifies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LastObservation {
    pub b_hat: f64,
    pub last_meas: Option<f64>,
}

impl LastObservation {
    pub fn new() -> Self {
        LastObservation { b_hat: 0.0, last_meas: None }
    }

    pub fn seed(&mut self, b_tilde0: f64) {
        self.last_meas = Some(b_tilde0);
    }

    pub fn update(&mut self, meas: Option<f64>) -> f64 {
        if let Some(b_tilde) = meas.or(self.last_meas) {
            self.b_hat = b_tilde;
        }
        if meas.is_some() {
            self.last_meas = meas;
        }
        self.b_hat
    }
}

impl Default for LastObservation {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_tracks_faster_than_adhoc() {
        let mut e = Ewma::paper();
        let mut a = crate::estimation::AdHoc::paper();
        e.seed(10.0);
        a.seed(10.0);
        for _ in 0..5 {
            e.update(Some(10.0));
            a.update(Some(10.0));
        }
        assert!((e.b_hat - 10.0).abs() < (a.b_hat - 10.0).abs());
    }

    #[test]
    fn ewma_recursion_values() {
        let mut e = Ewma::new(0.5);
        e.seed(100.0);
        assert!((e.update(Some(100.0)) - 50.0).abs() < 1e-12);
        assert!((e.update(Some(100.0)) - 75.0).abs() < 1e-12);
        e.update(None); // re-uses 100.0 -> 87.5
        assert!((e.b_hat - 87.5).abs() < 1e-12);
    }

    #[test]
    fn last_observation_is_the_measurement() {
        let mut r = LastObservation::new();
        r.seed(10.0);
        assert_eq!(r.update(Some(42.0)), 42.0);
        assert_eq!(r.update(Some(7.0)), 7.0);
        // no measurement: holds the last one (no decay)
        assert_eq!(r.update(None), 7.0);
    }

    #[test]
    fn never_seeded_stay_zero() {
        assert_eq!(Ewma::paper().update(None), 0.0);
        assert_eq!(LastObservation::new().update(None), 0.0);
    }
}
