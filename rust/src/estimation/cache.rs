//! Process-wide bank variant cache: sweep cells sharing a bank shape
//! pay backend selection once (PR-4 sweep-scale pass).
//!
//! Every grid cell of `cost_grid` / `estimator_grid` / `fleet` sweeps
//! used to re-run [`Bank::with_best_backend`] from scratch: probe the
//! artifacts directory, parse `manifest.json`, create a PJRT client,
//! pick the padded (W, K) variant and lazily compile its executable —
//! per cell, even though the N cells of a grid overwhelmingly share one
//! bank shape. Denninnart & Amini Salehi (arXiv:2104.04474) make the
//! general point for oversubscribed multimedia clouds: reusing
//! functions/artifacts across requests is the dominant cost lever; this
//! module applies it to our own sweep harness.
//!
//! [`BankCache`] is a sharded `RwLock` map keyed by
//! `(W, K, estimator kind, params hash, backend preference)`. A lookup
//! returns a fresh [`Bank`] — per-run estimator *state* (`b_hat`, `pi`)
//! is never shared — but XLA-backed banks reuse one
//! [`SharedEngine`](crate::estimation::bank::SharedEngine), so
//! executable selection/compilation happens once per shape per process
//! and the *negative* probe (artifacts absent → native fallback) is
//! also cached instead of stat-ing the filesystem per cell.
//!
//! Determinism: a cache hit must be indistinguishable from a cold
//! build. Native banks trivially so (the variant carries only the
//! resolved shape); XLA banks execute the identical compiled artifact.
//! `cached_bank_is_bit_identical_to_uncached` pins the bank level;
//! `platform::tests` and the cache-contention sweep test in
//! `tests/determinism.rs` pin whole runs.
//!
//! Concurrency: reads (the steady state once a sweep has warmed the
//! cache) take a shard read lock only; the first builder of a key holds
//! that shard's write lock while resolving, and a loser of the build
//! race observes the winner's entry (`cold_builds` counts each key
//! once). Keys hash-partition across [`N_SHARDS`] shards so concurrent
//! sweep workers with disjoint shapes do not contend on one lock.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use crate::estimation::{Backend, Bank, BankParams, EstimatorKind};
use crate::runtime::Engine;

/// Lock-partition count. Shapes hash across shards, so a sweep whose
/// cells span several shapes never funnels through one lock.
pub const N_SHARDS: usize = 8;

/// Cache key: everything bank construction depends on. `params` enter
/// as f32 bit patterns (exact — no epsilon aliasing of distinct
/// configs), and the artifacts path participates only when XLA is
/// preferred (native banks are path-independent).
///
/// The *driving estimator* is part of the key even though it does not
/// (today) change what [`resolve`] builds: variants are partitioned by
/// estimator so any future estimator-specific bank specialization
/// (e.g. a fused passive-estimator kernel) is cache-correct by
/// construction, and cells driving different estimators never share
/// compilation state. The cost is bounded at one extra cold build per
/// estimator kind per shape (the `estimators` sweep cold-builds 3
/// variants instead of 1); the steady-state sweep pattern — many
/// cells, one estimator — shares maximally, and executions are
/// read-locked either way (see [`crate::estimation::bank::SharedEngine`]).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct VariantKey {
    w: usize,
    k: usize,
    estimator: EstimatorKind,
    params_bits: [u32; 7],
    prefer_xla: bool,
    artifacts_dir: Option<PathBuf>,
}

impl VariantKey {
    fn new(
        w: usize,
        k: usize,
        estimator: EstimatorKind,
        params: &BankParams,
        artifacts_dir: &Path,
        prefer_xla: bool,
    ) -> Self {
        VariantKey {
            w,
            k,
            estimator,
            params_bits: [
                params.sigma_z2.to_bits(),
                params.sigma_v2.to_bits(),
                params.alpha.to_bits(),
                params.beta.to_bits(),
                params.n_min.to_bits(),
                params.n_max.to_bits(),
                params.n_w_max.to_bits(),
            ],
            prefer_xla,
            artifacts_dir: prefer_xla.then(|| artifacts_dir.to_path_buf()),
        }
    }
}

/// One cached backend selection: the resolved (possibly padded) shape
/// plus the backend — for XLA, a [`SharedEngine`] handle whose clone
/// is a reference, never a recompilation. [`BankVariant::instantiate`]
/// mints fresh per-run banks from it.
///
/// The returned `Arc` doubles as the **lockstep batch-group key**
/// (PR-5): two sweep cells may share one padded batch execution iff
/// the cache hands both the *same* `Arc` — same (W, K), params,
/// estimator and backend by construction of [`VariantKey`], so the
/// batched executor (`experiments::batched`) never has to re-derive
/// shape compatibility, and padding agreement on XLA is automatic
/// (the key bakes in the artifact-padded shape).
#[derive(Clone)]
pub struct BankVariant {
    w: usize,
    k: usize,
    params: BankParams,
    backend: Backend,
    name: &'static str,
}

impl std::fmt::Debug for BankVariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BankVariant")
            .field("w", &self.w)
            .field("k", &self.k)
            .field("backend", &self.name)
            .finish()
    }
}

impl BankVariant {
    /// Mint a fresh bank: zeroed estimator state, shared executable.
    pub fn instantiate(&self) -> Bank {
        Bank::new(self.w, self.k, self.params, self.backend.clone())
    }

    /// "xla" or "native".
    pub fn backend_name(&self) -> &'static str {
        self.name
    }
}

/// Hit/cold-build counters, exported into the bench report.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    /// Lookups served from a cached variant.
    pub hits: u64,
    /// Lookups that had to resolve a backend from scratch.
    pub cold_builds: u64,
}

/// Process-wide bank variant cache (see module docs).
#[derive(Debug, Default)]
pub struct BankCache {
    shards: [RwLock<HashMap<VariantKey, Arc<BankVariant>>>; N_SHARDS],
    hits: AtomicU64,
    cold_builds: AtomicU64,
}

impl BankCache {
    /// An empty cache. Sweeps that want attributable stats (bench
    /// report) or isolation (tests) build their own; everything else
    /// shares [`BankCache::global`].
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-wide cache every [`crate::platform::Scenario::run`]
    /// goes through by default.
    pub fn global() -> &'static BankCache {
        static GLOBAL: OnceLock<BankCache> = OnceLock::new();
        GLOBAL.get_or_init(BankCache::new)
    }

    fn shard_of(&self, key: &VariantKey) -> &RwLock<HashMap<VariantKey, Arc<BankVariant>>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % N_SHARDS]
    }

    /// Get (resolving on first use) the variant for a bank request, and
    /// instantiate a fresh bank from it. Drop-in for
    /// [`Bank::with_best_backend`] — same `(Bank, backend-name)`
    /// contract, same fallback semantics.
    pub fn bank(
        &self,
        w: usize,
        k: usize,
        params: BankParams,
        estimator: EstimatorKind,
        artifacts_dir: &Path,
        prefer_xla: bool,
    ) -> (Bank, &'static str) {
        let v = self.variant(w, k, params, estimator, artifacts_dir, prefer_xla);
        (v.instantiate(), v.backend_name())
    }

    /// The cached (or freshly resolved) variant for a bank request.
    pub fn variant(
        &self,
        w: usize,
        k: usize,
        params: BankParams,
        estimator: EstimatorKind,
        artifacts_dir: &Path,
        prefer_xla: bool,
    ) -> Arc<BankVariant> {
        let key = VariantKey::new(w, k, estimator, &params, artifacts_dir, prefer_xla);
        let shard = self.shard_of(&key);
        if let Some(v) = shard.read().expect("bank cache poisoned").get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return v.clone();
        }
        let mut map = shard.write().expect("bank cache poisoned");
        // a racing builder may have won while we waited for the lock
        if let Some(v) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return v.clone();
        }
        let v = Arc::new(resolve(w, k, params, artifacts_dir, prefer_xla));
        self.cold_builds.fetch_add(1, Ordering::Relaxed);
        map.insert(key, v.clone());
        v
    }

    /// Cumulative hit/cold-build counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            cold_builds: self.cold_builds.load(Ordering::Relaxed),
        }
    }

    /// Number of cached variants.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().expect("bank cache poisoned").len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The one copy of the backend-selection logic: probe the artifacts
/// manifest, pick the smallest covering padded shape, fall back to
/// native. [`Bank::with_best_backend`] (the uncached path) and the
/// cache both delegate here, so the two can never drift.
pub(crate) fn resolve(
    w: usize,
    k: usize,
    params: BankParams,
    artifacts_dir: &Path,
    prefer_xla: bool,
) -> BankVariant {
    if prefer_xla {
        if let Ok(engine) = Engine::load(artifacts_dir) {
            // the bank must adopt the artifact's padded (W, K) shape;
            // the caller masks the unused slots
            if let Some(v) = engine.manifest().pick(w, k) {
                let (vw, vk) = (v.w, v.k);
                return BankVariant {
                    w: vw,
                    k: vk,
                    params,
                    backend: Backend::xla(engine),
                    name: "xla",
                };
            }
        }
    }
    BankVariant { w, k, params, backend: Backend::Native, name: "native" }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimation::TickInputs;
    use crate::util::rng::Rng;

    fn params() -> BankParams {
        BankParams {
            sigma_z2: 0.5,
            sigma_v2: 0.5,
            alpha: 5.0,
            beta: 0.9,
            n_min: 10.0,
            n_max: 100.0,
            n_w_max: 10.0,
        }
    }

    fn dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Drive two banks through the same random tick sequence and
    /// require bit-identical outputs and internal state.
    fn assert_banks_identical(mut a: Bank, mut b: Bank, seed: u64) {
        assert_eq!((a.w, a.k), (b.w, b.k));
        let (w, k) = (a.w, a.k);
        let wk = w * k;
        let mut rng = Rng::new(seed);
        for step in 0..40 {
            let slot: Vec<f32> =
                (0..wk).map(|_| if rng.f64() < 0.8 { 1.0 } else { 0.0 }).collect();
            let meas: Vec<f32> = (0..wk)
                .map(|i| if slot[i] > 0.0 && rng.f64() < 0.6 { 1.0 } else { 0.0 })
                .collect();
            let b_tilde: Vec<f32> = (0..wk).map(|_| rng.uniform(0.0, 300.0) as f32).collect();
            let m_rem: Vec<f32> = (0..wk).map(|_| rng.int(0, 500) as f32).collect();
            let d: Vec<f32> = (0..w).map(|_| rng.uniform(60.0, 7620.0) as f32).collect();
            let inp = TickInputs {
                b_tilde: &b_tilde,
                meas_mask: &meas,
                m_rem: &m_rem,
                slot_mask: &slot,
                d: &d,
                n_tot: rng.uniform(1.0, 60.0) as f32,
            };
            let oa = a.step(&inp).unwrap();
            let ob = b.step(&inp).unwrap();
            assert_eq!(oa, ob, "step {step}: cached and uncached banks diverged");
        }
        assert_eq!(a.b_hat(), b.b_hat());
        assert_eq!(a.pi(), b.pi());
    }

    /// The determinism pin: a cache-built bank is bit-identical to the
    /// uncached [`Bank::with_best_backend`] construction, and a cache
    /// *hit* is bit-identical to the cold build it replays.
    #[test]
    fn cached_bank_is_bit_identical_to_uncached() {
        let cache = BankCache::new();
        for prefer_xla in [false, true] {
            let (cold, name_cold) =
                cache.bank(6, 3, params(), EstimatorKind::Kalman, &dir(), prefer_xla);
            let (uncached, name_un) =
                Bank::with_best_backend(6, 3, params(), &dir(), prefer_xla);
            assert_eq!(name_cold, name_un, "cache picked a different backend");
            assert_banks_identical(cold, uncached, 0xCAFE);
            let (hit, _) = cache.bank(6, 3, params(), EstimatorKind::Kalman, &dir(), prefer_xla);
            let (uncached, _) = Bank::with_best_backend(6, 3, params(), &dir(), prefer_xla);
            assert_banks_identical(hit, uncached, 0xF00D);
        }
        let s = cache.stats();
        assert_eq!(s.cold_builds, 2, "one cold build per preference");
        assert_eq!(s.hits, 2);
    }

    #[test]
    fn hits_share_a_variant_but_never_state() {
        let cache = BankCache::new();
        let (mut a, _) = cache.bank(2, 2, params(), EstimatorKind::Kalman, &dir(), false);
        a.step(&TickInputs {
            b_tilde: &[5.0; 4],
            meas_mask: &[1.0; 4],
            m_rem: &[1.0; 4],
            slot_mask: &[1.0; 4],
            d: &[100.0; 2],
            n_tot: 10.0,
        })
        .unwrap();
        assert!(a.estimate(0, 0) > 0.0);
        // a later cell hitting the same variant starts from zeroed state
        let (b, _) = cache.bank(2, 2, params(), EstimatorKind::Kalman, &dir(), false);
        assert_eq!(b.b_hat(), &[0.0; 4][..], "cache leaked estimator state across banks");
    }

    #[test]
    fn distinct_shapes_params_and_estimators_get_distinct_entries() {
        let cache = BankCache::new();
        cache.bank(2, 2, params(), EstimatorKind::Kalman, &dir(), false);
        cache.bank(3, 2, params(), EstimatorKind::Kalman, &dir(), false);
        cache.bank(2, 2, params(), EstimatorKind::Arma, &dir(), false);
        let mut p = params();
        p.alpha = 7.0;
        cache.bank(2, 2, p, EstimatorKind::Kalman, &dir(), false);
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.stats(), CacheStats { hits: 0, cold_builds: 4 });
        // and re-requesting any of them is a hit
        cache.bank(3, 2, params(), EstimatorKind::Kalman, &dir(), false);
        assert_eq!(cache.stats(), CacheStats { hits: 1, cold_builds: 4 });
    }

    #[test]
    fn concurrent_first_use_builds_each_key_once() {
        let cache = BankCache::new();
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..16 {
                        let (bank, _) =
                            cache.bank(4, 2, params(), EstimatorKind::Kalman, &dir(), false);
                        assert_eq!((bank.w, bank.k), (4, 2));
                    }
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.cold_builds, 1, "racing workers must not duplicate the build");
        assert_eq!(s.hits, 8 * 16 - 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn global_cache_is_one_instance() {
        assert!(std::ptr::eq(BankCache::global(), BankCache::global()));
    }
}
