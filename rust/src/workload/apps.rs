//! Application execution-time models — the stand-ins for the real
//! multimedia binaries the paper runs (Viola-Jones, FFMPEG, OpenCV BRISK,
//! Matlab SIFT, ImageMagick JS, CNN ensembles, word histogram).
//!
//! The control plane only ever observes per-task *durations* (CUSs), so a
//! faithful substitute must reproduce the statistical properties the
//! paper's estimators fight against:
//!   * data-dependent, right-skewed durations (lognormal per item);
//!   * per-chunk environment-setup "deadband" time — dominant for
//!     Matlab-compiled SIFT (§II-E-1), mandating large chunks;
//!   * non-representative footprinting: the paper reports initial
//!     estimates up to 50 % above the converged value for face detection
//!     and transcoding; we model it as a bias factor applied to the items
//!     sampled by the footprinting stage.
//!
//! ImageMagick means are derived from Table IV's Lambda billing backwards
//! (billed GB-seconds -> wall seconds at 0.5 core -> full-core seconds):
//! blur 1.42 s, convolve 0.50 s, rotate 0.16 s per image.

use crate::util::rng::Rng;

/// Application classes appearing in §V.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum App {
    /// Viola-Jones face detection (C++), §V-A.
    FaceDetection,
    /// FFMPEG video transcoding, §V-A.
    Transcode,
    /// OpenCV BRISK keypoint extraction, §V-A.
    Brisk,
    /// Matlab-compiled SIFT (deploytool + MCR), §V-A.
    SiftMatlab,
    /// ImageMagick blur (JS build), §V-D.
    ImBlur,
    /// ImageMagick convolve, §V-D.
    ImConvolve,
    /// ImageMagick rotate, §V-D.
    ImRotate,
    /// Deep-CNN ensemble image classification (Split step), §V-E.
    CnnClassify,
    /// Word-histogram text processing (Split step), §V-E.
    WordHistogram,
}

/// Statistical model of one application's per-item behaviour.
#[derive(Debug, Clone, PartialEq)]
pub struct AppModel {
    pub app: App,
    pub name: &'static str,
    /// Mean full-core seconds (CUSs) per media item.
    pub mean_cus: f64,
    /// Coefficient of variation of per-item duration (data dependence).
    pub cv: f64,
    /// Environment-setup time per chunk invocation, seconds ("deadband").
    pub deadband_s: f64,
    /// Mean input size per item, bytes.
    pub mean_item_bytes: f64,
    /// CV of item size.
    pub size_cv: f64,
    /// Multiplier applied to footprint-sampled durations (sampling bias).
    pub footprint_bias: f64,
}

/// Catalogue of all §V application models.
pub const APP_MODELS: &[AppModel] = &[
    AppModel { app: App::FaceDetection, name: "face-detection", mean_cus: 2.0, cv: 0.6, deadband_s: 0.5, mean_item_bytes: 1.5e6, size_cv: 0.6, footprint_bias: 1.5 },
    AppModel { app: App::Transcode, name: "transcode", mean_cus: 60.0, cv: 0.5, deadband_s: 1.0, mean_item_bytes: 40e6, size_cv: 0.5, footprint_bias: 1.5 },
    AppModel { app: App::Brisk, name: "brisk", mean_cus: 1.0, cv: 0.4, deadband_s: 0.3, mean_item_bytes: 1.2e6, size_cv: 0.5, footprint_bias: 1.1 },
    AppModel { app: App::SiftMatlab, name: "sift-matlab", mean_cus: 6.0, cv: 0.4, deadband_s: 30.0, mean_item_bytes: 2.0e6, size_cv: 0.5, footprint_bias: 1.2 },
    AppModel { app: App::ImBlur, name: "im-blur", mean_cus: 1.42, cv: 0.5, deadband_s: 0.2, mean_item_bytes: 1.0e6, size_cv: 0.8, footprint_bias: 1.1 },
    AppModel { app: App::ImConvolve, name: "im-convolve", mean_cus: 0.50, cv: 0.5, deadband_s: 0.2, mean_item_bytes: 1.0e6, size_cv: 0.8, footprint_bias: 1.1 },
    AppModel { app: App::ImRotate, name: "im-rotate", mean_cus: 0.16, cv: 0.5, deadband_s: 0.2, mean_item_bytes: 1.0e6, size_cv: 0.8, footprint_bias: 1.1 },
    AppModel { app: App::CnnClassify, name: "cnn-classify", mean_cus: 4.0, cv: 0.3, deadband_s: 10.0, mean_item_bytes: 0.15e6, size_cv: 0.4, footprint_bias: 1.15 },
    AppModel { app: App::WordHistogram, name: "word-histogram", mean_cus: 0.8, cv: 0.7, deadband_s: 0.3, mean_item_bytes: 0.4e6, size_cv: 1.0, footprint_bias: 1.05 },
];

pub fn model(app: App) -> &'static AppModel {
    APP_MODELS.iter().find(|m| m.app == app).expect("unknown app")
}

impl AppModel {
    /// Workload-level mean CUS: each submitted workload has its own
    /// characteristic item cost (different codecs, image resolutions...),
    /// drawn once per workload around the app mean.
    pub fn workload_mean(&self, rng: &mut Rng) -> f64 {
        self.mean_cus * rng.uniform(0.7, 1.4)
    }

    /// Full-core seconds for one item. Deterministic per rng substream.
    pub fn task_cus(&self, workload_mean: f64, rng: &mut Rng) -> f64 {
        rng.lognormal_mean_cv(workload_mean, self.cv).max(1e-3)
    }

    /// Input bytes for one item.
    pub fn item_bytes(&self, rng: &mut Rng) -> u64 {
        rng.lognormal_mean_cv(self.mean_item_bytes, self.size_cv).max(1.0) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalogue_covers_all_apps() {
        for app in [
            App::FaceDetection,
            App::Transcode,
            App::Brisk,
            App::SiftMatlab,
            App::ImBlur,
            App::ImConvolve,
            App::ImRotate,
            App::CnnClassify,
            App::WordHistogram,
        ] {
            assert_eq!(model(app).app, app);
        }
        assert_eq!(APP_MODELS.len(), 9);
    }

    #[test]
    fn imagemagick_means_derived_from_table_iv() {
        // Lambda Table IV reverse-engineering: blur must be the heaviest,
        // rotate the lightest, by the paper's ratios (~2.8x and ~9x).
        let blur = model(App::ImBlur).mean_cus;
        let conv = model(App::ImConvolve).mean_cus;
        let rot = model(App::ImRotate).mean_cus;
        assert!(blur > conv && conv > rot);
        assert!((blur / conv - 2.84).abs() < 0.1);
        assert!((blur / rot - 8.9).abs() < 0.3);
    }

    #[test]
    fn sift_deadband_dominates_single_items() {
        // §II-E-1: Matlab setup time dwarfs one item's compute.
        let m = model(App::SiftMatlab);
        assert!(m.deadband_s > m.mean_cus);
    }

    #[test]
    fn task_cus_mean_converges_to_workload_mean() {
        let m = model(App::FaceDetection);
        let mut rng = Rng::new(5);
        let wm = 2.5;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| m.task_cus(wm, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean - wm).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn task_cus_is_positive_and_deterministic() {
        let m = model(App::Transcode);
        let root = Rng::new(9);
        let a = m.task_cus(60.0, &mut root.substream(3));
        let b = m.task_cus(60.0, &mut root.substream(3));
        assert_eq!(a, b);
        assert!(a > 0.0);
    }

    #[test]
    fn workload_mean_within_bounds() {
        let m = model(App::Brisk);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let wm = m.workload_mean(&mut rng);
            assert!(wm >= m.mean_cus * 0.7 - 1e-9 && wm <= m.mean_cus * 1.4 + 1e-9);
        }
    }

    #[test]
    fn footprint_bias_reflects_paper_anecdote() {
        // face detection / transcoding footprint estimates ~50% high
        assert_eq!(model(App::FaceDetection).footprint_bias, 1.5);
        assert_eq!(model(App::Transcode).footprint_bias, 1.5);
        assert!(model(App::WordHistogram).footprint_bias < 1.1);
    }
}
