//! The §V-A experimental workload suite (Fig. 5) and §V-D/E workloads.
//!
//! Thirty workloads, introduced one every five minutes, in a fixed order:
//!   * 8 Viola-Jones face detection, 1–1000 images each;
//!   * 8 FFMPEG transcoding: six with 1–20 videos plus two large ones
//!     (200 and 300 videos) to stress sudden demand spikes;
//!   * 7 OpenCV BRISK feature extraction;
//!   * 7 Matlab-compiled SIFT (long deadband).
//!
//! Counts are random per workload but deterministic in the suite seed, so
//! `repro fig5` regenerates the same bar chart every run.

use crate::util::rng::Rng;
use crate::workload::apps::App;
use crate::workload::spec::{Mode, WorkloadSpec};

/// Interval between workload arrivals (§V-A: "once every five minutes").
pub const ARRIVAL_INTERVAL_S: u64 = 300;

/// Generate the 30-workload suite of Fig. 5.
pub fn paper_suite(seed: u64) -> Vec<WorkloadSpec> {
    let rng = Rng::new(seed ^ 0xF16_5);
    let mut counts = Vec::new();

    // 8 face detection: 1..=1000 images
    let mut crng = rng.substream(1);
    for _ in 0..8 {
        counts.push((App::FaceDetection, crng.int(1, 1000) as usize));
    }
    // 8 transcoding: 6 small (1..=20) + the 200- and 300-video spikes
    for _ in 0..6 {
        counts.push((App::Transcode, crng.int(1, 20) as usize));
    }
    counts.push((App::Transcode, 200));
    counts.push((App::Transcode, 300));
    // 7 BRISK
    for _ in 0..7 {
        counts.push((App::Brisk, crng.int(50, 800) as usize));
    }
    // 7 SIFT
    for _ in 0..7 {
        counts.push((App::SiftMatlab, crng.int(50, 800) as usize));
    }

    // interleave the classes (the paper submits mixed types over time);
    // deterministic shuffle, but keep the two transcode spikes around the
    // middle of the arrival order so they hit a warm platform (§V-A uses
    // them to test responsiveness under sudden load).
    let mut order: Vec<usize> = (0..counts.len()).collect();
    let mut srng = rng.substream(2);
    srng.shuffle(&mut order);
    // move spike workloads (indices 14, 15 in `counts`) to arrival slots 12 and 18
    let spike_a = order.iter().position(|&i| i == 14).unwrap();
    let spike_b = order.iter().position(|&i| i == 15).unwrap();
    order.swap(spike_a, 12);
    let spike_b = if spike_b == 12 { spike_a } else { spike_b };
    order.swap(spike_b, 18);

    order
        .iter()
        .enumerate()
        .map(|(slot, &ci)| {
            let (app, n) = counts[ci];
            WorkloadSpec::generate(slot, app, n, None, &rng)
        })
        .collect()
}

/// §V-D: one 25 000-image ImageMagick workload per function.
pub fn lambda_suite(seed: u64, n_images: usize) -> Vec<WorkloadSpec> {
    let rng = Rng::new(seed ^ 0x1A3B_DA);
    vec![
        WorkloadSpec::generate(0, App::ImBlur, n_images, None, &rng),
        WorkloadSpec::generate(1, App::ImConvolve, n_images, None, &rng),
        WorkloadSpec::generate(2, App::ImRotate, n_images, None, &rng),
    ]
}

/// §V-E example 1: deep-CNN ensemble classification as Split–Merge.
/// Holidays dataset (1491 images) + 50 000 ImageNet images.
pub fn cnn_splitmerge(seed: u64) -> WorkloadSpec {
    let rng = Rng::new(seed ^ 0xC44);
    WorkloadSpec::generate_mode(
        0,
        App::CnnClassify,
        1491 + 5000, // scaled 10x down from 50k to keep sim runtime sane;
        // scaling is uniform so cost *shape* (Fig. 10) is preserved
        Mode::SplitMerge { merge_frac: 0.05 },
        None,
        &rng,
    )
}

/// §V-E example 2: word-histogram over ~14 000 Gutenberg texts (5.5 GB).
pub fn wordcount_splitmerge(seed: u64) -> WorkloadSpec {
    let rng = Rng::new(seed ^ 0x90D);
    WorkloadSpec::generate_mode(
        0,
        App::WordHistogram,
        14_000,
        Mode::SplitMerge { merge_frac: 0.03 },
        None,
        &rng,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_paper_composition() {
        let suite = paper_suite(1);
        assert_eq!(suite.len(), 30);
        let count = |app: App| suite.iter().filter(|w| w.app == app).count();
        assert_eq!(count(App::FaceDetection), 8);
        assert_eq!(count(App::Transcode), 8);
        assert_eq!(count(App::Brisk), 7);
        assert_eq!(count(App::SiftMatlab), 7);
    }

    #[test]
    fn spikes_present_and_positioned() {
        let suite = paper_suite(1);
        let sizes: Vec<usize> = suite
            .iter()
            .filter(|w| w.app == App::Transcode)
            .map(|w| w.n_tasks())
            .collect();
        assert!(sizes.contains(&200) && sizes.contains(&300));
        // the spike workloads arrive mid-experiment
        let spike_slots: Vec<usize> = suite
            .iter()
            .filter(|w| w.n_tasks() >= 200 && w.app == App::Transcode)
            .map(|w| w.id)
            .collect();
        assert_eq!(spike_slots, vec![12, 18]);
    }

    #[test]
    fn face_detection_counts_in_range() {
        let suite = paper_suite(2);
        for w in suite.iter().filter(|w| w.app == App::FaceDetection) {
            assert!((1..=1000).contains(&w.n_tasks()));
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = paper_suite(7);
        let b = paper_suite(7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.app, y.app);
            assert_eq!(x.n_tasks(), y.n_tasks());
        }
        let c = paper_suite(8);
        let same = a.iter().zip(&c).all(|(x, y)| x.n_tasks() == y.n_tasks());
        assert!(!same);
    }

    #[test]
    fn ids_are_arrival_slots() {
        let suite = paper_suite(3);
        for (i, w) in suite.iter().enumerate() {
            assert_eq!(w.id, i);
        }
    }

    #[test]
    fn lambda_suite_is_three_functions() {
        let s = lambda_suite(1, 1000);
        assert_eq!(s.len(), 3);
        assert!(s.iter().all(|w| w.n_tasks() == 1000));
    }

    #[test]
    fn splitmerge_specs_are_splitmerge() {
        assert!(matches!(cnn_splitmerge(1).mode, Mode::SplitMerge { .. }));
        assert!(matches!(wordcount_splitmerge(1).mode, Mode::SplitMerge { .. }));
        assert_eq!(wordcount_splitmerge(1).n_tasks(), 14_000);
    }

    #[test]
    fn total_cus_budget_plausible_for_paper_scale() {
        // The whole suite should land in the tens of thousands of CUSs —
        // the scale a ~dozen m3.medium instances chew through in ~2 h.
        let suite = paper_suite(1);
        let total: f64 = suite.iter().map(|w| w.total_true_cus()).sum();
        assert!(
            (20_000.0..200_000.0).contains(&total),
            "total CUS {total} out of plausible band"
        );
    }
}
