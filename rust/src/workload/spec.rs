//! Workload specifications (Fig. 2's structure, §II-B's processing modes).
//!
//! A workload = application code + N independently-processable media
//! inputs (basic mode), optionally with a Merge step (advanced
//! Split–Merge mode). Tasks carry pre-drawn true durations and sizes so
//! every run is deterministic in the master seed; the platform only ever
//! *observes* durations through task execution, never reads them
//! directly.

use crate::util::rng::Rng;
use crate::workload::apps::{model, App, AppModel};

/// Processing mode (§II-B-1 / §II-B-2).
#[derive(Debug, Clone, PartialEq)]
pub enum Mode {
    /// Every input processed independently; results to storage.
    Basic,
    /// Split step over inputs + Merge step aggregating the results on a
    /// designated instance (main_split.sh / main_merge.sh).
    SplitMerge {
        /// Merge compute time as a fraction of total split CUS.
        merge_frac: f64,
    },
}

/// One media-processing task (one input item).
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// True full-core seconds this item needs (hidden from the platform).
    pub true_cus: f64,
    /// Input size in bytes.
    pub bytes: u64,
    /// Media-type index within the workload.
    pub media_type: usize,
}

/// A complete workload specification.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub id: usize,
    pub app: App,
    pub name: String,
    pub mode: Mode,
    /// Number of media types (paper workloads: 1).
    pub n_types: usize,
    pub tasks: Vec<TaskSpec>,
    /// True mean CUS per item per media type (ground truth for MAE).
    pub true_mean_cus: Vec<f64>,
    /// Requested TTC in seconds (None = platform allocates).
    pub requested_ttc: Option<u64>,
}

impl WorkloadSpec {
    /// Generate a single-type workload of `n_items` for `app`.
    /// Deterministic in (seed-derived rng, id).
    pub fn generate(
        id: usize,
        app: App,
        n_items: usize,
        requested_ttc: Option<u64>,
        rng: &Rng,
    ) -> WorkloadSpec {
        Self::generate_mode(id, app, n_items, Mode::Basic, requested_ttc, rng)
    }

    pub fn generate_mode(
        id: usize,
        app: App,
        n_items: usize,
        mode: Mode,
        requested_ttc: Option<u64>,
        rng: &Rng,
    ) -> WorkloadSpec {
        let m: &AppModel = model(app);
        let mut wrng = rng.substream(0x60D0 + id as u64);
        let wmean = m.workload_mean(&mut wrng);
        let tasks: Vec<TaskSpec> = (0..n_items)
            .map(|t| {
                let mut trng = wrng.substream(t as u64);
                TaskSpec {
                    true_cus: m.task_cus(wmean, &mut trng),
                    bytes: m.item_bytes(&mut trng),
                    media_type: 0,
                }
            })
            .collect();
        WorkloadSpec {
            id,
            app,
            name: format!("w{id:02}-{}", m.name),
            mode,
            n_types: 1,
            tasks,
            true_mean_cus: vec![wmean],
            requested_ttc,
        }
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Total input bytes (the Fig. 5 y-axis).
    pub fn total_bytes(&self) -> u64 {
        self.tasks.iter().map(|t| t.bytes).sum()
    }

    /// Total true CUSs (used by the lower-bound cost).
    pub fn total_true_cus(&self) -> f64 {
        let base: f64 = self.tasks.iter().map(|t| t.true_cus).sum();
        match self.mode {
            Mode::Basic => base,
            Mode::SplitMerge { merge_frac } => base * (1.0 + merge_frac),
        }
    }

    /// Empirical mean item duration per media type — the "final measured
    /// value" the paper's Table II MAE is computed against.
    pub fn empirical_mean_cus(&self, media_type: usize) -> f64 {
        let xs: Vec<f64> = self
            .tasks
            .iter()
            .filter(|t| t.media_type == media_type)
            .map(|t| t.true_cus)
            .collect();
        crate::util::stats::mean(&xs)
    }

    /// The application model behind this workload.
    pub fn app_model(&self) -> &'static AppModel {
        model(self.app)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let rng = Rng::new(11);
        let a = WorkloadSpec::generate(3, App::FaceDetection, 100, None, &rng);
        let b = WorkloadSpec::generate(3, App::FaceDetection, 100, None, &rng);
        assert_eq!(a.tasks.len(), b.tasks.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.true_cus, y.true_cus);
            assert_eq!(x.bytes, y.bytes);
        }
    }

    #[test]
    fn different_ids_differ() {
        let rng = Rng::new(11);
        let a = WorkloadSpec::generate(1, App::Brisk, 50, None, &rng);
        let b = WorkloadSpec::generate(2, App::Brisk, 50, None, &rng);
        assert_ne!(a.tasks[0].true_cus, b.tasks[0].true_cus);
    }

    #[test]
    fn empirical_mean_tracks_workload_mean() {
        let rng = Rng::new(4);
        let w = WorkloadSpec::generate(0, App::Transcode, 2000, None, &rng);
        let emp = w.empirical_mean_cus(0);
        let true_mean = w.true_mean_cus[0];
        assert!((emp / true_mean - 1.0).abs() < 0.1, "emp={emp} true={true_mean}");
    }

    #[test]
    fn split_merge_adds_merge_cost() {
        let rng = Rng::new(5);
        let basic = WorkloadSpec::generate(0, App::CnnClassify, 100, None, &rng);
        let sm = WorkloadSpec::generate_mode(
            0,
            App::CnnClassify,
            100,
            Mode::SplitMerge { merge_frac: 0.1 },
            None,
            &rng,
        );
        assert!((sm.total_true_cus() / basic.total_true_cus() - 1.1).abs() < 1e-9);
    }

    #[test]
    fn totals_are_positive() {
        let rng = Rng::new(6);
        let w = WorkloadSpec::generate(7, App::SiftMatlab, 10, Some(3600), &rng);
        assert!(w.total_bytes() > 0);
        assert!(w.total_true_cus() > 0.0);
        assert_eq!(w.requested_ttc, Some(3600));
        assert_eq!(w.n_tasks(), 10);
    }
}
