//! Workload substrate: application duration models, workload specs, the
//! paper's experimental suites, and Split–Merge structure.

pub mod apps;
pub mod generator;
pub mod spec;

pub use apps::{model as app_model, App, AppModel, APP_MODELS};
pub use generator::{
    cnn_splitmerge, lambda_suite, paper_suite, wordcount_splitmerge, ARRIVAL_INTERVAL_S,
};
pub use spec::{Mode, TaskSpec, WorkloadSpec};
