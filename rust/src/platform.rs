//! The integrated Dithen platform: GCI monitoring loop over the simulated
//! substrates (Fig. 1's architecture, end to end).
//!
//! One [`Platform::run`] call executes a complete experiment: workloads
//! arrive at the front end, are footprinted, estimated (Kalman bank on
//! the XLA/PJRT hot path), scheduled with proportional-fair service rates
//! through the tracker, while the scaling policy (AIMD or a baseline)
//! grows/shrinks the spot fleet. Everything is deterministic in
//! `Config::seed`.
//!
//! Perf (§Perf): the monitoring tick is allocation-free in steady state.
//! All per-tick working sets — the bank's input matrices, its outputs,
//! the service-rate scratch, estimator slots, last-measurement cache and
//! measurement-log cursors — are dense `w*K+k`-indexed arrays owned by
//! the platform and reused across ticks; the task DB serves every tick
//! query (status counts, m_{w,k}, measurement windows) from borrowed
//! slices of its flat arenas. `tests/alloc_steady_state.rs` pins this
//! with a counting global allocator.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::cloud::Provider;
use crate::config::Config;
use crate::coordinator::policy::{PolicyCtx, PolicyKind, ScalingPolicy};
use crate::coordinator::{
    chunk_size, confirm, footprint_count, service_rates_into, Tracker,
};
use crate::db::{TaskDb, TaskStatus};
use crate::estimation::{
    AdHoc, Arma, Bank, BankParams, DeviationDetector, EstimatorKind, SlopeDetector,
};
use crate::lci::{execute_chunk, Chunk};
use crate::metrics::{EstimatorTrace, RunMetrics, WorkloadOutcome};
use crate::runtime::StepOutputs;
use crate::sim::{Engine as SimEngine, Event, SimTime};
use crate::storage::ObjectStore;
use crate::workload::{Mode, WorkloadSpec};

/// Run options for one experiment.
#[derive(Debug, Clone)]
pub struct RunOpts {
    pub policy: PolicyKind,
    /// Which estimator drives service rates (Table II comparisons). The
    /// Kalman bank always runs (it is the platform hot path); ad-hoc and
    /// ARMA estimators additionally run passively on the same
    /// measurement stream so Fig. 6/7 can overlay all three.
    pub estimator: EstimatorKind,
    /// Fixed TTC applied to every workload (the §V-C experiments), or
    /// None for best-effort (Amazon AS runs).
    pub fixed_ttc_s: Option<u64>,
    /// Seconds between workload arrivals.
    pub arrival_interval_s: u64,
    /// Hard stop (safety bound for tests).
    pub horizon_s: u64,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            policy: PolicyKind::Aimd,
            estimator: EstimatorKind::Kalman,
            fixed_ttc_s: Some(7620), // 2 hr 07 min (§V-C experiment 1)
            arrival_interval_s: crate::workload::ARRIVAL_INTERVAL_S,
            horizon_s: 24 * 3600,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WlPhase {
    /// Waiting for / executing footprinting tasks.
    Footprinting,
    /// Normal task execution with estimation.
    Running,
    /// Split done, merge step pending or executing (Split–Merge mode).
    Merging,
    Done,
}

/// Per-(workload, media-type) estimation state. Stored densely at
/// `w * k_max + k`; slots outside a workload's `n_types` are inert.
#[derive(Debug)]
struct SlotEst {
    adhoc: AdHoc,
    arma: Arma,
    kalman_det: SlopeDetector,
    adhoc_det: SlopeDetector,
    arma_det: DeviationDetector,
    /// Cumulative measured CUS and completed count (ARMA normalization).
    cum_cus: f64,
    cum_done: usize,
    seeded: bool,
}

#[derive(Debug)]
struct WlState {
    phase: WlPhase,
    arrived_at: SimTime,
    deadline: Option<SimTime>,
    ttc_extended: bool,
    confirmed: bool,
    /// Footprint task ids not yet dispatched / completed.
    footprint_pending: Vec<usize>,
    footprint_outstanding: usize,
    footprint_meas: Vec<f64>,
    completed_tasks: usize,
    completed_at: Option<SimTime>,
    /// Busy seconds of all executed split chunks (merge time derivation).
    split_busy: f64,
    merge_dispatched: bool,
    merge_instance: Option<u64>,
}

/// Per-tick scratch buffers, `mem::take`n at tick entry and returned at
/// exit so the borrow checker sees them as locals. Sized once (bank
/// dims / workload count), then only `fill`ed.
#[derive(Debug, Default)]
struct TickScratch {
    // bank inputs, [bank.w * bank.k] / [bank.w]
    b_tilde: Vec<f32>,
    meas_mask: Vec<f32>,
    m_rem: Vec<f32>,
    slot_mask: Vec<f32>,
    d: Vec<f32>,
    // workloads whose driving estimator converged this tick
    converged: Vec<usize>,
    // non-Kalman service-rate scratch, [n_w]
    r: Vec<f64>,
    dd: Vec<f64>,
    active: Vec<bool>,
    rates_tmp: Vec<f64>,
}

/// The assembled platform.
pub struct Platform {
    cfg: Config,
    opts: RunOpts,
    sim: SimEngine,
    provider: Provider,
    storage: ObjectStore,
    db: TaskDb,
    bank: Bank,
    tracker: Tracker,
    policy: Box<dyn ScalingPolicy>,
    specs: Vec<WorkloadSpec>,
    wl: Vec<WlState>,
    /// Dense estimator slots, `w * k_max + k`.
    est: Vec<SlotEst>,
    /// Per-slot count of DB measurements already consumed by a tick —
    /// the ME reads `db.measurements(w, k)[cursor..]` as "completed
    /// since the last monitoring instant".
    meas_cursor: Vec<usize>,
    /// Last interval-mean measurement per slot (NaN = none yet) —
    /// reused when an interval produces no completions (eq. 8 uses
    /// b̃[t-1]).
    last_meas: Vec<f32>,
    chunks: BTreeMap<u64, Chunk>,
    next_chunk_id: u64,
    /// Latest service rates, indexed by workload id.
    rates: Vec<f64>,
    n_star_history: Vec<f64>,
    last_policy_eval: SimTime,
    k_max: usize,
    scratch: TickScratch,
    outs: StepOutputs,
    /// Reused idle-instance id buffer for `assign_idle`.
    idle_buf: Vec<u64>,
    metrics: RunMetrics,
    arrived: usize,
    all_done_at: Option<SimTime>,
}

impl Platform {
    /// Build a platform over `specs` (workload `id`s must be their
    /// arrival slots: 0, 1, 2, ...).
    pub fn new(cfg: Config, specs: Vec<WorkloadSpec>, opts: RunOpts) -> Platform {
        let n_w = specs.len().max(1);
        let k_max = specs.iter().map(|s| s.n_types).max().unwrap_or(1).max(1);
        let params = BankParams::from_config(&cfg.control);
        let (bank, _backend) = Bank::with_best_backend(
            n_w,
            k_max,
            params,
            std::path::Path::new(&cfg.artifacts_dir),
            cfg.use_xla,
        );
        let horizon_h = (opts.horizon_s / 3600 + 2) as usize;
        let provider = Provider::new(cfg.market.clone(), cfg.seed, horizon_h);
        let storage = ObjectStore::new(cfg.storage.clone());
        let tracker = Tracker::new(cfg.control.n_w_max);
        let policy = opts.policy.build(&cfg.control);
        let wl: Vec<WlState> = specs
            .iter()
            .map(|_| WlState {
                phase: WlPhase::Footprinting,
                arrived_at: 0,
                deadline: None,
                ttc_extended: false,
                confirmed: false,
                footprint_pending: vec![],
                footprint_outstanding: 0,
                footprint_meas: vec![],
                completed_tasks: 0,
                completed_at: None,
                split_busy: 0.0,
                merge_dispatched: false,
                merge_instance: None,
            })
            .collect();
        let n_slots = specs.len() * k_max;
        let est: Vec<SlotEst> = (0..n_slots)
            .map(|_| SlotEst {
                adhoc: AdHoc::paper(),
                arma: Arma::paper(),
                kalman_det: SlopeDetector::new(),
                adhoc_det: SlopeDetector::new(),
                arma_det: DeviationDetector::paper(cfg.control.monitor_interval_s),
                cum_cus: 0.0,
                cum_done: 0,
                seeded: false,
            })
            .collect();
        let n_real = specs.len();
        Platform {
            cfg,
            opts,
            sim: SimEngine::new(),
            provider,
            storage,
            db: TaskDb::new(),
            bank,
            tracker,
            policy,
            specs,
            wl,
            est,
            meas_cursor: vec![0; n_slots],
            last_meas: vec![f32::NAN; n_slots],
            chunks: BTreeMap::new(),
            next_chunk_id: 0,
            rates: vec![0.0; n_real],
            n_star_history: vec![],
            last_policy_eval: 0,
            k_max,
            scratch: TickScratch::default(),
            outs: StepOutputs::default(),
            idle_buf: vec![],
            metrics: RunMetrics::default(),
            arrived: 0,
            all_done_at: None,
        }
    }

    /// Name of the estimator-bank backend in use ("xla" or "native").
    pub fn backend_name(&self) -> &'static str {
        self.bank.backend_name()
    }

    /// Execute the experiment to completion; returns the metrics.
    pub fn run(mut self) -> Result<RunMetrics> {
        // bootstrap fleet at N_min (AS starts from the same launch group)
        let initial = self.cfg.control.n_min as usize;
        for _ in 0..initial {
            self.request_instance();
        }
        // workload arrivals
        for w in 0..self.specs.len() {
            self.sim
                .schedule(w as u64 * self.opts.arrival_interval_s, Event::WorkloadArrival {
                    workload: w,
                });
        }
        // first monitoring tick
        self.sim
            .schedule(self.cfg.control.monitor_interval_s, Event::MonitorTick);

        while let Some((now, event)) = self.sim.next() {
            if now > self.opts.horizon_s {
                break;
            }
            match event {
                Event::WorkloadArrival { workload } => self.on_arrival(workload)?,
                Event::InstanceReady { instance } => self.on_instance_ready(instance),
                Event::ChunkDone { instance, chunk } => self.on_chunk_done(instance, chunk),
                Event::MergeDone { workload } => self.on_merge_done(workload),
                Event::MonitorTick => self.on_tick()?,
                Event::FootprintDone { .. } => {} // handled inline
            }
            if self.all_done_at.is_some() {
                break;
            }
        }

        // wind down: terminate everything, settle billing
        let now = self.sim.now();
        let ids: Vec<u64> = self.provider.instances().map(|i| i.id).collect();
        for id in ids {
            self.provider.terminate_instance(id, now);
        }
        self.provider.bill_through(now);
        self.metrics.total_cost = self.provider.total_cost();
        self.metrics.cost_curve = self.provider.cost_curve().to_vec();
        self.metrics.finished_at = self.all_done_at.unwrap_or(now);
        self.metrics.outcomes = self
            .wl
            .iter()
            .enumerate()
            .map(|(w, st)| WorkloadOutcome {
                arrived_at: st.arrived_at,
                completed_at: st.completed_at,
                deadline: st.deadline,
                ttc_extended: st.ttc_extended,
                n_tasks: self.specs[w].n_tasks(),
                total_bytes: self.specs[w].total_bytes(),
            })
            .collect();
        // finalize estimator traces with ground truth
        for ((w, k), trace) in self.metrics.traces.iter_mut() {
            let log = self.db.measurements(*w, *k);
            if !log.is_empty() {
                let sum: f64 = log.iter().map(|&(_, c)| c).sum();
                trace.final_measured = Some(sum / log.len() as f64);
            }
        }
        Ok(self.metrics)
    }

    // ----- event handlers -------------------------------------------------

    fn on_arrival(&mut self, w: usize) -> Result<()> {
        let now = self.sim.now();
        self.arrived += 1;
        let spec = &self.specs[w];
        // upload inputs to storage (bookkeeping; transfer happens per chunk)
        for (t, task) in spec.tasks.iter().enumerate() {
            self.storage
                .put(&format!("w{w:02}/input/item{t:06}"), task.bytes);
            self.db.insert(w, task.media_type, t);
        }
        // pre-size the measurement logs: steady-state completions must
        // not reallocate (§Perf)
        self.db.reserve_measurements(w);
        let st = &mut self.wl[w];
        st.arrived_at = now;
        st.deadline = self.opts.fixed_ttc_s.map(|d| now + d);
        // footprinting: first F tasks (the paper samples a small
        // percentage of the inputs)
        let f = footprint_count(
            spec.n_tasks(),
            self.cfg.control.footprint_frac,
            self.cfg.control.footprint_min,
            self.cfg.control.footprint_max,
        );
        st.footprint_pending = (0..f).collect();
        st.phase = WlPhase::Footprinting;
        self.tracker.register(w);
        for k in 0..spec.n_types {
            self.metrics
                .traces
                .entry((w, k))
                .or_insert_with(EstimatorTrace::default);
        }
        self.assign_idle();
        Ok(())
    }

    fn on_instance_ready(&mut self, id: u64) {
        let now = self.sim.now();
        self.provider.instance_ready(id, now);
        self.sample_instances(now);
        self.assign_idle();
    }

    fn on_chunk_done(&mut self, instance: u64, chunk_id: u64) {
        let now = self.sim.now();
        let chunk = match self.chunks.remove(&chunk_id) {
            Some(c) => c,
            None => return,
        };
        let w = chunk.workload;
        let spec = &self.specs[w];
        // re-derive the result (deterministic) to record measurements
        let result = execute_chunk(spec, &chunk.tasks, chunk.footprint, &self.storage);
        for (i, &t) in chunk.tasks.iter().enumerate() {
            let cus = result.per_task_cus[i];
            let k = spec.tasks[t].media_type;
            self.db.complete((w, t), cus, now, result.exit_code);
            // abnormal exits (§II-A) feed neither estimator: the DB
            // measurement log (the Kalman b_tilde source) only records
            // completed tasks, and the ARMA cumulative feed must stay
            // consistent with it
            if result.exit_code == 0 {
                let est = &mut self.est[w * self.k_max + k];
                est.cum_cus += cus;
                est.cum_done += 1;
            }
            self.storage
                .put(&format!("w{w:02}/output/item{t:06}"), (spec.tasks[t].bytes as f64 * 0.3) as u64);
        }
        self.metrics.total_busy_cus += result.busy_s;
        let st = &mut self.wl[w];
        st.completed_tasks += chunk.tasks.len();
        st.split_busy += result.busy_s;
        if chunk.footprint {
            st.footprint_outstanding -= chunk.tasks.len();
            st.footprint_meas
                .extend(chunk.tasks.iter().enumerate().map(|(i, _)| result.per_task_cus[i]));
            if st.footprint_outstanding == 0 && st.footprint_pending.is_empty() {
                self.finish_footprinting(w);
            }
        }
        // instance becomes free (or dies if draining)
        if let Some(inst) = self.provider.instance_mut(instance) {
            inst.finish_chunk(now, result.busy_s.ceil() as SimTime);
        }
        self.tracker.on_release(w);
        self.update_pending_flag(w);
        self.check_workload_done(w);
        self.assign_idle();
    }

    fn finish_footprinting(&mut self, w: usize) {
        let now = self.sim.now();
        let st = &mut self.wl[w];
        st.phase = WlPhase::Running;
        // seed estimators with the footprinting mean (b̃[0], §II-E-3)
        let seed = crate::util::stats::mean(&st.footprint_meas);
        let spec = &self.specs[w];
        for k in 0..spec.n_types {
            let est = &mut self.est[w * self.k_max + k];
            est.adhoc.seed(seed);
            est.seeded = true;
            // the bank's slot sees the seed as its first measurement at
            // the next tick through the measurement-log cursor (the
            // footprint completions are already in the DB log)
        }
        let _ = now;
        self.update_pending_flag(w);
    }

    fn on_merge_done(&mut self, w: usize) {
        let now = self.sim.now();
        let merge_inst = self.wl[w].merge_instance.take();
        {
            let st = &mut self.wl[w];
            st.phase = WlPhase::Done;
            st.completed_at = Some(now);
        }
        // release the aggregation instance
        if let Some(id) = merge_inst {
            if let Some(inst) = self.provider.instance_mut(id) {
                inst.finish_chunk(now, 0);
            }
        }
        self.tracker.remove(w);
        self.check_all_done();
        self.assign_idle();
    }

    fn on_tick(&mut self) -> Result<()> {
        let now = self.sim.now();
        let tick_start = Instant::now();
        self.provider.bill_through(now);

        // take the scratch + output buffers so field borrows stay
        // disjoint; returned at the end of the tick
        let mut sc = std::mem::take(&mut self.scratch);
        let mut outs = std::mem::take(&mut self.outs);

        // ----- ME: assemble bank inputs (eqs. 1-3 bookkeeping) ----------
        let n_w = self.specs.len();
        let k = self.k_max;
        let (bw, bk) = (self.bank.w, self.bank.k);
        let wk = bw * bk;
        sc.b_tilde.resize(wk, 0.0);
        sc.meas_mask.resize(wk, 0.0);
        sc.m_rem.resize(wk, 0.0);
        sc.slot_mask.resize(wk, 0.0);
        sc.d.resize(bw, 0.0);
        sc.b_tilde.fill(0.0);
        sc.meas_mask.fill(0.0);
        sc.m_rem.fill(0.0);
        sc.slot_mask.fill(0.0);
        sc.d.fill(0.0);
        for w in 0..n_w {
            let st = &self.wl[w];
            if st.arrived_at > now || matches!(st.phase, WlPhase::Done) || self.arrived <= w {
                continue;
            }
            let remaining = self.db.remaining_slice(w);
            let dl = st.deadline.unwrap_or(now + 3600);
            // safety margin of one monitoring interval: allocation is
            // interval-quantized, so pacing against the raw deadline
            // systematically finishes up to one interval late
            let margin = self.cfg.control.monitor_interval_s;
            sc.d[w] = dl.saturating_sub(now).saturating_sub(margin).max(1) as f32;
            for ki in 0..self.specs[w].n_types.min(k) {
                let idx = w * bk + ki;
                let slot = w * self.k_max + ki;
                sc.slot_mask[idx] = 1.0;
                sc.m_rem[idx] = remaining.get(ki).copied().unwrap_or(0) as f32;
                let log = self.db.measurements(w, ki);
                let cursor = self.meas_cursor[slot];
                if log.len() > cursor {
                    let fresh = &log[cursor..];
                    let sum: f64 = fresh.iter().map(|&(_, c)| c).sum();
                    let m = (sum / fresh.len() as f64) as f32;
                    sc.b_tilde[idx] = m;
                    sc.meas_mask[idx] = 1.0;
                    self.meas_cursor[slot] = log.len();
                    self.last_meas[slot] = m;
                } else {
                    let last = self.last_meas[slot];
                    if !last.is_nan() {
                        // eq. (8) uses b̃[t-1]: when no tasks of this type
                        // completed in the interval, the previous
                        // measurement is reused (the paper's estimator
                        // keeps pulling toward the last observation)
                        sc.b_tilde[idx] = last;
                        sc.meas_mask[idx] = 1.0;
                    }
                }
            }
        }
        let fleet = self.provider.describe(now);
        let n_tot = fleet.active_cus as f32;

        // ----- the L1/L2 hot path: estimator-bank step -------------------
        self.bank.step_into(
            &crate::estimation::TickInputs {
                b_tilde: &sc.b_tilde,
                meas_mask: &sc.meas_mask,
                m_rem: &sc.m_rem,
                slot_mask: &sc.slot_mask,
                d: &sc.d,
                n_tot,
            },
            &mut outs,
        )?;

        // ----- passive estimators + convergence + traces ----------------
        sc.converged.clear();
        for w in 0..n_w {
            if self.arrived <= w || matches!(self.wl[w].phase, WlPhase::Done) {
                continue;
            }
            let spec = &self.specs[w];
            for ki in 0..spec.n_types {
                let idx = w * bk + ki;
                if sc.slot_mask[idx] == 0.0 {
                    continue;
                }
                let had_meas = sc.meas_mask[idx] > 0.0;
                let est = &mut self.est[w * self.k_max + ki];
                if !est.seeded {
                    continue;
                }
                let kalman_b = outs.b_hat[idx] as f64;
                let m = if had_meas { Some(sc.b_tilde[idx] as f64) } else { None };
                let adhoc_b = est.adhoc.update(m);
                let arma_b = match crate::estimation::arma::normalize_per_item(est.cum_cus, est.cum_done)
                {
                    Some(bn) if had_meas => est.arma.update(bn),
                    _ => est.arma.b_hat,
                };
                let trace = self.metrics.traces.get_mut(&(w, ki)).unwrap();
                trace.kalman.push((now, kalman_b));
                trace.adhoc.push((now, adhoc_b));
                trace.arma.push((now, arma_b));
                if est.kalman_det.push(kalman_b).is_some() {
                    trace.kalman_t_init = Some(now);
                    trace.kalman_at_init = Some(kalman_b);
                    if self.opts.estimator == EstimatorKind::Kalman {
                        sc.converged.push(w);
                    }
                }
                if est.adhoc_det.push(adhoc_b).is_some() {
                    trace.adhoc_t_init = Some(now);
                    trace.adhoc_at_init = Some(adhoc_b);
                    if self.opts.estimator == EstimatorKind::AdHoc {
                        sc.converged.push(w);
                    }
                }
                if est.arma_det.push(arma_b).is_some() {
                    trace.arma_t_init = Some(now);
                    trace.arma_at_init = Some(arma_b);
                    if self.opts.estimator == EstimatorKind::Arma {
                        sc.converged.push(w);
                    }
                }
            }
        }

        // ----- service rates from the *driving* estimator ----------------
        let n_star = self.driving_rates_into(&outs, &mut sc, n_tot as f64);
        for w in 0..n_w {
            self.rates[w] = sc.rates_tmp[w].min(self.cfg.control.n_w_max);
        }
        self.n_star_history.push(n_star);
        self.metrics.n_star_curve.push((now, n_star));

        // ----- TTC confirmation at t_init (§II-E-4) ----------------------
        for &w in &sc.converged {
            if self.wl[w].confirmed {
                continue;
            }
            self.wl[w].confirmed = true;
            if let Some(dl) = self.wl[w].deadline {
                let r_w = self.driving_r(&outs, w);
                let c = confirm(r_w, dl, now, self.cfg.control.n_w_max);
                let st = &mut self.wl[w];
                st.deadline = Some(c.deadline);
                st.ttc_extended = c.extended;
            }
        }

        // ----- scaling policy ---------------------------------------------
        let eval_due = match self.policy.eval_interval_s() {
            Some(iv) => now.saturating_sub(self.last_policy_eval) >= iv,
            None => true,
        };
        if eval_due {
            self.last_policy_eval = now;
            let work_pending = (0..n_w).any(|w| {
                self.arrived > w && !matches!(self.wl[w].phase, WlPhase::Done)
            });
            let ctx = PolicyCtx {
                now,
                n_tot: fleet.committed_cus,
                n_star,
                n_star_history: &self.n_star_history,
                mean_utilization: self.provider.mean_utilization(now),
                work_pending,
            };
            let target = self.policy.target(&ctx).round().max(0.0);
            self.adjust_fleet(target);
        }

        // ----- tracker credits + assignment -------------------------------
        self.tracker.tick(&self.rates);
        self.assign_idle();

        self.metrics.ticks += 1;
        self.metrics.tick_wall_ns += tick_start.elapsed().as_nanos();
        self.sample_instances(now);

        // continue while work remains or arrivals are still scheduled
        let more_arrivals = self.arrived < self.specs.len();
        let work_left = (0..n_w)
            .any(|w| self.arrived > w && !matches!(self.wl[w].phase, WlPhase::Done));
        if more_arrivals || work_left {
            self.sim
                .schedule(self.cfg.control.monitor_interval_s, Event::MonitorTick);
        }

        self.scratch = sc;
        self.outs = outs;
        Ok(())
    }

    // ----- helpers ---------------------------------------------------------

    /// r_w under the driving estimator.
    fn driving_r(&self, out: &StepOutputs, w: usize) -> f64 {
        match self.opts.estimator {
            EstimatorKind::Kalman => out.r[w] as f64,
            other => {
                let spec = &self.specs[w];
                let remaining = self.db.remaining_slice(w);
                let mut r = 0.0;
                for ki in 0..spec.n_types {
                    let est = &self.est[w * self.k_max + ki];
                    let b = match other {
                        EstimatorKind::AdHoc => est.adhoc.b_hat,
                        EstimatorKind::Arma => est.arma.b_hat,
                        EstimatorKind::Kalman => unreachable!(),
                    };
                    r += remaining.get(ki).copied().unwrap_or(0) as f64 * b;
                }
                r
            }
        }
    }

    /// Service rates under the driving estimator, written into
    /// `sc.rates_tmp` (reused across ticks); returns n_star.
    fn driving_rates_into(&self, out: &StepOutputs, sc: &mut TickScratch, n_tot: f64) -> f64 {
        let n_w = self.specs.len();
        let bk = self.bank.k;
        sc.rates_tmp.resize(n_w, 0.0);
        match self.opts.estimator {
            EstimatorKind::Kalman => {
                for w in 0..n_w {
                    sc.rates_tmp[w] = out.s[w] as f64;
                }
                out.n_star as f64
            }
            other => {
                sc.r.resize(n_w, 0.0);
                sc.dd.resize(n_w, 0.0);
                sc.active.resize(n_w, false);
                sc.r.fill(0.0);
                sc.active.fill(false);
                for w in 0..n_w {
                    sc.dd[w] = sc.d[w] as f64;
                    for ki in 0..self.specs[w].n_types {
                        let idx = w * bk + ki;
                        if sc.slot_mask[idx] > 0.0 {
                            sc.active[w] = true;
                            let est = &self.est[w * self.k_max + ki];
                            let b = match other {
                                EstimatorKind::AdHoc => est.adhoc.b_hat,
                                EstimatorKind::Arma => est.arma.b_hat,
                                EstimatorKind::Kalman => unreachable!(),
                            };
                            sc.r[w] += sc.m_rem[idx] as f64 * b;
                        }
                    }
                }
                service_rates_into(
                    &sc.r,
                    &sc.dd,
                    &sc.active,
                    n_tot,
                    self.cfg.control.alpha,
                    self.cfg.control.beta,
                    self.cfg.control.n_w_max,
                    &mut sc.rates_tmp,
                )
            }
        }
    }

    fn request_instance(&mut self) {
        let now = self.sim.now();
        let (id, ready) = self.provider.request_spot_instance(0, now);
        self.sim.schedule_at(ready, Event::InstanceReady { instance: id });
    }

    /// Scale the fleet toward `target` CUs.
    ///
    /// Down-scaling is *lazy* for the estimation-based methods: an excess
    /// instance is only terminated when its pre-billed hour is nearly
    /// exhausted (§IV: "the prudent action is always to terminate spot
    /// instances with the smallest remaining time before renewal" — an
    /// instance with 50 paid minutes left is free capacity; killing it
    /// early and re-requesting later would double-bill the hour). Amazon
    /// AS terminates immediately, as the real service does.
    fn adjust_fleet(&mut self, target: f64) {
        let now = self.sim.now();
        let fleet = self.provider.describe(now);
        let committed = fleet.committed_cus;
        // §IV's billing-aware termination prudence is part of the
        // *proposed* controller; the baselines set N_tot[t+1] directly
        // (Gandhi et al. semantics) and Amazon AS terminates eagerly.
        let lazy = self.opts.policy == PolicyKind::Aimd;
        // renewal window: terminate before the next billing increment hits
        let window = (self.cfg.control.monitor_interval_s * 3 / 2 + 1).max(120);
        if target > committed {
            let need = (target - committed).round() as usize;
            for _ in 0..need {
                self.request_instance();
            }
        } else if target < committed {
            let mut excess = (committed - target).round() as usize;
            // idle first, least remaining pre-billed time first (§IV)
            for id in self.provider.idle_instances_by_remaining(now) {
                if excess == 0 {
                    break;
                }
                let rem = self
                    .provider
                    .instance(id)
                    .map(|i| i.remaining_billed(now))
                    .unwrap_or(0);
                if !lazy || rem <= window {
                    self.provider.terminate_instance(id, now);
                    excess -= 1;
                }
            }
            // then drain busy ones if still above target (same laziness)
            if excess > 0 {
                let mut busy: Vec<(u64, SimTime)> = self
                    .provider
                    .instances()
                    .filter(|i| i.state == crate::cloud::InstanceState::Running && !i.is_idle())
                    .map(|i| (i.id, i.remaining_billed(now)))
                    .collect();
                busy.sort_by_key(|&(id, rem)| (rem, id));
                for (id, rem) in busy {
                    if excess == 0 {
                        break;
                    }
                    if !lazy || rem <= window {
                        self.provider.terminate_instance(id, now);
                        excess -= 1;
                    }
                }
            }
        }
        self.sample_instances(now);
    }

    fn update_pending_flag(&mut self, w: usize) {
        let runnable = matches!(self.wl[w].phase, WlPhase::Running)
            && self.db.count_status(w, TaskStatus::Pending) > 0;
        self.tracker.set_pending(w, runnable);
    }

    /// Dispatch work to every idle instance: footprint tasks first
    /// (single-task chunks), then tracker-allocated chunks.
    fn assign_idle(&mut self) {
        let now = self.sim.now();
        let mut idle = std::mem::take(&mut self.idle_buf);
        loop {
            idle.clear();
            idle.extend(
                self.provider
                    .instances()
                    .filter(|i| i.is_idle())
                    .map(|i| i.id),
            );
            if idle.is_empty() {
                break;
            }
            let mut assigned_any = false;
            for &inst_id in &idle {
                // 1. footprinting chunks take priority (small, unblock TTC)
                if let Some((w, tasks)) = self.next_footprint_chunk() {
                    self.dispatch_chunk(inst_id, w, tasks, true, now);
                    assigned_any = true;
                    continue;
                }
                // 2. regular chunk via tracker (or FIFO for Amazon AS)
                let pick = if self.policy.uses_estimation() {
                    self.tracker.next_assignment()
                } else {
                    self.tracker.next_fifo()
                };
                let w = match pick {
                    Some(w) => w,
                    None => continue,
                };
                let tasks = self.build_chunk(w, now);
                if tasks.is_empty() {
                    self.update_pending_flag(w);
                    continue;
                }
                self.tracker.on_assign(w);
                self.dispatch_chunk(inst_id, w, tasks, false, now);
                assigned_any = true;
            }
            // 3. pending merge steps can use an idle instance
            self.dispatch_merges();
            if !assigned_any {
                break;
            }
        }
        self.idle_buf = idle;
        self.dispatch_merges();
    }

    /// Next footprinting chunk: footprint tasks are grouped into (up to)
    /// three chunks rather than singles so per-chunk setup time
    /// ("deadband") is partially amortized even in the sampling stage —
    /// otherwise a Matlab-style 30 s setup would make every footprint
    /// measurement ~deadband-dominated (§II-E-1).
    fn next_footprint_chunk(&mut self) -> Option<(usize, Vec<usize>)> {
        for w in 0..self.wl.len() {
            if self.arrived <= w {
                continue;
            }
            let st = &mut self.wl[w];
            if st.phase == WlPhase::Footprinting && !st.footprint_pending.is_empty() {
                // group only when the app's setup time actually needs
                // amortizing; cheap-setup apps footprint with parallel
                // singles for the fastest possible seeding
                let deadband = self.specs[w].app_model().deadband_s;
                let total = st.footprint_pending.len() + st.footprint_outstanding;
                let per_chunk = if deadband > 5.0 { total.div_ceil(3).max(1) } else { 1 };
                let n = per_chunk.min(st.footprint_pending.len());
                let tasks: Vec<usize> =
                    st.footprint_pending.drain(..n).collect();
                st.footprint_outstanding += tasks.len();
                return Some((w, tasks));
            }
        }
        None
    }

    /// Claim up to chunk_size pending tasks of workload w.
    fn build_chunk(&mut self, w: usize, _now: SimTime) -> Vec<usize> {
        let spec = &self.specs[w];
        let model = spec.app_model();
        // per-item estimate from the driving estimator (fallback:
        // footprint seed; last resort: app deadband + 1s)
        let slot = &self.est[w * self.k_max];
        let est = Some(match self.opts.estimator {
            EstimatorKind::Kalman => self.bank.estimate(w, 0) as f64,
            EstimatorKind::AdHoc => slot.adhoc.b_hat,
            EstimatorKind::Arma => slot.arma.b_hat,
        })
        .filter(|&b| b > 0.0)
        .or_else(|| {
            let st = &self.wl[w];
            if st.footprint_meas.is_empty() {
                None
            } else {
                Some(crate::util::stats::mean(&st.footprint_meas))
            }
        })
        .unwrap_or(model.mean_cus + 1.0);
        let pending_n = self.db.count_status(w, TaskStatus::Pending);
        let n = chunk_size(
            est,
            model.deadband_s,
            self.cfg.control.monitor_interval_s as f64,
            pending_n,
        );
        self.db.status_iter(w, TaskStatus::Pending).take(n).collect()
    }

    fn dispatch_chunk(&mut self, inst_id: u64, w: usize, tasks: Vec<usize>, footprint: bool, now: SimTime) {
        for &t in &tasks {
            self.db.claim((w, t), inst_id);
        }
        self.next_chunk_id += 1;
        let id = self.next_chunk_id;
        let spec = &self.specs[w];
        let result = execute_chunk(spec, &tasks, footprint, &self.storage);
        let chunk = Chunk { id, workload: w, instance: inst_id, tasks, footprint, started_at: now };
        self.chunks.insert(id, chunk);
        if let Some(inst) = self.provider.instance_mut(inst_id) {
            inst.current_chunk = Some(id);
        }
        self.sim.schedule(
            result.busy_s.ceil().max(1.0) as SimTime,
            Event::ChunkDone { instance: inst_id, chunk: id },
        );
        self.update_pending_flag(w);
    }

    fn dispatch_merges(&mut self) {
        let _now = self.sim.now();
        for w in 0..self.wl.len() {
            let needs_merge = {
                let st = &self.wl[w];
                st.phase == WlPhase::Merging && !st.merge_dispatched
            };
            if !needs_merge {
                continue;
            }
            let idle = self
                .provider
                .instances()
                .find(|i| i.is_idle())
                .map(|i| i.id);
            if let Some(inst_id) = idle {
                let merge_frac = match self.specs[w].mode {
                    Mode::SplitMerge { merge_frac } => merge_frac,
                    Mode::Basic => 0.0,
                };
                let merge_s = (self.wl[w].split_busy * merge_frac).max(1.0);
                self.metrics.total_busy_cus += merge_s;
                if let Some(inst) = self.provider.instance_mut(inst_id) {
                    inst.current_chunk = Some(u64::MAX); // merge marker
                    inst.busy_s += merge_s.ceil() as SimTime;
                }
                self.wl[w].merge_dispatched = true;
                self.wl[w].merge_instance = Some(inst_id);
                self.sim
                    .schedule(merge_s.ceil() as SimTime, Event::MergeDone { workload: w });
            }
        }
    }

    fn check_workload_done(&mut self, w: usize) {
        let now = self.sim.now();
        let spec = &self.specs[w];
        if self.wl[w].completed_tasks < spec.n_tasks() {
            return;
        }
        match spec.mode {
            Mode::Basic => {
                let st = &mut self.wl[w];
                if st.phase != WlPhase::Done {
                    st.phase = WlPhase::Done;
                    st.completed_at = Some(now);
                    self.tracker.remove(w);
                    self.check_all_done();
                }
            }
            Mode::SplitMerge { .. } => {
                let st = &mut self.wl[w];
                if st.phase == WlPhase::Running || st.phase == WlPhase::Footprinting {
                    st.phase = WlPhase::Merging;
                    self.tracker.set_pending(w, false);
                    self.dispatch_merges();
                }
            }
        }
    }

    fn check_all_done(&mut self) {
        if self.arrived == self.specs.len()
            && self.wl.iter().all(|st| st.phase == WlPhase::Done)
        {
            self.all_done_at = Some(self.sim.now());
        }
    }

    fn sample_instances(&mut self, now: SimTime) {
        let fleet = self.provider.describe(now);
        let active = fleet.booting + fleet.running + fleet.draining;
        self.metrics.instances_curve.push((now, active));
        self.metrics.max_instances = self.metrics.max_instances.max(active);
    }
}

/// Convenience: run one experiment.
pub fn run_experiment(cfg: Config, specs: Vec<WorkloadSpec>, opts: RunOpts) -> Result<RunMetrics> {
    Platform::new(cfg, specs, opts).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{App, WorkloadSpec};
    use crate::util::rng::Rng;

    fn small_cfg() -> Config {
        let mut cfg = Config::paper_defaults();
        cfg.use_xla = false; // unit tests use the native bank (fast)
        cfg.control.n_min = 4.0;
        cfg
    }

    fn small_suite(n_wl: usize, tasks_each: usize) -> Vec<WorkloadSpec> {
        let rng = Rng::new(42);
        (0..n_wl)
            .map(|i| WorkloadSpec::generate(i, App::FaceDetection, tasks_each, None, &rng))
            .collect()
    }

    fn fast_opts() -> RunOpts {
        RunOpts {
            fixed_ttc_s: Some(3600),
            arrival_interval_s: 60,
            horizon_s: 6 * 3600,
            ..Default::default()
        }
    }

    #[test]
    fn completes_all_workloads() {
        let m = run_experiment(small_cfg(), small_suite(3, 40), fast_opts()).unwrap();
        assert_eq!(m.outcomes.len(), 3);
        for o in &m.outcomes {
            assert!(o.completed_at.is_some(), "workload never completed");
        }
        assert!(m.total_cost > 0.0);
        assert!(m.max_instances >= 4);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_experiment(small_cfg(), small_suite(2, 30), fast_opts()).unwrap();
        let b = run_experiment(small_cfg(), small_suite(2, 30), fast_opts()).unwrap();
        assert_eq!(a.total_cost, b.total_cost);
        assert_eq!(a.finished_at, b.finished_at);
        assert_eq!(a.max_instances, b.max_instances);
    }

    #[test]
    fn cost_is_monotone_and_above_lower_bound() {
        let m = run_experiment(small_cfg(), small_suite(3, 60), fast_opts()).unwrap();
        for wpair in m.cost_curve.windows(2) {
            assert!(wpair[1].1 >= wpair[0].1);
        }
        let lb = m.lower_bound_cost(0.0081);
        assert!(m.total_cost >= lb, "cost {} below LB {lb}", m.total_cost);
    }

    #[test]
    fn estimator_traces_recorded_and_converge() {
        // workload must span several monitoring intervals to converge
        let m = run_experiment(small_cfg(), small_suite(2, 800), fast_opts()).unwrap();
        let tr = &m.traces[&(0, 0)];
        assert!(!tr.kalman.is_empty());
        assert!(tr.final_measured.is_some());
        assert!(tr.kalman_t_init.is_some(), "kalman never converged");
    }

    #[test]
    fn all_policies_complete_the_suite() {
        for policy in [
            PolicyKind::Aimd,
            PolicyKind::Reactive,
            PolicyKind::Mwa,
            PolicyKind::Lr,
            PolicyKind::AmazonAs1,
        ] {
            let mut opts = fast_opts();
            opts.policy = policy;
            if policy == PolicyKind::AmazonAs1 {
                opts.fixed_ttc_s = None;
            }
            let m = run_experiment(small_cfg(), small_suite(2, 25), opts).unwrap();
            assert!(
                m.outcomes.iter().all(|o| o.completed_at.is_some()),
                "{policy:?} left workloads incomplete"
            );
        }
    }

    #[test]
    fn all_estimators_drive_completion() {
        for est in EstimatorKind::ALL {
            let mut opts = fast_opts();
            opts.estimator = est;
            let m = run_experiment(small_cfg(), small_suite(2, 25), opts).unwrap();
            assert!(m.outcomes.iter().all(|o| o.completed_at.is_some()));
        }
    }

    #[test]
    fn splitmerge_workload_runs_merge() {
        let rng = Rng::new(9);
        let spec = WorkloadSpec::generate_mode(
            0,
            App::CnnClassify,
            30,
            Mode::SplitMerge { merge_frac: 0.1 },
            None,
            &rng,
        );
        let m = run_experiment(small_cfg(), vec![spec], fast_opts()).unwrap();
        assert!(m.outcomes[0].completed_at.is_some());
    }

    #[test]
    fn ttc_honored_under_aimd() {
        let mut opts = fast_opts();
        opts.fixed_ttc_s = Some(2 * 3600);
        let m = run_experiment(small_cfg(), small_suite(3, 40), opts).unwrap();
        assert!(
            m.ttc_compliance() >= 0.99,
            "TTC compliance {}",
            m.ttc_compliance()
        );
    }

    #[test]
    fn single_task_workload_degenerates_cleanly() {
        let m = run_experiment(small_cfg(), small_suite(1, 1), fast_opts()).unwrap();
        assert!(m.outcomes[0].completed_at.is_some());
        assert_eq!(m.outcomes[0].n_tasks, 1);
    }
}
