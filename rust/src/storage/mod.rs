//! Cloud-storage substrate (the S3 stand-in, §II-C).
//!
//! Dithen uploads workload inputs/code to S3 and instances pull their
//! chunk's inputs and push results back. For the control plane only the
//! *transfer delay* matters (the paper measures ~27 % of billed time going
//! to data transport), so this module is an object catalogue plus a
//! deterministic bandwidth/latency delay model.

use std::collections::BTreeMap;

use crate::config::StorageCfg;

/// One stored object (a media input, script, or result).
#[derive(Debug, Clone, PartialEq)]
pub struct Object {
    pub key: String,
    pub size_bytes: u64,
}

/// Bucket-like object catalogue with prefix listing, mirroring the
/// `getIterator('ListObjects')` usage in §II-D.
#[derive(Debug, Default)]
pub struct ObjectStore {
    objects: BTreeMap<String, Object>,
    cfg: StorageCfg,
}

impl ObjectStore {
    pub fn new(cfg: StorageCfg) -> Self {
        ObjectStore { objects: BTreeMap::new(), cfg }
    }

    pub fn put(&mut self, key: &str, size_bytes: u64) {
        self.objects
            .insert(key.to_string(), Object { key: key.to_string(), size_bytes });
    }

    pub fn get(&self, key: &str) -> Option<&Object> {
        self.objects.get(key)
    }

    pub fn delete(&mut self, key: &str) -> bool {
        self.objects.remove(key).is_some()
    }

    /// List objects under a prefix (sorted by key, like S3).
    pub fn list(&self, prefix: &str) -> Vec<&Object> {
        self.objects
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(_, v)| v)
            .collect()
    }

    pub fn count(&self, prefix: &str) -> usize {
        self.list(prefix).len()
    }

    pub fn total_bytes(&self, prefix: &str) -> u64 {
        self.list(prefix).iter().map(|o| o.size_bytes).sum()
    }

    /// Delete every object under `prefix`, returning how many were
    /// removed — the bulk-delete a retired workload's `w{w:02}/` tree
    /// goes through (PR-8). Callers pass a `/`-terminated prefix so
    /// `w1/` can never swallow `w10/`.
    pub fn delete_prefix(&mut self, prefix: &str) -> usize {
        let doomed: Vec<String> = self
            .objects
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, _)| k.clone())
            .collect();
        for k in &doomed {
            self.objects.remove(k);
        }
        doomed.len()
    }

    /// Transfer time in seconds for `bytes` over one instance's share of
    /// bandwidth, including per-request latency for `requests` objects.
    pub fn transfer_time(&self, bytes: u64, requests: u64) -> f64 {
        bytes as f64 / self.cfg.bandwidth_bps + requests as f64 * self.cfg.request_latency_s
    }

    pub fn cfg(&self) -> &StorageCfg {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ObjectStore {
        ObjectStore::new(StorageCfg::default())
    }

    #[test]
    fn put_get_delete() {
        let mut s = store();
        s.put("w1/input/a.jpg", 1000);
        assert_eq!(s.get("w1/input/a.jpg").unwrap().size_bytes, 1000);
        assert!(s.delete("w1/input/a.jpg"));
        assert!(!s.delete("w1/input/a.jpg"));
        assert!(s.get("w1/input/a.jpg").is_none());
    }

    #[test]
    fn prefix_listing_is_exact() {
        let mut s = store();
        s.put("w1/input/a.jpg", 1);
        s.put("w1/input/b.jpg", 2);
        s.put("w1/output/a.out", 3);
        s.put("w10/input/x.jpg", 4);
        let keys: Vec<&str> = s.list("w1/input/").iter().map(|o| o.key.as_str()).collect();
        assert_eq!(keys, vec!["w1/input/a.jpg", "w1/input/b.jpg"]);
        assert_eq!(s.count("w1/"), 3);
        assert_eq!(s.total_bytes("w1/input/"), 3);
    }

    #[test]
    fn delete_prefix_is_exact_and_counts() {
        let mut s = store();
        s.put("w01/input/a.jpg", 1);
        s.put("w01/input/b.jpg", 2);
        s.put("w01/output/a.out", 3);
        s.put("w010/input/x.jpg", 4);
        assert_eq!(s.delete_prefix("w01/"), 3);
        assert_eq!(s.count("w01/"), 0);
        assert_eq!(s.count("w010/"), 1, "sibling prefixes must survive");
        assert_eq!(s.delete_prefix("w01/"), 0, "second delete finds nothing");
    }

    #[test]
    fn overwrite_replaces_size() {
        let mut s = store();
        s.put("k", 10);
        s.put("k", 20);
        assert_eq!(s.get("k").unwrap().size_bytes, 20);
        assert_eq!(s.count(""), 1);
    }

    #[test]
    fn transfer_time_scales_with_bytes_and_requests() {
        let s = store();
        let t1 = s.transfer_time(2_000_000, 1); // 1 s payload + latency
        assert!((t1 - (1.0 + 0.06)).abs() < 1e-9);
        let t2 = s.transfer_time(0, 10);
        assert!((t2 - 0.6).abs() < 1e-9);
    }
}
