//! Scenario description + builder: the experiment-facing API.
//!
//! A [`Scenario`] is a *plain-data* description of one platform run —
//! workload suite, arrival process, cloud backend, fault model, control
//! knobs — cheap to clone across sweep workers and deterministic in
//! `Config::seed`. Trait objects (the backend, the fault model) are only
//! instantiated when the scenario is run, so scenarios stay `Clone` and
//! grids of them stay thread-safe.
//!
//! [`ScenarioBuilder`] is the ergonomic front end:
//!
//! ```no_run
//! use dithen::cloud::BackendKind;
//! use dithen::config::Config;
//! use dithen::platform::{ArrivalProcess, FaultSpec, ScenarioBuilder};
//! use dithen::workload::paper_suite;
//!
//! let cfg = Config::paper_defaults();
//! let metrics = ScenarioBuilder::new(cfg.clone())
//!     .workloads(paper_suite(cfg.seed))
//!     .arrivals(ArrivalProcess::Poisson { mean_gap_s: 300.0 })
//!     .backend(BackendKind::Spot)
//!     .fault(FaultSpec::SpotReclamation { bid: 0.0085 })
//!     .build()
//!     .run()
//!     .unwrap();
//! # let _ = metrics;
//! ```
//!
//! The defaults mirror `RunOpts::default()` exactly (AIMD, Kalman, the
//! §V-C 2 hr 07 min TTC, fixed-interval arrivals, spot backend, no
//! faults, traces on), so `Scenario::from_opts` is a lossless embedding
//! of the legacy API.

use anyhow::Result;

use crate::cloud::{BackendKind, FleetSpec};
use crate::config::Config;
use crate::coordinator::PolicyKind;
use crate::estimation::EstimatorKind;
use crate::metrics::RunMetrics;
use crate::platform::{ArrivalProcess, FaultSpec, Platform, RunOpts};
use crate::workload::{App, WorkloadSpec};

/// Lazy workload suite for streaming arrivals (PR-8): instead of
/// materializing every [`WorkloadSpec`] up front, the platform calls
/// [`StreamSpec::spec_for`] at each workload's arrival instant, so a
/// 10M-task run never holds more than the live window's specs.
///
/// `spec_for(w, seed)` is *definitionally* the same call a
/// materialized suite makes for slot `w` (`WorkloadSpec::generate`
/// derives everything from `rng.substream(0x60D0 + w)`), which is
/// why streaming runs are bit-identical to their
/// [`Scenario::materialize`] twin.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamSpec {
    /// Total workloads the run will admit.
    pub n_workloads: usize,
    /// Tasks per workload (uniform across the stream).
    pub tasks_per_workload: usize,
    /// Application class every streamed workload runs.
    pub app: App,
}

impl StreamSpec {
    /// Materialize slot `w`'s spec — identical to what an eager suite
    /// generated for the same slot under the same seed.
    pub fn spec_for(&self, w: usize, seed: u64) -> WorkloadSpec {
        WorkloadSpec::generate(
            w,
            self.app,
            self.tasks_per_workload,
            None,
            &crate::util::rng::Rng::new(seed),
        )
    }

    pub fn n_tasks(&self) -> usize {
        self.n_workloads * self.tasks_per_workload
    }
}

/// A complete, self-contained experiment description.
///
/// Also the daemon's configuration unit (PR-7): `dithen serve` holds a
/// workload-less `Scenario` as its *template* and, at first advance,
/// fills `specs` + `arrivals` (as [`ArrivalProcess::Scripted`]) from
/// the HTTP submission log — so a served run is assembled by exactly
/// this struct's code path, which is why scripted-clock serving is
/// bit-identical to the batch twin (`tests/serve_parity.rs`).
#[derive(Debug, Clone)]
pub struct Scenario {
    pub cfg: Config,
    /// Workload suite; `specs[w].id` must equal its arrival slot `w`.
    pub specs: Vec<WorkloadSpec>,
    pub policy: PolicyKind,
    pub estimator: EstimatorKind,
    /// Fixed TTC per workload, or None for best-effort.
    pub fixed_ttc_s: Option<u64>,
    /// Hard stop (safety bound).
    pub horizon_s: u64,
    /// Front-end arrival process.
    pub arrivals: ArrivalProcess,
    /// Cloud substrate the fleet runs on.
    pub backend: BackendKind,
    /// Per-type instance pools (and their spot bids) the IaaS backends
    /// provision from; the default is the degenerate single bid-less
    /// m3.medium pool. Lambda ignores it.
    pub fleet: FleetSpec,
    /// Cloud-event injection stream.
    pub fault: FaultSpec,
    /// Record estimator traces (off in sweeps: per-tick allocations).
    pub record_traces: bool,
    /// Force every monitoring instant to run the full
    /// gather/step/finish round, disabling the event-driven sparse-tick
    /// skipper (PR-6). Off by default — skipping is proven
    /// bit-identical (`tick_skip_is_bit_identical_to_dense`); this
    /// switch exists as the dense reference arm of that pin and as an
    /// escape hatch for debugging.
    pub dense_ticks: bool,
    /// Streaming suite (PR-8): when set, `specs` must be empty and
    /// workload specs are generated lazily at their arrival instants.
    pub stream: Option<StreamSpec>,
    /// Audit-and-retire shards whose workloads reach terminal state
    /// (PR-8): terminal counts fold into `RunMetrics` exactly once,
    /// measurement logs drop, and arena slabs recycle through the
    /// shard free list, so memory tracks the live window.
    pub retire_shards: bool,
}

impl Scenario {
    /// Embed the legacy `RunOpts` API: fixed-interval arrivals on a
    /// fault-free spot fleet.
    pub fn from_opts(cfg: Config, specs: Vec<WorkloadSpec>, opts: RunOpts) -> Scenario {
        Scenario {
            cfg,
            specs,
            policy: opts.policy,
            estimator: opts.estimator,
            fixed_ttc_s: opts.fixed_ttc_s,
            horizon_s: opts.horizon_s,
            arrivals: ArrivalProcess::FixedInterval { interval_s: opts.arrival_interval_s },
            backend: BackendKind::Spot,
            fleet: FleetSpec::default(),
            fault: FaultSpec::None,
            record_traces: opts.record_traces,
            dense_ticks: opts.dense_ticks,
            stream: None,
            retire_shards: false,
        }
    }

    /// The eager twin of a streaming scenario: every slot's spec
    /// generated up front, `stream` cleared. `run()` on the result is
    /// the materialize-everything reference the streaming run must
    /// stay bit-identical to
    /// (`tests/determinism.rs::streaming_is_bit_identical_to_materialized`).
    /// Non-streaming scenarios materialize to themselves.
    pub fn materialize(&self) -> Scenario {
        let mut scn = self.clone();
        if let Some(stream) = scn.stream.take() {
            scn.specs =
                (0..stream.n_workloads).map(|w| stream.spec_for(w, scn.cfg.seed)).collect();
        }
        scn
    }

    /// Execute the scenario (pure in its inputs; the scenario itself is
    /// reusable — sweep cells call this from worker threads). Bank
    /// construction goes through the process-wide
    /// [`crate::estimation::BankCache`].
    pub fn run(&self) -> Result<RunMetrics> {
        self.run_with_cache(crate::estimation::BankCache::global())
    }

    /// Execute the scenario resolving its estimator bank through an
    /// explicit cache (sweep harnesses pass one shared cache across all
    /// cells; tests pass a fresh one for attributable hit counts).
    pub fn run_with_cache(&self, cache: &crate::estimation::BankCache) -> Result<RunMetrics> {
        self.validate()?;
        Platform::from_scenario_with_cache(self.clone(), cache).run()
    }

    /// Resolve this scenario's bank variant in `cache` — the *exact*
    /// request platform assembly makes (assembly calls this method, so
    /// the two can never drift). Calling it ahead of a timed sweep
    /// warms the cache, keeping cold-build cost (XLA manifest parse +
    /// executable compilation) out of the measured passes.
    pub fn bank_variant(
        &self,
        cache: &crate::estimation::BankCache,
    ) -> std::sync::Arc<crate::estimation::BankVariant> {
        let n_w = self.specs.len().max(1);
        let k_max = self.specs.iter().map(|s| s.n_types).max().unwrap_or(1).max(1);
        let params = crate::estimation::BankParams::from_config(&self.cfg.control);
        cache.variant(
            n_w,
            k_max,
            params,
            self.estimator,
            std::path::Path::new(&self.cfg.artifacts_dir),
            self.cfg.use_xla,
        )
    }

    /// Reject configurations that would otherwise panic deep inside
    /// platform assembly or run as silent no-ops: an invalid fleet
    /// (empty / duplicate types — constructible because `FleetSpec`'s
    /// fields are public), or `reclaim-pools` on a spot fleet where no
    /// pool carries a bid (nothing could ever be revoked, which is
    /// indistinguishable from "the market never spiked" in the
    /// metrics). Fault specs on *non-reclaimable* backends
    /// (on-demand/lambda) are deliberately not rejected: every fault
    /// family is defined — and tested — to no-op there, so e.g. a
    /// sweep can hold the fault axis fixed while varying the backend.
    pub fn validate(&self) -> Result<()> {
        if let Err(e) = self.fleet.validate() {
            anyhow::bail!("invalid fleet spec: {e}");
        }
        if self.backend == BackendKind::Spot
            && self.fault == FaultSpec::PoolReclamation
            && self.fleet.pools.iter().all(|p| p.bid.is_none())
        {
            anyhow::bail!("reclaim-pools needs at least one pool bid (--fleet <type>:bid=<$/hr>)");
        }
        if self.stream.is_some() && !self.specs.is_empty() {
            anyhow::bail!("streaming scenarios generate their suite lazily: specs must be empty");
        }
        if (self.stream.is_some() || self.retire_shards) && self.cfg.use_xla {
            anyhow::bail!("streaming/retirement needs a growable native bank (drop --use-xla)");
        }
        Ok(())
    }

    /// Total tasks across the suite (throughput accounting).
    pub fn n_tasks(&self) -> usize {
        match &self.stream {
            Some(s) => s.n_tasks(),
            None => self.specs.iter().map(|s| s.n_tasks()).sum(),
        }
    }

    /// Total arrival slots the run will admit (suite size in either
    /// eager or streaming form).
    pub fn n_workloads(&self) -> usize {
        match &self.stream {
            Some(s) => s.n_workloads,
            None => self.specs.len(),
        }
    }

    /// One-line human description (CLI headers, sweep labels).
    pub fn describe(&self) -> String {
        format!(
            "{} workloads / {} tasks{} | backend={} fleet={} fault={} arrivals={} policy={:?} estimator={:?} ttc={:?}",
            self.n_workloads(),
            self.n_tasks(),
            if self.stream.is_some() { " (streamed)" } else { "" },
            self.backend.name(),
            self.fleet.describe(),
            self.fault.describe(),
            self.arrivals.describe(),
            self.policy,
            self.estimator,
            self.fixed_ttc_s,
        )
    }
}

/// Fluent builder over [`Scenario`]. Defaults mirror `RunOpts::default`.
#[derive(Debug, Clone)]
pub struct ScenarioBuilder {
    scn: Scenario,
}

impl ScenarioBuilder {
    pub fn new(cfg: Config) -> Self {
        ScenarioBuilder { scn: Scenario::from_opts(cfg, vec![], RunOpts::default()) }
    }

    /// Set the workload suite (`specs[w].id` must be its arrival slot).
    pub fn workloads(mut self, specs: Vec<WorkloadSpec>) -> Self {
        self.scn.specs = specs;
        self
    }

    pub fn policy(mut self, policy: PolicyKind) -> Self {
        self.scn.policy = policy;
        self
    }

    pub fn estimator(mut self, estimator: EstimatorKind) -> Self {
        self.scn.estimator = estimator;
        self
    }

    /// Fixed TTC per workload; `None` = best effort.
    pub fn fixed_ttc(mut self, ttc_s: Option<u64>) -> Self {
        self.scn.fixed_ttc_s = ttc_s;
        self
    }

    pub fn horizon(mut self, horizon_s: u64) -> Self {
        self.scn.horizon_s = horizon_s;
        self
    }

    pub fn arrivals(mut self, arrivals: ArrivalProcess) -> Self {
        self.scn.arrivals = arrivals;
        self
    }

    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.scn.backend = backend;
        self
    }

    /// Per-type instance pools the IaaS backends provision from (see
    /// [`FleetSpec::parse`] for the CLI grammar).
    pub fn fleet(mut self, fleet: FleetSpec) -> Self {
        self.scn.fleet = fleet;
        self
    }

    pub fn fault(mut self, fault: FaultSpec) -> Self {
        self.scn.fault = fault;
        self
    }

    pub fn record_traces(mut self, on: bool) -> Self {
        self.scn.record_traces = on;
        self
    }

    /// Disable the sparse-tick skipper: run every monitoring instant
    /// densely (the reference arm of the skip-equivalence pin).
    pub fn dense_ticks(mut self, on: bool) -> Self {
        self.scn.dense_ticks = on;
        self
    }

    /// Stream the workload suite: specs are generated lazily at their
    /// arrival instants instead of up front (PR-8). Mutually exclusive
    /// with `.workloads(..)`.
    pub fn stream(mut self, stream: StreamSpec) -> Self {
        self.scn.stream = Some(stream);
        self
    }

    /// Audit-and-retire shards as workloads reach terminal state, so
    /// memory tracks the live window (PR-8).
    pub fn retire_shards(mut self, on: bool) -> Self {
        self.scn.retire_shards = on;
        self
    }

    pub fn build(self) -> Scenario {
        self.scn
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_mirror_run_opts() {
        let cfg = Config::paper_defaults();
        let built = ScenarioBuilder::new(cfg.clone()).build();
        let opts = RunOpts::default();
        assert_eq!(built.policy, opts.policy);
        assert_eq!(built.estimator, opts.estimator);
        assert_eq!(built.fixed_ttc_s, opts.fixed_ttc_s);
        assert_eq!(built.horizon_s, opts.horizon_s);
        assert_eq!(
            built.arrivals,
            ArrivalProcess::FixedInterval { interval_s: opts.arrival_interval_s }
        );
        assert_eq!(built.backend, BackendKind::Spot);
        assert_eq!(built.fleet, FleetSpec::default());
        assert_eq!(built.fault, FaultSpec::None);
        assert!(built.record_traces);
        assert!(!built.dense_ticks, "skipping is the default in both APIs");
        assert_eq!(built.dense_ticks, opts.dense_ticks);
    }

    #[test]
    fn builder_setters_apply() {
        let scn = ScenarioBuilder::new(Config::paper_defaults())
            .policy(PolicyKind::Mwa)
            .estimator(EstimatorKind::Arma)
            .fixed_ttc(None)
            .horizon(99)
            .arrivals(ArrivalProcess::Bursty { burst: 4, gap_s: 10 })
            .backend(BackendKind::Lambda)
            .fault(FaultSpec::SpotReclamation { bid: 0.01 })
            .record_traces(false)
            .dense_ticks(true)
            .build();
        assert_eq!(scn.policy, PolicyKind::Mwa);
        assert_eq!(scn.estimator, EstimatorKind::Arma);
        assert_eq!(scn.fixed_ttc_s, None);
        assert_eq!(scn.horizon_s, 99);
        assert_eq!(scn.backend, BackendKind::Lambda);
        assert_eq!(scn.fault, FaultSpec::SpotReclamation { bid: 0.01 });
        assert!(!scn.record_traces);
        assert!(scn.dense_ticks);
        assert!(scn.describe().contains("lambda"));
    }

    #[test]
    fn run_rejects_invalid_or_inert_configurations() {
        let cfg = Config::paper_defaults();
        let empty = ScenarioBuilder::new(cfg.clone()).fleet(FleetSpec { pools: vec![] }).build();
        let err = empty.run().unwrap_err().to_string();
        assert!(err.contains("fleet"), "empty fleet must be an Err, not a panic: {err}");
        // reclaim-pools over a fleet with no bids can never revoke
        // anything: reject the dead configuration up front
        let inert = ScenarioBuilder::new(cfg.clone())
            .fleet(FleetSpec::parse("m3.medium,m3.xlarge").unwrap())
            .fault(FaultSpec::PoolReclamation)
            .build();
        let err = inert.run().unwrap_err().to_string();
        assert!(err.contains("reclaim-pools"), "bid-less reclaim-pools must error: {err}");
        // ...while the same fault with a bid somewhere validates
        let ok = ScenarioBuilder::new(cfg)
            .fleet(FleetSpec::parse("m3.medium,m3.xlarge:bid=0.05").unwrap())
            .fault(FaultSpec::PoolReclamation)
            .build();
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn builder_carries_a_mixed_fleet() {
        let fleet = FleetSpec::parse("m3.medium:bid=0.0085,m4.10xlarge:bid=0.6").unwrap();
        let scn = ScenarioBuilder::new(Config::paper_defaults())
            .fleet(fleet.clone())
            .fault(FaultSpec::PoolReclamation)
            .build();
        assert_eq!(scn.fleet, fleet);
        assert!(scn.describe().contains("m4.10xlarge:bid=0.6"));
        assert!(scn.describe().contains("reclaim-pools"));
    }

    #[test]
    fn stream_materializes_to_the_same_suite_slot_by_slot() {
        let cfg = Config::paper_defaults();
        let stream =
            StreamSpec { n_workloads: 5, tasks_per_workload: 8, app: crate::workload::App::Brisk };
        let scn = ScenarioBuilder::new(cfg.clone()).stream(stream).retire_shards(true).build();
        assert!(scn.validate().is_ok());
        assert_eq!(scn.n_tasks(), 40);
        assert_eq!(scn.n_workloads(), 5);
        assert!(scn.describe().contains("(streamed)"));
        let twin = scn.materialize();
        assert!(twin.stream.is_none());
        assert_eq!(twin.specs.len(), 5);
        assert_eq!(twin.n_tasks(), 40);
        // each lazily generated slot is bitwise the spec the twin holds
        for (w, spec) in twin.specs.iter().enumerate() {
            let lazy = stream.spec_for(w, cfg.seed);
            assert_eq!(lazy.id, spec.id);
            assert_eq!(lazy.name, spec.name);
            assert_eq!(lazy.tasks.len(), spec.tasks.len());
            for (a, b) in lazy.tasks.iter().zip(&spec.tasks) {
                assert_eq!(a.true_cus.to_bits(), b.true_cus.to_bits());
                assert_eq!(a.bytes, b.bytes);
            }
            assert_eq!(lazy.true_mean_cus[0].to_bits(), spec.true_mean_cus[0].to_bits());
        }
        // non-streaming scenarios materialize to themselves
        let plain = ScenarioBuilder::new(cfg).build();
        assert_eq!(plain.materialize().specs.len(), plain.specs.len());
    }

    #[test]
    fn stream_validation_rejects_eager_specs_and_xla() {
        let cfg = Config::paper_defaults();
        let stream = StreamSpec {
            n_workloads: 2,
            tasks_per_workload: 3,
            app: crate::workload::App::ImRotate,
        };
        let rng = crate::util::rng::Rng::new(1);
        let spec = WorkloadSpec::generate(0, crate::workload::App::FaceDetection, 7, None, &rng);
        let both =
            ScenarioBuilder::new(cfg.clone()).workloads(vec![spec]).stream(stream).build();
        let err = both.validate().unwrap_err().to_string();
        assert!(err.contains("specs must be empty"), "{err}");
        let mut xla_cfg = cfg;
        xla_cfg.use_xla = true;
        let xla = ScenarioBuilder::new(xla_cfg).stream(stream).build();
        let err = xla.validate().unwrap_err().to_string();
        assert!(err.contains("native bank"), "{err}");
    }

    #[test]
    fn n_tasks_sums_suite() {
        let rng = crate::util::rng::Rng::new(1);
        let specs = vec![
            WorkloadSpec::generate(0, crate::workload::App::FaceDetection, 7, None, &rng),
            WorkloadSpec::generate(1, crate::workload::App::FaceDetection, 5, None, &rng),
        ];
        let scn = ScenarioBuilder::new(Config::paper_defaults()).workloads(specs).build();
        assert_eq!(scn.n_tasks(), 12);
    }
}
