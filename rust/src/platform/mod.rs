//! The integrated Dithen platform: GCI monitoring loop over the simulated
//! substrates (Fig. 1's architecture, end to end), assembled from a
//! [`Scenario`].
//!
//! One [`Platform::run`] call executes a complete experiment: workloads
//! arrive at the front end (per the scenario's [`ArrivalProcess`]), are
//! footprinted, estimated (Kalman bank on the XLA/PJRT hot path),
//! scheduled with proportional-fair service rates through the tracker,
//! while the scaling policy (AIMD or a baseline) grows/shrinks the fleet
//! on the scenario's [`crate::cloud::CloudBackend`] and the scenario's
//! [`FaultModel`] injects cloud events (spot reclamation) that the loop
//! must absorb — revoked chunks re-enter the task DB through
//! [`crate::db::TaskDb::requeue`]. Everything is deterministic in
//! `Config::seed`.
//!
//! Module layout (one concern per file, all `impl Platform` on the one
//! struct below):
//!
//! * [`scenario`] — [`Scenario`] / [`ScenarioBuilder`]: the experiment
//!   description (workloads, arrivals, backend, faults, knobs) and the
//!   [`RunOpts`] compatibility shim;
//! * [`arrivals`] — front-end arrival processes (fixed-interval, bursty,
//!   seeded Poisson);
//! * [`faults`] — the [`CloudEvent`] stream and [`FaultModel`]
//!   implementations (spot reclamation);
//! * [`events`] — discrete-event handlers: arrivals, instance readiness,
//!   chunk/merge completion, reclamation absorption;
//! * [`tick`] — the GCI monitoring tick (ME assembly, estimator bank,
//!   convergence, TTC confirmation, policy evaluation);
//! * [`dispatch`] — the tracker-driven chunk allocator (footprint chunks,
//!   regular chunks, merge steps), capacity-aware: each instance absorbs
//!   one concurrent chunk per CU;
//! * [`scaling`] — fleet adjustment toward the policy's CU target,
//!   translated into a type mix over the scenario's per-type pools
//!   ([`crate::cloud::FleetSpec`]) by a greedy cheapest-$/CU fill.
//!
//! Perf (§Perf): the monitoring tick is allocation-free in steady state.
//! All per-tick working sets — the bank's input matrices, its outputs,
//! the service-rate scratch, estimator slots, last-measurement cache and
//! measurement-log cursors — are dense `w*K+k`-indexed arrays owned by
//! the platform and reused across ticks; the task DB serves every tick
//! query (status counts, m_{w,k}, measurement windows) from borrowed
//! slices of its flat arenas. `tests/alloc_steady_state.rs` pins this
//! with a counting global allocator. Estimator *trace* recording (three
//! Vec pushes per active slot per tick) is the one remaining per-tick
//! allocator and is therefore gated behind `record_traces` (on for
//! figure-generating runs, off in sweeps).

pub mod arrivals;
pub mod dispatch;
pub mod events;
pub mod faults;
pub mod scaling;
pub mod scenario;
pub mod tick;

pub use arrivals::ArrivalProcess;
pub use faults::{
    ChunkCrash, CloudEvent, FaultModel, FaultSpec, LaunchFlake, NoFaults, ReclamationAt,
    SpotReclamation, Straggler,
};
pub use scenario::{Scenario, ScenarioBuilder, StreamSpec};

use std::collections::BTreeMap;

use anyhow::Result;

use crate::cloud::CloudBackend;
use crate::config::Config;
use crate::coordinator::policy::{ControlPolicy, PolicyKind, FORECAST_H};
use crate::coordinator::Tracker;
use crate::db::TaskDb;
use crate::estimation::{
    AdHoc, Arma, Bank, BankCache, DeviationDetector, EstimatorKind, Ewma, LastObservation,
    SlopeDetector,
};
use crate::lci::Chunk;
use crate::metrics::{RunMetrics, WorkloadOutcome};
use crate::runtime::StepOutputs;
use crate::sim::{Engine as SimEngine, Event, SimTime};
use crate::storage::ObjectStore;
use crate::workload::WorkloadSpec;

/// Run options for one experiment — the pre-scenario API, kept as a thin
/// compatibility shim: [`run_experiment`] and [`Platform::new`] translate
/// a `RunOpts` into a [`Scenario`] (fixed-interval arrivals, spot
/// backend, no faults), so every pre-existing experiment compiles and
/// produces identical metrics. New code should use [`ScenarioBuilder`].
#[derive(Debug, Clone)]
pub struct RunOpts {
    pub policy: PolicyKind,
    /// Which estimator drives service rates (Table II comparisons). The
    /// Kalman bank always runs (it is the platform hot path); ad-hoc and
    /// ARMA estimators additionally run passively on the same
    /// measurement stream so Fig. 6/7 can overlay all three.
    pub estimator: EstimatorKind,
    /// Fixed TTC applied to every workload (the §V-C experiments), or
    /// None for best-effort (Amazon AS runs).
    pub fixed_ttc_s: Option<u64>,
    /// Seconds between workload arrivals.
    pub arrival_interval_s: u64,
    /// Hard stop (safety bound for tests).
    pub horizon_s: u64,
    /// Record per-slot estimator traces in `RunMetrics::traces`. On by
    /// default (the Fig. 6/7 / Table II pipelines need them); sweeps
    /// turn it off — it is the largest per-tick allocation source.
    pub record_traces: bool,
    /// Disable the event-driven sparse-tick skipper (PR-6): run every
    /// monitoring instant densely. Off by default — skipping is
    /// bit-identical to dense ticks (pinned in `tests/determinism.rs`).
    pub dense_ticks: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            policy: PolicyKind::Aimd,
            estimator: EstimatorKind::Kalman,
            fixed_ttc_s: Some(7620), // 2 hr 07 min (§V-C experiment 1)
            arrival_interval_s: crate::workload::ARRIVAL_INTERVAL_S,
            horizon_s: 24 * 3600,
            record_traces: true,
            dense_ticks: false,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum WlPhase {
    /// Waiting for / executing footprinting tasks.
    Footprinting,
    /// Normal task execution with estimation.
    Running,
    /// Split done, merge step pending or executing (Split–Merge mode).
    Merging,
    Done,
}

/// Per-(workload, media-type) estimation state. Stored densely at
/// `w * k_max + k`; slots outside a workload's `n_types` are inert.
#[derive(Debug)]
pub(crate) struct SlotEst {
    pub(crate) adhoc: AdHoc,
    pub(crate) arma: Arma,
    pub(crate) ewma: Ewma,
    pub(crate) reactive: LastObservation,
    pub(crate) kalman_det: SlopeDetector,
    pub(crate) adhoc_det: SlopeDetector,
    pub(crate) arma_det: DeviationDetector,
    pub(crate) ewma_det: SlopeDetector,
    pub(crate) reactive_det: DeviationDetector,
    /// Cumulative measured CUS and completed count (ARMA normalization).
    pub(crate) cum_cus: f64,
    pub(crate) cum_done: usize,
    pub(crate) seeded: bool,
}

#[derive(Debug)]
pub(crate) struct WlState {
    pub(crate) phase: WlPhase,
    pub(crate) arrived_at: SimTime,
    pub(crate) deadline: Option<SimTime>,
    pub(crate) ttc_extended: bool,
    pub(crate) confirmed: bool,
    /// Footprint task ids not yet dispatched / completed.
    pub(crate) footprint_pending: Vec<usize>,
    pub(crate) footprint_outstanding: usize,
    pub(crate) footprint_meas: Vec<f64>,
    pub(crate) completed_tasks: usize,
    pub(crate) completed_at: Option<SimTime>,
    /// Busy seconds of all executed split chunks (merge time derivation).
    pub(crate) split_busy: f64,
    pub(crate) merge_dispatched: bool,
    pub(crate) merge_instance: Option<u64>,
    /// Bumped when a dispatched merge is revoked; stale `MergeDone`
    /// events (no engine-side cancellation) carry the old epoch and are
    /// ignored.
    pub(crate) merge_epoch: u32,
    /// Suite-shape caches taken from the spec at admission (PR-8): the
    /// outcomes assembly and the serve status endpoint read these, so a
    /// retired workload — whose `spec.tasks` slab is dropped — still
    /// reports its true shape.
    pub(crate) n_tasks: usize,
    pub(crate) total_bytes: u64,
    /// `(completed, failed)` folded exactly once from the shard audit
    /// at retirement; `None` while the shard is live (counts are read
    /// from the DB then).
    pub(crate) terminal: Option<(usize, usize)>,
    /// Tasks terminally Failed after exhausting the PR-10 retry budget.
    /// Counted into `completed_tasks` too (terminal = never a hang);
    /// any nonzero value makes the workload a deadline violation.
    pub(crate) tasks_abandoned: usize,
}

impl WlState {
    /// Fresh pre-arrival state for `spec`, caching the suite-shape
    /// facts that must outlive the shard (PR-8 retirement).
    pub(crate) fn new(spec: &WorkloadSpec) -> WlState {
        WlState {
            phase: WlPhase::Footprinting,
            arrived_at: 0,
            deadline: None,
            ttc_extended: false,
            confirmed: false,
            footprint_pending: vec![],
            footprint_outstanding: 0,
            footprint_meas: vec![],
            completed_tasks: 0,
            completed_at: None,
            split_busy: 0.0,
            merge_dispatched: false,
            merge_instance: None,
            merge_epoch: 0,
            n_tasks: spec.n_tasks(),
            total_bytes: spec.total_bytes(),
            terminal: None,
            tasks_abandoned: 0,
        }
    }
}

/// Live cursor over a streaming scenario's arrival schedule (PR-8).
/// Workload specs are generated at their arrival instants via
/// [`StreamSpec::spec_for`]; nothing about future slots is
/// materialized.
#[derive(Debug)]
pub(crate) struct StreamState {
    pub(crate) spec: StreamSpec,
    pub(crate) schedule: arrivals::ArrivalSchedule,
    /// Total arrival slots the stream will admit.
    pub(crate) total: usize,
}

/// Per-tick scratch buffers, `mem::take`n at tick entry and returned at
/// exit so the borrow checker sees them as locals. Sized once (bank
/// dims / workload count), then only `fill`ed.
#[derive(Debug, Default)]
pub(crate) struct TickScratch {
    // bank inputs, [bank.w * bank.k] / [bank.w]
    pub(crate) b_tilde: Vec<f32>,
    pub(crate) meas_mask: Vec<f32>,
    pub(crate) m_rem: Vec<f32>,
    pub(crate) slot_mask: Vec<f32>,
    pub(crate) d: Vec<f32>,
    // workloads whose driving estimator converged this tick
    pub(crate) converged: Vec<usize>,
    // non-Kalman service-rate scratch, [n_w]
    pub(crate) r: Vec<f64>,
    pub(crate) dd: Vec<f64>,
    pub(crate) active: Vec<bool>,
    pub(crate) rates_tmp: Vec<f64>,
    /// Active CUs at this tick's monitoring instant (the bank's n_tot
    /// input) — stashed by `tick_gather` so the bank step and
    /// `tick_finish` read the same pre-step fleet description.
    pub(crate) n_tot: f32,
    /// Committed CUs (running + booting) at the same instant — the
    /// scaling policy's N_tot input.
    pub(crate) committed_cus: f64,
}

/// The assembled platform. Construct through [`Scenario::run`],
/// [`Platform::from_scenario`], or the [`Platform::new`] shim.
pub struct Platform {
    pub(crate) cfg: Config,
    // scenario knobs (broken out of the Scenario so the hot loop reads
    // plain fields)
    pub(crate) estimator: EstimatorKind,
    pub(crate) fixed_ttc_s: Option<u64>,
    pub(crate) horizon_s: u64,
    pub(crate) arrivals: ArrivalProcess,
    pub(crate) record_traces: bool,
    pub(crate) dense_ticks: bool,
    pub(crate) sim: SimEngine,
    pub(crate) backend: Box<dyn CloudBackend>,
    /// Cached `backend.execution_multiplier()` (1.0 for whole-core
    /// backends; Lambda stretches wall time by 1/core_fraction).
    pub(crate) exec_mult: f64,
    pub(crate) fault: Box<dyn FaultModel>,
    /// Reused buffer for fault-model event polling.
    pub(crate) fault_events: Vec<CloudEvent>,
    pub(crate) storage: ObjectStore,
    pub(crate) db: TaskDb,
    pub(crate) bank: Bank,
    pub(crate) tracker: Tracker,
    pub(crate) policy: Box<dyn ControlPolicy>,
    pub(crate) specs: Vec<WorkloadSpec>,
    pub(crate) wl: Vec<WlState>,
    /// Dense estimator slots, `w * k_max + k`.
    pub(crate) est: Vec<SlotEst>,
    /// Per-slot count of DB measurements already consumed by a tick —
    /// the ME reads `db.measurements(w, k)[cursor..]` as "completed
    /// since the last monitoring instant".
    pub(crate) meas_cursor: Vec<usize>,
    /// Last interval-mean measurement per slot (NaN = none yet) —
    /// reused when an interval produces no completions (eq. 8 uses
    /// b̃[t-1]).
    pub(crate) last_meas: Vec<f32>,
    pub(crate) chunks: BTreeMap<u64, Chunk>,
    pub(crate) next_chunk_id: u64,
    /// PR-10 recovery policy: crash-retry counts per task key. A task
    /// appears once it has crashed; its count gates the retry budget
    /// and scales the exponential backoff.
    pub(crate) retry_counts: BTreeMap<(usize, usize), u32>,
    /// PR-10 speculation: chunk id ↔ chunk id links between a timed-out
    /// original and its speculative twin (stored in both directions).
    /// First completion wins; the loser is torn down via this map.
    pub(crate) spec_twin: BTreeMap<u64, u64>,
    /// Latest service rates, indexed by workload id.
    pub(crate) rates: Vec<f64>,
    pub(crate) n_star_history: Vec<f64>,
    /// Allocation-free forecast window handed to the policy each
    /// evaluation: `forecast_buf[0]` is the *current* N*_tot (bitwise),
    /// `forecast_buf[h]` an LR extrapolation `h` intervals out (PR-9).
    pub(crate) forecast_buf: [f64; FORECAST_H],
    pub(crate) last_policy_eval: SimTime,
    pub(crate) k_max: usize,
    pub(crate) scratch: TickScratch,
    pub(crate) outs: StepOutputs,
    /// Reused free-slot instance id buffer for `assign_idle`.
    pub(crate) idle_buf: Vec<u64>,
    /// Reused (id, remaining-billed, cus) buffer for busy-drain scans.
    pub(crate) busy_buf: Vec<(u64, SimTime, u32)>,
    /// Reused pool-candidate buffer for the up-scaling mix fill.
    pub(crate) pool_buf: Vec<scaling::PoolFill>,
    pub(crate) metrics: RunMetrics,
    pub(crate) arrived: usize,
    pub(crate) all_done_at: Option<SimTime>,
    // ----- streaming arrivals + shard retirement (PR-8) -----------------
    /// Bank-lane occupancy: `lanes[lane]` is the workload id estimator
    /// row `lane` belongs to, ascending in id. Materialized scenarios
    /// hold the identity over the whole suite (so every lane loop is
    /// bitwise the old id loop); streaming scenarios push a lane at
    /// admission and `remove` it at retirement, recycling rows instead
    /// of growing the bank without bound.
    pub(crate) lanes: Vec<u32>,
    /// Inverse map, workload id → bank lane (`u32::MAX` = no lane:
    /// retired, or streamed-but-not-yet-admitted).
    pub(crate) lane_of: Vec<u32>,
    /// Audit-and-retire shards at workload completion (scenario knob).
    pub(crate) retire_shards: bool,
    /// Streaming arrival cursor; `None` for materialized suites.
    pub(crate) stream: Option<StreamState>,
    /// Workloads retired so far (`arrived - retired` = live shards).
    pub(crate) retired: usize,
    /// Engine sequence watermark right after the boot fleet fill: a
    /// queued event with `seq <= boot_seq` was scheduled *before* the
    /// materialized twin would have enqueued its arrival events, so at
    /// an equal instant it beats a streamed arrival (and anything
    /// later-scheduled loses) — the exact tie order the twin's
    /// seq-ordered queue produces.
    pub(crate) boot_seq: u64,
}

impl Platform {
    /// Compatibility shim over [`Platform::from_scenario`]: build a
    /// platform over `specs` (workload `id`s must be their arrival
    /// slots: 0, 1, 2, ...) with fixed-interval arrivals on a
    /// fault-free spot fleet — exactly the pre-scenario behaviour.
    pub fn new(cfg: Config, specs: Vec<WorkloadSpec>, opts: RunOpts) -> Platform {
        Platform::from_scenario(Scenario::from_opts(cfg, specs, opts))
    }

    /// Assemble the platform a scenario describes, resolving its
    /// estimator bank through the process-wide [`BankCache`].
    pub fn from_scenario(scn: Scenario) -> Platform {
        Platform::from_scenario_with_cache(scn, BankCache::global())
    }

    /// Assemble the platform a scenario describes, resolving its
    /// estimator bank through `cache` — sweep cells sharing a
    /// (W, K, estimator, params) shape pay XLA executable selection
    /// once (PR-4; `estimation::cache` pins cached == uncached).
    pub fn from_scenario_with_cache(scn: Scenario, cache: &BankCache) -> Platform {
        // the one bank-variant request (shared with
        // Scenario::bank_variant, so a pre-warmed cache is always hit)
        let bank = scn.bank_variant(cache).instantiate();
        let Scenario {
            cfg,
            specs,
            policy: policy_kind,
            estimator,
            fixed_ttc_s,
            horizon_s,
            arrivals,
            backend: backend_kind,
            fleet,
            fault,
            record_traces,
            dense_ticks,
            stream,
            retire_shards,
        } = scn;
        let k_max = specs.iter().map(|s| s.n_types).max().unwrap_or(1).max(1);
        let horizon_h = (horizon_s / 3600 + 2) as usize;
        // a scenario-level SpotReclamation bid doubles as the fulfilment
        // gate on every bid-less pool (a pool's own bid always wins; the
        // fallback is quoted for the base type and scaled per type), so
        // requests placed while the market is above the bid stay pending
        // instead of fuelling the old fulfil-then-revoke churn
        let fleet = fleet.with_default_bid(fault.spot_bid());
        let backend = backend_kind.build(&cfg, cfg.seed, horizon_h, &fleet);
        let exec_mult = backend.execution_multiplier();
        let fault = fault.build(cfg.seed);
        let storage = ObjectStore::new(cfg.storage.clone());
        let tracker = Tracker::new(cfg.control.n_w_max);
        let policy = policy_kind.build(&cfg.control);
        let wl: Vec<WlState> = specs.iter().map(WlState::new).collect();
        // materialized suites occupy the identity lanes from birth;
        // streaming suites start empty and admit lanes at arrival
        let stream = stream.map(|sp| StreamState {
            schedule: arrivals.schedule(sp.n_workloads, cfg.seed),
            total: sp.n_workloads,
            spec: sp,
        });
        let lanes: Vec<u32> = (0..specs.len() as u32).collect();
        let lane_of = lanes.clone();
        let n_slots = specs.len() * k_max;
        let est: Vec<SlotEst> = (0..n_slots)
            .map(|_| SlotEst {
                adhoc: AdHoc::paper(),
                arma: Arma::paper(),
                ewma: Ewma::paper(),
                reactive: LastObservation::new(),
                kalman_det: SlopeDetector::new(),
                adhoc_det: SlopeDetector::new(),
                arma_det: DeviationDetector::paper(cfg.control.monitor_interval_s),
                ewma_det: SlopeDetector::new(),
                reactive_det: DeviationDetector::paper(cfg.control.monitor_interval_s),
                cum_cus: 0.0,
                cum_done: 0,
                seeded: false,
            })
            .collect();
        let n_real = specs.len();
        let metrics = RunMetrics {
            reclamations_by_pool: vec![0; backend.pool_count()],
            ..RunMetrics::default()
        };
        Platform {
            cfg,
            estimator,
            fixed_ttc_s,
            horizon_s,
            arrivals,
            record_traces,
            dense_ticks,
            sim: SimEngine::new(),
            backend,
            exec_mult,
            fault,
            fault_events: vec![],
            storage,
            db: TaskDb::new(),
            bank,
            tracker,
            policy,
            specs,
            wl,
            est,
            meas_cursor: vec![0; n_slots],
            last_meas: vec![f32::NAN; n_slots],
            chunks: BTreeMap::new(),
            next_chunk_id: 0,
            retry_counts: BTreeMap::new(),
            spec_twin: BTreeMap::new(),
            rates: vec![0.0; n_real],
            n_star_history: vec![],
            forecast_buf: [0.0; FORECAST_H],
            last_policy_eval: 0,
            k_max,
            scratch: TickScratch::default(),
            outs: StepOutputs::default(),
            idle_buf: vec![],
            busy_buf: vec![],
            pool_buf: vec![],
            metrics,
            arrived: 0,
            all_done_at: None,
            lanes,
            lane_of,
            retire_shards,
            stream,
            retired: 0,
            boot_seq: 0,
        }
    }

    /// Total arrival slots this run will admit — the suite length for
    /// materialized scenarios, the stream length for streaming ones
    /// (where `specs` only holds the admitted prefix).
    pub(crate) fn total_slots(&self) -> usize {
        self.stream.as_ref().map(|s| s.total).unwrap_or(self.specs.len())
    }

    /// Shards currently resident (admitted and not yet retired).
    pub fn live_shards(&self) -> usize {
        self.arrived - self.retired
    }

    /// Workloads audited and retired so far.
    pub fn retired_shards(&self) -> usize {
        self.retired
    }

    /// Name of the estimator-bank backend in use ("xla" or "native").
    pub fn backend_name(&self) -> &'static str {
        self.bank.backend_name()
    }

    /// Name of the cloud backend in use ("spot", "on-demand", "lambda").
    pub fn cloud_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Bootstrap the experiment: N_min CUs through the same greedy type
    /// mix as up-scaling (AS starts from the same launch group; a
    /// single 1-CU pool degenerates to N_min requests), workload
    /// arrivals per the scenario's arrival process, and the first
    /// monitoring tick.
    pub(crate) fn start(&mut self) {
        self.fill_cus(self.cfg.control.n_min as i64);
        // seq watermark for the streamed-arrival tie rule: everything
        // scheduled so far (the boot fleet's readiness events) would
        // precede the twin's arrival events in the queue's seq order
        self.boot_seq = self.sim.seq();
        if self.stream.is_none() {
            let times = self.arrivals.times(self.specs.len(), self.cfg.seed);
            for (w, &at) in times.iter().enumerate() {
                self.sim.schedule_at(at, Event::WorkloadArrival { workload: w });
            }
        }
        self.sim
            .schedule(self.cfg.control.monitor_interval_s, Event::MonitorTick);
    }

    /// Admit one workload into a *running* platform (PR-7, `dithen
    /// serve`): the mid-run twin of the spec having been in the suite
    /// from the start with a [`ArrivalProcess::Scripted`] arrival at
    /// `at`. The estimator bank widens by one row of zeroed state —
    /// bitwise-neutral until the workload arrives
    /// ([`crate::estimation::Bank::grow_w`]) — and every per-workload
    /// array gains its slot, so the next `tick_gather` sees exactly the
    /// state the wide-from-birth platform would carry.
    ///
    /// Caller contract (enforced by the serve daemon):
    /// * ids are dense: `spec.id` == current suite length;
    /// * `at` is not before any already-scheduled arrival — the
    ///   per-tick `arrived <= w` bookkeeping requires arrival order to
    ///   match id order (`at` is clamped to `now` by the engine);
    /// * native estimator bank (XLA executables are shape-compiled,
    ///   so [`crate::estimation::Bank::grow_w`] rejects growth there).
    ///
    /// Clearing `all_done_at` is what resumes a quiescent run: when
    /// the latch was set mid-pump, the next `MonitorTick` is still in
    /// the queue (the pump returns before popping it), so the tick
    /// chain continues on the same grid the batch twin ticks on.
    ///
    /// Returns the workload's admitted index.
    pub fn admit_workload(&mut self, spec: WorkloadSpec, at: SimTime) -> Result<usize> {
        anyhow::ensure!(
            spec.id == self.specs.len(),
            "workload ids must be dense: got {}, next is {}",
            spec.id,
            self.specs.len()
        );
        anyhow::ensure!(
            spec.n_types >= 1 && spec.n_types <= self.k_max,
            "workload has {} media types; this platform's bank is K={}",
            spec.n_types,
            self.k_max
        );
        anyhow::ensure!(
            self.sim.now() <= self.horizon_s,
            "cannot admit past the scenario horizon ({}s)",
            self.horizon_s
        );
        let w = spec.id;
        self.push_workload_state(spec)?;
        self.sim.schedule_at(at, Event::WorkloadArrival { workload: w });
        self.all_done_at = None;
        Ok(w)
    }

    /// Grow every per-workload structure for one admitted spec: a bank
    /// lane (recycled from retired workloads when one is free,
    /// otherwise grown — so the bank width tracks the *peak live
    /// window*), the id-indexed state vectors, and the lane maps.
    /// Shared by [`Platform::admit_workload`] (PR-7 serve) and the
    /// streaming admission path (PR-8).
    pub(crate) fn push_workload_state(&mut self, spec: WorkloadSpec) -> Result<()> {
        let w = spec.id;
        debug_assert_eq!(w, self.wl.len(), "ids are dense");
        // a recycled lane leaves bank.w untouched; the max() keeps the
        // native-backend gate (growth on XLA is always rejected)
        self.bank.grow_w((self.lanes.len() + 1).max(self.bank.w))?;
        self.wl.push(WlState::new(&spec));
        self.specs.push(spec);
        for _ in 0..self.k_max {
            self.est.push(SlotEst {
                adhoc: AdHoc::paper(),
                arma: Arma::paper(),
                ewma: Ewma::paper(),
                reactive: LastObservation::new(),
                kalman_det: SlopeDetector::new(),
                adhoc_det: SlopeDetector::new(),
                arma_det: DeviationDetector::paper(self.cfg.control.monitor_interval_s),
                ewma_det: SlopeDetector::new(),
                reactive_det: DeviationDetector::paper(self.cfg.control.monitor_interval_s),
                cum_cus: 0.0,
                cum_done: 0,
                seeded: false,
            });
            self.meas_cursor.push(0);
            self.last_meas.push(f32::NAN);
        }
        self.rates.push(0.0);
        self.lane_of.push(u32::MAX);
        self.lane_of[w] = self.lanes.len() as u32;
        self.lanes.push(w as u32);
        Ok(())
    }

    /// Admit the next streamed workload at the current instant:
    /// generate its spec lazily ([`StreamSpec::spec_for`] — the same
    /// generator call the materialized twin made for this slot), push
    /// its state, and run the arrival handler inline. The twin's
    /// `WorkloadArrival` event dispatch is exactly `on_arrival`, so the
    /// two paths coincide from here on.
    pub(crate) fn admit_streamed(&mut self) -> Result<()> {
        let seed = self.cfg.seed;
        let stream = self.stream.as_mut().expect("admit_streamed requires a stream");
        let (w, _at) = stream.schedule.next().expect("stream cursor exhausted");
        let spec = stream.spec.spec_for(w, seed);
        self.push_workload_state(spec)?;
        self.on_arrival(w)?;
        Ok(())
    }

    /// Audit and retire workload `w`'s resident state (PR-8): fold its
    /// estimator-trace ground truth (the measurement log is about to
    /// drop), audit the shard's terminal counts into the workload
    /// state, recycle its arena slabs and bank lane, and delete its
    /// storage tree. Caller guarantees the workload is terminal
    /// (`WlPhase::Done`); the shard audit re-asserts it row by row.
    pub(crate) fn retire_workload(&mut self, w: usize) {
        // peak sampling first: this workload still counts as live
        self.sample_live_peaks();
        if self.record_traces {
            for k in 0..self.specs[w].n_types {
                if let Some(trace) = self.metrics.traces.get_mut(&(w, k)) {
                    let log = self.db.measurements(w, k);
                    if !log.is_empty() {
                        let sum: f64 = log.iter().map(|&(_, c)| c).sum();
                        trace.final_measured = Some(sum / log.len() as f64);
                    }
                }
            }
        }
        let audit = self.db.retire_shard(w);
        self.wl[w].terminal = Some((audit.completed, audit.failed));
        // the spec's per-task slab is dead weight now — the cached
        // shape facts in WlState serve the outcomes assembly
        self.specs[w].tasks = Vec::new();
        self.storage.delete_prefix(&format!("w{w:02}/"));
        let lane = self.lane_of[w] as usize;
        self.bank
            .retire_lane(lane)
            .expect("retirement requires the native bank (enforced by Scenario::validate)");
        self.lanes.remove(lane);
        for l in lane..self.lanes.len() {
            self.lane_of[self.lanes[l] as usize] = l as u32;
        }
        self.lane_of[w] = u32::MAX;
        self.retired += 1;
    }

    /// Track the run's peak resident footprint: live shard count and
    /// the summed arena bytes of every resident shard. Sampled at
    /// admission and just before each retirement (the curve's local
    /// maxima); both fields are perf observables excluded from
    /// `RunMetrics` equality.
    pub(crate) fn sample_live_peaks(&mut self) {
        let live = self.arrived - self.retired;
        self.metrics.peak_live_shards = self.metrics.peak_live_shards.max(live);
        let bytes: usize =
            self.lanes.iter().map(|&w| self.db.arena_bytes(w as usize)).sum();
        self.metrics.peak_arena_bytes = self.metrics.peak_arena_bytes.max(bytes);
    }

    /// Pump the event loop up to (and consuming) the next
    /// `MonitorTick`. Returns `Ok(true)` stopped *at* a tick — the
    /// caller runs the tick phases (`tick_gather` → bank step →
    /// `tick_finish`) before pumping again — and `Ok(false)` when the
    /// run is over (queue drained, horizon crossed, or all workloads
    /// done): call [`Platform::finalize`]. This is the lockstep
    /// executor's suspension point (`experiments::batched`).
    ///
    /// Streamed arrivals (PR-8) are not queue events: before each pop
    /// the pump asks the stream cursor whether its next arrival fires
    /// first. The tie rule reproduces the twin's seq-ordered queue: at
    /// an equal instant the arrival wins against anything scheduled
    /// after boot (the twin enqueued its arrival events right after the
    /// boot fleet fill, so their seqs precede every runtime event's)
    /// and loses to the boot fill's own events (`seq <= boot_seq`). A
    /// horizon-crossing arrival still advances the clock before the
    /// pump returns — the twin pops the arrival event (moving `now`)
    /// and *then* bails, and `finalize` bills through `now`.
    pub(crate) fn pump_to_tick(&mut self) -> Result<bool> {
        loop {
            let next_stream = self.stream.as_ref().and_then(|s| s.schedule.peek());
            if let Some((_, at)) = next_stream {
                let arrival_first = match self.sim.peek() {
                    None => true,
                    Some((qt, qseq)) => at < qt || (at == qt && qseq > self.boot_seq),
                };
                if arrival_first {
                    self.sim.advance_to(at);
                    if at > self.horizon_s {
                        return Ok(false);
                    }
                    self.admit_streamed()?;
                    continue;
                }
            }
            let Some((now, event)) = self.sim.next() else {
                return Ok(false);
            };
            if now > self.horizon_s {
                return Ok(false);
            }
            match event {
                Event::WorkloadArrival { workload } => self.on_arrival(workload)?,
                Event::InstanceReady { instance } => self.on_instance_ready(instance),
                Event::ChunkDone { instance, chunk } => self.on_chunk_done(instance, chunk),
                Event::MergeDone { workload, epoch } => self.on_merge_done(workload, epoch),
                Event::RetryTasks { workload, tasks } => self.on_retry_tasks(workload, &tasks),
                Event::MonitorTick => return Ok(true),
                Event::FootprintDone { .. } => {} // handled inline
            }
            if self.all_done_at.is_some() {
                return Ok(false);
            }
        }
    }

    /// Wind down a finished run — terminate everything, settle billing,
    /// assemble the metrics — and hand back the task DB alongside them
    /// (the multi-platform shard driver decomposes it via
    /// [`crate::db::TaskDb::into_shards`] for its exactly-once merge
    /// receipts).
    pub(crate) fn finalize_with_db(mut self) -> Result<(RunMetrics, TaskDb)> {
        let now = self.sim.now();
        let mut ids: Vec<u64> = vec![];
        self.backend.for_each_instance(&mut |i| ids.push(i.id));
        for id in ids {
            self.backend.terminate_instance(id, now);
        }
        self.backend.bill_through(now);
        self.metrics.total_cost = self.backend.total_cost();
        self.metrics.cost_curve = self.backend.cost_curve().to_vec();
        self.metrics.finished_at = self.all_done_at.unwrap_or(now);
        self.metrics.tasks_completed = self.wl.iter().map(|st| st.completed_tasks).sum();
        self.metrics.outcomes = self
            .wl
            .iter()
            .map(|st| WorkloadOutcome {
                arrived_at: st.arrived_at,
                completed_at: st.completed_at,
                deadline: st.deadline,
                ttc_extended: st.ttc_extended,
                // cached at admission: a retired spec's task slab is
                // gone, but the shape facts survive in the state
                n_tasks: st.n_tasks,
                total_bytes: st.total_bytes,
                tasks_abandoned: st.tasks_abandoned,
            })
            .collect();
        // finalize estimator traces with ground truth
        for ((w, k), trace) in self.metrics.traces.iter_mut() {
            let log = self.db.measurements(*w, *k);
            if !log.is_empty() {
                let sum: f64 = log.iter().map(|&(_, c)| c).sum();
                trace.final_measured = Some(sum / log.len() as f64);
            }
        }
        Ok((self.metrics, self.db))
    }

    /// Wind down a finished run; returns the metrics.
    pub(crate) fn finalize(self) -> Result<RunMetrics> {
        self.finalize_with_db().map(|(m, _)| m)
    }

    /// Execute the experiment to completion; returns the metrics.
    ///
    /// The loop is phrased in the PR-5 tick phases — pump to the next
    /// monitoring instant, gather, one solo bank step, finish — which
    /// is operation-for-operation the pre-split event loop (the
    /// determinism and shim-parity pins below and in
    /// `tests/determinism.rs` hold across the refactor). The lockstep
    /// batch executor (`experiments::batched`) drives the same phases
    /// but replaces the solo [`Platform::step_bank`] with one padded
    /// batch execution across cells.
    pub fn run(self) -> Result<RunMetrics> {
        self.run_with_db().map(|(m, _)| m)
    }

    /// [`Platform::run`], additionally returning the final task DB.
    pub fn run_with_db(mut self) -> Result<(RunMetrics, TaskDb)> {
        self.start();
        while self.pump_to_tick()? {
            self.tick_gather();
            self.step_bank()?;
            self.tick_finish();
            if self.all_done_at.is_some() {
                break;
            }
        }
        self.finalize_with_db()
    }
}

/// Convenience shim: run one experiment with the pre-scenario options
/// (fixed-interval arrivals, fault-free spot fleet).
pub fn run_experiment(cfg: Config, specs: Vec<WorkloadSpec>, opts: RunOpts) -> Result<RunMetrics> {
    Platform::new(cfg, specs, opts).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::{BackendKind, FleetSpec, InstanceState};
    use crate::util::rng::Rng;
    use crate::workload::{App, Mode, WorkloadSpec};

    fn small_cfg() -> Config {
        let mut cfg = Config::paper_defaults();
        cfg.use_xla = false; // unit tests use the native bank (fast)
        cfg.control.n_min = 4.0;
        cfg
    }

    fn small_suite(n_wl: usize, tasks_each: usize) -> Vec<WorkloadSpec> {
        let rng = Rng::new(42);
        (0..n_wl)
            .map(|i| WorkloadSpec::generate(i, App::FaceDetection, tasks_each, None, &rng))
            .collect()
    }

    fn fast_opts() -> RunOpts {
        RunOpts {
            fixed_ttc_s: Some(3600),
            arrival_interval_s: 60,
            horizon_s: 6 * 3600,
            ..Default::default()
        }
    }

    #[test]
    fn completes_all_workloads() {
        let m = run_experiment(small_cfg(), small_suite(3, 40), fast_opts()).unwrap();
        assert_eq!(m.outcomes.len(), 3);
        for o in &m.outcomes {
            assert!(o.completed_at.is_some(), "workload never completed");
        }
        assert!(m.total_cost > 0.0);
        assert!(m.max_instances >= 4);
        // fault-free run: no reclamation bookkeeping, balanced counts
        assert_eq!(m.reclamations, 0);
        assert_eq!(m.requeued_tasks, 0);
        assert_eq!(m.tasks_completed, 3 * 40);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_experiment(small_cfg(), small_suite(2, 30), fast_opts()).unwrap();
        let b = run_experiment(small_cfg(), small_suite(2, 30), fast_opts()).unwrap();
        assert_eq!(a.total_cost, b.total_cost);
        assert_eq!(a.finished_at, b.finished_at);
        assert_eq!(a.max_instances, b.max_instances);
    }

    #[test]
    fn cost_is_monotone_and_above_lower_bound() {
        let m = run_experiment(small_cfg(), small_suite(3, 60), fast_opts()).unwrap();
        for wpair in m.cost_curve.windows(2) {
            assert!(wpair[1].1 >= wpair[0].1);
        }
        let lb = m.lower_bound_cost(0.0081);
        assert!(m.total_cost >= lb, "cost {} below LB {lb}", m.total_cost);
    }

    #[test]
    fn estimator_traces_recorded_and_converge() {
        // workload must span several monitoring intervals to converge
        let m = run_experiment(small_cfg(), small_suite(2, 800), fast_opts()).unwrap();
        let tr = &m.traces[&(0, 0)];
        assert!(!tr.kalman.is_empty());
        assert!(tr.final_measured.is_some());
        assert!(tr.kalman_t_init.is_some(), "kalman never converged");
    }

    #[test]
    fn all_policies_complete_the_suite() {
        for policy in [
            PolicyKind::Aimd,
            PolicyKind::Reactive,
            PolicyKind::Mwa,
            PolicyKind::Lr,
            PolicyKind::AmazonAs1,
        ] {
            let mut opts = fast_opts();
            opts.policy = policy;
            if policy == PolicyKind::AmazonAs1 {
                opts.fixed_ttc_s = None;
            }
            let m = run_experiment(small_cfg(), small_suite(2, 25), opts).unwrap();
            assert!(
                m.outcomes.iter().all(|o| o.completed_at.is_some()),
                "{policy:?} left workloads incomplete"
            );
        }
    }

    #[test]
    fn all_estimators_drive_completion() {
        for est in EstimatorKind::ALL {
            let mut opts = fast_opts();
            opts.estimator = est;
            let m = run_experiment(small_cfg(), small_suite(2, 25), opts).unwrap();
            assert!(m.outcomes.iter().all(|o| o.completed_at.is_some()));
        }
    }

    #[test]
    fn splitmerge_workload_runs_merge() {
        let rng = Rng::new(9);
        let spec = WorkloadSpec::generate_mode(
            0,
            App::CnnClassify,
            30,
            Mode::SplitMerge { merge_frac: 0.1 },
            None,
            &rng,
        );
        let m = run_experiment(small_cfg(), vec![spec], fast_opts()).unwrap();
        assert!(m.outcomes[0].completed_at.is_some());
    }

    #[test]
    fn ttc_honored_under_aimd() {
        let mut opts = fast_opts();
        opts.fixed_ttc_s = Some(2 * 3600);
        let m = run_experiment(small_cfg(), small_suite(3, 40), opts).unwrap();
        assert!(
            m.ttc_compliance() >= 0.99,
            "TTC compliance {}",
            m.ttc_compliance()
        );
    }

    #[test]
    fn single_task_workload_degenerates_cleanly() {
        let m = run_experiment(small_cfg(), small_suite(1, 1), fast_opts()).unwrap();
        assert!(m.outcomes[0].completed_at.is_some());
        assert_eq!(m.outcomes[0].n_tasks, 1);
    }

    // ----- scenario API ---------------------------------------------------

    /// The acceptance-criterion parity guard: the `RunOpts` shim and an
    /// explicitly-built default scenario (fixed-interval arrivals, spot
    /// backend, no faults) must be the *same* experiment — bit-identical
    /// `RunMetrics`.
    #[test]
    fn shim_and_builder_are_bit_identical() {
        let shim = run_experiment(small_cfg(), small_suite(2, 30), fast_opts()).unwrap();
        let built = ScenarioBuilder::new(small_cfg())
            .workloads(small_suite(2, 30))
            .policy(PolicyKind::Aimd)
            .estimator(EstimatorKind::Kalman)
            .fixed_ttc(Some(3600))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(6 * 3600)
            .backend(BackendKind::Spot)
            .fault(FaultSpec::None)
            .build()
            .run()
            .unwrap();
        assert_eq!(shim, built, "builder diverged from the RunOpts shim");
    }

    /// The heterogeneous-fleet parity guard: the explicit degenerate
    /// single-pool fleet (one bid-less m3.medium pool) must be the
    /// *same* experiment as the pre-fleet shim — bit-identical
    /// `RunMetrics`. Together with `shim_and_builder_are_bit_identical`
    /// this pins the pool-aware cloud layer to the pre-refactor output.
    #[test]
    fn single_pool_fleet_is_bit_identical_to_shim() {
        let shim = run_experiment(small_cfg(), small_suite(2, 30), fast_opts()).unwrap();
        let built = ScenarioBuilder::new(small_cfg())
            .workloads(small_suite(2, 30))
            .fixed_ttc(Some(3600))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(6 * 3600)
            .fleet(FleetSpec::parse("m3.medium").unwrap())
            .build()
            .run()
            .unwrap();
        assert_eq!(shim, built, "explicit single-pool fleet diverged from the shim");
    }

    /// PR-10 fault-free parity pin: the partial-failure machinery
    /// (the straggler lookup at dispatch, the crash check at chunk
    /// completion, the flake hook at instance request, the speculation
    /// gate in the tick) must be invisible when disabled — a
    /// `FaultSpec::None` run and the degenerate zero-rate fault models
    /// are all bit-identical (exhaustive `RunMetrics` equality, traces
    /// on) to the plain `RunOpts` shim, i.e. the pre-PR-10 trajectory,
    /// and every new degradation receipt stays zero.
    ///
    /// `Straggler { frac: 0 }` is deliberately absent from the list: a
    /// straggler model *arms* the speculation scan
    /// ([`FaultModel::enables_speculation`]), whose timeout heuristic
    /// may legitimately fire on an honest estimate miss — only models
    /// that leave the scan disarmed promise bit-identity.
    #[test]
    fn partial_fault_machinery_is_bit_identical_when_disabled() {
        let reference = run_experiment(small_cfg(), small_suite(2, 30), fast_opts()).unwrap();
        assert_eq!(reference.chunk_retries, 0);
        assert_eq!(reference.speculative_launches, 0);
        assert_eq!(reference.straggler_instances, 0);
        assert_eq!(reference.tasks_abandoned, 0);
        assert!(reference.outcomes.iter().all(|o| o.tasks_abandoned == 0));
        for fault in [
            FaultSpec::None,
            FaultSpec::ChunkCrash { rate: 0.0 },
            FaultSpec::LaunchFlake { prob: 0.0, delay_s: 120 },
        ] {
            let label = format!("{fault:?}");
            let m = ScenarioBuilder::new(small_cfg())
                .workloads(small_suite(2, 30))
                .fixed_ttc(Some(3600))
                .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
                .horizon(6 * 3600)
                .fault(fault)
                .build()
                .run()
                .unwrap();
            assert_eq!(reference, m, "disabled fault machinery diverged under {label}");
        }
    }

    /// Regression for the old up-scaling 1-CU assumption: a CU deficit
    /// was requested as that many *instances*, over-provisioning a
    /// 16-CU-type fleet 16-fold. The mix fill requests whole CU blocks,
    /// so a 100-CU cap (`n_max`) can never exceed a handful of 16-CU
    /// instances.
    #[test]
    fn multi_cu_fleet_does_not_overshoot_cu_target() {
        let m = ScenarioBuilder::new(small_cfg())
            .workloads(small_suite(2, 60))
            .fixed_ttc(Some(1800))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(6 * 3600)
            .fleet(FleetSpec::parse("m4.4xlarge").unwrap())
            .build()
            .run()
            .unwrap();
        // ceil(100 / 16) = 7 concurrent instances (+ transient drain
        // overlap); the pre-fix behaviour requested dozens
        assert!(
            m.max_instances <= 10,
            "{} concurrent 16-CU instances for a 100-CU cap",
            m.max_instances
        );
        assert!(m.outcomes.iter().all(|o| o.completed_at.is_some()));
        assert_eq!(m.tasks_completed, 2 * 60);
    }

    // ----- §IV lazy-drain billing window ---------------------------------

    fn drain_platform(policy: PolicyKind) -> Platform {
        let scn = ScenarioBuilder::new(small_cfg())
            .workloads(small_suite(1, 10))
            .policy(policy)
            .build();
        Platform::from_scenario(scn)
    }

    /// Boot one idle instance and pin its remaining pre-billed time to
    /// `rem` seconds (at sim time 0), then shrink the fleet to zero.
    fn boot_idle_with_remaining(p: &mut Platform, rem: SimTime) -> u64 {
        let (id, ready) = p.backend.request_instance_in(0, 0).unwrap();
        p.backend.instance_ready(id, ready);
        p.backend.instance_mut(id).unwrap().billed_until = rem;
        id
    }

    /// §IV: under AIMD an idle instance whose pre-billed hour still has
    /// more than the renewal window left is free capacity — down-scaling
    /// keeps it; once the remainder falls inside the window it is
    /// released before the next increment bills.
    #[test]
    fn aimd_lazy_drain_respects_the_billing_window() {
        // window = max(3/2 * monitor_interval + 1, 120) = 120 s here
        let mut p = drain_platform(PolicyKind::Aimd);
        let kept = boot_idle_with_remaining(&mut p, 121);
        p.adjust_fleet(0.0);
        assert_eq!(
            p.backend.instance(kept).unwrap().state,
            InstanceState::Running,
            "remaining time just above the window must be kept"
        );
        // the same instance one tick later: now inside the window
        p.backend.instance_mut(kept).unwrap().billed_until = 120;
        p.adjust_fleet(0.0);
        assert_eq!(
            p.backend.instance(kept).unwrap().state,
            InstanceState::Terminated,
            "remaining time at/below the window must terminate"
        );
    }

    /// Baselines (`PolicyKind != Aimd`) set N_tot[t+1] directly and
    /// terminate eagerly no matter how much pre-billed time remains.
    #[test]
    fn baseline_policies_terminate_eagerly_regardless_of_window() {
        for policy in [PolicyKind::Reactive, PolicyKind::AmazonAs1] {
            let mut p = drain_platform(policy);
            let id = boot_idle_with_remaining(&mut p, 3600);
            p.adjust_fleet(0.0);
            assert_eq!(
                p.backend.instance(id).unwrap().state,
                InstanceState::Terminated,
                "{policy:?} must not apply the AIMD lazy-drain window"
            );
        }
    }

    /// Real-EC2 unfulfilled-request semantics: a bid below the simulated
    /// price floor leaves every spot request pending — the fleet never
    /// grows, nothing is billed, nothing can be reclaimed (no more
    /// fulfil-at-market-then-revoke churn).
    #[test]
    fn below_floor_bid_starves_the_fleet() {
        let m = ScenarioBuilder::new(small_cfg())
            .workloads(small_suite(1, 5))
            .fixed_ttc(Some(1200))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(1800)
            .fault(FaultSpec::SpotReclamation { bid: 0.001 })
            .build()
            .run()
            .unwrap();
        assert_eq!(m.max_instances, 0, "an above-bid request must stay pending");
        assert_eq!(m.total_cost, 0.0);
        assert!(m.unfulfilled_requests > 0);
        assert_eq!(m.reclamations, 0, "nothing was ever fulfilled, nothing to revoke");
        assert!(m.outcomes[0].completed_at.is_none());
    }

    /// ... and a bid above the m3.medium hard price cap (the market
    /// simulator clamps at on-demand x 1.2 = $0.0804) fulfils
    /// everything: the fault bid only bites when the market actually
    /// crosses it.
    #[test]
    fn above_cap_bid_fulfils_every_request() {
        let m = ScenarioBuilder::new(small_cfg())
            .workloads(small_suite(1, 20))
            .fixed_ttc(Some(3600))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(4 * 3600)
            .fault(FaultSpec::SpotReclamation { bid: 0.1 })
            .build()
            .run()
            .unwrap();
        assert_eq!(m.unfulfilled_requests, 0);
        assert_eq!(m.reclamations, 0);
        assert!(m.outcomes[0].completed_at.is_some());
    }

    /// Gating trace recording must not perturb the control loop: same
    /// costs/curves/outcomes, just no recorded traces.
    #[test]
    fn trace_gating_does_not_perturb_control() {
        let on = run_experiment(small_cfg(), small_suite(2, 30), fast_opts()).unwrap();
        let mut opts = fast_opts();
        opts.record_traces = false;
        let off = run_experiment(small_cfg(), small_suite(2, 30), opts).unwrap();
        assert!(off.traces.is_empty(), "record_traces=false still recorded traces");
        assert!(!on.traces.is_empty());
        assert_eq!(on.total_cost, off.total_cost);
        assert_eq!(on.finished_at, off.finished_at);
        assert_eq!(on.cost_curve, off.cost_curve);
        assert_eq!(on.n_star_curve, off.n_star_curve);
        assert_eq!(on.outcomes, off.outcomes);
        assert_eq!(on.ticks, off.ticks);
    }

    #[test]
    fn on_demand_backend_completes_and_costs_more_than_spot() {
        let build = |backend| {
            ScenarioBuilder::new(small_cfg())
                .workloads(small_suite(2, 40))
                .fixed_ttc(Some(3600))
                .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
                .horizon(6 * 3600)
                .backend(backend)
                .build()
                .run()
                .unwrap()
        };
        let spot = build(BackendKind::Spot);
        let od = build(BackendKind::OnDemand);
        assert!(od.outcomes.iter().all(|o| o.completed_at.is_some()));
        // Table V: spot is ~78-89 % below on-demand; same schedule, same
        // hourly increments, so the total must be several times cheaper
        assert!(
            spot.total_cost < od.total_cost / 2.0,
            "spot {} vs on-demand {}",
            spot.total_cost,
            od.total_cost
        );
    }

    #[test]
    fn lambda_backend_runs_the_same_loop() {
        let m = ScenarioBuilder::new(small_cfg())
            .workloads(small_suite(1, 30))
            .fixed_ttc(Some(2 * 3600))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(8 * 3600)
            .backend(BackendKind::Lambda)
            .build()
            .run()
            .unwrap();
        assert!(m.outcomes[0].completed_at.is_some(), "lambda run incomplete");
        assert!(m.total_cost > 0.0);
        assert_eq!(m.tasks_completed, 30);
    }

    #[test]
    fn bursty_and_poisson_arrivals_complete() {
        for arrivals in [
            ArrivalProcess::Bursty { burst: 3, gap_s: 900 },
            ArrivalProcess::Poisson { mean_gap_s: 120.0 },
        ] {
            let m = ScenarioBuilder::new(small_cfg())
                .workloads(small_suite(3, 25))
                .fixed_ttc(Some(3600))
                .arrivals(arrivals.clone())
                .horizon(8 * 3600)
                .build()
                .run()
                .unwrap();
            assert!(
                m.outcomes.iter().all(|o| o.completed_at.is_some()),
                "{arrivals:?} left workloads incomplete"
            );
        }
    }

    #[test]
    fn scripted_reclamation_requeues_and_still_completes() {
        let m = ScenarioBuilder::new(small_cfg())
            .workloads(small_suite(2, 40))
            .fixed_ttc(Some(1500))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(4 * 3600)
            .fault(FaultSpec::ReclamationAt {
                times: vec![420, 540, 660, 780, 900, 1020],
            })
            .build()
            .run()
            .unwrap();
        assert!(m.reclamations > 0, "no instances were revoked");
        assert!(m.outcomes.iter().all(|o| o.completed_at.is_some()));
        assert_eq!(m.tasks_completed, 2 * 40, "task counts must balance");
    }

    #[test]
    fn mid_run_admission_is_bitwise_equal_to_the_scripted_batch_twin() {
        // PR-7 pin: admitting a workload into a quiescent live platform
        // (`dithen serve`'s mid-run /submit path) must continue the run
        // exactly as if the workload had been in the suite from the
        // start with a Scripted arrival at the same instant. Workload 0
        // finishes long before t = 3600, so the admission lands after
        // the all-done latch — the hard case, where the tick chain is
        // resumed from the still-queued MonitorTick.
        use crate::estimation::BankCache;
        let rng = Rng::new(42);
        let spec0 = WorkloadSpec::generate(0, App::FaceDetection, 30, None, &rng);
        let spec1 = WorkloadSpec::generate(1, App::FaceDetection, 25, None, &rng);
        let build = |specs: Vec<WorkloadSpec>, times: Vec<SimTime>| {
            ScenarioBuilder::new(small_cfg())
                .workloads(specs)
                .fixed_ttc(Some(1500))
                .arrivals(ArrivalProcess::Scripted { times })
                .horizon(6 * 3600)
                .build()
        };
        let batch = build(vec![spec0.clone(), spec1.clone()], vec![0, 3600]).run().unwrap();

        let cache = BankCache::new();
        let scn = build(vec![spec0], vec![0]);
        let mut p = Platform::from_scenario_with_cache(scn, &cache);
        p.start();
        while p.pump_to_tick().unwrap() {
            p.tick_gather();
            p.step_bank().unwrap();
            p.tick_finish();
            if p.all_done_at.is_some() {
                break;
            }
        }
        assert!(p.all_done_at.is_some(), "workload 0 should have drained");
        p.admit_workload(spec1, 3600).unwrap();
        assert!(p.all_done_at.is_none(), "admission must clear the latch");
        while p.pump_to_tick().unwrap() {
            p.tick_gather();
            p.step_bank().unwrap();
            p.tick_finish();
            if p.all_done_at.is_some() {
                break;
            }
        }
        let (live, _db) = p.finalize_with_db().unwrap();
        assert_eq!(live, batch, "mid-run admission diverged from the scripted batch twin");
        assert_eq!(live.tasks_completed, 55);

        // contract violations surface as errors, not corruption
        let scn = build(vec![WorkloadSpec::generate(0, App::Brisk, 5, None, &rng)], vec![0]);
        let mut p = Platform::from_scenario_with_cache(scn, &cache);
        p.start();
        let bad_id = WorkloadSpec::generate(5, App::Brisk, 5, None, &rng);
        assert!(p.admit_workload(bad_id, 0).is_err(), "non-dense id must be rejected");
    }

    // ----- PR-8 streaming arrivals + shard retirement ---------------------

    /// The PR-8 headline pin in miniature: a streaming suite (lazy
    /// workload materialization at arrival instants) with shard
    /// retirement must produce *bit-identical* `RunMetrics` to the
    /// materialize-everything twin that pre-builds every spec and keeps
    /// every shard resident. The full cross-thread version lives in
    /// `tests/determinism.rs`.
    #[test]
    fn streaming_with_retirement_matches_the_materialized_twin() {
        let stream = StreamSpec {
            n_workloads: 4,
            tasks_per_workload: 25,
            app: App::FaceDetection,
        };
        let scn = ScenarioBuilder::new(small_cfg())
            .stream(stream)
            .retire_shards(true)
            .fixed_ttc(Some(1500))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(6 * 3600)
            .build();
        // the twin: same suite, fully materialized up front, nothing
        // retired — the memory-proportional path must be unobservable
        let mut twin = scn.materialize();
        assert!(twin.stream.is_none() && twin.specs.len() == 4);
        twin.retire_shards = false;
        let streamed = scn.run().unwrap();
        let batch = twin.run().unwrap();
        assert_eq!(streamed, batch, "streaming+retirement diverged from the batch twin");
        assert_eq!(streamed.tasks_completed, 100);
        assert!(streamed.outcomes.iter().all(|o| o.completed_at.is_some()));
        // peaks are observability-only (excluded from RunMetrics
        // equality): streaming+retirement keeps at most the live window
        // resident, the twin keeps everything
        assert!(streamed.peak_live_shards >= 1 && streamed.peak_live_shards <= 4);
        assert!(streamed.peak_arena_bytes > 0);
        assert!(streamed.peak_arena_bytes <= batch.peak_arena_bytes);
        assert_eq!(batch.peak_live_shards, 4);
    }

    /// Retirement audits every terminal shard exactly once and recycles
    /// its resources: task counts land in the metrics, the arena slab
    /// moves to the DB free pool, the storage prefix is dropped, and the
    /// bank lane is compacted away.
    #[test]
    fn retirement_recycles_shards_and_conserves_tasks() {
        use crate::estimation::BankCache;
        let stream = StreamSpec {
            n_workloads: 6,
            tasks_per_workload: 20,
            app: App::FaceDetection,
        };
        let scn = ScenarioBuilder::new(small_cfg())
            .stream(stream)
            .retire_shards(true)
            .fixed_ttc(Some(1500))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 600 })
            .horizon(8 * 3600)
            .build();
        let cache = BankCache::new();
        let p = Platform::from_scenario_with_cache(scn, &cache);
        let (m, db) = p.run_with_db().unwrap();
        assert_eq!(m.tasks_completed, 6 * 20, "retirement lost or duplicated tasks");
        assert_eq!(m.outcomes.len(), 6);
        for (w, o) in m.outcomes.iter().enumerate() {
            assert!(o.completed_at.is_some(), "w{w} never completed");
            assert_eq!(o.n_tasks, 20, "w{w} shape facts must survive retirement");
        }
        // every shard was retired: tombstones hold no arena memory and
        // the slabs sit in (or were recycled through) the free pool
        for w in 0..6 {
            assert_eq!(db.arena_bytes(w), 0, "w{w} still holds arena memory");
        }
        assert!(db.free_shards() >= 1, "no slab ever reached the free pool");
        // staggered arrivals + retirement keep the live window below the
        // full suite
        assert!(m.peak_live_shards < 6, "peak {} never dipped below the suite", m.peak_live_shards);
    }
}
