//! The GCI monitoring tick: billing, fault polling, measurement-engine
//! assembly, the estimator-bank step (L1/L2 hot path), passive
//! estimators + convergence, TTC confirmation, service rates and the
//! scaling-policy evaluation.
//!
//! Order within a tick (deliberate): billing settles first; then the
//! fault model fires (so a reclamation at this instant is *visible* to
//! the same tick's fleet description and the policy reacts immediately —
//! the reactive-control story of §V); then estimation/scheduling run on
//! the post-fault fleet. With the `NoFaults` model this is byte-for-byte
//! the pre-scenario tick.
//!
//! §Structure (PR-5): the tick is split at the bank step so a lockstep
//! batch driver can interpose — [`Platform::tick_gather`] runs
//! everything *up to* the estimator-bank inputs (billing, faults, ME
//! assembly into `TickScratch`, the fleet description stashed as
//! `scratch.n_tot` / `scratch.committed_cus`), the bank step consumes
//! [`Platform::bank_inputs`] (solo runs via [`Platform::step_bank`];
//! the batched executor gathers the same inputs into a padded
//! [`crate::estimation::BatchScratch`] lane instead), and
//! [`Platform::tick_finish`] runs everything *after* it off the
//! refilled `StepOutputs`. A solo tick is exactly the pre-split tick:
//! the same operations in the same order on the same state. Each phase
//! accrues its *own* wall time into `metrics.tick_wall_ns`, so a
//! batched cell never absorbs other lanes' work in its tick metric
//! (the shared padded execution is timed by the batch driver's caller,
//! e.g. `bench-report`'s `batched_tasks_per_s`, not per cell).
//!
//! §Perf: allocation-free in steady state with traces off — every
//! working set lives in [`super::TickScratch`] or a platform-owned
//! buffer and is reused across ticks. Trace recording (three Vec pushes
//! per active slot per tick) is gated behind `record_traces`.
//!
//! §Serve (PR-7): the phase seams double as the daemon's *ingestion
//! suspension points* — `dithen serve`'s control thread drains queued
//! HTTP submissions between `tick_finish` and the next
//! `pump_to_tick`, so a mid-run [`Platform::admit_workload`] always
//! lands on a monitoring-instant boundary. `tick_gather` re-sizes the
//! scratch from the *current* `bank.w` every tick, which is what lets
//! an admitted workload (one `Bank::grow_w` row) flow through the
//! next round with no daemon-specific tick code.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::policy::PolicyCtx;
use crate::coordinator::service_rates_into;
use crate::coordinator::ttc::confirm;
use crate::estimation::EstimatorKind;
use crate::platform::{Platform, TickScratch, WlPhase};
use crate::runtime::StepOutputs;
use crate::sim::Event;

impl Platform {
    /// The pre-bank half of the monitoring tick: settle billing, poll
    /// the fault model, assemble the estimator-bank inputs (eqs. 1-3
    /// bookkeeping) into `self.scratch`, and stash the fleet
    /// description the post-bank half needs (`n_tot`,
    /// `committed_cus`). After this returns, [`Platform::bank_inputs`]
    /// is the exact input of this tick's bank step.
    pub(crate) fn tick_gather(&mut self) {
        let now = self.sim.now();
        let t0 = Instant::now();
        self.backend.bill_through(now);

        // ----- fault injection (spot reclamation) -----------------------
        let mut evs = std::mem::take(&mut self.fault_events);
        evs.clear();
        self.fault.poll(&*self.backend, now, &mut evs);
        for ev in &evs {
            self.apply_cloud_event(ev, now);
        }
        self.fault_events = evs;

        // take the scratch so field borrows stay disjoint; returned at
        // the end of the phase
        let mut sc = std::mem::take(&mut self.scratch);

        // ----- ME: assemble bank inputs (eqs. 1-3 bookkeeping) ----------
        // Bank rows are *lane*-indexed (PR-8): `lanes[lane]` is the
        // workload occupying estimator row `lane`. Materialized suites
        // hold the identity mapping, so this loop is bitwise the old
        // id-indexed walk; streaming suites only walk the live window.
        let k = self.k_max;
        let (bw, bk) = (self.bank.w, self.bank.k);
        let wk = bw * bk;
        sc.b_tilde.resize(wk, 0.0);
        sc.meas_mask.resize(wk, 0.0);
        sc.m_rem.resize(wk, 0.0);
        sc.slot_mask.resize(wk, 0.0);
        sc.d.resize(bw, 0.0);
        sc.b_tilde.fill(0.0);
        sc.meas_mask.fill(0.0);
        sc.m_rem.fill(0.0);
        sc.slot_mask.fill(0.0);
        sc.d.fill(0.0);
        for lane in 0..self.lanes.len() {
            let w = self.lanes[lane] as usize;
            let st = &self.wl[w];
            if st.arrived_at > now || matches!(st.phase, WlPhase::Done) || self.arrived <= w {
                continue;
            }
            // resolve the workload's DB shard once; every m_{w,k} /
            // measurement read below is then shard-local (PR-4)
            let shard = self.db.shard(w);
            let remaining = shard.map(|s| s.remaining_slice()).unwrap_or(&[]);
            let dl = st.deadline.unwrap_or(now + 3600);
            // safety margin of one monitoring interval: allocation is
            // interval-quantized, so pacing against the raw deadline
            // systematically finishes up to one interval late
            let margin = self.cfg.control.monitor_interval_s;
            sc.d[lane] = dl.saturating_sub(now).saturating_sub(margin).max(1) as f32;
            for ki in 0..self.specs[w].n_types.min(k) {
                let idx = lane * bk + ki;
                let slot = w * self.k_max + ki;
                sc.slot_mask[idx] = 1.0;
                sc.m_rem[idx] = remaining.get(ki).copied().unwrap_or(0) as f32;
                let log = shard.map(|s| s.measurements(ki)).unwrap_or(&[]);
                let cursor = self.meas_cursor[slot];
                if log.len() > cursor {
                    let fresh = &log[cursor..];
                    let sum: f64 = fresh.iter().map(|&(_, c)| c).sum();
                    let m = (sum / fresh.len() as f64) as f32;
                    sc.b_tilde[idx] = m;
                    sc.meas_mask[idx] = 1.0;
                    self.meas_cursor[slot] = log.len();
                    self.last_meas[slot] = m;
                } else {
                    let last = self.last_meas[slot];
                    if !last.is_nan() {
                        // eq. (8) uses b̃[t-1]: when no tasks of this type
                        // completed in the interval, the previous
                        // measurement is reused (the paper's estimator
                        // keeps pulling toward the last observation)
                        sc.b_tilde[idx] = last;
                        sc.meas_mask[idx] = 1.0;
                    }
                }
            }
        }
        let fleet = self.backend.describe(now);
        sc.n_tot = fleet.active_cus as f32;
        sc.committed_cus = fleet.committed_cus;
        self.scratch = sc;
        self.metrics.tick_wall_ns += t0.elapsed().as_nanos();
    }

    /// This tick's estimator-bank inputs, borrowed from the scratch
    /// [`Platform::tick_gather`] filled — the gather point of the
    /// lockstep batch executor (`experiments::batched`).
    pub(crate) fn bank_inputs(&self) -> crate::estimation::TickInputs<'_> {
        let sc = &self.scratch;
        crate::estimation::TickInputs {
            b_tilde: &sc.b_tilde,
            meas_mask: &sc.meas_mask,
            m_rem: &sc.m_rem,
            slot_mask: &sc.slot_mask,
            d: &sc.d,
            n_tot: sc.n_tot,
        }
    }

    /// The solo bank step: one `step_into` on this platform's own bank
    /// (the batched executor replaces exactly this call with its padded
    /// lane).
    pub(crate) fn step_bank(&mut self) -> Result<()> {
        let t0 = Instant::now();
        // field-disjoint borrows: bank (mut) reads scratch (shared) and
        // refills outs (mut)
        let r = self.bank.step_into(
            &crate::estimation::TickInputs {
                b_tilde: &self.scratch.b_tilde,
                meas_mask: &self.scratch.meas_mask,
                m_rem: &self.scratch.m_rem,
                slot_mask: &self.scratch.slot_mask,
                d: &self.scratch.d,
                n_tot: self.scratch.n_tot,
            },
            &mut self.outs,
        );
        self.metrics.tick_wall_ns += t0.elapsed().as_nanos();
        r
    }

    /// The post-bank half of the monitoring tick, consuming the
    /// refilled `self.outs`: passive estimators + convergence, service
    /// rates, TTC confirmation, the scaling policy, tracker credits,
    /// dispatch, metrics and the next tick's scheduling.
    pub(crate) fn tick_finish(&mut self) {
        let t0 = Instant::now();
        let now = self.sim.now();
        let n_w = self.specs.len();
        let bk = self.bank.k;
        let mut sc = std::mem::take(&mut self.scratch);
        let outs = std::mem::take(&mut self.outs);

        // ----- passive estimators + convergence + traces ----------------
        sc.converged.clear();
        for lane in 0..self.lanes.len() {
            let w = self.lanes[lane] as usize;
            if self.arrived <= w || matches!(self.wl[w].phase, WlPhase::Done) {
                continue;
            }
            let spec = &self.specs[w];
            for ki in 0..spec.n_types {
                let idx = lane * bk + ki;
                if sc.slot_mask[idx] == 0.0 {
                    continue;
                }
                let had_meas = sc.meas_mask[idx] > 0.0;
                let kalman_b = outs.b_hat[idx] as f64;
                // update the passive estimators + detectors (borrow of
                // the slot ends before any trace recording below)
                let (vals, conv) = {
                    let est = &mut self.est[w * self.k_max + ki];
                    if !est.seeded {
                        continue;
                    }
                    let m = if had_meas { Some(sc.b_tilde[idx] as f64) } else { None };
                    let adhoc_b = est.adhoc.update(m);
                    let arma_b = match crate::estimation::arma::normalize_per_item(
                        est.cum_cus,
                        est.cum_done,
                    ) {
                        Some(bn) if had_meas => est.arma.update(bn),
                        _ => est.arma.b_hat,
                    };
                    let ewma_b = est.ewma.update(m);
                    let reactive_b = est.reactive.update(m);
                    (
                        [adhoc_b, arma_b, ewma_b, reactive_b],
                        [
                            est.kalman_det.push(kalman_b).is_some(),
                            est.adhoc_det.push(adhoc_b).is_some(),
                            est.arma_det.push(arma_b).is_some(),
                            est.ewma_det.push(ewma_b).is_some(),
                            est.reactive_det.push(reactive_b).is_some(),
                        ],
                    )
                };
                let [adhoc_b, arma_b, ewma_b, reactive_b] = vals;
                let [kalman_conv, adhoc_conv, arma_conv, ewma_conv, reactive_conv] = conv;
                if self.record_traces {
                    let trace = self.metrics.traces.get_mut(&(w, ki)).unwrap();
                    trace.kalman.push((now, kalman_b));
                    trace.adhoc.push((now, adhoc_b));
                    trace.arma.push((now, arma_b));
                    trace.ewma.push((now, ewma_b));
                    trace.reactive.push((now, reactive_b));
                    if kalman_conv {
                        trace.kalman_t_init = Some(now);
                        trace.kalman_at_init = Some(kalman_b);
                    }
                    if adhoc_conv {
                        trace.adhoc_t_init = Some(now);
                        trace.adhoc_at_init = Some(adhoc_b);
                    }
                    if arma_conv {
                        trace.arma_t_init = Some(now);
                        trace.arma_at_init = Some(arma_b);
                    }
                    if ewma_conv {
                        trace.ewma_t_init = Some(now);
                        trace.ewma_at_init = Some(ewma_b);
                    }
                    if reactive_conv {
                        trace.reactive_t_init = Some(now);
                        trace.reactive_at_init = Some(reactive_b);
                    }
                }
                if kalman_conv && self.estimator == EstimatorKind::Kalman {
                    sc.converged.push(w);
                }
                if adhoc_conv && self.estimator == EstimatorKind::AdHoc {
                    sc.converged.push(w);
                }
                if arma_conv && self.estimator == EstimatorKind::Arma {
                    sc.converged.push(w);
                }
                if ewma_conv && self.estimator == EstimatorKind::Ewma {
                    sc.converged.push(w);
                }
                if reactive_conv && self.estimator == EstimatorKind::Reactive {
                    sc.converged.push(w);
                }
            }
        }

        // ----- service rates from the *driving* estimator ----------------
        let n_tot = sc.n_tot as f64;
        let n_star = self.driving_rates_into(&outs, &mut sc, n_tot);
        for w in 0..n_w {
            self.rates[w] = sc.rates_tmp[w].min(self.cfg.control.n_w_max);
        }
        self.n_star_history.push(n_star);
        self.metrics.n_star_curve.push((now, n_star));

        // ----- TTC confirmation at t_init (§II-E-4) ----------------------
        for &w in &sc.converged {
            if self.wl[w].confirmed {
                continue;
            }
            self.wl[w].confirmed = true;
            if let Some(dl) = self.wl[w].deadline {
                let r_w = self.driving_r(&outs, w);
                let c = confirm(r_w, dl, now, self.cfg.control.n_w_max);
                let st = &mut self.wl[w];
                st.deadline = Some(c.deadline);
                st.ttc_extended = c.extended;
            }
        }

        // ----- scaling policy ---------------------------------------------
        let eval_due = match self.policy.eval_interval_s() {
            Some(iv) => now.saturating_sub(self.last_policy_eval) >= iv,
            None => true,
        };
        if eval_due {
            self.last_policy_eval = now;
            let work_pending = self.work_left();
            self.fill_forecast(n_star);
            let deadline_slack_s = self.deadline_slack(now);
            let ctx = PolicyCtx {
                now,
                n_tot: sc.committed_cus,
                n_star,
                n_star_history: &self.n_star_history,
                forecast: &self.forecast_buf,
                deadline_slack_s,
                mean_utilization: self.backend.mean_utilization(now),
                work_pending,
            };
            let target = self.policy.target(&ctx).round().max(0.0);
            self.adjust_fleet(target);
        }

        // ----- tracker credits + assignment -------------------------------
        self.tracker.tick(&self.rates);
        self.assign_idle();
        self.check_speculation(now);

        self.metrics.ticks += 1;
        self.metrics.tick_wall_ns += t0.elapsed().as_nanos();
        self.sample_instances(now);

        // continue while work remains or arrivals are still scheduled
        // (for streaming suites, while the stream cursor has slots left)
        let more_arrivals = self.arrived < self.total_slots();
        let work_left = self.work_left();
        if more_arrivals || work_left {
            let interval = self.cfg.control.monitor_interval_s;
            let mut next_tick = now + interval;
            // ----- sparse-tick skipping (PR-6) --------------------------
            // Between workload batches the dense loop burns ticks on an
            // idle platform. When every arrived workload is Done
            // (`!work_left`; the chunk map being empty is the same fact
            // seen from the dispatch side) the only observable work a
            // dense tick does is decay the idle fleet, settle due
            // billing and append curve samples — all replayed exactly by
            // `fast_forward_tick`, tick by tick, while event dispatch is
            // provably idle (no arrival, completion, price change or
            // scheduled fault strictly before the skip horizon).
            if !self.dense_ticks && !work_left && more_arrivals && self.chunks.is_empty() {
                next_tick = self.skip_idle_ticks(next_tick, interval, &mut sc, &outs);
            }
            self.sim.schedule_at(next_tick, Event::MonitorTick);
        }

        self.scratch = sc;
        self.outs = outs;
    }

    // ----- sparse-tick skipping (PR-6) -------------------------------------

    /// Earliest instant at which something *other than a monitoring
    /// tick* can change observable platform state: the next non-tick
    /// simulator event (chunk completions, instance readiness, and —
    /// for materialized suites — the pre-scheduled arrivals), the
    /// streaming cursor's next arrival (PR-8: streamed arrivals never
    /// enter the queue, so the old queue-bounds-the-horizon assumption
    /// is replaced by this leg, not silently kept), the fault model's
    /// next scheduled action, and the fleet's next billing increment.
    /// Monitoring instants strictly before this horizon observe a
    /// platform that only the replayed per-tick work itself mutates.
    pub(crate) fn skip_horizon(&self) -> crate::sim::SimTime {
        let now = self.sim.now();
        let mut h = self.sim.next_non_tick_time();
        if let Some(s) = &self.stream {
            // every arrival at or before `now` was already admitted, so
            // the cursor's head strictly bounds future streamed arrivals
            if let Some((_, at)) = s.schedule.peek() {
                h = Some(h.map_or(at, |x| x.min(at)));
            }
        }
        // eligibility requires pending arrivals — queued (materialized)
        // or at the stream cursor — so one of the legs above is Some
        let mut h = h.expect("skip eligibility requires a pending arrival");
        if let Some(t) = self.fault.next_scheduled(&*self.backend, now) {
            h = h.min(t);
        }
        if let Some(t) = self.backend.next_billing_due(now) {
            h = h.min(t);
        }
        h
    }

    /// Fast-forward monitoring instants from `next_tick` (exclusive of
    /// the tick that just ran) while they fall strictly before the skip
    /// horizon, replaying each one's observable work. Returns the first
    /// instant that must run densely. The horizon is recomputed whenever
    /// a replayed tick changes the event queue (an AIMD refill below the
    /// floor schedules `InstanceReady`) — the stale horizon is only ever
    /// conservative in between (terminating idle instances can only move
    /// the billing leg later), but a new event can pull it earlier.
    pub(crate) fn skip_idle_ticks(
        &mut self,
        mut next_tick: crate::sim::SimTime,
        interval: u64,
        sc: &mut TickScratch,
        outs: &StepOutputs,
    ) -> crate::sim::SimTime {
        'outer: loop {
            let horizon = self.skip_horizon();
            if next_tick >= horizon || next_tick > self.horizon_s {
                return next_tick;
            }
            let pending = self.sim.pending();
            while next_tick < horizon && next_tick <= self.horizon_s {
                self.fast_forward_tick(next_tick, sc, outs);
                next_tick += interval;
                if self.sim.pending() != pending {
                    continue 'outer;
                }
            }
            return next_tick;
        }
    }

    /// Replay the observable work of one idle monitoring tick at `t`
    /// without running the full gather/step/finish round. Exactness
    /// argument, piece by piece against the dense tick:
    ///
    /// * billing (`bill_through`) — nothing is due strictly before the
    ///   skip horizon (its leg is the fleet-wide min `billed_until`, and
    ///   a charge lands exactly when `billed_until <= now`), and with
    ///   nothing newly billed the dense call appends no cost sample;
    /// * fault poll — the horizon's `next_scheduled` leg proves the
    ///   model would observe nothing and (for `ReclamationAt`) that its
    ///   script cursor would not advance;
    /// * ME assembly — every arrived workload is `Done`, so the dense
    ///   gather writes an all-zero slot/measurement mask (phases only
    ///   change in event handlers, never mid-tick);
    /// * the bank step — on an all-zero slot mask the kernel is
    ///   state-preserving (`b_hat`/`pi` write back unchanged) and its
    ///   consumed outputs (`r`, `s`, `n_star`) are zero independent of
    ///   `n_tot`, so `outs` already holds exactly what a dense step at
    ///   `t` would produce (`n_next` does vary with `n_tot` but nothing
    ///   reads it);
    /// * passive estimators / TTC — both loops skip every workload
    ///   (`Done` / empty `converged`);
    /// * everything else — replayed live below, in dense-tick order.
    ///
    /// `tick_wall_ns` is deliberately not accrued here: it is a perf
    /// observable excluded from `RunMetrics` equality, and timing the
    /// fast path would cost more than the path itself.
    pub(crate) fn fast_forward_tick(
        &mut self,
        t: crate::sim::SimTime,
        sc: &mut TickScratch,
        outs: &StepOutputs,
    ) {
        self.sim.advance_to(t);
        let n_w = self.specs.len();
        // dense gather's observable remainder: the fleet description
        let fleet = self.backend.describe(t);
        sc.n_tot = fleet.active_cus as f32;
        sc.committed_cus = fleet.committed_cus;
        // dense finish, minus the provably-no-op loops
        sc.converged.clear();
        let n_star = self.driving_rates_into(outs, sc, sc.n_tot as f64);
        for w in 0..n_w {
            self.rates[w] = sc.rates_tmp[w].min(self.cfg.control.n_w_max);
        }
        self.n_star_history.push(n_star);
        self.metrics.n_star_curve.push((t, n_star));
        let eval_due = match self.policy.eval_interval_s() {
            Some(iv) => t.saturating_sub(self.last_policy_eval) >= iv,
            None => true,
        };
        if eval_due {
            self.last_policy_eval = t;
            let work_pending = self.work_left();
            self.fill_forecast(n_star);
            let deadline_slack_s = self.deadline_slack(t);
            let ctx = PolicyCtx {
                now: t,
                n_tot: sc.committed_cus,
                n_star,
                n_star_history: &self.n_star_history,
                forecast: &self.forecast_buf,
                deadline_slack_s,
                mean_utilization: self.backend.mean_utilization(t),
                work_pending,
            };
            let target = self.policy.target(&ctx).round().max(0.0);
            self.adjust_fleet(target);
        }
        self.tracker.tick(&self.rates);
        self.assign_idle();
        self.metrics.ticks += 1;
        self.metrics.ticks_skipped += 1;
        self.sample_instances(t);
    }

    // ----- speculative re-execution (PR-10) --------------------------------

    /// Expected wall time of chunk `c`: the same deadband + per-item
    /// estimate chain [`Platform::build_chunk`] sizes chunks with
    /// (driving estimator → footprint mean → app prior), stretched by
    /// the backend and instance-type multipliers. Deliberately blind to
    /// any straggler multiplier on `c.instance` — the whole point is
    /// that the *controller* does not know which units are slow.
    pub(crate) fn expected_chunk_wall(&self, c: &crate::lci::Chunk) -> f64 {
        let w = c.workload;
        let model = self.specs[w].app_model();
        let slot = &self.est[w * self.k_max];
        let est = Some(match self.estimator {
            EstimatorKind::Kalman => self.bank.estimate(self.lane_of[w] as usize, 0) as f64,
            EstimatorKind::AdHoc => slot.adhoc.b_hat,
            EstimatorKind::Arma => slot.arma.b_hat,
            EstimatorKind::Ewma => slot.ewma.b_hat,
            EstimatorKind::Reactive => slot.reactive.b_hat,
        })
        .filter(|&b| b > 0.0)
        .or_else(|| {
            let st = &self.wl[w];
            if st.footprint_meas.is_empty() {
                None
            } else {
                Some(crate::util::stats::mean(&st.footprint_meas))
            }
        })
        .unwrap_or(model.mean_cus + 1.0);
        (model.deadband_s + est * c.tasks.len() as f64)
            * self.exec_mult
            * self.backend.instance_exec_mult(c.instance)
    }

    /// Deadline-aware speculative re-execution: a regular chunk whose
    /// age exceeds a slack-dependent multiple of its expected wall time
    /// (1.5× when the workload's TTC is within two expected walls, 3×
    /// otherwise) gets a *twin* on a healthy free slot; first completion
    /// wins ([`Platform::dispatch_speculative_twin`]). Gated on
    /// [`crate::platform::FaultModel::enables_speculation`] so the
    /// timeout heuristic can never fire on an honest estimate miss in a
    /// fault-free or reclamation-only run — those stay bitwise on the
    /// pre-PR-10 trajectory.
    pub(crate) fn check_speculation(&mut self, now: crate::sim::SimTime) {
        if !self.fault.enables_speculation() || self.chunks.is_empty() {
            return;
        }
        let mut candidates: Vec<u64> = Vec::new();
        for (&id, c) in &self.chunks {
            if c.footprint || self.spec_twin.contains_key(&id) {
                continue;
            }
            let expected = self.expected_chunk_wall(c);
            let age = now.saturating_sub(c.started_at) as f64;
            let slack = match self.wl[c.workload].deadline {
                Some(dl) => dl.saturating_sub(now) as f64,
                None => f64::INFINITY,
            };
            let factor = if slack < 2.0 * expected { 1.5 } else { 3.0 };
            if age > factor * expected {
                candidates.push(id);
            }
        }
        for orig in candidates {
            let orig_inst = self.chunks[&orig].instance;
            let mut target: Option<u64> = None;
            let fault = &self.fault;
            self.backend.for_each_instance(&mut |i| {
                if target.is_none()
                    && i.has_free_slot()
                    && i.id != orig_inst
                    && fault.straggler_mult(i.id).is_none()
                {
                    target = Some(i.id);
                }
            });
            if let Some(inst) = target {
                self.dispatch_speculative_twin(orig, inst, now);
            }
        }
    }

    // ----- helpers ---------------------------------------------------------

    /// Any admitted workload not yet terminal? Scans the live lanes
    /// (identity for materialized suites, the resident window for
    /// streaming ones — retired workloads are `Done` and lane-less, so
    /// the two forms agree).
    pub(crate) fn work_left(&self) -> bool {
        self.lanes.iter().any(|&w| {
            let w = w as usize;
            self.arrived > w && !matches!(self.wl[w].phase, WlPhase::Done)
        })
    }

    /// Fill the policy forecast window (PR-9). `forecast_buf[0]` is the
    /// *current* N*_tot — bitwise, so `forecast[0].clamp(..)` is the
    /// reactive target and MPC at horizon 1 degenerates to it — and
    /// `forecast_buf[h]` extrapolates a least-squares line over the last
    /// 6 N* samples `h` intervals out, floored at zero (the same LR
    /// family as [`crate::util::stats::lr_extrapolate`], hand-rolled
    /// here because the hot path may not allocate the xs vector).
    pub(crate) fn fill_forecast(&mut self, n_star: f64) {
        const WINDOW: usize = 6;
        self.forecast_buf[0] = n_star;
        let hist = &self.n_star_history;
        let tail = if hist.len() > WINDOW { &hist[hist.len() - WINDOW..] } else { &hist[..] };
        let n = tail.len() as f64;
        let (slope, icept) = if tail.len() < 2 {
            (0.0, crate::util::stats::mean(tail))
        } else {
            let mx = (n - 1.0) / 2.0;
            let my = crate::util::stats::mean(tail);
            let mut sxx = 0.0;
            let mut sxy = 0.0;
            for (i, &v) in tail.iter().enumerate() {
                let dx = i as f64 - mx;
                sxx += dx * dx;
                sxy += dx * (v - my);
            }
            let slope = sxy / sxx;
            (slope, my - slope * mx)
        };
        for (step, slot) in self.forecast_buf.iter_mut().enumerate().skip(1) {
            *slot = (slope * (n - 1.0 + step as f64) + icept).max(0.0);
        }
    }

    /// Tightest live deadline, in seconds from `now` (PR-9): the
    /// minimum over admitted, non-`Done` workloads that carry a
    /// deadline. `f64::INFINITY` when none is live — a policy reading
    /// this sees "no deadline pressure", and any finite threshold
    /// comparison is false.
    pub(crate) fn deadline_slack(&self, now: crate::sim::SimTime) -> f64 {
        let mut slack = f64::INFINITY;
        for &w in &self.lanes {
            let w = w as usize;
            if self.arrived <= w || matches!(self.wl[w].phase, WlPhase::Done) {
                continue;
            }
            if let Some(dl) = self.wl[w].deadline {
                slack = slack.min(dl.saturating_sub(now) as f64);
            }
        }
        slack
    }

    /// r_w under the driving estimator.
    pub(crate) fn driving_r(&self, out: &StepOutputs, w: usize) -> f64 {
        match self.estimator {
            EstimatorKind::Kalman => out.r[self.lane_of[w] as usize] as f64,
            other => {
                let spec = &self.specs[w];
                let remaining = self.db.remaining_slice(w);
                let mut r = 0.0;
                for ki in 0..spec.n_types {
                    let est = &self.est[w * self.k_max + ki];
                    let b = match other {
                        EstimatorKind::AdHoc => est.adhoc.b_hat,
                        EstimatorKind::Arma => est.arma.b_hat,
                        EstimatorKind::Ewma => est.ewma.b_hat,
                        EstimatorKind::Reactive => est.reactive.b_hat,
                        EstimatorKind::Kalman => unreachable!(),
                    };
                    r += remaining.get(ki).copied().unwrap_or(0) as f64 * b;
                }
                r
            }
        }
    }

    /// Service rates under the driving estimator, written into
    /// `sc.rates_tmp` (reused across ticks); returns n_star.
    pub(crate) fn driving_rates_into(
        &self,
        out: &StepOutputs,
        sc: &mut TickScratch,
        n_tot: f64,
    ) -> f64 {
        let n_w = self.specs.len();
        let bk = self.bank.k;
        sc.rates_tmp.resize(n_w, 0.0);
        match self.estimator {
            EstimatorKind::Kalman => {
                // bank outputs are lane-indexed; rates stay id-indexed.
                // Identity lanes make this the old 0..n_w copy; with
                // retirement, lane-less ids get the 0.0 a masked bank
                // row would have produced for them.
                sc.rates_tmp.fill(0.0);
                for lane in 0..self.lanes.len() {
                    let w = self.lanes[lane] as usize;
                    sc.rates_tmp[w] = out.s[lane] as f64;
                }
                out.n_star as f64
            }
            other => {
                sc.r.resize(n_w, 0.0);
                sc.dd.resize(n_w, 0.0);
                sc.active.resize(n_w, false);
                sc.r.fill(0.0);
                sc.dd.fill(0.0);
                sc.active.fill(false);
                for lane in 0..self.lanes.len() {
                    let w = self.lanes[lane] as usize;
                    sc.dd[w] = sc.d[lane] as f64;
                    for ki in 0..self.specs[w].n_types {
                        let idx = lane * bk + ki;
                        if sc.slot_mask[idx] > 0.0 {
                            sc.active[w] = true;
                            let est = &self.est[w * self.k_max + ki];
                            let b = match other {
                                EstimatorKind::AdHoc => est.adhoc.b_hat,
                                EstimatorKind::Arma => est.arma.b_hat,
                                EstimatorKind::Ewma => est.ewma.b_hat,
                                EstimatorKind::Reactive => est.reactive.b_hat,
                                EstimatorKind::Kalman => unreachable!(),
                            };
                            sc.r[w] += sc.m_rem[idx] as f64 * b;
                        }
                    }
                }
                service_rates_into(
                    &sc.r,
                    &sc.dd,
                    &sc.active,
                    n_tot,
                    self.cfg.control.alpha,
                    self.cfg.control.beta,
                    self.cfg.control.n_w_max,
                    &mut sc.rates_tmp,
                )
            }
        }
    }
}
