//! Chunk dispatch: map free compute-unit slots to work.
//!
//! Capacity-aware: an instance of a `cus`-CU type absorbs up to `cus`
//! concurrent chunks (one per compute unit) — a 40-CU m4.10xlarge takes
//! 40 chunks, a 1-CU m3.medium one. Each pass of the assignment loop
//! hands every instance with a free slot at most one chunk, then
//! rescans, so a big instance fills over successive passes while chunks
//! remain; with a homogeneous 1-CU fleet this is byte-for-byte the old
//! one-chunk-per-idle-instance loop.
//!
//! Footprint chunks first (they unblock TTC confirmation), then
//! tracker-allocated regular chunks (deficit-round-robin over the
//! proportional-fair service rates; FIFO for Amazon AS), then pending
//! merge steps. The free-slot scan buffer is platform-owned and reused
//! so the steady-state pass is allocation-free.

use crate::coordinator::chunk_size;
use crate::db::TaskStatus;
use crate::estimation::EstimatorKind;
use crate::lci::{execute_chunk, Chunk};
use crate::platform::{Platform, WlPhase};
use crate::sim::{Event, SimTime};

impl Platform {
    pub(crate) fn update_pending_flag(&mut self, w: usize) {
        let runnable = matches!(self.wl[w].phase, WlPhase::Running)
            && self.db.count_status(w, TaskStatus::Pending) > 0;
        self.tracker.set_pending(w, runnable);
    }

    /// Dispatch work to every free compute-unit slot: footprint tasks
    /// first (small chunks), then tracker-allocated chunks.
    pub(crate) fn assign_idle(&mut self) {
        let now = self.sim.now();
        let mut idle = std::mem::take(&mut self.idle_buf);
        loop {
            idle.clear();
            self.backend.for_each_instance(&mut |i| {
                if i.has_free_slot() {
                    idle.push(i.id);
                }
            });
            if idle.is_empty() {
                break;
            }
            let mut assigned_any = false;
            for &inst_id in &idle {
                // 1. footprinting chunks take priority (small, unblock TTC)
                if let Some((w, tasks)) = self.next_footprint_chunk() {
                    self.dispatch_chunk(inst_id, w, tasks, true, now);
                    assigned_any = true;
                    continue;
                }
                // 2. regular chunk via tracker (or FIFO for Amazon AS)
                let pick = if self.policy.uses_estimation() {
                    self.tracker.next_assignment()
                } else {
                    self.tracker.next_fifo()
                };
                let w = match pick {
                    Some(w) => w,
                    None => continue,
                };
                let tasks = self.build_chunk(w, now);
                if tasks.is_empty() {
                    self.update_pending_flag(w);
                    continue;
                }
                self.tracker.on_assign(w);
                self.dispatch_chunk(inst_id, w, tasks, false, now);
                assigned_any = true;
            }
            // 3. pending merge steps can use an idle instance
            self.dispatch_merges();
            if !assigned_any {
                break;
            }
        }
        self.idle_buf = idle;
        self.dispatch_merges();
    }

    /// Next footprinting chunk: footprint tasks are grouped into (up to)
    /// three chunks rather than singles so per-chunk setup time
    /// ("deadband") is partially amortized even in the sampling stage —
    /// otherwise a Matlab-style 30 s setup would make every footprint
    /// measurement ~deadband-dominated (§II-E-1).
    pub(crate) fn next_footprint_chunk(&mut self) -> Option<(usize, Vec<usize>)> {
        // lanes ascend in workload id, so this is the old 0..wl.len()
        // walk restricted to resident workloads (retired ones are Done
        // and were skipped anyway)
        for lane in 0..self.lanes.len() {
            let w = self.lanes[lane] as usize;
            if self.arrived <= w {
                continue;
            }
            let st = &mut self.wl[w];
            if st.phase == WlPhase::Footprinting && !st.footprint_pending.is_empty() {
                // group only when the app's setup time actually needs
                // amortizing; cheap-setup apps footprint with parallel
                // singles for the fastest possible seeding
                let deadband = self.specs[w].app_model().deadband_s;
                let total = st.footprint_pending.len() + st.footprint_outstanding;
                let per_chunk = if deadband > 5.0 { total.div_ceil(3).max(1) } else { 1 };
                let n = per_chunk.min(st.footprint_pending.len());
                let tasks: Vec<usize> =
                    st.footprint_pending.drain(..n).collect();
                st.footprint_outstanding += tasks.len();
                return Some((w, tasks));
            }
        }
        None
    }

    /// Claim up to chunk_size pending tasks of workload w.
    pub(crate) fn build_chunk(&mut self, w: usize, _now: SimTime) -> Vec<usize> {
        let spec = &self.specs[w];
        let model = spec.app_model();
        // per-item estimate from the driving estimator (fallback:
        // footprint seed; last resort: app deadband + 1s)
        let slot = &self.est[w * self.k_max];
        let est = Some(match self.estimator {
            // bank rows are lane-indexed (identity for materialized
            // suites); only live workloads build chunks, so the lane
            // always exists
            EstimatorKind::Kalman => self.bank.estimate(self.lane_of[w] as usize, 0) as f64,
            EstimatorKind::AdHoc => slot.adhoc.b_hat,
            EstimatorKind::Arma => slot.arma.b_hat,
            EstimatorKind::Ewma => slot.ewma.b_hat,
            EstimatorKind::Reactive => slot.reactive.b_hat,
        })
        .filter(|&b| b > 0.0)
        .or_else(|| {
            let st = &self.wl[w];
            if st.footprint_meas.is_empty() {
                None
            } else {
                Some(crate::util::stats::mean(&st.footprint_meas))
            }
        })
        .unwrap_or(model.mean_cus + 1.0);
        let pending_n = self.db.count_status(w, TaskStatus::Pending);
        let n = chunk_size(
            est,
            model.deadband_s,
            self.cfg.control.monitor_interval_s as f64,
            pending_n,
        );
        self.db.status_iter(w, TaskStatus::Pending).take(n).collect()
    }

    pub(crate) fn dispatch_chunk(
        &mut self,
        inst_id: u64,
        w: usize,
        tasks: Vec<usize>,
        footprint: bool,
        now: SimTime,
    ) {
        for &t in &tasks {
            self.db.claim((w, t), inst_id);
        }
        self.next_chunk_id += 1;
        let id = self.next_chunk_id;
        let spec = &self.specs[w];
        let result = execute_chunk(spec, &tasks, footprint, &self.storage);
        let chunk = Chunk { id, workload: w, instance: inst_id, tasks, footprint, started_at: now };
        self.chunks.insert(id, chunk);
        if let Some(inst) = self.backend.instance_mut(inst_id) {
            inst.begin_chunk(id);
        }
        // wall time scales by the backend stretch and by the *instance's*
        // per-type multiplier (PR-9 heterogeneity: an ECU-denser type
        // finishes the same chunk sooner); measurements and busy-CUS
        // accounting stay in backend-normalized CU-seconds. m3.medium's
        // multiplier is exactly 1.0, so the default fleet is unchanged.
        let wall = result.busy_s * self.exec_mult * self.backend.instance_exec_mult(inst_id);
        // PR-10 stragglers stretch wall time further; the multiply is
        // skipped entirely on healthy units (None) so the fault-free
        // float chain stays bitwise what it was
        let wall = match self.fault.straggler_mult(inst_id) {
            Some(slow) => wall * slow,
            None => wall,
        };
        self.sim.schedule(
            wall.ceil().max(1.0) as SimTime,
            Event::ChunkDone { instance: inst_id, chunk: id },
        );
        self.update_pending_flag(w);
    }

    /// PR-10: launch a speculative twin of timed-out chunk `orig` on
    /// `inst_id`. The twin re-executes the same task set under a fresh
    /// chunk id but takes **no** new DB claims (the tasks stay
    /// Processing under the original's claim) and no tracker
    /// assignment (the original's is still outstanding): the pair
    /// resolves to exactly one completion through the `spec_twin`
    /// links — first finisher wins, the loser is torn down.
    pub(crate) fn dispatch_speculative_twin(&mut self, orig: u64, inst_id: u64, now: SimTime) {
        let (w, tasks) = {
            let c = &self.chunks[&orig];
            (c.workload, c.tasks.clone())
        };
        self.next_chunk_id += 1;
        let id = self.next_chunk_id;
        let spec = &self.specs[w];
        let result = execute_chunk(spec, &tasks, false, &self.storage);
        let chunk =
            Chunk { id, workload: w, instance: inst_id, tasks, footprint: false, started_at: now };
        self.chunks.insert(id, chunk);
        if let Some(inst) = self.backend.instance_mut(inst_id) {
            inst.begin_chunk(id);
        }
        let wall = result.busy_s * self.exec_mult * self.backend.instance_exec_mult(inst_id);
        // the target was picked healthy, but compose defensively
        let wall = match self.fault.straggler_mult(inst_id) {
            Some(slow) => wall * slow,
            None => wall,
        };
        self.sim.schedule(
            wall.ceil().max(1.0) as SimTime,
            Event::ChunkDone { instance: inst_id, chunk: id },
        );
        self.spec_twin.insert(orig, id);
        self.spec_twin.insert(id, orig);
        self.metrics.speculative_launches += 1;
    }

    pub(crate) fn dispatch_merges(&mut self) {
        let _now = self.sim.now();
        for lane in 0..self.lanes.len() {
            let w = self.lanes[lane] as usize;
            let needs_merge = {
                let st = &self.wl[w];
                st.phase == WlPhase::Merging && !st.merge_dispatched
            };
            if !needs_merge {
                continue;
            }
            let idle = self.backend.first_free_slot();
            if let Some(inst_id) = idle {
                let merge_s = self.merge_duration(w);
                self.metrics.total_busy_cus += merge_s;
                // marks the instance busy; usage-billed backends charge
                // the aggregation invocation here
                self.backend.on_merge_dispatched(inst_id, _now, merge_s);
                let epoch = self.wl[w].merge_epoch;
                self.wl[w].merge_dispatched = true;
                self.wl[w].merge_instance = Some(inst_id);
                // merge wall time scales with the aggregation instance's
                // type multiplier too (billing stays usage-based)
                let wall = merge_s * self.backend.instance_exec_mult(inst_id);
                self.sim
                    .schedule(wall.ceil() as SimTime, Event::MergeDone { workload: w, epoch });
            }
        }
    }
}
