//! Fleet adjustment toward the scaling-policy target.
//!
//! Down-scaling is *lazy* for the estimation-based methods: an excess
//! instance is only terminated when its pre-billed hour is nearly
//! exhausted (§IV: "the prudent action is always to terminate spot
//! instances with the smallest remaining time before renewal" — an
//! instance with 50 paid minutes left is free capacity; killing it
//! early and re-requesting later would double-bill the hour). Amazon
//! AS terminates immediately, as the real service does. The busy-drain
//! scan reuses a platform-owned buffer so policy evaluation stays
//! allocation-light.

use crate::cloud::InstanceState;
use crate::coordinator::policy::PolicyKind;
use crate::platform::Platform;
use crate::sim::Event;

impl Platform {
    pub(crate) fn request_instance(&mut self) {
        let now = self.sim.now();
        let (id, ready) = self.backend.request_instance(now);
        self.sim.schedule_at(ready, Event::InstanceReady { instance: id });
    }

    /// Scale the fleet toward `target` CUs (see module docs for the
    /// billing-aware termination policy).
    pub(crate) fn adjust_fleet(&mut self, target: f64) {
        let now = self.sim.now();
        let fleet = self.backend.describe(now);
        let committed = fleet.committed_cus;
        // §IV's billing-aware termination prudence is part of the
        // *proposed* controller; the baselines set N_tot[t+1] directly
        // (Gandhi et al. semantics) and Amazon AS terminates eagerly.
        let lazy = self.policy_kind == PolicyKind::Aimd;
        // renewal window: terminate before the next billing increment hits
        let window = (self.cfg.control.monitor_interval_s * 3 / 2 + 1).max(120);
        if target > committed {
            let need = (target - committed).round() as usize;
            for _ in 0..need {
                self.request_instance();
            }
        } else if target < committed {
            let mut excess = (committed - target).round() as usize;
            // idle first, least remaining pre-billed time first (§IV)
            for id in self.backend.idle_instances_by_remaining(now) {
                if excess == 0 {
                    break;
                }
                let rem = self
                    .backend
                    .instance(id)
                    .map(|i| i.remaining_billed(now))
                    .unwrap_or(0);
                if !lazy || rem <= window {
                    self.backend.terminate_instance(id, now);
                    excess -= 1;
                }
            }
            // then drain busy ones if still above target (same laziness)
            if excess > 0 {
                let mut busy = std::mem::take(&mut self.busy_buf);
                busy.clear();
                self.backend.for_each_instance(&mut |i| {
                    if i.state == InstanceState::Running && !i.is_idle() {
                        busy.push((i.id, i.remaining_billed(now)));
                    }
                });
                busy.sort_by_key(|&(id, rem)| (rem, id));
                for &(id, rem) in &busy {
                    if excess == 0 {
                        break;
                    }
                    if !lazy || rem <= window {
                        self.backend.terminate_instance(id, now);
                        excess -= 1;
                    }
                }
                self.busy_buf = busy;
            }
        }
        self.sample_instances(now);
    }
}
