//! Fleet adjustment toward the scaling-policy target.
//!
//! Up-scaling translates the policy's **CU** target into a *type mix*
//! over the scenario's per-type pools: a greedy cheapest-$/CU fill at
//! the current spot prices — among pools whose instance still fits in
//! the remaining deficit, the cheapest per CU wins; when nothing fits
//! (deficit smaller than every type) the smallest type overshoots
//! least. A spot request whose pool price sits above its bid stays
//! *unfulfilled* (real-EC2 semantics): the pool is skipped this round
//! and the deficit is retried at later instants. With the degenerate
//! single 1-CU pool this is exactly the old "request `target −
//! committed` instances" loop — and for multi-CU types it fixes the old
//! 1-CU assumption that over-provisioned a 16-CU fleet 16-fold.
//!
//! Down-scaling is *lazy* for the estimation-based methods: an excess
//! instance is only terminated when its pre-billed hour is nearly
//! exhausted (§IV: "the prudent action is always to terminate spot
//! instances with the smallest remaining time before renewal" — an
//! instance with 50 paid minutes left is free capacity; killing it
//! early and re-requesting later would double-bill the hour). The rule
//! applies per instance — and therefore per pool — with one extra
//! guard for heterogeneous fleets: an instance is only released when
//! its whole CU block fits in the excess, so shedding 1 CU never kills
//! a 40-CU instance. Amazon AS terminates immediately, as the real
//! service does. The busy-drain and pool-candidate scans reuse
//! platform-owned buffers so policy evaluation stays allocation-light.

use crate::cloud::InstanceState;
use crate::platform::{CloudEvent, Platform};
use crate::sim::Event;

/// One up-scaling candidate pool (reused buffer element).
#[derive(Debug, Clone, Copy)]
pub(crate) struct PoolFill {
    pub(crate) pool: usize,
    pub(crate) cus: u32,
    /// $/CU/hr at the current instant (the greedy key).
    pub(crate) per_cu: f64,
    /// Cleared when a request comes back unfulfilled (price above bid);
    /// prices are constant within an instant, so retrying is pointless
    /// until the next monitoring tick.
    pub(crate) open: bool,
}

impl Platform {
    /// Request one instance from `pool`; returns the granted CUs, or 0
    /// when the spot request stays pending (market above the pool bid).
    pub(crate) fn request_instance_in(&mut self, pool: usize) -> u32 {
        let now = self.sim.now();
        match self.backend.request_instance_in(pool, now) {
            Some((id, ready)) => {
                // PR-10 launch flake: the fulfilled request fails to boot
                // and is transparently re-requested — modeled as a seeded
                // readiness push-back (the `InstanceReady` event still
                // bounds the skip horizon, so sparse ticking stays exact).
                // `unfulfilled_requests` is *not* bumped: that counter
                // means "price above bid", and the policy keys off it.
                let ready = match self.fault.launch_flake_delay(id) {
                    Some(delay) => {
                        self.fault_events.push(CloudEvent::BootFailure { instances: vec![id] });
                        ready + delay
                    }
                    None => ready,
                };
                self.sim.schedule_at(ready, Event::InstanceReady { instance: id });
                self.backend.pool_cus(pool)
            }
            None => {
                self.metrics.unfulfilled_requests += 1;
                0
            }
        }
    }

    /// Greedy cheapest-$/CU mix fill: request instances across the
    /// pools until `need` additional CUs are committed (or every pool is
    /// price-blocked).
    pub(crate) fn fill_cus(&mut self, mut need: i64) {
        if need <= 0 {
            return;
        }
        let now = self.sim.now();
        let mut pools = std::mem::take(&mut self.pool_buf);
        pools.clear();
        for pool in 0..self.backend.pool_count() {
            let cus = self.backend.pool_cus(pool);
            let price = self.backend.pool_unit_price(pool, now);
            pools.push(PoolFill { pool, cus, per_cu: price / cus as f64, open: true });
        }
        while need > 0 {
            // among open pools that fit the deficit, cheapest per CU
            // (ties keep the lower pool index: deterministic)
            let mut pick: Option<usize> = None;
            for (i, pf) in pools.iter().enumerate() {
                if !pf.open || pf.cus as i64 > need {
                    continue;
                }
                let better = match pick {
                    Some(j) => pf.per_cu.total_cmp(&pools[j].per_cu).is_lt(),
                    None => true,
                };
                if better {
                    pick = Some(i);
                }
            }
            // nothing fits: the smallest open type overshoots least
            if pick.is_none() {
                for (i, pf) in pools.iter().enumerate() {
                    if !pf.open {
                        continue;
                    }
                    let better = match pick {
                        Some(j) => (pf.cus, pf.per_cu) < (pools[j].cus, pools[j].per_cu),
                        None => true,
                    };
                    if better {
                        pick = Some(i);
                    }
                }
            }
            let i = match pick {
                Some(i) => i,
                None => break, // every pool price-blocked this instant
            };
            let granted = self.request_instance_in(pools[i].pool);
            if granted == 0 {
                pools[i].open = false;
            } else {
                need -= granted as i64;
            }
        }
        self.pool_buf = pools;
    }

    /// Scale the fleet toward `target` CUs (see module docs for the
    /// type-mix fill and the billing-aware termination policy).
    pub(crate) fn adjust_fleet(&mut self, target: f64) {
        let now = self.sim.now();
        let fleet = self.backend.describe(now);
        let committed = fleet.committed_cus;
        // §IV's billing-aware termination prudence is part of the
        // *proposed* controller family; the baselines set N_tot[t+1]
        // directly (Gandhi et al. semantics) and Amazon AS terminates
        // eagerly. Since PR-9 the policy itself declares which side it
        // is on ([`crate::coordinator::policy::ControlPolicy::lazy_drain`]),
        // so new policies opt in without touching this function.
        let lazy = self.policy.lazy_drain();
        // renewal window: terminate before the next billing increment hits
        let window = (self.cfg.control.monitor_interval_s * 3 / 2 + 1).max(120);
        if target > committed {
            self.fill_cus((target - committed).round() as i64);
        } else if target < committed {
            let mut excess = (committed - target).round() as i64;
            // idle first, least remaining pre-billed time first (§IV)
            for id in self.backend.idle_instances_by_remaining(now) {
                if excess <= 0 {
                    break;
                }
                let (rem, cus) = match self.backend.instance(id) {
                    Some(i) => (i.remaining_billed(now), i.cus),
                    None => continue,
                };
                if cus as i64 > excess {
                    continue; // releasing this block would undershoot
                }
                if !lazy || rem <= window {
                    self.backend.terminate_instance(id, now);
                    excess -= cus as i64;
                }
            }
            // then drain busy ones if still above target (same laziness)
            if excess > 0 {
                let mut busy = std::mem::take(&mut self.busy_buf);
                busy.clear();
                self.backend.for_each_instance(&mut |i| {
                    if i.state == InstanceState::Running && !i.is_idle() {
                        busy.push((i.id, i.remaining_billed(now), i.cus));
                    }
                });
                busy.sort_by_key(|&(id, rem, _)| (rem, id));
                for &(id, rem, cus) in &busy {
                    if excess <= 0 {
                        break;
                    }
                    if cus as i64 > excess {
                        continue;
                    }
                    if !lazy || rem <= window {
                        self.backend.terminate_instance(id, now);
                        excess -= cus as i64;
                    }
                }
                self.busy_buf = busy;
            }
        }
        self.sample_instances(now);
    }
}
