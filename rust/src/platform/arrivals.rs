//! Front-end arrival processes.
//!
//! The paper's evaluation feeds the front end a fixed drip (one workload
//! every 5 minutes, §V-A); the companion work (arXiv:1604.04804,
//! arXiv:1711.02150) stresses that reactive control earns its keep under
//! *bursty* and *random* demand. An [`ArrivalProcess`] maps each arrival
//! slot `w` to a deterministic arrival instant; randomness (Poisson)
//! comes from the scenario seed, never from wall clock, so every arrival
//! schedule is bit-reproducible.
//!
//! Invariant: arrival times are nondecreasing in the slot index — the
//! platform's per-tick bookkeeping (`arrived <= w` guards) relies on
//! arrival order matching workload-id order.
//!
//! Because `Platform::start` schedules *every* arrival instant up front
//! as a simulator event, the engine's `next_non_tick_time` is a
//! complete bound on future arrivals — the sparse-tick skipper (PR-6)
//! leans on this: no arrival can materialize inside a skipped stretch
//! that the event queue did not already know about.

use crate::sim::SimTime;
use crate::util::rng::Rng;

/// Stream tag for the arrival-process RNG substream (disjoint from the
/// market / workload-generator streams).
const ARRIVAL_STREAM: u64 = 0xA221_7A1F_0F1C_E55D;

/// When each workload reaches the front end.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Workload `w` arrives at `w * interval_s` (the paper's schedule).
    FixedInterval { interval_s: u64 },
    /// Back-to-back groups of `burst` workloads, one group every
    /// `gap_s` seconds: all members of a group arrive at the same
    /// instant (flash-crowd shape).
    Bursty { burst: usize, gap_s: u64 },
    /// Poisson process: exponential inter-arrival gaps with the given
    /// mean, drawn from the seeded RNG (first arrival at t = 0).
    Poisson { mean_gap_s: f64 },
    /// An explicit per-slot arrival schedule — the batch twin of a
    /// `dithen serve` submission log (PR-7). The daemon records the
    /// effective arrival instant of every `POST /submit` it accepts;
    /// replaying that log through a `Scripted` scenario reproduces the
    /// served run bit-for-bit, which is what `tests/serve_parity.rs`
    /// pins. Times are clamped to the nondecreasing invariant on read;
    /// slots beyond the scripted length repeat the last instant.
    Scripted { times: Vec<SimTime> },
}

impl ArrivalProcess {
    /// Arrival instant per slot, for `n` workloads under `seed`.
    /// Deterministic, nondecreasing.
    pub fn times(&self, n: usize, seed: u64) -> Vec<SimTime> {
        match *self {
            ArrivalProcess::FixedInterval { interval_s } => {
                (0..n as u64).map(|w| w * interval_s).collect()
            }
            ArrivalProcess::Bursty { burst, gap_s } => {
                let burst = burst.max(1);
                (0..n).map(|w| (w / burst) as u64 * gap_s).collect()
            }
            ArrivalProcess::Poisson { mean_gap_s } => {
                let mut rng = Rng::new(seed).substream(ARRIVAL_STREAM);
                let mut t = 0u64;
                (0..n)
                    .map(|w| {
                        if w > 0 {
                            t += rng.exponential(mean_gap_s.max(0.0)).round() as u64;
                        }
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Scripted { ref times } => {
                let mut last = 0u64;
                (0..n)
                    .map(|w| {
                        last = times.get(w).copied().unwrap_or(last).max(last);
                        last
                    })
                    .collect()
            }
        }
    }

    /// Compact human label (CLI headers).
    pub fn describe(&self) -> String {
        match *self {
            ArrivalProcess::FixedInterval { interval_s } => format!("fixed:{interval_s}"),
            ArrivalProcess::Bursty { burst, gap_s } => format!("burst:{burst}x{gap_s}"),
            ArrivalProcess::Poisson { mean_gap_s } => format!("poisson:{mean_gap_s}"),
            ArrivalProcess::Scripted { ref times } => format!("scripted:{}", times.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_interval_matches_legacy_schedule() {
        let t = ArrivalProcess::FixedInterval { interval_s: 300 }.times(4, 99);
        assert_eq!(t, vec![0, 300, 600, 900]);
        // seed-independent
        assert_eq!(t, ArrivalProcess::FixedInterval { interval_s: 300 }.times(4, 1));
    }

    #[test]
    fn bursty_groups_share_an_instant() {
        let t = ArrivalProcess::Bursty { burst: 3, gap_s: 600 }.times(7, 0);
        assert_eq!(t, vec![0, 0, 0, 600, 600, 600, 1200]);
        // degenerate burst size is clamped to 1
        let t = ArrivalProcess::Bursty { burst: 0, gap_s: 60 }.times(3, 0);
        assert_eq!(t, vec![0, 60, 120]);
    }

    #[test]
    fn poisson_is_seeded_and_nondecreasing() {
        let p = ArrivalProcess::Poisson { mean_gap_s: 300.0 };
        let a = p.times(20, 7);
        let b = p.times(20, 7);
        assert_eq!(a, b, "same seed must give the same schedule");
        assert_eq!(a[0], 0, "first arrival opens the experiment");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "times must be nondecreasing");
        let c = p.times(20, 8);
        assert_ne!(a, c, "different seeds must differ");
        // mean gap lands near the configured mean
        let mean = *a.last().unwrap() as f64 / (a.len() - 1) as f64;
        assert!((100.0..900.0).contains(&mean), "mean gap {mean}");
    }

    #[test]
    fn empty_suite_has_no_arrivals() {
        for p in [
            ArrivalProcess::FixedInterval { interval_s: 60 },
            ArrivalProcess::Bursty { burst: 2, gap_s: 60 },
            ArrivalProcess::Poisson { mean_gap_s: 60.0 },
            ArrivalProcess::Scripted { times: vec![0, 60] },
        ] {
            assert!(p.times(0, 3).is_empty());
        }
    }

    #[test]
    fn scripted_replays_the_submission_log() {
        let p = ArrivalProcess::Scripted { times: vec![0, 60, 60, 900] };
        assert_eq!(p.times(4, 1), vec![0, 60, 60, 900]);
        // seed-independent: the log *is* the schedule
        assert_eq!(p.times(4, 99), vec![0, 60, 60, 900]);
        // out-of-order entries are clamped to the nondecreasing
        // invariant, extra slots repeat the last instant
        let p = ArrivalProcess::Scripted { times: vec![300, 60] };
        assert_eq!(p.times(3, 0), vec![300, 300, 300]);
        // an empty script pins every slot to t = 0
        let p = ArrivalProcess::Scripted { times: vec![] };
        assert_eq!(p.times(2, 0), vec![0, 0]);
    }

    #[test]
    fn describe_labels_are_compact() {
        assert_eq!(ArrivalProcess::FixedInterval { interval_s: 60 }.describe(), "fixed:60");
        assert_eq!(ArrivalProcess::Bursty { burst: 3, gap_s: 900 }.describe(), "burst:3x900");
        assert_eq!(ArrivalProcess::Poisson { mean_gap_s: 120.0 }.describe(), "poisson:120");
        assert_eq!(ArrivalProcess::Scripted { times: vec![0, 9, 9] }.describe(), "scripted:3");
    }
}
