//! Front-end arrival processes.
//!
//! The paper's evaluation feeds the front end a fixed drip (one workload
//! every 5 minutes, §V-A); the companion work (arXiv:1604.04804,
//! arXiv:1711.02150) stresses that reactive control earns its keep under
//! *bursty* and *random* demand. An [`ArrivalProcess`] maps each arrival
//! slot `w` to a deterministic arrival instant; randomness (Poisson)
//! comes from the scenario seed, never from wall clock, so every arrival
//! schedule is bit-reproducible.
//!
//! Invariant: arrival times are nondecreasing in the slot index — the
//! platform's per-tick bookkeeping (`arrived <= w` guards) relies on
//! arrival order matching workload-id order.
//!
//! Materialized scenarios schedule *every* arrival instant up front as
//! a simulator event, so the engine's `next_non_tick_time` bounds
//! future arrivals. Streaming scenarios (PR-8) do **not** pre-schedule
//! arrivals: an [`ArrivalSchedule`] generator yields `(slot, instant)`
//! pairs lazily and the platform admits each workload at its instant.
//! The sparse-tick skipper therefore takes its arrival bound from the
//! schedule cursor (the [`ArrivalProcess::next_arrival_after`] leg)
//! instead of assuming the event queue already knows every arrival —
//! the PR-6 queue-bounds-the-horizon assumption is replaced, not
//! silently kept. [`ArrivalProcess::times`] is defined as a drained
//! [`ArrivalSchedule`], so the lazy and materialized forms agree on
//! every prefix by construction.

use crate::sim::SimTime;
use crate::util::rng::Rng;

/// Stream tag for the arrival-process RNG substream (disjoint from the
/// market / workload-generator streams).
const ARRIVAL_STREAM: u64 = 0xA221_7A1F_0F1C_E55D;

/// When each workload reaches the front end.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Workload `w` arrives at `w * interval_s` (the paper's schedule).
    FixedInterval { interval_s: u64 },
    /// Back-to-back groups of `burst` workloads, one group every
    /// `gap_s` seconds: all members of a group arrive at the same
    /// instant (flash-crowd shape).
    Bursty { burst: usize, gap_s: u64 },
    /// Poisson process: exponential inter-arrival gaps with the given
    /// mean, drawn from the seeded RNG (first arrival at t = 0).
    Poisson { mean_gap_s: f64 },
    /// An explicit per-slot arrival schedule — the batch twin of a
    /// `dithen serve` submission log (PR-7). The daemon records the
    /// effective arrival instant of every `POST /submit` it accepts;
    /// replaying that log through a `Scripted` scenario reproduces the
    /// served run bit-for-bit, which is what `tests/serve_parity.rs`
    /// pins. Times are clamped to the nondecreasing invariant on read;
    /// slots beyond the scripted length repeat the last instant.
    Scripted { times: Vec<SimTime> },
}

impl ArrivalProcess {
    /// The generator form: a lazily-driven cursor over the first `n`
    /// arrival slots under `seed`. Streaming scenarios (PR-8) hold one
    /// of these and admit each workload at its instant instead of
    /// materializing the whole schedule (and suite) up front.
    pub fn schedule(&self, n: usize, seed: u64) -> ArrivalSchedule {
        let kind = match *self {
            ArrivalProcess::FixedInterval { interval_s } => ScheduleKind::Fixed { interval_s },
            ArrivalProcess::Bursty { burst, gap_s } => {
                ScheduleKind::Bursty { burst: burst.max(1), gap_s }
            }
            ArrivalProcess::Poisson { mean_gap_s } => ScheduleKind::Poisson {
                mean_gap_s: mean_gap_s.max(0.0),
                rng: Rng::new(seed).substream(ARRIVAL_STREAM),
            },
            ArrivalProcess::Scripted { ref times } => {
                ScheduleKind::Scripted { times: times.clone() }
            }
        };
        let at = if n == 0 {
            0
        } else {
            match &kind {
                ScheduleKind::Scripted { times } => times.first().copied().unwrap_or(0),
                _ => 0,
            }
        };
        ArrivalSchedule { kind, n, slot: 0, at }
    }

    /// Arrival instant per slot, for `n` workloads under `seed`.
    /// Deterministic, nondecreasing. Defined as the drained
    /// [`schedule`](Self::schedule) generator, so the materialized and
    /// streaming forms agree on every prefix by construction.
    pub fn times(&self, n: usize, seed: u64) -> Vec<SimTime> {
        self.schedule(n, seed).map(|(_, at)| at).collect()
    }

    /// Earliest arrival instant strictly after `after` in the first `n`
    /// slots, or `None` when the schedule is exhausted by then — the
    /// streaming leg of the PR-6 skip horizon. Scans a fresh cursor;
    /// the platform's hot path uses its live cursor's peek instead.
    pub fn next_arrival_after(&self, n: usize, seed: u64, after: SimTime) -> Option<SimTime> {
        self.schedule(n, seed).next_arrival_after(after)
    }

    /// Compact human label (CLI headers).
    pub fn describe(&self) -> String {
        match *self {
            ArrivalProcess::FixedInterval { interval_s } => format!("fixed:{interval_s}"),
            ArrivalProcess::Bursty { burst, gap_s } => format!("burst:{burst}x{gap_s}"),
            ArrivalProcess::Poisson { mean_gap_s } => format!("poisson:{mean_gap_s}"),
            ArrivalProcess::Scripted { ref times } => format!("scripted:{}", times.len()),
        }
    }
}

/// Private per-process cursor state for [`ArrivalSchedule`]. The
/// Poisson arm owns its RNG substream so draws happen in slot order —
/// exactly the order [`ArrivalProcess::times`] used to draw them.
#[derive(Debug, Clone)]
enum ScheduleKind {
    Fixed { interval_s: u64 },
    Bursty { burst: usize, gap_s: u64 },
    Poisson { mean_gap_s: f64, rng: Rng },
    Scripted { times: Vec<SimTime> },
}

/// A lazily-driven arrival cursor: yields `(slot, instant)` pairs in
/// slot order, nondecreasing in time. Cloneable (the clone replays the
/// remaining schedule identically — used by lookahead scans that must
/// not consume the live cursor).
#[derive(Debug, Clone)]
pub struct ArrivalSchedule {
    kind: ScheduleKind,
    n: usize,
    slot: usize,
    /// Arrival instant of `slot`; meaningful only while `slot < n`.
    at: SimTime,
}

impl ArrivalSchedule {
    /// Total number of slots this schedule will yield.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Next pending `(slot, instant)` without consuming it.
    pub fn peek(&self) -> Option<(usize, SimTime)> {
        (self.slot < self.n).then_some((self.slot, self.at))
    }

    /// Consume the pending slot and compute the next instant.
    pub fn advance(&mut self) {
        debug_assert!(self.slot < self.n, "advance past the end of the schedule");
        self.slot += 1;
        if self.slot >= self.n {
            return;
        }
        self.at = match &mut self.kind {
            ScheduleKind::Fixed { interval_s } => self.slot as u64 * *interval_s,
            ScheduleKind::Bursty { burst, gap_s } => (self.slot / *burst) as u64 * *gap_s,
            ScheduleKind::Poisson { mean_gap_s, rng } => {
                self.at + rng.exponential(*mean_gap_s).round() as u64
            }
            ScheduleKind::Scripted { times } => {
                times.get(self.slot).copied().unwrap_or(self.at).max(self.at)
            }
        };
    }

    /// Earliest remaining arrival instant strictly after `after`, or
    /// `None` when the schedule has none. Non-consuming: scans a clone
    /// of the cursor (times are nondecreasing, so the scan stops at the
    /// first qualifying instant).
    pub fn next_arrival_after(&self, after: SimTime) -> Option<SimTime> {
        self.clone().map(|(_, at)| at).find(|&at| at > after)
    }
}

impl Iterator for ArrivalSchedule {
    type Item = (usize, SimTime);

    /// Pop the next `(slot, instant)`; `None` when drained.
    fn next(&mut self) -> Option<(usize, SimTime)> {
        let head = self.peek()?;
        self.advance();
        Some(head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_interval_matches_legacy_schedule() {
        let t = ArrivalProcess::FixedInterval { interval_s: 300 }.times(4, 99);
        assert_eq!(t, vec![0, 300, 600, 900]);
        // seed-independent
        assert_eq!(t, ArrivalProcess::FixedInterval { interval_s: 300 }.times(4, 1));
    }

    #[test]
    fn bursty_groups_share_an_instant() {
        let t = ArrivalProcess::Bursty { burst: 3, gap_s: 600 }.times(7, 0);
        assert_eq!(t, vec![0, 0, 0, 600, 600, 600, 1200]);
        // degenerate burst size is clamped to 1
        let t = ArrivalProcess::Bursty { burst: 0, gap_s: 60 }.times(3, 0);
        assert_eq!(t, vec![0, 60, 120]);
    }

    #[test]
    fn poisson_is_seeded_and_nondecreasing() {
        let p = ArrivalProcess::Poisson { mean_gap_s: 300.0 };
        let a = p.times(20, 7);
        let b = p.times(20, 7);
        assert_eq!(a, b, "same seed must give the same schedule");
        assert_eq!(a[0], 0, "first arrival opens the experiment");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "times must be nondecreasing");
        let c = p.times(20, 8);
        assert_ne!(a, c, "different seeds must differ");
        // mean gap lands near the configured mean
        let mean = *a.last().unwrap() as f64 / (a.len() - 1) as f64;
        assert!((100.0..900.0).contains(&mean), "mean gap {mean}");
    }

    #[test]
    fn empty_suite_has_no_arrivals() {
        for p in [
            ArrivalProcess::FixedInterval { interval_s: 60 },
            ArrivalProcess::Bursty { burst: 2, gap_s: 60 },
            ArrivalProcess::Poisson { mean_gap_s: 60.0 },
            ArrivalProcess::Scripted { times: vec![0, 60] },
        ] {
            assert!(p.times(0, 3).is_empty());
        }
    }

    #[test]
    fn scripted_replays_the_submission_log() {
        let p = ArrivalProcess::Scripted { times: vec![0, 60, 60, 900] };
        assert_eq!(p.times(4, 1), vec![0, 60, 60, 900]);
        // seed-independent: the log *is* the schedule
        assert_eq!(p.times(4, 99), vec![0, 60, 60, 900]);
        // out-of-order entries are clamped to the nondecreasing
        // invariant, extra slots repeat the last instant
        let p = ArrivalProcess::Scripted { times: vec![300, 60] };
        assert_eq!(p.times(3, 0), vec![300, 300, 300]);
        // an empty script pins every slot to t = 0
        let p = ArrivalProcess::Scripted { times: vec![] };
        assert_eq!(p.times(2, 0), vec![0, 0]);
    }

    #[test]
    fn schedule_generator_agrees_with_materialized_times() {
        for p in [
            ArrivalProcess::FixedInterval { interval_s: 300 },
            ArrivalProcess::Bursty { burst: 3, gap_s: 600 },
            ArrivalProcess::Poisson { mean_gap_s: 120.0 },
            ArrivalProcess::Scripted { times: vec![5, 1, 60, 60] },
        ] {
            let eager = p.times(9, 7);
            let lazy: Vec<SimTime> = p.schedule(9, 7).map(|(_, at)| at).collect();
            assert_eq!(eager, lazy, "{p:?}");
            // slots come out in order and the cursor clone replays the
            // remaining suffix identically (the lookahead contract)
            let mut s = p.schedule(9, 7);
            assert_eq!(s.len(), 9);
            for want in 0..4 {
                let (slot, at) = s.next().unwrap();
                assert_eq!(slot, want);
                assert_eq!(at, eager[want]);
            }
            let replay: Vec<SimTime> = s.clone().map(|(_, at)| at).collect();
            assert_eq!(replay, eager[4..].to_vec(), "{p:?}");
            assert_eq!(s.peek(), Some((4, eager[4])));
        }
    }

    #[test]
    fn empty_schedule_is_immediately_drained() {
        let mut s = ArrivalProcess::Poisson { mean_gap_s: 60.0 }.schedule(0, 3);
        assert!(s.is_empty());
        assert_eq!(s.peek(), None);
        assert_eq!(s.next(), None);
    }

    #[test]
    fn next_arrival_after_is_strictly_after_and_none_at_exhaustion() {
        let p = ArrivalProcess::FixedInterval { interval_s: 300 };
        assert_eq!(p.next_arrival_after(4, 0, 0), Some(300), "strictly after, not at");
        assert_eq!(p.next_arrival_after(4, 0, 299), Some(300));
        assert_eq!(p.next_arrival_after(4, 0, 300), Some(600));
        assert_eq!(p.next_arrival_after(4, 0, 900), None, "schedule exhausted");
        // the cursor form is non-consuming
        let mut s = p.schedule(4, 0);
        s.next();
        assert_eq!(s.next_arrival_after(300), Some(600));
        assert_eq!(s.peek(), Some((1, 300)), "lookahead must not consume the cursor");
        // Poisson lookahead agrees with the materialized schedule
        let p = ArrivalProcess::Poisson { mean_gap_s: 120.0 };
        let times = p.times(12, 9);
        let mid = times[5];
        let want = times.iter().copied().find(|&t| t > mid);
        assert_eq!(p.next_arrival_after(12, 9, mid), want);
    }

    #[test]
    fn describe_labels_are_compact() {
        assert_eq!(ArrivalProcess::FixedInterval { interval_s: 60 }.describe(), "fixed:60");
        assert_eq!(ArrivalProcess::Bursty { burst: 3, gap_s: 900 }.describe(), "burst:3x900");
        assert_eq!(ArrivalProcess::Poisson { mean_gap_s: 120.0 }.describe(), "poisson:120");
        assert_eq!(ArrivalProcess::Scripted { times: vec![0, 9, 9] }.describe(), "scripted:3");
    }
}
