//! Discrete-event handlers: workload arrival, instance readiness, chunk
//! and merge completion, and cloud-event (reclamation) absorption.
//!
//! All handlers are `impl Platform` methods over the struct in
//! [`super`]; they mutate the task DB, tracker and fleet, then funnel
//! back through `assign_idle` so freed/booted capacity is used
//! immediately.
//!
//! Reclamation semantics: a revoked instance dies *now* (no drain). Its
//! in-flight chunks — a multi-CU instance can carry one per compute
//! unit, and the engine cannot cancel the already-scheduled `ChunkDone`
//! events — are removed from the live-chunk map so the stale events are
//! ignored, and every claimed task re-enters Pending at the tail via
//! `TaskDb::requeue` (FIFO re-entry, re-executed from scratch later; the
//! DB state machine guarantees each task still completes exactly once).
//! Footprint chunks return their task ids to the workload's footprint
//! queue; a revoked merge bumps the workload's merge epoch so the stale
//! `MergeDone` is discarded and the merge is re-dispatched. Revocations
//! are also tallied per pool (`RunMetrics::reclamations_by_pool`) so
//! partial-revocation scenarios can verify that only the spiking pool
//! was hit.

use crate::cloud::MERGE_CHUNK;
use crate::coordinator::footprint_count;
use crate::lci::{execute_chunk, Chunk};
use crate::metrics::EstimatorTrace;
use crate::platform::{CloudEvent, Platform, WlPhase};
use crate::sim::{Event, SimTime};
use crate::workload::Mode;

use anyhow::Result;

/// PR-10 recovery policy: how many transient crashes one task survives
/// before it is terminally abandoned (Failed, a deadline violation —
/// never a hang).
pub(crate) const CHUNK_RETRY_BUDGET: u32 = 3;

/// Base re-dispatch backoff after a crash, doubled per prior crash of
/// the most-retried task in the chunk (capped well inside SimTime).
pub(crate) const RETRY_BACKOFF_BASE_S: SimTime = 30;

impl Platform {
    pub(crate) fn on_arrival(&mut self, w: usize) -> Result<()> {
        let now = self.sim.now();
        self.arrived += 1;
        let spec = &self.specs[w];
        // upload inputs to storage (bookkeeping; transfer happens per chunk)
        for (t, task) in spec.tasks.iter().enumerate() {
            self.storage
                .put(&format!("w{w:02}/input/item{t:06}"), task.bytes);
            self.db.insert(w, task.media_type, t);
        }
        // pre-size the measurement logs: steady-state completions must
        // not reallocate (§Perf)
        self.db.reserve_measurements(w);
        let st = &mut self.wl[w];
        st.arrived_at = now;
        st.deadline = self.fixed_ttc_s.map(|d| now + d);
        // footprinting: first F tasks (the paper samples a small
        // percentage of the inputs)
        let f = footprint_count(
            spec.n_tasks(),
            self.cfg.control.footprint_frac,
            self.cfg.control.footprint_min,
            self.cfg.control.footprint_max,
        );
        st.footprint_pending = (0..f).collect();
        st.phase = WlPhase::Footprinting;
        self.tracker.register(w);
        if self.record_traces {
            for k in 0..spec.n_types {
                self.metrics
                    .traces
                    .entry((w, k))
                    .or_insert_with(EstimatorTrace::default);
            }
        }
        // a fresh shard is a local maximum of the resident footprint
        self.sample_live_peaks();
        self.assign_idle();
        Ok(())
    }

    pub(crate) fn on_instance_ready(&mut self, id: u64) {
        let now = self.sim.now();
        // PR-10 receipt: the straggler decision is a pure function of
        // (seed, id), so counting at readiness agrees with every later
        // dispatch-time query. Healthy models answer None and the
        // counter stays at its fault-free zero.
        if self.fault.straggler_mult(id).is_some() {
            self.metrics.straggler_instances += 1;
        }
        self.backend.instance_ready(id, now);
        self.sample_instances(now);
        self.assign_idle();
    }

    pub(crate) fn on_chunk_done(&mut self, instance: u64, chunk_id: u64) {
        let now = self.sim.now();
        let chunk = match self.chunks.remove(&chunk_id) {
            // a missing chunk is a stale event: the instance was
            // reclaimed mid-flight and the tasks already requeued
            Some(c) => c,
            None => return,
        };
        let w = chunk.workload;
        let spec = &self.specs[w];
        let mult = self.exec_mult;
        // re-derive the result (deterministic) to record measurements
        let result = execute_chunk(spec, &chunk.tasks, chunk.footprint, &self.storage);
        // PR-10 transient crash, evaluated exactly once per chunk id at
        // this (deterministic) completion instant. Footprint chunks are
        // exempt — the sampling stage is tiny and keeps its own queue.
        // A fault-free model answers false and the path below is
        // untouched.
        if !chunk.footprint {
            let wall = now.saturating_sub(chunk.started_at);
            if self.fault.chunk_crashes(chunk_id, wall) {
                self.on_chunk_crashed(chunk, result.busy_s * mult, now);
                return;
            }
        }
        // PR-10 speculation: first completion wins. Tear the losing
        // twin down — free its slot, drop it from the live map so its
        // later ChunkDone hits the stale guard — before completing the
        // tasks exactly once below.
        if let Some(twin) = self.spec_twin.remove(&chunk_id) {
            self.spec_twin.remove(&twin);
            if let Some(loser) = self.chunks.remove(&twin) {
                // no busy contribution: the loser produced nothing
                self.backend.on_chunk_finished(loser.instance, twin, now, 0.0, 0);
            }
        }
        for (i, &t) in chunk.tasks.iter().enumerate() {
            let cus = result.per_task_cus[i] * mult;
            let k = spec.tasks[t].media_type;
            self.db.complete((w, t), cus, now, result.exit_code);
            // abnormal exits (§II-A) feed neither estimator: the DB
            // measurement log (the Kalman b_tilde source) only records
            // completed tasks, and the ARMA cumulative feed must stay
            // consistent with it
            if result.exit_code == 0 {
                let est = &mut self.est[w * self.k_max + k];
                est.cum_cus += cus;
                est.cum_done += 1;
            }
            let out_bytes = (spec.tasks[t].bytes as f64 * 0.3) as u64;
            self.storage.put(&format!("w{w:02}/output/item{t:06}"), out_bytes);
        }
        self.metrics.total_busy_cus += result.busy_s * mult;
        let st = &mut self.wl[w];
        st.completed_tasks += chunk.tasks.len();
        st.split_busy += result.busy_s * mult;
        if chunk.footprint {
            st.footprint_outstanding -= chunk.tasks.len();
            st.footprint_meas
                .extend(chunk.tasks.iter().enumerate().map(|(i, _)| result.per_task_cus[i] * mult));
            if st.footprint_outstanding == 0 && st.footprint_pending.is_empty() {
                self.finish_footprinting(w);
            }
        }
        // the chunk's slot frees (or the instance dies if draining and
        // this was its last chunk); usage-billed backends charge here
        self.backend
            .on_chunk_finished(instance, chunk_id, now, result.busy_s * mult, chunk.tasks.len());
        self.tracker.on_release(w);
        self.update_pending_flag(w);
        self.check_workload_done(w);
        self.assign_idle();
    }

    /// PR-10: absorb a transient chunk crash at its completion instant.
    /// The chunk's work is lost (the instance slot frees and the lost
    /// attempt is still charged on usage-billed backends); each member
    /// task either re-enters the pending tail after an exponential
    /// backoff — via a scheduled [`Event::RetryTasks`], so the sparse
    /// skipper can never jump the retry — or, once its budget is
    /// exhausted, is terminally abandoned (Failed; the workload still
    /// reaches Done, but as a deadline violation). If the crashed chunk
    /// had a live speculative twin, the twin still owns every task and
    /// nothing needs recovery.
    pub(crate) fn on_chunk_crashed(&mut self, chunk: Chunk, busy: f64, now: SimTime) {
        let w = chunk.workload;
        self.backend
            .on_chunk_finished(chunk.instance, chunk.id, now, busy, chunk.tasks.len());
        if let Some(twin) = self.spec_twin.remove(&chunk.id) {
            self.spec_twin.remove(&twin);
            if self.chunks.contains_key(&twin) {
                // the twin carries the tasks to completion; the tracker
                // assignment stays outstanding with it
                self.assign_idle();
                return;
            }
        }
        let mut retry: Vec<usize> = Vec::new();
        let mut worst = 0u32;
        for &t in &chunk.tasks {
            let c = self.retry_counts.entry((w, t)).or_insert(0);
            *c += 1;
            if *c <= CHUNK_RETRY_BUDGET {
                worst = worst.max(*c);
                retry.push(t);
            } else {
                // budget exhausted: terminal failure, counted as
                // completed for conservation (the run never hangs)
                self.db.abandon((w, t), now);
                let st = &mut self.wl[w];
                st.completed_tasks += 1;
                st.tasks_abandoned += 1;
                self.metrics.tasks_abandoned += 1;
            }
        }
        if !retry.is_empty() {
            self.metrics.chunk_retries += 1;
            // exponential backoff, keyed on the chunk's most-retried
            // task (the shift stays small; budget bounds `worst`)
            let backoff = RETRY_BACKOFF_BASE_S << (worst - 1).min(16);
            self.sim.schedule(backoff, Event::RetryTasks { workload: w, tasks: retry });
        }
        self.tracker.on_release(w);
        self.update_pending_flag(w);
        self.check_workload_done(w);
        self.assign_idle();
    }

    /// PR-10: a crashed chunk's backoff elapsed — its tasks re-enter
    /// the pending tail (they sat Processing in the interim, invisible
    /// to dispatch, so nothing could double-claim them).
    pub(crate) fn on_retry_tasks(&mut self, w: usize, tasks: &[usize]) {
        for &t in tasks {
            self.db.requeue((w, t));
        }
        self.metrics.requeued_tasks += tasks.len() as u64;
        self.update_pending_flag(w);
        self.assign_idle();
    }

    pub(crate) fn finish_footprinting(&mut self, w: usize) {
        let now = self.sim.now();
        let st = &mut self.wl[w];
        st.phase = WlPhase::Running;
        // seed estimators with the footprinting mean (b̃[0], §II-E-3)
        let seed = crate::util::stats::mean(&st.footprint_meas);
        let spec = &self.specs[w];
        for k in 0..spec.n_types {
            let est = &mut self.est[w * self.k_max + k];
            est.adhoc.seed(seed);
            est.ewma.seed(seed);
            est.reactive.seed(seed);
            est.seeded = true;
            // the bank's slot sees the seed as its first measurement at
            // the next tick through the measurement-log cursor (the
            // footprint completions are already in the DB log)
        }
        let _ = now;
        self.update_pending_flag(w);
    }

    pub(crate) fn on_merge_done(&mut self, w: usize, epoch: u32) {
        if self.wl[w].merge_epoch != epoch {
            return; // stale: this merge's instance was reclaimed
        }
        let now = self.sim.now();
        let merge_s = self.merge_duration(w);
        let merge_inst = self.wl[w].merge_instance.take();
        {
            let st = &mut self.wl[w];
            st.phase = WlPhase::Done;
            st.completed_at = Some(now);
        }
        // release the aggregation instance; usage-billed backends charge
        // the aggregation invocation here (not at dispatch, so a
        // reclaimed-and-redispatched merge bills once)
        if let Some(id) = merge_inst {
            self.backend.on_merge_finished(id, now, merge_s);
        }
        self.tracker.remove(w);
        if self.retire_shards {
            self.retire_workload(w);
        }
        self.check_all_done();
        self.assign_idle();
    }

    // ----- fault absorption -----------------------------------------------

    /// Apply one injected cloud event at the current instant.
    pub(crate) fn apply_cloud_event(&mut self, ev: &CloudEvent, now: SimTime) {
        match ev {
            CloudEvent::Reclamation { instances } => {
                for &id in instances {
                    self.reclaim_instance(id, now);
                }
                // the surviving fleet (if any) picks up requeued work
                self.assign_idle();
            }
            // a boot failure was already absorbed at request time (the
            // readiness push-back in scaling.rs); the event is the
            // observability receipt for the daemon's SSE stream
            CloudEvent::BootFailure { .. } => {}
        }
    }

    /// Revoke one instance: tear down its in-flight work — *every*
    /// concurrent chunk a multi-CU instance carries — requeue the
    /// claimed tasks (FIFO tail re-entry), kill the instance. The
    /// already-billed increment is sunk (no partial-hour refund; keeps
    /// the cost curve monotone).
    pub(crate) fn reclaim_instance(&mut self, id: u64, now: SimTime) {
        let (in_flight, type_idx) = match self.backend.instance(id) {
            Some(i) if i.state != crate::cloud::InstanceState::Terminated => {
                (i.chunks.clone(), i.type_idx)
            }
            _ => return,
        };
        self.metrics.reclamations += 1;
        if let Some(pool) = self.backend.pool_of_type(type_idx) {
            if let Some(n) = self.metrics.reclamations_by_pool.get_mut(pool) {
                *n += 1;
            }
        }
        for chunk_id in in_flight {
            if chunk_id == MERGE_CHUNK {
                // a merge was running in this slot: forget it, bump the
                // epoch so the stale MergeDone is ignored, and let
                // dispatch_merges re-run it on a surviving/future
                // instance. One MERGE_CHUNK entry per dispatched merge;
                // resetting clears merge_instance, so repeated entries
                // resolve to the next merging workload on this instance.
                if let Some(w) =
                    (0..self.wl.len()).find(|&w| self.wl[w].merge_instance == Some(id))
                {
                    let merge_s = self.merge_duration(w);
                    let st = &mut self.wl[w];
                    st.merge_dispatched = false;
                    st.merge_instance = None;
                    st.merge_epoch += 1;
                    // the revoked merge's busy time was accounted at
                    // dispatch; it will be re-added on re-dispatch
                    self.metrics.total_busy_cus -= merge_s;
                }
            } else if let Some(chunk) = self.chunks.remove(&chunk_id) {
                let w = chunk.workload;
                // PR-10 speculation: a torn-down chunk with a *live*
                // twin leaves its tasks with the twin (they stay
                // Processing there; requeueing would double-claim).
                // The link is cleared from both sides, so if the twin
                // is reclaimed later in this same event, it requeues
                // the tasks normally — exactly once either way.
                let twin_alive = match self.spec_twin.remove(&chunk_id) {
                    Some(twin) => {
                        self.spec_twin.remove(&twin);
                        self.chunks.contains_key(&twin)
                    }
                    None => false,
                };
                if twin_alive {
                    continue;
                }
                for &t in &chunk.tasks {
                    self.db.requeue((w, t));
                }
                self.metrics.requeued_tasks += chunk.tasks.len() as u64;
                if chunk.footprint {
                    let st = &mut self.wl[w];
                    st.footprint_outstanding -= chunk.tasks.len();
                    st.footprint_pending.extend(chunk.tasks.iter().copied());
                } else {
                    self.tracker.on_release(w);
                }
                self.update_pending_flag(w);
            }
        }
        self.backend.revoke_instance(id, now);
    }

    /// Merge-step duration for workload `w` (deterministic in the
    /// accumulated split busy time; shared by dispatch and reclamation).
    pub(crate) fn merge_duration(&self, w: usize) -> f64 {
        let merge_frac = match self.specs[w].mode {
            Mode::SplitMerge { merge_frac } => merge_frac,
            Mode::Basic => 0.0,
        };
        (self.wl[w].split_busy * merge_frac).max(1.0)
    }

    // ----- completion bookkeeping ----------------------------------------

    pub(crate) fn check_workload_done(&mut self, w: usize) {
        let now = self.sim.now();
        let spec = &self.specs[w];
        if self.wl[w].completed_tasks < spec.n_tasks() {
            return;
        }
        match spec.mode {
            Mode::Basic => {
                let st = &mut self.wl[w];
                if st.phase != WlPhase::Done {
                    st.phase = WlPhase::Done;
                    st.completed_at = Some(now);
                    self.tracker.remove(w);
                    if self.retire_shards {
                        self.retire_workload(w);
                    }
                    self.check_all_done();
                }
            }
            Mode::SplitMerge { .. } => {
                let st = &mut self.wl[w];
                if st.phase == WlPhase::Running || st.phase == WlPhase::Footprinting {
                    st.phase = WlPhase::Merging;
                    self.tracker.set_pending(w, false);
                    self.dispatch_merges();
                }
            }
        }
    }

    pub(crate) fn check_all_done(&mut self) {
        // total_slots: a streaming suite is only "all done" once the
        // stream itself is exhausted, not merely the admitted prefix
        if self.arrived == self.total_slots()
            && self.wl.iter().all(|st| st.phase == WlPhase::Done)
        {
            self.all_done_at = Some(self.sim.now());
        }
    }

    pub(crate) fn sample_instances(&mut self, now: SimTime) {
        let fleet = self.backend.describe(now);
        let active = fleet.booting + fleet.running + fleet.draining;
        self.metrics.instances_curve.push((now, active));
        self.metrics.max_instances = self.metrics.max_instances.max(active);
    }
}
