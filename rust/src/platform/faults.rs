//! Cloud-event / fault injection.
//!
//! The platform polls its [`FaultModel`] at every monitoring instant;
//! the model inspects the backend (prices, fleet) and emits
//! [`CloudEvent`]s for the loop to absorb. The first fault family is
//! **spot reclamation** (§IV's core risk): when the simulated market
//! price crosses the scenario's bid, every active spot instance is
//! revoked at once — exactly EC2's behaviour for a single-bid launch
//! group. In-flight chunks are torn down and their tasks re-enter the
//! task DB's Pending list at the tail through
//! [`crate::db::TaskDb::requeue`] (the documented FIFO re-entry).
//!
//! Determinism: price traces are seeded and polling happens at
//! deterministic tick instants, so revocation schedules are bit-identical
//! across runs and thread counts. [`ReclamationAt`] additionally offers a
//! scripted revocation schedule for tests and chaos-style experiments
//! where the *timing* must be controlled exactly.

use crate::cloud::{CloudBackend, InstanceState};
use crate::sim::SimTime;

/// An injected cloud event, applied by the platform loop at a
/// monitoring instant.
#[derive(Debug, Clone, PartialEq)]
pub enum CloudEvent {
    /// These instances are revoked *now* (forced immediate termination;
    /// in-flight chunks must be requeued).
    Reclamation { instances: Vec<u64> },
}

/// A fault model: polled once per monitoring tick, reads the backend,
/// pushes events for the platform to absorb.
pub trait FaultModel: std::fmt::Debug {
    fn poll(&mut self, backend: &dyn CloudBackend, now: SimTime, out: &mut Vec<CloudEvent>);
}

/// Plain-data fault descriptor carried by a `Scenario` (the trait object
/// is built per run so scenarios stay `Clone`).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultSpec {
    /// No injected events (the pre-scenario behaviour).
    None,
    /// Market-driven spot reclamation: whenever the backend's unit price
    /// exceeds `bid` $/hr at a monitoring instant, the whole fleet is
    /// revoked. Only applies to reclaimable (spot) backends.
    SpotReclamation { bid: f64 },
    /// Scripted reclamation: the whole fleet is revoked at each listed
    /// instant (evaluated at the first monitoring tick at/after it).
    /// Like [`FaultSpec::SpotReclamation`], only applies to reclaimable
    /// (spot) backends.
    ReclamationAt { times: Vec<SimTime> },
}

impl FaultSpec {
    pub fn build(&self) -> Box<dyn FaultModel> {
        match self {
            FaultSpec::None => Box::new(NoFaults),
            FaultSpec::SpotReclamation { bid } => Box::new(SpotReclamation { bid: *bid }),
            FaultSpec::ReclamationAt { times } => Box::new(ReclamationAt::new(times.clone())),
        }
    }

    /// Compact human label (CLI headers).
    pub fn describe(&self) -> String {
        match self {
            FaultSpec::None => "none".into(),
            FaultSpec::SpotReclamation { bid } => format!("reclaim:{bid}"),
            FaultSpec::ReclamationAt { times } => format!("reclaim-at:{times:?}"),
        }
    }
}

fn collect_active(backend: &dyn CloudBackend, out: &mut Vec<u64>) {
    backend.for_each_instance(&mut |i| {
        if i.state != InstanceState::Terminated {
            out.push(i.id);
        }
    });
}

/// The fault-free model.
#[derive(Debug, Default)]
pub struct NoFaults;

impl FaultModel for NoFaults {
    fn poll(&mut self, _backend: &dyn CloudBackend, _now: SimTime, _out: &mut Vec<CloudEvent>) {}
}

/// Market-driven spot reclamation (see [`FaultSpec::SpotReclamation`]).
///
/// Modeling note: the bid gates *revocation* only. The scaling policy's
/// replacement requests are always fulfilled at the market price, so
/// during a sustained above-bid stretch the controller re-buys capacity
/// each interval and loses it again at the next poll — a bid-chasing
/// controller paying churn cost, which is exactly the stress regime the
/// reclamation experiments want. Real EC2 would instead leave below-bid
/// requests unfulfilled; an unfulfillable-request mode is listed in
/// ROADMAP's open items.
#[derive(Debug, Clone)]
pub struct SpotReclamation {
    /// The launch group's bid, $/hr.
    pub bid: f64,
}

impl FaultModel for SpotReclamation {
    fn poll(&mut self, backend: &dyn CloudBackend, now: SimTime, out: &mut Vec<CloudEvent>) {
        if !backend.reclaimable() || backend.unit_price(now) <= self.bid {
            return;
        }
        let mut ids = vec![];
        collect_active(backend, &mut ids);
        if !ids.is_empty() {
            out.push(CloudEvent::Reclamation { instances: ids });
        }
    }
}

/// Scripted reclamation schedule (see [`FaultSpec::ReclamationAt`]).
#[derive(Debug, Clone)]
pub struct ReclamationAt {
    /// Sorted revocation instants; each fires once.
    pub times: Vec<SimTime>,
    next: usize,
}

impl ReclamationAt {
    pub fn new(mut times: Vec<SimTime>) -> Self {
        times.sort_unstable();
        ReclamationAt { times, next: 0 }
    }
}

impl FaultModel for ReclamationAt {
    fn poll(&mut self, backend: &dyn CloudBackend, now: SimTime, out: &mut Vec<CloudEvent>) {
        let mut due = false;
        while self.next < self.times.len() && self.times[self.next] <= now {
            self.next += 1;
            due = true;
        }
        if !due || !backend.reclaimable() {
            return;
        }
        let mut ids = vec![];
        collect_active(backend, &mut ids);
        if !ids.is_empty() {
            out.push(CloudEvent::Reclamation { instances: ids });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Provider;
    use crate::config::MarketCfg;

    fn fleet_of(n: usize) -> Provider {
        let mut p = Provider::new(MarketCfg::default(), 11, 8);
        for _ in 0..n {
            let (id, ready) = CloudBackend::request_instance(&mut p, 0);
            CloudBackend::instance_ready(&mut p, id, ready);
        }
        p
    }

    #[test]
    fn no_faults_emits_nothing() {
        let p = fleet_of(2);
        let mut out = vec![];
        NoFaults.poll(&p, 1000, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn reclamation_fires_when_price_crosses_bid() {
        let p = fleet_of(3);
        let mut out = vec![];
        // bid below the m3.medium price floor: always crossed
        SpotReclamation { bid: 0.0 }.poll(&p, 500, &mut out);
        assert_eq!(out.len(), 1);
        match &out[0] {
            CloudEvent::Reclamation { instances } => assert_eq!(instances.len(), 3),
        }
        // bid above any possible price: never crossed
        out.clear();
        SpotReclamation { bid: 100.0 }.poll(&p, 500, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn reclamation_skips_non_reclaimable_backends() {
        let mut od = Provider::new_on_demand(MarketCfg::default(), 1, 8);
        let (id, ready) = CloudBackend::request_instance(&mut od, 0);
        CloudBackend::instance_ready(&mut od, id, ready);
        let mut out = vec![];
        SpotReclamation { bid: 0.0 }.poll(&od, 500, &mut out);
        assert!(out.is_empty(), "on-demand instances must never be reclaimed");
    }

    #[test]
    fn scripted_schedule_skips_non_reclaimable_backends() {
        let mut od = Provider::new_on_demand(MarketCfg::default(), 1, 8);
        let (id, ready) = CloudBackend::request_instance(&mut od, 0);
        CloudBackend::instance_ready(&mut od, id, ready);
        let mut out = vec![];
        ReclamationAt::new(vec![100]).poll(&od, 500, &mut out);
        assert!(out.is_empty(), "scripted reclamation must not touch on-demand fleets");
    }

    #[test]
    fn scripted_schedule_fires_each_instant_once() {
        let p = fleet_of(1);
        let mut f = ReclamationAt::new(vec![900, 300]);
        let mut out = vec![];
        f.poll(&p, 100, &mut out);
        assert!(out.is_empty(), "nothing due yet");
        f.poll(&p, 300, &mut out);
        assert_eq!(out.len(), 1, "t=300 fires (sorted schedule)");
        f.poll(&p, 600, &mut out);
        assert_eq!(out.len(), 1, "no double fire between instants");
        f.poll(&p, 2000, &mut out);
        assert_eq!(out.len(), 2, "t=900 fires at the next poll after it");
        f.poll(&p, 3000, &mut out);
        assert_eq!(out.len(), 2, "schedule exhausted");
    }

    #[test]
    fn fault_spec_builds_and_describes() {
        assert!(FaultSpec::None.describe().contains("none"));
        assert!(FaultSpec::SpotReclamation { bid: 0.01 }.describe().contains("0.01"));
        let spec = FaultSpec::ReclamationAt { times: vec![5, 2] };
        assert!(spec.describe().contains("reclaim-at"));
        // building sorts the scripted schedule
        let p = fleet_of(1);
        let mut m = spec.build();
        let mut out = vec![];
        m.poll(&p, 2, &mut out);
        assert_eq!(out.len(), 1);
    }
}
