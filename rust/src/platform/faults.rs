//! Cloud-event / fault injection.
//!
//! The platform polls its [`FaultModel`] at every monitoring instant;
//! the model inspects the backend (prices, fleet) and emits
//! [`CloudEvent`]s for the loop to absorb. The first fault family is
//! **spot reclamation** (§IV's core risk), evaluated **per pool**: when
//! a pool's simulated market price crosses its bid, that pool's active
//! instances are revoked — a price spike on m4.10xlarge revokes only
//! the m4.10xlarge pool while smaller pools keep working (*partial*
//! revocation). The degenerate single-pool fleet reproduces the old
//! whole-fleet behaviour exactly. In-flight chunks are torn down and
//! their tasks re-enter the task DB's Pending list at the tail through
//! [`crate::db::TaskDb::requeue`] (the documented FIFO re-entry).
//!
//! Determinism: price traces are seeded and polling happens at
//! deterministic tick instants, so revocation schedules are bit-identical
//! across runs and thread counts. [`ReclamationAt`] additionally offers a
//! scripted revocation schedule for tests and chaos-style experiments
//! where the *timing* must be controlled exactly.

use crate::cloud::{CloudBackend, InstanceState};
use crate::sim::SimTime;
use crate::util::rng::Rng;

/// An injected cloud event, applied by the platform loop at a
/// monitoring instant.
#[derive(Debug, Clone, PartialEq)]
pub enum CloudEvent {
    /// These instances are revoked *now* (forced immediate termination;
    /// in-flight chunks must be requeued).
    Reclamation { instances: Vec<u64> },
    /// These fulfilled requests failed to boot (PR-10 [`LaunchFlake`]):
    /// readiness is pushed back by the flake delay, observable over the
    /// daemon's SSE stream. The delay itself is applied at request time
    /// in `scaling.rs`; this event is the receipt, not the mechanism.
    BootFailure { instances: Vec<u64> },
}

/// A fault model: polled once per monitoring tick, reads the backend,
/// pushes events for the platform to absorb.
pub trait FaultModel: std::fmt::Debug {
    fn poll(&mut self, backend: &dyn CloudBackend, now: SimTime, out: &mut Vec<CloudEvent>);

    /// Earliest instant at which a future [`FaultModel::poll`] could
    /// behave differently from a poll at `now` — the fault leg of the
    /// sparse-tick skip horizon (PR-6). Monitoring instants strictly
    /// before this time may be fast-forwarded without polling; `None`
    /// means no future poll can ever emit (or advance internal state)
    /// beyond what `now` sees. The conservative default (`Some(now)`)
    /// makes a model that hasn't reasoned about skipping simply never
    /// allow it.
    fn next_scheduled(&self, _backend: &dyn CloudBackend, now: SimTime) -> Option<SimTime> {
        Some(now)
    }

    /// Wall-time multiplier for chunks executed on `instance`, or
    /// `None` for a healthy unit (PR-10 [`Straggler`]). A pure function
    /// of `(seed, instance)` — queried at dispatch instants and once at
    /// instance readiness (the `straggler_instances` receipt), so the
    /// answer must be stable across repeated calls. Call sites skip the
    /// multiply entirely on `None`, keeping the fault-free path
    /// bit-identical to the pre-PR-10 platform.
    fn straggler_mult(&self, _instance: u64) -> Option<f64> {
        None
    }

    /// Does `chunk` crash at its scheduled completion instant after
    /// `wall` seconds of execution (PR-10 [`ChunkCrash`])? Evaluated
    /// exactly once per chunk id, at the `ChunkDone` event — a
    /// deterministic event instant, so dense and tick-skipped runs ask
    /// the same question at the same time. A pure function of
    /// `(seed, chunk, wall)`.
    fn chunk_crashes(&self, _chunk: u64, _wall: SimTime) -> bool {
        false
    }

    /// Boot-failure delay for fulfilled request `instance`, or `None`
    /// when the launch succeeds (PR-10 [`LaunchFlake`]). A pure
    /// function of `(seed, instance)`, queried once at the request
    /// instant.
    fn launch_flake_delay(&self, _instance: u64) -> Option<SimTime> {
        None
    }

    /// Whether the PR-10 speculative re-execution scan arms at all.
    /// Only fault models that can slow individual units ([`Straggler`])
    /// return true: speculation's timeout heuristic could otherwise
    /// fire on an honest estimate miss, and the fault-free / reclaim
    /// scenarios are pinned bit-identical to the pre-PR-10 platform.
    fn enables_speculation(&self) -> bool {
        false
    }
}

/// Plain-data fault descriptor carried by a `Scenario` (the trait object
/// is built per run so scenarios stay `Clone`).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultSpec {
    /// No injected events (the pre-scenario behaviour).
    None,
    /// Market-driven spot reclamation with a global fallback bid: each
    /// pool is revoked whenever its price exceeds its effective bid —
    /// the pool's own [`crate::cloud::PoolSpec::bid`] when set, else
    /// `bid` quoted for the base type and scaled to the pool's type by
    /// the catalogue base-price ratio
    /// ([`crate::cloud::FleetSpec::with_default_bid`]). The same
    /// effective bid gates request *fulfilment* on the backend, so
    /// above-bid stretches leave replacement requests pending instead
    /// of the old fulfil-then-revoke churn. Only applies to reclaimable
    /// (spot) backends.
    SpotReclamation { bid: f64 },
    /// Market-driven reclamation using **only** each pool's own bid:
    /// pools without a bid are never revoked. The mixed-fleet partial-
    /// revocation scenario (`--fleet m3.medium,m4.10xlarge:bid=0.6
    /// --fault reclaim-pools`).
    PoolReclamation,
    /// Scripted reclamation: the whole fleet (every pool) is revoked at
    /// each listed instant (evaluated at the first monitoring tick
    /// at/after it). Like the market-driven variants, only applies to
    /// reclaimable (spot) backends.
    ReclamationAt { times: Vec<SimTime> },
    /// A seeded fraction `frac` of launched instances are stragglers:
    /// every chunk they run takes `slowdown`x the healthy wall time
    /// (composing multiplicatively with the backend `exec_mult` chain).
    /// CLI token `straggler:<frac>x<slowdown>`.
    Straggler { frac: f64, slowdown: f64 },
    /// Seeded transient per-chunk failure: a chunk running `wall`
    /// seconds crashes at its completion instant with hazard
    /// probability `1 - (1-rate)^wall` (per-second hazard `rate`), its
    /// work lost; the recovery policy requeues its tasks with backoff.
    /// CLI token `crash:<rate>`.
    ChunkCrash { rate: f64 },
    /// Seeded launch flake: each fulfilled spot request fails to boot
    /// with probability `prob`, pushing its readiness back by `delay_s`
    /// (the re-request round trip). CLI token `flake:<prob>+<delay_s>`.
    LaunchFlake { prob: f64, delay_s: SimTime },
}

/// Substream salts separating the partial-failure decision streams from
/// each other (and from everything else keyed off the master seed).
const STRAGGLER_SALT: u64 = 0x5747;
const CRASH_SALT: u64 = 0xC4A5;
const FLAKE_SALT: u64 = 0xF1A6;

impl FaultSpec {
    /// Build the run's fault model. `seed` is the scenario's master
    /// seed; the partial-failure models derive per-entity substreams
    /// from it so their decisions are pure functions of
    /// `(seed, entity id)` — order- and thread-count-independent.
    pub fn build(&self, seed: u64) -> Box<dyn FaultModel> {
        match self {
            FaultSpec::None => Box::new(NoFaults),
            FaultSpec::SpotReclamation { bid } => Box::new(SpotReclamation { bid: *bid }),
            // per-pool bids only: the fallback can never be crossed
            FaultSpec::PoolReclamation => Box::new(SpotReclamation { bid: f64::INFINITY }),
            FaultSpec::ReclamationAt { times } => Box::new(ReclamationAt::new(times.clone())),
            FaultSpec::Straggler { frac, slowdown } => Box::new(Straggler {
                frac: *frac,
                slowdown: *slowdown,
                stream: Rng::new(seed).substream(STRAGGLER_SALT),
            }),
            FaultSpec::ChunkCrash { rate } => Box::new(ChunkCrash {
                rate: *rate,
                stream: Rng::new(seed).substream(CRASH_SALT),
            }),
            FaultSpec::LaunchFlake { prob, delay_s } => Box::new(LaunchFlake {
                prob: *prob,
                delay_s: *delay_s,
                stream: Rng::new(seed).substream(FLAKE_SALT),
            }),
        }
    }

    /// The global fallback bid the scenario assembly copies onto
    /// bid-less pools (request-fulfilment gating).
    pub fn spot_bid(&self) -> Option<f64> {
        match self {
            FaultSpec::SpotReclamation { bid } => Some(*bid),
            _ => None,
        }
    }

    /// Compact human label (CLI headers).
    pub fn describe(&self) -> String {
        match self {
            FaultSpec::None => "none".into(),
            FaultSpec::SpotReclamation { bid } => format!("reclaim:{bid}"),
            // the CLI token, so printed scenario headers round-trip
            // through parse_fault
            FaultSpec::PoolReclamation => "reclaim-pools".into(),
            FaultSpec::ReclamationAt { times } => format!("reclaim-at:{times:?}"),
            FaultSpec::Straggler { frac, slowdown } => format!("straggler:{frac}x{slowdown}"),
            FaultSpec::ChunkCrash { rate } => format!("crash:{rate}"),
            FaultSpec::LaunchFlake { prob, delay_s } => format!("flake:{prob}+{delay_s}"),
        }
    }
}

/// Collect the active instances of catalogue type `type_idx`.
fn collect_active_of_type(backend: &dyn CloudBackend, type_idx: usize, out: &mut Vec<u64>) {
    backend.for_each_instance(&mut |i| {
        if i.state != InstanceState::Terminated && i.type_idx == type_idx {
            out.push(i.id);
        }
    });
}

fn collect_active(backend: &dyn CloudBackend, out: &mut Vec<u64>) {
    backend.for_each_instance(&mut |i| {
        if i.state != InstanceState::Terminated {
            out.push(i.id);
        }
    });
}

/// The fault-free model.
#[derive(Debug, Default)]
pub struct NoFaults;

impl FaultModel for NoFaults {
    fn poll(&mut self, _backend: &dyn CloudBackend, _now: SimTime, _out: &mut Vec<CloudEvent>) {}

    fn next_scheduled(&self, _backend: &dyn CloudBackend, _now: SimTime) -> Option<SimTime> {
        None // never emits: no fault leg on the skip horizon
    }
}

/// Market-driven spot reclamation, per pool (see
/// [`FaultSpec::SpotReclamation`] / [`FaultSpec::PoolReclamation`]): a
/// pool whose price exceeds its effective bid — the pool's own bid,
/// falling back to `bid` — is revoked in one event; other pools are
/// untouched. With a single-pool fleet this degenerates to the old
/// whole-fleet wipe.
#[derive(Debug, Clone)]
pub struct SpotReclamation {
    /// Fallback bid for pools without their own, $/hr
    /// (`f64::INFINITY` = bid-less pools are never revoked).
    pub bid: f64,
}

impl FaultModel for SpotReclamation {
    fn poll(&mut self, backend: &dyn CloudBackend, now: SimTime, out: &mut Vec<CloudEvent>) {
        if !backend.reclaimable() {
            return;
        }
        for pool in 0..backend.pool_count() {
            let bid = backend.pool_bid(pool).unwrap_or(self.bid);
            if backend.pool_unit_price(pool, now) <= bid {
                continue;
            }
            let mut ids = vec![];
            collect_active_of_type(backend, backend.pool_type_idx(pool), &mut ids);
            if !ids.is_empty() {
                out.push(CloudEvent::Reclamation { instances: ids });
            }
        }
    }

    fn next_scheduled(&self, backend: &dyn CloudBackend, now: SimTime) -> Option<SimTime> {
        // a bid crossing can only appear when a pool price moves; on
        // non-reclaimable backends poll() is a permanent no-op. (The
        // billing leg does NOT cover this: billed_until anchors to each
        // instance's readiness instant, not to hour boundaries, so a
        // crossing could otherwise fall inside a skipped stretch.)
        if backend.reclaimable() {
            backend.next_price_change(now)
        } else {
            None
        }
    }
}

/// Scripted reclamation schedule (see [`FaultSpec::ReclamationAt`]).
#[derive(Debug, Clone)]
pub struct ReclamationAt {
    /// Sorted revocation instants; each fires once.
    pub times: Vec<SimTime>,
    next: usize,
}

impl ReclamationAt {
    pub fn new(mut times: Vec<SimTime>) -> Self {
        times.sort_unstable();
        ReclamationAt { times, next: 0 }
    }
}

impl FaultModel for ReclamationAt {
    fn poll(&mut self, backend: &dyn CloudBackend, now: SimTime, out: &mut Vec<CloudEvent>) {
        let mut due = false;
        while self.next < self.times.len() && self.times[self.next] <= now {
            self.next += 1;
            due = true;
        }
        if !due || !backend.reclaimable() {
            return;
        }
        let mut ids = vec![];
        collect_active(backend, &mut ids);
        if !ids.is_empty() {
            out.push(CloudEvent::Reclamation { instances: ids });
        }
    }

    fn next_scheduled(&self, _backend: &dyn CloudBackend, _now: SimTime) -> Option<SimTime> {
        // the next scripted instant, unconditionally: poll() advances
        // its cursor *before* the reclaimable() check, so dense and
        // skipped runs must stop at the same instants to keep the
        // cursor state identical (conservative on non-reclaimable
        // backends, but observably exact).
        self.times.get(self.next).copied()
    }
}

/// One uniform draw for `id`, derived from a salted substream of the
/// master seed: pure in `(stream, id)`, so repeated queries agree and
/// answer order never matters.
fn unit_draw(stream: &Rng, id: u64) -> f64 {
    stream.substream(id).f64()
}

/// Seeded straggler fleet (see [`FaultSpec::Straggler`]): each launched
/// instance is independently a straggler with probability `frac`, and
/// stays one for its whole lifetime. The decision is a pure function of
/// `(seed, instance id)` — dispatch-time queries and the readiness-time
/// receipt count always agree.
#[derive(Debug)]
pub struct Straggler {
    pub frac: f64,
    pub slowdown: f64,
    stream: Rng,
}

impl FaultModel for Straggler {
    fn poll(&mut self, _backend: &dyn CloudBackend, _now: SimTime, _out: &mut Vec<CloudEvent>) {}

    fn next_scheduled(&self, _backend: &dyn CloudBackend, _now: SimTime) -> Option<SimTime> {
        // straggling acts at dispatch instants, never at an idle tick;
        // skipping is only attempted while no chunks are in flight, so
        // there is no fault leg to pin on the horizon
        None
    }

    fn straggler_mult(&self, instance: u64) -> Option<f64> {
        (unit_draw(&self.stream, instance) < self.frac).then_some(self.slowdown)
    }

    fn enables_speculation(&self) -> bool {
        true
    }
}

/// Seeded transient chunk failure (see [`FaultSpec::ChunkCrash`]): the
/// per-second hazard `rate` integrates over the chunk's wall time, so a
/// long chunk is proportionally likelier to die than a short one —
/// `p = 1 - (1-rate)^wall`, computed by repeated multiplication
/// (`powi`) so the result is bit-identical across platforms (no libm
/// `exp`). Evaluated at the chunk's scheduled completion event.
#[derive(Debug)]
pub struct ChunkCrash {
    pub rate: f64,
    stream: Rng,
}

impl FaultModel for ChunkCrash {
    fn poll(&mut self, _backend: &dyn CloudBackend, _now: SimTime, _out: &mut Vec<CloudEvent>) {}

    fn next_scheduled(&self, _backend: &dyn CloudBackend, _now: SimTime) -> Option<SimTime> {
        // crashes fire at ChunkDone events, which already bound the
        // skip horizon through the engine's next_non_tick_time leg
        None
    }

    fn chunk_crashes(&self, chunk: u64, wall: SimTime) -> bool {
        let survive_per_s = (1.0 - self.rate).clamp(0.0, 1.0);
        let crash_p = 1.0 - survive_per_s.powi(wall.min(i32::MAX as u64) as i32);
        unit_draw(&self.stream, chunk) < crash_p
    }
}

/// Seeded launch flake (see [`FaultSpec::LaunchFlake`]): a fulfilled
/// request fails to boot with probability `prob` and becomes ready
/// `delay_s` later than the provider quoted. Pure in
/// `(seed, instance id)`, queried once at the request instant.
#[derive(Debug)]
pub struct LaunchFlake {
    pub prob: f64,
    pub delay_s: SimTime,
    stream: Rng,
}

impl FaultModel for LaunchFlake {
    fn poll(&mut self, _backend: &dyn CloudBackend, _now: SimTime, _out: &mut Vec<CloudEvent>) {}

    fn next_scheduled(&self, _backend: &dyn CloudBackend, _now: SimTime) -> Option<SimTime> {
        // flakes act at request instants (inside adjust_fleet, which
        // runs on every executed tick); the delayed InstanceReady event
        // they schedule bounds the horizon via next_non_tick_time
        None
    }

    fn launch_flake_delay(&self, instance: u64) -> Option<SimTime> {
        (unit_draw(&self.stream, instance) < self.prob).then_some(self.delay_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::{FleetSpec, Provider};
    use crate::config::MarketCfg;

    fn fleet_of(n: usize) -> Provider {
        let mut p = Provider::new(MarketCfg::default(), 11, 8);
        for _ in 0..n {
            let (id, ready) = CloudBackend::request_instance(&mut p, 0);
            CloudBackend::instance_ready(&mut p, id, ready);
        }
        p
    }

    #[test]
    fn no_faults_emits_nothing() {
        let p = fleet_of(2);
        let mut out = vec![];
        NoFaults.poll(&p, 1000, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn reclamation_fires_when_price_crosses_bid() {
        let p = fleet_of(3);
        let mut out = vec![];
        // bid below the m3.medium price floor: always crossed
        SpotReclamation { bid: 0.0 }.poll(&p, 500, &mut out);
        assert_eq!(out.len(), 1);
        match &out[0] {
            CloudEvent::Reclamation { instances } => assert_eq!(instances.len(), 3),
            other => panic!("expected a reclamation, got {other:?}"),
        }
        // bid above any possible price: never crossed
        out.clear();
        SpotReclamation { bid: 100.0 }.poll(&p, 500, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn reclamation_skips_non_reclaimable_backends() {
        let mut od = Provider::new_on_demand(MarketCfg::default(), 1, 8);
        let (id, ready) = CloudBackend::request_instance(&mut od, 0);
        CloudBackend::instance_ready(&mut od, id, ready);
        let mut out = vec![];
        SpotReclamation { bid: 0.0 }.poll(&od, 500, &mut out);
        assert!(out.is_empty(), "on-demand instances must never be reclaimed");
    }

    #[test]
    fn pool_bid_crossing_revokes_only_that_pool() {
        // big pool's bid sits below the price floor (always crossed);
        // the small pool's bid sits above the hard price cap of
        // on-demand x 1.2 (never crossed)
        let fleet = FleetSpec::parse("m3.medium:bid=0.1,m4.4xlarge:bid=0.001").unwrap();
        let mut p = Provider::with_fleet(MarketCfg::default(), 11, 8, &fleet);
        let (small, rs) = p.request_spot_instance(0, 0);
        Provider::instance_ready(&mut p, small, rs);
        let (big, rb) = p.request_spot_instance(4, 0);
        Provider::instance_ready(&mut p, big, rb);

        let mut out = vec![];
        SpotReclamation { bid: f64::INFINITY }.poll(&p, 500, &mut out);
        assert_eq!(out.len(), 1, "exactly one pool crosses its bid");
        match &out[0] {
            CloudEvent::Reclamation { instances } => {
                assert_eq!(instances, &vec![big], "only the big pool is revoked");
            }
            other => panic!("expected a reclamation, got {other:?}"),
        }
    }

    #[test]
    fn bidless_pools_are_never_revoked_under_pool_reclamation() {
        let fleet = FleetSpec::parse("m3.medium,m3.xlarge").unwrap();
        let mut p = Provider::with_fleet(MarketCfg::default(), 11, 8, &fleet);
        let (a, ra) = p.request_spot_instance(0, 0);
        Provider::instance_ready(&mut p, a, ra);
        let mut m = FaultSpec::PoolReclamation.build(11);
        let mut out = vec![];
        m.poll(&p, 500, &mut out);
        assert!(out.is_empty(), "no pool has a bid, nothing can cross it");
    }

    #[test]
    fn scripted_schedule_skips_non_reclaimable_backends() {
        let mut od = Provider::new_on_demand(MarketCfg::default(), 1, 8);
        let (id, ready) = CloudBackend::request_instance(&mut od, 0);
        CloudBackend::instance_ready(&mut od, id, ready);
        let mut out = vec![];
        ReclamationAt::new(vec![100]).poll(&od, 500, &mut out);
        assert!(out.is_empty(), "scripted reclamation must not touch on-demand fleets");
    }

    #[test]
    fn scripted_schedule_fires_each_instant_once() {
        let p = fleet_of(1);
        let mut f = ReclamationAt::new(vec![900, 300]);
        let mut out = vec![];
        f.poll(&p, 100, &mut out);
        assert!(out.is_empty(), "nothing due yet");
        f.poll(&p, 300, &mut out);
        assert_eq!(out.len(), 1, "t=300 fires (sorted schedule)");
        f.poll(&p, 600, &mut out);
        assert_eq!(out.len(), 1, "no double fire between instants");
        f.poll(&p, 2000, &mut out);
        assert_eq!(out.len(), 2, "t=900 fires at the next poll after it");
        f.poll(&p, 3000, &mut out);
        assert_eq!(out.len(), 2, "schedule exhausted");
    }

    #[test]
    fn next_scheduled_legs_of_the_skip_horizon() {
        let p = fleet_of(1);
        // no faults: no leg at all
        assert_eq!(NoFaults.next_scheduled(&p, 500), None);
        // market-driven: the next price boundary on reclaimable backends
        let m = SpotReclamation { bid: 0.01 };
        assert_eq!(m.next_scheduled(&p, 500), CloudBackend::next_price_change(&p, 500));
        assert!(m.next_scheduled(&p, 500).is_some());
        let od = Provider::new_on_demand(MarketCfg::default(), 1, 8);
        assert_eq!(m.next_scheduled(&od, 500), None, "on-demand is never reclaimed");
        // scripted: the next un-fired instant, and it tracks the cursor
        let mut f = ReclamationAt::new(vec![900, 300]);
        assert_eq!(f.next_scheduled(&p, 100), Some(300));
        let mut out = vec![];
        f.poll(&p, 300, &mut out);
        assert_eq!(f.next_scheduled(&p, 300), Some(900));
        f.poll(&p, 2000, &mut out);
        assert_eq!(f.next_scheduled(&p, 2000), None, "schedule exhausted");
        // the cursor advances even on non-reclaimable backends, so the
        // scripted leg must hold there too — dense and skipped runs
        // keep identical cursor state
        let mut g = ReclamationAt::new(vec![700]);
        assert_eq!(g.next_scheduled(&od, 100), Some(700));
        g.poll(&od, 800, &mut out);
        assert_eq!(g.next_scheduled(&od, 800), None);
    }

    #[test]
    fn fault_spec_builds_and_describes() {
        assert!(FaultSpec::None.describe().contains("none"));
        assert!(FaultSpec::SpotReclamation { bid: 0.01 }.describe().contains("0.01"));
        assert_eq!(FaultSpec::PoolReclamation.describe(), "reclaim-pools");
        assert_eq!(FaultSpec::SpotReclamation { bid: 0.01 }.spot_bid(), Some(0.01));
        assert_eq!(FaultSpec::PoolReclamation.spot_bid(), None);
        assert_eq!(FaultSpec::None.spot_bid(), None);
        // the partial-failure variants round-trip the CLI grammar and
        // carry no spot bid
        let s = FaultSpec::Straggler { frac: 0.2, slowdown: 4.0 };
        assert_eq!(s.describe(), "straggler:0.2x4");
        assert_eq!(s.spot_bid(), None);
        assert_eq!(FaultSpec::ChunkCrash { rate: 0.01 }.describe(), "crash:0.01");
        assert_eq!(FaultSpec::LaunchFlake { prob: 0.3, delay_s: 120 }.describe(), "flake:0.3+120");
        let spec = FaultSpec::ReclamationAt { times: vec![5, 2] };
        assert!(spec.describe().contains("reclaim-at"));
        // building sorts the scripted schedule
        let p = fleet_of(1);
        let mut m = spec.build(11);
        let mut out = vec![];
        m.poll(&p, 2, &mut out);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn straggler_decisions_are_stable_and_hit_the_fraction() {
        let m = FaultSpec::Straggler { frac: 0.25, slowdown: 4.0 }.build(42);
        let mut hits = 0;
        for id in 0..1000u64 {
            let first = m.straggler_mult(id);
            assert_eq!(first, m.straggler_mult(id), "decision must be idempotent");
            if let Some(mult) = first {
                assert_eq!(mult, 4.0);
                hits += 1;
            }
        }
        // seeded binomial(1000, 0.25): a loose window proves the draw
        // actually spans the unit interval
        assert!((150..350).contains(&hits), "straggler fraction off: {hits}/1000");
        // frac=0 never straggles, frac=1 always does
        assert!(FaultSpec::Straggler { frac: 0.0, slowdown: 4.0 }
            .build(42)
            .straggler_mult(7)
            .is_none());
        assert_eq!(
            FaultSpec::Straggler { frac: 1.0, slowdown: 2.5 }.build(42).straggler_mult(7),
            Some(2.5)
        );
    }

    #[test]
    fn chunk_crash_hazard_scales_with_wall_time() {
        let m = FaultSpec::ChunkCrash { rate: 0.01 }.build(42);
        let crashes = |wall: SimTime| (0..1000u64).filter(|&c| m.chunk_crashes(c, wall)).count();
        // p(60s) ≈ 0.45, p(1s) ≈ 0.01: the hazard must integrate over
        // wall time, and each query must be stable
        let short = crashes(1);
        let long = crashes(60);
        assert!(short < 50, "1s chunks should rarely crash: {short}/1000");
        assert!((300..600).contains(&long), "60s chunks crash ~45%: {long}/1000");
        assert_eq!(m.chunk_crashes(3, 60), m.chunk_crashes(3, 60));
        // rate=0 never crashes, even for very long chunks
        let never = FaultSpec::ChunkCrash { rate: 0.0 }.build(42);
        assert!((0..1000u64).all(|c| !never.chunk_crashes(c, 100_000)));
    }

    #[test]
    fn launch_flake_delays_a_seeded_fraction() {
        let m = FaultSpec::LaunchFlake { prob: 0.3, delay_s: 120 }.build(42);
        let mut hits = 0;
        for id in 0..1000u64 {
            let first = m.launch_flake_delay(id);
            assert_eq!(first, m.launch_flake_delay(id), "decision must be idempotent");
            if let Some(d) = first {
                assert_eq!(d, 120);
                hits += 1;
            }
        }
        assert!((200..400).contains(&hits), "flake fraction off: {hits}/1000");
        assert!(FaultSpec::LaunchFlake { prob: 0.0, delay_s: 120 }
            .build(42)
            .launch_flake_delay(7)
            .is_none());
    }

    #[test]
    fn partial_failure_models_add_no_skip_horizon_leg() {
        // these faults act at dispatch/completion/request instants —
        // events that already bound the skip horizon — so the fault leg
        // itself must stay empty (the PR-6 skipper may engage)
        let p = fleet_of(1);
        for spec in [
            FaultSpec::Straggler { frac: 0.2, slowdown: 4.0 },
            FaultSpec::ChunkCrash { rate: 0.01 },
            FaultSpec::LaunchFlake { prob: 0.3, delay_s: 120 },
        ] {
            let mut m = spec.build(42);
            assert_eq!(m.next_scheduled(&p, 500), None, "{}", spec.describe());
            let mut out = vec![];
            m.poll(&p, 500, &mut out);
            assert!(out.is_empty(), "{}: poll must not emit", spec.describe());
        }
    }
}
