//! Configuration system: typed config structs + a TOML-subset parser.
//!
//! Everything tunable in the platform — AIMD constants, monitoring
//! interval, spot-market calibration, estimator noise, workload suite —
//! lives here with the paper's §V values as defaults, and can be
//! overridden from a config file (`dithen run --config platform.toml`)
//! or key=value CLI overrides.
//!
//! The parser supports the subset we emit and document: `[section]`
//! headers, `key = value` with string / float / int / bool values, and
//! `#` comments. That is all the platform config needs; arrays/tables of
//! tables are deliberately rejected with a clear error.

use std::fmt;

/// Paper §V: AIMD and platform control constants.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlCfg {
    /// AIMD additive constant α (CUs per increase step).
    pub alpha: f64,
    /// AIMD multiplicative constant β in (0, 1].
    pub beta: f64,
    /// Lower bound for total CUs, N_min.
    pub n_min: f64,
    /// Upper bound for total CUs, N_max.
    pub n_max: f64,
    /// Per-workload service-rate cap N_{w,max}.
    pub n_w_max: f64,
    /// Monitoring interval in seconds (paper: 60–300 s).
    pub monitor_interval_s: u64,
    /// Kalman process noise σ_z².
    pub sigma_z2: f64,
    /// Kalman measurement noise σ_v².
    pub sigma_v2: f64,
    /// Fraction of a workload's tasks executed in the footprinting stage.
    pub footprint_frac: f64,
    /// Footprinting task-count bounds.
    pub footprint_min: usize,
    pub footprint_max: usize,
}

impl Default for ControlCfg {
    fn default() -> Self {
        ControlCfg {
            alpha: 5.0,
            beta: 0.9,
            n_min: 10.0,
            n_max: 100.0,
            n_w_max: 10.0,
            monitor_interval_s: 60,
            sigma_z2: 0.5,
            sigma_v2: 0.5,
            footprint_frac: 0.05,
            footprint_min: 1,
            footprint_max: 10,
        }
    }
}

/// Cloud-market simulator calibration (Appendix A / Table V).
#[derive(Debug, Clone, PartialEq)]
pub struct MarketCfg {
    /// Baseline m3.medium spot price ($/hr). Table V: 0.0081.
    pub base_spot_price: f64,
    /// On-demand price for m3.medium ($/hr). Table V: 0.067.
    pub on_demand_price: f64,
    /// Instance boot (spot fulfilment + AMI boot) delay, seconds.
    pub boot_delay_s: u64,
    /// Billing increment, seconds (EC2 spot: hourly).
    pub billing_increment_s: u64,
    /// Relative price volatility per sqrt(hour) for a 1-CU instance; larger
    /// instances scale volatility by their CU count (Fig. 12 behaviour).
    pub volatility: f64,
    /// Mean-reversion strength of the price process (per hour).
    pub reversion: f64,
}

impl Default for MarketCfg {
    fn default() -> Self {
        MarketCfg {
            base_spot_price: 0.0081,
            on_demand_price: 0.067,
            boot_delay_s: 90,
            billing_increment_s: 3600,
            volatility: 0.02,
            reversion: 0.5,
        }
    }
}

/// Storage / transfer model (S3 substitute).
#[derive(Debug, Clone, PartialEq)]
pub struct StorageCfg {
    /// Sustained transfer bandwidth per instance, bytes/s.
    pub bandwidth_bps: f64,
    /// Per-object request latency, seconds.
    pub request_latency_s: f64,
}

impl Default for StorageCfg {
    fn default() -> Self {
        // Effective single-stream S3 throughput from an m3.medium incl.
        // small-object overheads (2015-era), plus 60 ms per request.
        // Calibrated so transfer ≈ 27 % of billed time (§V-C's footnote:
        // removing transport would lower all costs by ~27 %).
        StorageCfg { bandwidth_bps: 2.0e6, request_latency_s: 0.06 }
    }
}

/// Lambda pricing model (§V-D).
#[derive(Debug, Clone, PartialEq)]
pub struct LambdaCfg {
    /// $ per GB-second (2015-era Lambda: $0.00001667 / GB-s).
    pub price_per_gb_s: f64,
    /// $ per request.
    pub price_per_request: f64,
    /// Billing quantum in seconds (Lambda bills per 100 ms).
    pub billing_quantum_s: f64,
    /// Configured function memory, GB (paper: 1024 MB).
    pub memory_gb: f64,
    /// Memory of the underlying host instance, GB, and its cores: Lambda
    /// allocates memory_gb/host_memory_gb × host_cores fractional cores.
    pub host_memory_gb: f64,
    pub host_cores: f64,
}

impl Default for LambdaCfg {
    fn default() -> Self {
        LambdaCfg {
            price_per_gb_s: 0.000_016_67,
            price_per_request: 0.000_000_2,
            billing_quantum_s: 0.1,
            memory_gb: 1.0,
            host_memory_gb: 4.0,
            host_cores: 2.0,
        }
    }
}

/// Top-level platform configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub control: ControlCfg,
    pub market: MarketCfg,
    pub storage: StorageCfg,
    pub lambda: LambdaCfg,
    /// Master seed for all stochastic substreams.
    pub seed: u64,
    /// Directory holding AOT artifacts (manifest.json + HLO text).
    pub artifacts_dir: String,
    /// Prefer the XLA/PJRT estimator-bank backend when artifacts exist.
    pub use_xla: bool,
}

impl Config {
    pub fn paper_defaults() -> Self {
        Config {
            seed: 20161021, // paper's DOI date
            artifacts_dir: "artifacts".into(),
            use_xla: true,
            ..Default::default()
        }
    }

    /// Apply a parsed TOML document over the defaults.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<(), ConfigError> {
        for ((section, key), value) in &doc.entries {
            self.apply_kv(section, key, value)?;
        }
        Ok(())
    }

    /// Apply one override, e.g. ("control", "alpha", "5.0") or a
    /// dotted CLI override "control.alpha=5".
    pub fn apply_kv(&mut self, section: &str, key: &str, v: &TomlValue) -> Result<(), ConfigError> {
        let unknown = || ConfigError::UnknownKey(format!("{section}.{key}"));
        let as_f = |v: &TomlValue| v.as_f64().ok_or(ConfigError::TypeMismatch(format!("{section}.{key}")));
        let as_u = |v: &TomlValue| v.as_f64().map(|f| f as u64).ok_or(ConfigError::TypeMismatch(format!("{section}.{key}")));
        match (section, key) {
            ("control", "alpha") => self.control.alpha = as_f(v)?,
            ("control", "beta") => self.control.beta = as_f(v)?,
            ("control", "n_min") => self.control.n_min = as_f(v)?,
            ("control", "n_max") => self.control.n_max = as_f(v)?,
            ("control", "n_w_max") => self.control.n_w_max = as_f(v)?,
            ("control", "monitor_interval_s") => self.control.monitor_interval_s = as_u(v)?,
            ("control", "sigma_z2") => self.control.sigma_z2 = as_f(v)?,
            ("control", "sigma_v2") => self.control.sigma_v2 = as_f(v)?,
            ("control", "footprint_frac") => self.control.footprint_frac = as_f(v)?,
            ("control", "footprint_min") => self.control.footprint_min = as_u(v)? as usize,
            ("control", "footprint_max") => self.control.footprint_max = as_u(v)? as usize,
            ("market", "base_spot_price") => self.market.base_spot_price = as_f(v)?,
            ("market", "on_demand_price") => self.market.on_demand_price = as_f(v)?,
            ("market", "boot_delay_s") => self.market.boot_delay_s = as_u(v)?,
            ("market", "billing_increment_s") => self.market.billing_increment_s = as_u(v)?,
            ("market", "volatility") => self.market.volatility = as_f(v)?,
            ("market", "reversion") => self.market.reversion = as_f(v)?,
            ("storage", "bandwidth_bps") => self.storage.bandwidth_bps = as_f(v)?,
            ("storage", "request_latency_s") => self.storage.request_latency_s = as_f(v)?,
            ("lambda", "price_per_gb_s") => self.lambda.price_per_gb_s = as_f(v)?,
            ("lambda", "price_per_request") => self.lambda.price_per_request = as_f(v)?,
            ("lambda", "billing_quantum_s") => self.lambda.billing_quantum_s = as_f(v)?,
            ("lambda", "memory_gb") => self.lambda.memory_gb = as_f(v)?,
            ("lambda", "host_memory_gb") => self.lambda.host_memory_gb = as_f(v)?,
            ("lambda", "host_cores") => self.lambda.host_cores = as_f(v)?,
            ("", "seed") => self.seed = as_u(v)?,
            ("", "artifacts_dir") => {
                self.artifacts_dir = v.as_str().ok_or(ConfigError::TypeMismatch("artifacts_dir".into()))?.to_string()
            }
            ("", "use_xla") => {
                self.use_xla = v.as_bool().ok_or(ConfigError::TypeMismatch("use_xla".into()))?
            }
            _ => return Err(unknown()),
        }
        self.validate()
    }

    /// Parse and apply a `section.key=value` CLI override.
    pub fn apply_override(&mut self, spec: &str) -> Result<(), ConfigError> {
        let (path, raw) = spec
            .split_once('=')
            .ok_or_else(|| ConfigError::Syntax(format!("override '{spec}' missing '='")))?;
        let (section, key) = match path.split_once('.') {
            Some((s, k)) => (s, k),
            None => ("", path),
        };
        let value = TomlValue::parse(raw.trim())
            .map_err(|e| ConfigError::Syntax(format!("override '{spec}': {e}")))?;
        self.apply_kv(section.trim(), key.trim(), &value)
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let bad = |m: &str| Err(ConfigError::Invalid(m.to_string()));
        if self.control.alpha <= 0.0 {
            return bad("control.alpha must be > 0");
        }
        if !(0.0 < self.control.beta && self.control.beta <= 1.0) {
            return bad("control.beta must be in (0, 1]");
        }
        if self.control.n_min > self.control.n_max {
            return bad("control.n_min must be <= control.n_max");
        }
        if self.control.monitor_interval_s == 0 {
            return bad("control.monitor_interval_s must be > 0");
        }
        if !(0.0 < self.control.footprint_frac && self.control.footprint_frac <= 1.0) {
            return bad("control.footprint_frac must be in (0, 1]");
        }
        if self.market.base_spot_price <= 0.0 || self.market.billing_increment_s == 0 {
            return bad("market prices/billing must be positive");
        }
        if self.storage.bandwidth_bps <= 0.0 {
            return bad("storage.bandwidth_bps must be > 0");
        }
        Ok(())
    }

    pub fn load_file(path: &str) -> Result<Config, ConfigError> {
        let body = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::Io(format!("{path}: {e}")))?;
        let doc = parse_toml(&body)?;
        let mut cfg = Config::paper_defaults();
        cfg.apply_toml(&doc)?;
        Ok(cfg)
    }
}

#[derive(Debug)]
pub enum ConfigError {
    Syntax(String),
    UnknownKey(String),
    TypeMismatch(String),
    Invalid(String),
    Io(String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Syntax(m) => write!(f, "config syntax error: {m}"),
            ConfigError::UnknownKey(k) => write!(f, "unknown config key: {k}"),
            ConfigError::TypeMismatch(k) => write!(f, "wrong value type for key: {k}"),
            ConfigError::Invalid(m) => write!(f, "invalid config: {m}"),
            ConfigError::Io(m) => write!(f, "config io error: {m}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// A parsed TOML-subset document: ordered (section, key) -> value.
#[derive(Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: Vec<((String, String), TomlValue)>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Float(f64),
    Int(i64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Parse a scalar literal: quoted string, bool, int or float.
    pub fn parse(raw: &str) -> Result<TomlValue, String> {
        let raw = raw.trim();
        if raw.is_empty() {
            return Err("empty value".into());
        }
        if let Some(inner) = raw.strip_prefix('"') {
            return inner
                .strip_suffix('"')
                .map(|s| TomlValue::Str(s.to_string()))
                .ok_or_else(|| "unterminated string".into());
        }
        match raw {
            "true" => return Ok(TomlValue::Bool(true)),
            "false" => return Ok(TomlValue::Bool(false)),
            _ => {}
        }
        if raw.starts_with('[') {
            return Err("arrays are not supported in this TOML subset".into());
        }
        if let Ok(i) = raw.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
        raw.parse::<f64>()
            .map(TomlValue::Float)
            .map_err(|_| format!("cannot parse value '{raw}'"))
    }
}

/// Parse the supported TOML subset (see module docs).
pub fn parse_toml(body: &str) -> Result<TomlDoc, ConfigError> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (lineno, line) in body.lines().enumerate() {
        let line = match line.find('#') {
            // '#' inside a quoted string is not a comment; handle the easy
            // common case (comment after value) by checking quote parity.
            Some(idx) if line[..idx].matches('"').count() % 2 == 0 => &line[..idx],
            _ => line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(hdr) = line.strip_prefix('[') {
            let name = hdr
                .strip_suffix(']')
                .ok_or_else(|| ConfigError::Syntax(format!("line {}: bad section header", lineno + 1)))?;
            if name.starts_with('[') {
                return Err(ConfigError::Syntax(format!(
                    "line {}: array-of-tables not supported",
                    lineno + 1
                )));
            }
            section = name.trim().to_string();
            continue;
        }
        let (key, raw) = line
            .split_once('=')
            .ok_or_else(|| ConfigError::Syntax(format!("line {}: expected key = value", lineno + 1)))?;
        let value = TomlValue::parse(raw)
            .map_err(|e| ConfigError::Syntax(format!("line {}: {e}", lineno + 1)))?;
        doc.entries
            .push(((section.clone(), key.trim().to_string()), value));
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::paper_defaults();
        assert_eq!(c.control.alpha, 5.0);
        assert_eq!(c.control.beta, 0.9);
        assert_eq!(c.control.n_min, 10.0);
        assert_eq!(c.control.n_max, 100.0);
        assert_eq!(c.control.n_w_max, 10.0);
        assert_eq!(c.control.sigma_z2, 0.5);
        assert_eq!(c.market.base_spot_price, 0.0081);
        assert_eq!(c.market.billing_increment_s, 3600);
        c.validate().unwrap();
    }

    #[test]
    fn toml_roundtrip() {
        let doc = parse_toml(
            r#"
            seed = 7
            use_xla = false
            [control]
            alpha = 3.5       # AIMD add
            monitor_interval_s = 300
            [market]
            base_spot_price = 0.01
            "#,
        )
        .unwrap();
        let mut cfg = Config::paper_defaults();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.seed, 7);
        assert!(!cfg.use_xla);
        assert_eq!(cfg.control.alpha, 3.5);
        assert_eq!(cfg.control.monitor_interval_s, 300);
        assert_eq!(cfg.market.base_spot_price, 0.01);
    }

    #[test]
    fn rejects_unknown_key() {
        let doc = parse_toml("[control]\nbogus = 1").unwrap();
        let mut cfg = Config::paper_defaults();
        assert!(matches!(cfg.apply_toml(&doc), Err(ConfigError::UnknownKey(_))));
    }

    #[test]
    fn rejects_invalid_values() {
        let mut cfg = Config::paper_defaults();
        assert!(cfg.apply_override("control.beta=1.5").is_err());
        assert!(cfg.apply_override("control.alpha=-1").is_err());
        assert!(cfg.apply_override("control.monitor_interval_s=0").is_err());
    }

    #[test]
    fn cli_override() {
        let mut cfg = Config::paper_defaults();
        cfg.apply_override("control.beta=0.5").unwrap();
        assert_eq!(cfg.control.beta, 0.5);
        cfg.apply_override("seed=99").unwrap();
        assert_eq!(cfg.seed, 99);
        cfg.apply_override("artifacts_dir=\"x/y\"").unwrap();
        assert_eq!(cfg.artifacts_dir, "x/y");
    }

    #[test]
    fn rejects_arrays_and_bad_syntax() {
        assert!(parse_toml("[a]\nk = [1,2]").is_err());
        assert!(parse_toml("[[t]]").is_err());
        assert!(parse_toml("novalue").is_err());
    }
}
