//! Spot-price market simulator (Appendix A / Fig. 12 / Table V).
//!
//! The paper's empirical observations, which this module reproduces:
//!   * spot prices are roughly linear in the instance's CU count;
//!   * price *volatility* grows with CU count — m3.medium (1 CU) stayed
//!     under $0.01 for three months while m4.10xlarge swung wildly;
//!   * spot is ~78–89 % below on-demand.
//!
//! Model: per instance type, a mean-reverting (Ornstein–Uhlenbeck in log
//! space) process around the Table V spot price, with volatility scaled by
//! the CU count, plus occasional demand spikes for large types. Sampled
//! hourly; deterministic per (seed, type).

use crate::config::MarketCfg;
use crate::util::rng::Rng;

/// Static catalogue entry (Table V, North Virginia, July 2015).
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceType {
    pub name: &'static str,
    pub ecus: f64,
    pub cus: u32,
    pub on_demand: f64,
    pub spot_base: f64,
    /// Per-type execution-time multiplier (PR-9, Table V extension):
    /// scheduled busy seconds on this type are scaled by this factor, so
    /// service rates differ by type — not just CU count — as the
    /// heterogeneous-transcoding study (arxiv 1809.06529) observes.
    /// Derived from per-CU ECU density normalized to m3.medium
    /// (`3.0 * cus / ecus`): an ECU-denser CU finishes the same task in
    /// less wall time. m3.medium is *exactly* 1.0 by construction, which
    /// keeps the default single-type fleet bit-identical to pre-PR-9
    /// runs (`x * 1.0 == x` bitwise).
    pub exec_mult: f64,
}

/// Table V catalogue. `exec_mult` entries are the const expressions
/// `3.0 * cus / ecus` so the derivation stays visible (and m3.medium's
/// is the exact literal 1.0).
pub const CATALOG: &[InstanceType] = &[
    InstanceType {
        name: "m3.medium",
        ecus: 3.0,
        cus: 1,
        on_demand: 0.067,
        spot_base: 0.0081,
        exec_mult: 1.0,
    },
    InstanceType {
        name: "m3.large",
        ecus: 6.5,
        cus: 2,
        on_demand: 0.133,
        spot_base: 0.0173,
        exec_mult: 3.0 * 2.0 / 6.5,
    },
    InstanceType {
        name: "m3.xlarge",
        ecus: 13.0,
        cus: 4,
        on_demand: 0.266,
        spot_base: 0.0333,
        exec_mult: 3.0 * 4.0 / 13.0,
    },
    InstanceType {
        name: "m3.2xlarge",
        ecus: 26.0,
        cus: 8,
        on_demand: 0.532,
        spot_base: 0.066,
        exec_mult: 3.0 * 8.0 / 26.0,
    },
    InstanceType {
        name: "m4.4xlarge",
        ecus: 53.5,
        cus: 16,
        on_demand: 1.008,
        spot_base: 0.1097,
        exec_mult: 3.0 * 16.0 / 53.5,
    },
    InstanceType {
        name: "m4.10xlarge",
        ecus: 124.5,
        cus: 40,
        on_demand: 2.52,
        spot_base: 0.5655,
        exec_mult: 3.0 * 40.0 / 124.5,
    },
];

pub fn instance_type(name: &str) -> Option<&'static InstanceType> {
    CATALOG.iter().find(|t| t.name == name)
}

/// One simulated price trace.
#[derive(Debug, Clone)]
pub struct PriceTrace {
    /// Hourly price samples ($/hr).
    pub hourly: Vec<f64>,
}

impl PriceTrace {
    /// Price at a simulated second (step interpolation over hours).
    pub fn price_at(&self, t_secs: u64) -> f64 {
        let h = (t_secs / 3600) as usize;
        self.hourly[h.min(self.hourly.len() - 1)]
    }

    /// Earliest instant strictly after `t_secs` at which [`price_at`]
    /// can return a different value: the next hour boundary, while one
    /// still lies inside the trace. Past the last sample the step
    /// interpolation clamps to `hourly[len-1]`, so the price is
    /// constant forever and there is no next change (`None`).
    ///
    /// [`price_at`]: PriceTrace::price_at
    pub fn next_change_after(&self, t_secs: u64) -> Option<u64> {
        let next_h = t_secs / 3600 + 1;
        // boundaries at or beyond the last sample index never change
        // the clamped lookup
        if (next_h as usize) <= self.hourly.len().saturating_sub(1) {
            Some(next_h * 3600)
        } else {
            None
        }
    }

    pub fn max(&self) -> f64 {
        self.hourly.iter().cloned().fold(f64::MIN, f64::max)
    }

    pub fn mean(&self) -> f64 {
        crate::util::stats::mean(&self.hourly)
    }
}

/// The market: generates + caches per-type price traces.
#[derive(Debug)]
pub struct Market {
    cfg: MarketCfg,
    seed: u64,
    horizon_hours: usize,
    traces: Vec<PriceTrace>,
}

impl Market {
    pub fn new(cfg: MarketCfg, seed: u64, horizon_hours: usize) -> Self {
        let traces = CATALOG
            .iter()
            .enumerate()
            .map(|(i, ty)| Self::simulate_type(&cfg, seed, i as u64, ty, horizon_hours))
            .collect();
        Market { cfg, seed, horizon_hours, traces }
    }

    /// OU-in-log-space around the Table V base price. Volatility per step
    /// scales as cfg.volatility * cus^0.8 (sub-linear: Fig. 12 shows large
    /// types spike by multiples, not by ~40x), and types with >= 8 CUs get
    /// Poisson-ish demand spikes that decay over a few hours.
    fn simulate_type(
        cfg: &MarketCfg,
        seed: u64,
        type_idx: u64,
        ty: &InstanceType,
        hours: usize,
    ) -> PriceTrace {
        let mut rng = Rng::new(seed ^ 0x5707_1234).substream(type_idx);
        let base_ln = ty.spot_base.ln();
        let vol = cfg.volatility * (ty.cus as f64).powf(0.8);
        let mut x = 0.0f64; // log-price deviation from base
        let mut spike = 0.0f64;
        let mut hourly = Vec::with_capacity(hours.max(1));
        for _ in 0..hours.max(1) {
            x += -cfg.reversion * x + vol * rng.normal();
            // demand spikes on big instances (paper: m4.10xlarge volatility)
            if ty.cus >= 8 && rng.f64() < 0.01 {
                spike += rng.uniform(0.5, 2.0);
            }
            spike *= 0.7; // decay
            // spot never exceeds on-demand for long; cap at on-demand x1.2
            let p = (base_ln + x + spike).exp().min(ty.on_demand * 1.2);
            hourly.push(p.max(ty.spot_base * 0.5));
        }
        PriceTrace { hourly }
    }

    pub fn trace(&self, type_idx: usize) -> &PriceTrace {
        &self.traces[type_idx]
    }

    /// Current spot price for a type at simulated time t.
    pub fn spot_price(&self, type_idx: usize, t_secs: u64) -> f64 {
        self.traces[type_idx].price_at(t_secs)
    }

    pub fn cfg(&self) -> &MarketCfg {
        &self.cfg
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn horizon_hours(&self) -> usize {
        self.horizon_hours
    }

    /// Earliest instant strictly after `t_secs` at which *any* type's
    /// spot price can move. Every trace is sampled on the same hourly
    /// grid with the same length, so one boundary bounds all pools;
    /// `None` once every trace has clamped to its final sample.
    pub fn next_price_change(&self, t_secs: u64) -> Option<u64> {
        self.traces.first().and_then(|t| t.next_change_after(t_secs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn market() -> Market {
        Market::new(MarketCfg::default(), 42, 24 * 90)
    }

    #[test]
    fn catalog_matches_table_v() {
        assert_eq!(CATALOG.len(), 6);
        let m3m = instance_type("m3.medium").unwrap();
        assert_eq!(m3m.cus, 1);
        assert_eq!(m3m.spot_base, 0.0081);
        assert_eq!(m3m.on_demand, 0.067);
        // on-demand cost roughly linear in CUs (paper's observation)
        for ty in CATALOG {
            let per_cu = ty.on_demand / ty.cus as f64;
            assert!((0.05..0.075).contains(&per_cu), "{}: {per_cu}", ty.name);
        }
    }

    #[test]
    fn exec_mult_normalized_to_m3_medium() {
        // the base type is *exactly* 1.0 (default-fleet bit-identity:
        // busy_s * 1.0 is bitwise busy_s), larger types within ~10 %
        assert_eq!(instance_type("m3.medium").unwrap().exec_mult.to_bits(), 1.0f64.to_bits());
        for ty in CATALOG {
            assert!(
                (0.85..=1.0).contains(&ty.exec_mult),
                "{}: exec_mult={}",
                ty.name,
                ty.exec_mult
            );
            // derivation: per-CU ECU density normalized to m3.medium
            let want = 3.0 * ty.cus as f64 / ty.ecus;
            assert_eq!(ty.exec_mult.to_bits(), want.to_bits(), "{}", ty.name);
        }
    }

    #[test]
    fn spot_discount_in_paper_range() {
        // Table V: 78%-89% below on-demand.
        for ty in CATALOG {
            let disc = 1.0 - ty.spot_base / ty.on_demand;
            assert!((0.7..0.95).contains(&disc), "{}: {disc}", ty.name);
        }
    }

    #[test]
    fn m3_medium_stays_under_one_cent() {
        // Paper: "at no point in the three month period does the m3.medium
        // spot price exceed $0.01".
        let m = market();
        assert!(m.trace(0).max() < 0.011, "max={}", m.trace(0).max());
    }

    #[test]
    fn volatility_grows_with_cus() {
        let m = market();
        let cv = |i: usize| {
            let t = &m.trace(i).hourly;
            stats::std(t) / stats::mean(t)
        };
        assert!(cv(0) < cv(3), "cv(m3.medium)={} cv(m3.2xlarge)={}", cv(0), cv(3));
        assert!(cv(0) < cv(5), "cv(m3.medium)={} cv(m4.10xlarge)={}", cv(0), cv(5));
    }

    #[test]
    fn prices_track_base() {
        let m = market();
        for (i, ty) in CATALOG.iter().enumerate() {
            let mean = m.trace(i).mean();
            assert!(
                (mean / ty.spot_base - 1.0).abs() < 0.8,
                "{}: mean={mean} base={}",
                ty.name,
                ty.spot_base
            );
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = Market::new(MarketCfg::default(), 7, 48);
        let b = Market::new(MarketCfg::default(), 7, 48);
        assert_eq!(a.trace(2).hourly, b.trace(2).hourly);
        let c = Market::new(MarketCfg::default(), 8, 48);
        assert_ne!(a.trace(2).hourly, c.trace(2).hourly);
    }

    #[test]
    fn price_at_steps_by_hour() {
        let m = market();
        assert_eq!(m.spot_price(0, 10), m.spot_price(0, 3599));
        assert_eq!(m.spot_price(0, 3600), m.trace(0).hourly[1]);
        // beyond the horizon clamps to the last sample
        let last = *m.trace(0).hourly.last().unwrap();
        assert_eq!(m.spot_price(0, u64::MAX / 2), last);
    }

    #[test]
    fn next_change_is_the_next_in_trace_hour_boundary() {
        let m = Market::new(MarketCfg::default(), 7, 3); // samples @ h 0,1,2
        assert_eq!(m.next_price_change(0), Some(3600));
        assert_eq!(m.next_price_change(3599), Some(3600));
        assert_eq!(m.next_price_change(3600), Some(7200), "boundary itself already applied");
        // the last sample (h=2) covers [7200, ∞) under clamping: no change
        assert_eq!(m.next_price_change(7200), None);
        assert_eq!(m.next_price_change(50_000), None);
        // soundness against the lookup: price is constant on [t, next)
        let t = m.trace(1);
        let nb = t.next_change_after(100).unwrap();
        assert_eq!(t.price_at(100), t.price_at(nb - 1));
    }
}
