//! Spot-instance lifecycle and billing (the `a_{i,j}[t]` bookkeeping).
//!
//! EC2 spot semantics modeled per §II-C / §IV:
//!   * requesting an instance incurs a boot delay before it can work;
//!   * billing is per started `billing_increment_s` (hourly for EC2) at
//!     the spot price in force when the increment starts;
//!   * `a_{i,j}[t]` = seconds remaining in the already-billed increment —
//!     AIMD terminates the instances with the *smallest* remaining time
//!     (their sunk cost is nearly used up).
//!
//! Capacity model: an instance of a `cus`-CU catalogue type executes up
//! to `cus` chunks *concurrently* (one per compute unit) — a 40-CU
//! m4.10xlarge absorbs 40 single-core chunks at once, a 1-CU m3.medium
//! exactly one. `chunks` holds the in-flight chunk ids; dispatch fills
//! free slots, termination drains until every slot empties.

use crate::sim::SimTime;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    /// Spot request placed, still booting.
    Booting,
    /// Running and available for task execution.
    Running,
    /// Marked for termination once its in-flight chunks finish.
    Draining,
    /// Terminated; no further billing.
    Terminated,
}

/// One spot instance of some catalogue type.
#[derive(Debug, Clone)]
pub struct Instance {
    pub id: u64,
    pub type_idx: usize,
    pub cus: u32,
    pub state: InstanceState,
    /// When the spot request was placed.
    pub requested_at: SimTime,
    /// When it became Running (boot complete).
    pub ready_at: Option<SimTime>,
    /// When it was terminated.
    pub terminated_at: Option<SimTime>,
    /// End of the currently-billed increment (absolute sim time).
    pub billed_until: SimTime,
    /// Total $ billed so far.
    pub cost: f64,
    /// Number of billing increments paid.
    pub increments: u32,
    /// Busy core-seconds accumulated (for utilization metrics / Amazon
    /// AS): each concurrent chunk contributes its own busy time.
    pub busy_s: u64,
    /// Ids of the chunks currently executing, in dispatch order
    /// (at most `cus`; merge steps appear as `MERGE_CHUNK` entries).
    pub chunks: Vec<u64>,
}

impl Instance {
    pub fn new(id: u64, type_idx: usize, cus: u32, now: SimTime) -> Self {
        Instance {
            id,
            type_idx,
            cus,
            state: InstanceState::Booting,
            requested_at: now,
            ready_at: None,
            terminated_at: None,
            billed_until: now, // first increment charged at boot-complete
            cost: 0.0,
            increments: 0,
            busy_s: 0,
            chunks: vec![],
        }
    }

    /// Remaining pre-billed seconds, a_{i,j}[t]. Zero for terminated.
    pub fn remaining_billed(&self, now: SimTime) -> SimTime {
        if self.state == InstanceState::Terminated {
            return 0;
        }
        self.billed_until.saturating_sub(now)
    }

    pub fn is_active(&self, now: SimTime) -> bool {
        let _ = now;
        matches!(self.state, InstanceState::Running | InstanceState::Draining)
    }

    /// Fully idle: running with no chunk in flight (the termination
    /// preference — only whole instances can be released).
    pub fn is_idle(&self) -> bool {
        self.state == InstanceState::Running && self.chunks.is_empty()
    }

    /// Has a free compute unit to absorb one more concurrent chunk.
    pub fn has_free_slot(&self) -> bool {
        self.state == InstanceState::Running && (self.chunks.len() as u32) < self.cus
    }

    /// Occupy one compute unit with chunk `id`.
    pub fn begin_chunk(&mut self, id: u64) {
        debug_assert!((self.chunks.len() as u32) < self.cus, "instance over capacity");
        self.chunks.push(id);
    }

    /// Charge billing increments so the instance is paid up through `now`.
    /// `price` is the $/hr spot price at the start of each new increment;
    /// `increment_s` the billing quantum. Returns $ newly billed.
    pub fn bill_through(
        &mut self,
        now: SimTime,
        price_at: impl Fn(SimTime) -> f64,
        increment_s: SimTime,
    ) -> f64 {
        if self.state == InstanceState::Terminated {
            return 0.0;
        }
        let mut newly = 0.0;
        while self.billed_until <= now {
            let price = price_at(self.billed_until);
            let charge = price * (increment_s as f64 / 3600.0);
            self.cost += charge;
            newly += charge;
            self.increments += 1;
            self.billed_until += increment_s;
        }
        newly
    }

    /// Mark boot complete.
    pub fn boot_complete(&mut self, now: SimTime) {
        debug_assert_eq!(self.state, InstanceState::Booting);
        self.state = InstanceState::Running;
        self.ready_at = Some(now);
    }

    /// Terminate now (or drain if busy: terminates once every in-flight
    /// chunk completes).
    pub fn terminate(&mut self, now: SimTime) {
        match self.state {
            InstanceState::Terminated => {}
            _ if !self.chunks.is_empty() => self.state = InstanceState::Draining,
            _ => {
                self.state = InstanceState::Terminated;
                self.terminated_at = Some(now);
            }
        }
    }

    /// Finish chunk `chunk`, releasing its compute unit; returns true if
    /// the instance terminated because it was draining and this was the
    /// last in-flight chunk.
    pub fn finish_chunk(&mut self, chunk: u64, now: SimTime, busy: SimTime) -> bool {
        self.busy_s += busy;
        if let Some(i) = self.chunks.iter().position(|&c| c == chunk) {
            self.chunks.remove(i);
        }
        if self.state == InstanceState::Draining && self.chunks.is_empty() {
            self.state = InstanceState::Terminated;
            self.terminated_at = Some(now);
            true
        } else {
            false
        }
    }

    /// CPU utilization over the instance's active lifetime so far, in
    /// [0, 1], normalized by its CU count (a 16-CU instance running one
    /// chunk is 1/16 utilized). This is what the Amazon-AS baseline's
    /// 20 % rule reads (mpstat / wmic in the paper).
    pub fn utilization(&self, now: SimTime) -> f64 {
        let start = match self.ready_at {
            Some(t) => t,
            None => return 0.0,
        };
        let end = self.terminated_at.unwrap_or(now);
        if end <= start {
            return 0.0;
        }
        (self.busy_s as f64 / ((end - start) as f64 * self.cus as f64)).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst() -> Instance {
        Instance::new(1, 0, 1, 100)
    }

    #[test]
    fn bills_hourly_increments_at_spot_price() {
        let mut i = inst();
        i.boot_complete(190);
        let billed = i.bill_through(190, |_| 0.0081, 3600);
        assert!((billed - 0.0081).abs() < 1e-12);
        assert_eq!(i.billed_until, 100 + 3600);
        // nothing more due within the hour
        assert_eq!(i.bill_through(3000, |_| 0.0081, 3600), 0.0);
        // crossing into hour 2 charges again
        let billed = i.bill_through(3700, |_| 0.009, 3600);
        assert!((billed - 0.009).abs() < 1e-12);
        assert_eq!(i.increments, 2);
    }

    #[test]
    fn remaining_billed_counts_down() {
        let mut i = inst();
        i.boot_complete(100);
        i.bill_through(100, |_| 0.0081, 3600);
        assert_eq!(i.remaining_billed(100), 3600);
        assert_eq!(i.remaining_billed(1300), 2400);
        i.terminate(1300);
        assert_eq!(i.remaining_billed(1300), 0);
    }

    #[test]
    fn terminate_busy_instance_drains() {
        let mut i = inst();
        i.boot_complete(100);
        i.begin_chunk(9);
        i.terminate(200);
        assert_eq!(i.state, InstanceState::Draining);
        let died = i.finish_chunk(9, 500, 300);
        assert!(died);
        assert_eq!(i.state, InstanceState::Terminated);
        assert_eq!(i.terminated_at, Some(500));
    }

    #[test]
    fn terminate_idle_is_immediate() {
        let mut i = inst();
        i.boot_complete(100);
        i.terminate(150);
        assert_eq!(i.state, InstanceState::Terminated);
        // idempotent
        i.terminate(160);
        assert_eq!(i.terminated_at, Some(150));
    }

    #[test]
    fn no_billing_after_termination() {
        let mut i = inst();
        i.boot_complete(100);
        i.bill_through(100, |_| 0.0081, 3600);
        i.terminate(200);
        assert_eq!(i.bill_through(50_000, |_| 0.0081, 3600), 0.0);
        assert_eq!(i.increments, 1);
    }

    #[test]
    fn utilization_tracks_busy_fraction() {
        let mut i = inst();
        i.boot_complete(100);
        i.begin_chunk(1);
        i.finish_chunk(1, 600, 250);
        // 250 busy out of 500 elapsed
        assert!((i.utilization(600) - 0.5).abs() < 1e-9);
        assert_eq!(i.utilization(100), 0.0); // degenerate window guarded
    }

    #[test]
    fn booting_instance_has_zero_utilization() {
        let i = inst();
        assert_eq!(i.utilization(1000), 0.0);
        assert!(!i.is_idle());
        assert!(!i.has_free_slot());
    }

    #[test]
    fn multi_cu_instance_runs_concurrent_chunks() {
        let mut i = Instance::new(7, 4, 16, 0);
        i.boot_complete(90);
        assert!(i.is_idle() && i.has_free_slot());
        for c in 0..16 {
            assert!(i.has_free_slot(), "slot {c} should be free");
            i.begin_chunk(c);
        }
        assert!(!i.has_free_slot(), "all 16 slots occupied");
        assert!(!i.is_idle());
        // releasing one slot reopens capacity but the instance stays busy
        assert!(!i.finish_chunk(3, 500, 100));
        assert!(i.has_free_slot());
        assert!(!i.is_idle());
        assert_eq!(i.chunks.len(), 15);
    }

    #[test]
    fn draining_multi_cu_instance_dies_with_last_chunk() {
        let mut i = Instance::new(7, 2, 2, 0);
        i.boot_complete(90);
        i.begin_chunk(1);
        i.begin_chunk(2);
        i.terminate(100);
        assert_eq!(i.state, InstanceState::Draining);
        assert!(!i.finish_chunk(1, 200, 50), "first completion keeps draining");
        assert!(i.finish_chunk(2, 300, 60), "last completion terminates");
        assert_eq!(i.terminated_at, Some(300));
    }

    #[test]
    fn utilization_is_normalized_by_cus() {
        let mut i = Instance::new(9, 3, 8, 0);
        i.boot_complete(0);
        i.begin_chunk(1);
        // one core busy for the full 400 s window on an 8-CU instance
        i.finish_chunk(1, 400, 400);
        assert!((i.utilization(400) - 1.0 / 8.0).abs() < 1e-9);
    }
}
