//! Spot-instance lifecycle and billing (the `a_{i,j}[t]` bookkeeping).
//!
//! EC2 spot semantics modeled per §II-C / §IV:
//!   * requesting an instance incurs a boot delay before it can work;
//!   * billing is per started `billing_increment_s` (hourly for EC2) at
//!     the spot price in force when the increment starts;
//!   * `a_{i,j}[t]` = seconds remaining in the already-billed increment —
//!     AIMD terminates the instances with the *smallest* remaining time
//!     (their sunk cost is nearly used up).

use crate::sim::SimTime;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    /// Spot request placed, still booting.
    Booting,
    /// Running and available for task execution.
    Running,
    /// Marked for termination once its current chunk finishes.
    Draining,
    /// Terminated; no further billing.
    Terminated,
}

/// One spot instance of some catalogue type.
#[derive(Debug, Clone)]
pub struct Instance {
    pub id: u64,
    pub type_idx: usize,
    pub cus: u32,
    pub state: InstanceState,
    /// When the spot request was placed.
    pub requested_at: SimTime,
    /// When it became Running (boot complete).
    pub ready_at: Option<SimTime>,
    /// When it was terminated.
    pub terminated_at: Option<SimTime>,
    /// End of the currently-billed increment (absolute sim time).
    pub billed_until: SimTime,
    /// Total $ billed so far.
    pub cost: f64,
    /// Number of billing increments paid.
    pub increments: u32,
    /// Busy seconds accumulated (for utilization metrics / Amazon AS).
    pub busy_s: u64,
    /// Id of the chunk currently executing, if any.
    pub current_chunk: Option<u64>,
}

impl Instance {
    pub fn new(id: u64, type_idx: usize, cus: u32, now: SimTime) -> Self {
        Instance {
            id,
            type_idx,
            cus,
            state: InstanceState::Booting,
            requested_at: now,
            ready_at: None,
            terminated_at: None,
            billed_until: now, // first increment charged at boot-complete
            cost: 0.0,
            increments: 0,
            busy_s: 0,
            current_chunk: None,
        }
    }

    /// Remaining pre-billed seconds, a_{i,j}[t]. Zero for terminated.
    pub fn remaining_billed(&self, now: SimTime) -> SimTime {
        if self.state == InstanceState::Terminated {
            return 0;
        }
        self.billed_until.saturating_sub(now)
    }

    pub fn is_active(&self, now: SimTime) -> bool {
        let _ = now;
        matches!(self.state, InstanceState::Running | InstanceState::Draining)
    }

    pub fn is_idle(&self) -> bool {
        self.state == InstanceState::Running && self.current_chunk.is_none()
    }

    /// Charge billing increments so the instance is paid up through `now`.
    /// `price` is the $/hr spot price at the start of each new increment;
    /// `increment_s` the billing quantum. Returns $ newly billed.
    pub fn bill_through(&mut self, now: SimTime, price_at: impl Fn(SimTime) -> f64, increment_s: SimTime) -> f64 {
        if self.state == InstanceState::Terminated {
            return 0.0;
        }
        let mut newly = 0.0;
        while self.billed_until <= now {
            let price = price_at(self.billed_until);
            let charge = price * (increment_s as f64 / 3600.0);
            self.cost += charge;
            newly += charge;
            self.increments += 1;
            self.billed_until += increment_s;
        }
        newly
    }

    /// Mark boot complete.
    pub fn boot_complete(&mut self, now: SimTime) {
        debug_assert_eq!(self.state, InstanceState::Booting);
        self.state = InstanceState::Running;
        self.ready_at = Some(now);
    }

    /// Terminate now (or drain if busy: terminates after chunk completion).
    pub fn terminate(&mut self, now: SimTime) {
        match self.state {
            InstanceState::Terminated => {}
            _ if self.current_chunk.is_some() => self.state = InstanceState::Draining,
            _ => {
                self.state = InstanceState::Terminated;
                self.terminated_at = Some(now);
            }
        }
    }

    /// Finish the current chunk; returns true if the instance terminated
    /// because it was draining.
    pub fn finish_chunk(&mut self, now: SimTime, busy: SimTime) -> bool {
        self.busy_s += busy;
        self.current_chunk = None;
        if self.state == InstanceState::Draining {
            self.state = InstanceState::Terminated;
            self.terminated_at = Some(now);
            true
        } else {
            false
        }
    }

    /// CPU utilization over the instance's active lifetime so far, in
    /// [0, 1]. This is what the Amazon-AS baseline's 20 % rule reads
    /// (mpstat / wmic in the paper).
    pub fn utilization(&self, now: SimTime) -> f64 {
        let start = match self.ready_at {
            Some(t) => t,
            None => return 0.0,
        };
        let end = self.terminated_at.unwrap_or(now);
        if end <= start {
            return 0.0;
        }
        (self.busy_s as f64 / (end - start) as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst() -> Instance {
        Instance::new(1, 0, 1, 100)
    }

    #[test]
    fn bills_hourly_increments_at_spot_price() {
        let mut i = inst();
        i.boot_complete(190);
        let billed = i.bill_through(190, |_| 0.0081, 3600);
        assert!((billed - 0.0081).abs() < 1e-12);
        assert_eq!(i.billed_until, 100 + 3600);
        // nothing more due within the hour
        assert_eq!(i.bill_through(3000, |_| 0.0081, 3600), 0.0);
        // crossing into hour 2 charges again
        let billed = i.bill_through(3700, |_| 0.009, 3600);
        assert!((billed - 0.009).abs() < 1e-12);
        assert_eq!(i.increments, 2);
    }

    #[test]
    fn remaining_billed_counts_down() {
        let mut i = inst();
        i.boot_complete(100);
        i.bill_through(100, |_| 0.0081, 3600);
        assert_eq!(i.remaining_billed(100), 3600);
        assert_eq!(i.remaining_billed(1300), 2400);
        i.terminate(1300);
        assert_eq!(i.remaining_billed(1300), 0);
    }

    #[test]
    fn terminate_busy_instance_drains() {
        let mut i = inst();
        i.boot_complete(100);
        i.current_chunk = Some(9);
        i.terminate(200);
        assert_eq!(i.state, InstanceState::Draining);
        let died = i.finish_chunk(500, 300);
        assert!(died);
        assert_eq!(i.state, InstanceState::Terminated);
        assert_eq!(i.terminated_at, Some(500));
    }

    #[test]
    fn terminate_idle_is_immediate() {
        let mut i = inst();
        i.boot_complete(100);
        i.terminate(150);
        assert_eq!(i.state, InstanceState::Terminated);
        // idempotent
        i.terminate(160);
        assert_eq!(i.terminated_at, Some(150));
    }

    #[test]
    fn no_billing_after_termination() {
        let mut i = inst();
        i.boot_complete(100);
        i.bill_through(100, |_| 0.0081, 3600);
        i.terminate(200);
        assert_eq!(i.bill_through(50_000, |_| 0.0081, 3600), 0.0);
        assert_eq!(i.increments, 1);
    }

    #[test]
    fn utilization_tracks_busy_fraction() {
        let mut i = inst();
        i.boot_complete(100);
        i.current_chunk = Some(1);
        i.finish_chunk(600, 250);
        // 250 busy out of 500 elapsed
        assert!((i.utilization(600) - 0.5).abs() < 1e-9);
        assert_eq!(i.utilization(100), 0.0); // degenerate window guarded
    }

    #[test]
    fn booting_instance_has_zero_utilization() {
        let i = inst();
        assert_eq!(i.utilization(1000), 0.0);
        assert!(!i.is_idle());
    }
}
