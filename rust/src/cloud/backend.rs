//! Pluggable cloud backends behind one trait.
//!
//! [`CloudBackend`] abstracts everything the platform loop needs from an
//! IaaS/FaaS substrate: instance lifecycle (request / ready / terminate /
//! revoke), billing, fleet description and the usage hooks fired when
//! work finishes. Three implementations ship:
//!
//! * **spot** — the paper's substrate: [`crate::cloud::Provider`] over the
//!   simulated spot market, hourly pre-billing, boot delay, and forced
//!   revocation when a fault model reclaims instances;
//! * **on-demand** — the same `Provider` mechanics at the flat Table V
//!   on-demand rate (never reclaimable): the §V-C "what if we didn't use
//!   spot" baseline through the identical scheduling loop;
//! * **lambda** — [`LambdaBackend`]: §V-D FaaS semantics — near-instant
//!   cold start, *fractional* cores (tasks run `1/core_fraction` slower),
//!   and usage billing per 100 ms GB-second quantum plus a per-request
//!   fee, charged as chunks finish instead of by the wall-clock hour.
//!
//! The trait is **pool-aware**: a backend exposes one or more per-type
//! instance *pools* (see [`crate::cloud::FleetSpec`]) — capacity is
//! requested by pool ([`CloudBackend::request_instance_in`], which may
//! leave an above-bid spot request *unfulfilled*), and described either
//! per pool ([`CloudBackend::describe_pool`], the per-type CU vector) or
//! in aggregate ([`CloudBackend::describe`], what the controller's
//! scaling law reads). Single-pool backends (Lambda, the default trait
//! impls) behave exactly like the pre-fleet platform.
//!
//! The trait is object-safe (the platform owns a `Box<dyn CloudBackend>`)
//! and its iteration surface is callback-based (`for_each_instance`) so
//! the steady-state monitoring tick stays allocation-free.

use std::collections::BTreeMap;

use crate::cloud::fleet::FleetSpec;
use crate::cloud::instance::{Instance, InstanceState};
use crate::cloud::lambda::core_fraction;
use crate::cloud::provider::{FleetView, Provider};
use crate::config::{Config, LambdaCfg};
use crate::sim::SimTime;

/// Chunk-id marker for a merge step occupying an instance slot.
pub const MERGE_CHUNK: u64 = u64::MAX;

/// Lambda cold-start latency (container spin-up), seconds.
pub const LAMBDA_COLD_START_S: u64 = 2;

/// Which backend a scenario runs on. Plain descriptor (Clone/PartialEq)
/// so scenarios stay cheap to copy across sweep workers; the trait
/// object is built per run by [`BackendKind::build`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// EC2 spot market (the paper's substrate). Reclaimable.
    Spot,
    /// EC2 on-demand: flat hourly rate, never reclaimed.
    OnDemand,
    /// AWS-Lambda-style FaaS: fractional cores, usage billing.
    Lambda,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Spot => "spot",
            BackendKind::OnDemand => "on-demand",
            BackendKind::Lambda => "lambda",
        }
    }

    /// Instantiate the backend for one run. `fleet` selects the per-type
    /// pools for the IaaS backends (Lambda has no instance types and
    /// ignores it).
    pub fn build(
        &self,
        cfg: &Config,
        seed: u64,
        horizon_hours: usize,
        fleet: &FleetSpec,
    ) -> Box<dyn CloudBackend> {
        match self {
            BackendKind::Spot => {
                Box::new(Provider::with_fleet(cfg.market.clone(), seed, horizon_hours, fleet))
            }
            BackendKind::OnDemand => Box::new(Provider::with_fleet_on_demand(
                cfg.market.clone(),
                seed,
                horizon_hours,
                fleet,
            )),
            BackendKind::Lambda => Box::new(LambdaBackend::new(cfg.lambda.clone())),
        }
    }
}

/// The cloud substrate seen by the platform loop.
pub trait CloudBackend {
    /// Human-readable backend name ("spot" / "on-demand" / "lambda").
    fn name(&self) -> &'static str;

    /// Whether a spot-reclamation fault model applies to this backend.
    fn reclaimable(&self) -> bool {
        false
    }

    // ----- pools -------------------------------------------------------

    /// Number of per-type instance pools (1 for single-type backends).
    fn pool_count(&self) -> usize {
        1
    }

    /// Catalogue type index of pool `pool`.
    fn pool_type_idx(&self, _pool: usize) -> usize {
        0
    }

    /// CUs per instance of pool `pool`.
    fn pool_cus(&self, pool: usize) -> u32 {
        crate::cloud::market::CATALOG[self.pool_type_idx(pool)].cus
    }

    /// The pool owning catalogue type `type_idx`, if any.
    fn pool_of_type(&self, type_idx: usize) -> Option<usize> {
        (type_idx == 0).then_some(0)
    }

    /// The pool's spot bid, if it has one (fulfilment + revocation gate).
    fn pool_bid(&self, _pool: usize) -> Option<f64> {
        None
    }

    /// Current $/hr unit price of pool `pool` (its type's spot price /
    /// flat rate). Market-driven fault models compare this against the
    /// pool's bid.
    fn pool_unit_price(&self, _pool: usize, now: SimTime) -> f64 {
        self.unit_price(now)
    }

    /// `describeInstances()` restricted to one pool: the per-type CU
    /// vector entry.
    fn describe_pool(&self, _pool: usize, now: SimTime) -> FleetView {
        self.describe(now)
    }

    // ----- lifecycle ---------------------------------------------------

    /// Request one instance from pool `pool`; returns `Some((id,
    /// ready_at))` when the request is fulfilled. A spot request placed
    /// while the pool's market price exceeds its bid returns `None` —
    /// real-EC2 semantics: the request stays *pending* and the caller
    /// retries at a later instant (nothing is booked or billed).
    fn request_instance_in(&mut self, pool: usize, now: SimTime) -> Option<(u64, SimTime)>;

    /// Request one unit of capacity from the first pool — a
    /// compatibility surface for *bid-less* single-pool backends
    /// (tests, direct `Provider` drivers). Panics if the request is
    /// left unfulfilled, which can happen on platform-built spot
    /// backends whose pool 0 carries a bid (scenario assembly copies a
    /// `SpotReclamation` fault bid onto it): platform code must use
    /// [`CloudBackend::request_instance_in`], which reports an
    /// unfulfilled request instead of panicking.
    fn request_instance(&mut self, now: SimTime) -> (u64, SimTime) {
        self.request_instance_in(0, now)
            .expect("pool 0 spot request unfulfilled (market above bid)")
    }

    /// Boot/cold-start completion for `id`.
    fn instance_ready(&mut self, id: u64, now: SimTime);

    /// Graceful termination (drains if busy).
    fn terminate_instance(&mut self, id: u64, now: SimTime);

    /// Forced revocation (spot reclamation): immediate termination even
    /// mid-chunk. The already-billed increment is sunk — the simulator
    /// deliberately skips the partial-hour refund real EC2 grants so the
    /// cost curve stays monotone (documented simplification).
    fn revoke_instance(&mut self, id: u64, now: SimTime) {
        if let Some(inst) = self.instance_mut(id) {
            if inst.state != InstanceState::Terminated {
                inst.state = InstanceState::Terminated;
                inst.terminated_at = Some(now);
                inst.chunks.clear();
            }
        }
    }

    /// Advance time-based billing through `now` (no-op for usage-billed
    /// backends).
    fn bill_through(&mut self, now: SimTime);

    /// Earliest instant at which [`CloudBackend::bill_through`] would
    /// charge something — one leg of the sparse-tick skip horizon
    /// (PR-6): a monitoring instant strictly before this time can be
    /// fast-forwarded without missing a billing charge or a cost-curve
    /// point. `None` means "never" (usage-billed backends, whose cost
    /// accrues entirely in completion events). The conservative default
    /// (`Some(now)`) makes an unaware backend simply never skip.
    fn next_billing_due(&self, now: SimTime) -> Option<SimTime> {
        Some(now)
    }

    /// Earliest instant strictly after `now` at which any pool's
    /// [`CloudBackend::pool_unit_price`] can change — the market leg of
    /// the skip horizon (market-driven fault models and the greedy
    /// fill's price comparisons both read live prices). `None` means
    /// prices are constant from `now` on (flat-rate and usage-billed
    /// backends).
    fn next_price_change(&self, _now: SimTime) -> Option<SimTime> {
        None
    }

    /// `describeInstances()` fleet summary — the aggregate over every
    /// pool (what the scaling controller reads).
    fn describe(&self, now: SimTime) -> FleetView;

    fn instance(&self, id: u64) -> Option<&Instance>;
    fn instance_mut(&mut self, id: u64) -> Option<&mut Instance>;

    /// Visit every instance (allocation-free iteration surface).
    fn for_each_instance(&self, f: &mut dyn FnMut(&Instance));

    /// First running instance with a free compute-unit slot, in id
    /// order, if any (merge-step placement).
    fn first_free_slot(&self) -> Option<u64>;

    /// Fully idle running instances ordered by ascending remaining
    /// pre-billed time (the AIMD termination preference).
    fn idle_instances_by_remaining(&self, now: SimTime) -> Vec<u64>;

    /// Mean CPU utilization over active instances (Amazon AS input).
    fn mean_utilization(&self, now: SimTime) -> f64;

    fn total_cost(&self) -> f64;
    fn cost_curve(&self) -> &[(SimTime, f64)];

    /// Current $/hr unit price of the first pool (spot market price,
    /// flat rate, or the GB-second-equivalent hourly rate for Lambda).
    fn unit_price(&self, now: SimTime) -> f64;

    /// Wall-clock multiplier on task execution: 1.0 for whole-core
    /// instances, `1 / core_fraction` for Lambda's fractional cores.
    fn execution_multiplier(&self) -> f64 {
        1.0
    }

    /// Per-*instance* execution-time multiplier (PR-9): the Table V
    /// catalogue's per-type `exec_mult` for IaaS backends — an ECU-dense
    /// type runs the same task in less wall time — composed with the
    /// backend-wide [`execution_multiplier`] at dispatch. Defaults to
    /// 1.0 (Lambda's fleet is homogeneous; the base m3.medium type is
    /// exactly 1.0, so default fleets are bit-identical to pre-PR-9).
    ///
    /// [`execution_multiplier`]: CloudBackend::execution_multiplier
    fn instance_exec_mult(&self, id: u64) -> f64 {
        let _ = id;
        1.0
    }

    /// Chunk `chunk` of `tasks` tasks finished on `id` after `busy_s`
    /// occupied core-seconds: release its slot and do any usage billing.
    fn on_chunk_finished(&mut self, id: u64, chunk: u64, now: SimTime, busy_s: f64, tasks: usize) {
        let _ = tasks;
        if let Some(inst) = self.instance_mut(id) {
            inst.finish_chunk(chunk, now, busy_s.ceil() as SimTime);
        }
    }

    /// A merge step of `merge_s` seconds was dispatched onto `id`: mark
    /// one slot busy. (Usage billing happens at completion — a reclaimed
    /// merge is re-dispatched and must not be charged twice.)
    fn on_merge_dispatched(&mut self, id: u64, now: SimTime, merge_s: f64) {
        let _ = now;
        if let Some(inst) = self.instance_mut(id) {
            inst.begin_chunk(MERGE_CHUNK);
            inst.busy_s += merge_s.ceil() as SimTime;
        }
    }

    /// The merge step on `id` completed after `merge_s` seconds: release
    /// its slot and do any usage billing (the busy time was already
    /// accounted at dispatch).
    fn on_merge_finished(&mut self, id: u64, now: SimTime, merge_s: f64) {
        let _ = merge_s;
        if let Some(inst) = self.instance_mut(id) {
            inst.finish_chunk(MERGE_CHUNK, now, 0);
        }
    }
}

// ----- shared fleet helpers (spot/on-demand/lambda all keep a dense
// id-ordered instance map) --------------------------------------------

pub(crate) fn fleet_view(instances: &BTreeMap<u64, Instance>, now: SimTime) -> FleetView {
    let mut v = FleetView::default();
    for inst in instances.values() {
        fleet_view_add(&mut v, inst, now);
    }
    v
}

/// Accumulate one instance into a [`FleetView`] (shared by the
/// aggregate and the per-pool describes).
pub(crate) fn fleet_view_add(v: &mut FleetView, inst: &Instance, now: SimTime) {
    match inst.state {
        InstanceState::Booting => {
            v.booting += 1;
            v.committed_cus += inst.cus as f64;
        }
        InstanceState::Running => {
            v.running += 1;
            v.active_cus += inst.cus as f64;
            v.committed_cus += inst.cus as f64;
            v.c_tot += (inst.cus as u64 * inst.remaining_billed(now)) as f64;
        }
        InstanceState::Draining => {
            v.draining += 1;
            v.active_cus += inst.cus as f64;
            v.committed_cus += inst.cus as f64;
            v.c_tot += (inst.cus as u64 * inst.remaining_billed(now)) as f64;
        }
        InstanceState::Terminated => v.terminated += 1,
    }
}

pub(crate) fn fleet_first_free(instances: &BTreeMap<u64, Instance>) -> Option<u64> {
    instances.values().find(|i| i.has_free_slot()).map(|i| i.id)
}

pub(crate) fn fleet_idle_by_remaining(
    instances: &BTreeMap<u64, Instance>,
    now: SimTime,
) -> Vec<u64> {
    let mut v: Vec<(u64, SimTime)> = instances
        .values()
        .filter(|i| i.is_idle())
        .map(|i| (i.id, i.remaining_billed(now)))
        .collect();
    v.sort_by_key(|&(id, rem)| (rem, id));
    v.into_iter().map(|(id, _)| id).collect()
}

// One-pass (allocation-free) mean over active instances: identical to
// `stats::mean` of the collected utilizations — same left-to-right
// summation order over the same id-ordered values, empty fleet -> 0.0 —
// but callable from the fast-forward path of a skipped tick, which must
// not touch the heap.
pub(crate) fn fleet_mean_utilization(instances: &BTreeMap<u64, Instance>, now: SimTime) -> f64 {
    let mut sum = 0.0f64;
    let mut count = 0usize;
    for i in instances.values().filter(|i| i.is_active(now)) {
        sum += i.utilization(now);
        count += 1;
    }
    if count == 0 {
        0.0
    } else {
        sum / count as f64
    }
}

// ----- Lambda backend --------------------------------------------------

/// FaaS execution substrate (§V-D): each "instance" is a warm function
/// slot. No pre-billing — cost accrues per finished chunk as
/// `ceil(busy / quantum) * quantum * memory_gb * $/GB-s` plus one
/// request fee per task, and tasks run on a fractional core so their
/// wall time is `1 / core_fraction` times the whole-core duration.
#[derive(Debug)]
pub struct LambdaBackend {
    cfg: LambdaCfg,
    instances: BTreeMap<u64, Instance>,
    next_id: u64,
    total_cost: f64,
    cost_curve: Vec<(SimTime, f64)>,
}

impl LambdaBackend {
    pub fn new(cfg: LambdaCfg) -> Self {
        LambdaBackend {
            cfg,
            instances: BTreeMap::new(),
            next_id: 0,
            total_cost: 0.0,
            cost_curve: vec![(0, 0.0)],
        }
    }

    /// Charge GB-seconds for `busy_s` of wall time (+ per-request fees).
    fn charge(&mut self, now: SimTime, busy_s: f64, requests: usize) {
        let quanta = (busy_s / self.cfg.billing_quantum_s).ceil().max(1.0);
        let gb_s = quanta * self.cfg.billing_quantum_s * self.cfg.memory_gb;
        let charge = gb_s * self.cfg.price_per_gb_s + requests as f64 * self.cfg.price_per_request;
        self.total_cost += charge;
        self.cost_curve.push((now, self.total_cost));
    }
}

impl CloudBackend for LambdaBackend {
    fn name(&self) -> &'static str {
        "lambda"
    }

    fn request_instance_in(&mut self, _pool: usize, now: SimTime) -> Option<(u64, SimTime)> {
        self.next_id += 1;
        let id = self.next_id;
        self.instances.insert(id, Instance::new(id, 0, 1, now));
        Some((id, now + LAMBDA_COLD_START_S))
    }

    fn instance_ready(&mut self, id: u64, now: SimTime) {
        if let Some(inst) = self.instances.get_mut(&id) {
            if inst.state == InstanceState::Booting {
                inst.boot_complete(now);
                inst.billed_until = now; // no pre-billed increment
            }
        }
    }

    fn terminate_instance(&mut self, id: u64, now: SimTime) {
        if let Some(inst) = self.instances.get_mut(&id) {
            if inst.state == InstanceState::Booting {
                inst.state = InstanceState::Terminated;
                inst.terminated_at = Some(now);
            } else {
                inst.terminate(now);
            }
        }
    }

    fn bill_through(&mut self, _now: SimTime) {
        // usage-billed: all cost accrues in on_chunk_finished
    }

    fn next_billing_due(&self, _now: SimTime) -> Option<SimTime> {
        // bill_through never charges: time-based billing is never due
        None
    }

    fn describe(&self, now: SimTime) -> FleetView {
        fleet_view(&self.instances, now)
    }

    fn instance(&self, id: u64) -> Option<&Instance> {
        self.instances.get(&id)
    }

    fn instance_mut(&mut self, id: u64) -> Option<&mut Instance> {
        self.instances.get_mut(&id)
    }

    fn for_each_instance(&self, f: &mut dyn FnMut(&Instance)) {
        for inst in self.instances.values() {
            f(inst);
        }
    }

    fn first_free_slot(&self) -> Option<u64> {
        fleet_first_free(&self.instances)
    }

    fn idle_instances_by_remaining(&self, now: SimTime) -> Vec<u64> {
        fleet_idle_by_remaining(&self.instances, now)
    }

    fn mean_utilization(&self, now: SimTime) -> f64 {
        fleet_mean_utilization(&self.instances, now)
    }

    fn total_cost(&self) -> f64 {
        self.total_cost
    }

    fn cost_curve(&self) -> &[(SimTime, f64)] {
        &self.cost_curve
    }

    fn unit_price(&self, _now: SimTime) -> f64 {
        // GB-second-equivalent hourly rate for one slot
        self.cfg.memory_gb * self.cfg.price_per_gb_s * 3600.0
    }

    fn execution_multiplier(&self) -> f64 {
        1.0 / core_fraction(&self.cfg).max(1e-9)
    }

    fn on_chunk_finished(&mut self, id: u64, chunk: u64, now: SimTime, busy_s: f64, tasks: usize) {
        if let Some(inst) = self.instances.get_mut(&id) {
            inst.finish_chunk(chunk, now, busy_s.ceil() as SimTime);
        }
        self.charge(now, busy_s, tasks);
    }

    fn on_merge_finished(&mut self, id: u64, now: SimTime, merge_s: f64) {
        if let Some(inst) = self.instances.get_mut(&id) {
            inst.finish_chunk(MERGE_CHUNK, now, 0);
        }
        // one aggregation invocation, charged on completion only — a
        // reclaimed merge re-dispatches without double billing
        self.charge(now, merge_s, 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MarketCfg;

    fn lambda() -> LambdaBackend {
        LambdaBackend::new(LambdaCfg::default())
    }

    #[test]
    fn backend_kind_builds_all_three() {
        let cfg = Config::paper_defaults();
        let fleet = FleetSpec::default();
        for (kind, name, reclaimable) in [
            (BackendKind::Spot, "spot", true),
            (BackendKind::OnDemand, "on-demand", false),
            (BackendKind::Lambda, "lambda", false),
        ] {
            let b = kind.build(&cfg, 7, 24, &fleet);
            assert_eq!(b.name(), name);
            assert_eq!(b.reclaimable(), reclaimable);
            assert_eq!(kind.name(), name);
            assert_eq!(b.pool_count(), 1);
            assert_eq!(b.pool_type_idx(0), 0);
            assert_eq!(b.pool_cus(0), 1);
        }
    }

    #[test]
    fn backend_kind_builds_mixed_fleets() {
        let cfg = Config::paper_defaults();
        let fleet = FleetSpec::parse("m3.medium,m4.4xlarge:bid=0.12").unwrap();
        for kind in [BackendKind::Spot, BackendKind::OnDemand] {
            let b = kind.build(&cfg, 7, 24, &fleet);
            assert_eq!(b.pool_count(), 2);
            assert_eq!(b.pool_cus(1), 16);
            assert_eq!(b.pool_bid(1), Some(0.12));
            assert_eq!(b.pool_of_type(4), Some(1));
            assert_eq!(b.pool_of_type(5), None);
        }
        // Lambda has no instance types: the fleet is ignored
        let b = BackendKind::Lambda.build(&cfg, 7, 24, &fleet);
        assert_eq!(b.pool_count(), 1);
    }

    #[test]
    fn lambda_cold_start_and_no_prebilling() {
        let mut b = lambda();
        let (id, ready) = b.request_instance(100);
        assert_eq!(ready, 100 + LAMBDA_COLD_START_S);
        b.instance_ready(id, ready);
        assert_eq!(b.describe(ready).running, 1);
        // no hourly pre-billing: readiness is free
        assert_eq!(b.total_cost(), 0.0);
        b.bill_through(ready + 50_000);
        assert_eq!(b.total_cost(), 0.0);
        assert_eq!(b.describe(ready).c_tot, 0.0);
    }

    #[test]
    fn lambda_has_no_skip_horizon_legs() {
        // usage-billed: time-based billing is never due and prices are
        // flat, so neither leg ever blocks a sparse-tick skip
        let mut b = lambda();
        let (id, ready) = b.request_instance(100);
        b.instance_ready(id, ready);
        assert_eq!(b.next_billing_due(ready), None);
        assert_eq!(b.next_price_change(ready), None);
    }

    #[test]
    fn lambda_charges_per_chunk_with_quantum_roundup() {
        let mut b = lambda();
        let (id, ready) = b.request_instance(0);
        b.instance_ready(id, ready);
        b.instance_mut(id).unwrap().begin_chunk(1);
        // 10.03 s busy -> 10.1 billed seconds at 1 GB + 4 request fees
        b.on_chunk_finished(id, 1, ready + 11, 10.03, 4);
        let cfg = LambdaCfg::default();
        let want = 10.1 * cfg.memory_gb * cfg.price_per_gb_s + 4.0 * cfg.price_per_request;
        assert!((b.total_cost() - want).abs() < 1e-12, "{} vs {want}", b.total_cost());
        assert!(b.instance(id).unwrap().is_idle());
    }

    #[test]
    fn lambda_execution_multiplier_is_inverse_core_fraction() {
        // default config: 1 GB on a 4 GB / 2-core host -> 0.5 core -> 2x
        assert!((lambda().execution_multiplier() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn whole_core_backends_do_not_stretch_execution() {
        let cfg = Config::paper_defaults();
        for kind in [BackendKind::Spot, BackendKind::OnDemand] {
            assert_eq!(kind.build(&cfg, 1, 4, &FleetSpec::default()).execution_multiplier(), 1.0);
        }
    }

    #[test]
    fn revoke_kills_busy_instance_immediately() {
        let mut p = Provider::new(MarketCfg::default(), 1, 4);
        let (id, ready) = CloudBackend::request_instance(&mut p, 0);
        CloudBackend::instance_ready(&mut p, id, ready);
        p.instance_mut(id).unwrap().begin_chunk(9);
        // graceful terminate would only drain; revoke must kill now
        p.revoke_instance(id, ready + 10);
        let inst = CloudBackend::instance(&p, id).unwrap();
        assert_eq!(inst.state, InstanceState::Terminated);
        assert_eq!(inst.terminated_at, Some(ready + 10));
        assert!(inst.chunks.is_empty());
        // idempotent: the original termination instant is preserved
        p.revoke_instance(id, ready + 99);
        assert_eq!(CloudBackend::instance(&p, id).unwrap().terminated_at, Some(ready + 10));
    }

    #[test]
    fn on_demand_prices_flat_and_above_spot() {
        let mcfg = MarketCfg::default();
        let mut od = Provider::new_on_demand(mcfg.clone(), 3, 24);
        let mut sp = Provider::new(mcfg.clone(), 3, 24);
        assert_eq!(CloudBackend::name(&od), "on-demand");
        assert_eq!(CloudBackend::name(&sp), "spot");
        for (p, _) in [(&mut od, 0), (&mut sp, 1)] {
            let (id, ready) = CloudBackend::request_instance(p, 0);
            CloudBackend::instance_ready(p, id, ready);
        }
        // first-hour charge: flat on-demand rate vs the (much cheaper) spot price
        assert!((od.total_cost() - mcfg.on_demand_price).abs() < 1e-12);
        assert!(sp.total_cost() < od.total_cost() / 3.0);
        assert_eq!(od.unit_price(0), mcfg.on_demand_price);
        assert_eq!(od.unit_price(500_000), mcfg.on_demand_price);
    }

    #[test]
    fn lambda_merge_bills_on_completion_only() {
        let mut b = lambda();
        let (id, ready) = b.request_instance(0);
        b.instance_ready(id, ready);
        b.on_merge_dispatched(id, ready, 30.0);
        assert_eq!(b.total_cost(), 0.0, "a dispatched merge must not be charged yet");
        b.on_merge_finished(id, ready + 30, 30.0);
        let cfg = LambdaCfg::default();
        let want = 30.0 * cfg.memory_gb * cfg.price_per_gb_s + cfg.price_per_request;
        assert!((b.total_cost() - want).abs() < 1e-12, "{} vs {want}", b.total_cost());
        assert!(b.instance(id).unwrap().is_idle());
    }

    #[test]
    fn default_merge_hook_marks_instance_busy() {
        let mut p = Provider::new(MarketCfg::default(), 1, 4);
        let (id, ready) = CloudBackend::request_instance(&mut p, 0);
        CloudBackend::instance_ready(&mut p, id, ready);
        p.on_merge_dispatched(id, ready, 40.2);
        let inst = CloudBackend::instance(&p, id).unwrap();
        assert_eq!(inst.chunks, vec![MERGE_CHUNK]);
        assert_eq!(inst.busy_s, 41);
    }
}
