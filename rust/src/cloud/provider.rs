//! IaaS provider facade — the simulator's equivalent of the AWS SDK EC2
//! class the paper names: `requestSpotInstances()`, `terminateInstances()`,
//! `describeInstances()` (§II-C), plus the billing engine.
//!
//! The provider owns all instances and the market; the coordinator only
//! talks to this API, so swapping in a real cloud backend would touch
//! nothing above this layer.
//!
//! Since the heterogeneous-fleet refactor the provider is organized as
//! **per-type pools** ([`crate::cloud::FleetSpec`]): each pool owns one
//! Table V catalogue type, the market's per-type price trace, and an
//! optional spot bid. Requests are placed *by pool*; a spot request
//! whose pool price exceeds its bid is left **unfulfilled** (real EC2
//! keeps it pending — the old simulator fulfilled every request at
//! market price, producing the bid-chasing churn documented in earlier
//! revisions). The degenerate single-pool fleet (bid-less m3.medium)
//! reproduces the pre-fleet provider bit for bit.

use std::collections::BTreeMap;

use crate::cloud::fleet::{FleetSpec, PoolSpec};
use crate::cloud::instance::{Instance, InstanceState};
use crate::cloud::market::{Market, CATALOG};
use crate::config::MarketCfg;
use crate::sim::SimTime;

/// Summary of fleet state, as `describeInstances()` would return — used
/// both for the aggregate fleet and for one pool's slice of it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetView {
    pub booting: usize,
    pub running: usize,
    pub draining: usize,
    pub terminated: usize,
    /// Total active CUs, N_tot[t] (running + draining; booting excluded —
    /// they cannot take work yet but are counted by `committed_cus`).
    pub active_cus: f64,
    /// CUs including booting instances (what scaling decisions see, so a
    /// pending request is not double-fulfilled).
    pub committed_cus: f64,
    /// c_tot[t]: pre-billed compute-unit-seconds still available (eq. 3).
    pub c_tot: f64,
}

/// The cloud provider simulator.
#[derive(Debug)]
pub struct Provider {
    market: Market,
    cfg: MarketCfg,
    /// `Some(rate)` = flat hourly pricing (on-demand) for catalogue type
    /// 0, with larger types at their Table V on-demand rate; `None` =
    /// spot market pricing. Everything else (boot delay, hourly
    /// increments, instance lifecycle) is shared between the two modes.
    flat_rate: Option<f64>,
    /// Per-type pools (distinct catalogue types; see `FleetSpec`).
    pools: Vec<PoolSpec>,
    instances: BTreeMap<u64, Instance>,
    next_id: u64,
    /// Cumulative $ billed across all instances.
    total_cost: f64,
    /// (time, cumulative cost) samples, appended on every billing event.
    cost_curve: Vec<(SimTime, f64)>,
}

impl Provider {
    pub fn new(cfg: MarketCfg, seed: u64, horizon_hours: usize) -> Self {
        Provider::with_fleet(cfg, seed, horizon_hours, &FleetSpec::default())
    }

    /// On-demand variant: identical lifecycle and hourly billing, but at
    /// the flat Table V on-demand rate and never subject to reclamation.
    pub fn new_on_demand(cfg: MarketCfg, seed: u64, horizon_hours: usize) -> Self {
        Provider::with_fleet_on_demand(cfg, seed, horizon_hours, &FleetSpec::default())
    }

    /// Spot provider over an explicit per-type pool set.
    pub fn with_fleet(cfg: MarketCfg, seed: u64, horizon_hours: usize, fleet: &FleetSpec) -> Self {
        fleet.validate().expect("invalid fleet spec");
        Provider {
            market: Market::new(cfg.clone(), seed, horizon_hours),
            cfg,
            flat_rate: None,
            pools: fleet.pools.clone(),
            instances: BTreeMap::new(),
            next_id: 0,
            total_cost: 0.0,
            cost_curve: vec![(0, 0.0)],
        }
    }

    /// On-demand provider over an explicit per-type pool set (bids are
    /// meaningless at a flat rate and ignored).
    pub fn with_fleet_on_demand(
        cfg: MarketCfg,
        seed: u64,
        horizon_hours: usize,
        fleet: &FleetSpec,
    ) -> Self {
        let rate = cfg.on_demand_price;
        Provider { flat_rate: Some(rate), ..Provider::with_fleet(cfg, seed, horizon_hours, fleet) }
    }

    pub fn market(&self) -> &Market {
        &self.market
    }

    /// $/hr for `type_idx` at `t` under this provider's pricing mode.
    fn price_at(&self, type_idx: usize, t: SimTime) -> f64 {
        type_price(self.flat_rate, &self.market, type_idx, t)
    }

    /// requestSpotInstances(): place a spot request for one instance of
    /// catalogue type `type_idx`. Returns (id, ready_at) — the caller
    /// schedules an `InstanceReady` event at `ready_at`.
    pub fn request_spot_instance(&mut self, type_idx: usize, now: SimTime) -> (u64, SimTime) {
        let cus = CATALOG[type_idx].cus;
        self.next_id += 1;
        let id = self.next_id;
        self.instances.insert(id, Instance::new(id, type_idx, cus, now));
        (id, now + self.cfg.boot_delay_s)
    }

    /// Boot completion: the instance becomes Running and its first billing
    /// increment is charged (EC2 bills from launch).
    pub fn instance_ready(&mut self, id: u64, now: SimTime) {
        // billing below needs &self.market while the instance is &mut;
        // snapshot the price function inputs first.
        let (type_idx, state) = {
            let inst = &self.instances[&id];
            (inst.type_idx, inst.state)
        };
        if state != InstanceState::Booting {
            return; // terminated while booting
        }
        let price = self.price_at(type_idx, now);
        let inst = self.instances.get_mut(&id).unwrap();
        inst.boot_complete(now);
        inst.billed_until = now; // first increment starts at readiness
        let billed = inst.bill_through(now, |_| price, self.cfg.billing_increment_s);
        self.total_cost += billed;
        self.cost_curve.push((now, self.total_cost));
    }

    /// terminateInstances(): terminate (or drain) the given instance.
    pub fn terminate_instance(&mut self, id: u64, now: SimTime) {
        if let Some(inst) = self.instances.get_mut(&id) {
            if inst.state == InstanceState::Booting {
                // cancel the spot request before fulfilment: no billing
                inst.state = InstanceState::Terminated;
                inst.terminated_at = Some(now);
            } else {
                inst.terminate(now);
            }
        }
    }

    /// Advance billing for all active instances through `now`.
    /// Must be called at (or before) every monitoring instant.
    pub fn bill_through(&mut self, now: SimTime) {
        let increment = self.cfg.billing_increment_s;
        let mut newly = 0.0;
        // collect ids to avoid holding a borrow over self.market
        let ids: Vec<u64> = self.instances.keys().copied().collect();
        for id in ids {
            let type_idx = self.instances[&id].type_idx;
            let flat = self.flat_rate;
            let market = &self.market;
            let inst = self.instances.get_mut(&id).unwrap();
            if inst.state == InstanceState::Booting || inst.state == InstanceState::Terminated {
                continue;
            }
            newly += inst.bill_through(now, |t| type_price(flat, market, type_idx, t), increment);
        }
        if newly > 0.0 {
            self.total_cost += newly;
            self.cost_curve.push((now, self.total_cost));
        }
    }

    /// describeInstances(): fleet summary at `now`.
    pub fn describe(&self, now: SimTime) -> FleetView {
        crate::cloud::backend::fleet_view(&self.instances, now)
    }

    pub fn instance(&self, id: u64) -> Option<&Instance> {
        self.instances.get(&id)
    }

    pub fn instance_mut(&mut self, id: u64) -> Option<&mut Instance> {
        self.instances.get_mut(&id)
    }

    pub fn instances(&self) -> impl Iterator<Item = &Instance> {
        self.instances.values()
    }

    /// Idle running instances, cheapest-to-keep last: ordered by ascending
    /// remaining billed time (the AIMD termination preference).
    pub fn idle_instances_by_remaining(&self, now: SimTime) -> Vec<u64> {
        crate::cloud::backend::fleet_idle_by_remaining(&self.instances, now)
    }

    /// All running (not draining) instance ids, idle first.
    pub fn running_instances(&self) -> Vec<u64> {
        self.instances
            .values()
            .filter(|i| i.state == InstanceState::Running)
            .map(|i| i.id)
            .collect()
    }

    pub fn total_cost(&self) -> f64 {
        self.total_cost
    }

    pub fn cost_curve(&self) -> &[(SimTime, f64)] {
        &self.cost_curve
    }

    /// Average CPU utilization over running instances (Amazon AS input).
    pub fn mean_utilization(&self, now: SimTime) -> f64 {
        crate::cloud::backend::fleet_mean_utilization(&self.instances, now)
    }

    /// Maximum concurrently active instance count seen across the cost
    /// curve — recomputed live by the platform; provided here for tests.
    pub fn active_count(&self, now: SimTime) -> usize {
        self.instances.values().filter(|i| i.is_active(now)).count()
    }
}

/// $/hr for `type_idx` at `t`. Flat mode (`flat = Some(rate)`) charges
/// the configurable rate for the base type and the Table V on-demand
/// rate for larger ones; spot mode reads the per-type market trace.
/// Free function (not a method) so `Provider::bill_through` can price
/// while an instance is mutably borrowed.
fn type_price(flat: Option<f64>, market: &Market, type_idx: usize, t: SimTime) -> f64 {
    match flat {
        Some(rate) if type_idx == 0 => rate,
        Some(_) => CATALOG[type_idx].on_demand,
        None => market.spot_price(type_idx, t),
    }
}

/// The spot/on-demand [`crate::cloud::CloudBackend`]: platform-facing
/// surface over the inherent `Provider` API, one pool per fleet entry.
/// The default fleet is a single bid-less m3.medium pool — exactly what
/// the pre-fleet loop requested.
impl crate::cloud::CloudBackend for Provider {
    fn name(&self) -> &'static str {
        if self.flat_rate.is_some() {
            "on-demand"
        } else {
            "spot"
        }
    }

    fn reclaimable(&self) -> bool {
        // only spot instances can be reclaimed by the market
        self.flat_rate.is_none()
    }

    fn pool_count(&self) -> usize {
        self.pools.len()
    }

    fn pool_type_idx(&self, pool: usize) -> usize {
        self.pools[pool].type_idx
    }

    fn pool_of_type(&self, type_idx: usize) -> Option<usize> {
        self.pools.iter().position(|p| p.type_idx == type_idx)
    }

    fn pool_bid(&self, pool: usize) -> Option<f64> {
        self.pools[pool].bid
    }

    fn pool_unit_price(&self, pool: usize, now: SimTime) -> f64 {
        self.price_at(self.pools[pool].type_idx, now)
    }

    fn describe_pool(&self, pool: usize, now: SimTime) -> FleetView {
        let ty = self.pools[pool].type_idx;
        let mut v = FleetView::default();
        for inst in self.instances.values().filter(|i| i.type_idx == ty) {
            crate::cloud::backend::fleet_view_add(&mut v, inst, now);
        }
        v
    }

    fn request_instance_in(&mut self, pool: usize, now: SimTime) -> Option<(u64, SimTime)> {
        let spec = &self.pools[pool];
        if self.flat_rate.is_none() {
            if let Some(bid) = spec.bid {
                if self.market.spot_price(spec.type_idx, now) > bid {
                    // real-EC2 semantics: the request stays pending while
                    // the market is above the bid — nothing is booked
                    return None;
                }
            }
        }
        Some(self.request_spot_instance(spec.type_idx, now))
    }

    fn instance_ready(&mut self, id: u64, now: SimTime) {
        Provider::instance_ready(self, id, now)
    }

    fn terminate_instance(&mut self, id: u64, now: SimTime) {
        Provider::terminate_instance(self, id, now)
    }

    fn bill_through(&mut self, now: SimTime) {
        Provider::bill_through(self, now)
    }

    fn next_billing_due(&self, _now: SimTime) -> Option<SimTime> {
        // `Instance::bill_through` charges the moment `now` reaches an
        // instance's `billed_until`, so the earliest such instant over
        // the billable states is exactly when the next charge lands.
        // Booting instances are excluded (billing starts at readiness —
        // an InstanceReady *event*, already part of the skip horizon);
        // terminated ones never bill again.
        self.instances
            .values()
            .filter(|i| matches!(i.state, InstanceState::Running | InstanceState::Draining))
            .map(|i| i.billed_until)
            .min()
    }

    fn next_price_change(&self, now: SimTime) -> Option<SimTime> {
        if self.flat_rate.is_some() {
            None // on-demand: flat rates never move
        } else {
            self.market.next_price_change(now)
        }
    }

    fn describe(&self, now: SimTime) -> FleetView {
        Provider::describe(self, now)
    }

    fn instance(&self, id: u64) -> Option<&Instance> {
        Provider::instance(self, id)
    }

    fn instance_mut(&mut self, id: u64) -> Option<&mut Instance> {
        Provider::instance_mut(self, id)
    }

    fn for_each_instance(&self, f: &mut dyn FnMut(&Instance)) {
        for inst in self.instances.values() {
            f(inst);
        }
    }

    fn first_free_slot(&self) -> Option<u64> {
        crate::cloud::backend::fleet_first_free(&self.instances)
    }

    fn idle_instances_by_remaining(&self, now: SimTime) -> Vec<u64> {
        Provider::idle_instances_by_remaining(self, now)
    }

    fn mean_utilization(&self, now: SimTime) -> f64 {
        Provider::mean_utilization(self, now)
    }

    fn total_cost(&self) -> f64 {
        Provider::total_cost(self)
    }

    fn cost_curve(&self) -> &[(SimTime, f64)] {
        Provider::cost_curve(self)
    }

    fn unit_price(&self, now: SimTime) -> f64 {
        self.price_at(self.pools[0].type_idx, now)
    }

    fn instance_exec_mult(&self, id: u64) -> f64 {
        // Table V per-type execution-time multiplier (PR-9): ECU-denser
        // types finish the same task in less wall time. m3.medium is
        // exactly 1.0, so the default fleet is untouched bitwise.
        self.instances.get(&id).map_or(1.0, |i| CATALOG[i.type_idx].exec_mult)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::CloudBackend;

    fn provider() -> Provider {
        Provider::new(MarketCfg::default(), 1, 24)
    }

    fn mixed() -> Provider {
        // bid-less pools: fulfilment never depends on the seeded trace
        let fleet = FleetSpec::parse("m3.medium,m4.4xlarge").unwrap();
        Provider::with_fleet(MarketCfg::default(), 1, 24, &fleet)
    }

    #[test]
    fn request_boots_after_delay() {
        let mut p = provider();
        let (id, ready) = p.request_spot_instance(0, 100);
        assert_eq!(ready, 100 + MarketCfg::default().boot_delay_s);
        assert_eq!(p.describe(100).booting, 1);
        p.instance_ready(id, ready);
        let v = p.describe(ready);
        assert_eq!(v.running, 1);
        assert_eq!(v.active_cus, 1.0);
        // first hour billed up front
        assert!(p.total_cost() > 0.0);
        assert_eq!(v.c_tot, 3600.0);
    }

    #[test]
    fn cancel_before_boot_costs_nothing() {
        let mut p = provider();
        let (id, ready) = p.request_spot_instance(0, 0);
        p.terminate_instance(id, 10);
        p.instance_ready(id, ready); // late fulfilment is ignored
        assert_eq!(p.total_cost(), 0.0);
        assert_eq!(p.describe(ready).running, 0);
    }

    #[test]
    fn billing_accrues_hourly() {
        let mut p = provider();
        let (id, ready) = p.request_spot_instance(0, 0);
        p.instance_ready(id, ready);
        let c1 = p.total_cost();
        p.bill_through(ready + 3599);
        assert_eq!(p.total_cost(), c1); // still within first hour
        p.bill_through(ready + 3600);
        assert!(p.total_cost() > c1);
        assert_eq!(p.instance(id).unwrap().increments, 2);
    }

    #[test]
    fn cost_curve_is_monotone() {
        let mut p = provider();
        let (a, ra) = p.request_spot_instance(0, 0);
        let (b, rb) = p.request_spot_instance(1, 50);
        p.instance_ready(a, ra);
        p.instance_ready(b, rb);
        for t in (0..20_000).step_by(600) {
            p.bill_through(t);
        }
        let curve = p.cost_curve();
        for w in curve.windows(2) {
            assert!(w[1].0 >= w[0].0 && w[1].1 >= w[0].1 - 1e-12);
        }
    }

    #[test]
    fn idle_ordering_prefers_least_remaining() {
        let mut p = provider();
        let (a, ra) = p.request_spot_instance(0, 0);
        p.instance_ready(a, ra);
        // second instance starts an hour later: more remaining time
        let (b, rb) = p.request_spot_instance(0, 1800);
        p.instance_ready(b, rb);
        let order = p.idle_instances_by_remaining(2000);
        assert_eq!(order, vec![a, b]);
    }

    #[test]
    fn describe_counts_draining_as_active() {
        let mut p = provider();
        let (id, ready) = p.request_spot_instance(0, 0);
        p.instance_ready(id, ready);
        p.instance_mut(id).unwrap().begin_chunk(1);
        p.terminate_instance(id, ready + 10);
        let v = p.describe(ready + 10);
        assert_eq!(v.draining, 1);
        assert_eq!(v.active_cus, 1.0);
    }

    #[test]
    fn mean_utilization_empty_fleet_is_zero() {
        let p = provider();
        assert_eq!(p.mean_utilization(100), 0.0);
    }

    #[test]
    fn next_billing_due_tracks_earliest_billed_until() {
        let mut p = provider();
        assert_eq!(p.next_billing_due(0), None, "empty fleet never bills");
        let (a, ra) = p.request_spot_instance(0, 0);
        // a booting instance does not bill until its ready event fires
        assert_eq!(p.next_billing_due(0), None);
        p.instance_ready(a, ra);
        // first increment charged at readiness: next charge one hour on
        assert_eq!(p.next_billing_due(ra), Some(ra + 3600));
        let (b, rb) = p.request_spot_instance(0, 1800);
        p.instance_ready(b, rb);
        assert_eq!(p.next_billing_due(rb), Some(ra + 3600), "earliest instance wins");
        // soundness: bill_through strictly before the due instant is free
        let c = p.total_cost();
        p.bill_through(ra + 3599);
        assert_eq!(p.total_cost(), c);
        p.bill_through(ra + 3600);
        assert!(p.total_cost() > c);
        // terminating an idle instance removes it from the horizon
        p.terminate_instance(a, ra + 3601);
        assert_eq!(p.next_billing_due(ra + 3601), Some(rb + 3600));
    }

    #[test]
    fn next_price_change_modes() {
        let p = provider(); // spot: hourly boundaries within the trace
        assert_eq!(
            CloudBackend::next_price_change(&p, 100),
            p.market().next_price_change(100)
        );
        assert!(CloudBackend::next_price_change(&p, 100).is_some());
        let od = Provider::new_on_demand(MarketCfg::default(), 1, 24);
        assert_eq!(CloudBackend::next_price_change(&od, 100), None, "flat rates never move");
    }

    #[test]
    fn pools_describe_their_own_types_only() {
        let mut p = mixed();
        let (small, rs) = p.request_instance_in(0, 0).unwrap();
        p.instance_ready(small, rs);
        let (big, rb) = p.request_instance_in(1, 0).unwrap();
        p.instance_ready(big, rb);

        let all = p.describe(rb);
        assert_eq!(all.running, 2);
        assert_eq!(all.active_cus, 17.0, "1 + 16 CUs in aggregate");
        let v0 = p.describe_pool(0, rb);
        let v1 = p.describe_pool(1, rb);
        assert_eq!((v0.running, v0.active_cus), (1, 1.0));
        assert_eq!((v1.running, v1.active_cus), (1, 16.0));
        assert_eq!(p.pool_of_type(4), Some(1));
        assert_eq!(p.pool_of_type(2), None);
        assert_eq!(p.pool_cus(1), 16);
    }

    #[test]
    fn instance_exec_mult_follows_the_catalogue() {
        let mut p = mixed();
        let (small, rs) = p.request_instance_in(0, 0).unwrap();
        p.instance_ready(small, rs);
        let (big, rb) = p.request_instance_in(1, 0).unwrap();
        p.instance_ready(big, rb);
        assert_eq!(p.instance_exec_mult(small).to_bits(), 1.0f64.to_bits());
        assert_eq!(p.instance_exec_mult(big).to_bits(), CATALOG[4].exec_mult.to_bits());
        assert!(p.instance_exec_mult(big) < 1.0, "m4.4xlarge CUs are ECU-denser");
        assert_eq!(p.instance_exec_mult(9999), 1.0, "unknown id defaults to 1.0");
    }

    #[test]
    fn above_bid_spot_requests_stay_unfulfilled() {
        let mcfg = MarketCfg::default();
        // bid below the simulated price floor (0.5 x base): never fulfils
        let fleet = FleetSpec::parse("m3.medium:bid=0.001").unwrap();
        let mut p = Provider::with_fleet(mcfg.clone(), 1, 24, &fleet);
        assert!(p.request_instance_in(0, 0).is_none());
        assert_eq!(p.describe(0).booting, 0, "an unfulfilled request books nothing");
        assert_eq!(p.total_cost(), 0.0);
        // bid above the hard price cap (on-demand x 1.2): always fulfils
        let fleet = FleetSpec::parse("m3.medium:bid=0.1").unwrap();
        let mut p = Provider::with_fleet(mcfg.clone(), 1, 24, &fleet);
        assert!(p.request_instance_in(0, 0).is_some());
        // on-demand ignores bids entirely (flat rate, no spot market)
        let fleet = FleetSpec::parse("m3.medium:bid=0.001").unwrap();
        let mut p = Provider::with_fleet_on_demand(mcfg, 1, 24, &fleet);
        assert!(p.request_instance_in(0, 0).is_some());
    }

    #[test]
    fn flat_mode_prices_large_types_at_catalogue_rate() {
        let fleet = FleetSpec::parse("m3.medium,m4.4xlarge").unwrap();
        let mut p = Provider::with_fleet_on_demand(MarketCfg::default(), 1, 24, &fleet);
        assert_eq!(p.pool_unit_price(0, 0), MarketCfg::default().on_demand_price);
        assert_eq!(p.pool_unit_price(1, 0), CATALOG[4].on_demand);
        let (big, rb) = p.request_instance_in(1, 0).unwrap();
        p.instance_ready(big, rb);
        assert!((p.total_cost() - CATALOG[4].on_demand).abs() < 1e-12);
    }

    #[test]
    fn pool_prices_follow_their_own_traces() {
        let p = mixed();
        assert_eq!(p.pool_unit_price(0, 4000), p.market().spot_price(0, 4000));
        assert_eq!(p.pool_unit_price(1, 4000), p.market().spot_price(4, 4000));
        // the aggregate unit price is pool 0's (the controller's view)
        assert_eq!(p.unit_price(4000), p.pool_unit_price(0, 4000));
    }
}
