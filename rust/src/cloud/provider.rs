//! IaaS provider facade — the simulator's equivalent of the AWS SDK EC2
//! class the paper names: `requestSpotInstances()`, `terminateInstances()`,
//! `describeInstances()` (§II-C), plus the billing engine.
//!
//! The provider owns all instances and the market; the coordinator only
//! talks to this API, so swapping in a real cloud backend would touch
//! nothing above this layer.

use std::collections::BTreeMap;

use crate::cloud::instance::{Instance, InstanceState};
use crate::cloud::market::Market;
use crate::config::MarketCfg;
use crate::sim::SimTime;

/// Summary of fleet state, as `describeInstances()` would return.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetView {
    pub booting: usize,
    pub running: usize,
    pub draining: usize,
    pub terminated: usize,
    /// Total active CUs, N_tot[t] (running + draining; booting excluded —
    /// they cannot take work yet but are counted by `committed_cus`).
    pub active_cus: f64,
    /// CUs including booting instances (what scaling decisions see, so a
    /// pending request is not double-fulfilled).
    pub committed_cus: f64,
    /// c_tot[t]: pre-billed compute-unit-seconds still available (eq. 3).
    pub c_tot: f64,
}

/// The cloud provider simulator.
#[derive(Debug)]
pub struct Provider {
    market: Market,
    cfg: MarketCfg,
    /// `Some(rate)` = flat hourly pricing (on-demand); `None` = spot
    /// market pricing. Everything else (boot delay, hourly increments,
    /// instance lifecycle) is shared between the two modes.
    flat_rate: Option<f64>,
    instances: BTreeMap<u64, Instance>,
    next_id: u64,
    /// Cumulative $ billed across all instances.
    total_cost: f64,
    /// (time, cumulative cost) samples, appended on every billing event.
    cost_curve: Vec<(SimTime, f64)>,
}

impl Provider {
    pub fn new(cfg: MarketCfg, seed: u64, horizon_hours: usize) -> Self {
        Provider {
            market: Market::new(cfg.clone(), seed, horizon_hours),
            cfg,
            flat_rate: None,
            instances: BTreeMap::new(),
            next_id: 0,
            total_cost: 0.0,
            cost_curve: vec![(0, 0.0)],
        }
    }

    /// On-demand variant: identical lifecycle and hourly billing, but at
    /// the flat Table V on-demand rate and never subject to reclamation.
    pub fn new_on_demand(cfg: MarketCfg, seed: u64, horizon_hours: usize) -> Self {
        let rate = cfg.on_demand_price;
        Provider { flat_rate: Some(rate), ..Provider::new(cfg, seed, horizon_hours) }
    }

    pub fn market(&self) -> &Market {
        &self.market
    }

    /// $/hr for `type_idx` at `t` under this provider's pricing mode.
    fn price_at(&self, type_idx: usize, t: SimTime) -> f64 {
        match self.flat_rate {
            Some(rate) => rate,
            None => self.market.spot_price(type_idx, t),
        }
    }

    /// requestSpotInstances(): place a spot request for one instance of
    /// catalogue type `type_idx`. Returns (id, ready_at) — the caller
    /// schedules an `InstanceReady` event at `ready_at`.
    pub fn request_spot_instance(&mut self, type_idx: usize, now: SimTime) -> (u64, SimTime) {
        let cus = crate::cloud::market::CATALOG[type_idx].cus;
        self.next_id += 1;
        let id = self.next_id;
        self.instances.insert(id, Instance::new(id, type_idx, cus, now));
        (id, now + self.cfg.boot_delay_s)
    }

    /// Boot completion: the instance becomes Running and its first billing
    /// increment is charged (EC2 bills from launch).
    pub fn instance_ready(&mut self, id: u64, now: SimTime) {
        // billing below needs &self.market while the instance is &mut;
        // snapshot the price function inputs first.
        let (type_idx, state) = {
            let inst = &self.instances[&id];
            (inst.type_idx, inst.state)
        };
        if state != InstanceState::Booting {
            return; // terminated while booting
        }
        let price = self.price_at(type_idx, now);
        let inst = self.instances.get_mut(&id).unwrap();
        inst.boot_complete(now);
        inst.billed_until = now; // first increment starts at readiness
        let billed = inst.bill_through(now, |_| price, self.cfg.billing_increment_s);
        self.total_cost += billed;
        self.cost_curve.push((now, self.total_cost));
    }

    /// terminateInstances(): terminate (or drain) the given instance.
    pub fn terminate_instance(&mut self, id: u64, now: SimTime) {
        if let Some(inst) = self.instances.get_mut(&id) {
            if inst.state == InstanceState::Booting {
                // cancel the spot request before fulfilment: no billing
                inst.state = InstanceState::Terminated;
                inst.terminated_at = Some(now);
            } else {
                inst.terminate(now);
            }
        }
    }

    /// Advance billing for all active instances through `now`.
    /// Must be called at (or before) every monitoring instant.
    pub fn bill_through(&mut self, now: SimTime) {
        let increment = self.cfg.billing_increment_s;
        let mut newly = 0.0;
        // collect ids to avoid holding a borrow over self.market
        let ids: Vec<u64> = self.instances.keys().copied().collect();
        for id in ids {
            let type_idx = self.instances[&id].type_idx;
            let flat = self.flat_rate;
            let market = &self.market;
            let inst = self.instances.get_mut(&id).unwrap();
            if inst.state == InstanceState::Booting || inst.state == InstanceState::Terminated {
                continue;
            }
            newly += inst.bill_through(
                now,
                |t| match flat {
                    Some(rate) => rate,
                    None => market.spot_price(type_idx, t),
                },
                increment,
            );
        }
        if newly > 0.0 {
            self.total_cost += newly;
            self.cost_curve.push((now, self.total_cost));
        }
    }

    /// describeInstances(): fleet summary at `now`.
    pub fn describe(&self, now: SimTime) -> FleetView {
        crate::cloud::backend::fleet_view(&self.instances, now)
    }

    pub fn instance(&self, id: u64) -> Option<&Instance> {
        self.instances.get(&id)
    }

    pub fn instance_mut(&mut self, id: u64) -> Option<&mut Instance> {
        self.instances.get_mut(&id)
    }

    pub fn instances(&self) -> impl Iterator<Item = &Instance> {
        self.instances.values()
    }

    /// Idle running instances, cheapest-to-keep last: ordered by ascending
    /// remaining billed time (the AIMD termination preference).
    pub fn idle_instances_by_remaining(&self, now: SimTime) -> Vec<u64> {
        crate::cloud::backend::fleet_idle_by_remaining(&self.instances, now)
    }

    /// All running (not draining) instance ids, idle first.
    pub fn running_instances(&self) -> Vec<u64> {
        self.instances
            .values()
            .filter(|i| i.state == InstanceState::Running)
            .map(|i| i.id)
            .collect()
    }

    pub fn total_cost(&self) -> f64 {
        self.total_cost
    }

    pub fn cost_curve(&self) -> &[(SimTime, f64)] {
        &self.cost_curve
    }

    /// Average CPU utilization over running instances (Amazon AS input).
    pub fn mean_utilization(&self, now: SimTime) -> f64 {
        crate::cloud::backend::fleet_mean_utilization(&self.instances, now)
    }

    /// Maximum concurrently active instance count seen across the cost
    /// curve — recomputed live by the platform; provided here for tests.
    pub fn active_count(&self, now: SimTime) -> usize {
        self.instances.values().filter(|i| i.is_active(now)).count()
    }
}

/// The spot/on-demand [`crate::cloud::CloudBackend`]: platform-facing
/// surface over the inherent `Provider` API. Single-CU m3.medium units
/// (catalogue type 0), exactly what the pre-refactor loop requested.
impl crate::cloud::CloudBackend for Provider {
    fn name(&self) -> &'static str {
        if self.flat_rate.is_some() {
            "on-demand"
        } else {
            "spot"
        }
    }

    fn reclaimable(&self) -> bool {
        // only spot instances can be reclaimed by the market
        self.flat_rate.is_none()
    }

    fn request_instance(&mut self, now: SimTime) -> (u64, SimTime) {
        self.request_spot_instance(0, now)
    }

    fn instance_ready(&mut self, id: u64, now: SimTime) {
        Provider::instance_ready(self, id, now)
    }

    fn terminate_instance(&mut self, id: u64, now: SimTime) {
        Provider::terminate_instance(self, id, now)
    }

    fn bill_through(&mut self, now: SimTime) {
        Provider::bill_through(self, now)
    }

    fn describe(&self, now: SimTime) -> FleetView {
        Provider::describe(self, now)
    }

    fn instance(&self, id: u64) -> Option<&Instance> {
        Provider::instance(self, id)
    }

    fn instance_mut(&mut self, id: u64) -> Option<&mut Instance> {
        Provider::instance_mut(self, id)
    }

    fn for_each_instance(&self, f: &mut dyn FnMut(&Instance)) {
        for inst in self.instances.values() {
            f(inst);
        }
    }

    fn first_idle(&self) -> Option<u64> {
        crate::cloud::backend::fleet_first_idle(&self.instances)
    }

    fn idle_instances_by_remaining(&self, now: SimTime) -> Vec<u64> {
        Provider::idle_instances_by_remaining(self, now)
    }

    fn mean_utilization(&self, now: SimTime) -> f64 {
        Provider::mean_utilization(self, now)
    }

    fn total_cost(&self) -> f64 {
        Provider::total_cost(self)
    }

    fn cost_curve(&self) -> &[(SimTime, f64)] {
        Provider::cost_curve(self)
    }

    fn unit_price(&self, now: SimTime) -> f64 {
        self.price_at(0, now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn provider() -> Provider {
        Provider::new(MarketCfg::default(), 1, 24)
    }

    #[test]
    fn request_boots_after_delay() {
        let mut p = provider();
        let (id, ready) = p.request_spot_instance(0, 100);
        assert_eq!(ready, 100 + MarketCfg::default().boot_delay_s);
        assert_eq!(p.describe(100).booting, 1);
        p.instance_ready(id, ready);
        let v = p.describe(ready);
        assert_eq!(v.running, 1);
        assert_eq!(v.active_cus, 1.0);
        // first hour billed up front
        assert!(p.total_cost() > 0.0);
        assert_eq!(v.c_tot, 3600.0);
    }

    #[test]
    fn cancel_before_boot_costs_nothing() {
        let mut p = provider();
        let (id, ready) = p.request_spot_instance(0, 0);
        p.terminate_instance(id, 10);
        p.instance_ready(id, ready); // late fulfilment is ignored
        assert_eq!(p.total_cost(), 0.0);
        assert_eq!(p.describe(ready).running, 0);
    }

    #[test]
    fn billing_accrues_hourly() {
        let mut p = provider();
        let (id, ready) = p.request_spot_instance(0, 0);
        p.instance_ready(id, ready);
        let c1 = p.total_cost();
        p.bill_through(ready + 3599);
        assert_eq!(p.total_cost(), c1); // still within first hour
        p.bill_through(ready + 3600);
        assert!(p.total_cost() > c1);
        assert_eq!(p.instance(id).unwrap().increments, 2);
    }

    #[test]
    fn cost_curve_is_monotone() {
        let mut p = provider();
        let (a, ra) = p.request_spot_instance(0, 0);
        let (b, rb) = p.request_spot_instance(1, 50);
        p.instance_ready(a, ra);
        p.instance_ready(b, rb);
        for t in (0..20_000).step_by(600) {
            p.bill_through(t);
        }
        let curve = p.cost_curve();
        for w in curve.windows(2) {
            assert!(w[1].0 >= w[0].0 && w[1].1 >= w[0].1 - 1e-12);
        }
    }

    #[test]
    fn idle_ordering_prefers_least_remaining() {
        let mut p = provider();
        let (a, ra) = p.request_spot_instance(0, 0);
        p.instance_ready(a, ra);
        // second instance starts an hour later: more remaining time
        let (b, rb) = p.request_spot_instance(0, 1800);
        p.instance_ready(b, rb);
        let order = p.idle_instances_by_remaining(2000);
        assert_eq!(order, vec![a, b]);
    }

    #[test]
    fn describe_counts_draining_as_active() {
        let mut p = provider();
        let (id, ready) = p.request_spot_instance(0, 0);
        p.instance_ready(id, ready);
        p.instance_mut(id).unwrap().current_chunk = Some(1);
        p.terminate_instance(id, ready + 10);
        let v = p.describe(ready + 10);
        assert_eq!(v.draining, 1);
        assert_eq!(v.active_cus, 1.0);
    }

    #[test]
    fn mean_utilization_empty_fleet_is_zero() {
        let p = provider();
        assert_eq!(p.mean_utilization(100), 0.0);
    }
}
