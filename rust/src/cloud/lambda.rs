//! AWS Lambda pricing + execution model (§V-D / Table IV).
//!
//! The paper's account of why Lambda loses on heavy tasks:
//! Lambda allocates `memory_gb / host_memory_gb × host_cores` fractional
//! cores, so a task whose full-core duration is `d` runs for
//! `d / core_fraction` wall seconds, billed per 100 ms GB-second plus a
//! per-request fee. Dithen always gives a task a whole core.

use crate::config::LambdaCfg;

/// Cost + duration of executing one task on Lambda.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LambdaExec {
    /// Wall-clock duration on the fractional core, seconds.
    pub duration_s: f64,
    /// Billed duration after the 100 ms quantum round-up, seconds.
    pub billed_s: f64,
    /// Total $ cost (GB-seconds + request fee).
    pub cost: f64,
}

/// Fraction of one core a function of `memory_gb` receives.
pub fn core_fraction(cfg: &LambdaCfg) -> f64 {
    ((cfg.memory_gb / cfg.host_memory_gb) * cfg.host_cores).min(1.0)
}

/// Price one task whose *full-core* compute time is `full_core_s` seconds.
pub fn price_task(cfg: &LambdaCfg, full_core_s: f64) -> LambdaExec {
    let frac = core_fraction(cfg).max(1e-9);
    let duration_s = full_core_s / frac;
    let quanta = (duration_s / cfg.billing_quantum_s).ceil().max(1.0);
    let billed_s = quanta * cfg.billing_quantum_s;
    let cost = billed_s * cfg.memory_gb * cfg.price_per_gb_s + cfg.price_per_request;
    LambdaExec { duration_s, billed_s, cost }
}

/// Price a batch of tasks; returns (total cost, mean cost per task).
pub fn price_batch(cfg: &LambdaCfg, full_core_secs: &[f64]) -> (f64, f64) {
    let total: f64 = full_core_secs.iter().map(|&s| price_task(cfg, s).cost).sum();
    let mean = if full_core_secs.is_empty() { 0.0 } else { total / full_core_secs.len() as f64 };
    (total, mean)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LambdaCfg {
        LambdaCfg::default()
    }

    #[test]
    fn paper_core_fraction_example() {
        // §V-D: 1 GB function on a 4 GB / 2-core host -> 1/4 x 2 = 0.5 core.
        assert!((core_fraction(&cfg()) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fractional_core_prolongs_execution() {
        let e = price_task(&cfg(), 2.0);
        assert!((e.duration_s - 4.0).abs() < 1e-9); // 2 s / 0.5 core
    }

    #[test]
    fn rounds_up_to_100ms() {
        let e = price_task(&cfg(), 0.011); // 22 ms wall -> 100 ms billed
        assert!((e.billed_s - 0.1).abs() < 1e-12);
        let e = price_task(&cfg(), 0.06); // 120 ms wall -> 200 ms billed
        assert!((e.billed_s - 0.2).abs() < 1e-12);
    }

    #[test]
    fn cost_grows_linearly_in_duration() {
        let a = price_task(&cfg(), 1.0);
        let b = price_task(&cfg(), 2.0);
        let marginal = b.cost - a.cost;
        // one extra full-core second = 2 billed seconds at 1 GB
        assert!((marginal - 2.0 * cfg().price_per_gb_s).abs() < 1e-9);
    }

    #[test]
    fn batch_mean_matches_manual() {
        let (total, mean) = price_batch(&cfg(), &[1.0, 2.0, 3.0]);
        let manual: f64 = [1.0, 2.0, 3.0].iter().map(|&s| price_task(&cfg(), s).cost).sum();
        assert!((total - manual).abs() < 1e-12);
        assert!((mean - manual / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_batch_is_free() {
        assert_eq!(price_batch(&cfg(), &[]), (0.0, 0.0));
    }

    #[test]
    fn heavier_memory_gets_more_core() {
        let mut c = cfg();
        c.memory_gb = 2.0;
        assert!((core_fraction(&c) - 1.0).abs() < 1e-12); // capped at 1 core
    }
}
