//! Cloud substrate: EC2 spot-market + instance + billing simulator, the
//! Lambda pricing model, and the [`CloudBackend`] trait that lets the
//! platform run the same scheduling loop over spot, on-demand, or
//! Lambda-style substrates. See DESIGN.md §2 for the substitution
//! rationale (paper ran on live AWS; repro band 0 ⇒ simulate).

pub mod backend;
pub mod fleet;
pub mod instance;
pub mod lambda;
pub mod market;
pub mod provider;

pub use backend::{BackendKind, CloudBackend, LambdaBackend, MERGE_CHUNK};
pub use fleet::{FleetSpec, PoolSpec};
pub use instance::{Instance, InstanceState};
pub use market::{instance_type, InstanceType, Market, CATALOG};
pub use provider::{FleetView, Provider};
