//! Cloud substrate: EC2 spot-market + instance + billing simulator, and
//! the Lambda pricing model. See DESIGN.md §2 for the substitution
//! rationale (paper ran on live AWS; repro band 0 ⇒ simulate).

pub mod instance;
pub mod lambda;
pub mod market;
pub mod provider;

pub use instance::{Instance, InstanceState};
pub use market::{instance_type, InstanceType, Market, CATALOG};
pub use provider::{FleetView, Provider};
