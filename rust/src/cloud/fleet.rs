//! Heterogeneous fleet description: per-type instance pools.
//!
//! A [`FleetSpec`] names which Table V catalogue types a scenario may
//! provision and, optionally, a per-pool spot **bid**. The pool-aware
//! [`crate::cloud::CloudBackend`] surface turns each entry into one
//! *pool*: the pool owns its catalogue type, its own price trace (the
//! per-type trace the [`crate::cloud::Market`] already simulates), its
//! own bid, and its own boot/billing bookkeeping, while the aggregate
//! `describe()` view the controller reads stays unchanged.
//!
//! Bid semantics (real-EC2, §II-C):
//!
//! * **fulfilment** — a spot request placed while the pool's market
//!   price exceeds its bid stays *pending* (the request is simply not
//!   fulfilled; the scaling loop retries at later instants). Pools
//!   without a bid are always fulfilled at market price.
//! * **revocation** — a market-driven fault model revokes a pool when
//!   its price crosses the pool's bid (see
//!   [`crate::platform::FaultSpec::PoolReclamation`]); other pools keep
//!   working — a *partial* revocation.
//!
//! The default fleet is the degenerate single pool — one `m3.medium`
//! (1 CU) pool with no bid — which reproduces the pre-fleet platform
//! bit for bit (`platform::tests` pins this).
//!
//! Since PR-9 the catalogue also carries a per-type **execution-time
//! multiplier** (`InstanceType::exec_mult`, normalized per-CU ECU
//! density): work dispatched onto an ECU-denser type finishes faster,
//! so a mixed fleet's service rates differ by type — not just CU count.
//! `m3.medium` is exactly 1.0, keeping the default fleet bitwise
//! unchanged.
//!
//! CLI grammar (`dithen scenario --fleet …`):
//!
//! ```text
//! m3.medium,m4.4xlarge                 two pools, no bids
//! m3.medium:bid=0.0085,m4.4xlarge:bid=0.12
//! ```

use crate::cloud::market::CATALOG;

/// One per-type pool: a catalogue type plus an optional spot bid.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolSpec {
    /// Index into [`CATALOG`].
    pub type_idx: usize,
    /// Spot bid, $/hr. `None` = bid-less (always fulfilled, only
    /// revocable by a scripted schedule or a global fault bid).
    pub bid: Option<f64>,
}

impl PoolSpec {
    pub fn name(&self) -> &'static str {
        CATALOG[self.type_idx].name
    }

    pub fn cus(&self) -> u32 {
        CATALOG[self.type_idx].cus
    }
}

/// A scenario's fleet: one pool per catalogue type (types must be
/// distinct — the pool *is* the type's launch group).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub pools: Vec<PoolSpec>,
}

impl Default for FleetSpec {
    /// The degenerate single-pool fleet: one bid-less m3.medium pool —
    /// exactly the pre-fleet platform.
    fn default() -> Self {
        FleetSpec { pools: vec![PoolSpec { type_idx: 0, bid: None }] }
    }
}

impl FleetSpec {
    /// A homogeneous single-type fleet.
    pub fn homogeneous(type_idx: usize, bid: Option<f64>) -> Self {
        FleetSpec { pools: vec![PoolSpec { type_idx, bid }] }
    }

    /// Parse the CLI grammar: comma-separated `type[:bid=$/hr]` entries
    /// with Table V type names.
    pub fn parse(s: &str) -> Result<FleetSpec, String> {
        let mut pools = vec![];
        for entry in s.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                return Err(format!("empty fleet entry in '{s}'"));
            }
            let (name, bid) = match entry.split_once(':') {
                None => (entry, None),
                Some((name, attr)) => {
                    let raw = match attr.strip_prefix("bid=") {
                        Some(raw) => raw,
                        None => {
                            return Err(format!("bad fleet attribute '{attr}' (want bid=<$/hr>)"))
                        }
                    };
                    let bid: f64 = raw.parse().map_err(|_| format!("bad fleet bid '{raw}'"))?;
                    if bid.is_nan() || bid <= 0.0 {
                        return Err(format!("fleet bid '{raw}' must be a positive $/hr price"));
                    }
                    (name, Some(bid))
                }
            };
            let type_idx = CATALOG
                .iter()
                .position(|t| t.name == name)
                .ok_or_else(|| format!("unknown instance type '{name}' (Table V names)"))?;
            pools.push(PoolSpec { type_idx, bid });
        }
        let fleet = FleetSpec { pools };
        fleet.validate()?;
        Ok(fleet)
    }

    /// Structural checks: non-empty, valid catalogue indices, distinct
    /// types (a pool is its type's launch group).
    pub fn validate(&self) -> Result<(), String> {
        if self.pools.is_empty() {
            return Err("fleet needs at least one pool".into());
        }
        for (i, p) in self.pools.iter().enumerate() {
            if p.type_idx >= CATALOG.len() {
                return Err(format!("pool {i}: type index {} out of catalogue", p.type_idx));
            }
            if self.pools[..i].iter().any(|q| q.type_idx == p.type_idx) {
                return Err(format!("duplicate pool type '{}'", p.name()));
            }
        }
        Ok(())
    }

    /// Fill in missing bids from a global default (the scenario-level
    /// `SpotReclamation { bid }` fallback): a pool's own bid always
    /// wins. The default is quoted for the base type (m3.medium) and
    /// scaled to each pool by the catalogue base-price ratio — a
    /// sensible $0.0085 bid for a 1-CU type would otherwise sit below a
    /// 40-CU type's price *floor* and permanently starve that pool.
    /// The base type itself keeps the bid verbatim (single-pool parity).
    pub fn with_default_bid(&self, default: Option<f64>) -> FleetSpec {
        FleetSpec {
            pools: self
                .pools
                .iter()
                .map(|p| {
                    let scale = CATALOG[p.type_idx].spot_base / CATALOG[0].spot_base;
                    let scaled = default.map(|b| b * scale);
                    PoolSpec { type_idx: p.type_idx, bid: p.bid.or(scaled) }
                })
                .collect(),
        }
    }

    /// Compact human label (CLI headers, sweep labels).
    pub fn describe(&self) -> String {
        self.pools
            .iter()
            .map(|p| match p.bid {
                Some(b) => format!("{}:bid={b}", p.name()),
                None => p.name().to_string(),
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_single_bidless_m3_medium() {
        let f = FleetSpec::default();
        assert_eq!(f.pools.len(), 1);
        assert_eq!(f.pools[0].type_idx, 0);
        assert_eq!(f.pools[0].bid, None);
        assert_eq!(f.pools[0].name(), "m3.medium");
        assert_eq!(f.pools[0].cus(), 1);
        f.validate().unwrap();
    }

    #[test]
    fn parses_types_and_bids() {
        let f = FleetSpec::parse("m3.medium:bid=0.0085, m4.4xlarge:bid=0.12,m4.10xlarge").unwrap();
        assert_eq!(f.pools.len(), 3);
        assert_eq!(f.pools[0].name(), "m3.medium");
        assert_eq!(f.pools[0].bid, Some(0.0085));
        assert_eq!(f.pools[1].name(), "m4.4xlarge");
        assert_eq!(f.pools[1].cus(), 16);
        assert_eq!(f.pools[2].bid, None);
        assert_eq!(f.describe(), "m3.medium:bid=0.0085,m4.4xlarge:bid=0.12,m4.10xlarge");
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(FleetSpec::parse("").is_err());
        assert!(FleetSpec::parse("c9.mega").is_err());
        assert!(FleetSpec::parse("m3.medium,").is_err());
        assert!(FleetSpec::parse("m3.medium:bid=").is_err());
        assert!(FleetSpec::parse("m3.medium:bid=-1").is_err());
        assert!(FleetSpec::parse("m3.medium:bid=nan").is_err());
        assert!(FleetSpec::parse("m3.medium:price=1").is_err());
        assert!(FleetSpec::parse("m3.medium,m3.medium").is_err(), "duplicate types rejected");
    }

    #[test]
    fn default_bid_fills_only_missing_scaled_by_base_price() {
        let f = FleetSpec::parse("m3.medium:bid=0.01,m3.xlarge").unwrap();
        let g = f.with_default_bid(Some(0.5));
        assert_eq!(g.pools[0].bid, Some(0.01), "explicit pool bid wins");
        // the fallback is quoted for m3.medium and scaled per type
        let want = 0.5 * CATALOG[2].spot_base / CATALOG[0].spot_base;
        assert_eq!(g.pools[1].bid, Some(want));
        let h = f.with_default_bid(None);
        assert_eq!(h, f);
        // the base type keeps the fallback verbatim (single-pool parity)
        let base = FleetSpec::default().with_default_bid(Some(0.0085));
        assert_eq!(base.pools[0].bid, Some(0.0085));
    }
}
