//! PJRT runtime — loads the AOT-compiled monitor_step artifacts and
//! executes them on the L3 hot path.
//!
//! `Engine` wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! One compiled executable per (W, K) bank-shape variant; variants are
//! discovered through `artifacts/manifest.json` (written by
//! `python/compile/aot.py`).
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits protos with 64-bit ids the
//! bundled xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json;

/// Input/output layout of the monitor_step artifact (must match
/// python/compile/model.py).
pub const N_PARAMS: usize = 8;

/// One (W, K) variant entry from the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Variant {
    pub w: usize,
    pub k: usize,
    pub file: String,
}

/// Parsed artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub variants: Vec<Variant>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let body = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = json::parse(&body).map_err(|e| anyhow!("{e}"))?;
        if doc.get("format").and_then(|f| f.as_str()) != Some("hlo-text") {
            bail!("manifest format is not hlo-text");
        }
        let variants = doc
            .get("variants")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing variants"))?
            .iter()
            .map(|v| -> Result<Variant> {
                Ok(Variant {
                    w: v.get("w").and_then(|x| x.as_usize()).ok_or_else(|| anyhow!("variant missing w"))?,
                    k: v.get("k").and_then(|x| x.as_usize()).ok_or_else(|| anyhow!("variant missing k"))?,
                    file: v
                        .get("file")
                        .and_then(|x| x.as_str())
                        .ok_or_else(|| anyhow!("variant missing file"))?
                        .to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if variants.is_empty() {
            bail!("manifest has no variants");
        }
        Ok(Manifest { variants, dir: dir.to_path_buf() })
    }

    /// Smallest variant with w >= needed_w and k >= needed_k.
    pub fn pick(&self, needed_w: usize, needed_k: usize) -> Option<&Variant> {
        self.variants
            .iter()
            .filter(|v| v.w >= needed_w && v.k >= needed_k)
            .min_by_key(|v| v.w * v.k)
    }
}

/// Inputs to one monitor_step execution (row-major [W, K] matrices).
#[derive(Debug, Clone)]
pub struct StepInputs<'a> {
    pub b_hat: &'a [f32],
    pub pi: &'a [f32],
    pub b_tilde: &'a [f32],
    pub meas_mask: &'a [f32],
    pub m_rem: &'a [f32],
    pub slot_mask: &'a [f32],
    pub d: &'a [f32],
    /// [sigma_z2, sigma_v2, n_tot, alpha, beta, n_min, n_max, n_w_max]
    pub params: [f32; N_PARAMS],
}

/// Outputs of one monitor_step execution. `Default` gives empty
/// buffers that [`crate::estimation::Bank::step_into`] sizes on first
/// use and then refills in place, tick after tick.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StepOutputs {
    pub b_hat: Vec<f32>,
    pub pi: Vec<f32>,
    pub r: Vec<f32>,
    pub s: Vec<f32>,
    pub n_star: f32,
    pub n_next: f32,
}

/// A compiled monitor_step executable for one (W, K) shape.
pub struct Executable {
    pub w: usize,
    pub k: usize,
    exe: xla::PjRtLoadedExecutable,
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable").field("w", &self.w).field("k", &self.k).finish()
    }
}

impl Executable {
    /// Execute one monitoring step. Inputs must be exactly (w*k)-sized
    /// matrices / w-sized vector, padded by the caller.
    pub fn run(&self, inp: &StepInputs) -> Result<StepOutputs> {
        let (w, k) = (self.w, self.k);
        let wk = w * k;
        for (name, buf) in [
            ("b_hat", inp.b_hat),
            ("pi", inp.pi),
            ("b_tilde", inp.b_tilde),
            ("meas_mask", inp.meas_mask),
            ("m_rem", inp.m_rem),
            ("slot_mask", inp.slot_mask),
        ] {
            if buf.len() != wk {
                bail!("{name} has {} elements, want {wk}", buf.len());
            }
        }
        if inp.d.len() != w {
            bail!("d has {} elements, want {w}", inp.d.len());
        }
        // build literals straight from the raw bytes: vec1().reshape()
        // would materialize each argument twice (perf pass, §Perf)
        let as_bytes = |v: &[f32]| -> &[u8] {
            // f32 slices reinterpret safely as bytes (align 4 -> 1)
            unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, 4 * v.len()) }
        };
        let lit = |v: &[f32], dims: &[usize]| -> Result<xla::Literal> {
            Ok(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                dims,
                as_bytes(v),
            )?)
        };
        let args = [
            lit(inp.b_hat, &[w, k])?,
            lit(inp.pi, &[w, k])?,
            lit(inp.b_tilde, &[w, k])?,
            lit(inp.meas_mask, &[w, k])?,
            lit(inp.m_rem, &[w, k])?,
            lit(inp.slot_mask, &[w, k])?,
            lit(inp.d, &[w])?,
            lit(&inp.params, &[N_PARAMS])?,
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 6 {
            bail!("expected 6-tuple output, got {}", parts.len());
        }
        let mut it = parts.into_iter();
        let mut next = |_: &str| it.next().unwrap();
        Ok(StepOutputs {
            b_hat: next("b_hat").to_vec::<f32>()?,
            pi: next("pi").to_vec::<f32>()?,
            r: next("r").to_vec::<f32>()?,
            s: next("s").to_vec::<f32>()?,
            n_star: next("n_star").to_vec::<f32>()?[0],
            n_next: next("n_next").to_vec::<f32>()?[0],
        })
    }
}

/// The PJRT engine: client + compiled executables, keyed by (W, K).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: BTreeMap<(usize, usize), Executable>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.client.platform_name())
            .field("variants", &self.manifest.variants)
            .field("compiled", &self.compiled.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl Engine {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest, compiled: BTreeMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The already-compiled executable covering (needed_w, needed_k),
    /// if any — the read-only fast path shared-engine banks take so
    /// concurrent executions need no exclusive lock (see
    /// [`crate::estimation::bank::SharedEngine`]).
    pub fn compiled(&self, needed_w: usize, needed_k: usize) -> Option<&Executable> {
        let v = self.manifest.pick(needed_w, needed_k)?;
        self.compiled.get(&(v.w, v.k))
    }

    /// Get (compiling on first use) the smallest executable covering
    /// (needed_w, needed_k).
    pub fn executable(&mut self, needed_w: usize, needed_k: usize) -> Result<&Executable> {
        let variant = self
            .manifest
            .pick(needed_w, needed_k)
            .ok_or_else(|| {
                anyhow!("no artifact variant covers W={needed_w} K={needed_k}; re-run `make artifacts` with a larger variant")
            })?
            .clone();
        let key = (variant.w, variant.k);
        if !self.compiled.contains_key(&key) {
            let path = self.manifest.dir.join(&variant.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.compiled
                .insert(key, Executable { w: variant.w, k: variant.k, exe });
        }
        Ok(&self.compiled[&key])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the crate root
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_loads_and_picks() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert!(!m.variants.is_empty());
        let v = m.pick(8, 2).unwrap();
        assert!(v.w >= 8 && v.k >= 2);
        // smallest covering variant is chosen
        let tiny = m.pick(1, 1).unwrap();
        assert_eq!((tiny.w, tiny.k), (8, 2));
        assert!(m.pick(100_000, 1).is_none());
    }

    #[test]
    fn engine_runs_monitor_step_against_native_reference() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut eng = Engine::load(&artifacts_dir()).unwrap();
        let exe = eng.executable(8, 2).unwrap();
        let (w, k) = (exe.w, exe.k);
        let wk = w * k;

        // one active slot with one measurement; rest masked
        let mut b_hat = vec![0.0f32; wk];
        let pi = vec![0.0f32; wk];
        let mut b_tilde = vec![0.0f32; wk];
        let mut meas = vec![0.0f32; wk];
        let mut m_rem = vec![0.0f32; wk];
        let mut slot = vec![0.0f32; wk];
        let mut d = vec![0.0f32; w];
        b_hat[0] = 0.0;
        b_tilde[0] = 10.0;
        meas[0] = 1.0;
        m_rem[0] = 100.0;
        slot[0] = 1.0;
        d[0] = 1000.0;
        let params = [0.5, 0.5, 10.0, 5.0, 0.9, 10.0, 100.0, 10.0];
        let out = exe
            .run(&StepInputs {
                b_hat: &b_hat,
                pi: &pi,
                b_tilde: &b_tilde,
                meas_mask: &meas,
                m_rem: &m_rem,
                slot_mask: &slot,
                d: &d,
                params,
            })
            .unwrap();
        // Kalman: pi_minus=0.5, kappa=0.5 -> b = 0 + 0.5*10 = 5
        assert!((out.b_hat[0] - 5.0).abs() < 1e-5, "b={}", out.b_hat[0]);
        assert!((out.pi[0] - 0.25).abs() < 1e-5);
        // r = 100 * 5 = 500; s* = 500/1000 = 0.5 -> below beta*n_tot=9 so
        // upscaled to 9 (eq. 14): s = 0.5 * (9/0.5) = 9
        assert!((out.r[0] - 500.0).abs() < 1e-2);
        assert!((out.n_star - 0.5).abs() < 1e-4);
        assert!((out.s[0] - 9.0).abs() < 1e-3, "s={}", out.s[0]);
        // AIMD: n_tot=10 > n_star=0.5 -> decrease: max(0.9*10, 10) = 10
        assert!((out.n_next - 10.0).abs() < 1e-5);
        // inactive slots untouched
        assert!(out.b_hat[1..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn engine_rejects_wrong_sizes() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut eng = Engine::load(&artifacts_dir()).unwrap();
        let exe = eng.executable(8, 2).unwrap();
        let bad = vec![0.0f32; 3];
        let ok = vec![0.0f32; exe.w * exe.k];
        let d = vec![0.0f32; exe.w];
        let r = exe.run(&StepInputs {
            b_hat: &bad,
            pi: &ok,
            b_tilde: &ok,
            meas_mask: &ok,
            m_rem: &ok,
            slot_mask: &ok,
            d: &d,
            params: [0.5, 0.5, 10.0, 5.0, 0.9, 10.0, 100.0, 10.0],
        });
        assert!(r.is_err());
    }
}
