//! The pre-arena task store: `BTreeMap` rows + per-status `BTreeSet`
//! indexes (the seed implementation, verbatim semantics).
//!
//! Kept for two purposes:
//!  * the measured **baseline** of the flat-arena refactor —
//!    `dithen bench-report` and `benches/bench_substrates.rs` time the
//!    same task lifecycle against both stores;
//!  * a semantic **oracle** — the parity test in [`super`] drives both
//!    stores through random operation sequences and asserts identical
//!    observable state.
//!
//! Not used on any platform code path.

use std::collections::{BTreeMap, BTreeSet};

use crate::sim::SimTime;

use super::{TaskKey, TaskRow, TaskStatus};

fn status_tag(s: TaskStatus) -> u8 {
    match s {
        TaskStatus::Pending => 0,
        TaskStatus::Processing => 1,
        TaskStatus::Completed => 2,
        TaskStatus::Failed => 3,
    }
}

/// The seed `TaskDb`: O(log n) ops, sorted-set status indexes, and
/// allocating, whole-table-scan measurement queries.
#[derive(Debug, Default)]
pub struct LegacyTaskDb {
    rows: BTreeMap<TaskKey, TaskRow>,
    by_status: BTreeMap<(usize, u8), BTreeSet<usize>>, // (workload, status) -> task ids
    remaining: BTreeMap<(usize, usize), u64>,
}

impl LegacyTaskDb {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, workload: usize, media_type: usize, task: usize) {
        let row = TaskRow {
            workload,
            media_type,
            task,
            status: TaskStatus::Pending,
            instance: None,
            measured_cus: None,
            completed_at: None,
            exit_code: 0,
        };
        let prev = self.rows.insert((workload, task), row);
        assert!(prev.is_none(), "task ({workload},{task}) inserted twice");
        self.by_status
            .entry((workload, status_tag(TaskStatus::Pending)))
            .or_default()
            .insert(task);
        *self.remaining.entry((workload, media_type)).or_default() += 1;
    }

    fn move_status(&mut self, key: TaskKey, to: TaskStatus) {
        let row = self.rows.get_mut(&key).expect("unknown task");
        let from = row.status;
        row.status = to;
        if let Some(s) = self.by_status.get_mut(&(key.0, status_tag(from))) {
            s.remove(&key.1);
        }
        self.by_status
            .entry((key.0, status_tag(to)))
            .or_default()
            .insert(key.1);
    }

    pub fn claim(&mut self, key: TaskKey, instance: u64) {
        {
            let row = self.rows.get(&key).expect("unknown task");
            assert_eq!(row.status, TaskStatus::Pending, "claiming non-pending task {key:?}");
        }
        self.move_status(key, TaskStatus::Processing);
        self.rows.get_mut(&key).unwrap().instance = Some(instance);
    }

    pub fn complete(&mut self, key: TaskKey, cus: f64, at: SimTime, exit_code: i32) {
        {
            let row = self.rows.get(&key).expect("unknown task");
            assert_eq!(row.status, TaskStatus::Processing, "completing unclaimed task {key:?}");
        }
        let to = if exit_code == 0 { TaskStatus::Completed } else { TaskStatus::Failed };
        self.move_status(key, to);
        let row = self.rows.get_mut(&key).unwrap();
        row.measured_cus = Some(cus);
        row.completed_at = Some(at);
        row.exit_code = exit_code;
        if to == TaskStatus::Completed {
            let media_type = row.media_type;
            let c = self
                .remaining
                .get_mut(&(key.0, media_type))
                .expect("remaining counter missing");
            *c -= 1;
        }
    }

    pub fn requeue(&mut self, key: TaskKey) {
        {
            let row = self.rows.get(&key).expect("unknown task");
            assert_eq!(row.status, TaskStatus::Processing);
        }
        self.move_status(key, TaskStatus::Pending);
        self.rows.get_mut(&key).unwrap().instance = None;
    }

    pub fn get(&self, key: TaskKey) -> Option<&TaskRow> {
        self.rows.get(&key)
    }

    pub fn tasks_with_status(&self, workload: usize, status: TaskStatus) -> Vec<usize> {
        self.by_status
            .get(&(workload, status_tag(status)))
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }

    pub fn first_with_status(&self, workload: usize, status: TaskStatus, n: usize) -> Vec<usize> {
        self.by_status
            .get(&(workload, status_tag(status)))
            .map(|s| s.iter().take(n).copied().collect())
            .unwrap_or_default()
    }

    pub fn count_status(&self, workload: usize, status: TaskStatus) -> usize {
        self.by_status
            .get(&(workload, status_tag(status)))
            .map(|s| s.len())
            .unwrap_or(0)
    }

    pub fn remaining_by_type(&self, workload: usize, n_types: usize) -> Vec<f64> {
        (0..n_types)
            .map(|k| self.remaining.get(&(workload, k)).copied().unwrap_or(0) as f64)
            .collect()
    }

    pub fn measurements_between(
        &self,
        workload: usize,
        media_type: usize,
        since: SimTime,
        until: SimTime,
    ) -> Vec<f64> {
        self.rows
            .values()
            .filter(|r| {
                r.workload == workload
                    && r.media_type == media_type
                    && r.status == TaskStatus::Completed
                    && r.completed_at.map(|t| t > since && t <= until).unwrap_or(false)
            })
            .map(|r| r.measured_cus.unwrap())
            .collect()
    }

    pub fn all_measurements(&self, workload: usize, media_type: usize) -> Vec<f64> {
        self.rows
            .values()
            .filter(|r| {
                r.workload == workload
                    && r.media_type == media_type
                    && r.status == TaskStatus::Completed
            })
            .map(|r| r.measured_cus.unwrap())
            .collect()
    }

    pub fn workload_complete(&self, workload: usize) -> bool {
        self.count_status(workload, TaskStatus::Pending) == 0
            && self.count_status(workload, TaskStatus::Processing) == 0
            && (self.count_status(workload, TaskStatus::Completed)
                + self.count_status(workload, TaskStatus::Failed))
                > 0
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}
