//! One task-DB shard: the complete store for a single workload.
//!
//! PR-4 made the per-workload arena of the flat-arena refactor a
//! first-class, independently-ownable type. A [`Shard`] carries its own
//! rows, intrusive status lists, `m_{w,k}` counters and time-ordered
//! measurement logs — *nothing* is shared between shards, so
//!
//! * a multi-platform process can hand each workload's shard to a
//!   different platform instance (or thread) with no synchronization:
//!   `Shard` is plain data (`Send`), and [`super::TaskDb::into_shards`] /
//!   [`super::TaskDb::from_shards`] move shards out of and back into the
//!   facade losslessly;
//! * the GCI tick's per-workload reads (`remaining_slice`,
//!   `measurements`) resolve the workload index once via
//!   [`super::TaskDb::shard`] and then touch only this shard's memory —
//!   one bounds check per workload per tick instead of one per query.
//!
//! [`super::TaskDb`] keeps the exact pre-shard API (workload-indexed
//! keys) as a thin delegating facade; the legacy parity property test in
//! `super` drives that facade, so shard semantics stay pinned to the
//! seed store.
//!
//! All asymptotics of the PR-1 arena are unchanged: O(1) splices for
//! `claim`/`complete`/`requeue`, zero-allocation status walks, O(1)
//! remaining counters, binary-searched measurement windows.

use crate::sim::SimTime;

use super::{status_tag, StatusList, TaskRow, TaskStatus, N_STATUS, NIL};

/// Flat task arena for one workload: rows indexed by task id plus
/// intrusive per-status links and the per-media-type aggregates.
#[derive(Debug, Default)]
pub struct Shard {
    /// The workload this shard stores (stamped into every [`TaskRow`]).
    workload: usize,
    rows: Vec<TaskRow>,
    /// Intrusive links; `next[id]`/`prev[id]` position `id` within the
    /// list of its current status.
    next: Vec<u32>,
    prev: Vec<u32>,
    lists: [StatusList; N_STATUS],
    /// Not-completed counter per media type: m_{w,k}[t].
    remaining: Vec<u64>,
    /// Total inserted per media type (sizes the measurement reserve).
    n_by_type: Vec<usize>,
    /// Completed (time, measured CUS) per media type, appended in
    /// nondecreasing simulation time.
    meas: Vec<Vec<(SimTime, f64)>>,
}

/// In-order walk of one shard's status list. Zero allocation.
#[derive(Debug, Clone)]
pub struct StatusIter<'a> {
    pub(super) cur: u32,
    pub(super) remaining: usize,
    pub(super) next: &'a [u32],
}

impl Iterator for StatusIter<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        if self.cur == NIL {
            return None;
        }
        let id = self.cur as usize;
        self.cur = self.next[id];
        self.remaining -= 1;
        Some(id)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl ExactSizeIterator for StatusIter<'_> {}

impl Shard {
    /// An empty shard for `workload`.
    pub fn new(workload: usize) -> Self {
        Shard { workload, ..Self::default() }
    }

    /// The workload this shard stores.
    pub fn workload(&self) -> usize {
        self.workload
    }

    fn push_back(&mut self, s: TaskStatus, id: usize) {
        let si = status_tag(s);
        let mut l = self.lists[si];
        let id32 = id as u32;
        self.prev[id] = l.tail;
        self.next[id] = NIL;
        if l.tail == NIL {
            l.head = id32;
        } else {
            self.next[l.tail as usize] = id32;
        }
        l.tail = id32;
        l.len += 1;
        self.lists[si] = l;
    }

    fn unlink(&mut self, s: TaskStatus, id: usize) {
        let si = status_tag(s);
        let mut l = self.lists[si];
        let (p, n) = (self.prev[id], self.next[id]);
        if p == NIL {
            l.head = n;
        } else {
            self.next[p as usize] = n;
        }
        if n == NIL {
            l.tail = p;
        } else {
            self.prev[n as usize] = p;
        }
        l.len -= 1;
        self.prev[id] = NIL;
        self.next[id] = NIL;
        self.lists[si] = l;
    }

    fn grow_types(&mut self, media_type: usize) {
        if self.remaining.len() <= media_type {
            self.remaining.resize(media_type + 1, 0);
            self.n_by_type.resize(media_type + 1, 0);
            self.meas.resize_with(media_type + 1, Vec::new);
        }
    }

    /// Register a new pending task. Task ids must be inserted densely
    /// in order (0, 1, 2, ...) — the arena index *is* the task id.
    pub fn insert(&mut self, media_type: usize, task: usize) {
        let workload = self.workload;
        assert!(
            task >= self.rows.len(),
            "task ({workload},{task}) inserted twice"
        );
        assert_eq!(
            task,
            self.rows.len(),
            "task ids must be dense and in order (workload {workload})"
        );
        self.rows.push(TaskRow {
            workload,
            media_type,
            task,
            status: TaskStatus::Pending,
            instance: None,
            measured_cus: None,
            completed_at: None,
            exit_code: 0,
        });
        self.next.push(NIL);
        self.prev.push(NIL);
        self.push_back(TaskStatus::Pending, task);
        self.grow_types(media_type);
        self.remaining[media_type] += 1;
        self.n_by_type[media_type] += 1;
    }

    /// Pre-size the measurement logs to the final task counts so
    /// steady-state `complete` calls never reallocate.
    pub fn reserve_measurements(&mut self) {
        for k in 0..self.meas.len() {
            let need = self.n_by_type[k].saturating_sub(self.meas[k].len());
            self.meas[k].reserve(need);
        }
    }

    /// LCI claims a task for an instance (Pending -> Processing). O(1).
    pub fn claim(&mut self, task: usize, instance: u64) {
        {
            let row = self.rows.get(task).expect("unknown task");
            assert_eq!(
                row.status,
                TaskStatus::Pending,
                "claiming non-pending task ({}, {task})",
                self.workload
            );
        }
        self.unlink(TaskStatus::Pending, task);
        self.push_back(TaskStatus::Processing, task);
        let row = &mut self.rows[task];
        row.status = TaskStatus::Processing;
        row.instance = Some(instance);
    }

    /// LCI reports completion with the measured CUS. O(1).
    pub fn complete(&mut self, task: usize, cus: f64, at: SimTime, exit_code: i32) {
        {
            let row = self.rows.get(task).expect("unknown task");
            assert_eq!(
                row.status,
                TaskStatus::Processing,
                "completing unclaimed task ({}, {task})",
                self.workload
            );
        }
        let to = if exit_code == 0 { TaskStatus::Completed } else { TaskStatus::Failed };
        self.unlink(TaskStatus::Processing, task);
        self.push_back(to, task);
        let row = &mut self.rows[task];
        row.status = to;
        row.measured_cus = Some(cus);
        row.completed_at = Some(at);
        row.exit_code = exit_code;
        let media_type = row.media_type;
        if to == TaskStatus::Completed {
            self.remaining[media_type] -= 1;
            debug_assert!(
                self.meas[media_type].last().map_or(true, |&(t, _)| t <= at),
                "completions must arrive in nondecreasing sim time"
            );
            self.meas[media_type].push((at, cus));
        }
    }

    /// Abandon a processing task whose PR-10 retry budget is exhausted:
    /// Processing -> Failed, terminally (it will never be re-queued).
    /// Unlike a `complete` with a nonzero exit code, abandonment also
    /// drains the remaining-work counter — the task is out of the
    /// demand picture, so N* must stop sizing capacity for it. No
    /// measurement is logged (there is nothing to measure). O(1).
    pub fn abandon(&mut self, task: usize, at: SimTime) {
        {
            let row = self.rows.get(task).expect("unknown task");
            assert_eq!(
                row.status,
                TaskStatus::Processing,
                "abandoning unclaimed task ({}, {task})",
                self.workload
            );
        }
        self.unlink(TaskStatus::Processing, task);
        self.push_back(TaskStatus::Failed, task);
        let row = &mut self.rows[task];
        row.status = TaskStatus::Failed;
        row.completed_at = Some(at);
        row.exit_code = -1;
        self.remaining[row.media_type] -= 1;
    }

    /// Requeue a processing task (instance lost / spot reclaimed):
    /// Processing -> Pending, at the **tail** of the pending list (see
    /// the module docs in [`super`]). O(1).
    pub fn requeue(&mut self, task: usize) {
        {
            let row = self.rows.get(task).expect("unknown task");
            assert_eq!(row.status, TaskStatus::Processing);
        }
        self.unlink(TaskStatus::Processing, task);
        self.push_back(TaskStatus::Pending, task);
        let row = &mut self.rows[task];
        row.status = TaskStatus::Pending;
        row.instance = None;
    }

    pub fn get(&self, task: usize) -> Option<&TaskRow> {
        self.rows.get(task)
    }

    /// Walk a status list in order without allocating.
    pub fn status_iter(&self, status: TaskStatus) -> StatusIter<'_> {
        let l = self.lists[status_tag(status)];
        StatusIter { cur: l.head, remaining: l.len, next: &self.next }
    }

    /// O(1) status cardinality.
    pub fn count_status(&self, status: TaskStatus) -> usize {
        self.lists[status_tag(status)].len
    }

    /// Remaining counters per media type as a borrowed slice — the
    /// zero-allocation m_{w,k}[t] read on the GCI tick.
    pub fn remaining_slice(&self) -> &[u64] {
        &self.remaining
    }

    /// All completed (time, CUS) measurements for one media type, in
    /// nondecreasing completion time. Zero allocation.
    pub fn measurements(&self, media_type: usize) -> &[(SimTime, f64)] {
        self.meas.get(media_type).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// The (since, until] window of the completion log as a borrowed
    /// slice (binary search on the time-ordered log). Zero allocation.
    pub fn measurements_window(
        &self,
        media_type: usize,
        since: SimTime,
        until: SimTime,
    ) -> &[(SimTime, f64)] {
        let log = self.measurements(media_type);
        let start = log.partition_point(|&(t, _)| t <= since);
        let end = log.partition_point(|&(t, _)| t <= until);
        &log[start..end.max(start)]
    }

    /// The workload is complete when nothing is pending or processing.
    pub fn workload_complete(&self) -> bool {
        self.count_status(TaskStatus::Pending) == 0
            && self.count_status(TaskStatus::Processing) == 0
            && (self.count_status(TaskStatus::Completed) + self.count_status(TaskStatus::Failed))
                > 0
    }

    /// Total tasks ever inserted into this shard.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Clear all task state and re-stamp the shard for `workload`,
    /// keeping every allocation (row arena, intrusive links,
    /// measurement logs) — the free-list primitive of shard retirement
    /// (PR-8): a retired workload's slabs are recycled into the next
    /// admitted workload instead of being freed and re-grown.
    pub fn recycle(&mut self, workload: usize) {
        self.workload = workload;
        self.rows.clear();
        self.next.clear();
        self.prev.clear();
        self.lists = [StatusList::default(); N_STATUS];
        for m in &mut self.meas {
            m.clear();
        }
        // keep remaining/n_by_type/meas the same length (all-zero) so
        // the grow-together invariant of `grow_types` holds
        self.remaining.clear();
        self.remaining.resize(self.meas.len(), 0);
        self.n_by_type.clear();
        self.n_by_type.resize(self.meas.len(), 0);
    }

    /// Heap bytes currently held by this shard's arenas (capacity, not
    /// length — recycled shards keep their slabs). Feeds the
    /// `peak_arena_bytes` gauge of the streaming run (PR-8).
    pub fn arena_bytes(&self) -> usize {
        use std::mem::size_of;
        self.rows.capacity() * size_of::<TaskRow>()
            + (self.next.capacity() + self.prev.capacity()) * size_of::<u32>()
            + self.remaining.capacity() * size_of::<u64>()
            + self.n_by_type.capacity() * size_of::<usize>()
            + self.meas.capacity() * size_of::<Vec<(SimTime, f64)>>()
            + self
                .meas
                .iter()
                .map(|m| m.capacity() * size_of::<(SimTime, f64)>())
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard_with(n: usize) -> Shard {
        let mut s = Shard::new(3);
        for t in 0..n {
            s.insert(t % 2, t);
        }
        s
    }

    #[test]
    fn shard_stamps_its_workload_into_rows() {
        let s = shard_with(2);
        assert_eq!(s.workload(), 3);
        assert_eq!(s.get(0).unwrap().workload, 3);
        assert_eq!(s.get(1).unwrap().workload, 3);
    }

    #[test]
    fn shards_share_nothing() {
        // mutating one shard is invisible to another — the multi-platform
        // isolation contract
        let mut a = shard_with(4);
        let b = shard_with(4);
        a.claim(0, 7);
        a.complete(0, 2.0, 10, 0);
        assert_eq!(a.count_status(TaskStatus::Completed), 1);
        assert_eq!(b.count_status(TaskStatus::Completed), 0);
        assert_eq!(a.remaining_slice(), &[1, 2]);
        assert_eq!(b.remaining_slice(), &[2, 2]);
    }

    #[test]
    fn shards_move_across_threads() {
        // Shard is plain data: each workload's store can be processed on
        // its own thread with no synchronization, then collected
        let shards: Vec<Shard> = (0..4).map(|_| shard_with(8)).collect();
        let processed: Vec<Shard> = std::thread::scope(|scope| {
            let handles: Vec<_> = shards
                .into_iter()
                .map(|mut s| {
                    scope.spawn(move || {
                        for t in 0..s.len() {
                            s.claim(t, 1);
                            s.complete(t, 1.0, (t as u64 + 1) * 10, 0);
                        }
                        s
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for s in &processed {
            assert!(s.workload_complete());
            assert_eq!(s.count_status(TaskStatus::Completed), 8);
            assert_eq!(s.remaining_slice(), &[0, 0]);
        }
    }

    #[test]
    fn recycle_clears_state_but_keeps_slabs() {
        let mut s = shard_with(64);
        for t in 0..64 {
            s.claim(t, 1);
            s.complete(t, 1.0, (t as u64 + 1) * 5, 0);
        }
        let bytes_before = s.arena_bytes();
        assert!(bytes_before > 0);
        s.recycle(9);
        assert_eq!(s.workload(), 9);
        assert_eq!(s.len(), 0);
        assert_eq!(s.count_status(TaskStatus::Completed), 0);
        assert!(s.measurements(0).is_empty());
        assert!(s.remaining_slice().iter().all(|&m| m == 0));
        assert_eq!(s.arena_bytes(), bytes_before, "recycle must keep the slabs");
        // the recycled shard behaves exactly like a fresh one
        s.insert(0, 0);
        s.insert(1, 1);
        s.reserve_measurements();
        s.claim(0, 2);
        s.complete(0, 3.0, 7, 0);
        assert_eq!(s.get(0).unwrap().workload, 9, "rows re-stamp the new workload");
        assert_eq!(s.remaining_slice(), &[0, 1]);
        assert_eq!(s.measurements(0), &[(7, 3.0)]);
    }

    #[test]
    fn window_queries_are_shard_local() {
        let mut s = shard_with(3);
        for (t, at) in [(0usize, 10u64), (1, 20), (2, 30)] {
            s.claim(t, 1);
            s.complete(t, t as f64, at, 0);
        }
        // media types alternate 0,1,0
        assert_eq!(s.measurements(0), &[(10, 0.0), (30, 2.0)]);
        assert_eq!(s.measurements_window(0, 10, 30), &[(30, 2.0)]);
        assert!(s.measurements(9).is_empty());
    }
}
