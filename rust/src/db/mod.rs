//! Task-status database — the MySQL substitute of §II-E-1.
//!
//! The GCI allocates chunks "in a manner analogous to a BitTorrent
//! tracker": LCIs *write* task status + duration measurements, the GCI
//! *reads* pending/processing/completed sets. This store keeps exactly
//! those semantics on a flat-arena layout built for the monitoring tick
//! (perf pass, §Perf), and — since the PR-4 sharding pass — organizes
//! that layout as one independent [`Shard`] per workload:
//!
//! * each shard owns one `Vec<TaskRow>` arena indexed directly by task
//!   id (task ids are dense 0..n — the front end numbers them at
//!   upload), its own intrusive per-status lists, its own incremental
//!   `remaining` (m_{w,k}[t]) counters and its own time-ordered
//!   measurement logs — shards share **nothing**, so concurrent
//!   platform instances can own disjoint shards with no locking
//!   ([`TaskDb::into_shards`] / [`TaskDb::from_shards`]);
//! * intrusive doubly-linked lists thread the rows of each status, so
//!   `claim` / `complete` / `requeue` are O(1) pointer splices and
//!   status scans are in-order list walks with no allocation;
//! * per-(workload, media-type) completion logs, appended in simulation
//!   time order, make the ME's measurement queries (`measurements`,
//!   `measurements_window`) binary-search slices instead of full-table
//!   scans;
//! * the GCI tick resolves a workload to its shard once
//!   ([`TaskDb::shard`]) and reads `remaining_slice` / `measurements`
//!   shard-locally.
//!
//! `TaskDb` itself is a thin facade that routes the pre-shard,
//! workload-indexed API onto the shard vector — every method is a
//! one-line delegation, so the parity property test against the seed
//! store below pins shard semantics too.
//!
//! Ordering semantics: within a status, tasks appear in *insertion*
//! order (FIFO). For freshly inserted work this equals ascending task
//! id, matching the seed's sorted-set behaviour; a requeued task
//! (spot reclamation) re-enters Pending at the **tail**, i.e. behind
//! work that never ran — a deliberate fairness choice documented here
//! because it differs from the seed's sorted re-entry.
//!
//! The seed implementation is preserved in [`legacy`] as the perf
//! baseline and the semantic oracle for the parity property test.

pub mod legacy;
pub mod shard;

pub use shard::{Shard, StatusIter};

use crate::sim::SimTime;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskStatus {
    Pending,
    Processing,
    Completed,
    Failed,
}

pub(crate) const N_STATUS: usize = 4;

#[inline]
pub(crate) fn status_tag(s: TaskStatus) -> usize {
    match s {
        TaskStatus::Pending => 0,
        TaskStatus::Processing => 1,
        TaskStatus::Completed => 2,
        TaskStatus::Failed => 3,
    }
}

/// One media-processing task row.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskRow {
    pub workload: usize,
    pub media_type: usize,
    pub task: usize,
    pub status: TaskStatus,
    /// Instance currently/last processing it.
    pub instance: Option<u64>,
    /// Measured CUS to complete (set on completion).
    pub measured_cus: Option<f64>,
    /// Completion time.
    pub completed_at: Option<SimTime>,
    /// Exit status (0 normal, -1 abnormal — §II-A).
    pub exit_code: i32,
}

/// Composite key: (workload, task index).
pub type TaskKey = (usize, usize);

/// Intrusive-list null.
pub(crate) const NIL: u32 = u32::MAX;

#[derive(Debug, Clone, Copy)]
pub(crate) struct StatusList {
    pub(crate) head: u32,
    pub(crate) tail: u32,
    pub(crate) len: usize,
}

impl Default for StatusList {
    fn default() -> Self {
        StatusList { head: NIL, tail: NIL, len: 0 }
    }
}

/// Exactly-once terminal accounting of one retired shard (PR-8): the
/// audit receipt [`TaskDb::retire_shard`] hands back before the
/// shard's slabs move to the free pool. Every task the shard ever held
/// is accounted terminal here — retirement refuses shards with live
/// (pending/processing) work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardAudit {
    pub workload: usize,
    /// Total tasks the shard held (== `completed + failed`).
    pub tasks: usize,
    pub completed: usize,
    pub failed: usize,
    /// Arena bytes recycled into the free pool.
    pub freed_bytes: usize,
}

/// The workload-sharded task store: a vector of independent
/// [`Shard`]s behind the pre-shard, workload-indexed API. Deliberately
/// carries **no** state of its own — every query derives from the
/// shards, so going through [`Self::shard_mut`] can never desync the
/// facade. (The PR-8 free pool holds only *empty* recycled slabs, so
/// the no-state property stands.)
#[derive(Debug, Default)]
pub struct TaskDb {
    shards: Vec<Shard>,
    /// Recycled arena slabs from retired shards, reused by the next
    /// admitted workload instead of growing fresh (PR-8).
    free: Vec<Shard>,
}

impl TaskDb {
    pub fn new() -> Self {
        Self::default()
    }

    /// Assemble a db from per-workload shards. `shards[w].workload()`
    /// must equal its position `w` (the inverse of [`Self::into_shards`]).
    pub fn from_shards(shards: Vec<Shard>) -> Self {
        for (w, s) in shards.iter().enumerate() {
            assert_eq!(s.workload(), w, "shard at position {w} stores workload {}", s.workload());
        }
        TaskDb { shards, free: Vec::new() }
    }

    /// Decompose into per-workload shards (nothing shared between
    /// them) — the handoff point for concurrent platform instances.
    pub fn into_shards(self) -> Vec<Shard> {
        self.shards
    }

    /// Number of workload shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Borrow one workload's shard — the GCI tick resolves the
    /// workload index once and reads shard-locally.
    pub fn shard(&self, workload: usize) -> Option<&Shard> {
        self.shards.get(workload)
    }

    /// Mutably borrow one workload's shard.
    pub fn shard_mut(&mut self, workload: usize) -> Option<&mut Shard> {
        self.shards.get_mut(workload)
    }

    fn shard_for(&mut self, workload: usize) -> &mut Shard {
        while self.shards.len() <= workload {
            let id = self.shards.len();
            let shard = match self.free.pop() {
                Some(mut s) => {
                    s.recycle(id);
                    s
                }
                None => Shard::new(id),
            };
            self.shards.push(shard);
        }
        &mut self.shards[workload]
    }

    /// Audit and retire one terminal workload's shard (PR-8): assert
    /// every task is terminal (no pending/processing work — callers
    /// retire only `Done` workloads), fold the exactly-once terminal
    /// counts into a [`ShardAudit`] receipt, leave a cheap empty
    /// tombstone at the shard's position (the vector stays indexed by
    /// workload id), and move the arena slabs to the free pool for the
    /// next admission. After retirement the facade's queries on this
    /// workload read the tombstone (all-zero counts, empty logs) — the
    /// caller owns the receipt.
    pub fn retire_shard(&mut self, workload: usize) -> ShardAudit {
        let s = self.shards.get_mut(workload).expect("retiring unknown workload");
        assert_eq!(
            s.count_status(TaskStatus::Pending),
            0,
            "retiring workload {workload} with pending tasks"
        );
        assert_eq!(
            s.count_status(TaskStatus::Processing),
            0,
            "retiring workload {workload} with in-flight tasks"
        );
        let completed = s.count_status(TaskStatus::Completed);
        let failed = s.count_status(TaskStatus::Failed);
        let tasks = s.len();
        assert_eq!(completed + failed, tasks, "workload {workload}: non-terminal rows at audit");
        let freed_bytes = s.arena_bytes();
        let mut slab = std::mem::replace(s, Shard::new(workload));
        slab.recycle(workload);
        self.free.push(slab);
        ShardAudit { workload, tasks, completed, failed, freed_bytes }
    }

    /// Recycled slabs waiting for the next admission.
    pub fn free_shards(&self) -> usize {
        self.free.len()
    }

    /// Heap bytes held by one workload's shard arenas (0 for never-seen
    /// or retired workloads).
    pub fn arena_bytes(&self, workload: usize) -> usize {
        self.shards.get(workload).map(|s| s.arena_bytes()).unwrap_or(0)
    }

    /// Register a new pending task. Task ids must be inserted densely
    /// in order (0, 1, 2, ...) per workload — the arena index *is* the
    /// task id.
    pub fn insert(&mut self, workload: usize, media_type: usize, task: usize) {
        self.shard_for(workload).insert(media_type, task);
    }

    /// Pre-size the measurement logs to the workload's final task
    /// counts so steady-state `complete` calls never reallocate. Call
    /// once after a workload's inserts (the platform does this at
    /// arrival).
    pub fn reserve_measurements(&mut self, workload: usize) {
        if let Some(s) = self.shards.get_mut(workload) {
            s.reserve_measurements();
        }
    }

    /// LCI claims a task for an instance (Pending -> Processing). O(1).
    pub fn claim(&mut self, key: TaskKey, instance: u64) {
        self.shards.get_mut(key.0).expect("unknown task").claim(key.1, instance);
    }

    /// LCI reports completion with the measured CUS. O(1).
    pub fn complete(&mut self, key: TaskKey, cus: f64, at: SimTime, exit_code: i32) {
        self.shards.get_mut(key.0).expect("unknown task").complete(key.1, cus, at, exit_code);
    }

    /// Requeue a processing task (instance lost / spot reclaimed):
    /// Processing -> Pending, at the **tail** of the pending list (see
    /// module docs). O(1).
    pub fn requeue(&mut self, key: TaskKey) {
        self.shards.get_mut(key.0).expect("unknown task").requeue(key.1);
    }

    /// Abandon a processing task terminally (PR-10 retry budget
    /// exhausted): Processing -> Failed, remaining-work counter
    /// drained, no measurement logged. O(1).
    pub fn abandon(&mut self, key: TaskKey, at: SimTime) {
        self.shards.get_mut(key.0).expect("unknown task").abandon(key.1, at);
    }

    pub fn get(&self, key: TaskKey) -> Option<&TaskRow> {
        self.shards.get(key.0).and_then(|s| s.get(key.1))
    }

    /// Walk a status list in order without allocating — the GCI-tick
    /// query primitive (`build_chunk` takes the first n via `.take(n)`).
    pub fn status_iter(&self, workload: usize, status: TaskStatus) -> StatusIter<'_> {
        match self.shards.get(workload) {
            Some(s) => s.status_iter(status),
            None => StatusIter { cur: NIL, remaining: 0, next: &[] },
        }
    }

    /// Task ids in a given status for a workload (allocating
    /// convenience over [`Self::status_iter`]; tests/debug).
    pub fn tasks_with_status(&self, workload: usize, status: TaskStatus) -> Vec<usize> {
        self.status_iter(workload, status).collect()
    }

    /// First `n` task ids of a status (allocating convenience over
    /// `status_iter(..).take(n)`).
    pub fn first_with_status(&self, workload: usize, status: TaskStatus, n: usize) -> Vec<usize> {
        self.status_iter(workload, status).take(n).collect()
    }

    /// O(1) status cardinality.
    pub fn count_status(&self, workload: usize, status: TaskStatus) -> usize {
        self.shards.get(workload).map(|s| s.count_status(status)).unwrap_or(0)
    }

    /// Remaining (not completed) count for one (workload, media type).
    pub fn remaining(&self, workload: usize, media_type: usize) -> u64 {
        self.remaining_slice(workload).get(media_type).copied().unwrap_or(0)
    }

    /// Remaining counters per media type as a borrowed slice — the
    /// zero-allocation m_{w,k}[t] read on the GCI tick.
    pub fn remaining_slice(&self, workload: usize) -> &[u64] {
        self.shards.get(workload).map(|s| s.remaining_slice()).unwrap_or(&[])
    }

    /// Remaining (not completed) items per media type: m_{w,k}[t]
    /// (allocating convenience over [`Self::remaining_slice`]).
    pub fn remaining_by_type(&self, workload: usize, n_types: usize) -> Vec<f64> {
        let s = self.remaining_slice(workload);
        (0..n_types).map(|k| s.get(k).copied().unwrap_or(0) as f64).collect()
    }

    /// All completed (time, CUS) measurements for (workload, media
    /// type), in nondecreasing completion time. Zero allocation.
    pub fn measurements(&self, workload: usize, media_type: usize) -> &[(SimTime, f64)] {
        self.shards.get(workload).map(|s| s.measurements(media_type)).unwrap_or(&[])
    }

    /// The (since, until] window of the completion log as a borrowed
    /// slice (binary search on the time-ordered log; eq. 4's
    /// per-interval measurement feed). Zero allocation.
    pub fn measurements_window(
        &self,
        workload: usize,
        media_type: usize,
        since: SimTime,
        until: SimTime,
    ) -> &[(SimTime, f64)] {
        self.shards
            .get(workload)
            .map(|s| s.measurements_window(media_type, since, until))
            .unwrap_or(&[])
    }

    /// Completed-task CUS measurements within (since, until]
    /// (allocating convenience over [`Self::measurements_window`]).
    pub fn measurements_between(
        &self,
        workload: usize,
        media_type: usize,
        since: SimTime,
        until: SimTime,
    ) -> Vec<f64> {
        self.measurements_window(workload, media_type, since, until)
            .iter()
            .map(|&(_, c)| c)
            .collect()
    }

    /// All completed CUS measurements for a workload/type (allocating
    /// convenience over [`Self::measurements`]).
    pub fn all_measurements(&self, workload: usize, media_type: usize) -> Vec<f64> {
        self.measurements(workload, media_type).iter().map(|&(_, c)| c).collect()
    }

    /// A workload is complete when nothing is pending or processing.
    pub fn workload_complete(&self, workload: usize) -> bool {
        self.shards.get(workload).map(|s| s.workload_complete()).unwrap_or(false)
    }

    /// Total tasks ever inserted, derived from the shards (O(#workloads)).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::legacy::LegacyTaskDb;
    use super::*;
    use crate::util::proptest::forall;

    fn db_with(n: usize) -> TaskDb {
        let mut db = TaskDb::new();
        for t in 0..n {
            db.insert(0, 0, t);
        }
        db
    }

    #[test]
    fn lifecycle_pending_processing_completed() {
        let mut db = db_with(3);
        assert_eq!(db.tasks_with_status(0, TaskStatus::Pending), vec![0, 1, 2]);
        db.claim((0, 1), 42);
        assert_eq!(db.tasks_with_status(0, TaskStatus::Pending), vec![0, 2]);
        assert_eq!(db.tasks_with_status(0, TaskStatus::Processing), vec![1]);
        db.complete((0, 1), 3.5, 100, 0);
        assert_eq!(db.get((0, 1)).unwrap().measured_cus, Some(3.5));
        assert_eq!(db.count_status(0, TaskStatus::Completed), 1);
        assert!(!db.workload_complete(0));
    }

    #[test]
    #[should_panic(expected = "inserted twice")]
    fn double_insert_panics() {
        let mut db = db_with(1);
        db.insert(0, 0, 0);
    }

    #[test]
    #[should_panic(expected = "claiming non-pending")]
    fn double_claim_panics() {
        let mut db = db_with(1);
        db.claim((0, 0), 1);
        db.claim((0, 0), 2);
    }

    #[test]
    fn failed_tasks_counted_separately() {
        let mut db = db_with(2);
        db.claim((0, 0), 1);
        db.complete((0, 0), 1.0, 10, -1);
        assert_eq!(db.count_status(0, TaskStatus::Failed), 1);
        assert_eq!(db.count_status(0, TaskStatus::Completed), 0);
        // failed measurements do not enter the completion log
        assert!(db.measurements(0, 0).is_empty());
    }

    #[test]
    fn requeue_returns_to_pending() {
        let mut db = db_with(1);
        db.claim((0, 0), 1);
        db.requeue((0, 0));
        assert_eq!(db.tasks_with_status(0, TaskStatus::Pending), vec![0]);
        assert!(db.get((0, 0)).unwrap().instance.is_none());
    }

    #[test]
    fn requeue_enters_pending_at_tail() {
        // documented FIFO semantics: a reclaimed task waits behind
        // work that never ran
        let mut db = db_with(3);
        db.claim((0, 0), 1);
        db.requeue((0, 0));
        assert_eq!(db.tasks_with_status(0, TaskStatus::Pending), vec![1, 2, 0]);
        assert_eq!(db.first_with_status(0, TaskStatus::Pending, 2), vec![1, 2]);
    }

    #[test]
    fn remaining_by_type_counts_non_completed() {
        let mut db = TaskDb::new();
        db.insert(3, 0, 0);
        db.insert(3, 1, 1);
        db.insert(3, 1, 2);
        db.claim((3, 1), 9);
        db.complete((3, 1), 2.0, 5, 0);
        assert_eq!(db.remaining_by_type(3, 2), vec![1.0, 1.0]);
        assert_eq!(db.remaining_slice(3), &[1, 1]);
        assert_eq!(db.remaining(3, 1), 1);
    }

    #[test]
    fn measurement_window_is_half_open() {
        let mut db = db_with(3);
        for (t, at) in [(0usize, 10u64), (1, 20), (2, 30)] {
            db.claim((0, t), 1);
            db.complete((0, t), t as f64, at, 0);
        }
        assert_eq!(db.measurements_between(0, 0, 10, 30), vec![1.0, 2.0]);
        assert_eq!(db.all_measurements(0, 0).len(), 3);
        assert_eq!(db.measurements_window(0, 0, 0, 10), &[(10, 0.0)]);
        assert!(db.measurements_window(0, 0, 30, 99).is_empty());
    }

    #[test]
    fn workload_complete_requires_all_done() {
        let mut db = db_with(2);
        db.claim((0, 0), 1);
        db.complete((0, 0), 1.0, 1, 0);
        assert!(!db.workload_complete(0));
        db.claim((0, 1), 1);
        db.complete((0, 1), 1.0, 2, -1); // failure still terminal
        assert!(db.workload_complete(0));
    }

    #[test]
    fn status_iter_matches_collected_and_is_exact_size() {
        let mut db = db_with(5);
        db.claim((0, 2), 1);
        db.claim((0, 4), 1);
        let it = db.status_iter(0, TaskStatus::Pending);
        assert_eq!(it.len(), 3);
        assert_eq!(it.collect::<Vec<_>>(), db.tasks_with_status(0, TaskStatus::Pending));
        assert_eq!(db.status_iter(7, TaskStatus::Pending).count(), 0);
    }

    #[test]
    fn out_of_range_queries_are_empty() {
        let db = db_with(1);
        assert_eq!(db.count_status(9, TaskStatus::Pending), 0);
        assert!(db.remaining_slice(9).is_empty());
        assert!(db.measurements(0, 9).is_empty());
        assert!(db.get((9, 0)).is_none());
    }

    #[test]
    fn shard_accessors_expose_the_facade_state() {
        let mut db = TaskDb::new();
        db.insert(0, 0, 0);
        db.insert(2, 1, 0);
        assert_eq!(db.shard_count(), 3);
        // workload 1 exists as an empty interposed shard
        let s1 = db.shard(1).unwrap();
        assert!(s1.is_empty());
        assert_eq!(s1.workload(), 1);
        let s2 = db.shard(2).unwrap();
        assert_eq!(s2.remaining_slice(), &[0, 1]);
        assert!(db.shard(9).is_none());
        db.shard_mut(2).unwrap().claim(0, 5);
        assert_eq!(db.count_status(2, TaskStatus::Processing), 1);
    }

    #[test]
    fn shards_roundtrip_through_the_facade() {
        let mut db = TaskDb::new();
        for w in 0..3 {
            for t in 0..4 {
                db.insert(w, t % 2, t);
            }
        }
        db.claim((1, 2), 9);
        db.complete((1, 2), 1.5, 30, 0);
        let len = db.len();
        let shards = db.into_shards();
        assert_eq!(shards.len(), 3);
        let db = TaskDb::from_shards(shards);
        assert_eq!(db.len(), len);
        assert_eq!(db.count_status(1, TaskStatus::Completed), 1);
        assert_eq!(db.remaining_slice(1), &[2, 1]);
        assert_eq!(db.measurements(1, 0), &[(30, 1.5)]);
    }

    #[test]
    #[should_panic(expected = "shard at position")]
    fn from_shards_rejects_misplaced_workloads() {
        let mut shards = db_with(2).into_shards();
        shards.insert(0, Shard::new(7));
        let _ = TaskDb::from_shards(shards);
    }

    #[test]
    fn retire_shard_audits_and_recycles() {
        let mut db = TaskDb::new();
        for t in 0..5 {
            db.insert(0, 0, t);
            db.claim((0, t), 1);
            db.complete((0, t), 1.0, (t as u64 + 1) * 10, if t == 4 { -1 } else { 0 });
        }
        db.insert(1, 0, 0); // a live neighbour must be untouched
        let bytes = db.arena_bytes(0);
        assert!(bytes > 0);
        let audit = db.retire_shard(0);
        assert_eq!(
            audit,
            ShardAudit { workload: 0, tasks: 5, completed: 4, failed: 1, freed_bytes: bytes }
        );
        // the tombstone reads as empty but keeps its position
        assert_eq!(db.count_status(0, TaskStatus::Completed), 0);
        assert!(db.measurements(0, 0).is_empty());
        assert_eq!(db.shard(0).unwrap().workload(), 0);
        assert_eq!(db.shard_count(), 2);
        assert_eq!(db.len(), 1, "only the live neighbour's task remains");
        // the slab waits in the pool and the next admission reuses it
        assert_eq!(db.free_shards(), 1);
        db.insert(2, 0, 0);
        assert_eq!(db.free_shards(), 0, "admission must pop the recycled slab");
        assert!(db.arena_bytes(2) >= bytes, "the new shard inherits the slab capacity");
    }

    #[test]
    #[should_panic(expected = "in-flight tasks")]
    fn retiring_a_live_workload_panics() {
        let mut db = db_with(2);
        db.claim((0, 0), 1);
        db.complete((0, 0), 1.0, 5, 0);
        db.claim((0, 1), 1);
        let _ = db.retire_shard(0);
    }

    /// PR-8 satellite: interleaved admit/claim/complete/requeue/retire
    /// sequences conserve tasks **exactly once** — every inserted task
    /// ends up either in a retirement audit receipt or in a shard that
    /// survives to `into_shards`, never both, never dropped; terminal
    /// counts (completed vs failed) are conserved the same way.
    #[test]
    fn admit_retire_interleavings_conserve_tasks_exactly_once() {
        forall(
            "admit-retire-conservation",
            0xDB08,
            25,
            |r| (0..300).map(|_| r.next_u64()).collect::<Vec<u64>>(),
            |ops| {
                let mut db = TaskDb::new();
                let mut inserted = 0usize;
                let (mut done_ok, mut done_bad) = (0usize, 0usize);
                let mut audits: Vec<ShardAudit> = Vec::new();
                let mut retired: Vec<bool> = Vec::new();
                let mut clock = 0u64;
                for &op in ops {
                    clock += 1;
                    let live: Vec<usize> =
                        (0..retired.len()).filter(|&w| !retired[w]).collect();
                    let pick = live.get(op as usize % live.len().max(1)).copied();
                    match op % 5 {
                        0 => {
                            let w = retired.len();
                            let n = (op / 5 % 6 + 1) as usize;
                            for t in 0..n {
                                db.insert(w, t % 2, t);
                            }
                            inserted += n;
                            retired.push(false);
                        }
                        1 | 2 => {
                            if let Some(w) = pick {
                                if let Some(t) = db.status_iter(w, TaskStatus::Pending).next() {
                                    db.claim((w, t), op % 9);
                                    let code = if op % 7 == 0 { -1 } else { 0 };
                                    db.complete((w, t), (op % 50) as f64, clock, code);
                                    if code == 0 {
                                        done_ok += 1;
                                    } else {
                                        done_bad += 1;
                                    }
                                }
                            }
                        }
                        3 => {
                            if let Some(w) = pick {
                                if let Some(t) = db.status_iter(w, TaskStatus::Pending).next() {
                                    db.claim((w, t), 1);
                                    db.requeue((w, t));
                                }
                            }
                        }
                        _ => {
                            if let Some(&w) = live.iter().find(|&&w| db.workload_complete(w)) {
                                audits.push(db.retire_shard(w));
                                retired[w] = true;
                            }
                        }
                    }
                }
                for a in &audits {
                    if a.completed + a.failed != a.tasks {
                        return Err(format!("audit not terminal-exact: {a:?}"));
                    }
                }
                let shards = db.into_shards();
                for a in &audits {
                    if !shards[a.workload].is_empty() {
                        return Err(format!("workload {} counted twice", a.workload));
                    }
                }
                let surviving: usize = shards.iter().map(|s| s.len()).sum();
                let audited: usize = audits.iter().map(|a| a.tasks).sum();
                if audited + surviving != inserted {
                    return Err(format!(
                        "task conservation: {audited} audited + {surviving} live != {inserted}"
                    ));
                }
                let c: usize = audits.iter().map(|a| a.completed).sum::<usize>()
                    + shards.iter().map(|s| s.count_status(TaskStatus::Completed)).sum::<usize>();
                let f: usize = audits.iter().map(|a| a.failed).sum::<usize>()
                    + shards.iter().map(|s| s.count_status(TaskStatus::Failed)).sum::<usize>();
                if c != done_ok || f != done_bad {
                    return Err(format!(
                        "terminal conservation: ({c}, {f}) != ({done_ok}, {done_bad})"
                    ));
                }
                Ok(())
            },
        );
    }

    /// Drive the arena and the seed (legacy) store through the same
    /// random operation sequence and require identical observable
    /// state. Pending-list *order* is compared as a sorted set because
    /// requeue re-entry order is the one documented divergence.
    #[test]
    fn parity_with_legacy_store_under_random_ops() {
        forall(
            "arena-vs-legacy-parity",
            0xDB01,
            25,
            |r| {
                let n = r.int(1, 60) as usize;
                let ops: Vec<u64> = (0..200).map(|_| r.next_u64()).collect();
                (n, ops)
            },
            |(n, ops)| {
                let mut a = TaskDb::new();
                let mut b = LegacyTaskDb::new();
                for t in 0..*n {
                    let mt = t % 3;
                    a.insert(0, mt, t);
                    b.insert(0, mt, t);
                }
                let mut clock = 0u64;
                for op in ops {
                    clock += 1;
                    match op % 3 {
                        0 => {
                            // claim the first pending task
                            if let Some(t) = a.status_iter(0, TaskStatus::Pending).next() {
                                a.claim((0, t), op % 7);
                                b.claim((0, t), op % 7);
                            }
                        }
                        1 => {
                            // complete the first processing task
                            if let Some(t) = a.status_iter(0, TaskStatus::Processing).next() {
                                let cus = (op % 100) as f64;
                                let code = if op % 11 == 0 { -1 } else { 0 };
                                a.complete((0, t), cus, clock, code);
                                b.complete((0, t), cus, clock, code);
                            }
                        }
                        _ => {
                            // requeue the first processing task
                            if let Some(t) = a.status_iter(0, TaskStatus::Processing).next() {
                                a.requeue((0, t));
                                b.requeue((0, t));
                            }
                        }
                    }
                }
                for s in [
                    TaskStatus::Pending,
                    TaskStatus::Processing,
                    TaskStatus::Completed,
                    TaskStatus::Failed,
                ] {
                    if a.count_status(0, s) != b.count_status(0, s) {
                        return Err(format!("count mismatch for {s:?}"));
                    }
                    let mut ta = a.tasks_with_status(0, s);
                    ta.sort_unstable();
                    let tb = b.tasks_with_status(0, s); // BTreeSet: already sorted
                    if ta != tb {
                        return Err(format!("id set mismatch for {s:?}: {ta:?} vs {tb:?}"));
                    }
                }
                for t in 0..*n {
                    let (ra, rb) = (a.get((0, t)).unwrap(), b.get((0, t)).unwrap());
                    if ra != rb {
                        return Err(format!("row {t} mismatch: {ra:?} vs {rb:?}"));
                    }
                }
                if a.remaining_by_type(0, 3) != b.remaining_by_type(0, 3) {
                    return Err("remaining mismatch".into());
                }
                for k in 0..3 {
                    let mut ma = a.all_measurements(0, k);
                    let mut mb = b.all_measurements(0, k);
                    ma.sort_by(|x, y| x.partial_cmp(y).unwrap());
                    mb.sort_by(|x, y| x.partial_cmp(y).unwrap());
                    if ma != mb {
                        return Err(format!("measurement mismatch for type {k}"));
                    }
                }
                if a.workload_complete(0) != b.workload_complete(0) || a.len() != b.len() {
                    return Err("completion/len mismatch".into());
                }
                Ok(())
            },
        );
    }
}
