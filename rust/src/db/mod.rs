//! Task-status database — the MySQL substitute of §II-E-1.
//!
//! The GCI allocates chunks "in a manner analogous to a BitTorrent
//! tracker": LCIs *write* task status + duration measurements, the GCI
//! *reads* pending/processing/completed sets. This store keeps exactly
//! those semantics (indexed by workload and status, insertion-ordered
//! within a status) so tracker behaviour is deterministic.

use std::collections::{BTreeMap, BTreeSet};

use crate::sim::SimTime;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskStatus {
    Pending,
    Processing,
    Completed,
    Failed,
}

/// One media-processing task row.
#[derive(Debug, Clone)]
pub struct TaskRow {
    pub workload: usize,
    pub media_type: usize,
    pub task: usize,
    pub status: TaskStatus,
    /// Instance currently/last processing it.
    pub instance: Option<u64>,
    /// Measured CUS to complete (set on completion).
    pub measured_cus: Option<f64>,
    /// Completion time.
    pub completed_at: Option<SimTime>,
    /// Exit status (0 normal, -1 abnormal — §II-A).
    pub exit_code: i32,
}

/// Composite key: (workload, task index).
pub type TaskKey = (usize, usize);

#[derive(Debug, Default)]
pub struct TaskDb {
    rows: BTreeMap<TaskKey, TaskRow>,
    by_status: BTreeMap<(usize, u8), BTreeSet<usize>>, // (workload, status) -> task ids
    /// Incremental not-completed counters per (workload, media type):
    /// the GCI reads m_{w,k}[t] every tick, so this must be O(1), not a
    /// table scan (perf pass, §Perf).
    remaining: BTreeMap<(usize, usize), u64>,
}

fn status_tag(s: TaskStatus) -> u8 {
    match s {
        TaskStatus::Pending => 0,
        TaskStatus::Processing => 1,
        TaskStatus::Completed => 2,
        TaskStatus::Failed => 3,
    }
}

impl TaskDb {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a new pending task.
    pub fn insert(&mut self, workload: usize, media_type: usize, task: usize) {
        let row = TaskRow {
            workload,
            media_type,
            task,
            status: TaskStatus::Pending,
            instance: None,
            measured_cus: None,
            completed_at: None,
            exit_code: 0,
        };
        let prev = self.rows.insert((workload, task), row);
        assert!(prev.is_none(), "task ({workload},{task}) inserted twice");
        self.by_status
            .entry((workload, status_tag(TaskStatus::Pending)))
            .or_default()
            .insert(task);
        *self.remaining.entry((workload, media_type)).or_default() += 1;
    }

    fn move_status(&mut self, key: TaskKey, to: TaskStatus) {
        let row = self.rows.get_mut(&key).expect("unknown task");
        let from = row.status;
        row.status = to;
        self.by_status
            .get_mut(&(key.0, status_tag(from)))
            .map(|s| s.remove(&key.1));
        self.by_status
            .entry((key.0, status_tag(to)))
            .or_default()
            .insert(key.1);
    }

    /// LCI claims a task for an instance (Pending -> Processing).
    pub fn claim(&mut self, key: TaskKey, instance: u64) {
        {
            let row = self.rows.get(&key).expect("unknown task");
            assert_eq!(row.status, TaskStatus::Pending, "claiming non-pending task {key:?}");
        }
        self.move_status(key, TaskStatus::Processing);
        self.rows.get_mut(&key).unwrap().instance = Some(instance);
    }

    /// LCI reports completion with the measured CUS.
    pub fn complete(&mut self, key: TaskKey, cus: f64, at: SimTime, exit_code: i32) {
        {
            let row = self.rows.get(&key).expect("unknown task");
            assert_eq!(row.status, TaskStatus::Processing, "completing unclaimed task {key:?}");
        }
        let to = if exit_code == 0 { TaskStatus::Completed } else { TaskStatus::Failed };
        self.move_status(key, to);
        let row = self.rows.get_mut(&key).unwrap();
        row.measured_cus = Some(cus);
        row.completed_at = Some(at);
        row.exit_code = exit_code;
        if to == TaskStatus::Completed {
            let media_type = row.media_type;
            let c = self
                .remaining
                .get_mut(&(key.0, media_type))
                .expect("remaining counter missing");
            *c -= 1;
        }
    }

    /// Requeue a processing task (instance lost / spot reclaimed).
    pub fn requeue(&mut self, key: TaskKey) {
        {
            let row = self.rows.get(&key).expect("unknown task");
            assert_eq!(row.status, TaskStatus::Processing);
        }
        self.move_status(key, TaskStatus::Pending);
        self.rows.get_mut(&key).unwrap().instance = None;
    }

    pub fn get(&self, key: TaskKey) -> Option<&TaskRow> {
        self.rows.get(&key)
    }

    /// Task ids in a given status for a workload (sorted).
    pub fn tasks_with_status(&self, workload: usize, status: TaskStatus) -> Vec<usize> {
        self.by_status
            .get(&(workload, status_tag(status)))
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }

    /// First `n` task ids of a status (ascending) without materializing
    /// the full id set — build_chunk calls this on every assignment.
    pub fn first_with_status(&self, workload: usize, status: TaskStatus, n: usize) -> Vec<usize> {
        self.by_status
            .get(&(workload, status_tag(status)))
            .map(|s| s.iter().take(n).copied().collect())
            .unwrap_or_default()
    }

    pub fn count_status(&self, workload: usize, status: TaskStatus) -> usize {
        self.by_status
            .get(&(workload, status_tag(status)))
            .map(|s| s.len())
            .unwrap_or(0)
    }

    /// Remaining (not completed) items per media type: m_{w,k}[t]. O(K)
    /// via incremental counters.
    pub fn remaining_by_type(&self, workload: usize, n_types: usize) -> Vec<f64> {
        (0..n_types)
            .map(|k| self.remaining.get(&(workload, k)).copied().unwrap_or(0) as f64)
            .collect()
    }

    /// Completed-task CUS measurements for (workload, media type) within
    /// (since, until] — the ME's per-interval measurement feed (eq. 4).
    pub fn measurements_between(
        &self,
        workload: usize,
        media_type: usize,
        since: SimTime,
        until: SimTime,
    ) -> Vec<f64> {
        self.rows
            .values()
            .filter(|r| {
                r.workload == workload
                    && r.media_type == media_type
                    && r.status == TaskStatus::Completed
                    && r.completed_at.map(|t| t > since && t <= until).unwrap_or(false)
            })
            .map(|r| r.measured_cus.unwrap())
            .collect()
    }

    /// All completed CUS measurements for a workload/type (any time).
    pub fn all_measurements(&self, workload: usize, media_type: usize) -> Vec<f64> {
        self.rows
            .values()
            .filter(|r| {
                r.workload == workload
                    && r.media_type == media_type
                    && r.status == TaskStatus::Completed
            })
            .map(|r| r.measured_cus.unwrap())
            .collect()
    }

    /// A workload is complete when nothing is pending or processing.
    pub fn workload_complete(&self, workload: usize) -> bool {
        self.count_status(workload, TaskStatus::Pending) == 0
            && self.count_status(workload, TaskStatus::Processing) == 0
            && (self.count_status(workload, TaskStatus::Completed)
                + self.count_status(workload, TaskStatus::Failed))
                > 0
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn db_with(n: usize) -> TaskDb {
        let mut db = TaskDb::new();
        for t in 0..n {
            db.insert(0, 0, t);
        }
        db
    }

    #[test]
    fn lifecycle_pending_processing_completed() {
        let mut db = db_with(3);
        assert_eq!(db.tasks_with_status(0, TaskStatus::Pending), vec![0, 1, 2]);
        db.claim((0, 1), 42);
        assert_eq!(db.tasks_with_status(0, TaskStatus::Pending), vec![0, 2]);
        assert_eq!(db.tasks_with_status(0, TaskStatus::Processing), vec![1]);
        db.complete((0, 1), 3.5, 100, 0);
        assert_eq!(db.get((0, 1)).unwrap().measured_cus, Some(3.5));
        assert_eq!(db.count_status(0, TaskStatus::Completed), 1);
        assert!(!db.workload_complete(0));
    }

    #[test]
    #[should_panic(expected = "inserted twice")]
    fn double_insert_panics() {
        let mut db = db_with(1);
        db.insert(0, 0, 0);
    }

    #[test]
    #[should_panic(expected = "claiming non-pending")]
    fn double_claim_panics() {
        let mut db = db_with(1);
        db.claim((0, 0), 1);
        db.claim((0, 0), 2);
    }

    #[test]
    fn failed_tasks_counted_separately() {
        let mut db = db_with(2);
        db.claim((0, 0), 1);
        db.complete((0, 0), 1.0, 10, -1);
        assert_eq!(db.count_status(0, TaskStatus::Failed), 1);
        assert_eq!(db.count_status(0, TaskStatus::Completed), 0);
    }

    #[test]
    fn requeue_returns_to_pending() {
        let mut db = db_with(1);
        db.claim((0, 0), 1);
        db.requeue((0, 0));
        assert_eq!(db.tasks_with_status(0, TaskStatus::Pending), vec![0]);
        assert!(db.get((0, 0)).unwrap().instance.is_none());
    }

    #[test]
    fn remaining_by_type_counts_non_completed() {
        let mut db = TaskDb::new();
        db.insert(3, 0, 0);
        db.insert(3, 1, 1);
        db.insert(3, 1, 2);
        db.claim((3, 1), 9);
        db.complete((3, 1), 2.0, 5, 0);
        assert_eq!(db.remaining_by_type(3, 2), vec![1.0, 1.0]);
    }

    #[test]
    fn measurement_window_is_half_open() {
        let mut db = db_with(3);
        for (t, at) in [(0usize, 10u64), (1, 20), (2, 30)] {
            db.claim((0, t), 1);
            db.complete((0, t), t as f64, at, 0);
        }
        assert_eq!(db.measurements_between(0, 0, 10, 30), vec![1.0, 2.0]);
        assert_eq!(db.all_measurements(0, 0).len(), 3);
    }

    #[test]
    fn workload_complete_requires_all_done() {
        let mut db = db_with(2);
        db.claim((0, 0), 1);
        db.complete((0, 0), 1.0, 1, 0);
        assert!(!db.workload_complete(0));
        db.claim((0, 1), 1);
        db.complete((0, 1), 1.0, 2, -1); // failure still terminal
        assert!(db.workload_complete(0));
    }
}
