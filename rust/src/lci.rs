//! Local Controller Instance (LCI) — chunk execution model (§II-E-1).
//!
//! Each spot instance runs an LCI that downloads a chunk's inputs,
//! executes the user code per item, uploads the results and writes
//! per-task duration measurements to the task DB. Here the execution is
//! simulated: the chunk duration is deadband + Σ(item compute) + transfer
//! time, and the per-item measured CUS is the chunk's occupied time
//! divided over its items (exactly what a wall-clock measuring LCI would
//! report — including the deadband distortion the paper discusses).

use crate::sim::SimTime;
use crate::storage::ObjectStore;
use crate::workload::WorkloadSpec;

/// One chunk of tasks assigned to an instance.
#[derive(Debug, Clone)]
pub struct Chunk {
    pub id: u64,
    pub workload: usize,
    pub instance: u64,
    /// Task indices in the workload.
    pub tasks: Vec<usize>,
    /// True when this is a footprinting chunk (biased sampling).
    pub footprint: bool,
    pub started_at: SimTime,
}

/// Result of executing a chunk.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkResult {
    /// Total occupied seconds (compute + deadband + transfer).
    pub busy_s: f64,
    /// Per-task measured CUS, aligned with `Chunk::tasks` (the LCI's DB
    /// rows): each task's compute time plus its equal share of deadband
    /// and transfer overhead.
    pub per_task_cus: Vec<f64>,
    /// Exit code (0 normal; the simulator never crashes user code, but
    /// the field keeps the DB schema honest).
    pub exit_code: i32,
}

/// Execute a chunk of `spec`'s tasks. `footprint_bias` multiplies item
/// durations in footprinting chunks (non-representative sampling, §II-E-1).
pub fn execute_chunk(
    spec: &WorkloadSpec,
    tasks: &[usize],
    footprint: bool,
    storage: &ObjectStore,
) -> ChunkResult {
    let model = spec.app_model();
    let bias = if footprint { model.footprint_bias } else { 1.0 };
    let mut compute: Vec<f64> = Vec::with_capacity(tasks.len());
    let mut bytes: u64 = 0;
    for &t in tasks {
        let task = &spec.tasks[t];
        compute.push(task.true_cus * bias);
        // inputs down + results up (~30 % of input size back)
        bytes += task.bytes + (task.bytes as f64 * 0.3) as u64;
    }
    // two storage requests per task (get input, put result)
    let transfer = storage.transfer_time(bytes, 2 * tasks.len() as u64);
    let total_compute: f64 = compute.iter().sum();
    let busy = model.deadband_s + total_compute + transfer;
    // the LCI measures wall time per task: its own compute plus an equal
    // share of the shared overheads
    let overhead_share = (model.deadband_s + transfer) / tasks.len().max(1) as f64;
    let per_task_cus = compute.iter().map(|c| c + overhead_share).collect();
    ChunkResult { busy_s: busy, per_task_cus, exit_code: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StorageCfg;
    use crate::util::rng::Rng;
    use crate::workload::{App, WorkloadSpec};

    fn setup(app: App, n: usize) -> (WorkloadSpec, ObjectStore) {
        let rng = Rng::new(3);
        let spec = WorkloadSpec::generate(0, app, n, None, &rng);
        (spec, ObjectStore::new(StorageCfg::default()))
    }

    #[test]
    fn busy_time_is_deadband_plus_compute_plus_transfer() {
        let (spec, storage) = setup(App::FaceDetection, 10);
        let tasks: Vec<usize> = (0..5).collect();
        let r = execute_chunk(&spec, &tasks, false, &storage);
        let compute: f64 = tasks.iter().map(|&t| spec.tasks[t].true_cus).sum();
        assert!(r.busy_s > compute, "must include overheads");
        let per_sum: f64 = r.per_task_cus.iter().sum();
        assert!((per_sum - r.busy_s).abs() < 1e-9, "per-task shares add to busy");
        assert_eq!(r.exit_code, 0);
    }

    #[test]
    fn footprint_bias_inflates_measurements() {
        let (spec, storage) = setup(App::Transcode, 10);
        let tasks = [0usize, 1, 2];
        let plain = execute_chunk(&spec, &tasks, false, &storage);
        let fp = execute_chunk(&spec, &tasks, true, &storage);
        // transcode bias 1.5: compute part scales, overheads don't
        assert!(fp.busy_s > plain.busy_s * 1.2);
    }

    #[test]
    fn deadband_distorts_small_chunks_most() {
        let (spec, storage) = setup(App::SiftMatlab, 100);
        let small = execute_chunk(&spec, &[0], false, &storage);
        let big_tasks: Vec<usize> = (0..50).collect();
        let big = execute_chunk(&spec, &big_tasks, false, &storage);
        let small_per = small.per_task_cus[0];
        let big_per = crate::util::stats::mean(&big.per_task_cus);
        // 30 s deadband over 1 item vs over 50 items
        assert!(
            small_per > big_per * 2.0,
            "small={small_per} big={big_per}: deadband must dominate single items"
        );
    }

    #[test]
    fn transfer_overhead_near_paper_fraction() {
        // across the four §V-A app classes, transfer should sit in the
        // vicinity of the paper's ~27 % of occupied time (we accept a
        // broad band; exact value depends on chunk composition)
        let mut fracs = vec![];
        for app in [App::FaceDetection, App::Transcode, App::Brisk] {
            let (spec, storage) = setup(app, 40);
            let tasks: Vec<usize> = (0..30).collect();
            let r = execute_chunk(&spec, &tasks, false, &storage);
            let compute: f64 = tasks.iter().map(|&t| spec.tasks[t].true_cus).sum();
            let model = spec.app_model();
            let transfer = r.busy_s - compute - model.deadband_s;
            fracs.push(transfer / r.busy_s);
        }
        let mean = crate::util::stats::mean(&fracs);
        assert!((0.10..0.45).contains(&mean), "mean transfer fraction {mean}");
    }

    #[test]
    fn per_task_alignment() {
        let (spec, storage) = setup(App::Brisk, 10);
        let tasks = [7usize, 2, 9];
        let r = execute_chunk(&spec, &tasks, false, &storage);
        assert_eq!(r.per_task_cus.len(), 3);
        // heavier true item -> heavier measurement (same overhead share)
        let t7 = spec.tasks[7].true_cus;
        let t2 = spec.tasks[2].true_cus;
        assert_eq!(r.per_task_cus[0] > r.per_task_cus[1], t7 > t2);
    }
}
