//! Command-line interface (hand-rolled: the offline vendor set has no
//! clap). Subcommands:
//!
//! ```text
//! dithen repro <exp|all>      regenerate a paper table/figure (see list)
//! dithen run [options]        run the platform on the paper suite
//! dithen sweep <grid>         parallel experiment grid (cost|estimators|seeds)
//! dithen bench-report         measure tasks/s, write BENCH json
//! dithen list                 list experiment ids
//! dithen market               print current simulated spot prices
//! dithen --help
//! ```
//!
//! Common options: `--config <file>`, `--set k=v` (repeatable),
//! `--policy <aimd|reactive|mwa|lr|as1|as10>`, `--estimator
//! <kalman|adhoc|arma>`, `--ttc <seconds>`, `--seed <n>`, `--native`,
//! `--threads <n>`, `--out <file>`.

use crate::config::Config;
use crate::coordinator::PolicyKind;
use crate::estimation::EstimatorKind;
use crate::platform::{Platform, RunOpts};
use crate::workload::paper_suite;

pub const USAGE: &str = "\
dithen — Computation-as-a-Service control plane (TCC 2016 reproduction)

USAGE:
    dithen <COMMAND> [OPTIONS]

COMMANDS:
    repro <exp|all>   regenerate a paper table/figure (fig5..fig12, table2..table5)
    run               run the platform on the 30-workload paper suite
    sweep <grid>      run an experiment grid across cores: cost | estimators | seeds
    bench-report      measure end-to-end tasks/s + DB ops/s, write a JSON report
    list              list experiment ids
    market            print the simulated spot-price snapshot

OPTIONS:
    --config <file>        load a TOML config
    --set <section.key=v>  override one config value (repeatable)
    --policy <p>           aimd | reactive | mwa | lr | as1 | as10
    --estimator <e>        kalman | adhoc | arma
    --ttc <seconds>        fixed per-workload TTC (0 = best effort)
    --seed <n>             master seed
    --native               force the native estimator bank (skip XLA)
    --threads <n>          worker threads for sweep/bench-report (default: cores)
    --out <file>           bench-report output path (default: BENCH_PR1.json)
    --smoke                bench-report: tiny CI-sized grid instead of the full one
    -h, --help             show this help
";

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Cli {
    pub command: String,
    pub arg: Option<String>,
    pub config_file: Option<String>,
    pub overrides: Vec<String>,
    pub policy: Option<String>,
    pub estimator: Option<String>,
    pub ttc: Option<u64>,
    pub seed: Option<u64>,
    pub native: bool,
    pub threads: Option<usize>,
    pub out: Option<String>,
    pub smoke: bool,
    pub help: bool,
}

#[derive(Debug, PartialEq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// Parse an argv (excluding argv[0]).
pub fn parse(args: &[String]) -> Result<Cli, CliError> {
    let mut cli = Cli::default();
    let mut it = args.iter().peekable();
    let need_value = |it: &mut std::iter::Peekable<std::slice::Iter<String>>,
                          flag: &str|
     -> Result<String, CliError> {
        it.next()
            .cloned()
            .ok_or_else(|| CliError(format!("missing value for {flag}")))
    };
    while let Some(a) = it.next() {
        match a.as_str() {
            "-h" | "--help" => cli.help = true,
            "--config" => cli.config_file = Some(need_value(&mut it, "--config")?),
            "--set" => cli.overrides.push(need_value(&mut it, "--set")?),
            "--policy" => cli.policy = Some(need_value(&mut it, "--policy")?),
            "--estimator" => cli.estimator = Some(need_value(&mut it, "--estimator")?),
            "--ttc" => {
                let v = need_value(&mut it, "--ttc")?;
                cli.ttc = Some(v.parse().map_err(|_| CliError(format!("bad --ttc '{v}'")))?);
            }
            "--seed" => {
                let v = need_value(&mut it, "--seed")?;
                cli.seed = Some(v.parse().map_err(|_| CliError(format!("bad --seed '{v}'")))?);
            }
            "--native" => cli.native = true,
            "--threads" => {
                let v = need_value(&mut it, "--threads")?;
                cli.threads =
                    Some(v.parse().map_err(|_| CliError(format!("bad --threads '{v}'")))?);
            }
            "--out" => cli.out = Some(need_value(&mut it, "--out")?),
            "--smoke" => cli.smoke = true,
            flag if flag.starts_with('-') => {
                return Err(CliError(format!("unknown flag '{flag}'")));
            }
            cmd if cli.command.is_empty() => cli.command = cmd.to_string(),
            arg if cli.arg.is_none() => cli.arg = Some(arg.to_string()),
            extra => return Err(CliError(format!("unexpected argument '{extra}'"))),
        }
    }
    Ok(cli)
}

pub fn parse_policy(s: &str) -> Result<PolicyKind, CliError> {
    Ok(match s {
        "aimd" => PolicyKind::Aimd,
        "reactive" => PolicyKind::Reactive,
        "mwa" => PolicyKind::Mwa,
        "lr" => PolicyKind::Lr,
        "as1" => PolicyKind::AmazonAs1,
        "as10" => PolicyKind::AmazonAs10,
        other => return Err(CliError(format!("unknown policy '{other}'"))),
    })
}

pub fn parse_estimator(s: &str) -> Result<EstimatorKind, CliError> {
    Ok(match s {
        "kalman" => EstimatorKind::Kalman,
        "adhoc" => EstimatorKind::AdHoc,
        "arma" => EstimatorKind::Arma,
        other => return Err(CliError(format!("unknown estimator '{other}'"))),
    })
}

/// Build the effective config from CLI flags.
pub fn build_config(cli: &Cli) -> anyhow::Result<Config> {
    let mut cfg = match &cli.config_file {
        Some(f) => Config::load_file(f)?,
        None => Config::paper_defaults(),
    };
    for ov in &cli.overrides {
        cfg.apply_override(ov)?;
    }
    if let Some(seed) = cli.seed {
        cfg.seed = seed;
    }
    if cli.native {
        cfg.use_xla = false;
    }
    Ok(cfg)
}

/// Entry point used by main().
pub fn main_with(args: &[String]) -> anyhow::Result<i32> {
    let cli = match parse(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            return Ok(2);
        }
    };
    if cli.help || cli.command.is_empty() {
        println!("{USAGE}");
        return Ok(0);
    }
    let cfg = build_config(&cli)?;
    match cli.command.as_str() {
        "list" => {
            for id in crate::experiments::ALL {
                println!("{id}");
            }
        }
        "repro" => {
            let what = cli.arg.as_deref().unwrap_or("all");
            if what == "all" {
                crate::experiments::run_all(&cfg)?;
            } else {
                crate::experiments::run(what, &cfg)?;
            }
        }
        "run" => {
            let opts = RunOpts {
                policy: cli
                    .policy
                    .as_deref()
                    .map(parse_policy)
                    .transpose()?
                    .unwrap_or(PolicyKind::Aimd),
                estimator: cli
                    .estimator
                    .as_deref()
                    .map(parse_estimator)
                    .transpose()?
                    .unwrap_or(EstimatorKind::Kalman),
                fixed_ttc_s: match cli.ttc {
                    Some(0) => None,
                    Some(t) => Some(t),
                    None => Some(crate::experiments::cost::TTC_LONG_S),
                },
                horizon_s: 24 * 3600,
                ..Default::default()
            };
            let suite = paper_suite(cfg.seed);
            let n_tasks: usize = suite.iter().map(|w| w.n_tasks()).sum();
            let platform = Platform::new(cfg.clone(), suite, opts.clone());
            println!(
                "running {} workloads / {} tasks | policy={:?} estimator={:?} backend={}",
                30,
                n_tasks,
                opts.policy,
                opts.estimator,
                platform.backend_name()
            );
            let m = platform.run()?;
            println!(
                "done at {} | cost ${:.3} (LB ${:.3}) | max instances {} | TTC compliance {:.0}% | ticks {} @ {:.1} µs",
                crate::util::table::fmt_hm(m.finished_at as f64),
                m.total_cost,
                m.lower_bound_cost(cfg.market.base_spot_price),
                m.max_instances,
                100.0 * m.ttc_compliance(),
                m.ticks,
                m.mean_tick_ns() / 1000.0
            );
        }
        "sweep" => {
            let grid = cli.arg.as_deref().unwrap_or("cost");
            let threads = cli
                .threads
                .unwrap_or_else(crate::experiments::parallel::default_threads);
            crate::experiments::parallel::run_sweep(grid, &cfg, threads)?;
        }
        "bench-report" => {
            let threads = cli
                .threads
                .unwrap_or_else(crate::experiments::parallel::default_threads);
            let out = cli.out.as_deref().unwrap_or("BENCH_PR1.json");
            crate::experiments::bench_report::run(&cfg, threads, out, cli.smoke)?;
        }
        "market" => {
            crate::experiments::market::run_table5(&cfg)?;
        }
        other => {
            eprintln!("error: unknown command '{other}'\n\n{USAGE}");
            return Ok(2);
        }
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_repro_command() {
        let c = parse(&argv("repro fig8 --seed 7 --native")).unwrap();
        assert_eq!(c.command, "repro");
        assert_eq!(c.arg.as_deref(), Some("fig8"));
        assert_eq!(c.seed, Some(7));
        assert!(c.native);
    }

    #[test]
    fn parses_run_with_options() {
        let c = parse(&argv("run --policy mwa --estimator arma --ttc 5820")).unwrap();
        assert_eq!(c.policy.as_deref(), Some("mwa"));
        assert_eq!(c.estimator.as_deref(), Some("arma"));
        assert_eq!(c.ttc, Some(5820));
    }

    #[test]
    fn parses_sweep_and_bench_flags() {
        let c = parse(&argv("sweep cost --threads 8")).unwrap();
        assert_eq!(c.command, "sweep");
        assert_eq!(c.arg.as_deref(), Some("cost"));
        assert_eq!(c.threads, Some(8));
        let c = parse(&argv("bench-report --out out/bench.json --threads 2 --smoke")).unwrap();
        assert_eq!(c.command, "bench-report");
        assert_eq!(c.out.as_deref(), Some("out/bench.json"));
        assert!(c.smoke);
        assert!(parse(&argv("bench-report --threads two")).is_err());
    }

    #[test]
    fn rejects_unknown_flag() {
        assert!(parse(&argv("run --bogus")).is_err());
        assert!(parse(&argv("run --ttc notanumber")).is_err());
        assert!(parse(&argv("repro fig8 extra-arg")).is_err());
    }

    #[test]
    fn policy_and_estimator_names() {
        assert_eq!(parse_policy("aimd").unwrap(), PolicyKind::Aimd);
        assert_eq!(parse_policy("as10").unwrap(), PolicyKind::AmazonAs10);
        assert!(parse_policy("nope").is_err());
        assert_eq!(parse_estimator("arma").unwrap(), EstimatorKind::Arma);
        assert!(parse_estimator("nope").is_err());
    }

    #[test]
    fn config_overrides_apply() {
        let c = parse(&argv("run --set control.alpha=7 --seed 3")).unwrap();
        let cfg = build_config(&c).unwrap();
        assert_eq!(cfg.control.alpha, 7.0);
        assert_eq!(cfg.seed, 3);
    }
}
