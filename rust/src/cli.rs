//! Command-line interface (hand-rolled: the offline vendor set has no
//! clap). Subcommands:
//!
//! ```text
//! dithen repro <exp|all>      regenerate a paper table/figure (see list)
//! dithen run [options]        run the platform on the paper suite
//! dithen scenario [options]   run a composed scenario (backend/fault/arrivals)
//! dithen sweep <grid>         parallel experiment grid (see SWEEP_GRIDS / --help)
//! dithen bench-report         measure tasks/s, write BENCH json
//! dithen bench-check          gate: compare two bench reports, exit 1 on regression
//! dithen serve                resident CaaS daemon: HTTP submission, SSE, Prometheus
//! dithen list                 list experiment ids
//! dithen market               print current simulated spot prices
//! dithen --help
//! ```
//!
//! Common options: `--config <file>`, `--set k=v` (repeatable),
//! `--policy <aimd|pid|mpc|reactive|mwa|lr|as1|as10>`, `--estimator
//! <kalman|adhoc|arma|ewma|reactive>`, `--ttc <seconds>`, `--seed <n>`, `--native`,
//! `--threads <n>`, `--out <file>`. Scenario options: `--backend
//! <spot|ondemand|lambda>`, `--fleet <type[:bid=P],..>`, `--fault
//! <none|reclaim:BID|reclaim-pools|reclaim-at:T,..>`,
//! `--arrivals <fixed:S|burst:NxGAP|poisson:MEAN>`, `--workloads <n>`,
//! `--tasks <n>`, `--horizon <s>`, `--no-traces`,
//! `--stream <workloads>x<tasks>` (lazy arrival-time materialization +
//! shard retirement).

use crate::cloud::{BackendKind, FleetSpec};
use crate::config::Config;
use crate::coordinator::PolicyKind;
use crate::estimation::EstimatorKind;
use crate::platform::{ArrivalProcess, FaultSpec, Platform, RunOpts, ScenarioBuilder, StreamSpec};
use crate::util::rng::Rng;
use crate::workload::{paper_suite, App, WorkloadSpec};

/// Help-text template. The sweep-grid list is spliced in by [`usage`]
/// from [`crate::experiments::parallel::SWEEP_GRIDS`] — the same const
/// `run_sweep` dispatches on — so the help can never drift from the
/// grids the command actually accepts (a unit test pins this).
const USAGE_TEMPLATE: &str = "\
dithen — Computation-as-a-Service control plane (TCC 2016 reproduction)

USAGE:
    dithen <COMMAND> [OPTIONS]

COMMANDS:
    repro <exp|all>   regenerate a paper table/figure (fig5..fig12, table2..table5)
    run               run the platform on the 30-workload paper suite
    scenario          run a composed scenario: pluggable backend, arrivals, faults
    sweep <grid>      run an experiment grid across cores:
                      {sweep-grids}
    bench-report      measure end-to-end tasks/s + DB ops/s, write a JSON report
    bench-check       regression gate: exit 1 if --current tasks/s < tolerance x --baseline
    serve             resident CaaS daemon: POST /submit + /advance, GET /status/{w},
                      /metrics (Prometheus), /events (SSE), /healthz
    list              list experiment ids
    market            print the simulated spot-price snapshot

OPTIONS:
    --config <file>        load a TOML config
    --set <section.key=v>  override one config value (repeatable)
    --policy <p>           aimd | pid | mpc | reactive | mwa | lr | as1 | as10
    --estimator <e>        kalman | adhoc | arma | ewma | reactive
    --ttc <seconds>        fixed per-workload TTC (0 = best effort)
    --seed <n>             master seed
    --native               force the native estimator bank (skip XLA)
    --threads <n[,n..]>    worker threads (default: cores); bench-report takes a
                           comma list and measures one pass per width (scaling
                           curve), sweep uses the max
    --batched              sweep: lockstep batched executor (one padded bank
                           execution across same-shape cells; bit-identical)
    --out <file>           bench-report output path (default: BENCH_PR1.json)
    --smoke                bench-report/scenario/sweep: tiny CI-sized run (sweep
                           stream keeps only the 100k-task cell)
    --baseline <file>      bench-check: the reference bench-report JSON
    --current <file>       bench-check: the freshly measured bench-report JSON
    --tolerance <ratio>    bench-check: minimum current/baseline tasks/s (default 0.8)

SCENARIO OPTIONS:
    --backend <b>          spot (default) | ondemand | lambda
    --fleet <spec>         per-type pools: <type[:bid=$/hr]>,... over the Table V
                           names (default m3.medium), e.g.
                           m3.medium:bid=0.0085,m4.10xlarge:bid=0.6
    --fault <f>            none (default) | reclaim:<bid $/hr> | reclaim-pools
                           (each pool revoked on its own bid) | reclaim-at:<t1,t2,...>
                           | straggler:<frac>x<slowdown> (seeded fraction of
                           instances runs chunks <slowdown>x slower; speculative
                           re-execution arms) | crash:<rate> (per-chunk transient
                           failure hazard per wall-second; retry with backoff)
                           | flake:<prob>+<delay_s> (fulfilled requests fail to
                           boot and re-request after delay)
    --arrivals <a>         fixed:<gap_s> | burst:<n>x<gap_s> | poisson:<mean_gap_s>
    --workloads <n>        generated workload count (default 6; smoke 3)
    --tasks <n>            tasks per generated workload (default 120; smoke 40)
    --horizon <s>          hard stop in sim seconds
    --no-traces            skip estimator-trace recording (sweep-style)
    --stream <n>x<m>       stream n workloads of m tasks: lazy arrival-time
                           materialization + shard retirement (implies --native;
                           replaces the eager --workloads/--tasks suite)
    -h, --help             show this help

SERVE OPTIONS (plus the scenario options above for the template):
    --port <n>             listen port on 127.0.0.1 (default 8080)
    --pace <speed>         paced clock: sim-seconds per wall-second; without it
                           the clock is scripted and only moves on POST /advance
";

/// Render the help text; the sweep-grid list comes from its single
/// source of truth, [`crate::experiments::parallel::SWEEP_GRIDS`].
pub fn usage() -> String {
    USAGE_TEMPLATE
        .replace("{sweep-grids}", &crate::experiments::parallel::SWEEP_GRIDS.join(" | "))
}

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Cli {
    pub command: String,
    pub arg: Option<String>,
    pub config_file: Option<String>,
    pub overrides: Vec<String>,
    pub policy: Option<String>,
    pub estimator: Option<String>,
    pub ttc: Option<u64>,
    pub seed: Option<u64>,
    pub native: bool,
    /// `--threads` accepts a comma list (`--threads 1,2,4,8`):
    /// bench-report measures one pass per width (a scaling curve);
    /// sweep, the one single-width consumer, uses the max.
    pub threads: Option<Vec<usize>>,
    pub batched: bool,
    pub out: Option<String>,
    pub smoke: bool,
    pub baseline: Option<String>,
    pub current: Option<String>,
    pub tolerance: Option<f64>,
    pub backend: Option<String>,
    pub fleet: Option<String>,
    pub fault: Option<String>,
    pub arrivals: Option<String>,
    pub workloads: Option<usize>,
    pub tasks: Option<usize>,
    pub horizon: Option<u64>,
    pub no_traces: bool,
    /// `--stream <workloads>x<tasks>`: scenario streams its suite
    /// instead of materializing it up front.
    pub stream: Option<String>,
    pub port: Option<u16>,
    pub pace: Option<f64>,
    pub help: bool,
}

#[derive(Debug, PartialEq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// Parse an argv (excluding argv[0]).
pub fn parse(args: &[String]) -> Result<Cli, CliError> {
    let mut cli = Cli::default();
    let mut it = args.iter().peekable();
    let need_value = |it: &mut std::iter::Peekable<std::slice::Iter<String>>,
                          flag: &str|
     -> Result<String, CliError> {
        it.next()
            .cloned()
            .ok_or_else(|| CliError(format!("missing value for {flag}")))
    };
    while let Some(a) = it.next() {
        match a.as_str() {
            "-h" | "--help" => cli.help = true,
            "--config" => cli.config_file = Some(need_value(&mut it, "--config")?),
            "--set" => cli.overrides.push(need_value(&mut it, "--set")?),
            "--policy" => cli.policy = Some(need_value(&mut it, "--policy")?),
            "--estimator" => cli.estimator = Some(need_value(&mut it, "--estimator")?),
            "--ttc" => {
                let v = need_value(&mut it, "--ttc")?;
                cli.ttc = Some(v.parse().map_err(|_| CliError(format!("bad --ttc '{v}'")))?);
            }
            "--seed" => {
                let v = need_value(&mut it, "--seed")?;
                cli.seed = Some(v.parse().map_err(|_| CliError(format!("bad --seed '{v}'")))?);
            }
            "--native" => cli.native = true,
            "--threads" => {
                let v = need_value(&mut it, "--threads")?;
                cli.threads = Some(parse_threads(&v)?);
            }
            "--batched" => cli.batched = true,
            "--out" => cli.out = Some(need_value(&mut it, "--out")?),
            "--smoke" => cli.smoke = true,
            "--baseline" => cli.baseline = Some(need_value(&mut it, "--baseline")?),
            "--current" => cli.current = Some(need_value(&mut it, "--current")?),
            "--tolerance" => {
                let v = need_value(&mut it, "--tolerance")?;
                cli.tolerance =
                    Some(v.parse().map_err(|_| CliError(format!("bad --tolerance '{v}'")))?);
            }
            "--backend" => cli.backend = Some(need_value(&mut it, "--backend")?),
            "--fleet" => cli.fleet = Some(need_value(&mut it, "--fleet")?),
            "--fault" => cli.fault = Some(need_value(&mut it, "--fault")?),
            "--arrivals" => cli.arrivals = Some(need_value(&mut it, "--arrivals")?),
            "--workloads" => {
                let v = need_value(&mut it, "--workloads")?;
                cli.workloads =
                    Some(v.parse().map_err(|_| CliError(format!("bad --workloads '{v}'")))?);
            }
            "--tasks" => {
                let v = need_value(&mut it, "--tasks")?;
                cli.tasks = Some(v.parse().map_err(|_| CliError(format!("bad --tasks '{v}'")))?);
            }
            "--horizon" => {
                let v = need_value(&mut it, "--horizon")?;
                cli.horizon =
                    Some(v.parse().map_err(|_| CliError(format!("bad --horizon '{v}'")))?);
            }
            "--no-traces" => cli.no_traces = true,
            "--stream" => cli.stream = Some(need_value(&mut it, "--stream")?),
            "--port" => {
                let v = need_value(&mut it, "--port")?;
                cli.port = Some(v.parse().map_err(|_| CliError(format!("bad --port '{v}'")))?);
            }
            "--pace" => {
                let v = need_value(&mut it, "--pace")?;
                let speed: f64 = v.parse().map_err(|_| CliError(format!("bad --pace '{v}'")))?;
                if speed.is_nan() || speed <= 0.0 {
                    return Err(CliError("--pace must be a positive speed".into()));
                }
                cli.pace = Some(speed);
            }
            flag if flag.starts_with('-') => {
                return Err(CliError(format!("unknown flag '{flag}'")));
            }
            cmd if cli.command.is_empty() => cli.command = cmd.to_string(),
            arg if cli.arg.is_none() => cli.arg = Some(arg.to_string()),
            extra => return Err(CliError(format!("unexpected argument '{extra}'"))),
        }
    }
    Ok(cli)
}

/// Parse `--threads`: a single width or a comma list of widths
/// (`1,2,4,8`), each >= 1.
pub fn parse_threads(s: &str) -> Result<Vec<usize>, CliError> {
    let widths: Result<Vec<usize>, CliError> = s
        .split(',')
        .map(|t| {
            let t = t.trim();
            t.parse::<usize>().map_err(|_| CliError(format!("bad --threads value '{t}'")))
        })
        .collect();
    let widths = widths?;
    // split(',') always yields at least one token (an empty one fails
    // the parse above), so only the zero-width case remains to reject
    if widths.contains(&0) {
        return Err(CliError("--threads widths must be >= 1".into()));
    }
    Ok(widths)
}

pub fn parse_policy(s: &str) -> Result<PolicyKind, CliError> {
    Ok(match s {
        "aimd" => PolicyKind::Aimd,
        "pid" => PolicyKind::Pid,
        "mpc" => PolicyKind::Mpc,
        "reactive" => PolicyKind::Reactive,
        "mwa" => PolicyKind::Mwa,
        "lr" => PolicyKind::Lr,
        "as1" => PolicyKind::AmazonAs1,
        "as10" => PolicyKind::AmazonAs10,
        other => return Err(CliError(format!("unknown policy '{other}'"))),
    })
}

pub fn parse_estimator(s: &str) -> Result<EstimatorKind, CliError> {
    Ok(match s {
        "kalman" => EstimatorKind::Kalman,
        "adhoc" => EstimatorKind::AdHoc,
        "arma" => EstimatorKind::Arma,
        "ewma" => EstimatorKind::Ewma,
        "reactive" => EstimatorKind::Reactive,
        other => return Err(CliError(format!("unknown estimator '{other}'"))),
    })
}

pub fn parse_backend(s: &str) -> Result<BackendKind, CliError> {
    Ok(match s {
        "spot" => BackendKind::Spot,
        "ondemand" | "on-demand" => BackendKind::OnDemand,
        "lambda" => BackendKind::Lambda,
        other => return Err(CliError(format!("unknown backend '{other}'"))),
    })
}

pub fn parse_fleet(s: &str) -> Result<FleetSpec, CliError> {
    FleetSpec::parse(s).map_err(CliError)
}

pub fn parse_fault(s: &str) -> Result<FaultSpec, CliError> {
    if s == "none" {
        return Ok(FaultSpec::None);
    }
    if s == "reclaim-pools" {
        return Ok(FaultSpec::PoolReclamation);
    }
    if let Some(bid) = s.strip_prefix("reclaim:") {
        let bid: f64 = bid
            .parse()
            .map_err(|_| CliError(format!("bad reclaim bid '{bid}'")))?;
        if bid.is_nan() || bid < 0.0 {
            return Err(CliError("reclaim bid must be a non-negative $/hr price".into()));
        }
        return Ok(FaultSpec::SpotReclamation { bid });
    }
    if let Some(times) = s.strip_prefix("reclaim-at:") {
        let times: Result<Vec<u64>, _> = times.split(',').map(|t| t.trim().parse()).collect();
        let times = times.map_err(|_| CliError(format!("bad reclaim-at times in '{s}'")))?;
        if times.is_empty() {
            return Err(CliError("reclaim-at needs at least one instant".into()));
        }
        return Ok(FaultSpec::ReclamationAt { times });
    }
    if let Some(rest) = s.strip_prefix("straggler:") {
        let (frac, slowdown) = rest.split_once('x').ok_or_else(|| {
            CliError(format!("straggler needs '<frac>x<slowdown>' (e.g. 0.2x4), got '{rest}'"))
        })?;
        let frac: f64 =
            frac.parse().map_err(|_| CliError(format!("bad straggler fraction '{frac}'")))?;
        let slowdown: f64 = slowdown
            .parse()
            .map_err(|_| CliError(format!("bad straggler slowdown '{slowdown}'")))?;
        if frac.is_nan() || !(0.0..=1.0).contains(&frac) {
            return Err(CliError("straggler fraction must be in [0, 1]".into()));
        }
        if slowdown.is_nan() || slowdown < 1.0 {
            return Err(CliError("straggler slowdown must be >= 1".into()));
        }
        return Ok(FaultSpec::Straggler { frac, slowdown });
    }
    if let Some(rate) = s.strip_prefix("crash:") {
        let rate: f64 =
            rate.parse().map_err(|_| CliError(format!("bad crash rate '{rate}'")))?;
        if rate.is_nan() || !(0.0..=1.0).contains(&rate) {
            return Err(CliError("crash rate must be a per-second hazard in [0, 1]".into()));
        }
        return Ok(FaultSpec::ChunkCrash { rate });
    }
    if let Some(rest) = s.strip_prefix("flake:") {
        let (prob, delay) = rest.split_once('+').ok_or_else(|| {
            CliError(format!("flake needs '<prob>+<delay_s>' (e.g. 0.3+120), got '{rest}'"))
        })?;
        let prob: f64 =
            prob.parse().map_err(|_| CliError(format!("bad flake probability '{prob}'")))?;
        let delay_s: u64 =
            delay.parse().map_err(|_| CliError(format!("bad flake delay '{delay}'")))?;
        if prob.is_nan() || !(0.0..=1.0).contains(&prob) {
            return Err(CliError("flake probability must be in [0, 1]".into()));
        }
        return Ok(FaultSpec::LaunchFlake { prob, delay_s });
    }
    Err(CliError(format!(
        "unknown fault '{s}' (use none | reclaim:<bid> | reclaim-pools | reclaim-at:<t1,t2,...> \
         | straggler:<frac>x<slowdown> | crash:<rate> | flake:<prob>+<delay_s>)"
    )))
}

/// Parse `--stream <workloads>x<tasks>` (e.g. `1000x100`).
pub fn parse_stream(s: &str) -> Result<(usize, usize), CliError> {
    let (n, t) = s
        .split_once('x')
        .ok_or_else(|| CliError(format!("--stream needs '<workloads>x<tasks>', got '{s}'")))?;
    let n_workloads: usize =
        n.parse().map_err(|_| CliError(format!("bad stream workload count '{n}'")))?;
    let tasks: usize =
        t.parse().map_err(|_| CliError(format!("bad stream task count '{t}'")))?;
    if n_workloads == 0 || tasks == 0 {
        return Err(CliError("--stream dimensions must be >= 1".into()));
    }
    Ok((n_workloads, tasks))
}

pub fn parse_arrivals(s: &str) -> Result<ArrivalProcess, CliError> {
    if let Some(gap) = s.strip_prefix("fixed:") {
        let interval_s: u64 = gap
            .parse()
            .map_err(|_| CliError(format!("bad fixed arrival gap '{gap}'")))?;
        return Ok(ArrivalProcess::FixedInterval { interval_s });
    }
    if let Some(spec) = s.strip_prefix("burst:") {
        let (n, gap) = spec
            .split_once('x')
            .ok_or_else(|| CliError(format!("burst arrivals need '<n>x<gap_s>', got '{spec}'")))?;
        let burst: usize =
            n.parse().map_err(|_| CliError(format!("bad burst size '{n}'")))?;
        let gap_s: u64 =
            gap.parse().map_err(|_| CliError(format!("bad burst gap '{gap}'")))?;
        if burst == 0 {
            return Err(CliError("burst size must be >= 1".into()));
        }
        return Ok(ArrivalProcess::Bursty { burst, gap_s });
    }
    if let Some(mean) = s.strip_prefix("poisson:") {
        let mean_gap_s: f64 = mean
            .parse()
            .map_err(|_| CliError(format!("bad poisson mean gap '{mean}'")))?;
        if mean_gap_s.is_nan() || mean_gap_s <= 0.0 {
            return Err(CliError("poisson mean gap must be > 0".into()));
        }
        return Ok(ArrivalProcess::Poisson { mean_gap_s });
    }
    Err(CliError(format!(
        "unknown arrivals '{s}' (use fixed:<gap_s> | burst:<n>x<gap_s> | poisson:<mean_gap_s>)"
    )))
}

/// Build the effective config from CLI flags.
pub fn build_config(cli: &Cli) -> anyhow::Result<Config> {
    let mut cfg = match &cli.config_file {
        Some(f) => Config::load_file(f)?,
        None => Config::paper_defaults(),
    };
    for ov in &cli.overrides {
        cfg.apply_override(ov)?;
    }
    if let Some(seed) = cli.seed {
        cfg.seed = seed;
    }
    if cli.native {
        cfg.use_xla = false;
    }
    Ok(cfg)
}

/// `dithen scenario`: assemble + run one scenario from flags. Returns
/// the process exit code (non-zero when a smoke run leaves workloads
/// incomplete, so CI can gate on it).
fn run_scenario(cli: &Cli, mut cfg: Config) -> anyhow::Result<i32> {
    let smoke = cli.smoke;
    if smoke {
        // CI-sized determinstic run: small suite, native bank, a
        // scripted mid-run reclamation so the requeue path is exercised
        cfg.use_xla = false;
        cfg.control.n_min = 4.0;
    }
    let stream = cli.stream.as_deref().map(parse_stream).transpose()?;
    if stream.is_some() {
        // streamed admissions grow the estimator bank one lane at a
        // time, which is native-only (XLA executables are shape-compiled)
        cfg.use_xla = false;
    }
    let n_wl = cli.workloads.unwrap_or(if smoke { 3 } else { 6 });
    let tasks = cli.tasks.unwrap_or(if smoke { 40 } else { 120 });
    if n_wl == 0 || tasks == 0 {
        // a zero-task workload can never leave footprinting; reject the
        // input instead of ticking to the horizon
        anyhow::bail!("--workloads and --tasks must be >= 1");
    }
    let arrivals = match &cli.arrivals {
        Some(s) => parse_arrivals(s)?,
        None => ArrivalProcess::FixedInterval { interval_s: if smoke { 60 } else { 300 } },
    };
    let fault = match &cli.fault {
        Some(s) => parse_fault(s)?,
        None if smoke => FaultSpec::ReclamationAt { times: vec![900, 1800] },
        None => FaultSpec::None,
    };
    let backend = match &cli.backend {
        Some(s) => parse_backend(s)?,
        None => BackendKind::Spot,
    };
    let fleet = match &cli.fleet {
        Some(s) => parse_fleet(s)?,
        None => FleetSpec::default(),
    };
    let builder = ScenarioBuilder::new(cfg.clone())
        .fleet(fleet)
        .policy(cli.policy.as_deref().map(parse_policy).transpose()?.unwrap_or(PolicyKind::Aimd))
        .estimator(
            cli.estimator
                .as_deref()
                .map(parse_estimator)
                .transpose()?
                .unwrap_or(EstimatorKind::Kalman),
        )
        .fixed_ttc(match cli.ttc {
            Some(0) => None,
            Some(t) => Some(t),
            None => Some(3600),
        })
        .horizon(cli.horizon.unwrap_or(if smoke { 6 * 3600 } else { 24 * 3600 }))
        .arrivals(arrivals)
        .backend(backend)
        .fault(fault)
        .record_traces(!cli.no_traces);
    let scn = match stream {
        // streaming: workloads materialize lazily at their arrival
        // instants and retire (shard audit + slab recycling) once done
        Some((n_workloads, tasks_per_workload)) => builder
            .stream(StreamSpec { n_workloads, tasks_per_workload, app: App::FaceDetection })
            .retire_shards(true)
            .build(),
        None => {
            let rng = Rng::new(cfg.seed);
            let suite: Vec<WorkloadSpec> = (0..n_wl)
                .map(|i| WorkloadSpec::generate(i, App::FaceDetection, tasks, None, &rng))
                .collect();
            builder.workloads(suite).build()
        }
    };
    println!("scenario: {}", scn.describe());
    let streams = scn.stream.is_some();
    let pool_names: Vec<&'static str> = scn.fleet.pools.iter().map(|p| p.name()).collect();
    let m = scn.run()?;
    let done = m.outcomes.iter().filter(|o| o.completed_at.is_some()).count();
    println!(
        "done at {} | cost ${:.3} | max instances {} | TTC compliance {:.0}% | \
         completed {done}/{} workloads ({} tasks) | reclamations {} | requeued tasks {} | \
         unfulfilled requests {}",
        crate::util::table::fmt_hm(m.finished_at as f64),
        m.total_cost,
        m.max_instances,
        100.0 * m.ttc_compliance(),
        m.outcomes.len(),
        m.tasks_completed,
        m.reclamations,
        m.requeued_tasks,
        m.unfulfilled_requests,
    );
    // partial-failure receipts (PR-10); printed only when any fired so
    // the fault-free summary line set is unchanged
    if m.chunk_retries + m.speculative_launches + m.straggler_instances + m.tasks_abandoned > 0 {
        println!(
            "faults: chunk retries {} | speculative launches {} | straggler instances {} | \
             tasks abandoned {}",
            m.chunk_retries, m.speculative_launches, m.straggler_instances, m.tasks_abandoned,
        );
    }
    if m.reclamations_by_pool.len() > 1 {
        let per_pool: Vec<String> = pool_names
            .iter()
            .zip(&m.reclamations_by_pool)
            .map(|(name, n)| format!("{name}={n}"))
            .collect();
        println!("reclamations by pool: {}", per_pool.join(" "));
    }
    if streams {
        println!(
            "stream: peak {} live shards | peak arena {} bytes",
            m.peak_live_shards, m.peak_arena_bytes
        );
    }
    if smoke && done != m.outcomes.len() {
        let n = m.outcomes.len();
        eprintln!("error: smoke scenario left {}/{n} workloads incomplete", n - done);
        return Ok(1);
    }
    Ok(0)
}

/// `dithen serve`: run the resident daemon until SIGTERM/SIGINT or a
/// `POST /shutdown`, then print the final (drained) run summary.
fn run_serve(cli: &Cli, mut cfg: Config) -> anyhow::Result<i32> {
    use crate::serve::{ClockMode, Daemon, ServeOpts};
    // mid-run admission grows the estimator bank one row per workload,
    // which is native-only (XLA executables are shape-compiled)
    cfg.use_xla = false;
    let backend = match &cli.backend {
        Some(s) => parse_backend(s)?,
        None => BackendKind::Spot,
    };
    let fleet = match &cli.fleet {
        Some(s) => parse_fleet(s)?,
        None => FleetSpec::default(),
    };
    let fault = match &cli.fault {
        Some(s) => parse_fault(s)?,
        None => FaultSpec::None,
    };
    let template = ScenarioBuilder::new(cfg.clone())
        .policy(cli.policy.as_deref().map(parse_policy).transpose()?.unwrap_or(PolicyKind::Aimd))
        .estimator(
            cli.estimator
                .as_deref()
                .map(parse_estimator)
                .transpose()?
                .unwrap_or(EstimatorKind::Kalman),
        )
        // best-effort by default: each submission may carry its own ttc
        .fixed_ttc(match cli.ttc {
            Some(0) | None => None,
            Some(t) => Some(t),
        })
        .horizon(cli.horizon.unwrap_or(7 * 24 * 3600))
        .arrivals(ArrivalProcess::Scripted { times: vec![] })
        .backend(backend)
        .fleet(fleet)
        .fault(fault)
        .record_traces(!cli.no_traces)
        .build();
    let clock = match cli.pace {
        Some(speed) => ClockMode::Paced { speed },
        None => ClockMode::Scripted,
    };
    let opts = ServeOpts { template, clock, workload_seed: cfg.seed };
    crate::serve::install_signal_handlers();
    let handle = Daemon::spawn(opts, cli.port.unwrap_or(8080))?;
    println!(
        "dithen serve listening on {} | clock: {} | horizon: {}s",
        handle.base_url(),
        match cli.pace {
            Some(speed) => format!("paced x{speed}"),
            None => "scripted (POST /advance)".to_string(),
        },
        cli.horizon.unwrap_or(7 * 24 * 3600),
    );
    let m = handle.wait()?;
    println!(
        "drained at {} | cost ${:.3} | {} workloads ({} tasks) | reclamations {} | \
         requeued tasks {} | ticks {}",
        crate::util::table::fmt_hm(m.finished_at as f64),
        m.total_cost,
        m.outcomes.len(),
        m.tasks_completed,
        m.reclamations,
        m.requeued_tasks,
        m.ticks,
    );
    Ok(0)
}

/// Entry point used by main().
pub fn main_with(args: &[String]) -> anyhow::Result<i32> {
    let cli = match parse(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage());
            return Ok(2);
        }
    };
    if cli.help || cli.command.is_empty() {
        println!("{}", usage());
        return Ok(0);
    }
    let cfg = build_config(&cli)?;
    match cli.command.as_str() {
        "list" => {
            for id in crate::experiments::ALL {
                println!("{id}");
            }
        }
        "repro" => {
            let what = cli.arg.as_deref().unwrap_or("all");
            if what == "all" {
                crate::experiments::run_all(&cfg)?;
            } else {
                crate::experiments::run(what, &cfg)?;
            }
        }
        "run" => {
            let opts = RunOpts {
                policy: cli
                    .policy
                    .as_deref()
                    .map(parse_policy)
                    .transpose()?
                    .unwrap_or(PolicyKind::Aimd),
                estimator: cli
                    .estimator
                    .as_deref()
                    .map(parse_estimator)
                    .transpose()?
                    .unwrap_or(EstimatorKind::Kalman),
                fixed_ttc_s: match cli.ttc {
                    Some(0) => None,
                    Some(t) => Some(t),
                    None => Some(crate::experiments::cost::TTC_LONG_S),
                },
                horizon_s: 24 * 3600,
                record_traces: !cli.no_traces,
                ..Default::default()
            };
            let suite = paper_suite(cfg.seed);
            let n_tasks: usize = suite.iter().map(|w| w.n_tasks()).sum();
            let platform = Platform::new(cfg.clone(), suite, opts.clone());
            println!(
                "running {} workloads / {} tasks | policy={:?} estimator={:?} backend={}",
                30,
                n_tasks,
                opts.policy,
                opts.estimator,
                platform.backend_name()
            );
            let m = platform.run()?;
            println!(
                "done at {} | cost ${:.3} (LB ${:.3}) | max instances {} | TTC compliance {:.0}% | ticks {} @ {:.1} µs",
                crate::util::table::fmt_hm(m.finished_at as f64),
                m.total_cost,
                m.lower_bound_cost(cfg.market.base_spot_price),
                m.max_instances,
                100.0 * m.ttc_compliance(),
                m.ticks,
                m.mean_tick_ns() / 1000.0
            );
        }
        "scenario" => {
            return run_scenario(&cli, cfg);
        }
        "serve" => {
            return run_serve(&cli, cfg);
        }
        "sweep" => {
            let grid = cli.arg.as_deref().unwrap_or("cost");
            // single-width consumer: a comma list collapses to its max
            let threads = cli
                .threads
                .as_ref()
                .and_then(|v| v.iter().copied().max())
                .unwrap_or_else(crate::experiments::parallel::default_threads);
            crate::experiments::parallel::run_sweep(grid, &cfg, threads, cli.batched, cli.smoke)?;
        }
        "bench-report" => {
            let threads = cli
                .threads
                .clone()
                .unwrap_or_else(|| vec![crate::experiments::parallel::default_threads()]);
            let out = cli.out.as_deref().unwrap_or("BENCH_PR1.json");
            crate::experiments::bench_report::run(&cfg, &threads, out, cli.smoke)?;
        }
        "bench-check" => {
            let baseline = cli
                .baseline
                .as_deref()
                .ok_or_else(|| CliError("bench-check needs --baseline <json>".into()))?;
            let current = cli
                .current
                .as_deref()
                .ok_or_else(|| CliError("bench-check needs --current <json>".into()))?;
            return crate::experiments::bench_check::run(
                baseline,
                current,
                cli.tolerance.unwrap_or(0.8),
            );
        }
        "market" => {
            crate::experiments::market::run_table5(&cfg)?;
        }
        other => {
            eprintln!("error: unknown command '{other}'\n\n{}", usage());
            return Ok(2);
        }
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_repro_command() {
        let c = parse(&argv("repro fig8 --seed 7 --native")).unwrap();
        assert_eq!(c.command, "repro");
        assert_eq!(c.arg.as_deref(), Some("fig8"));
        assert_eq!(c.seed, Some(7));
        assert!(c.native);
    }

    #[test]
    fn parses_run_with_options() {
        let c = parse(&argv("run --policy mwa --estimator arma --ttc 5820")).unwrap();
        assert_eq!(c.policy.as_deref(), Some("mwa"));
        assert_eq!(c.estimator.as_deref(), Some("arma"));
        assert_eq!(c.ttc, Some(5820));
    }

    #[test]
    fn parses_sweep_and_bench_flags() {
        let c = parse(&argv("sweep cost --threads 8")).unwrap();
        assert_eq!(c.command, "sweep");
        assert_eq!(c.arg.as_deref(), Some("cost"));
        assert_eq!(c.threads, Some(vec![8]));
        assert!(!c.batched);
        let c = parse(&argv("sweep smoke --batched --threads 2")).unwrap();
        assert!(c.batched);
        assert_eq!(c.arg.as_deref(), Some("smoke"));
        let c = parse(&argv("bench-report --out out/bench.json --threads 2 --smoke")).unwrap();
        assert_eq!(c.command, "bench-report");
        assert_eq!(c.out.as_deref(), Some("out/bench.json"));
        assert!(c.smoke);
        assert!(parse(&argv("bench-report --threads two")).is_err());
    }

    #[test]
    fn threads_accepts_a_comma_list() {
        let c = parse(&argv("bench-report --threads 1,2,4,8")).unwrap();
        assert_eq!(c.threads, Some(vec![1, 2, 4, 8]));
        assert_eq!(parse_threads("4").unwrap(), vec![4]);
        assert_eq!(parse_threads(" 1, 2 ").unwrap(), vec![1, 2]);
        assert!(parse_threads("").is_err());
        assert!(parse_threads("1,").is_err());
        assert!(parse_threads("1,zero").is_err());
        assert!(parse_threads("0").is_err(), "zero-width pools are rejected");
    }

    #[test]
    fn parses_bench_check_flags() {
        let c = parse(&argv(
            "bench-check --baseline prev.json --current out/bench-ci.json --tolerance 0.75",
        ))
        .unwrap();
        assert_eq!(c.command, "bench-check");
        assert_eq!(c.baseline.as_deref(), Some("prev.json"));
        assert_eq!(c.current.as_deref(), Some("out/bench-ci.json"));
        assert_eq!(c.tolerance, Some(0.75));
        assert!(parse(&argv("bench-check --tolerance high")).is_err());
        assert!(parse(&argv("bench-check --baseline")).is_err(), "--baseline needs a value");
    }

    #[test]
    fn parses_scenario_flags() {
        let c = parse(&argv(
            "scenario --backend lambda --fault reclaim:0.009 --arrivals burst:3x600 \
             --workloads 4 --tasks 50 --horizon 7200 --no-traces",
        ))
        .unwrap();
        assert_eq!(c.command, "scenario");
        assert_eq!(c.backend.as_deref(), Some("lambda"));
        assert_eq!(c.fault.as_deref(), Some("reclaim:0.009"));
        assert_eq!(c.arrivals.as_deref(), Some("burst:3x600"));
        assert_eq!(c.workloads, Some(4));
        assert_eq!(c.tasks, Some(50));
        assert_eq!(c.horizon, Some(7200));
        assert!(c.no_traces);
        assert!(parse(&argv("scenario --workloads four")).is_err());
    }

    #[test]
    fn parses_fleet_flag() {
        let c = parse(&argv(
            "scenario --fleet m3.medium:bid=0.0085,m4.10xlarge:bid=0.6 --fault reclaim-pools",
        ))
        .unwrap();
        let fleet = parse_fleet(c.fleet.as_deref().unwrap()).unwrap();
        assert_eq!(fleet.pools.len(), 2);
        assert_eq!(fleet.pools[1].name(), "m4.10xlarge");
        assert_eq!(fleet.pools[1].bid, Some(0.6));
        assert!(parse_fleet("warp9.huge").is_err());
        assert!(parse(&argv("scenario --fleet")).is_err(), "--fleet needs a value");
    }

    #[test]
    fn parses_serve_flags() {
        let c = parse(&argv("serve --port 8787 --ttc 1500 --fault reclaim-at:300,420")).unwrap();
        assert_eq!(c.command, "serve");
        assert_eq!(c.port, Some(8787));
        assert_eq!(c.ttc, Some(1500));
        assert_eq!(c.fault.as_deref(), Some("reclaim-at:300,420"));
        assert_eq!(c.pace, None, "default clock is scripted");
        let c = parse(&argv("serve --pace 60")).unwrap();
        assert_eq!(c.pace, Some(60.0));
        assert!(parse(&argv("serve --port eighty")).is_err());
        assert!(parse(&argv("serve --pace 0")).is_err(), "pace must be positive");
        assert!(parse(&argv("serve --pace -2")).is_err());
        assert!(parse(&argv("serve --pace nan")).is_err());
    }

    #[test]
    fn rejects_unknown_flag() {
        assert!(parse(&argv("run --bogus")).is_err());
        assert!(parse(&argv("run --ttc notanumber")).is_err());
        assert!(parse(&argv("repro fig8 extra-arg")).is_err());
    }

    #[test]
    fn policy_and_estimator_names() {
        assert_eq!(parse_policy("aimd").unwrap(), PolicyKind::Aimd);
        assert_eq!(parse_policy("as10").unwrap(), PolicyKind::AmazonAs10);
        assert_eq!(parse_policy("pid").unwrap(), PolicyKind::Pid);
        assert_eq!(parse_policy("mpc").unwrap(), PolicyKind::Mpc);
        assert!(parse_policy("nope").is_err());
        assert_eq!(parse_estimator("arma").unwrap(), EstimatorKind::Arma);
        assert_eq!(parse_estimator("ewma").unwrap(), EstimatorKind::Ewma);
        assert_eq!(parse_estimator("reactive").unwrap(), EstimatorKind::Reactive);
        assert!(parse_estimator("nope").is_err());
    }

    #[test]
    fn backend_names() {
        assert_eq!(parse_backend("spot").unwrap(), BackendKind::Spot);
        assert_eq!(parse_backend("ondemand").unwrap(), BackendKind::OnDemand);
        assert_eq!(parse_backend("on-demand").unwrap(), BackendKind::OnDemand);
        assert_eq!(parse_backend("lambda").unwrap(), BackendKind::Lambda);
        assert!(parse_backend("gce").is_err());
    }

    #[test]
    fn fault_specs() {
        assert_eq!(parse_fault("none").unwrap(), FaultSpec::None);
        assert_eq!(parse_fault("reclaim-pools").unwrap(), FaultSpec::PoolReclamation);
        assert_eq!(
            parse_fault("reclaim:0.0085").unwrap(),
            FaultSpec::SpotReclamation { bid: 0.0085 }
        );
        assert_eq!(
            parse_fault("reclaim-at:600,1200").unwrap(),
            FaultSpec::ReclamationAt { times: vec![600, 1200] }
        );
        assert!(parse_fault("reclaim:abc").is_err());
        assert!(parse_fault("reclaim:nan").is_err());
        assert!(parse_fault("reclaim:-1").is_err());
        assert!(parse_fault("reclaim-at:").is_err());
        assert!(parse_fault("meteor").is_err());
    }

    #[test]
    fn partial_failure_fault_specs() {
        assert_eq!(
            parse_fault("straggler:0.2x4").unwrap(),
            FaultSpec::Straggler { frac: 0.2, slowdown: 4.0 }
        );
        assert_eq!(parse_fault("crash:0.01").unwrap(), FaultSpec::ChunkCrash { rate: 0.01 });
        assert_eq!(
            parse_fault("flake:0.3+120").unwrap(),
            FaultSpec::LaunchFlake { prob: 0.3, delay_s: 120 }
        );
        // boundary values round-trip
        assert_eq!(
            parse_fault("straggler:1x1").unwrap(),
            FaultSpec::Straggler { frac: 1.0, slowdown: 1.0 }
        );
        assert_eq!(parse_fault("crash:0").unwrap(), FaultSpec::ChunkCrash { rate: 0.0 });
        // malformed forms are named errors, never panics
        assert!(parse_fault("straggler:0.2").is_err()); // missing slowdown
        assert!(parse_fault("straggler:2x4").is_err()); // frac > 1
        assert!(parse_fault("straggler:0.2x0.5").is_err()); // slowdown < 1
        assert!(parse_fault("straggler:nanx4").is_err());
        assert!(parse_fault("crash:1.5").is_err());
        assert!(parse_fault("crash:-0.1").is_err());
        assert!(parse_fault("crash:nan").is_err());
        assert!(parse_fault("flake:0.3").is_err()); // missing delay
        assert!(parse_fault("flake:1.5+120").is_err());
        assert!(parse_fault("flake:0.3+-5").is_err());
        // the unknown-fault error now advertises the new grammar
        let err = parse_fault("meteor").unwrap_err().to_string();
        assert!(err.contains("straggler:<frac>x<slowdown>"));
        assert!(err.contains("crash:<rate>"));
        assert!(err.contains("flake:<prob>+<delay_s>"));
    }

    #[test]
    fn arrival_specs() {
        assert_eq!(
            parse_arrivals("fixed:300").unwrap(),
            ArrivalProcess::FixedInterval { interval_s: 300 }
        );
        assert_eq!(
            parse_arrivals("burst:5x900").unwrap(),
            ArrivalProcess::Bursty { burst: 5, gap_s: 900 }
        );
        assert_eq!(
            parse_arrivals("poisson:120").unwrap(),
            ArrivalProcess::Poisson { mean_gap_s: 120.0 }
        );
        assert!(parse_arrivals("burst:0x900").is_err());
        assert!(parse_arrivals("burst:5").is_err());
        assert!(parse_arrivals("poisson:-1").is_err());
        assert!(parse_arrivals("sometimes").is_err());
    }

    #[test]
    fn usage_lists_every_sweep_grid() {
        // the help text is rendered from SWEEP_GRIDS itself, so a new
        // grid (or a rename) can never leave the usage text stale
        let text = usage();
        assert!(!text.contains("{sweep-grids}"), "placeholder must be spliced out");
        let joined = crate::experiments::parallel::SWEEP_GRIDS.join(" | ");
        assert!(text.contains(&joined), "usage must list the sweep grids verbatim");
        for grid in crate::experiments::parallel::SWEEP_GRIDS {
            assert!(text.contains(grid), "usage is missing sweep grid '{grid}'");
        }
        assert!(crate::experiments::parallel::SWEEP_GRIDS.contains(&"stream"));
    }

    #[test]
    fn parses_stream_flag() {
        let c = parse(&argv("scenario --stream 1000x100 --smoke")).unwrap();
        assert_eq!(c.stream.as_deref(), Some("1000x100"));
        assert!(c.smoke);
        assert_eq!(parse_stream("1000x100").unwrap(), (1000, 100));
        assert_eq!(parse_stream("1x1").unwrap(), (1, 1));
        assert!(parse_stream("1000").is_err(), "needs the <n>x<m> shape");
        assert!(parse_stream("0x100").is_err(), "zero workloads rejected");
        assert!(parse_stream("100x0").is_err(), "zero tasks rejected");
        assert!(parse_stream("manyxfew").is_err());
        assert!(parse(&argv("scenario --stream")).is_err(), "--stream needs a value");
    }

    #[test]
    fn config_overrides_apply() {
        let c = parse(&argv("run --set control.alpha=7 --seed 3")).unwrap();
        let cfg = build_config(&c).unwrap();
        assert_eq!(cfg.control.alpha, 7.0);
        assert_eq!(cfg.seed, 3);
    }
}
