//! Fig. 6 / Fig. 7 / Table II: CUS-estimator comparison.
//!
//! One AIMD run of the full §V-A suite per monitoring interval; the
//! Kalman bank drives scheduling while ad-hoc and ARMA estimators run
//! passively on the *same* measurement stream, giving a controlled
//! comparison (identical measurements for all three estimators — the
//! figures overlay them on one axis, as in the paper).

use std::collections::BTreeMap;

use crate::config::Config;
use crate::estimation::EstimatorKind;
use crate::metrics::RunMetrics;
use crate::platform::{run_experiment, RunOpts};
use crate::util::stats;
use crate::util::table::{ascii_chart, fmt_mmss, write_csv, Table};
use crate::workload::{paper_suite, App};

/// Run the suite under AIMD/Kalman at a given monitoring interval.
fn run_suite(cfg: &Config, monitor_s: u64) -> anyhow::Result<RunMetrics> {
    let mut cfg = cfg.clone();
    cfg.control.monitor_interval_s = monitor_s;
    let suite = paper_suite(cfg.seed);
    let opts = RunOpts {
        fixed_ttc_s: Some(super::cost::TTC_LONG_S),
        horizon_s: 12 * 3600,
        ..Default::default()
    };
    run_experiment(cfg, suite, opts)
}

/// Fig. 6 (FFMPEG) / Fig. 7 (SIFT): convergence trace of a representative
/// workload of `app` under 1-min monitoring.
pub fn run_fig(cfg: &Config, app: App, name: &str) -> anyhow::Result<String> {
    let suite = paper_suite(cfg.seed);
    let metrics = run_suite(cfg, 60)?;
    // representative workload: the largest of the class (longest-running,
    // clearest convergence shape)
    let wid = suite
        .iter()
        .filter(|w| w.app == app)
        .max_by_key(|w| w.n_tasks())
        .map(|w| w.id)
        .ok_or_else(|| anyhow::anyhow!("no workload of class {app:?}"))?;
    let tr = &metrics.traces[&(wid, 0)];
    let arrived = metrics.outcomes[wid].arrived_at;
    let rel = |pts: &[(u64, f64)]| -> Vec<(f64, f64)> {
        pts.iter()
            .map(|&(t, b)| ((t.saturating_sub(arrived)) as f64 / 60.0, b))
            .collect()
    };
    let kalman = rel(&tr.kalman);
    let adhoc = rel(&tr.adhoc);
    let arma = rel(&tr.arma);
    let chart = ascii_chart(
        &format!(
            "{name} — CUS estimate convergence, workload w{wid:02} ({}), 1-min monitoring",
            suite[wid].name
        ),
        &[("Kalman", &kalman), ("Ad-hoc", &adhoc), ("ARMA", &arma)],
        70,
        14,
    );
    write_csv(
        &format!("{}/{name}.csv", super::OUT_DIR),
        "minutes",
        &[("kalman", &kalman), ("adhoc", &adhoc), ("arma", &arma)],
    )?;
    let mut lines = String::new();
    for (label, t_init) in [
        ("Kalman", tr.kalman_t_init),
        ("Ad-hoc", tr.adhoc_t_init),
        ("ARMA", tr.arma_t_init),
    ] {
        match t_init {
            Some(t) => lines.push_str(&format!(
                "{label}: reliable estimate at {} after arrival\n",
                fmt_mmss((t - arrived) as f64)
            )),
            None => lines.push_str(&format!("{label}: did not converge\n")),
        }
    }
    if let Some(fin) = tr.final_measured {
        lines.push_str(&format!("final measured CUS/item: {fin:.2}\n"));
    }
    let out = format!("{chart}{lines}");
    println!("{out}");
    Ok(out)
}

/// Which Table II class a workload belongs to.
fn class_of(app: App) -> Option<&'static str> {
    match app {
        App::FaceDetection => Some("Face Detection"),
        App::Transcode => Some("Transcoding"),
        App::Brisk => Some("Feat. Extraction"),
        App::SiftMatlab => Some("SIFT"),
        _ => None,
    }
}

struct Cell {
    times: Vec<f64>,
    maes: Vec<f64>,
}

/// Table II: average time-to-reliable-estimate and percentile MAE, per
/// workload class and estimator, for 5-min and 1-min monitoring.
pub fn run_table2(cfg: &Config) -> anyhow::Result<String> {
    let suite = paper_suite(cfg.seed);
    let mut per_interval: BTreeMap<u64, BTreeMap<(&str, EstimatorKind), Cell>> = BTreeMap::new();
    for &interval in &[300u64, 60u64] {
        let metrics = run_suite(cfg, interval)?;
        let slot = per_interval.entry(interval).or_default();
        for (w, spec) in suite.iter().enumerate() {
            let class = match class_of(spec.app) {
                Some(c) => c,
                None => continue,
            };
            let tr = match metrics.traces.get(&(w, 0)) {
                Some(t) => t,
                None => continue,
            };
            let arrived = metrics.outcomes[w].arrived_at;
            for kind in EstimatorKind::ALL {
                let cell = slot
                    .entry((class, kind))
                    .or_insert_with(|| Cell { times: vec![], maes: vec![] });
                if let Some(t) = tr.time_to_estimate(kind, arrived) {
                    cell.times.push(t);
                }
                if let Some(m) = tr.mae_pct(kind) {
                    cell.maes.push(m);
                }
            }
        }
    }

    let classes = ["Face Detection", "Transcoding", "Feat. Extraction", "SIFT"];
    let mut t = Table::new(vec![
        "class / estimator",
        "5-min time",
        "5-min MAE (%)",
        "1-min time",
        "1-min MAE (%)",
        "time reduction (%)",
    ]);
    let mut overall: BTreeMap<(u64, EstimatorKind), (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for class in classes {
        for kind in EstimatorKind::ALL {
            let get = |iv: u64| -> (f64, f64) {
                per_interval
                    .get(&iv)
                    .and_then(|m| m.get(&(class, kind)))
                    .map(|c| {
                        (
                            if c.times.is_empty() { f64::NAN } else { stats::mean(&c.times) },
                            if c.maes.is_empty() { f64::NAN } else { stats::mean(&c.maes) },
                        )
                    })
                    .unwrap_or((f64::NAN, f64::NAN))
            };
            let (t5, m5) = get(300);
            let (t1, m1) = get(60);
            for (iv, tv, mv) in [(300u64, t5, m5), (60, t1, m1)] {
                let e = overall.entry((iv, kind)).or_default();
                if tv.is_finite() {
                    e.0.push(tv);
                }
                if mv.is_finite() {
                    e.1.push(mv);
                }
            }
            let red = if t5 > 0.0 { 100.0 * (t5 - t1) / t5 } else { f64::NAN };
            let fmt_t = |x: f64| if x.is_finite() { fmt_mmss(x) } else { "–".to_string() };
            let fmt_p = |x: f64| if x.is_finite() { format!("{x:.1}") } else { "–".to_string() };
            t.row(vec![
                format!("{class} / {}", kind.name()),
                fmt_t(t5),
                fmt_p(m5),
                fmt_t(t1),
                fmt_p(m1),
                fmt_p(red),
            ]);
        }
    }
    // overall average block
    let mut summary = String::new();
    for kind in EstimatorKind::ALL {
        let (t5v, m5v) = overall.get(&(300, kind)).cloned().unwrap_or_default();
        let (t1v, m1v) = overall.get(&(60, kind)).cloned().unwrap_or_default();
        let (t5, m5) = (stats::mean(&t5v), stats::mean(&m5v));
        let (t1, m1) = (stats::mean(&t1v), stats::mean(&m1v));
        let red = if t5 > 0.0 { 100.0 * (t5 - t1) / t5 } else { f64::NAN };
        t.row(vec![
            format!("Overall Average / {}", kind.name()),
            fmt_mmss(t5),
            format!("{m5:.1}"),
            fmt_mmss(t1),
            format!("{m1:.1}"),
            format!("{red:.1}"),
        ]);
        summary.push_str(&format!(
            "{}: 1-min avg time {} MAE {:.1}%\n",
            kind.name(),
            fmt_mmss(t1),
            m1
        ));
    }
    let out = format!("{}{}", t.render(), summary);
    println!("{out}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smoke-scale variant of the Table II pipeline (full suite runs are
    /// exercised by `repro`; this keeps `cargo test` fast).
    #[test]
    fn class_mapping_covers_suite() {
        let suite = paper_suite(1);
        let mapped = suite.iter().filter(|w| class_of(w.app).is_some()).count();
        assert_eq!(mapped, 30);
    }
}
