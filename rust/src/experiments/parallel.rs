//! Parallel experiment runner: fan independent scenario runs across
//! cores.
//!
//! The companion paper (Doyle et al., arXiv:1604.04804) sweeps
//! estimator × policy × workload grids; every cell is an independent
//! deterministic simulation, so the whole sweep is embarrassingly
//! parallel. [`run_many`] is a rayon-style scoped worker pool over a
//! shared atomic work index (the offline vendor set has no rayon; the
//! pool is `std::thread::scope` + `AtomicUsize`, and swapping the body
//! of `run_many` for `rayon::par_iter` is a three-line change if the
//! vendor set ever gains it).
//!
//! **Determinism**: each [`RunSpec`] carries a self-contained
//! [`Scenario`] (own config/seed, own suite), and every simulation is a
//! pure function of it. Results are returned in spec order regardless of
//! which worker ran which spec or in what order they finished, so a
//! sweep is bit-identical across thread counts — `tests/determinism.rs`
//! pins sequential == 2 threads == 8 threads, including a
//! spot-reclamation scenario (revocations come from the seeded market).
//!
//! Grid cells run with estimator-trace recording **off**: the traces are
//! never read by sweep reporting and are the largest per-tick allocation
//! source (rust/BENCHMARKS.md).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::Config;
use crate::coordinator::PolicyKind;
use crate::estimation::{BankCache, EstimatorKind};
use crate::metrics::RunMetrics;
use crate::platform::{RunOpts, Scenario, ScenarioBuilder};
use crate::workload::{paper_suite, WorkloadSpec};

/// One cell of an experiment grid: a fully self-contained scenario plus
/// its display label.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub label: String,
    pub scenario: Scenario,
}

impl RunSpec {
    pub fn new(label: impl Into<String>, scenario: Scenario) -> Self {
        RunSpec { label: label.into(), scenario }
    }

    /// Compatibility constructor over the `RunOpts` shim (fixed-interval
    /// arrivals, fault-free spot fleet).
    pub fn from_opts(
        label: impl Into<String>,
        cfg: Config,
        suite: Vec<WorkloadSpec>,
        opts: RunOpts,
    ) -> Self {
        RunSpec::new(label, Scenario::from_opts(cfg, suite, opts))
    }

    /// Execute this cell (pure in its inputs) through the process-wide
    /// bank cache.
    pub fn execute(&self) -> anyhow::Result<RunMetrics> {
        self.scenario.run()
    }

    /// Execute this cell resolving its estimator bank through an
    /// explicit shared [`BankCache`] — the N cells of a grid that share
    /// a (W, K, estimator, params) bank shape pay backend selection
    /// once. Cached and uncached execution are bit-identical
    /// (`estimation::cache` determinism pin).
    pub fn execute_with_cache(&self, cache: &BankCache) -> anyhow::Result<RunMetrics> {
        self.scenario.run_with_cache(cache)
    }

    /// Total tasks this cell simulates (throughput accounting).
    pub fn n_tasks(&self) -> usize {
        self.scenario.n_tasks()
    }
}

/// Default worker count: one per core, at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Evaluate `f(0..n)` on a pool of `threads` scoped workers pulling
/// indices from a shared atomic counter (work-stealing-lite: the
/// counter is the one queue). Results land in pre-sized **per-index
/// slots**, so collection never serializes workers on a shared lock
/// (the pre-PR-4 version funneled every result through one
/// `Mutex<Vec>`): each slot's mutex is touched by exactly the one
/// worker that claimed its index, making every lock acquisition
/// uncontended, and index order holds by construction — no post-sort.
/// `threads <= 1` runs inline with no pool.
pub fn run_many<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| {
            s.into_inner()
                .unwrap()
                .expect("every claimed index writes its slot before the scope joins")
        })
        .collect()
}

/// Run every spec of a grid, `threads`-wide, through the process-wide
/// bank cache; results in spec order.
pub fn run_specs(specs: &[RunSpec], threads: usize) -> anyhow::Result<Vec<RunMetrics>> {
    run_specs_with_cache(specs, threads, BankCache::global())
}

/// Run every spec of a grid, `threads`-wide, sharing one explicit
/// [`BankCache`] across all cells; results in spec order.
pub fn run_specs_with_cache(
    specs: &[RunSpec],
    threads: usize,
    cache: &BankCache,
) -> anyhow::Result<Vec<RunMetrics>> {
    run_many(specs.len(), threads, |i| specs[i].execute_with_cache(cache))
        .into_iter()
        .collect()
}

/// Shared base for the §V-C grids: 5-minute monitoring, paper suite,
/// traces off (sweeps never read them).
fn grid_cell(base: &Config, suite: &[WorkloadSpec]) -> ScenarioBuilder {
    ScenarioBuilder::new(base.clone())
        .workloads(suite.to_vec())
        .horizon(16 * 3600)
        .record_traces(false)
}

/// The default cost-experiment grid (§V-C / Table III): the 5 scaling
/// methods × 2 fixed TTCs over the paper suite, 5-minute monitoring.
pub fn cost_grid(cfg: &Config) -> Vec<RunSpec> {
    let mut base = cfg.clone();
    base.control.monitor_interval_s = 300;
    let suite = paper_suite(base.seed);
    let mut specs = vec![];
    for &ttc in &[super::cost::TTC_LONG_S, super::cost::TTC_SHORT_S] {
        let as_kind = if ttc == super::cost::TTC_LONG_S {
            PolicyKind::AmazonAs1
        } else {
            PolicyKind::AmazonAs10
        };
        for (name, policy, fixed_ttc) in [
            ("aimd", PolicyKind::Aimd, Some(ttc)),
            ("reactive", PolicyKind::Reactive, Some(ttc)),
            ("mwa", PolicyKind::Mwa, Some(ttc)),
            ("lr", PolicyKind::Lr, Some(ttc)),
            ("amazon-as", as_kind, None),
        ] {
            specs.push(RunSpec::new(
                format!("cost/{name}/ttc{ttc}"),
                grid_cell(&base, &suite)
                    .policy(policy)
                    .estimator(EstimatorKind::Kalman)
                    .fixed_ttc(fixed_ttc)
                    .build(),
            ));
        }
    }
    specs
}

/// Estimator-shootout grid (Table II axis): each estimator drives the
/// same suite.
pub fn estimator_grid(cfg: &Config) -> Vec<RunSpec> {
    let mut base = cfg.clone();
    base.control.monitor_interval_s = 300;
    let suite = paper_suite(base.seed);
    EstimatorKind::ALL
        .iter()
        .map(|&estimator| {
            RunSpec::new(
                format!("estimator/{}", estimator.name()),
                grid_cell(&base, &suite)
                    .estimator(estimator)
                    .fixed_ttc(Some(super::cost::TTC_LONG_S))
                    .build(),
            )
        })
        .collect()
}

/// Seed-sweep / ablation grid: `n` independent replicas with
/// deterministic per-run seeds derived from the master seed, each with
/// its own suite realization.
pub fn seed_grid(cfg: &Config, n: usize) -> Vec<RunSpec> {
    (0..n)
        .map(|i| {
            let mut c = cfg.clone();
            c.control.monitor_interval_s = 300;
            c.seed = cfg.seed.wrapping_add(i as u64);
            let suite = paper_suite(c.seed);
            RunSpec::new(
                format!("seed/{}", c.seed),
                grid_cell(&c, &suite)
                    .fixed_ttc(Some(super::cost::TTC_LONG_S))
                    .build(),
            )
        })
        .collect()
}

/// Run a named grid and render a summary table (the `dithen sweep`
/// subcommand).
pub fn run_sweep(name: &str, cfg: &Config, threads: usize) -> anyhow::Result<String> {
    let specs = match name {
        "cost" => cost_grid(cfg),
        "estimators" => estimator_grid(cfg),
        "seeds" => seed_grid(cfg, 8),
        "fleet" => super::heterogeneous::grid(cfg, 6, 100, 12 * 3600),
        other => anyhow::bail!("unknown sweep '{other}' (use cost | estimators | seeds | fleet)"),
    };
    let cache = BankCache::global();
    let cache_before = cache.stats();
    let t0 = std::time::Instant::now();
    let results = run_specs(&specs, threads)?;
    let wall = t0.elapsed().as_secs_f64();
    let cache_after = cache.stats();
    let mut table = crate::util::table::Table::new(vec![
        "run",
        "cost ($)",
        "max inst",
        "TTC (%)",
        "finished",
    ]);
    let mut tasks = 0usize;
    for (spec, m) in specs.iter().zip(&results) {
        tasks += spec.n_tasks();
        table.row(vec![
            spec.label.clone(),
            format!("{:.3}", m.total_cost),
            format!("{}", m.max_instances),
            format!("{:.0}", 100.0 * m.ttc_compliance()),
            crate::util::table::fmt_hm(m.finished_at as f64),
        ]);
    }
    let summary = format!(
        "{} runs / {tasks} simulated tasks in {wall:.2}s on {threads} threads ({:.0} tasks/s) | \
         bank cache: {} cold builds / {} hits\n",
        specs.len(),
        tasks as f64 / wall.max(1e-9),
        cache_after.cold_builds - cache_before.cold_builds,
        cache_after.hits - cache_before.hits,
    );
    let out = format!("{}{summary}", table.render());
    println!("{out}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::App;

    fn tiny_specs(n: usize) -> Vec<RunSpec> {
        let rng = Rng::new(5);
        (0..n)
            .map(|i| {
                let mut cfg = Config::paper_defaults();
                cfg.use_xla = false;
                cfg.control.n_min = 4.0;
                cfg.seed = 100 + i as u64;
                RunSpec::from_opts(
                    format!("tiny/{i}"),
                    cfg,
                    vec![WorkloadSpec::generate(0, App::FaceDetection, 15, None, &rng)],
                    RunOpts {
                        fixed_ttc_s: Some(3600),
                        arrival_interval_s: 60,
                        horizon_s: 4 * 3600,
                        ..Default::default()
                    },
                )
            })
            .collect()
    }

    #[test]
    fn run_many_preserves_index_order() {
        let out = run_many(64, 8, |i| i * 3);
        assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn run_many_handles_edge_sizes() {
        assert!(run_many(0, 4, |i| i).is_empty());
        assert_eq!(run_many(1, 16, |i| i + 7), vec![7]);
        assert_eq!(run_many(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn parallel_equals_sequential() {
        let specs = tiny_specs(4);
        let seq = run_specs(&specs, 1).unwrap();
        let par = run_specs(&specs, 4).unwrap();
        assert_eq!(seq, par, "thread count changed simulation results");
    }

    /// Cache-contention pin: 8 workers over cells that all share one
    /// (W, K, estimator, params) bank shape — every cell after the
    /// first resolves its bank from the shared cache, concurrently —
    /// must produce exactly the sequential results.
    #[test]
    fn contended_cache_is_thread_count_invariant() {
        let specs = tiny_specs(8); // same suite shape per cell => one variant
        let seq_cache = BankCache::new();
        let seq = run_specs_with_cache(&specs, 1, &seq_cache).unwrap();
        let par_cache = BankCache::new();
        let par = run_specs_with_cache(&specs, 8, &par_cache).unwrap();
        assert_eq!(seq, par, "shared bank cache changed simulation results");
        for (name, cache) in [("sequential", &seq_cache), ("parallel", &par_cache)] {
            let s = cache.stats();
            assert_eq!(s.cold_builds, 1, "{name}: cells share one bank shape");
            assert_eq!(s.hits, specs.len() as u64 - 1, "{name}: all later cells must hit");
        }
    }

    fn assert_labels_unique(specs: &[RunSpec]) {
        let mut labels: Vec<&str> = specs.iter().map(|s| s.label.as_str()).collect();
        labels.sort_unstable();
        let n = labels.len();
        labels.dedup();
        assert_eq!(labels.len(), n, "duplicate sweep labels");
    }

    /// Mirror of `grids_are_well_formed` for the heterogeneous fleet
    /// grid (`dithen sweep fleet`): labels unique, every cell simulates
    /// work, traces stay off in sweeps.
    #[test]
    fn fleet_grid_is_well_formed() {
        let cfg = Config::paper_defaults();
        let g = crate::experiments::heterogeneous::grid(&cfg, 3, 10, 3600);
        assert!(!g.is_empty());
        assert_labels_unique(&g);
        assert!(g.iter().all(|s| s.n_tasks() > 0));
        assert!(g.iter().all(|s| !s.scenario.record_traces));
        // every cell must survive scenario validation (the mixed+bids
        // cell carries the bids reclaim-pools requires)
        for s in &g {
            s.scenario.validate().unwrap_or_else(|e| panic!("{}: {e}", s.label));
        }
    }

    #[test]
    fn grids_are_well_formed() {
        let cfg = Config::paper_defaults();
        let g = cost_grid(&cfg);
        assert_eq!(g.len(), 10); // 5 policies x 2 TTCs
        assert_labels_unique(&g);
        assert!(g.iter().all(|s| s.n_tasks() > 0));
        // sweeps never read traces; recording stays off (perf)
        assert!(g.iter().all(|s| !s.scenario.record_traces));
        assert_eq!(estimator_grid(&cfg).len(), 3);
        assert_labels_unique(&estimator_grid(&cfg));
        let seeds = seed_grid(&cfg, 4);
        assert_eq!(seeds.len(), 4);
        assert_labels_unique(&seeds);
        // per-run seeds are distinct and deterministic
        let s: Vec<u64> = seeds.iter().map(|r| r.scenario.cfg.seed).collect();
        assert_eq!(s, vec![cfg.seed, cfg.seed + 1, cfg.seed + 2, cfg.seed + 3]);
    }
}
